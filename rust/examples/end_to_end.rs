//! End-to-end driver (the repo's full-system validation, DESIGN.md §4):
//! trains VQ-GNN and all four baselines on the arxiv_sim benchmark,
//! logging loss / validation curves, then reports test metrics, per-step
//! memory and inference latency — every layer of the stack (rust
//! coordinator → PJRT → XLA-compiled JAX/Pallas artifacts) composing on a
//! real workload.  Results are recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example end_to_end [epochs]

use std::rc::Rc;

use vq_gnn::coordinator::edge_trainer::{Baseline, EdgeTrainer};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::{Dataset, Split};
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);
    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let mut rt = Runtime::new()?;
    let ds = Rc::new(Dataset::generate(&man.datasets["arxiv_sim"], 42));
    println!(
        "arxiv_sim: n={} arcs={} f={} classes={} (scale-free citation stand-in)\n",
        ds.n(),
        ds.graph.num_arcs(),
        ds.cfg.f_in,
        ds.cfg.n_classes
    );

    // ---- VQ-GNN with loss-curve logging --------------------------------
    println!("== VQ-GNN (GCN backbone, b={}, k={}) ==", man.train.b, man.train.k);
    let mut vq = VqTrainer::new(&mut rt, &man, ds.clone(), "gcn", "",
                                NodeStrategy::Nodes, 1)?;
    for epoch in 0..epochs {
        let loss = vq.epoch(&mut rt)?;
        let val = vq.evaluate(&mut rt, Split::Val)?;
        println!(
            "  epoch {epoch:>2}: loss {loss:.4}  val {val:.4}  ({:.1}s train)",
            vq.stats.train_secs
        );
    }
    let vq_test = vq.evaluate(&mut rt, Split::Test)?;

    // ---- Baselines ------------------------------------------------------
    let mut rows = vec![(
        "vq-gnn".to_string(),
        vq_test,
        vq.stats.train_secs,
        vq.stats.peak_step_bytes,
        vq.stats.messages_per_step,
    )];
    for (name, kind) in [
        ("full", Baseline::FullGraph),
        ("cluster", Baseline::ClusterGcn),
        ("saint", Baseline::SaintRw),
    ] {
        println!("== {name} ==");
        let mut tr = EdgeTrainer::new(&mut rt, &man, ds.clone(), "gcn", kind, 1)?;
        for epoch in 0..epochs {
            let loss = tr.epoch(&mut rt)?;
            if epoch % 5 == 4 {
                let val = tr.evaluate(&mut rt, Split::Val)?;
                println!("  epoch {epoch:>2}: loss {loss:.4}  val {val:.4}");
            }
        }
        let test = tr.evaluate(&mut rt, Split::Test)?;
        rows.push((
            name.to_string(),
            test,
            tr.stats.train_secs,
            tr.stats.peak_step_bytes,
            tr.stats.messages_per_step,
        ));
    }

    // ---- Inference latency ---------------------------------------------
    let nodes: Vec<u32> = (0..ds.n() as u32).collect();
    let t = std::time::Instant::now();
    vq.infer_nodes(&mut rt, &nodes)?;
    let vq_infer = t.elapsed().as_secs_f64();

    println!("\n| method | test acc | train s | peak step MB | msgs/step |");
    println!("|---|---|---|---|---|");
    for (name, acc, secs, bytes, msgs) in &rows {
        println!(
            "| {name} | {acc:.4} | {secs:.1} | {:.1} | {msgs} |",
            *bytes as f64 / 1e6
        );
    }
    println!("\nVQ-GNN full-graph inference ({} nodes): {vq_infer:.2}s", ds.n());
    println!(
        "runtime totals: {} executions, {:.1} MB shipped in, {:.1} MB out",
        rt.executions(),
        rt.bytes_in() as f64 / 1e6,
        rt.bytes_out() as f64 / 1e6
    );
    Ok(())
}
