//! Quickstart: train a GCN with VQ-GNN on a small synthetic citation graph
//! and compare it against the full-graph oracle — the 60-second tour of the
//! public API.
//!
//!   cargo run --release --example quickstart

use std::rc::Rc;

use vq_gnn::coordinator::edge_trainer::{Baseline, EdgeTrainer};
use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::{Dataset, Split};
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;

fn main() -> anyhow::Result<()> {
    // 1. Load the manifest (builtin registry when no AOT artifacts exist)
    //    and spin up the runtime (native CPU backend by default).
    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let mut rt = Runtime::new()?;

    // 2. Generate the tiny synthetic benchmark (deterministic).
    let ds = Rc::new(Dataset::generate(&man.datasets["tiny_sim"], 42));
    println!(
        "tiny_sim: {} nodes, {} arcs, {} classes",
        ds.n(),
        ds.graph.num_arcs(),
        ds.cfg.n_classes
    );

    // 3. Train VQ-GNN (mini-batches + codebooks, paper Alg. 1).
    let mut vq = VqTrainer::new(&mut rt, &man, ds.clone(), "gcn", "",
                                NodeStrategy::Nodes, 1)?;
    for epoch in 0..30 {
        let loss = vq.epoch(&mut rt)?;
        if epoch % 10 == 9 {
            let val = vq.evaluate(&mut rt, Split::Val)?;
            println!("  [vq]   epoch {epoch:>2}: loss {loss:.4}  val acc {val:.3}");
        }
    }
    let vq_test = vq.evaluate(&mut rt, Split::Test)?;

    // 4. Train the full-graph oracle for reference.
    let mut full = EdgeTrainer::new(&mut rt, &man, ds, "gcn",
                                    Baseline::FullGraph, 1)?;
    for _ in 0..120 {
        full.train_step(&mut rt)?;
    }
    let full_test = full.evaluate(&mut rt, Split::Test)?;

    println!("\ntest accuracy:  VQ-GNN {vq_test:.4}  vs  full-graph {full_test:.4}");
    println!(
        "per-step bytes: VQ-GNN {:.2} MB  vs  full-graph {:.2} MB",
        vq.stats.peak_step_bytes as f64 / 1e6,
        full.stats.peak_step_bytes as f64 / 1e6
    );
    Ok(())
}
