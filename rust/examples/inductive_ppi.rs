//! Inductive multilabel node classification (the paper's PPI setting):
//! val/test graphs are entirely unseen during training, so VQ-GNN must
//! assign fresh nodes to codewords by feature distance before inference
//! (paper §6 "one extra step"; implemented as a two-pass bootstrap).
//!
//!   cargo run --release --example inductive_ppi

use std::rc::Rc;

use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::{Dataset, Split};
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let mut rt = Runtime::new()?;
    let ds = Rc::new(Dataset::generate(&man.datasets["ppi_sim"], 42));
    let n_train = ds.nodes_in_split(Split::Train).len();
    let n_test = ds.nodes_in_split(Split::Test).len();
    println!(
        "ppi_sim: {} disjoint graphs, {} train / {} test nodes, multilabel {} classes",
        ds.cfg.n_graphs, n_train, n_test, ds.cfg.n_classes
    );

    let mut tr = VqTrainer::new(&mut rt, &man, ds, "sage", "",
                                NodeStrategy::Nodes, 7)?;
    for epoch in 0..12 {
        let loss = tr.epoch(&mut rt)?;
        println!("  epoch {epoch:>2}: loss {loss:.4}");
    }
    // evaluate() runs the inductive bootstrap internally: unseen nodes are
    // assigned per layer by feature columns, then refined with one sweep.
    let val = tr.evaluate(&mut rt, Split::Val)?;
    let test = tr.evaluate(&mut rt, Split::Test)?;
    println!("\nmicro-F1: val {val:.4}  test {test:.4} (unseen graphs)");
    Ok(())
}
