//! Link prediction with VQ-GNN (the paper's ogbl-collab setting):
//! held-out positive edges are scored against random negatives with the
//! Hits@50 protocol; training positives are intra-batch arcs.
//!
//!   cargo run --release --example link_prediction

use std::rc::Rc;

use vq_gnn::coordinator::vq_trainer::VqTrainer;
use vq_gnn::datasets::{Dataset, Split};
use vq_gnn::runtime::manifest::Manifest;
use vq_gnn::runtime::Runtime;
use vq_gnn::sampler::NodeStrategy;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let mut rt = Runtime::new()?;
    let ds = Rc::new(Dataset::generate(&man.datasets["collab_sim"], 42));
    println!(
        "collab_sim: {} nodes, {} message arcs, {} val / {} test held-out positives",
        ds.n(),
        ds.graph.num_arcs(),
        ds.val_pos.len(),
        ds.test_pos.len()
    );

    let mut tr = VqTrainer::new(&mut rt, &man, ds, "sage", "",
                                NodeStrategy::Edges, 3)?;
    for epoch in 0..15 {
        let loss = tr.epoch(&mut rt)?;
        if epoch % 5 == 4 {
            let hits = tr.evaluate(&mut rt, Split::Val)?;
            println!("  epoch {epoch:>2}: loss {loss:.4}  val Hits@50 {hits:.4}");
        }
    }
    let test = tr.evaluate(&mut rt, Split::Test)?;
    println!("\ntest Hits@50: {test:.4}");
    Ok(())
}
