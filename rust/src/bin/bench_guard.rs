//! Bench regression guard: diff a freshly-emitted `BENCH_hot_paths.json`
//! against the committed `BENCH_baseline.json` and print **non-fatal**
//! GitHub annotations for large regressions — the start of the
//! perf-trajectory tracking the ROADMAP asks for.
//!
//!   cargo run --release --bin bench_guard -- BENCH_baseline.json BENCH_hot_paths.json
//!
//! Rules (keys are matched recursively, joined with '.'):
//! - `*_ms` (timings, lower is better): warn when current > 1.5× baseline;
//! - `*_qps` / `*_per_sec` (throughput, higher is better): warn when
//!   current < baseline / 1.5.
//!
//! Always exits 0: bench noise across runners must never break CI — the
//! annotations are the signal.  A missing/keyless baseline prints a notice
//! explaining how to arm the guard (copy a CI `BENCH_hot_paths` artifact
//! to `BENCH_baseline.json`).

use std::collections::BTreeMap;

use vq_gnn::util::json::Json;

const RATIO: f64 = 1.5;

fn collect(prefix: &str, j: &Json, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                let key = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                collect(&key, v, out);
            }
        }
        Json::Num(x) => {
            out.insert(prefix.to_string(), *x);
        }
        _ => {}
    }
}

fn load(path: &str) -> Option<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            println!("::warning::bench_guard: {path} is not valid JSON ({e}); skipping");
            return None;
        }
    };
    let mut out = BTreeMap::new();
    collect("", &j, &mut out);
    Some(out)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (base_path, cur_path) = match args.as_slice() {
        [b, c] => (b.as_str(), c.as_str()),
        _ => {
            eprintln!("usage: bench_guard BASELINE.json CURRENT.json");
            return;
        }
    };
    let Some(base) = load(base_path) else {
        println!(
            "::notice::bench_guard: no readable baseline at {base_path} — copy a CI \
             BENCH_hot_paths artifact to {base_path} to arm the regression guard"
        );
        return;
    };
    let Some(cur) = load(cur_path) else {
        println!("::warning::bench_guard: no current bench output at {cur_path}");
        return;
    };

    let mut compared = 0usize;
    let mut regressions = 0usize;
    for (key, &b) in &base {
        let Some(&c) = cur.get(key) else { continue };
        let slower_is_worse = key.ends_with("_ms");
        let faster_is_better = key.ends_with("_qps") || key.ends_with("_per_sec");
        if !slower_is_worse && !faster_is_better {
            continue; // shape/config numbers (n, k, threads, speedups, ...)
        }
        compared += 1;
        if faster_is_better && c <= 0.0 && b > 0.0 {
            // throughput collapsed to zero — the worst regression must not
            // be silently dropped just because the ratio is undefined
            regressions += 1;
            println!("::warning::bench regression: {key} throughput collapsed ({b:.3} -> {c:.3})");
            continue;
        }
        if b <= 0.0 || c <= 0.0 {
            println!("::notice::bench_guard: {key} non-positive ({b:.3} -> {c:.3}); no ratio");
            continue;
        }
        let ratio = if slower_is_worse { c / b } else { b / c };
        let verdict = if ratio > RATIO {
            regressions += 1;
            println!(
                "::warning::bench regression: {key} {} ({b:.3} -> {c:.3}, {ratio:.2}x \
                 worse than baseline)",
                if slower_is_worse { "slowed down" } else { "throughput dropped" }
            );
            "REGRESSED"
        } else if ratio < 1.0 / RATIO {
            "improved"
        } else {
            "ok"
        };
        println!("  {key:<44} base {b:>12.3}  cur {c:>12.3}  [{verdict}]");
    }
    if compared == 0 {
        println!(
            "::notice::bench_guard: baseline {base_path} shares no timing/throughput keys \
             with {cur_path} — refresh it from a CI BENCH_hot_paths artifact"
        );
    } else {
        println!(
            "bench_guard: {compared} keys compared, {regressions} regression(s) beyond \
             {RATIO}x (non-fatal)"
        );
    }
}
