//! Bench regression guard: diff a freshly-emitted `BENCH_hot_paths.json`
//! against the committed `BENCH_baseline.json` — the perf-trajectory
//! tracking the ROADMAP asks for.
//!
//!   cargo run --release --bin bench_guard -- BENCH_baseline.json BENCH_hot_paths.json
//!
//! Rules (keys are matched recursively, joined with '.'):
//! - `*_ms` (timings, lower is better): warn when current > 1.5× baseline;
//!   `*_ms_r<tag>` (per-offered-rate open-loop latencies like
//!   `serve_open_loop_p99_ms_rhigh`) and `*_ms_s<N>` (per-shard-count
//!   timings like `train_step_sharded_ms_s2`) count too;
//! - `*_qps` / `*_per_sec` / `*_qps_t<N>` / `*_qps_s<N>` (throughput,
//!   incl. the per-pool-width and per-shard-count serving keys, higher is
//!   better): warn when current < baseline / 1.5;
//! - `*_alloc_bytes` (steady-state step allocation, lower is better —
//!   requires the `alloc-count` bench feature): warn when current >
//!   1.5× baseline, and when an allocation-free baseline (0 bytes) grows
//!   any allocation at all;
//! - `*_shed_rate` (fraction of offered load refused under saturation,
//!   in [0, 1]): compared on ABSOLUTE distance, not ratio — a shed rate
//!   is a proportion, so warn when current > baseline + 0.15 (a baseline
//!   of 0 would make any ratio rule degenerate);
//! - a timing/throughput/allocation key present in the baseline but
//!   MISSING from the fresh run is **fatal** (exit 1): a silently dropped
//!   bench key would retire its regression coverage without anyone
//!   noticing — guard keys may only be removed by refreshing the baseline.
//!
//! Ratio verdicts stay non-fatal: bench noise across runners must never
//! break CI — the annotations are the signal.  A missing/keyless baseline
//! prints a notice explaining how to arm the guard (copy a CI
//! `BENCH_hot_paths` artifact to `BENCH_baseline.json`).

use std::collections::BTreeMap;

use vq_gnn::util::json::Json;

const RATIO: f64 = 1.5;

/// Flatten nested objects into dotted numeric keys.  Only `Json::Num`
/// leaves are kept: string fields (`bench`, `mode`, `note`,
/// `simd_dispatch`) are annotations by design — they document the run
/// (or, in the baseline, the expectations) without entering the ratio or
/// missing-key rules.
fn collect(prefix: &str, j: &Json, out: &mut BTreeMap<String, f64>) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                let key = if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                collect(&key, v, out);
            }
        }
        Json::Num(x) => {
            out.insert(prefix.to_string(), *x);
        }
        _ => {}
    }
}

fn load(path: &str) -> Option<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            println!("::warning::bench_guard: {path} is not valid JSON ({e}); skipping");
            return None;
        }
    };
    let mut out = BTreeMap::new();
    collect("", &j, &mut out);
    Some(out)
}

/// Lower-is-better keys: timings (`*_ms`, nanosecond micro-costs `*_ns`
/// like `obs_record_overhead_ns`, the per-offered-rate open-loop
/// variants `*_ms_r<tag>`, and the per-shard-count variants `*_ms_s<N>`
/// like `train_step_sharded_ms_s2`) and per-step allocation bytes.
fn lower_is_better(key: &str) -> bool {
    if key.ends_with("_ms") || key.ends_with("_ns") || key.ends_with("_alloc_bytes") {
        return true;
    }
    if let Some((_, tag)) = key.rsplit_once("_ms_r") {
        if !tag.is_empty() && tag.bytes().all(|b| b.is_ascii_alphanumeric()) {
            return true;
        }
    }
    match key.rsplit_once("_ms_s") {
        Some((_, n)) => !n.is_empty() && n.bytes().all(|b| b.is_ascii_digit()),
        None => false,
    }
}

/// Absolute-tolerance keys: shed rates are proportions in [0, 1], so a
/// ratio rule degenerates around zero — compare absolute distance.
fn absolute_tolerance(key: &str) -> bool {
    key.ends_with("_shed_rate")
}

const SHED_TOLERANCE: f64 = 0.15;

/// Higher-is-better keys: throughput — `*_qps`, `*_per_sec`, the
/// per-pool-width variants `*_qps_t<N>` (`serve_concurrent_qps_t4`), and
/// the per-shard-count variants `*_qps_s<N>` (`serve_sharded_qps_s2`).
fn higher_is_better(key: &str) -> bool {
    if key.ends_with("_qps") || key.ends_with("_per_sec") {
        return true;
    }
    if let Some((_, n)) = key.rsplit_once("_qps_t") {
        if !n.is_empty() && n.bytes().all(|b| b.is_ascii_digit()) {
            return true;
        }
    }
    match key.rsplit_once("_qps_s") {
        Some((_, n)) => !n.is_empty() && n.bytes().all(|b| b.is_ascii_digit()),
        None => false,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (base_path, cur_path) = match args.as_slice() {
        [b, c] => (b.as_str(), c.as_str()),
        _ => {
            eprintln!("usage: bench_guard BASELINE.json CURRENT.json");
            return;
        }
    };
    let Some(base) = load(base_path) else {
        println!(
            "::notice::bench_guard: no readable baseline at {base_path} — copy a CI \
             BENCH_hot_paths artifact to {base_path} to arm the regression guard"
        );
        return;
    };
    let Some(cur) = load(cur_path) else {
        println!("::warning::bench_guard: no current bench output at {cur_path}");
        return;
    };

    let mut compared = 0usize;
    let mut regressions = 0usize;
    let mut missing: Vec<&str> = Vec::new();
    for (key, &b) in &base {
        let abs = absolute_tolerance(key);
        let low = lower_is_better(key);
        let high = higher_is_better(key);
        if !abs && !low && !high {
            continue; // shape/config numbers (n, k, threads, speedups, ...)
        }
        let Some(&c) = cur.get(key) else {
            missing.push(key);
            continue;
        };
        compared += 1;
        if abs {
            // shed rates: absolute distance, and only growth regresses
            // (shedding LESS under the same offered load is an improvement)
            let verdict = if c > b + SHED_TOLERANCE {
                regressions += 1;
                println!(
                    "::warning::bench regression: {key} shed rate grew \
                     ({b:.3} -> {c:.3}, tolerance +{SHED_TOLERANCE})"
                );
                "REGRESSED"
            } else if c + SHED_TOLERANCE < b {
                "improved"
            } else {
                "ok"
            };
            println!("  {key:<44} base {b:>12.3}  cur {c:>12.3}  [{verdict}]");
            continue;
        }
        if high && c <= 0.0 && b > 0.0 {
            // throughput collapsed to zero — the worst regression must not
            // be silently dropped just because the ratio is undefined
            regressions += 1;
            println!("::warning::bench regression: {key} throughput collapsed ({b:.3} -> {c:.3})");
            continue;
        }
        if b == 0.0 && c == 0.0 {
            // an allocation-free step staying allocation-free
            println!("  {key:<44} base {b:>12.3}  cur {c:>12.3}  [ok]");
            continue;
        }
        if low && b == 0.0 && c > 0.0 {
            // the arena path started allocating — a zero baseline has no
            // ratio, but this is exactly the regression the key exists for
            regressions += 1;
            println!(
                "::warning::bench regression: {key} was allocation-free, now {c:.0} bytes/step"
            );
            continue;
        }
        if b <= 0.0 || c <= 0.0 {
            println!("::notice::bench_guard: {key} non-positive ({b:.3} -> {c:.3}); no ratio");
            continue;
        }
        let ratio = if low { c / b } else { b / c };
        let verdict = if ratio > RATIO {
            regressions += 1;
            println!(
                "::warning::bench regression: {key} {} ({b:.3} -> {c:.3}, {ratio:.2}x \
                 worse than baseline)",
                if low { "got worse" } else { "throughput dropped" }
            );
            "REGRESSED"
        } else if ratio < 1.0 / RATIO {
            "improved"
        } else {
            "ok"
        };
        println!("  {key:<44} base {b:>12.3}  cur {c:>12.3}  [{verdict}]");
    }
    if compared == 0 && missing.is_empty() {
        println!(
            "::notice::bench_guard: baseline {base_path} shares no timing/throughput keys \
             with {cur_path} — refresh it from a CI BENCH_hot_paths artifact"
        );
    } else {
        println!(
            "bench_guard: {compared} keys compared, {regressions} regression(s) beyond \
             {RATIO}x (non-fatal)"
        );
    }
    if !missing.is_empty() {
        for key in &missing {
            println!(
                "::error::bench_guard: baseline key '{key}' is missing from {cur_path} — \
                 a guarded bench key was dropped (refresh {base_path} deliberately if this \
                 is intended)"
            );
        }
        std::process::exit(1);
    }
}
