//! `obs` — dependency-free observability: a [`Registry`] of named atomic
//! [`Counter`]s, [`Gauge`]s, and log-bucketed latency [`Histogram`]s, plus
//! a lightweight [`Stage`] span timer.
//!
//! Design constraints (the serve path is the customer):
//!
//! - **Never perturb the data path.**  Recording is a handful of relaxed
//!   atomic adds; a *disabled* registry hands out empty handles whose
//!   record calls are a single `Option` test — no `Instant::now()`, no
//!   atomics.  Served answers are byte-identical with metrics on or off
//!   (pinned by `tests/obs.rs`).
//! - **Sync by construction.**  Flush workers run on scoped threads, so
//!   every metric is an atomic cell; handles are `Arc`-shared and record
//!   via `&self` from any thread.  Handles are resolved ONCE at wiring
//!   time (engine build / trainer construction) — the hot path never
//!   touches the registry's name map.
//! - **Deterministic exposition.**  [`Registry::render_prometheus`] and
//!   [`Registry::to_json`] iterate `BTreeMap`s, so the scrape output is
//!   byte-stable for a given metric state (the STATS-frame acceptance
//!   criterion).
//!
//! # Histogram shape
//!
//! Fixed [`BUCKETS`] = 64 log-spaced buckets over nanoseconds: bucket 0
//! holds everything below 2^8 ns, then two sub-buckets per power of two
//! (boundaries 256, 384, 512, 768, 1024, ... ns), and the last bucket
//! saturates (everything ≥ ~2^39 ns ≈ 9 minutes).  Counts are exact;
//! `count`/`sum`/`max` are tracked exactly alongside, so `mean` and `max`
//! carry no bucketing error.  Quantiles are estimated as the midpoint of
//! the bucket holding the nearest-rank sample: for in-range values the
//! relative error is at most **25%** (worst case: the true value sits on
//! a bucket's lower edge whose width ratio is 1.5) — the bound
//! `tests/obs.rs` property-tests against an exact sort.  Histograms
//! [`Histogram::merge_into`] by bucket-wise addition, which is exactly
//! pooled recording (also property-tested) — per-worker aggregation
//! without locks.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Number of histogram buckets (fixed — merges never disagree on shape).
pub const BUCKETS: usize = 64;

/// Bucket 0 holds all values below `2^LO_BITS` nanoseconds.
const LO_BITS: u32 = 8;

/// Sub-buckets per power of two (1 bit → 2 sub-buckets, ratio ≤ 1.5).
const SUB_BITS: u32 = 1;

/// Bucket index of a nanosecond value (saturating at the last bucket).
#[inline]
fn bucket_of(ns: u64) -> usize {
    if ns < (1u64 << LO_BITS) {
        return 0;
    }
    let e = 63 - ns.leading_zeros(); // floor log2, >= LO_BITS
    let sub = ((ns >> (e - SUB_BITS)) & ((1 << SUB_BITS) - 1)) as usize;
    let idx = (((e - LO_BITS) as usize) << SUB_BITS) + sub + 1;
    idx.min(BUCKETS - 1)
}

/// Lower edge of a bucket in nanoseconds (bucket 0 starts at 0).
fn bucket_lo(idx: usize) -> u64 {
    if idx == 0 {
        return 0;
    }
    let oct = ((idx - 1) >> SUB_BITS) as u32 + LO_BITS;
    let sub = ((idx - 1) & ((1 << SUB_BITS) - 1)) as u64;
    (1u64 << oct) + sub * (1u64 << (oct - SUB_BITS))
}

/// Upper edge of a bucket (exclusive); the saturation bucket is unbounded
/// and reports its lower edge ×1.5 so midpoints stay finite.
fn bucket_hi(idx: usize) -> u64 {
    if idx + 1 < BUCKETS {
        bucket_lo(idx + 1)
    } else {
        bucket_lo(idx) + bucket_lo(idx) / 2
    }
}

/// Monotone event counter (relaxed atomic add).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in an atomic word).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Log-bucketed latency histogram over nanoseconds (see module docs for
/// the bucket layout and the 25% quantile error bound).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one nanosecond sample (a few relaxed atomic RMWs).
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record a wall-clock duration.
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Consistent-enough point-in-time copy for rendering (individual
    /// loads are relaxed; concurrent recording may skew cross-field
    /// totals by in-flight samples, which scraping tolerates).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }

    /// Fold this histogram into `dst` (bucket-wise add; max of maxes).
    /// Merging per-worker histograms equals pooled recording exactly.
    pub fn merge_into(&self, dst: &Histogram) {
        for i in 0..BUCKETS {
            let c = self.buckets[i].load(Ordering::Relaxed);
            if c > 0 {
                dst.buckets[i].fetch_add(c, Ordering::Relaxed);
            }
        }
        dst.count.fetch_add(self.count.load(Ordering::Relaxed), Ordering::Relaxed);
        dst.sum_ns.fetch_add(self.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        dst.max_ns.fetch_max(self.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// Plain (non-atomic) histogram snapshot: quantile/mean/max accessors.
#[derive(Debug, Clone, Copy)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { buckets: [0; BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl HistSnapshot {
    /// Fold `other` into this snapshot (bucket-wise add — identical to
    /// having recorded both sample sets into one histogram).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for i in 0..BUCKETS {
            self.buckets[i] += other.buckets[i];
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Nearest-rank quantile estimate in nanoseconds: the midpoint of the
    /// bucket holding the ⌈q·count⌉-th smallest sample (≤ 25% relative
    /// error in-range; the saturation bucket reports a finite midpoint).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for i in 0..BUCKETS {
            seen += self.buckets[i];
            if seen >= rank {
                return (bucket_lo(i) + bucket_hi(i)) / 2;
            }
        }
        self.max_ns // unreachable when fields are consistent
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }
}

/// RAII span timer: measures from construction to drop and records into
/// its histogram.  A handle from a disabled registry produces a no-op
/// stage that never reads the clock.
pub struct Stage {
    h: Option<Arc<Histogram>>,
    t0: Option<Instant>,
}

impl Stage {
    /// End the span now (drop does the same; this names the intent).
    pub fn stop(self) {}
}

impl Drop for Stage {
    fn drop(&mut self) {
        if let (Some(h), Some(t0)) = (&self.h, self.t0) {
            h.record_duration(t0.elapsed());
        }
    }
}

/// Cheap cloneable handle to a registered histogram (`None` = disabled).
#[derive(Clone, Default)]
pub struct HistHandle(Option<Arc<Histogram>>);

impl HistHandle {
    /// The permanently-disabled handle (records nothing, reads no clock).
    pub fn disabled() -> HistHandle {
        HistHandle(None)
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    pub fn record_ns(&self, ns: u64) {
        if let Some(h) = &self.0 {
            h.record(ns);
        }
    }

    pub fn record_duration(&self, d: std::time::Duration) {
        if let Some(h) = &self.0 {
            h.record_duration(d);
        }
    }

    /// Start a span; recording happens when the returned [`Stage`] drops.
    pub fn stage(&self) -> Stage {
        Stage { h: self.0.clone(), t0: self.0.as_ref().map(|_| Instant::now()) }
    }
}

/// Cheap cloneable handle to a registered counter (`None` = disabled).
#[derive(Clone, Default)]
pub struct CounterHandle(Option<Arc<Counter>>);

impl CounterHandle {
    pub fn disabled() -> CounterHandle {
        CounterHandle(None)
    }

    pub fn add(&self, n: u64) {
        if let Some(c) = &self.0 {
            c.add(n);
        }
    }
}

/// Cheap cloneable handle to a registered gauge (`None` = disabled).
#[derive(Clone, Default)]
pub struct GaugeHandle(Option<Arc<Gauge>>);

impl GaugeHandle {
    pub fn disabled() -> GaugeHandle {
        GaugeHandle(None)
    }

    pub fn set(&self, v: f64) {
        if let Some(g) = &self.0 {
            g.set(v);
        }
    }
}

/// Named-metric registry.  Registration (`hist`/`counter`/`gauge`) takes
/// a mutex and interns the name; the returned handles are lock-free.  A
/// [`Registry::disabled`] registry interns nothing and hands out empty
/// handles — the data-path cost of "metrics off" is one `Option` test.
pub struct Registry {
    enabled: bool,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            enabled: true,
            hists: Mutex::new(BTreeMap::new()),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
        }
    }

    /// A registry that registers nothing and hands out disabled handles.
    pub fn disabled() -> Registry {
        Registry { enabled: false, ..Registry::new() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Resolve (or create) the named histogram.  Same name → same cells,
    /// so independent wiring sites aggregate into one family.
    pub fn hist(&self, name: &str) -> HistHandle {
        if !self.enabled {
            return HistHandle(None);
        }
        let mut m = self.hists.lock().unwrap();
        let h = m.entry(name.to_string()).or_default();
        HistHandle(Some(h.clone()))
    }

    /// Resolve (or create) the named counter.
    pub fn counter(&self, name: &str) -> CounterHandle {
        if !self.enabled {
            return CounterHandle(None);
        }
        let mut m = self.counters.lock().unwrap();
        let c = m.entry(name.to_string()).or_default();
        CounterHandle(Some(c.clone()))
    }

    /// Resolve (or create) the named gauge.
    pub fn gauge(&self, name: &str) -> GaugeHandle {
        if !self.enabled {
            return GaugeHandle(None);
        }
        let mut m = self.gauges.lock().unwrap();
        let g = m.entry(name.to_string()).or_default();
        GaugeHandle(Some(g.clone()))
    }

    /// Prometheus text exposition, deterministic key order: counters as
    /// `<name>_total`, gauges bare, histograms as summaries
    /// (`<name>_seconds{quantile="..."}` + `_seconds_sum`/`_count`/
    /// `_seconds_max`).  Floats print with enough digits to round-trip
    /// the gauge exactly is not needed — 9 significant digits keeps the
    /// output stable and readable.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name}_total counter\n"));
            out.push_str(&format!("{name}_total {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {:.9}\n", g.get()));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let s = h.snapshot();
            out.push_str(&format!("# TYPE {name}_seconds summary\n"));
            for (label, q) in [("0.5", 0.5), ("0.9", 0.9), ("0.99", 0.99)] {
                out.push_str(&format!(
                    "{name}_seconds{{quantile=\"{label}\"}} {:.9}\n",
                    s.quantile_ns(q) as f64 / 1e9
                ));
            }
            out.push_str(&format!("{name}_seconds_sum {:.9}\n", s.sum_ns as f64 / 1e9));
            out.push_str(&format!("{name}_seconds_count {}\n", s.count));
            out.push_str(&format!("{name}_seconds_max {:.9}\n", s.max_ns as f64 / 1e9));
        }
        out
    }

    /// One compact human line per scrape for the CLI's `--metrics-every`
    /// report: every family as `name=value`, histograms as `p50/p99` in
    /// ms, in deterministic key order.
    pub fn render_line(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            parts.push(format!("{name}={}", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            parts.push(format!("{name}={:.4}", g.get()));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let s = h.snapshot();
            parts.push(format!(
                "{name}_ms[p50={:.3} p99={:.3} n={}]",
                s.quantile_ns(0.5) as f64 / 1e6,
                s.quantile_ns(0.99) as f64 / 1e6,
                s.count
            ));
        }
        parts.join(" ")
    }

    /// JSON dump (stable key order via `util::json`): counters and gauges
    /// as numbers, each histogram as an object of exact `count` plus
    /// `p50_ms`/`p90_ms`/`p99_ms`/`mean_ms`/`max_ms` — the shape the
    /// bench harness merges into `BENCH_hot_paths.json`.
    pub fn to_json(&self) -> Json {
        let mut root: BTreeMap<String, Json> = BTreeMap::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            root.insert(name.clone(), Json::Num(c.get() as f64));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            root.insert(name.clone(), Json::Num(g.get()));
        }
        for (name, h) in self.hists.lock().unwrap().iter() {
            let s = h.snapshot();
            let mut o: BTreeMap<String, Json> = BTreeMap::new();
            o.insert("count".into(), Json::Num(s.count as f64));
            o.insert("p50_ms".into(), Json::Num(s.quantile_ns(0.5) as f64 / 1e6));
            o.insert("p90_ms".into(), Json::Num(s.quantile_ns(0.9) as f64 / 1e6));
            o.insert("p99_ms".into(), Json::Num(s.quantile_ns(0.99) as f64 / 1e6));
            o.insert("mean_ms".into(), Json::Num(s.mean_ns() / 1e6));
            o.insert("max_ms".into(), Json::Num(s.max_ns as f64 / 1e6));
            root.insert(name.clone(), Json::Obj(o));
        }
        Json::Obj(root)
    }
}

/// Stage handles for the serve execution split — batch assembly (sketch
/// building + input fills) vs. session execution (the compiled plan) —
/// passed down into the worker pool so each micro-batch attributes its
/// time to the right family.  All-disabled by default.
#[derive(Clone, Default)]
pub struct ServeStages {
    pub assembly: HistHandle,
    pub exec: HistHandle,
}

/// Per-layer VQ-health gauges from a codeword population histogram: the
/// codebook's **perplexity** `exp(−Σ p·ln p)` (effective number of used
/// codewords — k when uniform, 1 when collapsed) and its **dead-code
/// count** (clusters whose population is below `dead_eps` — the trainers'
/// EMA masses decay toward 0, so an exact-zero test would never fire).
pub fn codebook_health(counts: &[f32], dead_eps: f32) -> (f64, usize) {
    let total: f64 = counts.iter().map(|&c| c.max(0.0) as f64).sum();
    let mut dead = 0usize;
    let mut ent = 0.0f64;
    for &c in counts {
        if c < dead_eps {
            dead += 1;
        }
        let c = c.max(0.0) as f64;
        if c > 0.0 && total > 0.0 {
            let p = c / total;
            ent -= p * p.ln();
        }
    }
    let perplexity = if total > 0.0 { ent.exp() } else { 0.0 };
    (perplexity, dead)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_monotone_and_total() {
        // every value maps into exactly one bucket whose [lo, hi) holds it
        // (saturation bucket excepted), and indices are monotone in value
        let mut prev = 0usize;
        for e in 0..60u32 {
            for &m in &[1u64, 3, 5, 7] {
                let v = (m << e) / 4;
                let b = bucket_of(v);
                assert!(b >= prev || v < (1 << LO_BITS), "monotone at {v}");
                prev = prev.max(b);
                if b + 1 < BUCKETS {
                    assert!(
                        bucket_lo(b) <= v && v < bucket_hi(b),
                        "v={v} not in bucket {b} [{}, {})",
                        bucket_lo(b),
                        bucket_hi(b)
                    );
                }
            }
        }
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn exact_fields_and_quantile_edges() {
        let h = Histogram::new();
        assert_eq!(h.snapshot().quantile_ns(0.5), 0, "empty histogram");
        for ns in [1_000u64, 2_000, 3_000, 4_000] {
            h.record(ns);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 4);
        assert_eq!(s.sum_ns, 10_000);
        assert_eq!(s.max_ns, 4_000);
        assert!((s.mean_ns() - 2_500.0).abs() < 1e-9);
        // p50 of 4 samples is the 2nd smallest (2000 ns): within 25%
        let p50 = s.quantile_ns(0.5) as f64;
        assert!((p50 - 2_000.0).abs() <= 0.25 * 2_000.0, "p50={p50}");
    }

    #[test]
    fn registry_is_deterministic_and_disableable() {
        let r = Registry::new();
        r.counter("b_count").add(2);
        r.counter("a_count").add(1);
        r.gauge("z_gauge").set(1.5);
        r.hist("lat").record_ns(1_000_000);
        let text = r.render_prometheus();
        assert_eq!(text, r.render_prometheus(), "scrape is byte-stable");
        let a = text.find("a_count_total 1").unwrap();
        let b = text.find("b_count_total 2").unwrap();
        assert!(a < b, "counters render in sorted key order");
        assert!(text.contains("lat_seconds{quantile=\"0.9\"}"));
        assert!(text.contains("lat_seconds_count 1"));
        assert!(text.contains("z_gauge 1.5"));
        // same name resolves to the same cells
        r.counter("a_count").add(1);
        assert!(r.render_prometheus().contains("a_count_total 2"));
        // disabled: no interning, empty scrape, no-op handles
        let d = Registry::disabled();
        let h = d.hist("lat");
        assert!(!h.enabled());
        h.record_ns(5);
        h.stage().stop();
        d.counter("c").add(1);
        d.gauge("g").set(1.0);
        assert_eq!(d.render_prometheus(), "");
        assert_eq!(d.render_line(), "");
    }

    #[test]
    fn stage_records_on_drop() {
        let r = Registry::new();
        let h = r.hist("span");
        {
            let _t = h.stage();
        }
        assert_eq!(r.hist("span").0.unwrap().snapshot().count, 1);
    }

    #[test]
    fn codebook_health_extremes() {
        let (pp, dead) = codebook_health(&[1.0; 8], 1e-3);
        assert!((pp - 8.0).abs() < 1e-9, "uniform → perplexity k, got {pp}");
        assert_eq!(dead, 0);
        let (pp, dead) = codebook_health(&[8.0, 0.0, 0.0, 0.0], 1e-3);
        assert!((pp - 1.0).abs() < 1e-9, "collapsed → perplexity 1, got {pp}");
        assert_eq!(dead, 3);
        let (pp, dead) = codebook_health(&[], 1e-3);
        assert_eq!(pp, 0.0);
        assert_eq!(dead, 0);
    }
}
