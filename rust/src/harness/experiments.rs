//! Experiment harness: one function per paper exhibit (DESIGN.md §4 index).
//! Each writes a CSV + markdown table under results/ and prints it.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::coordinator::edge_trainer::{Baseline, EdgeTrainer};
use crate::coordinator::vq_trainer::VqTrainer;
use crate::datasets::{Dataset, Split};
use crate::runtime::manifest::Manifest;
use crate::runtime::Runtime;
use crate::sampler::NodeStrategy;
use crate::util::{mean_std, Stopwatch};

pub struct Ctx {
    pub rt: Runtime,
    pub man: Manifest,
    pub epochs: usize,
    pub seeds: Vec<u64>,
    pub out_dir: std::path::PathBuf,
    datasets: BTreeMap<String, Rc<Dataset>>,
    /// `train --metrics-every N`: trainers wire their stage timers and
    /// VQ-health gauges into the registry, and `run_one_suffix` prints one
    /// report line every N epochs (stderr).
    pub metrics: Option<(std::sync::Arc<crate::obs::Registry>, usize)>,
    /// `train --shards S`: in-process shard count handed to every trainer
    /// (1 = unsharded).  Trajectories are bit-identical at any value — the
    /// knob changes who computes what, never the bytes (`shard` module).
    pub shards: usize,
}

impl Ctx {
    pub fn new(epochs: usize, seeds: Vec<u64>) -> Result<Ctx> {
        let man = Manifest::load_or_builtin(&Manifest::default_dir());
        let out_dir = std::path::PathBuf::from("results");
        std::fs::create_dir_all(&out_dir)?;
        Ok(Ctx {
            rt: Runtime::new()?,
            man,
            epochs,
            seeds,
            out_dir,
            datasets: BTreeMap::new(),
            metrics: None,
            shards: 1,
        })
    }

    pub fn dataset(&mut self, name: &str) -> Rc<Dataset> {
        if let Some(d) = self.datasets.get(name) {
            return d.clone();
        }
        let cfg = &self.man.datasets[name];
        let d = Rc::new(Dataset::generate(cfg, 42));
        self.datasets.insert(name.to_string(), d.clone());
        d
    }

    pub fn save(&self, file: &str, text: &str) -> Result<()> {
        let path = self.out_dir.join(file);
        std::fs::write(&path, text)?;
        eprintln!("wrote {}", path.display());
        Ok(())
    }
}

/// One (dataset, model, method) run: train `epochs`, return test metric.
pub fn run_one(ctx: &mut Ctx, ds_name: &str, model: &str, method: &str,
               seed: u64) -> Result<(f64, crate::coordinator::RunStats)> {
    run_one_suffix(ctx, ds_name, model, method, "", seed)
}

/// Like run_one, with an artifact-suffix selector for the ablation / perf
/// variants ("_fp32", "_k64", ...; VQ method only).
pub fn run_one_suffix(ctx: &mut Ctx, ds_name: &str, model: &str, method: &str,
                      suffix: &str, seed: u64)
                      -> Result<(f64, crate::coordinator::RunStats)> {
    let ds = ctx.dataset(ds_name);
    let epochs = ctx.epochs;
    if method == "vq" {
        let mut tr = VqTrainer::new(&mut ctx.rt, &ctx.man, ds, model, suffix,
                                    NodeStrategy::Nodes, seed)?;
        tr.set_shards(ctx.shards);
        if let Some((reg, _)) = &ctx.metrics {
            tr.set_metrics(reg);
        }
        for e in 0..epochs {
            tr.epoch(&mut ctx.rt)?;
            metrics_line(ctx, e);
        }
        let m = tr.evaluate(&mut ctx.rt, Split::Test)?;
        Ok((m, tr.stats.clone()))
    } else {
        let kind = Baseline::from_str(method).context("method")?;
        let mut tr = EdgeTrainer::new(&mut ctx.rt, &ctx.man, ds, model, kind, seed)?;
        tr.set_shards(ctx.shards);
        if let Some((reg, _)) = &ctx.metrics {
            tr.set_metrics(reg);
        }
        for e in 0..epochs {
            tr.epoch(&mut ctx.rt)?;
            metrics_line(ctx, e);
        }
        let m = tr.evaluate(&mut ctx.rt, Split::Test)?;
        Ok((m, tr.stats.clone()))
    }
}

/// Print the periodic `--metrics-every` report line after epoch `e`.
fn metrics_line(ctx: &Ctx, e: usize) {
    if let Some((reg, every)) = &ctx.metrics {
        if *every > 0 && (e + 1) % every == 0 {
            eprintln!("[metrics epoch {}] {}", e + 1, reg.render_line());
        }
    }
}

fn fmt_cell(vals: &[f64]) -> String {
    let (m, s) = mean_std(vals);
    format!("{m:.4}±{s:.4}")
}

/// Tables 4 & 7: performance across datasets × backbones × methods.
pub fn table_perf(ctx: &mut Ctx, datasets: &[&str], file: &str) -> Result<()> {
    let methods = ["full", "ns", "cluster", "saint", "vq"];
    let models = ["gcn", "sage", "gat", "txf"];
    let mut md = String::new();
    let mut csv = String::from("dataset,model,method,metric_mean,metric_std\n");
    for ds in datasets {
        let metric = match ctx.man.datasets[*ds].task.as_str() {
            "link" => "Hits@50",
            _ if ctx.man.datasets[*ds].multilabel => "micro-F1",
            _ => "accuracy",
        };
        let _ = writeln!(md, "\n### {ds} ({metric})\n");
        let _ = writeln!(md, "| method | {} |", models.join(" | "));
        let _ = writeln!(md, "|---|{}|", "---|".repeat(models.len()));
        for method in methods {
            let mut row = format!("| {method} ");
            for model in models {
                let cell = if method == "ns" && model == "gcn" {
                    "NA¹".to_string()
                } else if model == "txf" && method != "vq" {
                    // Global attention has no edge-list form — the sampling
                    // baselines cannot run it (ManifestError::UnsupportedEdgeForm).
                    "NA³".to_string()
                } else if model == "txf"
                    && !ctx.man.artifacts.contains_key(&format!("vq_train_{ds}_txf"))
                {
                    "NA⁴".to_string()
                } else if !ctx.rt.supports_model(model) {
                    "NA²".to_string()
                } else {
                    let mut vals = Vec::new();
                    for (si, &seed) in ctx.seeds.clone().iter().enumerate() {
                        let t = Stopwatch::start();
                        match run_one(ctx, ds, model, method, seed) {
                            Ok((m, _)) => {
                                vals.push(m);
                                eprintln!(
                                    "  {ds}/{model}/{method} seed{si}: {m:.4} ({:.1}s)",
                                    t.secs()
                                );
                            }
                            Err(e) => eprintln!("  {ds}/{model}/{method}: ERROR {e:#}"),
                        }
                    }
                    if vals.is_empty() {
                        "ERR".into()
                    } else {
                        let (m, s) = mean_std(&vals);
                        let _ = writeln!(csv, "{ds},{model},{method},{m:.4},{s:.4}");
                        fmt_cell(&vals)
                    }
                };
                let _ = write!(row, "| {cell} ");
            }
            let _ = writeln!(md, "{row}|");
        }
    }
    md.push_str("\n¹ NS-SAGE sampling is not compatible with the GCN backbone (paper Table 4).\n");
    md.push_str("² backbone unsupported on this backend.\n");
    md.push_str(
        "³ global attention has no edge-list form — only VQ scales the Graph Transformer \
         (paper §5).\n",
    );
    md.push_str("⁴ no txf artifact registered for this dataset (Table 8 runs it on arxiv_sim).\n");
    println!("{md}");
    ctx.save(&format!("{file}.md"), &md)?;
    ctx.save(&format!("{file}.csv"), &csv)
}

/// Table 3: peak device bytes per training step, with measured node and
/// message counts (the paper's fixed-nodes / fixed-messages comparison).
pub fn table3(ctx: &mut Ctx) -> Result<()> {
    let ds_name = "arxiv_sim";
    let mut md = String::from(
        "### Table 3 — peak per-step device bytes (arxiv_sim)\n\n\
         | method | model | nodes/step | messages/step | step MB | KB/message |\n\
         |---|---|---|---|---|---|\n",
    );
    let mut csv = String::from("method,model,nodes,messages,bytes\n");
    for model in ["gcn", "sage", "gat"] {
        for method in ["ns", "cluster", "saint", "vq"] {
            if method == "ns" && model == "gcn" {
                continue;
            }
            let ds = ctx.dataset(ds_name);
            let (nodes, msgs, bytes) = if method == "vq" {
                let mut tr = VqTrainer::new(&mut ctx.rt, &ctx.man, ds, model, "",
                                            NodeStrategy::Nodes, 1)?;
                for _ in 0..3 {
                    tr.train_step(&mut ctx.rt)?;
                }
                (tr.stats.nodes_per_step, tr.stats.messages_per_step,
                 tr.stats.peak_step_bytes)
            } else {
                let kind = Baseline::from_str(method).unwrap();
                let mut tr = EdgeTrainer::new(&mut ctx.rt, &ctx.man, ds, model, kind, 1)?;
                for _ in 0..3 {
                    tr.train_step(&mut ctx.rt)?;
                }
                (tr.stats.nodes_per_step, tr.stats.messages_per_step,
                 tr.stats.peak_step_bytes)
            };
            let _ = writeln!(
                md, "| {method} | {model} | {nodes} | {msgs} | {:.1} | {:.2} |",
                bytes as f64 / 1e6,
                bytes as f64 / 1024.0 / msgs.max(1) as f64
            );
            let _ = writeln!(csv, "{method},{model},{nodes},{msgs},{bytes}");
        }
    }
    md.push_str(
        "\nKB/message is the fixed-message-count comparison: VQ-GNN preserves \
         ALL messages into the batch while samplers drop most, so its \
         per-message footprint is the smallest (paper Table 3, right half).\n",
    );
    println!("{md}");
    ctx.save("table3.md", &md)?;
    ctx.save("table3.csv", &csv)
}

/// Fig. 4: validation metric vs wall-clock training time.
pub fn fig4(ctx: &mut Ctx) -> Result<()> {
    let ds_name = "arxiv_sim";
    let mut csv = String::from("model,method,epoch,train_secs,val_metric\n");
    for model in ["gcn", "sage", "gat"] {
        for method in ["ns", "cluster", "saint", "vq"] {
            if method == "ns" && model == "gcn" {
                continue;
            }
            let ds = ctx.dataset(ds_name);
            eprintln!("fig4: {model}/{method}");
            if method == "vq" {
                let mut tr = VqTrainer::new(&mut ctx.rt, &ctx.man, ds, model, "",
                                            NodeStrategy::Nodes, 1)?;
                for e in 0..ctx.epochs {
                    tr.epoch(&mut ctx.rt)?;
                    let t = tr.stats.train_secs;
                    let v = tr.evaluate(&mut ctx.rt, Split::Val)?;
                    let _ = writeln!(csv, "{model},vq,{e},{t:.3},{v:.4}");
                }
            } else {
                let kind = Baseline::from_str(method).unwrap();
                let mut tr = EdgeTrainer::new(&mut ctx.rt, &ctx.man, ds, model, kind, 1)?;
                for e in 0..ctx.epochs {
                    tr.epoch(&mut ctx.rt)?;
                    let t = tr.stats.train_secs;
                    let v = tr.evaluate(&mut ctx.rt, Split::Val)?;
                    let _ = writeln!(csv, "{model},{method},{e},{t:.3},{v:.4}");
                }
            }
        }
    }
    println!("{csv}");
    ctx.save("fig4.csv", &csv)
}

/// §6 inference-time: VQ mini-batch inference vs the samplers' L-hop
/// neighbor-expansion inference (OGB protocol).
pub fn inference(ctx: &mut Ctx) -> Result<()> {
    let ds = ctx.dataset("arxiv_sim");
    let mut md = String::from("### Inference time, arxiv_sim SAGE (all nodes)\n\n");
    let mut base = EdgeTrainer::new(&mut ctx.rt, &ctx.man, ds.clone(), "sage",
                                    Baseline::SaintRw, 1)?;
    for _ in 0..2 {
        base.train_step(&mut ctx.rt)?;
    }
    let t = Stopwatch::start();
    base.infer_full(&mut ctx.rt)?;
    let t_full = t.secs();
    let mut vq = VqTrainer::new(&mut ctx.rt, &ctx.man, ds.clone(), "sage", "",
                                NodeStrategy::Nodes, 1)?;
    for _ in 0..2 {
        vq.train_step(&mut ctx.rt)?;
    }
    let nodes: Vec<u32> = (0..ds.n() as u32).collect();
    let t = Stopwatch::start();
    vq.infer_nodes(&mut ctx.rt, &nodes)?;
    let t_vq = t.secs();
    let _ = writeln!(
        md,
        "| path | seconds |\n|---|---|\n| neighbor-expansion (samplers) | {t_full:.3} |\n\
         | VQ-GNN mini-batch | {t_vq:.3} |\n\nratio: {:.2}×\n",
        t_full / t_vq.max(1e-9)
    );
    println!("{md}");
    ctx.save("inference.md", &md)
}

/// Table 2 companion: asymptotics + measured per-step message counts.
pub fn complexity(ctx: &mut Ctx) -> Result<()> {
    let ds = ctx.dataset("arxiv_sim");
    let (n, m) = (ds.n(), ds.graph.num_arcs());
    let b = ctx.man.train.b;
    let k = ctx.man.train.k;
    let mut md = format!(
        "### Table 2 — complexity (arxiv_sim: n={n}, m={m}, b={b}, k={k})\n\n\
         | method | memory | train time/epoch | measured msgs/step |\n|---|---|---|---|\n"
    );
    for (method, model, mem, tt) in [
        ("ns", "sage", "O(b·r^L·f + L·f²)", "O(n·r^L·f + n·r^{L-1}·f²)"),
        ("cluster", "gcn", "O(L·b·f + L·f²)", "O(L·m·f + L·n·f²)"),
        ("saint", "gcn", "O(L²·b·f + L·f²)", "O(L²·n·f + L²·n·f²)"),
        ("vq", "gcn", "O(L·b·f + L·f² + L·k·f)", "O(L·b·d·f + L·n·f² + L·n·k·f)"),
    ] {
        let dsr = ctx.dataset("arxiv_sim");
        let msgs = if method == "vq" {
            let mut tr = VqTrainer::new(&mut ctx.rt, &ctx.man, dsr, model, "",
                                        NodeStrategy::Nodes, 1)?;
            tr.train_step(&mut ctx.rt)?;
            tr.stats.messages_per_step
        } else {
            let kind = Baseline::from_str(method).unwrap();
            let mut tr = EdgeTrainer::new(&mut ctx.rt, &ctx.man, dsr, model, kind, 1)?;
            tr.train_step(&mut ctx.rt)?;
            tr.stats.messages_per_step
        };
        let _ = writeln!(md, "| {method} | {mem} | {tt} | {msgs} |");
    }
    println!("{md}");
    ctx.save("complexity.md", &md)
}

/// Table 8: Graph-Transformer hybrid backbone on arxiv_sim.
pub fn table8(ctx: &mut Ctx) -> Result<()> {
    if !ctx.rt.supports_model("txf") {
        eprintln!(
            "table8 skipped: the {} backend does not support the txf backbone",
            ctx.rt.backend_name()
        );
        return Ok(());
    }
    let mut md = String::from(
        "### Table 8 — Global attention + GAT (arxiv_sim)\n\n| run | acc |\n|---|---|\n",
    );
    let mut vals = Vec::new();
    for &seed in &ctx.seeds.clone() {
        let (m, _) = run_one(ctx, "arxiv_sim", "txf", "vq", seed)?;
        vals.push(m);
        let _ = writeln!(md, "| seed {seed} | {m:.4} |");
    }
    let (m, s) = mean_std(&vals);
    let _ = writeln!(md, "| **mean±std** | **{m:.4}±{s:.4}** |");
    println!("{md}");
    ctx.save("table8.md", &md)
}

/// App. G ablations: layers / codebook size / batch size / sampling strategy.
pub fn ablations(ctx: &mut Ctx, which: &str) -> Result<()> {
    let ds_name = "arxiv_sim";
    let mut md = format!(
        "### Ablation: {which} (arxiv_sim, GCN, VQ-GNN)\n\n| config | acc |\n|---|---|\n"
    );
    let mut results: Vec<(String, f64)> = Vec::new();
    let mut run_suffix = |ctx: &mut Ctx, label: String, suffix: String,
                          strategy: NodeStrategy| -> Result<(String, f64)> {
        let ds = ctx.dataset(ds_name);
        let mut tr = VqTrainer::new(&mut ctx.rt, &ctx.man, ds, "gcn", &suffix,
                                    strategy, 1)?;
        for _ in 0..ctx.epochs {
            tr.epoch(&mut ctx.rt)?;
        }
        let m = tr.evaluate(&mut ctx.rt, Split::Test)?;
        eprintln!("  ablation {label}: {m:.4}");
        Ok((label, m))
    };
    match which {
        "layers" => {
            for l in [1usize, 2, 3, 4, 5] {
                let suffix = if l == 3 { String::new() } else { format!("_l{l}") };
                results.push(run_suffix(ctx, format!("{l} layers"), suffix,
                                        NodeStrategy::Nodes)?);
            }
        }
        "codebook" => {
            for k in [32usize, 64, 128, 256] {
                let suffix = if k == ctx.man.train.k { String::new() } else { format!("_k{k}") };
                results.push(run_suffix(ctx, format!("k={k}"), suffix,
                                        NodeStrategy::Nodes)?);
            }
        }
        "batch" => {
            for b in [128usize, 256, 512, 1024] {
                let suffix = if b == ctx.man.train.b { String::new() } else { format!("_b{b}") };
                results.push(run_suffix(ctx, format!("b={b}"), suffix,
                                        NodeStrategy::Nodes)?);
            }
        }
        "sampling" => {
            for (name, s) in [("nodes", NodeStrategy::Nodes),
                              ("edges", NodeStrategy::Edges),
                              ("walks", NodeStrategy::Walks)] {
                results.push(run_suffix(ctx, format!("sampling {name}"),
                                        String::new(), s)?);
            }
        }
        other => anyhow::bail!("unknown ablation {other}"),
    }
    for (label, m) in &results {
        let _ = writeln!(md, "| {label} | {m:.4} |");
    }
    println!("{md}");
    ctx.save(&format!("ablation_{which}.md"), &md)
}
