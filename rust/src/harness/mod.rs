//! Experiment harness: reproduces every table and figure of the paper's
//! evaluation (DESIGN.md §4 maps exhibits to functions here).

pub mod experiments;
