//! `serve` — the micro-batched, **concurrent** inference-serving
//! subsystem (the repo's end-to-end read path).
//!
//! The paper's core inference claim is that VQ compresses all out-of-batch
//! context into a small codebook, so answering a query never touches the
//! full graph.  This module realizes that as a shared-nothing-but-the-plan
//! runtime:
//!
//! - [`cache::EmbeddingCache`] — per-layer codeword assignments for ALL
//!   servable nodes plus raw codebooks and whitening stats, frozen at load
//!   time; read-only on the serve path, appended to only by admission;
//! - [`model::ServingModel`] — a shared immutable core (params + cache +
//!   compiled plan) plus a pool of per-worker sessions
//!   (`set_threads(N)`), built by freezing a trainer or loading a
//!   `checkpoint::save_serving` ("VQS3"; VQS2/VQS1 artifacts still load)
//!   artifact;
//! - [`engine::ServeEngine`] — THE serving entry point: owns the
//!   `Runtime`, routes requests across any number of named models (one
//!   bounded [`engine::MicroBatcher`] queue + [`EngineStats`] each), and
//!   answers `submit(model, req) → poll()/drain() → Served`.  `drain`
//!   cuts everything (tail padded), `poll` is deadline-driven (partial
//!   tails wait for newer arrivals until a request's deadline expires);
//!   either way the batches fan out across each model's pool,
//!   bit-identical to the serial schedule for any worker count.  Bounded
//!   queues load-shed ([`ServeError::Shed`]) instead of letting tail
//!   latency grow without bound;
//! - [`proto`] / [`server`] — the dependency-free length-prefixed TCP
//!   front-end over `std::net`: framed node/link queries + typed error
//!   frames in, [`server::run`] drives the engine's deadline flush from a
//!   listener loop with graceful shutdown (`vq-gnn serve --listen ADDR`,
//!   exercised by `vq-gnn client`);
//! - [`admit::AdmittedNodes`] — inductive-node admission: unseen nodes
//!   (features + arcs into known nodes) are assigned codewords against
//!   the frozen codebooks and become servable without retraining.
//!   Admitted ids are stable-for-life: eviction (LRU cap / TTL, see
//!   `ServeEngine::maintain`) compacts the tables but never reissues an
//!   id, so an evicted id is refused with the typed unknown-id error
//!   instead of silently aliasing a newer node;
//! - [`drift::DriftHistogram`] — online distance-to-codeword histograms
//!   per layer; total-variation distance against a reference frozen at
//!   export is the drift signal that gates the opt-in EMA codebook
//!   refresh (`ServeEngine::refresh`);
//! - [`report::LatencyReport`] — p50/p90/p99/qps accounting for the CLI
//!   and the bench harness, backed by `obs::Histogram`.
//!
//! Observability: attach an `obs::Registry` via
//! `ServeEngine::builder().metrics(..)` and the engine records
//! queue-wait/assembly/exec/latency histograms, admission counters, and
//! maintenance timings + VQ-health gauges — answers stay byte-identical
//! (`tests/obs.rs`); a STATS wire frame (`0x06`) scrapes the Prometheus
//! exposition over the socket front-end.
//!
//! Driven by `vq-gnn serve --dataset D --model M (--requests FILE |
//! --listen ADDR) [--threads N] [--deadline-ms D] [--queue-cap C]`.

pub mod admit;
pub mod cache;
pub mod drift;
pub mod engine;
pub mod model;
pub mod proto;
pub mod report;
pub(crate) mod router;
pub mod server;

pub use admit::AdmittedNodes;
pub use cache::EmbeddingCache;
pub use drift::DriftHistogram;
pub use engine::{
    EngineStats, MicroBatcher, Served, ServeEngine, ServeEngineBuilder, ServeError,
};
pub use model::{ServingModel, WorkerStats};
pub use report::LatencyReport;
pub use server::{ServerProbe, ServerReport};

use anyhow::{bail, Result};

/// One serving query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Request {
    /// Node classification / embedding lookup for one node id.
    Node(u32),
    /// Link prediction: dot-product score of the two endpoints' outputs.
    Link(u32, u32),
}

/// One serving answer (same order as the [`Request`] variants).
#[derive(Debug, Clone, PartialEq)]
pub enum Answer {
    /// Per-class scores (or the embedding row for link-task datasets).
    Scores(Vec<f32>),
    Link(f32),
}

impl Answer {
    /// Highest-scoring class index of a `Scores` answer.
    pub fn argmax(&self) -> Option<usize> {
        match self {
            Answer::Scores(s) => s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i),
            Answer::Link(_) => None,
        }
    }
}

/// Parse a batch request file: one query per line — `<id>` or `node <id>`
/// for classification, `link <u> <v>` for link scores; `#` comments and
/// blank lines ignored.  Node ids are validated against `n`.
pub fn parse_requests(text: &str, n: usize) -> Result<Vec<Request>> {
    let mut out = Vec::new();
    let node = |tok: &str, lno: usize| -> Result<u32> {
        let v: u32 = tok
            .parse()
            .map_err(|_| anyhow::anyhow!("line {lno}: bad node id '{tok}'"))?;
        if v as usize >= n {
            bail!("line {lno}: node {v} out of range (n={n})");
        }
        Ok(v)
    };
    for (i, line) in text.lines().enumerate() {
        let lno = i + 1;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        match toks.as_slice() {
            [id] => out.push(Request::Node(node(id, lno)?)),
            ["node", id] => out.push(Request::Node(node(id, lno)?)),
            ["link", u, v] => out.push(Request::Link(node(u, lno)?, node(v, lno)?)),
            _ => bail!("line {lno}: expected '<id>' | 'node <id>' | 'link <u> <v>'"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_file_grammar() {
        let text = "# header\n3\nnode 7\n\nlink 1 2\n  # indented comment\n0\n";
        let reqs = parse_requests(text, 10).unwrap();
        assert_eq!(
            reqs,
            vec![
                Request::Node(3),
                Request::Node(7),
                Request::Link(1, 2),
                Request::Node(0)
            ]
        );
        assert!(parse_requests("99", 10).is_err(), "out of range");
        assert!(parse_requests("link 1", 10).is_err(), "arity");
        assert!(parse_requests("frob 1", 10).is_err(), "unknown verb");
        assert!(parse_requests("node x", 10).is_err(), "non-numeric");
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(Answer::Scores(vec![0.1, 0.9, 0.3]).argmax(), Some(1));
        assert_eq!(Answer::Link(0.5).argmax(), None);
    }
}
