//! Inductive-node admission — the serving-side realization of the paper's
//! claim that a frozen VQ-GNN generalizes to unseen nodes: a cold node is
//! described by its raw features plus its arcs into already-known nodes,
//! assigned to the frozen codebooks' nearest codewords per layer (the same
//! whitened FINDNEAREST the trainer's inductive bootstrap runs, feature
//! columns only — `VqTrainer::assign_by_features`), and appended to the
//! per-layer node→codeword tables.  From then on it is a first-class
//! servable id: queryable directly, and visible to other queries as an
//! out-of-batch neighbor through its codeword, with **no retraining and no
//! full-graph pass**.
//!
//! Semantics (documented limits of the read-path graph view):
//!
//! - admission is one-directional — the admitted node *receives* messages
//!   from its cited neighbors, but existing nodes' stored neighbor lists
//!   (and degrees) are not rewritten, so a pre-existing node's answer only
//!   sees an admitted node through the global codeword histogram (txf) —
//!   exactly the approximation Fig. 1 makes for any out-of-batch node;
//! - ids are **stable and monotone**: the store hands out `next_id` (which
//!   starts at `base_n` and never decreases across evictions), so evicting
//!   a node never renames a survivor — an evicted id simply stops being
//!   servable and is answered by the typed unknown-id error.  A node may
//!   only cite neighbors admitted before it (single-writer FIFO).
//! - eviction compacts storage (features, CSR, per-layer assignment rows)
//!   but keeps the id space sparse; survivors' arcs into evicted ids are
//!   dropped when the CSR is rebuilt.
//!
//! Writes are serialized through [`AdmissionQueue`] + the `&mut
//! ServingModel` admission entry points, while the pooled `flush` workers
//! only ever read the tables — the borrow checker enforces the
//! single-writer/many-reader split.

use crate::coordinator::checkpoint::ServingAdmitted;

/// The model-level admitted-node store: padded feature rows + CSR neighbor
/// lists + the slot→stable-id map.  Per-layer codeword assignments live
/// next to each layer's frozen table
/// (`serve::cache::LayerCache::admitted_assign`), indexed by the same
/// slots.
pub struct AdmittedNodes {
    /// Dataset node count — admitted ids start here.
    pub base_n: usize,
    /// Padded feature width (the dataset's `f_in_pad`).
    pub f_pad: usize,
    features: Vec<f32>,
    nbr_ptr: Vec<u32>,
    nbr: Vec<u32>,
    /// Slot → stable id, strictly increasing (push appends `next_id`,
    /// evict removes entries — order is preserved, so id lookup is a
    /// binary search).
    ids: Vec<u32>,
    /// Next stable id to hand out; monotone across evictions.
    next_id: u32,
}

impl AdmittedNodes {
    pub fn new(base_n: usize, f_pad: usize) -> AdmittedNodes {
        AdmittedNodes {
            base_n,
            f_pad,
            features: Vec::new(),
            nbr_ptr: vec![0],
            nbr: Vec::new(),
            ids: Vec::new(),
            next_id: base_n as u32,
        }
    }

    /// Rebuild from a serving artifact's admitted block.  VQS2-era blocks
    /// carry no id map (ids were dense `n + slot`); `ServingAdmitted`
    /// synthesizes one at load, so this constructor only has to trust it.
    pub fn from_serving(base_n: usize, f_pad: usize, adm: ServingAdmitted) -> AdmittedNodes {
        debug_assert!(adm.count() == 0 || adm.f_pad == f_pad);
        let count = adm.count();
        let ids = if adm.ids.len() == count {
            adm.ids
        } else {
            (0..count).map(|i| (base_n + i) as u32).collect()
        };
        let next_id = adm.next_id.max(ids.last().map_or(base_n as u32, |&i| i + 1));
        AdmittedNodes {
            base_n,
            f_pad,
            features: adm.features,
            nbr_ptr: if adm.nbr_ptr.is_empty() { vec![0] } else { adm.nbr_ptr },
            nbr: adm.nbr,
            ids,
            next_id,
        }
    }

    /// Export into the serving-artifact block.
    pub fn to_serving(&self) -> ServingAdmitted {
        ServingAdmitted {
            f_pad: if self.len() == 0 { 0 } else { self.f_pad },
            features: self.features.clone(),
            nbr_ptr: self.nbr_ptr.clone(),
            nbr: self.nbr.clone(),
            ids: self.ids.clone(),
            next_id: self.next_id,
        }
    }

    /// Number of admitted nodes.
    pub fn len(&self) -> usize {
        self.nbr_ptr.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total servable ids: dataset nodes + resident admitted nodes.  With
    /// eviction the id space is sparse, so this is a *count*, not a bound —
    /// use [`AdmittedNodes::is_servable`] / [`AdmittedNodes::slot_of`] to
    /// answer "is this id live".
    pub fn total(&self) -> usize {
        self.base_n + self.len()
    }

    /// Exclusive upper bound on every id ever issued (frozen or admitted).
    pub fn id_bound(&self) -> u32 {
        self.next_id
    }

    /// Storage slot of a stable admitted id, if it is still resident.
    pub fn slot_of(&self, id: u32) -> Option<usize> {
        self.ids.binary_search(&id).ok()
    }

    /// Stable id of storage slot `off`.
    pub fn id_of(&self, off: usize) -> u32 {
        self.ids[off]
    }

    /// Resident admitted ids, ascending.
    pub fn ids(&self) -> &[u32] {
        &self.ids
    }

    /// Is `id` answerable right now (a frozen node or a resident admit)?
    pub fn is_servable(&self, id: u32) -> bool {
        (id as usize) < self.base_n || self.slot_of(id).is_some()
    }

    /// In-neighbors of admitted node at slot `off` (slot, not id).
    pub fn neighbors_of(&self, off: usize) -> &[u32] {
        &self.nbr[self.nbr_ptr[off] as usize..self.nbr_ptr[off + 1] as usize]
    }

    /// In-degree of admitted node at slot `off`.
    pub fn degree(&self, off: usize) -> usize {
        (self.nbr_ptr[off + 1] - self.nbr_ptr[off]) as usize
    }

    /// Padded feature row of admitted node at slot `off`.
    pub fn feature_row(&self, off: usize) -> &[f32] {
        &self.features[off * self.f_pad..(off + 1) * self.f_pad]
    }

    /// Append one node (features already padded to `f_pad`); returns its
    /// stable id (`next_id`, monotone — never a recycled evictee).
    pub fn push(&mut self, features: &[f32], neighbors: &[u32]) -> u32 {
        debug_assert_eq!(features.len(), self.f_pad);
        let id = self.next_id;
        self.next_id += 1;
        self.ids.push(id);
        self.features.extend_from_slice(features);
        self.nbr.extend_from_slice(neighbors);
        self.nbr_ptr.push(self.nbr.len() as u32);
        id
    }

    /// Roll back the most recent `push` (admission bootstrap failed after
    /// the record landed — the half-admitted node must not stay servable).
    /// Restores `next_id` so queued admissions keep their promised ids.
    pub fn pop(&mut self) {
        if self.len() == 0 {
            return;
        }
        self.next_id = self.ids.pop().expect("id map in sync with csr");
        self.nbr_ptr.pop();
        self.nbr.truncate(*self.nbr_ptr.last().expect("csr base") as usize);
        self.features.truncate(self.len() * self.f_pad);
    }

    /// Evict a set of stable ids: compact features/ids and rebuild the CSR
    /// keeping only survivors, dropping survivors' arcs into evicted ids.
    /// Returns the **old slots** of the survivors in order, so sibling
    /// tables (per-layer `admitted_assign`, touch stamps) can compact in
    /// lockstep.  Unknown/frozen ids in `victims` are ignored.
    pub fn evict(&mut self, victims: &[u32]) -> Vec<usize> {
        let mut gone: Vec<u32> = victims
            .iter()
            .copied()
            .filter(|&v| self.slot_of(v).is_some())
            .collect();
        gone.sort_unstable();
        gone.dedup();
        if gone.is_empty() {
            return (0..self.len()).collect();
        }
        let keep: Vec<usize> =
            (0..self.len()).filter(|&s| gone.binary_search(&self.ids[s]).is_err()).collect();
        let mut features = Vec::with_capacity(keep.len() * self.f_pad);
        let mut ids = Vec::with_capacity(keep.len());
        let mut nbr_ptr = Vec::with_capacity(keep.len() + 1);
        let mut nbr = Vec::new();
        nbr_ptr.push(0u32);
        for &s in &keep {
            features.extend_from_slice(self.feature_row(s));
            ids.push(self.ids[s]);
            for &v in self.neighbors_of(s) {
                if v < self.base_n as u32 || gone.binary_search(&v).is_err() {
                    nbr.push(v);
                }
            }
            nbr_ptr.push(nbr.len() as u32);
        }
        self.features = features;
        self.ids = ids;
        self.nbr_ptr = nbr_ptr;
        self.nbr = nbr;
        keep
    }

    /// Resident bytes of the admitted tables (cache memory report).
    pub fn memory_bytes(&self) -> u64 {
        4 * (self.features.len() + self.nbr_ptr.len() + self.nbr.len() + self.ids.len()) as u64
    }
}

/// A FIFO of admission requests, applied by the single writer between
/// flushes.  Ids are handed out at enqueue time (monotone from `next_id`,
/// deterministic), so a caller can cite a queued node as a later request's
/// neighbor and query it as soon as the queue is applied.
#[derive(Default)]
pub struct AdmissionQueue {
    reqs: Vec<(Vec<f32>, Vec<u32>)>,
}

impl AdmissionQueue {
    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    /// Enqueue (validation against the live id space is the model's job).
    pub fn push(&mut self, features: Vec<f32>, neighbors: Vec<u32>) {
        self.reqs.push((features, neighbors));
    }

    pub fn take(&mut self) -> Vec<(Vec<f32>, Vec<u32>)> {
        std::mem::take(&mut self.reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_roundtrip() {
        let mut adm = AdmittedNodes::new(10, 3);
        assert_eq!(adm.total(), 10);
        let a = adm.push(&[1.0, 2.0, 3.0], &[0, 4]);
        assert_eq!(a, 10);
        let b = adm.push(&[4.0, 5.0, 6.0], &[10]); // cites the first admit
        assert_eq!(b, 11);
        assert_eq!(adm.len(), 2);
        assert_eq!(adm.neighbors_of(0), &[0, 4]);
        assert_eq!(adm.neighbors_of(1), &[10]);
        assert_eq!(adm.degree(0), 2);
        assert_eq!(adm.feature_row(1), &[4.0, 5.0, 6.0]);
        adm.pop();
        assert_eq!(adm.len(), 1);
        assert_eq!(adm.neighbors_of(0), &[0, 4]);
        assert_eq!(adm.total(), 11);
        assert_eq!(adm.id_bound(), 11); // pop released the id for reuse
        // serving-block round trip
        let again = AdmittedNodes::from_serving(10, 3, adm.to_serving());
        assert_eq!(again.len(), 1);
        assert_eq!(again.neighbors_of(0), &[0, 4]);
        assert_eq!(again.feature_row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(again.id_of(0), 10);
        assert_eq!(again.id_bound(), 11);
    }

    #[test]
    fn eviction_keeps_survivor_ids_stable_and_prunes_arcs() {
        let mut adm = AdmittedNodes::new(4, 2);
        let a = adm.push(&[1.0, 1.0], &[0]); // id 4
        let b = adm.push(&[2.0, 2.0], &[1, a]); // id 5, cites a
        let c = adm.push(&[3.0, 3.0], &[a, b]); // id 6, cites both
        assert_eq!((a, b, c), (4, 5, 6));
        let before = adm.memory_bytes();
        let keep = adm.evict(&[a]);
        assert_eq!(keep, vec![1, 2]); // old slots of b, c
        assert_eq!(adm.len(), 2);
        assert!(adm.memory_bytes() < before);
        // survivor ids unchanged; evicted id no longer servable
        assert_eq!(adm.slot_of(b), Some(0));
        assert_eq!(adm.slot_of(c), Some(1));
        assert_eq!(adm.slot_of(a), None);
        assert!(!adm.is_servable(a));
        assert!(adm.is_servable(b));
        assert!(adm.is_servable(2)); // frozen ids always servable
        // arcs into the evicted id were dropped, frozen arcs kept
        assert_eq!(adm.neighbors_of(0), &[1]);
        assert_eq!(adm.neighbors_of(1), &[b]);
        // the id space stays monotone: the next admit is NOT a recycled 4
        let d = adm.push(&[4.0, 4.0], &[b]);
        assert_eq!(d, 7);
        assert_eq!(adm.total(), 4 + 3);
        assert_eq!(adm.id_bound(), 8);
        // evicting everything leaves an empty, still-usable store
        let keep = adm.evict(&[b, c, d]);
        assert!(keep.is_empty());
        assert_eq!(adm.len(), 0);
        assert_eq!(adm.push(&[5.0, 5.0], &[0]), 8);
    }
}
