//! Inductive-node admission — the serving-side realization of the paper's
//! claim that a frozen VQ-GNN generalizes to unseen nodes: a cold node is
//! described by its raw features plus its arcs into already-known nodes,
//! assigned to the frozen codebooks' nearest codewords per layer (the same
//! whitened FINDNEAREST the trainer's inductive bootstrap runs, feature
//! columns only — `VqTrainer::assign_by_features`), and appended to the
//! per-layer node→codeword tables.  From then on it is a first-class
//! servable id: queryable directly, and visible to other queries as an
//! out-of-batch neighbor through its codeword, with **no retraining and no
//! full-graph pass**.
//!
//! Semantics (documented limits of the read-path graph view):
//!
//! - admission is one-directional — the admitted node *receives* messages
//!   from its cited neighbors, but existing nodes' stored neighbor lists
//!   (and degrees) are not rewritten, so a pre-existing node's answer only
//!   sees an admitted node through the global codeword histogram (txf) —
//!   exactly the approximation Fig. 1 makes for any out-of-batch node;
//! - ids are dense and append-only: node `i`'s id is `n + i`, and a node
//!   may only cite neighbors admitted before it (single-writer FIFO).
//!
//! Writes are serialized through [`AdmissionQueue`] + the `&mut
//! ServingModel` admission entry points, while the pooled `flush` workers
//! only ever read the tables — the borrow checker enforces the
//! single-writer/many-reader split.

use crate::coordinator::checkpoint::ServingAdmitted;

/// The model-level admitted-node store: padded feature rows + CSR neighbor
/// lists.  Per-layer codeword assignments live next to each layer's frozen
/// table (`serve::cache::LayerCache::admitted_assign`).
pub struct AdmittedNodes {
    /// Dataset node count — admitted ids start here.
    pub base_n: usize,
    /// Padded feature width (the dataset's `f_in_pad`).
    pub f_pad: usize,
    features: Vec<f32>,
    nbr_ptr: Vec<u32>,
    nbr: Vec<u32>,
}

impl AdmittedNodes {
    pub fn new(base_n: usize, f_pad: usize) -> AdmittedNodes {
        AdmittedNodes { base_n, f_pad, features: Vec::new(), nbr_ptr: vec![0], nbr: Vec::new() }
    }

    /// Rebuild from a serving artifact's admitted block.
    pub fn from_serving(base_n: usize, f_pad: usize, adm: ServingAdmitted) -> AdmittedNodes {
        debug_assert!(adm.count() == 0 || adm.f_pad == f_pad);
        AdmittedNodes {
            base_n,
            f_pad,
            features: adm.features,
            nbr_ptr: if adm.nbr_ptr.is_empty() { vec![0] } else { adm.nbr_ptr },
            nbr: adm.nbr,
        }
    }

    /// Export into the serving-artifact block.
    pub fn to_serving(&self) -> ServingAdmitted {
        ServingAdmitted {
            f_pad: if self.len() == 0 { 0 } else { self.f_pad },
            features: self.features.clone(),
            nbr_ptr: self.nbr_ptr.clone(),
            nbr: self.nbr.clone(),
        }
    }

    /// Number of admitted nodes.
    pub fn len(&self) -> usize {
        self.nbr_ptr.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total servable ids: dataset nodes + admitted nodes.
    pub fn total(&self) -> usize {
        self.base_n + self.len()
    }

    /// In-neighbors of admitted node `off` (offset, not id).
    pub fn neighbors_of(&self, off: usize) -> &[u32] {
        &self.nbr[self.nbr_ptr[off] as usize..self.nbr_ptr[off + 1] as usize]
    }

    /// In-degree of admitted node `off`.
    pub fn degree(&self, off: usize) -> usize {
        (self.nbr_ptr[off + 1] - self.nbr_ptr[off]) as usize
    }

    /// Padded feature row of admitted node `off`.
    pub fn feature_row(&self, off: usize) -> &[f32] {
        &self.features[off * self.f_pad..(off + 1) * self.f_pad]
    }

    /// Append one node (features already padded to `f_pad`); returns its id.
    pub fn push(&mut self, features: &[f32], neighbors: &[u32]) -> u32 {
        debug_assert_eq!(features.len(), self.f_pad);
        let id = self.total() as u32;
        self.features.extend_from_slice(features);
        self.nbr.extend_from_slice(neighbors);
        self.nbr_ptr.push(self.nbr.len() as u32);
        id
    }

    /// Roll back the most recent `push` (admission bootstrap failed after
    /// the record landed — the half-admitted node must not stay servable).
    pub fn pop(&mut self) {
        if self.len() == 0 {
            return;
        }
        self.nbr_ptr.pop();
        self.nbr.truncate(*self.nbr_ptr.last().expect("csr base") as usize);
        self.features.truncate(self.len() * self.f_pad);
    }

    /// Resident bytes of the admitted tables (cache memory report).
    pub fn memory_bytes(&self) -> u64 {
        4 * (self.features.len() + self.nbr_ptr.len() + self.nbr.len()) as u64
    }
}

/// A FIFO of admission requests, applied by the single writer between
/// flushes.  Ids are handed out at enqueue time (dense, deterministic), so
/// a caller can cite a queued node as a later request's neighbor and query
/// it as soon as the queue is applied.
#[derive(Default)]
pub struct AdmissionQueue {
    reqs: Vec<(Vec<f32>, Vec<u32>)>,
}

impl AdmissionQueue {
    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }

    /// Enqueue (validation against the live id space is the model's job).
    pub fn push(&mut self, features: Vec<f32>, neighbors: Vec<u32>) {
        self.reqs.push((features, neighbors));
    }

    pub fn take(&mut self) -> Vec<(Vec<f32>, Vec<u32>)> {
        std::mem::take(&mut self.reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_roundtrip() {
        let mut adm = AdmittedNodes::new(10, 3);
        assert_eq!(adm.total(), 10);
        let a = adm.push(&[1.0, 2.0, 3.0], &[0, 4]);
        assert_eq!(a, 10);
        let b = adm.push(&[4.0, 5.0, 6.0], &[10]); // cites the first admit
        assert_eq!(b, 11);
        assert_eq!(adm.len(), 2);
        assert_eq!(adm.neighbors_of(0), &[0, 4]);
        assert_eq!(adm.neighbors_of(1), &[10]);
        assert_eq!(adm.degree(0), 2);
        assert_eq!(adm.feature_row(1), &[4.0, 5.0, 6.0]);
        adm.pop();
        assert_eq!(adm.len(), 1);
        assert_eq!(adm.neighbors_of(0), &[0, 4]);
        assert_eq!(adm.total(), 11);
        // serving-block round trip
        let again = AdmittedNodes::from_serving(10, 3, adm.to_serving());
        assert_eq!(again.len(), 1);
        assert_eq!(again.neighbors_of(0), &[0, 4]);
        assert_eq!(again.feature_row(0), &[1.0, 2.0, 3.0]);
    }
}
