//! Dependency-free wire protocol for the socket serving front-end:
//! length-prefixed frames over TCP (`std::net` only).
//!
//! ```text
//! frame := len:u32le payload              (len excludes the prefix, ≤ 1 MiB)
//!
//! request payloads (first byte = kind):
//!   0x01 NODE     req_id:u64le  mlen:u8  model:utf8[mlen]  node:u32le
//!   0x02 LINK     req_id:u64le  mlen:u8  model:utf8[mlen]  u:u32le  v:u32le
//!   0x03 DRAIN    (force-flush partial tails now)
//!   0x04 SHUTDOWN (drain everything, reply, stop the server)
//!   0x05 PING     req_id:u64le
//!   0x06 STATS    req_id:u64le  (metrics scrape — answered inline)
//!
//! response payloads:
//!   0x81 SCORES   req_id:u64le  flags:u8  n:u32le  n × f32le
//!                 (flags bit0: the row is an embedding, not class scores)
//!   0x82 LINK     req_id:u64le  score:f32le
//!   0x83 ERROR    req_id:u64le  code:u8  mlen:u16le  msg:utf8[mlen]
//!                 (req_id = u64::MAX when the frame never parsed)
//!   0x85 PONG     req_id:u64le
//!   0x86 STATS    req_id:u64le  tlen:u32le  text:utf8[tlen]
//!                 (Prometheus text exposition, deterministic key order)
//!
//! error codes:
//!   1 SHED           bounded queue at capacity — retry later
//!   2 UNKNOWN_MODEL  routing name not registered
//!   3 BAD_REQUEST    well-formed frame, unserviceable query (bad node id)
//!   4 MALFORMED      frame failed to decode (connection survives unless
//!                    the length prefix itself is unusable)
//!   5 INTERNAL       engine failure
//! ```
//!
//! All integers little-endian.  Decoding is fully typed ([`ProtoError`]):
//! a malformed payload never panics and never desynchronizes the framing
//! layer ([`Framer`] consumes exactly the declared length).  An oversized
//! length prefix is the one unrecoverable case — the byte stream can no
//! longer be trusted, so the server replies MALFORMED and hangs up.

use std::io::{self, Read};

/// Hard ceiling on a frame's payload length.  Largest legitimate frame is
/// a SCORES row (a few KiB); anything near 1 MiB is garbage or abuse.
pub const MAX_FRAME: usize = 1 << 20;

/// `req_id` attached to error frames for requests that never parsed.
pub const NO_REQ_ID: u64 = u64::MAX;

const K_NODE: u8 = 0x01;
const K_LINK: u8 = 0x02;
const K_DRAIN: u8 = 0x03;
const K_SHUTDOWN: u8 = 0x04;
const K_PING: u8 = 0x05;
const K_STATS: u8 = 0x06;
const K_SCORES: u8 = 0x81;
const K_LINKSCORE: u8 = 0x82;
const K_ERROR: u8 = 0x83;
const K_PONG: u8 = 0x85;
const K_STATSTEXT: u8 = 0x86;

/// One decoded client→server frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireRequest {
    Node { req_id: u64, model: String, node: u32 },
    Link { req_id: u64, model: String, u: u32, v: u32 },
    Drain,
    Shutdown,
    Ping { req_id: u64 },
    Stats { req_id: u64 },
}

/// One decoded server→client frame.
#[derive(Debug, Clone, PartialEq)]
pub enum WireResponse {
    Scores { req_id: u64, embedding: bool, row: Vec<f32> },
    Link { req_id: u64, score: f32 },
    Error { req_id: u64, code: ErrCode, msg: String },
    Pong { req_id: u64 },
    Stats { req_id: u64, text: String },
}

/// Typed wire error codes (the `code` byte of an ERROR frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    Shed = 1,
    UnknownModel = 2,
    BadRequest = 3,
    Malformed = 4,
    Internal = 5,
}

impl ErrCode {
    fn from_u8(b: u8) -> Option<ErrCode> {
        match b {
            1 => Some(ErrCode::Shed),
            2 => Some(ErrCode::UnknownModel),
            3 => Some(ErrCode::BadRequest),
            4 => Some(ErrCode::Malformed),
            5 => Some(ErrCode::Internal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ErrCode::Shed => "SHED",
            ErrCode::UnknownModel => "UNKNOWN_MODEL",
            ErrCode::BadRequest => "BAD_REQUEST",
            ErrCode::Malformed => "MALFORMED",
            ErrCode::Internal => "INTERNAL",
        }
    }
}

/// Typed decode failures.  None of these panic, and only `Oversize`
/// poisons the framing layer (the declared length cannot be skipped).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// Length prefix exceeds [`MAX_FRAME`] — the stream is unusable.
    Oversize { len: usize, max: usize },
    /// Payload ended before a field completed (truncated frame, or a
    /// mid-frame disconnect surfaced at EOF).
    Truncated { need: usize, got: usize },
    /// Unknown kind byte.
    BadKind(u8),
    /// Model name is not UTF-8.
    BadUtf8,
    /// Payload has bytes past the last field.
    Trailing { extra: usize },
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Oversize { len, max } => {
                write!(f, "frame length {len} exceeds the {max}-byte cap")
            }
            ProtoError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            ProtoError::BadKind(b) => write!(f, "unknown frame kind 0x{b:02x}"),
            ProtoError::BadUtf8 => write!(f, "model name is not valid UTF-8"),
            ProtoError::Trailing { extra } => {
                write!(f, "{extra} trailing byte(s) after the last field")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

// ---- little-endian writer/reader helpers -------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.pos + n > self.buf.len() {
            return Err(ProtoError::Truncated { need: self.pos + n, got: self.buf.len() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, ProtoError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn done(&self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(ProtoError::Trailing { extra: self.buf.len() - self.pos });
        }
        Ok(())
    }
}

fn frame(payload: Vec<u8>) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_FRAME);
    let mut out = Vec::with_capacity(4 + payload.len());
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

// ---- encode ------------------------------------------------------------

/// Encode a request INCLUDING its 4-byte length prefix.
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    let mut p = Vec::new();
    match req {
        WireRequest::Node { req_id, model, node } => {
            assert!(model.len() <= u8::MAX as usize, "model name too long for the wire");
            p.push(K_NODE);
            put_u64(&mut p, *req_id);
            p.push(model.len() as u8);
            p.extend_from_slice(model.as_bytes());
            put_u32(&mut p, *node);
        }
        WireRequest::Link { req_id, model, u, v } => {
            assert!(model.len() <= u8::MAX as usize, "model name too long for the wire");
            p.push(K_LINK);
            put_u64(&mut p, *req_id);
            p.push(model.len() as u8);
            p.extend_from_slice(model.as_bytes());
            put_u32(&mut p, *u);
            put_u32(&mut p, *v);
        }
        WireRequest::Drain => p.push(K_DRAIN),
        WireRequest::Shutdown => p.push(K_SHUTDOWN),
        WireRequest::Ping { req_id } => {
            p.push(K_PING);
            put_u64(&mut p, *req_id);
        }
        WireRequest::Stats { req_id } => {
            p.push(K_STATS);
            put_u64(&mut p, *req_id);
        }
    }
    frame(p)
}

/// Encode a response INCLUDING its 4-byte length prefix.
pub fn encode_response(resp: &WireResponse) -> Vec<u8> {
    let mut p = Vec::new();
    match resp {
        WireResponse::Scores { req_id, embedding, row } => {
            p.push(K_SCORES);
            put_u64(&mut p, *req_id);
            p.push(u8::from(*embedding));
            put_u32(&mut p, row.len() as u32);
            for &x in row {
                put_f32(&mut p, x);
            }
        }
        WireResponse::Link { req_id, score } => {
            p.push(K_LINKSCORE);
            put_u64(&mut p, *req_id);
            put_f32(&mut p, *score);
        }
        WireResponse::Error { req_id, code, msg } => {
            let msg = &msg.as_bytes()[..msg.len().min(u16::MAX as usize)];
            p.push(K_ERROR);
            put_u64(&mut p, *req_id);
            p.push(*code as u8);
            p.extend_from_slice(&(msg.len() as u16).to_le_bytes());
            p.extend_from_slice(msg);
        }
        WireResponse::Pong { req_id } => {
            p.push(K_PONG);
            put_u64(&mut p, *req_id);
        }
        WireResponse::Stats { req_id, text } => {
            // a scrape must fit one frame: truncate at the cap (a real
            // exposition is a few KiB; the cap only guards abuse)
            let text = &text.as_bytes()[..text.len().min(MAX_FRAME - 13)];
            p.push(K_STATSTEXT);
            put_u64(&mut p, *req_id);
            put_u32(&mut p, text.len() as u32);
            p.extend_from_slice(text);
        }
    }
    frame(p)
}

// ---- decode ------------------------------------------------------------

fn take_model(r: &mut Reader<'_>) -> Result<String, ProtoError> {
    let mlen = r.u8()? as usize;
    let raw = r.take(mlen)?;
    String::from_utf8(raw.to_vec()).map_err(|_| ProtoError::BadUtf8)
}

/// Decode one request payload (the bytes AFTER the length prefix).
pub fn decode_request(payload: &[u8]) -> Result<WireRequest, ProtoError> {
    let mut r = Reader::new(payload);
    let req = match r.u8()? {
        K_NODE => {
            let req_id = r.u64()?;
            let model = take_model(&mut r)?;
            WireRequest::Node { req_id, model, node: r.u32()? }
        }
        K_LINK => {
            let req_id = r.u64()?;
            let model = take_model(&mut r)?;
            WireRequest::Link { req_id, model, u: r.u32()?, v: r.u32()? }
        }
        K_DRAIN => WireRequest::Drain,
        K_SHUTDOWN => WireRequest::Shutdown,
        K_PING => WireRequest::Ping { req_id: r.u64()? },
        K_STATS => WireRequest::Stats { req_id: r.u64()? },
        other => return Err(ProtoError::BadKind(other)),
    };
    r.done()?;
    Ok(req)
}

/// Decode one response payload (the bytes AFTER the length prefix).
pub fn decode_response(payload: &[u8]) -> Result<WireResponse, ProtoError> {
    let mut r = Reader::new(payload);
    let resp = match r.u8()? {
        K_SCORES => {
            let req_id = r.u64()?;
            let embedding = r.u8()? != 0;
            let n = r.u32()? as usize;
            if n > MAX_FRAME / 4 {
                return Err(ProtoError::Oversize { len: n * 4, max: MAX_FRAME });
            }
            let mut row = Vec::with_capacity(n);
            for _ in 0..n {
                row.push(r.f32()?);
            }
            WireResponse::Scores { req_id, embedding, row }
        }
        K_LINKSCORE => WireResponse::Link { req_id: r.u64()?, score: r.f32()? },
        K_ERROR => {
            let req_id = r.u64()?;
            let code = ErrCode::from_u8(r.u8()?).ok_or(ProtoError::BadKind(K_ERROR))?;
            let mlen = u16::from_le_bytes(r.take(2)?.try_into().unwrap()) as usize;
            let msg =
                String::from_utf8(r.take(mlen)?.to_vec()).map_err(|_| ProtoError::BadUtf8)?;
            WireResponse::Error { req_id, code, msg }
        }
        K_PONG => WireResponse::Pong { req_id: r.u64()? },
        K_STATSTEXT => {
            let req_id = r.u64()?;
            let tlen = r.u32()? as usize;
            if tlen > MAX_FRAME {
                return Err(ProtoError::Oversize { len: tlen, max: MAX_FRAME });
            }
            let text =
                String::from_utf8(r.take(tlen)?.to_vec()).map_err(|_| ProtoError::BadUtf8)?;
            WireResponse::Stats { req_id, text }
        }
        other => return Err(ProtoError::BadKind(other)),
    };
    r.done()?;
    Ok(resp)
}

// ---- framing -----------------------------------------------------------

/// Incremental frame accumulator for nonblocking/timeout reads: feed it
/// whatever bytes arrive, pop complete payloads.  Survives arbitrary
/// fragmentation; the one fatal state is an oversized length prefix.
#[derive(Default)]
pub struct Framer {
    buf: Vec<u8>,
}

impl Framer {
    pub fn new() -> Framer {
        Framer::default()
    }

    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame's payload, `None` if more bytes are
    /// needed, `Err` on an unusable length prefix.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>, ProtoError> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME {
            return Err(ProtoError::Oversize { len, max: MAX_FRAME });
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let payload = self.buf[4..4 + len].to_vec();
        self.buf.drain(..4 + len);
        Ok(Some(payload))
    }

    /// Bytes buffered that do not yet form a whole frame.  Non-zero at
    /// EOF means the peer died mid-frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }

    /// The typed error describing the buffered partial frame (for EOF
    /// reporting); `None` when the buffer is empty.
    pub fn eof_error(&self) -> Option<ProtoError> {
        if self.buf.is_empty() {
            return None;
        }
        let need = if self.buf.len() >= 4 {
            4 + u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize
        } else {
            4
        };
        Some(ProtoError::Truncated { need, got: self.buf.len() })
    }
}

/// Blocking read of one whole frame (the CLIENT side, where the socket
/// has no read timeout).  `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> io::Result<Option<Vec<u8>>> {
    let mut lenb = [0u8; 4];
    match r.read_exact(&mut lenb) {
        Ok(()) => {}
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(lenb) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            ProtoError::Oversize { len, max: MAX_FRAME },
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strip(framed: &[u8]) -> &[u8] {
        &framed[4..]
    }

    #[test]
    fn request_roundtrip() {
        let reqs = [
            WireRequest::Node { req_id: 7, model: "gcn".into(), node: 42 },
            WireRequest::Link { req_id: u64::MAX - 1, model: "sage".into(), u: 0, v: 9 },
            WireRequest::Drain,
            WireRequest::Shutdown,
            WireRequest::Ping { req_id: 3 },
            WireRequest::Stats { req_id: 8 },
        ];
        for req in reqs {
            let framed = encode_request(&req);
            let len = u32::from_le_bytes(framed[..4].try_into().unwrap()) as usize;
            assert_eq!(len + 4, framed.len(), "prefix counts payload only");
            assert_eq!(decode_request(strip(&framed)).unwrap(), req);
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = [
            WireResponse::Scores {
                req_id: 1,
                embedding: true,
                row: vec![1.5, -2.25, f32::MIN_POSITIVE],
            },
            WireResponse::Scores { req_id: 2, embedding: false, row: vec![] },
            WireResponse::Link { req_id: 3, score: -0.125 },
            WireResponse::Error {
                req_id: NO_REQ_ID,
                code: ErrCode::Shed,
                msg: "queue full".into(),
            },
            WireResponse::Pong { req_id: 4 },
            WireResponse::Stats {
                req_id: 5,
                text: "serve_requests_total 10\nserve_queue_wait_seconds_count 10\n".into(),
            },
            WireResponse::Stats { req_id: 6, text: String::new() },
        ];
        for resp in resps {
            let framed = encode_response(&resp);
            assert_eq!(decode_response(strip(&framed)).unwrap(), resp);
        }
    }

    #[test]
    fn stats_decode_guards_length_and_utf8() {
        // declared text length beyond the frame cap is typed Oversize
        let mut p = vec![0x86u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert_eq!(
            decode_response(&p),
            Err(ProtoError::Oversize { len: MAX_FRAME + 1, max: MAX_FRAME })
        );
        // non-UTF-8 exposition text is refused
        let mut p = vec![0x86u8];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.extend_from_slice(&2u32.to_le_bytes());
        p.extend_from_slice(&[0xff, 0xfe]);
        assert_eq!(decode_response(&p), Err(ProtoError::BadUtf8));
    }

    #[test]
    fn malformed_payloads_are_typed_errors() {
        // unknown kind
        assert_eq!(decode_request(&[0x7f]), Err(ProtoError::BadKind(0x7f)));
        // empty payload
        assert_eq!(decode_request(&[]), Err(ProtoError::Truncated { need: 1, got: 0 }));
        // node frame cut mid-req_id
        let full = encode_request(&WireRequest::Node {
            req_id: 9,
            model: "gcn".into(),
            node: 1,
        });
        let payload = strip(&full);
        for cut in 1..payload.len() {
            let err = decode_request(&payload[..cut]).unwrap_err();
            assert!(
                matches!(err, ProtoError::Truncated { .. }),
                "cut at {cut} gave {err:?}"
            );
        }
        // trailing garbage is refused, not ignored
        let mut long = payload.to_vec();
        long.push(0xAA);
        assert_eq!(decode_request(&long), Err(ProtoError::Trailing { extra: 1 }));
        // non-UTF-8 model name
        let mut bad = vec![0x01];
        bad.extend_from_slice(&5u64.to_le_bytes());
        bad.push(2);
        bad.extend_from_slice(&[0xff, 0xfe]);
        bad.extend_from_slice(&1u32.to_le_bytes());
        assert_eq!(decode_request(&bad), Err(ProtoError::BadUtf8));
    }

    #[test]
    fn framer_reassembles_fragmented_frames() {
        let a = encode_request(&WireRequest::Ping { req_id: 1 });
        let b = encode_request(&WireRequest::Node { req_id: 2, model: "gcn".into(), node: 3 });
        let stream: Vec<u8> = a.iter().chain(&b).copied().collect();
        // feed one byte at a time: frames pop exactly at their boundaries
        let mut fr = Framer::new();
        let mut got = Vec::new();
        for &byte in &stream {
            fr.extend(&[byte]);
            while let Some(p) = fr.next_frame().unwrap() {
                got.push(decode_request(&p).unwrap());
            }
        }
        assert_eq!(
            got,
            vec![
                WireRequest::Ping { req_id: 1 },
                WireRequest::Node { req_id: 2, model: "gcn".into(), node: 3 }
            ]
        );
        assert_eq!(fr.pending_bytes(), 0);
        assert!(fr.eof_error().is_none());
    }

    #[test]
    fn framer_oversize_and_truncation() {
        let mut fr = Framer::new();
        fr.extend(&(MAX_FRAME as u32 + 1).to_le_bytes());
        assert_eq!(
            fr.next_frame(),
            Err(ProtoError::Oversize { len: MAX_FRAME + 1, max: MAX_FRAME })
        );
        // a partial frame reports a typed truncation at EOF
        let mut fr = Framer::new();
        fr.extend(&10u32.to_le_bytes());
        fr.extend(&[1, 2, 3]);
        assert_eq!(fr.next_frame(), Ok(None));
        assert_eq!(fr.eof_error(), Some(ProtoError::Truncated { need: 14, got: 7 }));
    }
}
