//! Codebook-backed embedding cache — the serving-side realization of the
//! paper's "compact low-rank" global context.  At load time the cache
//! freezes, per layer, the node→codeword assignment table R (read straight
//! out of `vq::LayerVq`), the raw-space codewords (the inverse-whitened
//! Ṽ̄, materialized ONCE instead of per batch as the trainers do), and the
//! per-branch whitening stats (so inductive admission can run FINDNEAREST
//! in the same whitened space training used).  A query batch then only
//! materializes features for its own nodes plus forward sketches against k
//! codewords — no neighbor explosion, no full-graph forward, and no
//! transposed (backward) sketches at all.
//!
//! The cache is **shared and read-only on the serve path**: every builder
//! here takes `&self`, so N pool sessions can build their sketches against
//! one cache concurrently.  The writers all sit behind `&mut
//! ServingModel`: the admission path ([`LayerCache::record_admitted`]),
//! the eviction path ([`EmbeddingCache::evict`] — compacts the admitted
//! tails, never the frozen tables), the drift observers, and the opt-in
//! EMA [`LayerCache::refresh`].
//!
//! Admitted ids are **stable**: eviction compacts the storage slots but
//! never renames a survivor (see `serve::admit`), so every admitted
//! lookup here resolves id → slot through the store's sorted id map.
//!
//! Memory model: `Σ_l n_br·(n + admitted)` assignment words + `Σ_l
//! n_br·k·fp` codeword floats + whitening stats + the admitted block
//! (reported by [`EmbeddingCache::memory_bytes`]).

use crate::coordinator::checkpoint::{ServingAdmitted, ServingLayer};
use crate::graph::{Conv, Graph};
use crate::runtime::manifest::LayerPlan;
use crate::serve::admit::AdmittedNodes;
use crate::serve::drift::DriftHistogram;
use crate::util::tensor::Tensor;
use crate::vq::sketch::SketchScratch;
use crate::vq::{kernels, VqModel};

/// Rows of recent serving traffic each layer retains for an EMA refresh
/// (a bounded ring — old rows are overwritten, so the refresh always
/// re-fits against the freshest traffic window).
pub(crate) const RECENT_ROWS: usize = 512;

/// Storage slot of a servable admitted id (callers validate liveness
/// before the builders run, so a miss here is a logic error, not bad
/// request data).
fn slot_of(adm: &AdmittedNodes, v: usize) -> usize {
    adm.slot_of(v as u32).expect("servable admitted id")
}

/// In-degree of any servable id (frozen graph, or the admitted CSR).
fn deg_any(graph: &Graph, adm: &AdmittedNodes, v: usize) -> usize {
    if v < graph.n {
        graph.in_degree(v)
    } else {
        adm.degree(slot_of(adm, v))
    }
}

/// Convolution coefficient of the arc (src → dst) with admitted ids
/// allowed on either end.  Arcs between two frozen nodes go through
/// `Graph::coef` untouched (bit-identical to the pre-admission path);
/// arcs touching an admitted node mirror the same Table-1 formulas with
/// the admitted node's degree read from its CSR record.
fn coef_any(graph: &Graph, adm: &AdmittedNodes, conv: Conv, src: usize, dst: usize) -> f32 {
    if src < graph.n && dst < graph.n {
        return graph.coef(conv, src, dst);
    }
    match conv {
        Conv::GcnSym => {
            let dd = (deg_any(graph, adm, dst) + 1) as f32;
            let ds = (deg_any(graph, adm, src) + 1) as f32;
            1.0 / (dd * ds).sqrt()
        }
        Conv::SageMean => {
            let d = deg_any(graph, adm, dst);
            if d == 0 {
                0.0
            } else {
                1.0 / d as f32
            }
        }
    }
}

/// In-neighbors of any servable id.
fn nbrs_any<'a>(graph: &'a Graph, adm: &'a AdmittedNodes, v: usize) -> &'a [u32] {
    if v < graph.n {
        graph.in_neighbors(v)
    } else {
        adm.neighbors_of(slot_of(adm, v))
    }
}

/// One layer's frozen VQ state, forward-only, plus its admitted tail and
/// its drift-detection state.
pub struct LayerCache {
    pub plan: LayerPlan,
    pub k: usize,
    pub n: usize,
    /// Assignment table R, row-major (n_br, n): R_j[node] ∈ [0, k).
    pub assign: Vec<u32>,
    /// Raw-space codewords (n_br, k, fp), precomputed at load time.
    pub cw: Tensor,
    /// Whitening mean, row-major (n_br, fp) — admission FINDNEAREST input.
    pub mean: Vec<f32>,
    /// Whitening variance, row-major (n_br, fp).
    pub var: Vec<f32>,
    /// Whitened codewords (n_br, k, fp), derived once from `cw`/`mean`/
    /// `var` — the admission path's codebook.  Deriving (instead of
    /// freezing the trainer's own whitened table) keeps admission
    /// deterministic across save → load: the raw codewords round-trip
    /// exactly, so both sides derive the same table.
    cww: Vec<f32>,
    /// Admitted-node assignments, SLOT-major (count, n_br): entry
    /// `[slot * n_br + j]` is branch j's codeword for the admitted id the
    /// store maps to `slot`.
    pub admitted_assign: Vec<u32>,
    /// Branch-0 cluster populations over ALL servable nodes (frozen +
    /// admitted), maintained on admission/eviction: `cnt_out` per batch
    /// is this histogram minus the batch's members — O(b + k) per query
    /// batch instead of an O(n) sweep.
    global_hist: Vec<f32>,
    /// Reference distance histogram (the training distribution's
    /// footprint) — frozen into a VQS3 checkpoint at export.
    pub drift_ref: DriftHistogram,
    /// Observed distance histogram, accumulated online from serving
    /// traffic by the single-writer maintenance hook.
    pub drift_obs: DriftHistogram,
    /// Bounded ring of recent layer-input feature rows (`RECENT_ROWS` ×
    /// `f_in`) — the EMA refresh's fitting data.  Runtime-only.
    recent: Vec<f32>,
    recent_rows: usize,
    recent_next: usize,
}

impl LayerCache {
    /// Assemble one frozen layer: derive the whitened codebook, count the
    /// codeword histogram (admitted tail included).  `drift_ref` carries a
    /// checkpoint's reference bins (empty = no reference yet).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        plan: LayerPlan,
        k: usize,
        n: usize,
        assign: Vec<u32>,
        cw: Tensor,
        mean: Vec<f32>,
        var: Vec<f32>,
        admitted_assign: Vec<u32>,
        drift_ref: Vec<f32>,
    ) -> LayerCache {
        let (nb, fp) = (plan.n_br, plan.fp);
        debug_assert_eq!(mean.len(), nb * fp);
        debug_assert_eq!(var.len(), nb * fp);
        let mut cww = vec![0.0f32; nb * k * fp];
        let mut inv = vec![0.0f32; fp];
        for j in 0..nb {
            kernels::inv_std_into(&var[j * fp..(j + 1) * fp], &mut inv);
            for v in 0..k {
                for d in 0..fp {
                    let idx = (j * k + v) * fp + d;
                    cww[idx] = (cw.f[idx] - mean[j * fp + d]) * inv[d];
                }
            }
        }
        let mut global_hist = vec![0.0f32; k];
        for u in 0..n {
            global_hist[assign[u] as usize] += 1.0;
        }
        for off in 0..admitted_assign.len() / nb.max(1) {
            global_hist[admitted_assign[off * nb] as usize] += 1.0;
        }
        LayerCache {
            plan,
            k,
            n,
            assign,
            cw,
            mean,
            var,
            cww,
            admitted_assign,
            global_hist,
            drift_ref: DriftHistogram::from_bins(drift_ref),
            drift_obs: DriftHistogram::new(),
            recent: Vec::new(),
            recent_rows: 0,
            recent_next: 0,
        }
    }

    /// Admitted nodes recorded in THIS layer's table (during an admission
    /// bootstrap the in-flight node exists in the feature/neighbor store
    /// but not yet here).
    pub fn admitted_count(&self) -> usize {
        self.admitted_assign.len() / self.plan.n_br.max(1)
    }

    /// Branch-j codeword of any servable id (frozen table or admitted
    /// tail, resolved through the store's id map).
    #[inline]
    pub fn assign_any(&self, adm: &AdmittedNodes, j: usize, u: usize) -> usize {
        if u < self.n {
            self.assign[j * self.n + u] as usize
        } else {
            self.admitted_assign[slot_of(adm, u) * self.plan.n_br + j] as usize
        }
    }

    /// Branch-0 codeword populations over every servable node (frozen +
    /// admitted) — integer counts stored as f32.  Read-only view for the
    /// VQ-health gauges (`obs::codebook_health`): perplexity and
    /// dead-code count per layer.
    pub fn codeword_populations(&self) -> &[f32] {
        &self.global_hist
    }

    /// Append one admitted node's per-branch assignments (single-writer
    /// path) and fold it into the global histogram.
    pub fn record_admitted(&mut self, assigns: &[u32]) {
        debug_assert_eq!(assigns.len(), self.plan.n_br);
        debug_assert!(assigns.iter().all(|&a| (a as usize) < self.k));
        self.admitted_assign.extend_from_slice(assigns);
        self.global_hist[assigns[0] as usize] += 1.0;
    }

    /// Compact the admitted tail after an eviction: `keep` is the
    /// survivors' OLD slots in ascending order (from
    /// `AdmittedNodes::evict`).  Dropped rows give their branch-0 count
    /// back to the global histogram — counts are small integers, so the
    /// +1/−1 pair restores the exact pre-admission f32 value and
    /// frozen-node `cnt_out` builds return to bit-identity.
    pub fn evict_slots(&mut self, keep: &[usize]) {
        let nb = self.plan.n_br;
        let count = self.admitted_count();
        let mut kept = Vec::with_capacity(keep.len() * nb);
        let mut ki = 0usize;
        for s in 0..count {
            if ki < keep.len() && keep[ki] == s {
                kept.extend_from_slice(&self.admitted_assign[s * nb..(s + 1) * nb]);
                ki += 1;
            } else {
                self.global_hist[self.admitted_assign[s * nb] as usize] -= 1.0;
            }
        }
        self.admitted_assign = kept;
    }

    /// Nearest-codeword assignment of one node from its layer-input
    /// feature row, per branch, against the frozen codebooks — the
    /// admission FINDNEAREST.  Mirrors the trainer's inductive bootstrap
    /// (`VqTrainer::assign_by_features`): feature columns only (an unseen
    /// node has no gradient history), whitened per branch, ties to the
    /// lowest index via `vq::kernels::assign_blocked`.  Branches whose
    /// concat slice is entirely gradient columns get codeword 0 — their
    /// assignment never reaches the forward pass (the serve step reads
    /// only feature columns of the unsketched concat).
    pub fn assign_features(&self, row: &[f32], out: &mut [u32]) {
        let (fl, fp, k, nb) = (self.plan.f_in, self.plan.fp, self.k, self.plan.n_br);
        debug_assert_eq!(row.len(), fl);
        debug_assert_eq!(out.len(), nb);
        let mut inv = vec![0.0f32; fp];
        let mut vw = vec![0.0f32; fp];
        for j in 0..nb {
            let lo = j * fp;
            if lo >= fl {
                out[j] = 0; // pure-gradient branch: forward-neutral
                continue;
            }
            let width = fp.min(fl - lo);
            kernels::inv_std_into(&self.var[j * fp..j * fp + width], &mut inv[..width]);
            for d in 0..width {
                vw[d] = (row[lo + d] - self.mean[j * fp + d]) * inv[d];
            }
            let mut a = [0i32];
            kernels::assign_blocked(
                &vw[..width],
                width,
                width,
                &self.cww[j * k * fp..(j + 1) * k * fp],
                k,
                fp,
                &mut a,
            );
            out[j] = a[0] as u32;
        }
    }

    /// Whitened per-dim RMS distance from a layer-input feature row to its
    /// NEAREST codeword, averaged over the feature-bearing branches — the
    /// drift detector's sample statistic (how well the frozen codebook
    /// still quantizes this row, independent of any stale table entry).
    pub fn nearest_distance(&self, row: &[f32]) -> f32 {
        let (fl, fp, k, nb) = (self.plan.f_in, self.plan.fp, self.k, self.plan.n_br);
        debug_assert_eq!(row.len(), fl);
        let mut acc = 0.0f64;
        let mut branches = 0usize;
        let mut vw = vec![0.0f32; fp];
        for j in 0..nb {
            let lo = j * fp;
            if lo >= fl {
                continue;
            }
            let width = fp.min(fl - lo);
            for d in 0..width {
                let inv = 1.0 / (self.var[j * fp + d] + crate::vq::EPS).sqrt();
                vw[d] = (row[lo + d] - self.mean[j * fp + d]) * inv;
            }
            let mut best = f64::INFINITY;
            for c in 0..k {
                let base = (j * k + c) * fp;
                let mut d2 = 0.0f64;
                for d in 0..width {
                    let diff = (vw[d] - self.cww[base + d]) as f64;
                    d2 += diff * diff;
                }
                if d2 < best {
                    best = d2;
                }
            }
            acc += (best / width as f64).sqrt();
            branches += 1;
        }
        if branches == 0 {
            0.0
        } else {
            (acc / branches as f64) as f32
        }
    }

    /// Single-writer drift hook for one served/admitted row: record its
    /// nearest-codeword distance in the observed histogram and retain the
    /// row in the bounded refresh ring.
    pub fn observe_serving(&mut self, row: &[f32]) {
        let d = self.nearest_distance(row);
        self.record_observation(row, d);
    }

    /// The mutation half of [`Self::observe_serving`], with the distance
    /// precomputed — the sharded `note_served` path fans the (pure,
    /// read-only) `nearest_distance` calls across shard workers and then
    /// replays the recordings here in original request order, so the
    /// histogram and refresh ring are byte-identical to the serial path.
    pub fn record_observation(&mut self, row: &[f32], d: f32) {
        self.drift_obs.record(d);
        let fl = self.plan.f_in;
        if self.recent_rows < RECENT_ROWS {
            self.recent.extend_from_slice(row);
            self.recent_rows += 1;
            self.recent_next = self.recent_rows % RECENT_ROWS;
        } else {
            self.recent[self.recent_next * fl..(self.recent_next + 1) * fl]
                .copy_from_slice(row);
            self.recent_next = (self.recent_next + 1) % RECENT_ROWS;
        }
    }

    /// Record one row into the REFERENCE histogram (freeze-time seeding
    /// from the frozen nodes — the training distribution's footprint).
    pub fn observe_reference(&mut self, row: &[f32]) {
        let d = self.nearest_distance(row);
        self.drift_ref.record(d);
    }

    /// Drift metric: total-variation distance between the observed and
    /// reference distance histograms (0 until both hold data).
    pub fn drift(&self) -> f32 {
        self.drift_obs.tv_distance(&self.drift_ref)
    }

    /// Rows currently retained for a refresh.
    pub fn recent_len(&self) -> usize {
        self.recent_rows
    }

    /// Online EMA refresh (serving-side analogue of `VqBranch::update`,
    /// built on the same deterministic kernels): re-assign the retained
    /// traffic rows to the current codebook (`assign_blocked`), merge the
    /// per-cluster partials (`cluster_accumulate`), and pull each cluster
    /// with batch mass toward its traffic mean — `cww ← γ·cww +
    /// (1−γ)·mean` — then re-derive the raw codeword through the frozen
    /// inverse whitening.  Whitening stats and the node→codeword tables
    /// are left untouched: assignments go *stale* rather than wrong (the
    /// staleness caveat the README documents), and untouched clusters
    /// keep their exact bits, so a refresh with no retained rows — or no
    /// cluster mass — is a bit-exact no-op.  Finally the observed
    /// histogram is rebuilt against the new codebook, so the drift metric
    /// reflects the refreshed fit.  Returns whether anything changed.
    pub fn refresh(&mut self, gamma: f32) -> bool {
        let rows = self.recent_rows;
        if rows == 0 {
            return false;
        }
        let (fl, fp, k, nb) = (self.plan.f_in, self.plan.fp, self.k, self.plan.n_br);
        let mut changed = false;
        for j in 0..nb {
            let lo = j * fp;
            if lo >= fl {
                continue; // pure-gradient branch: no serving data for it
            }
            let width = fp.min(fl - lo);
            let mut inv = vec![0.0f32; width];
            kernels::inv_std_into(&self.var[j * fp..j * fp + width], &mut inv);
            let mut vw = vec![0.0f32; rows * width];
            for r in 0..rows {
                for d in 0..width {
                    vw[r * width + d] =
                        (self.recent[r * fl + lo + d] - self.mean[j * fp + d]) * inv[d];
                }
            }
            let mut assigns = vec![0i32; rows];
            kernels::assign_blocked(
                &vw,
                width,
                width,
                &self.cww[j * k * fp..(j + 1) * k * fp],
                k,
                fp,
                &mut assigns,
            );
            let (bc, bs) = kernels::cluster_accumulate(&vw, &assigns, rows, width, k);
            for c in 0..k {
                // clusters without traffic mass keep their exact position
                // (mirrors the trainer's empty-cluster guard)
                if bc[c] > 1e-6 && bc[c].is_finite() {
                    changed = true;
                    for d in 0..width {
                        let idx = (j * k + c) * fp + d;
                        let target = bs[c * width + d] / bc[c];
                        self.cww[idx] = gamma * self.cww[idx] + (1.0 - gamma) * target;
                        self.cw.f[idx] = self.cww[idx]
                            * (self.var[j * fp + d] + crate::vq::EPS).sqrt()
                            + self.mean[j * fp + d];
                    }
                }
            }
        }
        if changed {
            // the codebook moved: re-score the retained window so the
            // drift metric measures the refreshed fit
            self.drift_obs.clear();
            for r in 0..rows {
                let d = self.nearest_distance(&self.recent[r * fl..(r + 1) * fl]);
                self.drift_obs.record(d);
            }
        }
        changed
    }

    /// Forward fixed-convolution sketches for a query batch, written into
    /// caller-owned buffers: `(C_in, C̃_out)` — the exact intra-batch block
    /// plus the codeword-merged out-of-batch block.  Mirrors
    /// `vq::sketch::build_fixed` minus the transposed (Eq. 7) side,
    /// accumulating in the same arc order so the tensors are bit-identical
    /// to the trainer's for frozen-node batches; admitted rows read their
    /// neighbors/degrees from the admitted CSR.  The serving session
    /// rebuilds its dynamic input slots in place, so the steady-state
    /// micro-batch allocates nothing here.
    #[allow(clippy::too_many_arguments)]
    pub fn build_fixed_fwd_into(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        conv: Conv,
        batch: &[u32],
        scratch: &mut SketchScratch,
        c_in: &mut [f32],
        c_out: &mut [f32],
    ) {
        let b = batch.len();
        let (nb, k) = (self.plan.n_br, self.k);
        debug_assert_eq!(c_in.len(), b * b);
        debug_assert_eq!(c_out.len(), nb * b * k);
        c_in.fill(0.0);
        c_out.fill(0.0);
        scratch.mark(batch);
        for (i, &gi) in batch.iter().enumerate() {
            let gi = gi as usize;
            for &u in nbrs_any(graph, adm, gi) {
                let coef = coef_any(graph, adm, conv, u as usize, gi);
                let p = scratch.pos_of(u as usize);
                if p >= 0 {
                    c_in[i * b + p as usize] += coef;
                } else {
                    for j in 0..nb {
                        let v = self.assign_any(adm, j, u as usize);
                        c_out[(j * b + i) * k + v] += coef;
                    }
                }
            }
            if conv.with_self_loops() {
                c_in[i * b + i] += coef_any(graph, adm, conv, gi, gi);
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_fixed_fwd_into`].
    pub fn build_fixed_fwd(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        conv: Conv,
        batch: &[u32],
        scratch: &mut SketchScratch,
    ) -> (Tensor, Tensor) {
        let b = batch.len();
        let (nb, k) = (self.plan.n_br, self.k);
        let mut c_in = vec![0.0f32; b * b];
        let mut c_out = vec![0.0f32; nb * b * k];
        self.build_fixed_fwd_into(graph, adm, conv, batch, scratch, &mut c_in, &mut c_out);
        (
            Tensor::from_f32(&[b, b], c_in),
            Tensor::from_f32(&[nb, b, k], c_out),
        )
    }

    /// Forward learnable-convolution count sketches, written into
    /// caller-owned buffers: `(mask_in, M_out)` — 𝔠 = A+I over the batch
    /// block, out-of-batch in-neighbors counted per codeword bucket.
    /// Mirrors `vq::sketch::build_learnable` minus M_outᵀ.
    pub fn build_learnable_fwd_into(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        batch: &[u32],
        scratch: &mut SketchScratch,
        mask_in: &mut [f32],
        m_out: &mut [f32],
    ) {
        let b = batch.len();
        let k = self.k;
        debug_assert_eq!(self.plan.n_br, 1, "learnable convs use a single branch");
        debug_assert_eq!(mask_in.len(), b * b);
        debug_assert_eq!(m_out.len(), b * k);
        mask_in.fill(0.0);
        m_out.fill(0.0);
        scratch.mark(batch);
        for (i, &gi) in batch.iter().enumerate() {
            let gi = gi as usize;
            mask_in[i * b + i] = 1.0;
            for &u in nbrs_any(graph, adm, gi) {
                let p = scratch.pos_of(u as usize);
                if p >= 0 {
                    mask_in[i * b + p as usize] = 1.0;
                } else {
                    let v = self.assign_any(adm, 0, u as usize);
                    m_out[i * k + v] += 1.0;
                }
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_learnable_fwd_into`].
    pub fn build_learnable_fwd(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        batch: &[u32],
        scratch: &mut SketchScratch,
    ) -> (Tensor, Tensor) {
        let b = batch.len();
        let k = self.k;
        let mut mask_in = vec![0.0f32; b * b];
        let mut m_out = vec![0.0f32; b * k];
        self.build_learnable_fwd_into(graph, adm, batch, scratch, &mut mask_in, &mut m_out);
        (
            Tensor::from_f32(&[b, b], mask_in),
            Tensor::from_f32(&[b, k], m_out),
        )
    }

    /// Global out-of-batch cluster histogram (txf global attention),
    /// written into a caller-owned buffer: `cnt_out[v] = |{u ∉ batch :
    /// R[u] = v}|` over all servable nodes.  Computed as the maintained
    /// histogram minus the batch's distinct members — counts are small
    /// integers, exact in f32, so the result is bit-identical to
    /// `vq::sketch::build_cnt_out`'s O(n) counting sweep on frozen-node
    /// batches.  A batch member that is mid-admission (recorded features
    /// but no assignment yet — the bootstrap forward itself) is not in the
    /// histogram and is skipped.
    pub fn build_cnt_fwd_into(
        &self,
        adm: &AdmittedNodes,
        batch: &[u32],
        scratch: &mut SketchScratch,
        cnt: &mut [f32],
    ) {
        debug_assert_eq!(cnt.len(), self.k);
        cnt.copy_from_slice(&self.global_hist);
        scratch.mark(batch);
        for (i, &g) in batch.iter().enumerate() {
            // mark() keeps the LAST occurrence's position: decrement each
            // distinct node exactly once, duplicates included
            if scratch.pos_of(g as usize) == i as i32 {
                let u = g as usize;
                if u >= self.n {
                    match adm.slot_of(g) {
                        // mid-admission: not in the histogram yet
                        Some(s) if s < self.admitted_count() => {}
                        _ => continue,
                    }
                }
                cnt[self.assign_any(adm, 0, u)] -= 1.0;
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_cnt_fwd_into`].
    pub fn build_cnt_fwd(
        &self,
        adm: &AdmittedNodes,
        batch: &[u32],
        scratch: &mut SketchScratch,
    ) -> Tensor {
        let mut cnt = vec![0.0f32; self.k];
        self.build_cnt_fwd_into(adm, batch, scratch, &mut cnt);
        Tensor::from_f32(&[self.k], cnt)
    }
}

/// All layers' frozen VQ state for one serving model, plus the
/// admitted-node store shared by every layer.
pub struct EmbeddingCache {
    pub layers: Vec<LayerCache>,
    pub admitted: AdmittedNodes,
}

impl EmbeddingCache {
    /// Freeze a trained `VqModel`: copy the assignment tables, materialize
    /// the raw codeword tensors once, and snapshot the whitening stats.
    pub fn from_vq(vq: &VqModel) -> EmbeddingCache {
        let layers: Vec<LayerCache> = vq
            .layers
            .iter()
            .map(|l| {
                LayerCache::new(
                    l.plan.clone(),
                    l.k,
                    l.n,
                    l.assign.clone(),
                    l.cw_tensor(),
                    l.mean_tensor().f,
                    l.var_tensor().f,
                    Vec::new(),
                    Vec::new(),
                )
            })
            .collect();
        let (n, f_pad) = (
            layers.first().map(|l| l.n).unwrap_or(0),
            layers.first().map(|l| l.plan.f_in).unwrap_or(0),
        );
        EmbeddingCache { layers, admitted: AdmittedNodes::new(n, f_pad) }
    }

    /// Seed layer 0's drift REFERENCE from the frozen nodes' own
    /// nearest-codeword distances — the training distribution's footprint
    /// (freeze-time; an O(n·k·fp) one-off).  Deeper layers have no node
    /// rows here; they gain a reference only once observed traffic is
    /// exported into a VQS3 checkpoint.  No-op if a reference exists.
    pub fn seed_drift_reference(&mut self, features: &[f32], f: usize) {
        if let Some(l0) = self.layers.first_mut() {
            if l0.plan.f_in != f || !l0.drift_ref.is_empty() {
                return;
            }
            let rows = l0.n.min(features.len() / f.max(1));
            for u in 0..rows {
                let d = l0.nearest_distance(&features[u * f..(u + 1) * f]);
                l0.drift_ref.record(d);
            }
        }
    }

    /// Evict admitted ids everywhere: the feature/CSR store plus every
    /// layer's assignment tail and histogram, compacted in lockstep.
    /// Returns the survivors' OLD slots (for sibling-state compaction —
    /// touch stamps).  Single-writer path.
    pub fn evict(&mut self, victims: &[u32]) -> Vec<usize> {
        let before = self.admitted.len();
        let keep = self.admitted.evict(victims);
        if keep.len() != before {
            for l in &mut self.layers {
                l.evict_slots(&keep);
            }
        }
        keep
    }

    /// Largest per-layer drift metric (the engine's alert signal).
    pub fn max_drift(&self) -> f32 {
        self.layers.iter().map(|l| l.drift()).fold(0.0, f32::max)
    }

    /// Rebuild from a serving artifact's layers + the serve spec's plans.
    pub fn from_serving_layers(
        plans: &[LayerPlan],
        layers: Vec<ServingLayer>,
        admitted: ServingAdmitted,
    ) -> EmbeddingCache {
        let layers: Vec<LayerCache> = plans
            .iter()
            .zip(layers)
            .map(|(p, l)| {
                let cw = Tensor::from_f32(&[l.n_br, l.k, l.fp], l.cw);
                LayerCache::new(p.clone(), l.k, l.n, l.assign, cw, l.mean, l.var,
                                l.admitted_assign, l.drift_ref)
            })
            .collect();
        let (n, f_pad) = (
            layers.first().map(|l| l.n).unwrap_or(0),
            layers.first().map(|l| l.plan.f_in).unwrap_or(0),
        );
        EmbeddingCache {
            layers,
            admitted: AdmittedNodes::from_serving(n, f_pad, admitted),
        }
    }

    /// Export back into serving-artifact layers.  The drift reference
    /// frozen into the artifact is the existing reference when one exists;
    /// otherwise the observed traffic histogram is promoted — "the
    /// distribution at export time" becomes the next process's reference.
    pub fn to_serving_layers(&self) -> Vec<ServingLayer> {
        self.layers
            .iter()
            .map(|l| {
                let r = if l.drift_ref.is_empty() { &l.drift_obs } else { &l.drift_ref };
                ServingLayer {
                    k: l.k,
                    n: l.n,
                    n_br: l.plan.n_br,
                    fp: l.plan.fp,
                    cw: l.cw.f.clone(),
                    assign: l.assign.clone(),
                    mean: l.mean.clone(),
                    var: l.var.clone(),
                    admitted_assign: l.admitted_assign.clone(),
                    drift_ref: if r.is_empty() { Vec::new() } else { r.bins().to_vec() },
                }
            })
            .collect()
    }

    /// Export the admitted block.
    pub fn to_serving_admitted(&self) -> ServingAdmitted {
        self.admitted.to_serving()
    }

    /// Total servable ids: dataset nodes + resident admitted nodes.
    pub fn total_nodes(&self) -> usize {
        self.admitted.total()
    }

    /// Gather padded feature rows for any servable ids into a caller-owned
    /// `(b, f)` buffer — frozen nodes from the dataset's feature matrix,
    /// admitted nodes from the admitted store.
    pub fn gather_features_into(&self, features: &[f32], f: usize, batch: &[u32],
                                out: &mut [f32]) {
        debug_assert_eq!(out.len(), batch.len() * f);
        let base = self.admitted.base_n;
        for (i, &v) in batch.iter().enumerate() {
            let dst = &mut out[i * f..(i + 1) * f];
            if (v as usize) < base {
                let v = v as usize;
                dst.copy_from_slice(&features[v * f..(v + 1) * f]);
            } else {
                dst.copy_from_slice(self.admitted.feature_row(slot_of(&self.admitted, v as usize)));
            }
        }
    }

    /// Resident bytes: assignment words (frozen + admitted), codebooks,
    /// whitening stats, and the admitted feature/CSR block (the README's
    /// cache memory model).
    pub fn memory_bytes(&self) -> u64 {
        let layers: u64 = self
            .layers
            .iter()
            .map(|l| {
                4 * (l.assign.len()
                    + l.admitted_assign.len()
                    + l.cw.numel()
                    + l.mean.len()
                    + l.var.len()) as u64
            })
            .sum();
        layers + self.admitted.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::vq::LayerVq;

    fn setup(n: usize, seed: u64, nb: usize) -> (Graph, LayerVq) {
        let mut rng = Rng::new(seed);
        let mut edges = Vec::new();
        for _ in 0..n * 3 {
            edges.push((rng.below(n) as u32, rng.below(n) as u32));
        }
        let g = Graph::from_undirected(n, &edges);
        let plan = LayerPlan {
            f_in: 8, h_out: 4, g_dim: 4, n_br: nb, fp: 12 / nb, cf: 12, heads: 1,
        };
        let lv = LayerVq::init(&plan, 5, n, &mut rng);
        (g, lv)
    }

    fn freeze_one(lv: &LayerVq) -> LayerCache {
        LayerCache::new(
            lv.plan.clone(),
            lv.k,
            lv.n,
            lv.assign.clone(),
            lv.cw_tensor(),
            lv.mean_tensor().f,
            lv.var_tensor().f,
            Vec::new(),
            Vec::new(),
        )
    }

    fn no_admitted(g: &Graph, lv: &LayerVq) -> AdmittedNodes {
        AdmittedNodes::new(g.n, lv.plan.f_in)
    }

    #[test]
    fn forward_sketches_match_trainer_builders_bitwise() {
        use crate::vq::sketch::{build_cnt_out, build_fixed, build_learnable};
        let (g, lv) = setup(40, 31, 2);
        let cache = freeze_one(&lv);
        let adm = no_admitted(&g, &lv);
        let batch: Vec<u32> = vec![2, 9, 17, 33, 39, 9]; // includes a duplicate
        let mut s1 = SketchScratch::new(g.n);
        let mut s2 = SketchScratch::new(g.n);
        let (ci_t, co_t, _) = build_fixed(&g, Conv::GcnSym, &batch, &lv, &mut s1);
        let (ci_c, co_c) = cache.build_fixed_fwd(&g, &adm, Conv::GcnSym, &batch, &mut s2);
        assert_eq!(ci_t.f, ci_c.f);
        assert_eq!(co_t.f, co_c.f);

        let (g, mut lv) = setup(30, 37, 1);
        lv.plan.n_br = 1;
        let cache = freeze_one(&lv);
        let adm = no_admitted(&g, &lv);
        let batch: Vec<u32> = vec![1, 4, 4, 28];
        let mut s1 = SketchScratch::new(g.n);
        let mut s2 = SketchScratch::new(g.n);
        let (mi_t, mo_t, _) = build_learnable(&g, &batch, &lv, &mut s1);
        let (mi_c, mo_c) = cache.build_learnable_fwd(&g, &adm, &batch, &mut s2);
        assert_eq!(mi_t.f, mi_c.f);
        assert_eq!(mo_t.f, mo_c.f);
        let cnt_t = build_cnt_out(&batch, &lv, &mut s1);
        let cnt_c = cache.build_cnt_fwd(&adm, &batch, &mut s2);
        assert_eq!(cnt_t.f, cnt_c.f);
    }

    #[test]
    fn admitted_rows_merge_neighbors_through_their_codewords() {
        let (g, lv) = setup(24, 51, 2);
        let mut cache = freeze_one(&lv);
        let mut adm = no_admitted(&g, &lv);
        // admit one node with three known in-neighbors
        let id = adm.push(&[0.5; 8], &[1, 5, 9]);
        cache.record_admitted(&[3, 1]);
        assert_eq!(cache.admitted_count(), 1);
        assert_eq!(cache.assign_any(&adm, 0, id as usize), 3);
        assert_eq!(cache.assign_any(&adm, 1, id as usize), 1);

        let batch: Vec<u32> = vec![id, 2];
        let (b, k) = (batch.len(), cache.k);
        let mut scratch = SketchScratch::new(adm.id_bound() as usize);
        let (c_in, c_out) =
            cache.build_fixed_fwd(&g, &adm, Conv::GcnSym, &batch, &mut scratch);
        // the admitted row's mass is its 3 arcs (none of 1/5/9 is in the
        // batch, so all out-of-batch) at the mirrored GCN coefficient plus
        // a self loop — NO message dropped, per branch (paper Fig. 1)
        let dd = (adm.degree(0) + 1) as f32;
        let want: f32 = [1u32, 5, 9]
            .iter()
            .map(|&u| 1.0 / (dd * (g.in_degree(u as usize) + 1) as f32).sqrt())
            .sum::<f32>()
            + 1.0 / dd; // self loop
        for j in 0..2 {
            let intra: f32 = c_in.f[..b].iter().sum(); // row 0 of C_in
            let merged: f32 = c_out.f[(j * b) * k..(j * b) * k + k].iter().sum();
            assert!(
                (intra + merged - want).abs() < 1e-5,
                "branch {j}: {} vs {want}",
                intra + merged
            );
        }
        // each neighbor's coefficient landed in its codeword's bucket
        for &u in &[1u32, 5, 9] {
            let v = cache.assign_any(&adm, 0, u as usize);
            assert!(c_out.f[v] > 0.0, "arc {u}→{id} missing from c_out");
        }

        // the frozen row (node 2) is bit-identical to a no-admission build
        let fresh = freeze_one(&lv);
        let adm0 = no_admitted(&g, &lv);
        let mut s2 = SketchScratch::new(g.n);
        let (ci0, co0) = fresh.build_fixed_fwd(&g, &adm0, Conv::GcnSym, &[2, 7], &mut s2);
        let mut s3 = SketchScratch::new(adm.id_bound() as usize);
        let (ci1, co1) = cache.build_fixed_fwd(&g, &adm, Conv::GcnSym, &[2, 7], &mut s3);
        assert_eq!(ci0.f, ci1.f);
        assert_eq!(co0.f, co1.f);

        // cnt histogram: admitted node counted once it is recorded
        let (g1, mut lv1) = setup(20, 53, 1);
        lv1.plan.n_br = 1;
        let mut c1 = freeze_one(&lv1);
        let mut a1 = AdmittedNodes::new(g1.n, lv1.plan.f_in);
        let mut sc = SketchScratch::new(g1.n + 1);
        let before = c1.build_cnt_fwd(&a1, &[0, 3], &mut sc);
        let nid = a1.push(&[0.0; 8], &[0]);
        // mid-admission (no assignment recorded): histogram unchanged,
        // batches containing the in-flight node skip it
        let mid = c1.build_cnt_fwd(&a1, &[0, nid], &mut sc);
        assert_eq!(mid.f.iter().sum::<f32>(), before.f.iter().sum::<f32>() + 1.0);
        c1.record_admitted(&[2]);
        let after = c1.build_cnt_fwd(&a1, &[0, 3], &mut sc);
        assert_eq!(after.f[2], before.f[2] + 1.0);
        // and once admitted, the node decrements its own bucket in-batch:
        // hist(+node) − {0, node} == hist − {0} == the mid-admission build
        let with = c1.build_cnt_fwd(&a1, &[0, nid], &mut sc);
        assert_eq!(with.f, mid.f);
    }

    #[test]
    fn eviction_compacts_tables_and_restores_histogram_bitwise() {
        let (g, mut lv) = setup(20, 57, 1);
        lv.plan.n_br = 1;
        let mut cache = EmbeddingCache {
            layers: vec![freeze_one(&lv)],
            admitted: AdmittedNodes::new(g.n, lv.plan.f_in),
        };
        let mut sc = SketchScratch::new(64);
        let baseline = cache.layers[0].build_cnt_fwd(&cache.admitted, &[0, 3], &mut sc);
        let mem0 = cache.memory_bytes();
        // admit three nodes into distinct-ish buckets
        let a = cache.admitted.push(&[0.1; 8], &[0]);
        cache.layers[0].record_admitted(&[1]);
        let b = cache.admitted.push(&[0.2; 8], &[1, a]);
        cache.layers[0].record_admitted(&[2]);
        let c = cache.admitted.push(&[0.3; 8], &[b]);
        cache.layers[0].record_admitted(&[1]);
        assert!(cache.memory_bytes() > mem0);
        // evict the middle one: survivor slots compact, ids stay put
        let keep = cache.evict(&[b]);
        assert_eq!(keep, vec![0, 2]);
        assert_eq!(cache.layers[0].admitted_count(), 2);
        assert_eq!(cache.layers[0].assign_any(&cache.admitted, 0, a as usize), 1);
        assert_eq!(cache.layers[0].assign_any(&cache.admitted, 0, c as usize), 1);
        assert_eq!(cache.admitted.slot_of(b), None);
        // evict the rest: the cnt histogram returns to the frozen-only
        // build BIT-identically (+1/−1 on small integers is exact)
        cache.evict(&[a, c]);
        let back = cache.layers[0].build_cnt_fwd(&cache.admitted, &[0, 3], &mut sc);
        assert_eq!(baseline.f, back.f);
        assert_eq!(cache.memory_bytes(), mem0);
    }

    #[test]
    fn drift_signal_rises_with_far_traffic_and_refresh_reduces_it() {
        let (_g, lv) = setup(25, 59, 2);
        let mut cache = freeze_one(&lv);
        // no reference, no observation: no signal
        assert_eq!(cache.drift(), 0.0);
        // reference = rows sitting exactly ON codewords (distance ~0)
        let fp = lv.plan.fp;
        let mut on_codeword = vec![0.0f32; 8];
        for j in 0..2 {
            let lo = j * fp;
            let width = fp.min(8 - lo);
            for d in 0..width {
                on_codeword[lo + d] = cache.cw.f[(j * lv.k) * fp + d]; // cluster 0
            }
        }
        for _ in 0..20 {
            cache.observe_reference(&on_codeword);
        }
        assert_eq!(cache.drift(), 0.0, "reference alone is no signal");
        // observed traffic far from every codeword: drift jumps
        let far: Vec<f32> = on_codeword.iter().map(|x| x + 1000.0).collect();
        for _ in 0..20 {
            cache.observe_serving(&far);
        }
        let drifted = cache.drift();
        assert!(drifted > 0.9, "far traffic must alarm, got {drifted}");
        // refresh pulls codewords toward the retained rows → drift drops
        let cw_before = cache.cw.f.clone();
        assert!(cache.refresh(0.2));
        assert!(cache.cw.f != cw_before, "refresh must move codewords");
        let after = cache.drift();
        assert!(
            after < drifted,
            "refresh must reduce the drift metric ({drifted} → {after})"
        );
        // near-codeword traffic, refreshed codebook: assignment still sane
        let mut asg = vec![0u32; 2];
        cache.assign_features(&far, &mut asg);
        assert!(asg.iter().all(|&a| (a as usize) < cache.k));
    }

    #[test]
    fn refresh_without_recent_rows_is_a_bit_exact_noop() {
        let (_g, lv) = setup(25, 61, 2);
        let mut cache = freeze_one(&lv);
        let (cw0, cww0) = (cache.cw.f.clone(), cache.cww.clone());
        assert!(!cache.refresh(0.5));
        assert_eq!(cache.cw.f, cw0);
        assert_eq!(cache.cww, cww0);
    }

    #[test]
    fn assign_features_matches_wholesale_kernel() {
        let (_, lv) = setup(25, 61, 2);
        let cache = freeze_one(&lv);
        let mut rng = Rng::new(8);
        let row: Vec<f32> = (0..8).map(|_| rng.gauss_f32()).collect();
        let mut got = vec![0u32; 2];
        cache.assign_features(&row, &mut got);
        // brute force in the whitened feature-masked space, per branch
        let fp = lv.plan.fp; // 6: branch 0 covers cols 0..6 (all features up
                             // to 8? no: f_in=8 → branch 0 cols 0..6, branch
                             // 1 cols 6..12 of which 6..8 are features)
        for j in 0..2 {
            let lo = j * fp;
            let width = fp.min(8 - lo);
            let br = &lv.branches[j];
            let mut best = (f64::INFINITY, 0usize);
            let mut second = f64::INFINITY;
            for c in 0..lv.k {
                let mut d2 = 0.0f64;
                for d in 0..width {
                    let w = ((row[lo + d] - br.mean[d])
                        * (1.0 / (br.var[d] + crate::vq::EPS).sqrt()))
                        as f64;
                    let cwv = ((cache.cw.f[(j * lv.k + c) * fp + d] - cache.mean[j * fp + d])
                        * (1.0 / (cache.var[j * fp + d] + crate::vq::EPS).sqrt()))
                        as f64;
                    let diff = w - cwv;
                    d2 += diff * diff;
                }
                if d2 < best.0 {
                    second = best.0;
                    best = (d2, c);
                } else if d2 < second {
                    second = d2;
                }
            }
            if second - best.0 > 1e-6 {
                // unique winner: the kernel path must agree (near-ties may
                // legitimately break either way across float paths)
                assert_eq!(got[j] as usize, best.1, "branch {j}");
            }
        }
    }

    #[test]
    fn serving_layer_roundtrip_preserves_cache() {
        let (g, lv) = setup(25, 41, 2);
        let mut cache = EmbeddingCache {
            admitted: AdmittedNodes::new(g.n, lv.plan.f_in),
            layers: vec![freeze_one(&lv)],
        };
        cache.admitted.push(&[1.0; 8], &[3, 4]);
        cache.layers[0].record_admitted(&[2, 4]);
        // a non-empty reference must survive the round trip
        cache.layers[0].observe_reference(&[0.5; 8]);
        let plans = vec![lv.plan.clone()];
        let exported = cache.to_serving_layers();
        let adm_exported = cache.to_serving_admitted();
        let back = EmbeddingCache::from_serving_layers(&plans, exported, adm_exported);
        assert_eq!(cache.layers[0].assign, back.layers[0].assign);
        assert_eq!(cache.layers[0].cw.f, back.layers[0].cw.f);
        assert_eq!(cache.layers[0].mean, back.layers[0].mean);
        assert_eq!(cache.layers[0].var, back.layers[0].var);
        assert_eq!(cache.layers[0].admitted_assign, back.layers[0].admitted_assign);
        assert_eq!(cache.layers[0].cww, back.layers[0].cww, "derived codebooks agree");
        assert_eq!(cache.layers[0].drift_ref, back.layers[0].drift_ref);
        assert_eq!(cache.total_nodes(), back.total_nodes());
        assert_eq!(back.admitted.neighbors_of(0), &[3, 4]);
        assert_eq!(cache.memory_bytes(), back.memory_bytes());
        let l = &cache.layers[0];
        let expect = 4 * (l.assign.len()
            + l.admitted_assign.len()
            + l.cw.numel()
            + l.mean.len()
            + l.var.len()) as u64
            + cache.admitted.memory_bytes();
        assert_eq!(cache.memory_bytes(), expect);
        // with no explicit reference, the observed histogram is promoted
        // to the exported reference (freeze of "the distribution now")
        let mut fresh = EmbeddingCache {
            admitted: AdmittedNodes::new(g.n, lv.plan.f_in),
            layers: vec![freeze_one(&lv)],
        };
        fresh.layers[0].observe_serving(&[0.25; 8]);
        let promoted = fresh.to_serving_layers();
        assert_eq!(promoted[0].drift_ref, fresh.layers[0].drift_obs.bins().to_vec());
    }
}
