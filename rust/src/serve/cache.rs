//! Codebook-backed embedding cache — the serving-side realization of the
//! paper's "compact low-rank" global context.  At load time the cache
//! freezes, per layer, the node→codeword assignment table R (read straight
//! out of `vq::LayerVq`) and the raw-space codewords (the inverse-whitened
//! Ṽ̄, materialized ONCE instead of per batch as the trainers do).  A query
//! batch then only materializes features for its own nodes plus forward
//! sketches against k codewords — no neighbor explosion, no full-graph
//! forward, and no transposed (backward) sketches at all.
//!
//! Memory model: `Σ_l n_br·n × 4` assignment bytes + `Σ_l n_br·k·fp × 4`
//! codeword bytes (reported by [`EmbeddingCache::memory_bytes`]).

use crate::coordinator::checkpoint::ServingLayer;
use crate::graph::{Conv, Graph};
use crate::runtime::manifest::LayerPlan;
use crate::util::tensor::Tensor;
use crate::vq::sketch::SketchScratch;
use crate::vq::VqModel;

/// One layer's frozen VQ state, forward-only.
pub struct LayerCache {
    pub plan: LayerPlan,
    pub k: usize,
    pub n: usize,
    /// Assignment table R, row-major (n_br, n): R_j[node] ∈ [0, k).
    pub assign: Vec<u32>,
    /// Raw-space codewords (n_br, k, fp), precomputed at load time.
    pub cw: Tensor,
    /// Branch-0 cluster populations over ALL nodes, precomputed at load:
    /// `cnt_out` per batch is this histogram minus the batch's members —
    /// O(b + k) per query batch instead of an O(n) sweep.
    global_hist: Vec<f32>,
}

impl LayerCache {
    /// Assemble one frozen layer, precomputing the codeword histogram.
    fn new(plan: LayerPlan, k: usize, n: usize, assign: Vec<u32>, cw: Tensor) -> LayerCache {
        let mut global_hist = vec![0.0f32; k];
        for u in 0..n {
            global_hist[assign[u] as usize] += 1.0;
        }
        LayerCache { plan, k, n, assign, cw, global_hist }
    }

    /// Forward fixed-convolution sketches for a query batch, written into
    /// caller-owned buffers: `(C_in, C̃_out)` — the exact intra-batch block
    /// plus the codeword-merged out-of-batch block.  Mirrors
    /// `vq::sketch::build_fixed` minus the transposed (Eq. 7) side,
    /// accumulating in the same arc order so the tensors are bit-identical
    /// to the trainer's.  The serving session rebuilds its dynamic input
    /// slots in place, so the steady-state micro-batch allocates nothing
    /// here.
    pub fn build_fixed_fwd_into(
        &self,
        graph: &Graph,
        conv: Conv,
        batch: &[u32],
        scratch: &mut SketchScratch,
        c_in: &mut [f32],
        c_out: &mut [f32],
    ) {
        let b = batch.len();
        let (nb, k, n) = (self.plan.n_br, self.k, self.n);
        debug_assert_eq!(c_in.len(), b * b);
        debug_assert_eq!(c_out.len(), nb * b * k);
        c_in.fill(0.0);
        c_out.fill(0.0);
        scratch.mark(batch);
        for (i, &gi) in batch.iter().enumerate() {
            let gi = gi as usize;
            for &u in graph.in_neighbors(gi) {
                let coef = graph.coef(conv, u as usize, gi);
                let p = scratch.pos_of(u as usize);
                if p >= 0 {
                    c_in[i * b + p as usize] += coef;
                } else {
                    for j in 0..nb {
                        let v = self.assign[j * n + u as usize] as usize;
                        c_out[(j * b + i) * k + v] += coef;
                    }
                }
            }
            if conv.with_self_loops() {
                c_in[i * b + i] += graph.coef(conv, gi, gi);
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_fixed_fwd_into`].
    pub fn build_fixed_fwd(
        &self,
        graph: &Graph,
        conv: Conv,
        batch: &[u32],
        scratch: &mut SketchScratch,
    ) -> (Tensor, Tensor) {
        let b = batch.len();
        let (nb, k) = (self.plan.n_br, self.k);
        let mut c_in = vec![0.0f32; b * b];
        let mut c_out = vec![0.0f32; nb * b * k];
        self.build_fixed_fwd_into(graph, conv, batch, scratch, &mut c_in, &mut c_out);
        (
            Tensor::from_f32(&[b, b], c_in),
            Tensor::from_f32(&[nb, b, k], c_out),
        )
    }

    /// Forward learnable-convolution count sketches, written into
    /// caller-owned buffers: `(mask_in, M_out)` — 𝔠 = A+I over the batch
    /// block, out-of-batch in-neighbors counted per codeword bucket.
    /// Mirrors `vq::sketch::build_learnable` minus M_outᵀ.
    pub fn build_learnable_fwd_into(
        &self,
        graph: &Graph,
        batch: &[u32],
        scratch: &mut SketchScratch,
        mask_in: &mut [f32],
        m_out: &mut [f32],
    ) {
        let b = batch.len();
        let k = self.k;
        debug_assert_eq!(self.plan.n_br, 1, "learnable convs use a single branch");
        debug_assert_eq!(mask_in.len(), b * b);
        debug_assert_eq!(m_out.len(), b * k);
        mask_in.fill(0.0);
        m_out.fill(0.0);
        scratch.mark(batch);
        for (i, &gi) in batch.iter().enumerate() {
            let gi = gi as usize;
            mask_in[i * b + i] = 1.0;
            for &u in graph.in_neighbors(gi) {
                let p = scratch.pos_of(u as usize);
                if p >= 0 {
                    mask_in[i * b + p as usize] = 1.0;
                } else {
                    let v = self.assign[u as usize] as usize;
                    m_out[i * k + v] += 1.0;
                }
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_learnable_fwd_into`].
    pub fn build_learnable_fwd(
        &self,
        graph: &Graph,
        batch: &[u32],
        scratch: &mut SketchScratch,
    ) -> (Tensor, Tensor) {
        let b = batch.len();
        let k = self.k;
        let mut mask_in = vec![0.0f32; b * b];
        let mut m_out = vec![0.0f32; b * k];
        self.build_learnable_fwd_into(graph, batch, scratch, &mut mask_in, &mut m_out);
        (
            Tensor::from_f32(&[b, b], mask_in),
            Tensor::from_f32(&[b, k], m_out),
        )
    }

    /// Global out-of-batch cluster histogram (txf global attention),
    /// written into a caller-owned buffer: `cnt_out[v] = |{u ∉ batch :
    /// R[u] = v}|`.  Computed as the frozen all-node histogram minus the
    /// batch's distinct members — counts are small integers, exact in f32,
    /// so the result is bit-identical to `vq::sketch::build_cnt_out`'s O(n)
    /// counting sweep.
    pub fn build_cnt_fwd_into(&self, batch: &[u32], scratch: &mut SketchScratch, cnt: &mut [f32]) {
        debug_assert_eq!(cnt.len(), self.k);
        cnt.copy_from_slice(&self.global_hist);
        scratch.mark(batch);
        for (i, &g) in batch.iter().enumerate() {
            // mark() keeps the LAST occurrence's position: decrement each
            // distinct node exactly once, duplicates included
            if scratch.pos_of(g as usize) == i as i32 {
                cnt[self.assign[g as usize] as usize] -= 1.0;
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_cnt_fwd_into`].
    pub fn build_cnt_fwd(&self, batch: &[u32], scratch: &mut SketchScratch) -> Tensor {
        let mut cnt = vec![0.0f32; self.k];
        self.build_cnt_fwd_into(batch, scratch, &mut cnt);
        Tensor::from_f32(&[self.k], cnt)
    }
}

/// All layers' frozen VQ state for one serving model.
pub struct EmbeddingCache {
    pub layers: Vec<LayerCache>,
}

impl EmbeddingCache {
    /// Freeze a trained `VqModel`: copy the assignment tables and
    /// materialize the raw codeword tensors once.
    pub fn from_vq(vq: &VqModel) -> EmbeddingCache {
        EmbeddingCache {
            layers: vq
                .layers
                .iter()
                .map(|l| {
                    LayerCache::new(l.plan.clone(), l.k, l.n, l.assign.clone(), l.cw_tensor())
                })
                .collect(),
        }
    }

    /// Rebuild from a serving artifact's layers + the serve spec's plans.
    pub fn from_serving_layers(plans: &[LayerPlan], layers: Vec<ServingLayer>) -> EmbeddingCache {
        EmbeddingCache {
            layers: plans
                .iter()
                .zip(layers)
                .map(|(p, l)| {
                    let cw = Tensor::from_f32(&[l.n_br, l.k, l.fp], l.cw);
                    LayerCache::new(p.clone(), l.k, l.n, l.assign, cw)
                })
                .collect(),
        }
    }

    /// Export back into serving-artifact layers.
    pub fn to_serving_layers(&self) -> Vec<ServingLayer> {
        self.layers
            .iter()
            .map(|l| ServingLayer {
                k: l.k,
                n: l.n,
                n_br: l.plan.n_br,
                fp: l.plan.fp,
                cw: l.cw.f.clone(),
                assign: l.assign.clone(),
            })
            .collect()
    }

    /// Resident bytes: n × L assignment words + codebooks (the README's
    /// cache memory model).
    pub fn memory_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| 4 * (l.assign.len() as u64 + l.cw.numel() as u64))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::vq::LayerVq;

    fn setup(n: usize, seed: u64, nb: usize) -> (Graph, LayerVq) {
        let mut rng = Rng::new(seed);
        let mut edges = Vec::new();
        for _ in 0..n * 3 {
            edges.push((rng.below(n) as u32, rng.below(n) as u32));
        }
        let g = Graph::from_undirected(n, &edges);
        let plan = LayerPlan {
            f_in: 8, h_out: 4, g_dim: 4, n_br: nb, fp: 12 / nb, cf: 12, heads: 1,
        };
        let lv = LayerVq::init(&plan, 5, n, &mut rng);
        (g, lv)
    }

    fn freeze_one(lv: &LayerVq) -> LayerCache {
        LayerCache::new(lv.plan.clone(), lv.k, lv.n, lv.assign.clone(), lv.cw_tensor())
    }

    #[test]
    fn forward_sketches_match_trainer_builders_bitwise() {
        use crate::vq::sketch::{build_cnt_out, build_fixed, build_learnable};
        let (g, lv) = setup(40, 31, 2);
        let cache = freeze_one(&lv);
        let batch: Vec<u32> = vec![2, 9, 17, 33, 39, 9]; // includes a duplicate
        let mut s1 = SketchScratch::new(g.n);
        let mut s2 = SketchScratch::new(g.n);
        let (ci_t, co_t, _) = build_fixed(&g, Conv::GcnSym, &batch, &lv, &mut s1);
        let (ci_c, co_c) = cache.build_fixed_fwd(&g, Conv::GcnSym, &batch, &mut s2);
        assert_eq!(ci_t.f, ci_c.f);
        assert_eq!(co_t.f, co_c.f);

        let (g, mut lv) = setup(30, 37, 1);
        lv.plan.n_br = 1;
        let cache = freeze_one(&lv);
        let batch: Vec<u32> = vec![1, 4, 4, 28];
        let mut s1 = SketchScratch::new(g.n);
        let mut s2 = SketchScratch::new(g.n);
        let (mi_t, mo_t, _) = build_learnable(&g, &batch, &lv, &mut s1);
        let (mi_c, mo_c) = cache.build_learnable_fwd(&g, &batch, &mut s2);
        assert_eq!(mi_t.f, mi_c.f);
        assert_eq!(mo_t.f, mo_c.f);
        let cnt_t = build_cnt_out(&batch, &lv, &mut s1);
        let cnt_c = cache.build_cnt_fwd(&batch, &mut s2);
        assert_eq!(cnt_t.f, cnt_c.f);
    }

    #[test]
    fn serving_layer_roundtrip_preserves_cache() {
        let (_, lv) = setup(25, 41, 2);
        let cache = EmbeddingCache {
            layers: vec![freeze_one(&lv)],
        };
        let plans = vec![lv.plan.clone()];
        let exported = cache.to_serving_layers();
        let back = EmbeddingCache::from_serving_layers(&plans, exported);
        assert_eq!(cache.layers[0].assign, back.layers[0].assign);
        assert_eq!(cache.layers[0].cw.f, back.layers[0].cw.f);
        assert_eq!(cache.memory_bytes(), back.memory_bytes());
        assert_eq!(
            cache.memory_bytes(),
            4 * (2 * 25 + 2 * 5 * 6) as u64 // assignments + codewords
        );
    }
}
