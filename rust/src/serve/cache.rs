//! Codebook-backed embedding cache — the serving-side realization of the
//! paper's "compact low-rank" global context.  At load time the cache
//! freezes, per layer, the node→codeword assignment table R (read straight
//! out of `vq::LayerVq`), the raw-space codewords (the inverse-whitened
//! Ṽ̄, materialized ONCE instead of per batch as the trainers do), and the
//! per-branch whitening stats (so inductive admission can run FINDNEAREST
//! in the same whitened space training used).  A query batch then only
//! materializes features for its own nodes plus forward sketches against k
//! codewords — no neighbor explosion, no full-graph forward, and no
//! transposed (backward) sketches at all.
//!
//! The cache is **shared and read-only on the serve path**: every builder
//! here takes `&self`, so N pool sessions can build their sketches against
//! one cache concurrently.  The only writer is the admission path
//! ([`LayerCache::record_admitted`] behind `&mut ServingModel`), which
//! appends to the admitted tails — never touching the frozen tables.
//!
//! Memory model: `Σ_l n_br·(n + admitted)` assignment words + `Σ_l
//! n_br·k·fp` codeword floats + whitening stats + the admitted block
//! (reported by [`EmbeddingCache::memory_bytes`]).

use crate::coordinator::checkpoint::{ServingAdmitted, ServingLayer};
use crate::graph::{Conv, Graph};
use crate::runtime::manifest::LayerPlan;
use crate::serve::admit::AdmittedNodes;
use crate::util::tensor::Tensor;
use crate::vq::sketch::SketchScratch;
use crate::vq::{kernels, VqModel};

/// In-degree of any servable id (frozen graph, or the admitted CSR).
fn deg_any(graph: &Graph, adm: &AdmittedNodes, v: usize) -> usize {
    if v < graph.n {
        graph.in_degree(v)
    } else {
        adm.degree(v - graph.n)
    }
}

/// Convolution coefficient of the arc (src → dst) with admitted ids
/// allowed on either end.  Arcs between two frozen nodes go through
/// `Graph::coef` untouched (bit-identical to the pre-admission path);
/// arcs touching an admitted node mirror the same Table-1 formulas with
/// the admitted node's degree read from its CSR record.
fn coef_any(graph: &Graph, adm: &AdmittedNodes, conv: Conv, src: usize, dst: usize) -> f32 {
    if src < graph.n && dst < graph.n {
        return graph.coef(conv, src, dst);
    }
    match conv {
        Conv::GcnSym => {
            let dd = (deg_any(graph, adm, dst) + 1) as f32;
            let ds = (deg_any(graph, adm, src) + 1) as f32;
            1.0 / (dd * ds).sqrt()
        }
        Conv::SageMean => {
            let d = deg_any(graph, adm, dst);
            if d == 0 {
                0.0
            } else {
                1.0 / d as f32
            }
        }
    }
}

/// In-neighbors of any servable id.
fn nbrs_any<'a>(graph: &'a Graph, adm: &'a AdmittedNodes, v: usize) -> &'a [u32] {
    if v < graph.n {
        graph.in_neighbors(v)
    } else {
        adm.neighbors_of(v - graph.n)
    }
}

/// One layer's frozen VQ state, forward-only, plus its admitted tail.
pub struct LayerCache {
    pub plan: LayerPlan,
    pub k: usize,
    pub n: usize,
    /// Assignment table R, row-major (n_br, n): R_j[node] ∈ [0, k).
    pub assign: Vec<u32>,
    /// Raw-space codewords (n_br, k, fp), precomputed at load time.
    pub cw: Tensor,
    /// Whitening mean, row-major (n_br, fp) — admission FINDNEAREST input.
    pub mean: Vec<f32>,
    /// Whitening variance, row-major (n_br, fp).
    pub var: Vec<f32>,
    /// Whitened codewords (n_br, k, fp), derived once from `cw`/`mean`/
    /// `var` — the admission path's codebook.  Deriving (instead of
    /// freezing the trainer's own whitened table) keeps admission
    /// deterministic across save → load: the raw codewords round-trip
    /// exactly, so both sides derive the same table.
    cww: Vec<f32>,
    /// Admitted-node assignments, node-major (count, n_br): entry
    /// `[off * n_br + j]` is branch j's codeword for id `n + off`.
    pub admitted_assign: Vec<u32>,
    /// Branch-0 cluster populations over ALL servable nodes (frozen +
    /// admitted), maintained on admission: `cnt_out` per batch is this
    /// histogram minus the batch's members — O(b + k) per query batch
    /// instead of an O(n) sweep.
    global_hist: Vec<f32>,
}

impl LayerCache {
    /// Assemble one frozen layer: derive the whitened codebook, count the
    /// codeword histogram (admitted tail included).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        plan: LayerPlan,
        k: usize,
        n: usize,
        assign: Vec<u32>,
        cw: Tensor,
        mean: Vec<f32>,
        var: Vec<f32>,
        admitted_assign: Vec<u32>,
    ) -> LayerCache {
        let (nb, fp) = (plan.n_br, plan.fp);
        debug_assert_eq!(mean.len(), nb * fp);
        debug_assert_eq!(var.len(), nb * fp);
        let mut cww = vec![0.0f32; nb * k * fp];
        let mut inv = vec![0.0f32; fp];
        for j in 0..nb {
            kernels::inv_std_into(&var[j * fp..(j + 1) * fp], &mut inv);
            for v in 0..k {
                for d in 0..fp {
                    let idx = (j * k + v) * fp + d;
                    cww[idx] = (cw.f[idx] - mean[j * fp + d]) * inv[d];
                }
            }
        }
        let mut global_hist = vec![0.0f32; k];
        for u in 0..n {
            global_hist[assign[u] as usize] += 1.0;
        }
        for off in 0..admitted_assign.len() / nb.max(1) {
            global_hist[admitted_assign[off * nb] as usize] += 1.0;
        }
        LayerCache { plan, k, n, assign, cw, mean, var, cww, admitted_assign, global_hist }
    }

    /// Admitted nodes recorded in THIS layer's table (during an admission
    /// bootstrap the in-flight node exists in the feature/neighbor store
    /// but not yet here).
    pub fn admitted_count(&self) -> usize {
        self.admitted_assign.len() / self.plan.n_br.max(1)
    }

    /// Branch-j codeword of any servable id (frozen table or admitted
    /// tail).
    #[inline]
    pub fn assign_any(&self, j: usize, u: usize) -> usize {
        if u < self.n {
            self.assign[j * self.n + u] as usize
        } else {
            self.admitted_assign[(u - self.n) * self.plan.n_br + j] as usize
        }
    }

    /// Append one admitted node's per-branch assignments (single-writer
    /// path) and fold it into the global histogram.
    pub fn record_admitted(&mut self, assigns: &[u32]) {
        debug_assert_eq!(assigns.len(), self.plan.n_br);
        debug_assert!(assigns.iter().all(|&a| (a as usize) < self.k));
        self.admitted_assign.extend_from_slice(assigns);
        self.global_hist[assigns[0] as usize] += 1.0;
    }

    /// Nearest-codeword assignment of one node from its layer-input
    /// feature row, per branch, against the frozen codebooks — the
    /// admission FINDNEAREST.  Mirrors the trainer's inductive bootstrap
    /// (`VqTrainer::assign_by_features`): feature columns only (an unseen
    /// node has no gradient history), whitened per branch, ties to the
    /// lowest index via `vq::kernels::assign_blocked`.  Branches whose
    /// concat slice is entirely gradient columns get codeword 0 — their
    /// assignment never reaches the forward pass (the serve step reads
    /// only feature columns of the unsketched concat).
    pub fn assign_features(&self, row: &[f32], out: &mut [u32]) {
        let (fl, fp, k, nb) = (self.plan.f_in, self.plan.fp, self.k, self.plan.n_br);
        debug_assert_eq!(row.len(), fl);
        debug_assert_eq!(out.len(), nb);
        let mut inv = vec![0.0f32; fp];
        let mut vw = vec![0.0f32; fp];
        for j in 0..nb {
            let lo = j * fp;
            if lo >= fl {
                out[j] = 0; // pure-gradient branch: forward-neutral
                continue;
            }
            let width = fp.min(fl - lo);
            kernels::inv_std_into(&self.var[j * fp..j * fp + width], &mut inv[..width]);
            for d in 0..width {
                vw[d] = (row[lo + d] - self.mean[j * fp + d]) * inv[d];
            }
            let mut a = [0i32];
            kernels::assign_blocked(
                &vw[..width],
                width,
                width,
                &self.cww[j * k * fp..(j + 1) * k * fp],
                k,
                fp,
                &mut a,
            );
            out[j] = a[0] as u32;
        }
    }

    /// Forward fixed-convolution sketches for a query batch, written into
    /// caller-owned buffers: `(C_in, C̃_out)` — the exact intra-batch block
    /// plus the codeword-merged out-of-batch block.  Mirrors
    /// `vq::sketch::build_fixed` minus the transposed (Eq. 7) side,
    /// accumulating in the same arc order so the tensors are bit-identical
    /// to the trainer's for frozen-node batches; admitted rows read their
    /// neighbors/degrees from the admitted CSR.  The serving session
    /// rebuilds its dynamic input slots in place, so the steady-state
    /// micro-batch allocates nothing here.
    #[allow(clippy::too_many_arguments)]
    pub fn build_fixed_fwd_into(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        conv: Conv,
        batch: &[u32],
        scratch: &mut SketchScratch,
        c_in: &mut [f32],
        c_out: &mut [f32],
    ) {
        let b = batch.len();
        let (nb, k) = (self.plan.n_br, self.k);
        debug_assert_eq!(c_in.len(), b * b);
        debug_assert_eq!(c_out.len(), nb * b * k);
        c_in.fill(0.0);
        c_out.fill(0.0);
        scratch.mark(batch);
        for (i, &gi) in batch.iter().enumerate() {
            let gi = gi as usize;
            for &u in nbrs_any(graph, adm, gi) {
                let coef = coef_any(graph, adm, conv, u as usize, gi);
                let p = scratch.pos_of(u as usize);
                if p >= 0 {
                    c_in[i * b + p as usize] += coef;
                } else {
                    for j in 0..nb {
                        let v = self.assign_any(j, u as usize);
                        c_out[(j * b + i) * k + v] += coef;
                    }
                }
            }
            if conv.with_self_loops() {
                c_in[i * b + i] += coef_any(graph, adm, conv, gi, gi);
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_fixed_fwd_into`].
    pub fn build_fixed_fwd(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        conv: Conv,
        batch: &[u32],
        scratch: &mut SketchScratch,
    ) -> (Tensor, Tensor) {
        let b = batch.len();
        let (nb, k) = (self.plan.n_br, self.k);
        let mut c_in = vec![0.0f32; b * b];
        let mut c_out = vec![0.0f32; nb * b * k];
        self.build_fixed_fwd_into(graph, adm, conv, batch, scratch, &mut c_in, &mut c_out);
        (
            Tensor::from_f32(&[b, b], c_in),
            Tensor::from_f32(&[nb, b, k], c_out),
        )
    }

    /// Forward learnable-convolution count sketches, written into
    /// caller-owned buffers: `(mask_in, M_out)` — 𝔠 = A+I over the batch
    /// block, out-of-batch in-neighbors counted per codeword bucket.
    /// Mirrors `vq::sketch::build_learnable` minus M_outᵀ.
    pub fn build_learnable_fwd_into(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        batch: &[u32],
        scratch: &mut SketchScratch,
        mask_in: &mut [f32],
        m_out: &mut [f32],
    ) {
        let b = batch.len();
        let k = self.k;
        debug_assert_eq!(self.plan.n_br, 1, "learnable convs use a single branch");
        debug_assert_eq!(mask_in.len(), b * b);
        debug_assert_eq!(m_out.len(), b * k);
        mask_in.fill(0.0);
        m_out.fill(0.0);
        scratch.mark(batch);
        for (i, &gi) in batch.iter().enumerate() {
            let gi = gi as usize;
            mask_in[i * b + i] = 1.0;
            for &u in nbrs_any(graph, adm, gi) {
                let p = scratch.pos_of(u as usize);
                if p >= 0 {
                    mask_in[i * b + p as usize] = 1.0;
                } else {
                    let v = self.assign_any(0, u as usize);
                    m_out[i * k + v] += 1.0;
                }
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_learnable_fwd_into`].
    pub fn build_learnable_fwd(
        &self,
        graph: &Graph,
        adm: &AdmittedNodes,
        batch: &[u32],
        scratch: &mut SketchScratch,
    ) -> (Tensor, Tensor) {
        let b = batch.len();
        let k = self.k;
        let mut mask_in = vec![0.0f32; b * b];
        let mut m_out = vec![0.0f32; b * k];
        self.build_learnable_fwd_into(graph, adm, batch, scratch, &mut mask_in, &mut m_out);
        (
            Tensor::from_f32(&[b, b], mask_in),
            Tensor::from_f32(&[b, k], m_out),
        )
    }

    /// Global out-of-batch cluster histogram (txf global attention),
    /// written into a caller-owned buffer: `cnt_out[v] = |{u ∉ batch :
    /// R[u] = v}|` over all servable nodes.  Computed as the maintained
    /// histogram minus the batch's distinct members — counts are small
    /// integers, exact in f32, so the result is bit-identical to
    /// `vq::sketch::build_cnt_out`'s O(n) counting sweep on frozen-node
    /// batches.  A batch member that is mid-admission (recorded features
    /// but no assignment yet — the bootstrap forward itself) is not in the
    /// histogram and is skipped.
    pub fn build_cnt_fwd_into(&self, batch: &[u32], scratch: &mut SketchScratch, cnt: &mut [f32]) {
        debug_assert_eq!(cnt.len(), self.k);
        cnt.copy_from_slice(&self.global_hist);
        scratch.mark(batch);
        for (i, &g) in batch.iter().enumerate() {
            // mark() keeps the LAST occurrence's position: decrement each
            // distinct node exactly once, duplicates included
            if scratch.pos_of(g as usize) == i as i32 {
                let u = g as usize;
                if u >= self.n && u - self.n >= self.admitted_count() {
                    continue; // mid-admission: not in the histogram
                }
                cnt[self.assign_any(0, u)] -= 1.0;
            }
        }
        scratch.unmark(batch);
    }

    /// Allocating wrapper of [`LayerCache::build_cnt_fwd_into`].
    pub fn build_cnt_fwd(&self, batch: &[u32], scratch: &mut SketchScratch) -> Tensor {
        let mut cnt = vec![0.0f32; self.k];
        self.build_cnt_fwd_into(batch, scratch, &mut cnt);
        Tensor::from_f32(&[self.k], cnt)
    }
}

/// All layers' frozen VQ state for one serving model, plus the
/// admitted-node store shared by every layer.
pub struct EmbeddingCache {
    pub layers: Vec<LayerCache>,
    pub admitted: AdmittedNodes,
}

impl EmbeddingCache {
    /// Freeze a trained `VqModel`: copy the assignment tables, materialize
    /// the raw codeword tensors once, and snapshot the whitening stats.
    pub fn from_vq(vq: &VqModel) -> EmbeddingCache {
        let layers: Vec<LayerCache> = vq
            .layers
            .iter()
            .map(|l| {
                LayerCache::new(
                    l.plan.clone(),
                    l.k,
                    l.n,
                    l.assign.clone(),
                    l.cw_tensor(),
                    l.mean_tensor().f,
                    l.var_tensor().f,
                    Vec::new(),
                )
            })
            .collect();
        let (n, f_pad) = (
            layers.first().map(|l| l.n).unwrap_or(0),
            layers.first().map(|l| l.plan.f_in).unwrap_or(0),
        );
        EmbeddingCache { layers, admitted: AdmittedNodes::new(n, f_pad) }
    }

    /// Rebuild from a serving artifact's layers + the serve spec's plans.
    pub fn from_serving_layers(
        plans: &[LayerPlan],
        layers: Vec<ServingLayer>,
        admitted: ServingAdmitted,
    ) -> EmbeddingCache {
        let layers: Vec<LayerCache> = plans
            .iter()
            .zip(layers)
            .map(|(p, l)| {
                let cw = Tensor::from_f32(&[l.n_br, l.k, l.fp], l.cw);
                LayerCache::new(p.clone(), l.k, l.n, l.assign, cw, l.mean, l.var,
                                l.admitted_assign)
            })
            .collect();
        let (n, f_pad) = (
            layers.first().map(|l| l.n).unwrap_or(0),
            layers.first().map(|l| l.plan.f_in).unwrap_or(0),
        );
        EmbeddingCache {
            layers,
            admitted: AdmittedNodes::from_serving(n, f_pad, admitted),
        }
    }

    /// Export back into serving-artifact layers.
    pub fn to_serving_layers(&self) -> Vec<ServingLayer> {
        self.layers
            .iter()
            .map(|l| ServingLayer {
                k: l.k,
                n: l.n,
                n_br: l.plan.n_br,
                fp: l.plan.fp,
                cw: l.cw.f.clone(),
                assign: l.assign.clone(),
                mean: l.mean.clone(),
                var: l.var.clone(),
                admitted_assign: l.admitted_assign.clone(),
            })
            .collect()
    }

    /// Export the admitted block.
    pub fn to_serving_admitted(&self) -> ServingAdmitted {
        self.admitted.to_serving()
    }

    /// Total servable ids: dataset nodes + admitted nodes.
    pub fn total_nodes(&self) -> usize {
        self.admitted.total()
    }

    /// Gather padded feature rows for any servable ids into a caller-owned
    /// `(b, f)` buffer — frozen nodes from the dataset's feature matrix,
    /// admitted nodes from the admitted store.
    pub fn gather_features_into(&self, features: &[f32], f: usize, batch: &[u32],
                                out: &mut [f32]) {
        debug_assert_eq!(out.len(), batch.len() * f);
        let base = self.admitted.base_n;
        for (i, &v) in batch.iter().enumerate() {
            let v = v as usize;
            let dst = &mut out[i * f..(i + 1) * f];
            if v < base {
                dst.copy_from_slice(&features[v * f..(v + 1) * f]);
            } else {
                dst.copy_from_slice(self.admitted.feature_row(v - base));
            }
        }
    }

    /// Resident bytes: assignment words (frozen + admitted), codebooks,
    /// whitening stats, and the admitted feature/CSR block (the README's
    /// cache memory model).
    pub fn memory_bytes(&self) -> u64 {
        let layers: u64 = self
            .layers
            .iter()
            .map(|l| {
                4 * (l.assign.len()
                    + l.admitted_assign.len()
                    + l.cw.numel()
                    + l.mean.len()
                    + l.var.len()) as u64
            })
            .sum();
        layers + self.admitted.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::vq::LayerVq;

    fn setup(n: usize, seed: u64, nb: usize) -> (Graph, LayerVq) {
        let mut rng = Rng::new(seed);
        let mut edges = Vec::new();
        for _ in 0..n * 3 {
            edges.push((rng.below(n) as u32, rng.below(n) as u32));
        }
        let g = Graph::from_undirected(n, &edges);
        let plan = LayerPlan {
            f_in: 8, h_out: 4, g_dim: 4, n_br: nb, fp: 12 / nb, cf: 12, heads: 1,
        };
        let lv = LayerVq::init(&plan, 5, n, &mut rng);
        (g, lv)
    }

    fn freeze_one(lv: &LayerVq) -> LayerCache {
        LayerCache::new(
            lv.plan.clone(),
            lv.k,
            lv.n,
            lv.assign.clone(),
            lv.cw_tensor(),
            lv.mean_tensor().f,
            lv.var_tensor().f,
            Vec::new(),
        )
    }

    fn no_admitted(g: &Graph, lv: &LayerVq) -> AdmittedNodes {
        AdmittedNodes::new(g.n, lv.plan.f_in)
    }

    #[test]
    fn forward_sketches_match_trainer_builders_bitwise() {
        use crate::vq::sketch::{build_cnt_out, build_fixed, build_learnable};
        let (g, lv) = setup(40, 31, 2);
        let cache = freeze_one(&lv);
        let adm = no_admitted(&g, &lv);
        let batch: Vec<u32> = vec![2, 9, 17, 33, 39, 9]; // includes a duplicate
        let mut s1 = SketchScratch::new(g.n);
        let mut s2 = SketchScratch::new(g.n);
        let (ci_t, co_t, _) = build_fixed(&g, Conv::GcnSym, &batch, &lv, &mut s1);
        let (ci_c, co_c) = cache.build_fixed_fwd(&g, &adm, Conv::GcnSym, &batch, &mut s2);
        assert_eq!(ci_t.f, ci_c.f);
        assert_eq!(co_t.f, co_c.f);

        let (g, mut lv) = setup(30, 37, 1);
        lv.plan.n_br = 1;
        let cache = freeze_one(&lv);
        let adm = no_admitted(&g, &lv);
        let batch: Vec<u32> = vec![1, 4, 4, 28];
        let mut s1 = SketchScratch::new(g.n);
        let mut s2 = SketchScratch::new(g.n);
        let (mi_t, mo_t, _) = build_learnable(&g, &batch, &lv, &mut s1);
        let (mi_c, mo_c) = cache.build_learnable_fwd(&g, &adm, &batch, &mut s2);
        assert_eq!(mi_t.f, mi_c.f);
        assert_eq!(mo_t.f, mo_c.f);
        let cnt_t = build_cnt_out(&batch, &lv, &mut s1);
        let cnt_c = cache.build_cnt_fwd(&batch, &mut s2);
        assert_eq!(cnt_t.f, cnt_c.f);
    }

    #[test]
    fn admitted_rows_merge_neighbors_through_their_codewords() {
        let (g, lv) = setup(24, 51, 2);
        let mut cache = freeze_one(&lv);
        let mut adm = no_admitted(&g, &lv);
        // admit one node with three known in-neighbors
        let id = adm.push(&[0.5; 8], &[1, 5, 9]);
        cache.record_admitted(&[3, 1]);
        assert_eq!(cache.admitted_count(), 1);
        assert_eq!(cache.assign_any(0, id as usize), 3);
        assert_eq!(cache.assign_any(1, id as usize), 1);

        let batch: Vec<u32> = vec![id, 2];
        let (b, k) = (batch.len(), cache.k);
        let mut scratch = SketchScratch::new(adm.total());
        let (c_in, c_out) =
            cache.build_fixed_fwd(&g, &adm, Conv::GcnSym, &batch, &mut scratch);
        // the admitted row's mass is its 3 arcs (none of 1/5/9 is in the
        // batch, so all out-of-batch) at the mirrored GCN coefficient plus
        // a self loop — NO message dropped, per branch (paper Fig. 1)
        let dd = (adm.degree(0) + 1) as f32;
        let want: f32 = [1u32, 5, 9]
            .iter()
            .map(|&u| 1.0 / (dd * (g.in_degree(u as usize) + 1) as f32).sqrt())
            .sum::<f32>()
            + 1.0 / dd; // self loop
        for j in 0..2 {
            let intra: f32 = c_in.f[..b].iter().sum(); // row 0 of C_in
            let merged: f32 = c_out.f[(j * b) * k..(j * b) * k + k].iter().sum();
            assert!(
                (intra + merged - want).abs() < 1e-5,
                "branch {j}: {} vs {want}",
                intra + merged
            );
        }
        // each neighbor's coefficient landed in its codeword's bucket
        for &u in &[1u32, 5, 9] {
            let v = cache.assign_any(0, u as usize);
            assert!(c_out.f[v] > 0.0, "arc {u}→{id} missing from c_out");
        }

        // the frozen row (node 2) is bit-identical to a no-admission build
        let fresh = freeze_one(&lv);
        let adm0 = no_admitted(&g, &lv);
        let mut s2 = SketchScratch::new(g.n);
        let (ci0, co0) = fresh.build_fixed_fwd(&g, &adm0, Conv::GcnSym, &[2, 7], &mut s2);
        let mut s3 = SketchScratch::new(adm.total());
        let (ci1, co1) = cache.build_fixed_fwd(&g, &adm, Conv::GcnSym, &[2, 7], &mut s3);
        assert_eq!(ci0.f, ci1.f);
        assert_eq!(co0.f, co1.f);

        // cnt histogram: admitted node counted once it is recorded
        let (g1, mut lv1) = setup(20, 53, 1);
        lv1.plan.n_br = 1;
        let mut c1 = freeze_one(&lv1);
        let mut a1 = AdmittedNodes::new(g1.n, lv1.plan.f_in);
        let mut sc = SketchScratch::new(g1.n + 1);
        let before = c1.build_cnt_fwd(&[0, 3], &mut sc);
        let nid = a1.push(&[0.0; 8], &[0]);
        // mid-admission (no assignment recorded): histogram unchanged,
        // batches containing the in-flight node skip it
        let mid = c1.build_cnt_fwd(&[0, nid], &mut sc);
        assert_eq!(mid.f.iter().sum::<f32>(), before.f.iter().sum::<f32>() + 1.0);
        c1.record_admitted(&[2]);
        let after = c1.build_cnt_fwd(&[0, 3], &mut sc);
        assert_eq!(after.f[2], before.f[2] + 1.0);
        // and once admitted, the node decrements its own bucket in-batch:
        // hist(+node) − {0, node} == hist − {0} == the mid-admission build
        let with = c1.build_cnt_fwd(&[0, nid], &mut sc);
        assert_eq!(with.f, mid.f);
    }

    #[test]
    fn assign_features_matches_wholesale_kernel() {
        let (_, lv) = setup(25, 61, 2);
        let cache = freeze_one(&lv);
        let mut rng = Rng::new(8);
        let row: Vec<f32> = (0..8).map(|_| rng.gauss_f32()).collect();
        let mut got = vec![0u32; 2];
        cache.assign_features(&row, &mut got);
        // brute force in the whitened feature-masked space, per branch
        let fp = lv.plan.fp; // 6: branch 0 covers cols 0..6 (all features up
                             // to 8? no: f_in=8 → branch 0 cols 0..6, branch
                             // 1 cols 6..12 of which 6..8 are features)
        for j in 0..2 {
            let lo = j * fp;
            let width = fp.min(8 - lo);
            let br = &lv.branches[j];
            let mut best = (f64::INFINITY, 0usize);
            let mut second = f64::INFINITY;
            for c in 0..lv.k {
                let mut d2 = 0.0f64;
                for d in 0..width {
                    let w = ((row[lo + d] - br.mean[d])
                        * (1.0 / (br.var[d] + crate::vq::EPS).sqrt()))
                        as f64;
                    let cwv = ((cache.cw.f[(j * lv.k + c) * fp + d] - cache.mean[j * fp + d])
                        * (1.0 / (cache.var[j * fp + d] + crate::vq::EPS).sqrt()))
                        as f64;
                    let diff = w - cwv;
                    d2 += diff * diff;
                }
                if d2 < best.0 {
                    second = best.0;
                    best = (d2, c);
                } else if d2 < second {
                    second = d2;
                }
            }
            if second - best.0 > 1e-6 {
                // unique winner: the kernel path must agree (near-ties may
                // legitimately break either way across float paths)
                assert_eq!(got[j] as usize, best.1, "branch {j}");
            }
        }
    }

    #[test]
    fn serving_layer_roundtrip_preserves_cache() {
        let (g, lv) = setup(25, 41, 2);
        let mut cache = EmbeddingCache {
            admitted: AdmittedNodes::new(g.n, lv.plan.f_in),
            layers: vec![freeze_one(&lv)],
        };
        cache.admitted.push(&[1.0; 8], &[3, 4]);
        cache.layers[0].record_admitted(&[2, 4]);
        let plans = vec![lv.plan.clone()];
        let exported = cache.to_serving_layers();
        let adm_exported = cache.to_serving_admitted();
        let back = EmbeddingCache::from_serving_layers(&plans, exported, adm_exported);
        assert_eq!(cache.layers[0].assign, back.layers[0].assign);
        assert_eq!(cache.layers[0].cw.f, back.layers[0].cw.f);
        assert_eq!(cache.layers[0].mean, back.layers[0].mean);
        assert_eq!(cache.layers[0].var, back.layers[0].var);
        assert_eq!(cache.layers[0].admitted_assign, back.layers[0].admitted_assign);
        assert_eq!(cache.layers[0].cww, back.layers[0].cww, "derived codebooks agree");
        assert_eq!(cache.total_nodes(), back.total_nodes());
        assert_eq!(back.admitted.neighbors_of(0), &[3, 4]);
        assert_eq!(cache.memory_bytes(), back.memory_bytes());
        let l = &cache.layers[0];
        let expect = 4 * (l.assign.len()
            + l.admitted_assign.len()
            + l.cw.numel()
            + l.mean.len()
            + l.var.len()) as u64
            + cache.admitted.memory_bytes();
        assert_eq!(cache.memory_bytes(), expect);
    }
}
