//! Codebook-drift detection — the serving-side health signal for the
//! paper's central approximation.  Every answer rides the frozen
//! codebooks: an out-of-batch message is replaced by its node's assigned
//! codeword, so the approximation error is governed by the
//! distance-to-nearest-codeword of the traffic actually being served.
//! When that distribution walks away from the one the codebooks were
//! fitted on (new nodes from a different regime, feature drift), answers
//! silently degrade — nothing in the forward pass fails.
//!
//! The detector is a fixed-bin histogram of whitened per-dimension RMS
//! distances (the same whitened space training's FINDNEAREST ran in, so
//! "far" means the same thing it meant to the trainer):
//!
//! - a **reference** histogram frozen at export time — seeded from the
//!   frozen nodes' own distances when a trainer is frozen, carried in the
//!   "VQS3" checkpoint block;
//! - an **observed** histogram accumulated online from serving traffic
//!   (flush batches, admissions) by the single-writer maintenance hook.
//!
//! Drift is the total-variation distance between the two normalized
//! histograms: 0 (same distribution) … 1 (disjoint).  TV is insensitive
//! to traffic volume — only the *shape* of the distance distribution
//! matters — and is exactly 0 until both histograms hold data, so a
//! fresh model or a legacy (VQS1/VQS2) load never false-alarms.

/// Histogram resolution.  16 bins over `[0, DRIFT_MAX_DIST)` is coarse
/// enough to be volume-stable and fine enough that a drifted mode (mass
/// past the training distances) moves several bins of probability.
pub const DRIFT_BINS: usize = 16;

/// Saturation point of the binning, in whitened per-dim RMS distance.
/// Whitened dimensions have ~unit variance, so training-regime distances
/// land well under this; anything at or past it is "far" and shares the
/// last bin.
pub const DRIFT_MAX_DIST: f32 = 4.0;

/// A fixed-bin distance histogram (counts kept in f32 — they are small
/// integers, exact well past any realistic sample count).
#[derive(Debug, Clone, PartialEq)]
pub struct DriftHistogram {
    bins: Vec<f32>,
}

impl Default for DriftHistogram {
    fn default() -> DriftHistogram {
        DriftHistogram::new()
    }
}

impl DriftHistogram {
    pub fn new() -> DriftHistogram {
        DriftHistogram { bins: vec![0.0; DRIFT_BINS] }
    }

    /// Rebuild from serialized bin counts (a checkpoint's reference
    /// block).  An empty vector means "no reference" and stays empty;
    /// anything else is normalized to `DRIFT_BINS` entries.
    pub fn from_bins(bins: Vec<f32>) -> DriftHistogram {
        if bins.is_empty() {
            return DriftHistogram::new();
        }
        let mut h = DriftHistogram::new();
        for (i, v) in bins.into_iter().enumerate().take(DRIFT_BINS) {
            h.bins[i] = v;
        }
        h
    }

    /// Record one distance sample.  Non-finite distances (a poisoned
    /// input row) land in the saturation bin — they are maximally "far".
    pub fn record(&mut self, dist: f32) {
        let b = if dist.is_finite() && dist >= 0.0 {
            ((dist / DRIFT_MAX_DIST) * DRIFT_BINS as f32) as usize
        } else {
            DRIFT_BINS - 1
        };
        self.bins[b.min(DRIFT_BINS - 1)] += 1.0;
    }

    pub fn total(&self) -> f32 {
        self.bins.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total() <= 0.0
    }

    pub fn bins(&self) -> &[f32] {
        &self.bins
    }

    pub fn clear(&mut self) {
        self.bins.fill(0.0);
    }

    /// Total-variation distance between the two normalized histograms:
    /// `0.5 · Σ_i |p_i − q_i|` ∈ [0, 1].  Returns 0 unless BOTH sides
    /// hold samples — no reference (or no traffic) is "no signal", not
    /// "alarm".
    pub fn tv_distance(&self, other: &DriftHistogram) -> f32 {
        let (tp, tq) = (self.total(), other.total());
        if tp <= 0.0 || tq <= 0.0 {
            return 0.0;
        }
        let mut acc = 0.0f64;
        for (p, q) in self.bins.iter().zip(&other.bins) {
            acc += ((p / tp) as f64 - (q / tq) as f64).abs();
        }
        (0.5 * acc) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binning_saturates_and_rejects_nonfinite() {
        let mut h = DriftHistogram::new();
        h.record(0.0); // first bin
        h.record(DRIFT_MAX_DIST * 0.99); // last bin
        h.record(DRIFT_MAX_DIST * 100.0); // saturates into the last bin
        h.record(f32::NAN); // poisoned row: maximally far
        h.record(f32::INFINITY);
        assert_eq!(h.bins()[0], 1.0);
        assert_eq!(h.bins()[DRIFT_BINS - 1], 4.0);
        assert_eq!(h.total(), 5.0);
    }

    #[test]
    fn tv_distance_is_zero_same_one_disjoint_and_volume_insensitive() {
        let (mut a, mut b) = (DriftHistogram::new(), DriftHistogram::new());
        // empty vs anything: no signal
        assert_eq!(a.tv_distance(&b), 0.0);
        a.record(0.1);
        assert_eq!(a.tv_distance(&b), 0.0);
        // same shape at different volumes: still zero
        b.record(0.1);
        b.record(0.1);
        assert!(a.tv_distance(&b).abs() < 1e-7);
        // disjoint support: maximal drift
        let (mut lo, mut hi) = (DriftHistogram::new(), DriftHistogram::new());
        for _ in 0..5 {
            lo.record(0.0);
            hi.record(DRIFT_MAX_DIST);
        }
        assert!((lo.tv_distance(&hi) - 1.0).abs() < 1e-7);
        // symmetric
        assert_eq!(lo.tv_distance(&hi), hi.tv_distance(&lo));
    }

    #[test]
    fn from_bins_roundtrip() {
        let mut h = DriftHistogram::new();
        for d in [0.0, 0.5, 1.5, 3.9, 9.0] {
            h.record(d);
        }
        let back = DriftHistogram::from_bins(h.bins().to_vec());
        assert_eq!(h, back);
        assert!(DriftHistogram::from_bins(Vec::new()).is_empty());
    }
}
