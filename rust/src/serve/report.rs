//! Latency/throughput accounting for a serving run: per-request latency
//! percentiles + queries-per-second (plus the per-worker breakdown of a
//! pooled run), rendered for the CLI and emitted by the bench harness
//! into `BENCH_hot_paths.json`.
//!
//! Percentiles come from an [`obs::Histogram`] instead of sorting the
//! full per-request latency vector: O(64) per quantile, mergeable across
//! workers, and bounded to 25% relative error (the histogram's
//! property-tested bucket bound) while `count`/`qps`/`mean`/`max` stay
//! exact (the histogram tracks those fields exactly alongside).

use crate::obs::{HistSnapshot, Histogram};
use crate::serve::model::WorkerStats;

/// One line per pool worker — batches, rows, busy time, and that worker's
/// per-batch p50 from its own histogram — then one pooled line from the
/// bucket-wise MERGE of every worker's histogram.  The merge is the
/// pooled tally (no per-worker qps re-derivation): merged count/sum are
/// exactly what one shared histogram would have recorded.
pub fn format_workers(stats: &[WorkerStats]) -> String {
    let mut out = String::new();
    let mut pooled = HistSnapshot::default();
    for (w, s) in stats.iter().enumerate() {
        pooled.merge(&s.batch);
        out.push_str(&format!(
            "  worker {w}: {} batches, {} rows, batch p50 {:.3} ms (busy {:.3}s)\n",
            s.batches,
            s.rows,
            s.batch.quantile_ns(0.5) as f64 / 1e6,
            s.busy_s
        ));
    }
    if stats.len() > 1 {
        out.push_str(&format!(
            "  pool: {} workers, {} batches merged, batch p50 {:.3} ms / p99 {:.3} ms\n",
            stats.len(),
            pooled.count,
            pooled.quantile_ns(0.5) as f64 / 1e6,
            pooled.quantile_ns(0.99) as f64 / 1e6,
        ));
    }
    out
}

/// Summary of one serving run.
#[derive(Debug, Clone)]
pub struct LatencyReport {
    pub count: usize,
    pub wall_s: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl LatencyReport {
    /// Build from a histogram snapshot + run wall time.  `count`, `qps`,
    /// `mean` and `max` are exact; the percentiles carry the histogram's
    /// 25% bucket bound.
    pub fn from_snapshot(s: &HistSnapshot, wall_s: f64) -> LatencyReport {
        LatencyReport {
            count: s.count as usize,
            wall_s,
            qps: s.count as f64 / wall_s.max(1e-12),
            p50_ms: s.quantile_ns(0.50) as f64 / 1e6,
            p90_ms: s.quantile_ns(0.90) as f64 / 1e6,
            p99_ms: s.quantile_ns(0.99) as f64 / 1e6,
            mean_ms: s.mean_ns() / 1e6,
            max_ms: s.max_ns as f64 / 1e6,
        }
    }

    /// Build from raw per-request latencies (seconds) + run wall time —
    /// records into a histogram and summarizes that, instead of sorting
    /// the full vector.
    pub fn from_latencies(latencies_s: &[f64], wall_s: f64) -> LatencyReport {
        let h = Histogram::new();
        for &l in latencies_s {
            h.record((l.max(0.0) * 1e9) as u64);
        }
        LatencyReport::from_snapshot(&h.snapshot(), wall_s)
    }
}

impl std::fmt::Display for LatencyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {:.3}s — {:.0} qps; latency p50 {:.3} ms, p90 {:.3} ms, \
             p99 {:.3} ms, mean {:.3} ms, max {:.3} ms",
            self.count, self.wall_s, self.qps, self.p50_ms, self.p90_ms, self.p99_ms,
            self.mean_ms, self.max_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_within_the_histogram_bound() {
        // 1..=100 ms: nearest-rank p50 = 50 ms, p90 = 90 ms, p99 = 99 ms;
        // the histogram estimate must land within its 25% bucket bound
        // while count/qps/mean/max stay exact
        let lat: Vec<f64> = (1..=100).map(|x| x as f64 / 1000.0).collect();
        let r = LatencyReport::from_latencies(&lat, 1.0);
        assert_eq!(r.count, 100);
        assert!((r.qps - 100.0).abs() < 1e-9);
        assert!((r.p50_ms - 50.0).abs() <= 0.25 * 50.0, "{}", r.p50_ms);
        assert!((r.p90_ms - 90.0).abs() <= 0.25 * 90.0, "{}", r.p90_ms);
        assert!((r.p99_ms - 99.0).abs() <= 0.25 * 99.0, "{}", r.p99_ms);
        assert!((r.mean_ms - 50.5).abs() < 1e-6, "{}", r.mean_ms);
        assert!((r.max_ms - 100.0).abs() < 1e-6);
        assert!(r.p50_ms <= r.p90_ms && r.p90_ms <= r.p99_ms, "quantiles are monotone");
        // singleton and empty inputs stay finite
        let one = LatencyReport::from_latencies(&[0.002], 0.004);
        assert!((one.p50_ms - 2.0).abs() <= 0.25 * 2.0);
        assert!((one.p99_ms - 2.0).abs() <= 0.25 * 2.0);
        let zero = LatencyReport::from_latencies(&[], 1.0);
        assert_eq!(zero.count, 0);
        assert_eq!(zero.p50_ms, 0.0);
    }
}
