//! Latency/throughput accounting for a serving run: per-request latency
//! percentiles + queries-per-second (plus the per-worker breakdown of a
//! pooled run), rendered for the CLI and emitted by the bench harness
//! into `BENCH_hot_paths.json`.

use crate::serve::model::WorkerStats;

/// One line per pool worker: batches, rows, and that worker's effective
/// qps over the run's wall time (rows it produced / total wall — the
/// capacity split, not the busy-time rate, so the lines sum to ~the run
/// qps in rows).
pub fn format_workers(stats: &[WorkerStats], wall_s: f64) -> String {
    let mut out = String::new();
    for (w, s) in stats.iter().enumerate() {
        out.push_str(&format!(
            "  worker {w}: {} batches, {} rows, {:.0} rows/s (busy {:.3}s)\n",
            s.batches,
            s.rows,
            s.rows as f64 / wall_s.max(1e-12),
            s.busy_s
        ));
    }
    out
}

/// Summary of one serving run.
#[derive(Debug, Clone)]
pub struct LatencyReport {
    pub count: usize,
    pub wall_s: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

/// Nearest-rank percentile over a sorted slice (q in [0, 1]).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

impl LatencyReport {
    /// Build from raw per-request latencies (seconds) + run wall time.
    pub fn from_latencies(latencies_s: &[f64], wall_s: f64) -> LatencyReport {
        let mut sorted: Vec<f64> = latencies_s.to_vec();
        sorted.sort_by(f64::total_cmp);
        let count = sorted.len();
        let mean = if count == 0 { 0.0 } else { sorted.iter().sum::<f64>() / count as f64 };
        LatencyReport {
            count,
            wall_s,
            qps: count as f64 / wall_s.max(1e-12),
            p50_ms: 1e3 * percentile(&sorted, 0.50),
            p99_ms: 1e3 * percentile(&sorted, 0.99),
            mean_ms: 1e3 * mean,
            max_ms: 1e3 * sorted.last().copied().unwrap_or(0.0),
        }
    }
}

impl std::fmt::Display for LatencyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {:.3}s — {:.0} qps; latency p50 {:.3} ms, p99 {:.3} ms, \
             mean {:.3} ms, max {:.3} ms",
            self.count, self.wall_s, self.qps, self.p50_ms, self.p99_ms, self.mean_ms,
            self.max_ms
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_nearest_rank() {
        let lat: Vec<f64> = (1..=100).map(|x| x as f64 / 1000.0).collect();
        let r = LatencyReport::from_latencies(&lat, 1.0);
        assert_eq!(r.count, 100);
        assert!((r.qps - 100.0).abs() < 1e-9);
        assert!((r.p50_ms - 50.0).abs() < 1e-9, "{}", r.p50_ms);
        assert!((r.p99_ms - 99.0).abs() < 1e-9, "{}", r.p99_ms);
        assert!((r.max_ms - 100.0).abs() < 1e-9);
        // singleton and empty inputs stay finite
        let one = LatencyReport::from_latencies(&[0.002], 0.004);
        assert!((one.p50_ms - 2.0).abs() < 1e-9);
        assert!((one.p99_ms - 2.0).abs() < 1e-9);
        let zero = LatencyReport::from_latencies(&[], 1.0);
        assert_eq!(zero.count, 0);
        assert_eq!(zero.p50_ms, 0.0);
    }
}
