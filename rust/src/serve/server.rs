//! Socket front-end: a `std::net` TCP listener feeding the
//! [`ServeEngine`]'s micro-batcher.
//!
//! Thread layout (all scoped — [`run`] returns only after every thread
//! has exited):
//!
//! ```text
//!   caller thread          accept thread        per connection
//!   ─────────────          ─────────────        ──────────────
//!   batcher loop  ◀─mpsc── accept() ──spawns──▶ reader (socket → events)
//!   (owns engine)                               writer (frames → socket)
//! ```
//!
//! The engine stays on the caller's thread — serving cores hold `Rc`s, so
//! the facade is deliberately `!Send` — and every socket thread talks to
//! it through one event channel.  The batcher loop wakes on events or on
//! a tick derived from the engine deadline, calls [`ServeEngine::poll`]
//! (deadline flush) or [`ServeEngine::drain`] (DRAIN/SHUTDOWN frames),
//! and routes each [`Served`](crate::serve::Served) answer back to the
//! connection that submitted it.
//!
//! Every blocking point has an explicit wake instead of a poll interval:
//! the acceptor blocks in `accept()` and is woken at shutdown by a
//! loop-back connect to its own listen address; readers block in `read()`
//! and are woken by `shutdown(Read)` on a registered duplicate of their
//! socket; the batcher blocks in `recv()` whenever the engine is idle
//! (nothing queued, nothing in flight) and falls back to a deadline tick
//! only while work is pending.  An idle server burns no CPU.
//!
//! Failure containment: a malformed frame earns a typed ERROR frame and
//! the connection keeps going; an unusable length prefix earns the ERROR
//! and a hang-up; a mid-stream disconnect just drops that connection's
//! reply route — queued work still executes and the pool is never
//! poisoned.  Load-shedding ([`ServeError::Shed`]) is a SHED error frame,
//! not a dropped connection.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::serve::engine::{ServeEngine, ServeError};
use crate::serve::proto::{
    self, ErrCode, Framer, ProtoError, WireRequest, WireResponse, NO_REQ_ID,
};
use crate::serve::{Answer, Request};

/// What one [`run`] lifetime did (the CLI prints it; tests assert on it).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ServerReport {
    /// Connections accepted.
    pub connections: u64,
    /// Node/link query frames received (control frames excluded).
    pub requests: u64,
    /// Queries answered with scores.
    pub served: u64,
    /// Queries refused by the load-shedding policy.
    pub shed: u64,
    /// Error frames other than SHED (malformed, unknown model, bad node).
    pub errors: u64,
}

/// Live, thread-safe observation window into a running server — the
/// counters a test (or monitor) can watch *while* [`run_probed`] is still
/// blocked in its serve loop.  `ServerReport` is only available after the
/// server exits; the probe is how callers synchronize on mid-lifetime
/// events ("the truncation error has been counted") without sleeping.
#[derive(Debug, Default)]
pub struct ServerProbe {
    errors: AtomicU64,
    disconnects: AtomicU64,
}

impl ServerProbe {
    pub fn new() -> ServerProbe {
        ServerProbe::default()
    }

    /// Error frames issued so far (same counting rule as
    /// `ServerReport::errors`: malformed + unknown-model + bad-node, SHED
    /// excluded).
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Acquire)
    }

    /// Reader hang-ups observed so far (clean EOF, truncation hang-up,
    /// or socket error).  Counts reply-route teardowns, so a value of
    /// `k` means `k` connections can no longer receive frames.
    pub fn disconnects(&self) -> u64 {
        self.disconnects.load(Ordering::Acquire)
    }
}

enum Event {
    Connect { conn: u64, tx: mpsc::Sender<Vec<u8>> },
    Request { conn: u64, req: WireRequest },
    Malformed { conn: u64, err: ProtoError },
    Disconnect { conn: u64 },
}

/// A submitted query awaiting its flush: where the answer goes.
struct Pending {
    conn: u64,
    req_id: u64,
    embedding: bool,
}

fn send_to(conns: &HashMap<u64, mpsc::Sender<Vec<u8>>>, conn: u64, resp: &WireResponse) {
    if let Some(tx) = conns.get(&conn) {
        // a send to a closing connection just drops the frame — the
        // writer thread is already unwinding
        let _ = tx.send(proto::encode_response(resp));
    }
}

/// Socket → events.  Fully blocking: the thread parks in `read()` until
/// bytes arrive, the peer hangs up, or shutdown calls `shutdown(Read)`
/// on the registered duplicate of this socket (which surfaces here as
/// EOF).  No timeout, no stop-flag poll.
fn reader_loop(mut stream: TcpStream, conn: u64, etx: mpsc::Sender<Event>) {
    let mut framer = Framer::new();
    let mut buf = [0u8; 4096];
    'read: loop {
        match stream.read(&mut buf) {
            Ok(0) => {
                // EOF mid-frame is a typed truncation, not silence
                if let Some(err) = framer.eof_error() {
                    let _ = etx.send(Event::Malformed { conn, err });
                }
                break;
            }
            Ok(n) => {
                framer.extend(&buf[..n]);
                loop {
                    match framer.next_frame() {
                        Ok(Some(payload)) => {
                            let ev = match proto::decode_request(&payload) {
                                Ok(req) => Event::Request { conn, req },
                                // bad payload: report it, keep the
                                // connection — framing is still aligned
                                Err(err) => Event::Malformed { conn, err },
                            };
                            let _ = etx.send(ev);
                        }
                        Ok(None) => break,
                        Err(err) => {
                            // unusable length prefix — the stream can't
                            // be re-synchronized, hang up
                            let _ = etx.send(Event::Malformed { conn, err });
                            break 'read;
                        }
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    let _ = etx.send(Event::Disconnect { conn });
}

/// Frames → socket.  Exits once every sender is gone AND the queue is
/// drained, so replies issued just before a disconnect still go out.
fn writer_loop(mut stream: TcpStream, wrx: mpsc::Receiver<Vec<u8>>) {
    for frame in wrx.iter() {
        if stream.write_all(&frame).is_err() {
            break;
        }
    }
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Write);
}

#[allow(clippy::too_many_arguments)]
fn submit_query(
    engine: &mut ServeEngine,
    embed: &[(String, bool)],
    conns: &HashMap<u64, mpsc::Sender<Vec<u8>>>,
    inflight: &mut HashMap<usize, Pending>,
    report: &mut ServerReport,
    probe: &ServerProbe,
    conn: u64,
    req_id: u64,
    model: &str,
    req: Request,
) {
    report.requests += 1;
    match engine.submit(model, req) {
        Ok(ticket) => {
            let embedding = embed
                .iter()
                .find(|(m, _)| m.as_str() == model)
                .map(|&(_, e)| e)
                .unwrap_or(false);
            inflight.insert(ticket, Pending { conn, req_id, embedding });
        }
        Err(e) => {
            let code = match &e {
                ServeError::Shed { .. } => {
                    report.shed += 1;
                    ErrCode::Shed
                }
                ServeError::UnknownModel(_) => {
                    report.errors += 1;
                    probe.errors.fetch_add(1, Ordering::Release);
                    ErrCode::UnknownModel
                }
                ServeError::InvalidNode { .. } => {
                    report.errors += 1;
                    probe.errors.fetch_add(1, Ordering::Release);
                    ErrCode::BadRequest
                }
                _ => {
                    report.errors += 1;
                    probe.errors.fetch_add(1, Ordering::Release);
                    ErrCode::Internal
                }
            };
            send_to(conns, conn, &WireResponse::Error { req_id, code, msg: e.to_string() });
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_event(
    ev: Event,
    engine: &mut ServeEngine,
    embed: &[(String, bool)],
    conns: &mut HashMap<u64, mpsc::Sender<Vec<u8>>>,
    inflight: &mut HashMap<usize, Pending>,
    report: &mut ServerReport,
    probe: &ServerProbe,
    stopping: &mut bool,
    drain_now: &mut bool,
) {
    match ev {
        Event::Connect { conn, tx } => {
            conns.insert(conn, tx);
            report.connections += 1;
        }
        Event::Disconnect { conn } => {
            // answers already queued for this conn execute normally and
            // are dropped at send_to — nothing to unwind
            conns.remove(&conn);
            probe.disconnects.fetch_add(1, Ordering::Release);
        }
        Event::Malformed { conn, err } => {
            report.errors += 1;
            send_to(
                conns,
                conn,
                &WireResponse::Error {
                    req_id: NO_REQ_ID,
                    code: ErrCode::Malformed,
                    msg: err.to_string(),
                },
            );
            // counted after the ERROR frame is routed: once a watcher
            // sees the probe tick, the reply (if any route remains) is
            // already in the writer queue
            probe.errors.fetch_add(1, Ordering::Release);
        }
        Event::Request { conn, req } => match req {
            WireRequest::Ping { req_id } => {
                send_to(conns, conn, &WireResponse::Pong { req_id });
            }
            // answered inline like PING (control frame: not counted in
            // `ServerReport::requests`): the scrape text comes from the
            // engine's registry — deterministic key order, empty when the
            // engine was built without `.metrics(...)`
            WireRequest::Stats { req_id } => {
                let text =
                    engine.registry().map(|r| r.render_prometheus()).unwrap_or_default();
                send_to(conns, conn, &WireResponse::Stats { req_id, text });
            }
            WireRequest::Drain => *drain_now = true,
            WireRequest::Shutdown => *stopping = true,
            WireRequest::Node { req_id, model, node } => submit_query(
                engine,
                embed,
                conns,
                inflight,
                report,
                probe,
                conn,
                req_id,
                &model,
                Request::Node(node),
            ),
            WireRequest::Link { req_id, model, u, v } => submit_query(
                engine,
                embed,
                conns,
                inflight,
                report,
                probe,
                conn,
                req_id,
                &model,
                Request::Link(u, v),
            ),
        },
    }
}

/// Serve `engine` on `listener` until a SHUTDOWN frame arrives (then
/// drain everything, reply, and return).  Equivalent to [`run_probed`]
/// with a probe nobody watches.
pub fn run(engine: &mut ServeEngine, listener: TcpListener) -> Result<ServerReport> {
    run_probed(engine, listener, &ServerProbe::new())
}

/// [`run`] with a live [`ServerProbe`] the caller can watch from another
/// thread while the server loop is still running.  The flush cadence is
/// half the engine deadline (clamped to [1 ms, 50 ms]; 5 ms when no
/// deadline is set, where `poll` only ever cuts full batches anyway) —
/// and applies only while work is pending; an idle batcher blocks on the
/// event channel.
pub fn run_probed(
    engine: &mut ServeEngine,
    listener: TcpListener,
    probe: &ServerProbe,
) -> Result<ServerReport> {
    // kept blocking: accept() parks until a connection arrives, and the
    // shutdown path wakes it by connecting to this address
    let wake_addr = listener.local_addr().context("serve: local_addr of listener")?;
    let tick = engine
        .deadline()
        .map(|d| (d / 2).max(Duration::from_millis(1)))
        .unwrap_or(Duration::from_millis(5))
        .min(Duration::from_millis(50));
    // per-model embedding flag, resolved once: link-task rows are
    // embeddings and the SCORES frame says so
    let embed: Vec<(String, bool)> = engine
        .models()
        .iter()
        .map(|m| (m.to_string(), engine.model(m).map(|sm| sm.link_task()).unwrap_or(false)))
        .collect();
    // reply-write stage histogram (encode + route to the writer queue),
    // resolved once; disabled (no clock reads) without a registry
    let reply_write = engine
        .registry()
        .map(|r| r.hist("serve_reply_write"))
        .unwrap_or_default();
    let stop = AtomicBool::new(false);
    // one duplicate handle per accepted socket; shutdown(Read) on these
    // is what unparks the blocking readers (entries for already-closed
    // connections are inert — shutdown on them fails and is ignored)
    let wake_sockets: Mutex<Vec<TcpStream>> = Mutex::new(Vec::new());
    let (etx, erx) = mpsc::channel::<Event>();
    let mut report = ServerReport::default();
    let mut fatal: Option<anyhow::Error> = None;

    thread::scope(|s| {
        let stop = &stop;
        let wake_sockets = &wake_sockets;
        // ---- acceptor: owns the listener, spawns a reader + writer per
        // connection into the same scope ------------------------------
        let acceptor = s.spawn(move || {
            let mut next_conn = 0u64;
            loop {
                let stream = match listener.accept() {
                    Ok((stream, _addr)) => stream,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        // transient resource errors (e.g. fd exhaustion):
                        // back off instead of hot-looping on accept()
                        thread::sleep(Duration::from_millis(2));
                        continue;
                    }
                };
                if stop.load(Ordering::Relaxed) {
                    // the shutdown wake-up connect lands here — it is a
                    // courier, not a client: never counted, never served
                    break;
                }
                let _ = stream.set_nodelay(true);
                // reader handle + wake handle; a connection we can't
                // duplicate can't be woken at shutdown, so refuse it
                let (rstream, wake) = match (stream.try_clone(), stream.try_clone()) {
                    (Ok(r), Ok(w)) => (r, w),
                    _ => continue,
                };
                let conn = next_conn;
                next_conn += 1;
                let (wtx, wrx) = mpsc::channel::<Vec<u8>>();
                if etx.send(Event::Connect { conn, tx: wtx }).is_err() {
                    break; // batcher is gone
                }
                wake_sockets.lock().unwrap().push(wake);
                let retx = etx.clone();
                s.spawn(move || reader_loop(rstream, conn, retx));
                s.spawn(move || writer_loop(stream, wrx));
            }
        });

        // ---- batcher loop: the engine never leaves this thread -------
        let mut conns: HashMap<u64, mpsc::Sender<Vec<u8>>> = HashMap::new();
        let mut inflight: HashMap<usize, Pending> = HashMap::new();
        let mut stopping = false;
        loop {
            let mut drain_now = false;
            // idle (nothing queued, nothing awaiting an answer): block
            // until an event arrives — no deadline can be pending, so no
            // tick is owed.  Busy: bound the wait by the flush cadence.
            let idle = !stopping && engine.pending() == 0 && inflight.is_empty();
            let first = if idle {
                erx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected)
            } else {
                erx.recv_timeout(tick)
            };
            match first {
                Ok(ev) => handle_event(
                    ev,
                    engine,
                    &embed,
                    &mut conns,
                    &mut inflight,
                    &mut report,
                    probe,
                    &mut stopping,
                    &mut drain_now,
                ),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            while let Ok(ev) = erx.try_recv() {
                handle_event(
                    ev,
                    engine,
                    &embed,
                    &mut conns,
                    &mut inflight,
                    &mut report,
                    probe,
                    &mut stopping,
                    &mut drain_now,
                );
            }
            // On DRAIN/SHUTDOWN, poll FIRST (cut every full batch at its
            // stream-aligned boundary), THEN force the tail: the padded
            // batch is then the withheld tail alone, padded with its own
            // first node — the exact partition the file-driven path's
            // poll + drain produces, so socket answers stay bit-identical
            // to file answers even when the final event burst queued
            // several uncut batches.
            let flushed = if stopping || drain_now {
                engine.poll().and_then(|mut f| {
                    engine.drain().map(|rest| {
                        f.extend(rest);
                        f
                    })
                })
            } else {
                engine.poll()
            };
            let flushed = match flushed {
                Ok(f) => f,
                Err(e) => {
                    fatal = Some(e);
                    break;
                }
            };
            for sv in flushed {
                if let Some(p) = inflight.remove(&sv.id) {
                    report.served += 1;
                    let stage = reply_write.stage();
                    let resp = match sv.answer {
                        Answer::Scores(row) => WireResponse::Scores {
                            req_id: p.req_id,
                            embedding: p.embedding,
                            row,
                        },
                        Answer::Link(score) => {
                            WireResponse::Link { req_id: p.req_id, score }
                        }
                    };
                    send_to(&conns, p.conn, &resp);
                    stage.stop();
                }
            }
            if stopping && engine.pending() == 0 && inflight.is_empty() {
                break;
            }
        }

        // unwind, one explicit wake per blocking point:
        //   1. drop the reply routes — writers for live connections
        //      drain their queues, flush, and exit;
        //   2. flag down, drop the event receiver (so any late send —
        //      including a racing Connect — errors instead of landing),
        //      then loop-back connect to unpark accept(); the acceptor
        //      exits on the flag or on the failed Connect send, either
        //      way without counting the courier connection;
        //   3. join the acceptor BEFORE draining the wake registry —
        //      after the join no new reader can be spawned nor wake
        //      handle registered, so the drain below is complete;
        //   4. shutdown(Read) every registered socket duplicate — each
        //      blocking read() returns EOF and its reader exits (the
        //      write half stays open so writers can still drain).
        drop(conns);
        stop.store(true, Ordering::Relaxed);
        drop(erx);
        let _ = TcpStream::connect(wake_addr);
        let _ = acceptor.join();
        for sock in wake_sockets.lock().unwrap().drain(..) {
            let _ = sock.shutdown(Shutdown::Read);
        }
    });

    match fatal {
        Some(e) => Err(e),
        None => Ok(report),
    }
}
