//! Socket front-end: a `std::net` TCP listener feeding the
//! [`ServeEngine`]'s micro-batcher.
//!
//! Thread layout (all scoped — [`run`] returns only after every thread
//! has exited):
//!
//! ```text
//!   caller thread          accept thread        per connection
//!   ─────────────          ─────────────        ──────────────
//!   batcher loop  ◀─mpsc── accept() ──spawns──▶ reader (socket → events)
//!   (owns engine)                               writer (frames → socket)
//! ```
//!
//! The engine stays on the caller's thread — serving cores hold `Rc`s, so
//! the facade is deliberately `!Send` — and every socket thread talks to
//! it through one event channel.  The batcher loop wakes on events or on
//! a tick derived from the engine deadline, calls [`ServeEngine::poll`]
//! (deadline flush) or [`ServeEngine::drain`] (DRAIN/SHUTDOWN frames),
//! and routes each [`Served`](crate::serve::Served) answer back to the
//! connection that submitted it.
//!
//! Failure containment: a malformed frame earns a typed ERROR frame and
//! the connection keeps going; an unusable length prefix earns the ERROR
//! and a hang-up; a mid-stream disconnect just drops that connection's
//! reply route — queued work still executes and the pool is never
//! poisoned.  Load-shedding ([`ServeError::Shed`]) is a SHED error frame,
//! not a dropped connection.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::serve::engine::{ServeEngine, ServeError};
use crate::serve::proto::{
    self, ErrCode, Framer, ProtoError, WireRequest, WireResponse, NO_REQ_ID,
};
use crate::serve::{Answer, Request};

/// What one [`run`] lifetime did (the CLI prints it; tests assert on it).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ServerReport {
    /// Connections accepted.
    pub connections: u64,
    /// Node/link query frames received (control frames excluded).
    pub requests: u64,
    /// Queries answered with scores.
    pub served: u64,
    /// Queries refused by the load-shedding policy.
    pub shed: u64,
    /// Error frames other than SHED (malformed, unknown model, bad node).
    pub errors: u64,
}

enum Event {
    Connect { conn: u64, tx: mpsc::Sender<Vec<u8>> },
    Request { conn: u64, req: WireRequest },
    Malformed { conn: u64, err: ProtoError },
    Disconnect { conn: u64 },
}

/// A submitted query awaiting its flush: where the answer goes.
struct Pending {
    conn: u64,
    req_id: u64,
    embedding: bool,
}

fn send_to(conns: &HashMap<u64, mpsc::Sender<Vec<u8>>>, conn: u64, resp: &WireResponse) {
    if let Some(tx) = conns.get(&conn) {
        // a send to a closing connection just drops the frame — the
        // writer thread is already unwinding
        let _ = tx.send(proto::encode_response(resp));
    }
}

/// Socket → events.  Read timeout (25 ms) doubles as the stop-flag poll
/// interval, so shutdown never waits on a silent peer.
fn reader_loop(mut stream: TcpStream, conn: u64, etx: mpsc::Sender<Event>, stop: &AtomicBool) {
    let mut framer = Framer::new();
    let mut buf = [0u8; 4096];
    'read: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match stream.read(&mut buf) {
            Ok(0) => {
                // EOF mid-frame is a typed truncation, not silence
                if let Some(err) = framer.eof_error() {
                    let _ = etx.send(Event::Malformed { conn, err });
                }
                break;
            }
            Ok(n) => {
                framer.extend(&buf[..n]);
                loop {
                    match framer.next_frame() {
                        Ok(Some(payload)) => {
                            let ev = match proto::decode_request(&payload) {
                                Ok(req) => Event::Request { conn, req },
                                // bad payload: report it, keep the
                                // connection — framing is still aligned
                                Err(err) => Event::Malformed { conn, err },
                            };
                            let _ = etx.send(ev);
                        }
                        Ok(None) => break,
                        Err(err) => {
                            // unusable length prefix — the stream can't
                            // be re-synchronized, hang up
                            let _ = etx.send(Event::Malformed { conn, err });
                            break 'read;
                        }
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
    let _ = etx.send(Event::Disconnect { conn });
}

/// Frames → socket.  Exits once every sender is gone AND the queue is
/// drained, so replies issued just before a disconnect still go out.
fn writer_loop(mut stream: TcpStream, wrx: mpsc::Receiver<Vec<u8>>) {
    for frame in wrx.iter() {
        if stream.write_all(&frame).is_err() {
            break;
        }
    }
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Write);
}

#[allow(clippy::too_many_arguments)]
fn submit_query(
    engine: &mut ServeEngine,
    embed: &[(String, bool)],
    conns: &HashMap<u64, mpsc::Sender<Vec<u8>>>,
    inflight: &mut HashMap<usize, Pending>,
    report: &mut ServerReport,
    conn: u64,
    req_id: u64,
    model: &str,
    req: Request,
) {
    report.requests += 1;
    match engine.submit(model, req) {
        Ok(ticket) => {
            let embedding = embed
                .iter()
                .find(|(m, _)| m.as_str() == model)
                .map(|&(_, e)| e)
                .unwrap_or(false);
            inflight.insert(ticket, Pending { conn, req_id, embedding });
        }
        Err(e) => {
            let code = match &e {
                ServeError::Shed { .. } => {
                    report.shed += 1;
                    ErrCode::Shed
                }
                ServeError::UnknownModel(_) => {
                    report.errors += 1;
                    ErrCode::UnknownModel
                }
                ServeError::InvalidNode { .. } => {
                    report.errors += 1;
                    ErrCode::BadRequest
                }
                _ => {
                    report.errors += 1;
                    ErrCode::Internal
                }
            };
            send_to(conns, conn, &WireResponse::Error { req_id, code, msg: e.to_string() });
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_event(
    ev: Event,
    engine: &mut ServeEngine,
    embed: &[(String, bool)],
    conns: &mut HashMap<u64, mpsc::Sender<Vec<u8>>>,
    inflight: &mut HashMap<usize, Pending>,
    report: &mut ServerReport,
    stopping: &mut bool,
    drain_now: &mut bool,
) {
    match ev {
        Event::Connect { conn, tx } => {
            conns.insert(conn, tx);
            report.connections += 1;
        }
        Event::Disconnect { conn } => {
            // answers already queued for this conn execute normally and
            // are dropped at send_to — nothing to unwind
            conns.remove(&conn);
        }
        Event::Malformed { conn, err } => {
            report.errors += 1;
            send_to(
                conns,
                conn,
                &WireResponse::Error {
                    req_id: NO_REQ_ID,
                    code: ErrCode::Malformed,
                    msg: err.to_string(),
                },
            );
        }
        Event::Request { conn, req } => match req {
            WireRequest::Ping { req_id } => {
                send_to(conns, conn, &WireResponse::Pong { req_id });
            }
            WireRequest::Drain => *drain_now = true,
            WireRequest::Shutdown => *stopping = true,
            WireRequest::Node { req_id, model, node } => submit_query(
                engine,
                embed,
                conns,
                inflight,
                report,
                conn,
                req_id,
                &model,
                Request::Node(node),
            ),
            WireRequest::Link { req_id, model, u, v } => submit_query(
                engine,
                embed,
                conns,
                inflight,
                report,
                conn,
                req_id,
                &model,
                Request::Link(u, v),
            ),
        },
    }
}

/// Serve `engine` on `listener` until a SHUTDOWN frame arrives (then
/// drain everything, reply, and return).  The flush cadence is half the
/// engine deadline (clamped to [1 ms, 50 ms]; 5 ms when no deadline is
/// set, where `poll` only ever cuts full batches anyway).
pub fn run(engine: &mut ServeEngine, listener: TcpListener) -> Result<ServerReport> {
    listener.set_nonblocking(true).context("serve: set_nonblocking on listener")?;
    let tick = engine
        .deadline()
        .map(|d| (d / 2).max(Duration::from_millis(1)))
        .unwrap_or(Duration::from_millis(5))
        .min(Duration::from_millis(50));
    // per-model embedding flag, resolved once: link-task rows are
    // embeddings and the SCORES frame says so
    let embed: Vec<(String, bool)> = engine
        .models()
        .iter()
        .map(|m| (m.to_string(), engine.model(m).map(|sm| sm.link_task()).unwrap_or(false)))
        .collect();
    let stop = AtomicBool::new(false);
    let (etx, erx) = mpsc::channel::<Event>();
    let mut report = ServerReport::default();
    let mut fatal: Option<anyhow::Error> = None;

    thread::scope(|s| {
        let stop = &stop;
        // ---- acceptor: owns the listener, spawns a reader + writer per
        // connection into the same scope ------------------------------
        s.spawn(move || {
            let mut next_conn = 0u64;
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _addr)) => {
                        let conn = next_conn;
                        next_conn += 1;
                        let _ = stream.set_nodelay(true);
                        let _ = stream.set_read_timeout(Some(Duration::from_millis(25)));
                        let (wtx, wrx) = mpsc::channel::<Vec<u8>>();
                        if etx.send(Event::Connect { conn, tx: wtx }).is_err() {
                            break; // batcher is gone
                        }
                        let rstream = match stream.try_clone() {
                            Ok(st) => st,
                            Err(_) => continue,
                        };
                        let retx = etx.clone();
                        s.spawn(move || reader_loop(rstream, conn, retx, stop));
                        s.spawn(move || writer_loop(stream, wrx));
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(2)),
                }
            }
        });

        // ---- batcher loop: the engine never leaves this thread -------
        let mut conns: HashMap<u64, mpsc::Sender<Vec<u8>>> = HashMap::new();
        let mut inflight: HashMap<usize, Pending> = HashMap::new();
        let mut stopping = false;
        loop {
            let mut drain_now = false;
            match erx.recv_timeout(tick) {
                Ok(ev) => handle_event(
                    ev,
                    engine,
                    &embed,
                    &mut conns,
                    &mut inflight,
                    &mut report,
                    &mut stopping,
                    &mut drain_now,
                ),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            while let Ok(ev) = erx.try_recv() {
                handle_event(
                    ev,
                    engine,
                    &embed,
                    &mut conns,
                    &mut inflight,
                    &mut report,
                    &mut stopping,
                    &mut drain_now,
                );
            }
            // On DRAIN/SHUTDOWN, poll FIRST (cut every full batch at its
            // stream-aligned boundary), THEN force the tail: the padded
            // batch is then the withheld tail alone, padded with its own
            // first node — the exact partition the file-driven path's
            // poll + drain produces, so socket answers stay bit-identical
            // to file answers even when the final event burst queued
            // several uncut batches.
            let flushed = if stopping || drain_now {
                engine.poll().and_then(|mut f| {
                    engine.drain().map(|rest| {
                        f.extend(rest);
                        f
                    })
                })
            } else {
                engine.poll()
            };
            let flushed = match flushed {
                Ok(f) => f,
                Err(e) => {
                    fatal = Some(e);
                    break;
                }
            };
            for sv in flushed {
                if let Some(p) = inflight.remove(&sv.id) {
                    report.served += 1;
                    let resp = match sv.answer {
                        Answer::Scores(row) => WireResponse::Scores {
                            req_id: p.req_id,
                            embedding: p.embedding,
                            row,
                        },
                        Answer::Link(score) => {
                            WireResponse::Link { req_id: p.req_id, score }
                        }
                    };
                    send_to(&conns, p.conn, &resp);
                }
            }
            if stopping && engine.pending() == 0 && inflight.is_empty() {
                break;
            }
        }

        // unwind: flag the threads down, close every reply route (writer
        // loops drain their queues then shut the sockets), and release
        // any Connect events still buffered in the channel
        stop.store(true, Ordering::Relaxed);
        drop(conns);
        drop(erx);
    });

    match fatal {
        Some(e) => Err(e),
        None => Ok(report),
    }
}
