//! The serving model, split along the read/write axis the concurrent
//! runtime needs:
//!
//! - [`ServeCore`] — the **shared, immutable** half: frozen parameters,
//!   the codebook-backed [`EmbeddingCache`], the compiled serve artifact,
//!   and the input template with every constant slot (weights, codebooks)
//!   filled exactly once.  Everything here is read-only during a flush, so
//!   one core serves any number of workers.
//! - [`ServeSession`] — the **per-worker, mutable** half: a clone of the
//!   input template whose dynamic slots (xb + sketches) are rewritten in
//!   place per micro-batch, persistent output tensors, a sketch scratch,
//!   and a detached [`ExecSession`] owning the executor's step arena.
//! - The pool: `ServingModel` owns N sessions (`set_threads`); the
//!   engine's `flush` fans micro-batches across them via `util::par`, each
//!   worker driving `Artifact::run_session` against the shared core —
//!   bit-identical to the serial path for any worker count, because every
//!   batch's computation is a pure function of (core, batch).
//!
//! The single writer is the **admission path**: `admit` describes an
//! unseen node (features + arcs into known nodes), bootstraps its
//! per-layer input features with one forward through the serve artifact,
//! assigns it to the frozen codebooks' nearest codewords
//! (`LayerCache::assign_features` — the same whitened FINDNEAREST the
//! trainer's inductive bootstrap runs), and appends it to the per-layer
//! node→codeword tables.  Admissions never overlap a flush (`&mut self`),
//! which is exactly the single-writer discipline the shared cache needs.

use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::vq_trainer::VqTrainer;
use crate::datasets::Dataset;
use crate::graph::Conv;
use crate::obs;
use crate::runtime::manifest::Manifest;
use crate::runtime::{Artifact, ExecSession, InputSlots, Runtime};
use crate::serve::admit::AdmissionQueue;
use crate::serve::cache::EmbeddingCache;
use crate::shard::ShardPlan;
use crate::util::par;
use crate::util::tensor::{self, DType, Tensor};
use crate::vq::sketch::SketchScratch;

/// The shared immutable half of a serving model (see module docs).
pub struct ServeCore {
    pub art: Rc<Artifact>,
    pub ds: Rc<Dataset>,
    pub model_name: String,
    pub params: Vec<Tensor>,
    pub cache: EmbeddingCache,
    /// Prebuilt input list in spec order: constant slots (params,
    /// codebooks) filled ONCE, `Arc`-shared by every worker session; the
    /// tensors at dynamic positions are placeholders the executor never
    /// reads (an [`InputSlots::Overlay`] resolves those to the session).
    template: Arc<Vec<Tensor>>,
    /// Every batch-dependent slot, grouped per builder pass; indices are
    /// DENSE positions into a session's `dyn_inputs`.
    dynamic: Vec<DynSlot>,
    /// Ascending spec positions of the dynamic slots (`dyn_inputs[p]`
    /// stands in for spec input `dyn_spec_idx[p]`).
    dyn_spec_idx: Vec<usize>,
    conv: Option<Conv>,
}

/// One worker's mutable serving state: the DYNAMIC input slots only
/// (xb + sketches — the constant template is `Arc`-shared on the core),
/// persistent output tensors, a sketch scratch, and a detached executor
/// session.  Dynamic slots are rewritten IN PLACE per micro-batch — the
/// read path never re-copies frozen weights and never allocates for a
/// steady-state micro-batch (the `serve_alloc_bytes` bench key measures
/// this on the 1-session pool; `serve_session_alloc_bytes` measures the
/// per-worker spawn cost).
pub struct ServeSession {
    pub(crate) dyn_inputs: Vec<Tensor>,
    /// Second dynamic-slot set for the pipelined fan-out: batch i+1's
    /// slots are assembled here while batch i executes out of
    /// `dyn_inputs`, then the two are swapped.  Same shapes, same
    /// builders, so a pipelined fill is bit-identical to a serial one.
    pub(crate) spare_inputs: Vec<Tensor>,
    pub(crate) outputs: Vec<Tensor>,
    pub(crate) scratch: SketchScratch,
    pub(crate) exec: ExecSession,
    /// Micro-batches this session executed (per-worker qps reporting).
    pub batches: u64,
    /// Wall time this session spent filling + executing.
    pub busy_s: f64,
    /// Per-batch wall-time histogram, fed from the same stamps `busy_s`
    /// takes (no extra clock reads); merged across the pool by
    /// [`report::format_workers`](crate::serve::report::format_workers).
    pub(crate) batch_hist: obs::Histogram,
}

/// Per-worker throughput summary (`ServingModel::worker_stats`).
#[derive(Debug, Clone, Copy)]
pub struct WorkerStats {
    pub batches: u64,
    pub rows: u64,
    pub busy_s: f64,
    /// Snapshot of this worker's per-batch wall-time histogram.
    pub batch: obs::HistSnapshot,
}

/// A borrow-split view of the shared core — every field `Sync`, the whole
/// struct `Copy` — handed to pool workers alongside their `&mut` session.
/// (The core itself holds `Rc`s, which must not cross threads; this view
/// carries plain references instead.)
#[derive(Clone, Copy)]
pub(crate) struct CoreRef<'a> {
    pub art: &'a Artifact,
    pub ds: &'a Dataset,
    pub cache: &'a EmbeddingCache,
    template: &'a [Tensor],
    dynamic: &'a [DynSlot],
    dyn_spec_idx: &'a [usize],
    conv: Option<Conv>,
}

/// Batch-dependent input slots of the serve artifact, grouped so each
/// sketch-builder pass writes its slot pair in place (via disjoint `&mut`).
/// All indices are DENSE positions into a session's `dyn_inputs`.
#[derive(Debug, Clone, Copy)]
enum DynSlot {
    /// Gathered feature rows.
    Xb(usize),
    /// Fixed-conv sketch pair of layer `l` at positions `(c_in, c_out)`.
    Fixed { l: usize, c_in: usize, c_out: usize },
    /// Learnable count-sketch pair of layer `l` at `(mask_in, m_out)`.
    Learnable { l: usize, mask_in: usize, m_out: usize },
    /// txf global histogram of layer `l` at position `idx`.
    CntOut { l: usize, idx: usize },
}

fn serve_artifact_name(ds: &str, model: &str) -> String {
    format!("vq_serve_{ds}_{model}")
}

/// Fill the constant input slots (params + raw codebooks) and index the
/// dynamic ones.  Placeholder zeros keep every slot shape/dtype-correct;
/// each dynamic slot is rewritten in place on every micro-batch.  Returns
/// `(template, dynamic, dyn_spec_idx)` with the slot indices inside
/// `dynamic` already remapped to dense positions (see [`DynSlot`]).
fn build_input_template(
    spec: &crate::runtime::manifest::ArtifactSpec,
    params: &[Tensor],
    cache: &EmbeddingCache,
) -> Result<(Vec<Tensor>, Vec<DynSlot>, Vec<usize>)> {
    let nl = spec.plan.len();
    let mut inputs = Vec::with_capacity(spec.inputs.len());
    let mut dynamic = Vec::new();
    let mut dyn_spec_idx = Vec::new();
    // per-layer partner indices, paired up after the scan
    let mut c_in_idx = vec![None; nl];
    let mut c_out_idx = vec![None; nl];
    let mut mask_idx = vec![None; nl];
    let mut m_out_idx = vec![None; nl];
    let mut pi = 0usize;
    for (idx, ts) in spec.inputs.iter().enumerate() {
        let name = ts.name.as_str();
        if name == "xb" {
            dynamic.push(DynSlot::Xb(idx));
            dyn_spec_idx.push(idx);
            inputs.push(Tensor::zeros(&ts.shape));
        } else if name.starts_with("param.") {
            inputs.push(params[pi].clone());
            pi += 1;
        } else if let Some((lstr, field)) = name.split_once('.') {
            let l: usize = lstr[1..].parse().context("layer index")?;
            let known = match field {
                "c_in" => {
                    c_in_idx[l] = Some(idx);
                    true
                }
                "c_out" => {
                    c_out_idx[l] = Some(idx);
                    true
                }
                "mask_in" => {
                    mask_idx[l] = Some(idx);
                    true
                }
                "m_out" => {
                    m_out_idx[l] = Some(idx);
                    true
                }
                "cnt_out" => {
                    dynamic.push(DynSlot::CntOut { l, idx });
                    true
                }
                "cw" => {
                    inputs.push(cache.layers[l].cw.clone());
                    false
                }
                other => bail!("unknown serve ctx field {other}"),
            };
            if known && field != "cw" {
                dyn_spec_idx.push(idx);
                inputs.push(Tensor::zeros(&ts.shape));
            }
        } else {
            bail!("unknown serve input {name}");
        }
    }
    for l in 0..nl {
        match (c_in_idx[l], c_out_idx[l], mask_idx[l], m_out_idx[l]) {
            (Some(ci), Some(co), None, None) => {
                dynamic.push(DynSlot::Fixed { l, c_in: ci, c_out: co })
            }
            (None, None, Some(mi), Some(mo)) => {
                dynamic.push(DynSlot::Learnable { l, mask_in: mi, m_out: mo })
            }
            other => bail!("serve layer {l}: incomplete sketch slot pair {other:?}"),
        }
    }
    // Remap the slots' spec indices to dense positions into `dyn_inputs`
    // (dyn_spec_idx ascends by construction — the scan ran in spec order).
    let dense = |i: usize| dyn_spec_idx.binary_search(&i).expect("dynamic slot index");
    let dynamic = dynamic
        .into_iter()
        .map(|d| match d {
            DynSlot::Xb(i) => DynSlot::Xb(dense(i)),
            DynSlot::Fixed { l, c_in, c_out } => {
                DynSlot::Fixed { l, c_in: dense(c_in), c_out: dense(c_out) }
            }
            DynSlot::Learnable { l, mask_in, m_out } => {
                DynSlot::Learnable { l, mask_in: dense(mask_in), m_out: dense(m_out) }
            }
            DynSlot::CntOut { l, idx } => DynSlot::CntOut { l, idx: dense(idx) },
        })
        .collect();
    Ok((inputs, dynamic, dyn_spec_idx))
}

impl ServeCore {
    fn conv_of(model_name: &str) -> Option<Conv> {
        match model_name {
            "gcn" => Some(Conv::GcnSym),
            "sage" => Some(Conv::SageMean),
            _ => None, // learnable convolutions build count sketches instead
        }
    }

    /// Detach one fresh worker session from this core.  The session holds
    /// ONLY the dynamic input slots (xb + sketches) plus scratch and the
    /// executor's step arena — the constant slots (params + codebooks)
    /// stay on the core's `Arc`-shared template and are read through an
    /// [`InputSlots::Overlay`] view at execute time, so widening the pool
    /// never re-copies frozen weights.
    fn new_dyn_inputs(&self) -> Vec<Tensor> {
        let spec = &self.art.spec;
        self.dyn_spec_idx
            .iter()
            .map(|&i| {
                let ts = &spec.inputs[i];
                match ts.dtype {
                    DType::F32 => Tensor::zeros(&ts.shape),
                    DType::I32 => Tensor::from_i32(&ts.shape, vec![0; ts.numel()]),
                }
            })
            .collect()
    }

    fn new_session(&self) -> ServeSession {
        ServeSession {
            dyn_inputs: self.new_dyn_inputs(),
            spare_inputs: self.new_dyn_inputs(),
            outputs: Vec::new(),
            // sized by the id BOUND, not the resident count: admitted ids
            // are stable across eviction, so live ids can exceed the count
            scratch: SketchScratch::new(self.cache.admitted.id_bound() as usize),
            exec: self.art.new_session(),
            batches: 0,
            busy_s: 0.0,
            batch_hist: obs::Histogram::new(),
        }
    }

    /// Bytes of the constant input template — resident ONCE per model
    /// behind the `Arc`, not once per worker.
    pub fn template_bytes(&self) -> usize {
        self.template.iter().map(Tensor::bytes).sum()
    }

    pub(crate) fn view(&self) -> CoreRef<'_> {
        CoreRef {
            art: &self.art,
            ds: &self.ds,
            cache: &self.cache,
            template: self.template.as_slice(),
            dynamic: &self.dynamic,
            dyn_spec_idx: &self.dyn_spec_idx,
            conv: self.conv,
        }
    }
}

impl CoreRef<'_> {
    /// Validate a micro-batch against the compiled width and the servable
    /// id space (frozen + admitted).  Request-controlled ids must never
    /// panic the server.
    pub(crate) fn check_batch(&self, batch: &[u32]) -> Result<()> {
        let b = self.art.spec.b;
        if batch.len() != b {
            bail!("forward_batch wants exactly b={b} nodes, got {}", batch.len());
        }
        if let Some(&bad) = batch.iter().find(|&&v| !self.cache.admitted.is_servable(v)) {
            bail!(
                "node id {bad} is not servable (dataset '{}': {} nodes + {} resident \
                 admitted; evicted/unknown ids are refused)",
                self.ds.cfg.name,
                self.cache.admitted.base_n,
                self.cache.admitted.len()
            );
        }
        Ok(())
    }

    /// Rewrite a session's dynamic input slots in place for one batch.
    pub(crate) fn fill_inputs(&self, sess: &mut ServeSession, batch: &[u32]) {
        let ServeSession { dyn_inputs, scratch, .. } = sess;
        self.fill_slots(scratch, dyn_inputs, batch);
    }

    /// The slot-rewrite body of [`CoreRef::fill_inputs`], over an explicit
    /// (scratch, slots) pair so the pipelined fan-out can assemble batch
    /// i+1 into a session's spare buffers while batch i executes out of
    /// the live ones.  Every builder fully overwrites its slot
    /// (zero-then-accumulate), so which buffer set a batch lands in never
    /// changes the bytes.
    pub(crate) fn fill_slots(
        &self,
        scratch: &mut SketchScratch,
        dyn_inputs: &mut [Tensor],
        batch: &[u32],
    ) {
        let (ds, cache) = (self.ds, self.cache);
        scratch.ensure(cache.admitted.id_bound() as usize);
        for slot in self.dynamic {
            match *slot {
                DynSlot::Xb(idx) => cache.gather_features_into(
                    &ds.features,
                    ds.cfg.f_in_pad,
                    batch,
                    &mut dyn_inputs[idx].f,
                ),
                DynSlot::Fixed { l, c_in, c_out } => {
                    let (ti, to) = tensor::mut2(dyn_inputs, c_in, c_out);
                    cache.layers[l].build_fixed_fwd_into(
                        &ds.graph,
                        &cache.admitted,
                        self.conv.expect("fixed-conv serve artifact without a fixed conv"),
                        batch,
                        scratch,
                        &mut ti.f,
                        &mut to.f,
                    );
                }
                DynSlot::Learnable { l, mask_in, m_out } => {
                    let (tm, to) = tensor::mut2(dyn_inputs, mask_in, m_out);
                    cache.layers[l].build_learnable_fwd_into(
                        &ds.graph,
                        &cache.admitted,
                        batch,
                        scratch,
                        &mut tm.f,
                        &mut to.f,
                    );
                }
                DynSlot::CntOut { l, idx } => cache.layers[l].build_cnt_fwd_into(
                    &cache.admitted,
                    batch,
                    scratch,
                    &mut dyn_inputs[idx].f,
                ),
            }
        }
    }

    /// One forward-only micro-batch through a worker session, result left
    /// in `sess.outputs[0]` — THE per-batch sequence (validate → fill →
    /// execute → per-worker counters), shared by the fan-out workers and
    /// the single-session `forward_batch` so the two paths cannot drift.
    /// Takes `&self` on the shared core and touches only the worker's
    /// session, so N workers run this concurrently
    /// (`util::par::scope_map`).  Runtime accounting is the caller's job
    /// (`Runtime::record_external`).
    pub(crate) fn exec_batch(&self, sess: &mut ServeSession, batch: &[u32]) -> Result<()> {
        self.exec_batch_timed(sess, batch, &obs::ServeStages::default())
    }

    /// [`CoreRef::exec_batch`] with stage attribution: batch assembly
    /// (validation + dynamic-slot fills) and session execution (the
    /// compiled plan) recorded into the engine's histograms.  Disabled
    /// stage handles read no clock beyond the busy-time stamp the
    /// untimed path already took, and the computation is byte-for-byte
    /// the untimed sequence — timing never touches the data.
    pub(crate) fn exec_batch_timed(
        &self,
        sess: &mut ServeSession,
        batch: &[u32],
        stages: &obs::ServeStages,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let assembly = stages.assembly.stage();
        self.check_batch(batch)?;
        self.fill_inputs(sess, batch);
        assembly.stop();
        let execution = stages.exec.stage();
        let ServeSession { dyn_inputs, outputs, exec, .. } = sess;
        let view = InputSlots::Overlay {
            base: self.template,
            idx: self.dyn_spec_idx,
            dynamic: dyn_inputs.as_slice(),
        };
        self.art.run_slots(view, outputs, exec)?;
        execution.stop();
        let elapsed = t0.elapsed();
        sess.batches += 1;
        sess.busy_s += elapsed.as_secs_f64();
        sess.batch_hist.record_duration(elapsed);
        Ok(())
    }

    /// [`CoreRef::exec_batch_timed`] + copy the result rows into `out`
    /// (`b × out_dim`) — the engine's fan-out form.
    pub(crate) fn run_batch_timed(
        &self,
        sess: &mut ServeSession,
        batch: &[u32],
        out: &mut [f32],
        stages: &obs::ServeStages,
    ) -> Result<()> {
        self.exec_batch_timed(sess, batch, stages)?;
        out.copy_from_slice(&sess.outputs[0].f);
        Ok(())
    }

    /// Run one worker's micro-batches with prep/exec overlap: while batch
    /// i executes out of the session's live dynamic slots, batch i+1 is
    /// validated and assembled into the spare slots (mirroring the
    /// trainers' `par::join2` pipeline), then the buffer sets swap.
    ///
    /// Answers are byte-identical to the serial loop: every dynamic slot
    /// is fully overwritten by its builder, batches execute in submitted
    /// order, and the executor consumes only fully-prepared inputs.  The
    /// one observable difference is error timing — an invalid node id in
    /// batch i+1 is detected while batch i executes, so the flush fails
    /// one batch earlier in wall time (same error, same failed flush).
    ///
    /// Accounting: `busy_s`/`batch_hist` record the join span per batch
    /// (≈ max(exec_i, prep_{i+1}) — the worker's true busy time), and the
    /// completion stamp is taken inside the exec arm so request latency
    /// never includes the overlapped prep of the NEXT batch.
    pub(crate) fn run_batches_pipelined<'d>(
        &self,
        sess: &mut ServeSession,
        items: Vec<(usize, &'d [u32], &'d mut [f32])>,
        stages: &obs::ServeStages,
    ) -> Result<Vec<(usize, Instant)>> {
        let mut done: Vec<(usize, Instant)> = Vec::with_capacity(items.len());
        if items.len() <= 1 {
            // nothing to overlap — skip the thread spawn
            for (bi, nodes, out) in items {
                self.run_batch_timed(sess, nodes, out, stages)?;
                done.push((bi, Instant::now()));
            }
            return Ok(done);
        }
        let mut iter = items.into_iter();
        let (first_bi, first_nodes, first_out) = iter.next().expect("len > 1");
        let (mut bi, mut out) = (first_bi, first_out);
        // prologue: assemble batch 0 into the live slots (nothing to
        // overlap with yet)
        {
            let t0 = Instant::now();
            let assembly = stages.assembly.stage();
            self.check_batch(first_nodes)?;
            let ServeSession { dyn_inputs, scratch, .. } = sess;
            self.fill_slots(scratch, dyn_inputs, first_nodes);
            assembly.stop();
            sess.busy_s += t0.elapsed().as_secs_f64();
        }
        loop {
            let next = iter.next();
            let t0 = Instant::now();
            match next {
                None => {
                    // last batch: execute inline
                    let execution = stages.exec.stage();
                    let ServeSession { dyn_inputs, outputs, exec, .. } = sess;
                    let view = InputSlots::Overlay {
                        base: self.template,
                        idx: self.dyn_spec_idx,
                        dynamic: dyn_inputs.as_slice(),
                    };
                    self.art.run_slots(view, outputs, exec)?;
                    execution.stop();
                    out.copy_from_slice(&sess.outputs[0].f);
                    let elapsed = t0.elapsed();
                    sess.batches += 1;
                    sess.busy_s += elapsed.as_secs_f64();
                    sess.batch_hist.record_duration(elapsed);
                    done.push((bi, Instant::now()));
                    return Ok(done);
                }
                Some((nbi, nnodes, nout)) => {
                    let core = *self;
                    let ServeSession {
                        dyn_inputs,
                        spare_inputs,
                        outputs,
                        scratch,
                        exec,
                        ..
                    } = sess;
                    // prep on the spawned scoped thread, exec on the
                    // caller — stage spans are recorded inside each arm
                    // (the histogram handles are atomic).
                    let (prep_res, exec_res) = par::join2(
                        move || -> Result<(usize, &'d mut [f32])> {
                            let assembly = stages.assembly.stage();
                            core.check_batch(nnodes)?;
                            core.fill_slots(scratch, spare_inputs, nnodes);
                            assembly.stop();
                            Ok((nbi, nout))
                        },
                        move || -> Result<Instant> {
                            let execution = stages.exec.stage();
                            let view = InputSlots::Overlay {
                                base: core.template,
                                idx: core.dyn_spec_idx,
                                dynamic: dyn_inputs.as_slice(),
                            };
                            core.art.run_slots(view, outputs, exec)?;
                            execution.stop();
                            out.copy_from_slice(&outputs[0].f);
                            Ok(Instant::now())
                        },
                    );
                    let stamp = exec_res?;
                    done.push((bi, stamp));
                    let elapsed = t0.elapsed();
                    sess.batches += 1;
                    sess.busy_s += elapsed.as_secs_f64();
                    sess.batch_hist.record_duration(elapsed);
                    let (nbi, nout) = prep_res?;
                    // the spare slots hold batch i+1's inputs — make them
                    // live (the old live set becomes the next prep target)
                    std::mem::swap(&mut sess.dyn_inputs, &mut sess.spare_inputs);
                    bi = nbi;
                    out = nout;
                }
            }
        }
    }
}

pub struct ServingModel {
    pub core: ServeCore,
    pool: Vec<ServeSession>,
    queue: AdmissionQueue,
    /// Per-admitted-node last-touched stamps, in SLOT lockstep with the
    /// admitted store (compacted together on eviction).  Touched by the
    /// batcher via [`Self::note_served`] and at admission; read by the
    /// engine's retention policy.  Runtime-only (a loaded checkpoint's
    /// admitted nodes start "just touched").
    last_touch: Vec<Instant>,
    /// Reusable sort-dedup buffer for [`Self::note_served`] — a 10k-slot
    /// drain must not allocate per flush.
    touch_buf: Vec<u32>,
    /// Node→shard partition for the maintenance fan-out (`None` = serial).
    /// Governs which worker computes each served row's drift distance in
    /// [`Self::note_served`] and which slot range each worker scans in
    /// [`Self::retention_victims`]; recordings and eviction decisions are
    /// merged back in the serial order, so maintenance state is
    /// byte-identical at any shard count (see the `shard` module docs).
    shards: Option<ShardPlan>,
}

impl ServingModel {
    /// Freeze a trained `VqTrainer` into an immutable serving core (clone
    /// the parameters, snapshot the VQ state — assignments, codebooks,
    /// whitening stats — into the embedding cache, compile the forward-only
    /// serve artifact) with a 1-session pool; widen with
    /// [`ServingModel::set_threads`].
    pub fn freeze(rt: &mut Runtime, man: &Manifest, tr: &VqTrainer) -> Result<ServingModel> {
        let name = serve_artifact_name(&tr.ds.cfg.name, &tr.model_name);
        let art = rt.load(man, &name)?;
        // Refuse shape-incompatible trainers up front (ablation-suffix
        // trainers — "_l2", "_k64", ... — have no serve artifact; without
        // this check the mismatch surfaces as an index panic or a cryptic
        // execute-time shape error).
        let spec = &art.spec;
        let pspecs: Vec<_> =
            spec.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        if tr.params.len() != pspecs.len() {
            bail!(
                "cannot freeze '{}' into '{name}': trainer has {} params, serve spec \
                 wants {} (ablation-suffix trainers have no serving artifact)",
                tr.train_art.spec.name,
                tr.params.len(),
                pspecs.len()
            );
        }
        for (p, s) in tr.params.iter().zip(&pspecs) {
            if p.shape != s.shape {
                bail!(
                    "cannot freeze '{}' into '{name}': param '{}' is {:?}, serve spec \
                     wants {:?} (ablation-suffix trainers have no serving artifact)",
                    tr.train_art.spec.name,
                    s.name,
                    p.shape,
                    s.shape
                );
            }
        }
        if tr.vq.layers.len() != spec.plan.len()
            || tr.vq.layers.iter().any(|l| l.k != spec.k)
        {
            bail!(
                "cannot freeze '{}' into '{name}': VQ state ({} layers, k={}) does not \
                 fit the serve plan ({} layers, k={})",
                tr.train_art.spec.name,
                tr.vq.layers.len(),
                tr.vq.layers.first().map(|l| l.k).unwrap_or(0),
                spec.plan.len(),
                spec.k
            );
        }
        let params = tr.params.clone();
        let mut cache = EmbeddingCache::from_vq(&tr.vq);
        // freeze the drift detector's reference: the frozen nodes' own
        // distance-to-nearest-codeword is the training distribution's
        // footprint (exported into the VQS3 block by `save`)
        cache.seed_drift_reference(&tr.ds.features, tr.ds.cfg.f_in_pad);
        let (template, dynamic, dyn_spec_idx) = build_input_template(spec, &params, &cache)?;
        let core = ServeCore {
            conv: ServeCore::conv_of(&tr.model_name),
            ds: tr.ds.clone(),
            model_name: tr.model_name.clone(),
            params,
            cache,
            template: Arc::new(template),
            dynamic,
            dyn_spec_idx,
            art,
        };
        let pool = vec![core.new_session()];
        let last_touch = vec![Instant::now(); core.cache.admitted.len()];
        Ok(ServingModel {
            core,
            pool,
            queue: AdmissionQueue::default(),
            last_touch,
            touch_buf: Vec::new(),
            shards: None,
        })
    }

    /// Export this model as a "VQS3" serving artifact — admitted-node
    /// tables (stable ids included) and per-layer drift references, so
    /// cold nodes stay servable and the drift detector stays armed across
    /// processes (loadable by [`Self::load`] in a process that never
    /// trained anything).
    pub fn save(&self, path: &Path) -> Result<()> {
        checkpoint::save_serving(
            path,
            &self.core.art.spec.name,
            &self.core.params,
            &self.core.cache.to_serving_layers(),
            &self.core.cache.to_serving_admitted(),
        )
    }

    /// Load a serving artifact ("VQS3", or legacy "VQS2"/"VQS1") for
    /// `(dataset, model)` and validate every payload shape against the
    /// manifest's serve spec.
    pub fn load(
        rt: &mut Runtime,
        man: &Manifest,
        ds: Rc<Dataset>,
        model_name: &str,
        path: &Path,
    ) -> Result<ServingModel> {
        let name = serve_artifact_name(&ds.cfg.name, model_name);
        let art = rt.load(man, &name)?;
        let (params, layers, admitted) = checkpoint::load_serving(path, &name)?;
        let spec = &art.spec;
        let pspecs: Vec<_> =
            spec.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        if params.len() != pspecs.len() {
            bail!("serving artifact has {} params, spec wants {}", params.len(), pspecs.len());
        }
        for (p, s) in params.iter().zip(&pspecs) {
            if p.shape != s.shape {
                bail!("serving param '{}' shape {:?}, spec wants {:?}", s.name, p.shape, s.shape);
            }
        }
        if layers.len() != spec.plan.len() {
            bail!("serving artifact has {} layers, spec wants {}", layers.len(), spec.plan.len());
        }
        for (l, p) in layers.iter().zip(&spec.plan) {
            if l.k != spec.k || l.n != ds.n() || l.n_br != p.n_br || l.fp != p.fp {
                bail!(
                    "serving layer shape (k={}, n={}, n_br={}, fp={}) does not fit \
                     spec (k={}, n={}, n_br={}, fp={})",
                    l.k, l.n, l.n_br, l.fp, spec.k, ds.n(), p.n_br, p.fp
                );
            }
        }
        if admitted.count() > 0 && admitted.f_pad != ds.cfg.f_in_pad {
            bail!(
                "serving admitted features are {}-wide, dataset '{}' pads to {}",
                admitted.f_pad,
                ds.cfg.name,
                ds.cfg.f_in_pad
            );
        }
        let cache = EmbeddingCache::from_serving_layers(&spec.plan, layers, admitted);
        let (template, dynamic, dyn_spec_idx) = build_input_template(spec, &params, &cache)?;
        let core = ServeCore {
            conv: ServeCore::conv_of(model_name),
            ds,
            model_name: model_name.to_string(),
            params,
            cache,
            template: Arc::new(template),
            dynamic,
            dyn_spec_idx,
            art,
        };
        let pool = vec![core.new_session()];
        let last_touch = vec![Instant::now(); core.cache.admitted.len()];
        Ok(ServingModel {
            core,
            pool,
            queue: AdmissionQueue::default(),
            last_touch,
            touch_buf: Vec::new(),
            shards: None,
        })
    }

    /// Fixed micro-batch width of the compiled serve artifact.
    pub fn batch_size(&self) -> usize {
        self.core.art.spec.b
    }

    /// Output row width: class scores for node tasks, embedding dim for
    /// link tasks.
    pub fn out_dim(&self) -> usize {
        self.core.art.spec.outputs[0].shape[1]
    }

    /// The frozen embedding cache (assignments + codebooks + admitted).
    pub fn cache(&self) -> &EmbeddingCache {
        &self.core.cache
    }

    /// Total servable ids: dataset nodes + admitted nodes.
    pub fn total_nodes(&self) -> usize {
        self.core.cache.total_nodes()
    }

    /// Whether the dataset is a link task — its output rows are embedding
    /// vectors, not class scores (drives the wire SCORES `embedding` flag
    /// and the CLI's `emb_norm` rendering).
    pub fn link_task(&self) -> bool {
        self.core.ds.cfg.task == "link"
    }

    /// Bytes of ONE worker's dynamic input slots — the whole per-worker
    /// resident input cost, since the constant template is `Arc`-shared
    /// across the pool and counted once by `ServeCore::template_bytes`.
    pub fn worker_dyn_bytes(&self) -> usize {
        let s = &self.pool[0];
        s.dyn_inputs
            .iter()
            .chain(s.spare_inputs.iter())
            .map(Tensor::bytes)
            .sum()
    }

    /// Worker-pool width.
    pub fn threads(&self) -> usize {
        self.pool.len()
    }

    /// Resize the session pool to `n` workers (≥ 1).  Sessions are
    /// per-worker mutable state only — resizing never touches the shared
    /// core, so answers are bit-identical across any pool width.
    pub fn set_threads(&mut self, n: usize) {
        let n = n.max(1);
        while self.pool.len() > n {
            self.pool.pop();
        }
        while self.pool.len() < n {
            self.pool.push(self.core.new_session());
        }
    }

    /// Partition the maintenance paths across `s` shard workers (≤ 1 =
    /// serial).  The plan covers the frozen node range contiguously;
    /// admitted ids are assigned round-robin by [`ShardPlan::owner_of`].
    /// Maintenance output is merged back in serial order, so this knob —
    /// like the pool width — never changes a single byte of state.
    pub fn set_shards(&mut self, s: usize) {
        self.shards = (s > 1).then(|| ShardPlan::contiguous(self.core.ds.n(), s));
    }

    /// Current maintenance shard count (1 = serial).
    pub fn shards(&self) -> usize {
        self.shards.as_ref().map_or(1, ShardPlan::shards)
    }

    /// Per-worker throughput counters (batches, padded rows included).
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        let b = self.batch_size() as u64;
        self.pool
            .iter()
            .map(|s| WorkerStats {
                batches: s.batches,
                rows: s.batches * b,
                busy_s: s.busy_s,
                batch: s.batch_hist.snapshot(),
            })
            .collect()
    }

    /// Borrow-split the model into the `Sync` core view + the mutable
    /// worker pool (the engine's fan-out handle).
    pub(crate) fn parts(&mut self) -> (CoreRef<'_>, &mut [ServeSession]) {
        (self.core.view(), &mut self.pool)
    }

    /// One forward-only micro-batch on worker session 0: `batch` must be
    /// exactly `batch_size()` node ids (the engine pads); returns row-major
    /// `(b, out_dim)` scores borrowed from the session's output buffer
    /// (valid until the next call).  Only the batch-dependent input slots
    /// are rewritten — in place — so a steady-state micro-batch performs
    /// no heap allocation: the frozen weights and codebooks ride the
    /// prebuilt template untouched, and the session's step arena owns
    /// every intermediate.
    pub fn forward_batch(&mut self, rt: &Runtime, batch: &[u32]) -> Result<&[f32]> {
        let core = self.core.view();
        core.exec_batch(&mut self.pool[0], batch)?;
        let spec = &self.core.art.spec;
        rt.record_external(1, spec.input_bytes(), spec.output_bytes());
        Ok(&self.pool[0].outputs[0].f)
    }

    /// Admit one unseen node NOW (see module docs): `features` is its raw
    /// feature row (`f_in` or already-padded `f_in_pad` wide), `neighbors`
    /// its in-arcs from already-servable ids.  Returns the node's new id.
    /// This is the single-writer path — it takes `&mut self`, so it can
    /// never interleave with a pooled flush.  Refused while admissions are
    /// queued: a direct admit would steal the first queued node's promised
    /// id (run [`Self::admit_queued`] first).
    pub fn admit(&mut self, rt: &Runtime, features: &[f32], neighbors: &[u32]) -> Result<u32> {
        if !self.queue.is_empty() {
            bail!(
                "admit: {} queued admission(s) hold the next ids — apply admit_queued() \
                 before admitting directly",
                self.queue.len()
            );
        }
        self.admit_now(rt, features, neighbors)
    }

    /// Feature-row validation shared by the direct and queued admission
    /// paths — cheaply checkable up front, so a malformed request is
    /// refused at enqueue time instead of poisoning the queue at apply
    /// time.
    fn check_admit_features(&self, features: &[f32]) -> Result<()> {
        let f_pad = self.core.ds.cfg.f_in_pad;
        let f_raw = self.core.ds.cfg.f_in;
        if features.len() != f_raw && features.len() != f_pad {
            bail!(
                "admit: got {} features, dataset '{}' wants {f_raw} (or {f_pad} padded)",
                features.len(),
                self.core.ds.cfg.name
            );
        }
        if let Some(bad) = features.iter().find(|x| !x.is_finite()) {
            bail!("admit: non-finite feature {bad}");
        }
        Ok(())
    }

    fn admit_now(&mut self, rt: &Runtime, features: &[f32], neighbors: &[u32]) -> Result<u32> {
        self.check_admit_features(features)?;
        let f_pad = self.core.ds.cfg.f_in_pad;
        if let Some(&bad) =
            neighbors.iter().find(|&&u| !self.core.cache.admitted.is_servable(u))
        {
            bail!(
                "admit: neighbor {bad} is not a servable id ({} nodes + {} resident \
                 admitted)",
                self.core.cache.admitted.base_n,
                self.core.cache.admitted.len()
            );
        }
        let mut padded = vec![0.0f32; f_pad];
        padded[..features.len()].copy_from_slice(features);

        // capture the plan shape before taking &mut borrows
        let spec = &self.core.art.spec;
        let b = spec.b;
        let f_ins: Vec<usize> = spec.plan.iter().map(|p| p.f_in).collect();
        let n_brs: Vec<usize> = spec.plan.iter().map(|p| p.n_br).collect();

        // 1. record features + arcs — the node becomes visible to the
        //    sketch builders (it is IN the bootstrap batch, so its own
        //    still-missing assignment is never consulted)
        let id = self.core.cache.admitted.push(&padded, neighbors);

        // 2. bootstrap forward: one serve step over [id; b] leaves the
        //    node's per-layer input features in the session's step arena
        let mut feats: Vec<Vec<f32>> = Vec::with_capacity(f_ins.len());
        let boot: Result<()> = {
            let core = self.core.view();
            let sess = &mut self.pool[0];
            let batch = vec![id; b];
            core.exec_batch(&mut *sess, &batch).and_then(|()| {
                for (l, &fl) in f_ins.iter().enumerate() {
                    match sess.exec.layer_xfeat(l) {
                        Some(x) => feats.push(x[..fl].to_vec()),
                        None => bail!(
                            "admission needs the native backend's layer-{l} features \
                             (stateless sessions expose none)"
                        ),
                    }
                }
                Ok(())
            })
        };
        if let Err(e) = boot {
            self.core.cache.admitted.pop(); // roll the half-admitted node back
            return Err(e);
        }
        // the bootstrap forward is a real serve-artifact step — keep the
        // executions/bytes meters honest
        let spec = &self.core.art.spec;
        rt.record_external(1, spec.input_bytes(), spec.output_bytes());

        // 3. FINDNEAREST against the frozen codebooks, then append to the
        //    per-layer tables (all-or-nothing: assignment is infallible).
        //    The admitted rows double as drift observations — admission is
        //    exactly the traffic that can walk away from training.
        for (l, row) in feats.iter().enumerate() {
            let mut asg = vec![0u32; n_brs[l]];
            self.core.cache.layers[l].assign_features(row, &mut asg);
            self.core.cache.layers[l].record_admitted(&asg);
            self.core.cache.layers[l].observe_serving(row);
        }
        self.last_touch.push(Instant::now());
        Ok(id)
    }

    /// Batcher hook, called with a flush's REAL (unpadded) request ids
    /// under the engine's `&mut` — refresh the admitted nodes' touch
    /// stamps and feed the layer-0 drift observer.  Never touches
    /// anything an answer depends on: histograms and stamps only.
    pub fn note_served(&mut self, served: &[u32]) {
        if served.is_empty() {
            return;
        }
        let now = Instant::now();
        self.touch_buf.clear();
        self.touch_buf.extend_from_slice(served);
        self.touch_buf.sort_unstable();
        self.touch_buf.dedup();
        let ds = &self.core.ds;
        let f = ds.cfg.f_in_pad;
        let EmbeddingCache { layers, admitted } = &mut self.core.cache;
        let observe = layers.first().map(|l| l.plan.f_in == f).unwrap_or(false);
        // Phase 1, in id order: refresh admitted touch stamps and resolve
        // every served id to its feature row (dropping eviction races).
        let mut rows: Vec<(u32, &[f32])> = Vec::with_capacity(self.touch_buf.len());
        for &v in &self.touch_buf {
            let row = if (v as usize) < admitted.base_n {
                &ds.features[v as usize * f..(v as usize + 1) * f]
            } else {
                match admitted.slot_of(v) {
                    Some(s) => {
                        self.last_touch[s] = now;
                        admitted.feature_row(s)
                    }
                    None => continue, // raced an eviction: already refused upstream
                }
            };
            rows.push((v, row));
        }
        if !observe || rows.is_empty() {
            return;
        }
        let l0 = &mut layers[0];
        match &self.shards {
            None => {
                for &(_, row) in &rows {
                    l0.observe_serving(row);
                }
            }
            Some(plan) => {
                // Phase 2: fan the pure nearest-codeword distances across
                // the shard workers, each covering only the ids it owns.
                let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); plan.shards()];
                for (i, &(v, _)) in rows.iter().enumerate() {
                    buckets[plan.owner_of(v)].push(i);
                }
                let mut dists = vec![0.0f32; rows.len()];
                {
                    let l0r = &*l0;
                    let parts = par::scope_map(&mut buckets, |_w, idxs| {
                        idxs.iter()
                            .map(|&i| l0r.nearest_distance(rows[i].1))
                            .collect::<Vec<f32>>()
                    });
                    for (idxs, part) in buckets.iter().zip(&parts) {
                        for (&i, &d) in idxs.iter().zip(part) {
                            dists[i] = d;
                        }
                    }
                }
                // Phase 3, back in id order: replay the recordings so the
                // drift histogram and refresh ring match the serial bytes.
                for (i, &(_, row)) in rows.iter().enumerate() {
                    l0.record_observation(row, dists[i]);
                }
            }
        }
    }

    /// Admitted ids the retention policy would evict right now: every
    /// TTL-expired node, plus — beyond that — the least-recently-touched
    /// survivors over `max_admitted`.  Deterministic: ties broken by id.
    pub fn retention_victims(
        &self,
        max_admitted: Option<usize>,
        ttl: Option<Duration>,
    ) -> Vec<u32> {
        let adm = &self.core.cache.admitted;
        let n = adm.len();
        if n == 0 {
            return Vec::new();
        }
        let now = Instant::now();
        let scan = |lo: usize, hi: usize| {
            let mut victims: Vec<u32> = Vec::new();
            let mut live: Vec<(Instant, u32)> = Vec::new();
            for s in lo..hi {
                let id = adm.id_of(s);
                match ttl {
                    Some(t) if now.duration_since(self.last_touch[s]) >= t => {
                        victims.push(id)
                    }
                    _ => live.push((self.last_touch[s], id)),
                }
            }
            (victims, live)
        };
        let (mut victims, mut live) = match &self.shards {
            // shard the TTL scan over slot ranges; the merge order cannot
            // matter because both lists are globally sorted below
            Some(plan) if n >= 2 * plan.shards() => {
                let st = plan.shards();
                let mut ranges: Vec<(usize, usize)> =
                    (0..st).map(|s| crate::shard::chunk_range(n, st, s)).collect();
                let parts = par::scope_map(&mut ranges, |_w, r| scan(r.0, r.1));
                let mut victims = Vec::new();
                let mut live = Vec::new();
                for (v, l) in parts {
                    victims.extend(v);
                    live.extend(l);
                }
                (victims, live)
            }
            _ => scan(0, n),
        };
        if let Some(cap) = max_admitted {
            if live.len() > cap {
                live.sort(); // oldest stamp first, ids break ties
                victims.extend(live[..live.len() - cap].iter().map(|&(_, id)| id));
            }
        }
        victims.sort_unstable();
        victims
    }

    /// Evict admitted ids (single-writer path): compacts the feature/CSR
    /// store, every layer's assignment tail + histogram, and the touch
    /// stamps in lockstep.  Survivors keep their ids; evicted ids are
    /// refused by [`CoreRef::check_batch`] with the typed unknown-id
    /// error from then on.  Returns how many nodes actually left.
    pub fn evict(&mut self, victims: &[u32]) -> usize {
        let before = self.core.cache.admitted.len();
        let keep = self.core.cache.evict(victims);
        if keep.len() != before {
            self.last_touch = keep.iter().map(|&s| self.last_touch[s]).collect();
        }
        before - self.core.cache.admitted.len()
    }

    /// Largest per-layer codebook-drift metric (TV distance of observed
    /// vs reference distance histograms, 0 = healthy / no signal).
    pub fn max_drift(&self) -> f32 {
        self.core.cache.max_drift()
    }

    /// Online EMA refresh (single-writer path): re-fit each layer's
    /// codewords from its retained recent traffic
    /// ([`crate::serve::cache::LayerCache::refresh`]), then rebuild the
    /// constant input template so workers see the new codebooks (pool
    /// sessions carry only dynamic slots — no session rebuild needed).
    /// A refresh with no retained traffic is a bit-exact no-op.
    pub fn refresh(&mut self, gamma: f32) -> Result<bool> {
        let mut changed = false;
        for l in &mut self.core.cache.layers {
            changed |= l.refresh(gamma);
        }
        if changed {
            let (template, dynamic, dyn_spec_idx) =
                build_input_template(&self.core.art.spec, &self.core.params, &self.core.cache)?;
            self.core.template = Arc::new(template);
            self.core.dynamic = dynamic;
            self.core.dyn_spec_idx = dyn_spec_idx;
        }
        Ok(changed)
    }

    /// Enqueue an admission without applying it.  The id is assigned
    /// immediately (monotone FIFO), so later requests may cite it as a
    /// neighbor; it becomes servable once [`Self::admit_queued`] runs.
    /// Everything cheaply checkable is validated HERE — a malformed
    /// request is refused before it can sit in front of valid ones.
    /// Neighbors must be servable (frozen or resident — evicted ids are
    /// refused like any other unknown id) or an earlier promised id.
    pub fn queue_admission(&mut self, features: Vec<f32>, neighbors: Vec<u32>) -> Result<u32> {
        self.check_admit_features(&features)?;
        let bound = self.core.cache.admitted.id_bound();
        let provisional = bound + self.queue.len() as u32;
        if let Some(&bad) = neighbors.iter().find(|&&u| {
            !(self.core.cache.admitted.is_servable(u) || (bound..provisional).contains(&u))
        }) {
            bail!(
                "queue_admission: neighbor {bad} is not a servable or promised id \
                 (next is {provisional})"
            );
        }
        self.queue.push(features, neighbors);
        Ok(provisional)
    }

    /// Queued admissions not yet applied.
    pub fn queued_admissions(&self) -> usize {
        self.queue.len()
    }

    /// Apply every queued admission FIFO (the single writer, between
    /// flushes); returns the admitted ids.  On a failed request the
    /// earlier ones stay admitted, and the failing request PLUS everything
    /// after it go back on the queue — their promised dense ids stay
    /// reserved (nothing else can claim them while the queue is
    /// non-empty), so a caller can drop/fix the bad request and retry
    /// without invalidating ids already handed out.
    pub fn admit_queued(&mut self, rt: &Runtime) -> Result<Vec<u32>> {
        let reqs = self.queue.take();
        let mut ids = Vec::with_capacity(reqs.len());
        let mut failed: Option<(usize, anyhow::Error)> = None;
        for (i, (features, neighbors)) in reqs.into_iter().enumerate() {
            if failed.is_none() {
                match self.admit_now(rt, &features, &neighbors) {
                    Ok(id) => {
                        ids.push(id);
                        continue;
                    }
                    Err(e) => failed = Some((i, e)),
                }
            }
            // the failed request and everything behind it keep their slots
            self.queue.push(features, neighbors);
        }
        if let Some((i, e)) = failed {
            return Err(e.context(format!(
                "queued admission #{i} (it and {} later request(s) remain queued)",
                self.queue.len() - 1
            )));
        }
        Ok(ids)
    }

    /// Drop every queued-but-unapplied admission (after a failed
    /// [`Self::admit_queued`], this releases the reserved ids).
    pub fn clear_queued(&mut self) {
        self.queue.take();
    }
}
