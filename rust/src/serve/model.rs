//! An immutable, frozen model for the read path.  Built either by freezing
//! a live `VqTrainer` (training process hands off to serving) or by loading
//! a serving artifact exported by `coordinator::checkpoint::save_serving`
//! (inference-only process).  Executes the forward-only `vq_serve_*`
//! artifact on whatever backend the `Runtime` selected — no loss head, no
//! gradient buffers, no residual outputs.

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::gather_features;
use crate::coordinator::vq_trainer::VqTrainer;
use crate::datasets::Dataset;
use crate::graph::Conv;
use crate::runtime::manifest::Manifest;
use crate::runtime::{Artifact, Runtime};
use crate::serve::cache::EmbeddingCache;
use crate::util::tensor::Tensor;
use crate::vq::sketch::SketchScratch;

pub struct ServingModel {
    pub art: Rc<Artifact>,
    pub ds: Rc<Dataset>,
    pub model_name: String,
    pub params: Vec<Tensor>,
    pub cache: EmbeddingCache,
    scratch: SketchScratch,
    /// Prebuilt input list in spec order.  Constant slots (params,
    /// codebooks) are filled ONCE here; only the batch-dependent slots are
    /// overwritten per micro-batch — the read path never re-copies frozen
    /// weights.
    inputs: Vec<Tensor>,
    /// `(input index, kind)` of every batch-dependent slot, in spec order.
    dynamic: Vec<(usize, DynSlot)>,
}

/// Batch-dependent input slots of the serve artifact.
#[derive(Debug, Clone, Copy)]
enum DynSlot {
    Xb,
    CIn(usize),
    COut(usize),
    MaskIn(usize),
    MOut(usize),
    CntOut(usize),
}

fn serve_artifact_name(ds: &str, model: &str) -> String {
    format!("vq_serve_{ds}_{model}")
}

/// Fill the constant input slots (params + raw codebooks) and index the
/// dynamic ones.  Placeholder zeros keep every slot shape/dtype-correct;
/// each dynamic slot is overwritten on every `forward_batch`.
fn build_input_template(
    spec: &crate::runtime::manifest::ArtifactSpec,
    params: &[Tensor],
    cache: &EmbeddingCache,
) -> Result<(Vec<Tensor>, Vec<(usize, DynSlot)>)> {
    let mut inputs = Vec::with_capacity(spec.inputs.len());
    let mut dynamic = Vec::new();
    let mut pi = 0usize;
    for (idx, ts) in spec.inputs.iter().enumerate() {
        let name = ts.name.as_str();
        if name == "xb" {
            dynamic.push((idx, DynSlot::Xb));
            inputs.push(Tensor::zeros(&ts.shape));
        } else if name.starts_with("param.") {
            inputs.push(params[pi].clone());
            pi += 1;
        } else if let Some((lstr, field)) = name.split_once('.') {
            let l: usize = lstr[1..].parse().context("layer index")?;
            let slot = match field {
                "c_in" => Some(DynSlot::CIn(l)),
                "c_out" => Some(DynSlot::COut(l)),
                "mask_in" => Some(DynSlot::MaskIn(l)),
                "m_out" => Some(DynSlot::MOut(l)),
                "cnt_out" => Some(DynSlot::CntOut(l)),
                "cw" => None,
                other => bail!("unknown serve ctx field {other}"),
            };
            match slot {
                Some(kind) => {
                    dynamic.push((idx, kind));
                    inputs.push(Tensor::zeros(&ts.shape));
                }
                None => inputs.push(cache.layers[l].cw.clone()),
            }
        } else {
            bail!("unknown serve input {name}");
        }
    }
    Ok((inputs, dynamic))
}

impl ServingModel {
    /// Freeze a trained `VqTrainer` into an immutable serving model: clone
    /// the parameters, snapshot the VQ state into the embedding cache, and
    /// compile the forward-only serve artifact.
    pub fn freeze(rt: &mut Runtime, man: &Manifest, tr: &VqTrainer) -> Result<ServingModel> {
        let name = serve_artifact_name(&tr.ds.cfg.name, &tr.model_name);
        let art = rt.load(man, &name)?;
        // Refuse shape-incompatible trainers up front (ablation-suffix
        // trainers — "_l2", "_k64", ... — have no serve artifact; without
        // this check the mismatch surfaces as an index panic or a cryptic
        // execute-time shape error).
        let spec = &art.spec;
        let pspecs: Vec<_> =
            spec.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        if tr.params.len() != pspecs.len() {
            bail!(
                "cannot freeze '{}' into '{name}': trainer has {} params, serve spec \
                 wants {} (ablation-suffix trainers have no serving artifact)",
                tr.train_art.spec.name,
                tr.params.len(),
                pspecs.len()
            );
        }
        for (p, s) in tr.params.iter().zip(&pspecs) {
            if p.shape != s.shape {
                bail!(
                    "cannot freeze '{}' into '{name}': param '{}' is {:?}, serve spec \
                     wants {:?} (ablation-suffix trainers have no serving artifact)",
                    tr.train_art.spec.name,
                    s.name,
                    p.shape,
                    s.shape
                );
            }
        }
        if tr.vq.layers.len() != spec.plan.len()
            || tr.vq.layers.iter().any(|l| l.k != spec.k)
        {
            bail!(
                "cannot freeze '{}' into '{name}': VQ state ({} layers, k={}) does not \
                 fit the serve plan ({} layers, k={})",
                tr.train_art.spec.name,
                tr.vq.layers.len(),
                tr.vq.layers.first().map(|l| l.k).unwrap_or(0),
                spec.plan.len(),
                spec.k
            );
        }
        let params = tr.params.clone();
        let cache = EmbeddingCache::from_vq(&tr.vq);
        let (inputs, dynamic) = build_input_template(spec, &params, &cache)?;
        Ok(ServingModel {
            art,
            ds: tr.ds.clone(),
            model_name: tr.model_name.clone(),
            params,
            cache,
            scratch: SketchScratch::new(tr.ds.n()),
            inputs,
            dynamic,
        })
    }

    /// Export this model as a serving artifact (loadable by [`Self::load`]
    /// in a process that never trained anything).
    pub fn save(&self, path: &Path) -> Result<()> {
        checkpoint::save_serving(
            path,
            &self.art.spec.name,
            &self.params,
            &self.cache.to_serving_layers(),
        )
    }

    /// Load a serving artifact for `(dataset, model)` and validate every
    /// payload shape against the manifest's serve spec.
    pub fn load(
        rt: &mut Runtime,
        man: &Manifest,
        ds: Rc<Dataset>,
        model_name: &str,
        path: &Path,
    ) -> Result<ServingModel> {
        let name = serve_artifact_name(&ds.cfg.name, model_name);
        let art = rt.load(man, &name)?;
        let (params, layers) = checkpoint::load_serving(path, &name)?;
        let spec = &art.spec;
        let pspecs: Vec<_> =
            spec.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        if params.len() != pspecs.len() {
            bail!("serving artifact has {} params, spec wants {}", params.len(), pspecs.len());
        }
        for (p, s) in params.iter().zip(&pspecs) {
            if p.shape != s.shape {
                bail!("serving param '{}' shape {:?}, spec wants {:?}", s.name, p.shape, s.shape);
            }
        }
        if layers.len() != spec.plan.len() {
            bail!("serving artifact has {} layers, spec wants {}", layers.len(), spec.plan.len());
        }
        for (l, p) in layers.iter().zip(&spec.plan) {
            if l.k != spec.k || l.n != ds.n() || l.n_br != p.n_br || l.fp != p.fp {
                bail!(
                    "serving layer shape (k={}, n={}, n_br={}, fp={}) does not fit \
                     spec (k={}, n={}, n_br={}, fp={})",
                    l.k, l.n, l.n_br, l.fp, spec.k, ds.n(), p.n_br, p.fp
                );
            }
        }
        let cache = EmbeddingCache::from_serving_layers(&spec.plan, layers);
        let (inputs, dynamic) = build_input_template(spec, &params, &cache)?;
        let scratch = SketchScratch::new(ds.n());
        Ok(ServingModel {
            art,
            ds,
            model_name: model_name.to_string(),
            params,
            cache,
            scratch,
            inputs,
            dynamic,
        })
    }

    /// Fixed micro-batch width of the compiled serve artifact.
    pub fn batch_size(&self) -> usize {
        self.art.spec.b
    }

    /// Output row width: class scores for node tasks, embedding dim for
    /// link tasks.
    pub fn out_dim(&self) -> usize {
        self.art.spec.outputs[0].shape[1]
    }

    fn conv(&self) -> Conv {
        match self.model_name.as_str() {
            "gcn" => Conv::GcnSym,
            "sage" => Conv::SageMean,
            other => panic!("fixed conv requested for learnable model {other}"),
        }
    }

    /// One forward-only micro-batch: `batch` must be exactly `batch_size()`
    /// node ids (the engine pads); returns row-major `(b, out_dim)` scores.
    /// Only the batch-dependent input slots are rebuilt — the frozen
    /// weights and codebooks ride the prebuilt template untouched.
    pub fn forward_batch(&mut self, rt: &mut Runtime, batch: &[u32]) -> Result<Vec<f32>> {
        let art = self.art.clone();
        if batch.len() != art.spec.b {
            bail!("forward_batch wants exactly b={} nodes, got {}", art.spec.b, batch.len());
        }
        let ds = self.ds.clone();
        // request-controlled ids must never panic the server
        if let Some(&bad) = batch.iter().find(|&&v| v as usize >= ds.n()) {
            bail!("node id {bad} out of range (dataset '{}' has n={})", ds.cfg.name, ds.n());
        }
        // stash between paired slots of one layer (c_in → c_out /
        // mask_in → m_out share a single builder pass)
        let mut stash: Option<(usize, Tensor)> = None;
        for di in 0..self.dynamic.len() {
            let (idx, kind) = self.dynamic[di];
            let t = match kind {
                DynSlot::Xb => gather_features(&ds.features, ds.cfg.f_in_pad, batch),
                DynSlot::CIn(l) => {
                    let (c_in, c_out) = self.cache.layers[l].build_fixed_fwd(
                        &ds.graph, self.conv(), batch, &mut self.scratch,
                    );
                    stash = Some((l, c_out));
                    c_in
                }
                DynSlot::COut(l) => {
                    let (pl, c_out) = stash.take().unwrap();
                    assert_eq!(pl, l);
                    c_out
                }
                DynSlot::MaskIn(l) => {
                    let (mask_in, m_out) = self.cache.layers[l].build_learnable_fwd(
                        &ds.graph, batch, &mut self.scratch,
                    );
                    stash = Some((l, m_out));
                    mask_in
                }
                DynSlot::MOut(l) => {
                    let (pl, m_out) = stash.take().unwrap();
                    assert_eq!(pl, l);
                    m_out
                }
                DynSlot::CntOut(l) => self.cache.layers[l].build_cnt_fwd(batch, &mut self.scratch),
            };
            self.inputs[idx] = t;
        }
        let out = rt.execute(&art, &self.inputs)?;
        Ok(out[0].f.clone())
    }
}
