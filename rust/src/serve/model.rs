//! An immutable, frozen model for the read path.  Built either by freezing
//! a live `VqTrainer` (training process hands off to serving) or by loading
//! a serving artifact exported by `coordinator::checkpoint::save_serving`
//! (inference-only process).  Executes the forward-only `vq_serve_*`
//! artifact on whatever backend the `Runtime` selected — no loss head, no
//! gradient buffers, no residual outputs.

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::gather_features_into;
use crate::coordinator::vq_trainer::VqTrainer;
use crate::datasets::Dataset;
use crate::graph::Conv;
use crate::runtime::manifest::Manifest;
use crate::runtime::{Artifact, Runtime};
use crate::serve::cache::EmbeddingCache;
use crate::util::tensor::{self, Tensor};
use crate::vq::sketch::SketchScratch;

pub struct ServingModel {
    pub art: Rc<Artifact>,
    pub ds: Rc<Dataset>,
    pub model_name: String,
    pub params: Vec<Tensor>,
    pub cache: EmbeddingCache,
    scratch: SketchScratch,
    /// Prebuilt input list in spec order — the serving session.  Constant
    /// slots (params, codebooks) are filled ONCE here; the batch-dependent
    /// slots are rewritten IN PLACE per micro-batch — the read path never
    /// re-copies frozen weights and never allocates for a steady-state
    /// micro-batch (the `serve_alloc_bytes` bench key measures this).
    inputs: Vec<Tensor>,
    /// Output tensors rewritten in place by `Runtime::execute_into`.
    outputs: Vec<Tensor>,
    /// Every batch-dependent slot, grouped per builder pass.
    dynamic: Vec<DynSlot>,
}

/// Batch-dependent input slots of the serve artifact, grouped so each
/// sketch-builder pass writes its slot pair in place (via disjoint `&mut`).
#[derive(Debug, Clone, Copy)]
enum DynSlot {
    /// Gathered feature rows.
    Xb(usize),
    /// Fixed-conv sketch pair of layer `l` at input indices `(c_in, c_out)`.
    Fixed { l: usize, c_in: usize, c_out: usize },
    /// Learnable count-sketch pair of layer `l` at `(mask_in, m_out)`.
    Learnable { l: usize, mask_in: usize, m_out: usize },
    /// txf global histogram of layer `l` at input index `idx`.
    CntOut { l: usize, idx: usize },
}

fn serve_artifact_name(ds: &str, model: &str) -> String {
    format!("vq_serve_{ds}_{model}")
}

/// Fill the constant input slots (params + raw codebooks) and index the
/// dynamic ones.  Placeholder zeros keep every slot shape/dtype-correct;
/// each dynamic slot is rewritten in place on every `forward_batch`.
fn build_input_template(
    spec: &crate::runtime::manifest::ArtifactSpec,
    params: &[Tensor],
    cache: &EmbeddingCache,
) -> Result<(Vec<Tensor>, Vec<DynSlot>)> {
    let nl = spec.plan.len();
    let mut inputs = Vec::with_capacity(spec.inputs.len());
    let mut dynamic = Vec::new();
    // per-layer partner indices, paired up after the scan
    let mut c_in_idx = vec![None; nl];
    let mut c_out_idx = vec![None; nl];
    let mut mask_idx = vec![None; nl];
    let mut m_out_idx = vec![None; nl];
    let mut pi = 0usize;
    for (idx, ts) in spec.inputs.iter().enumerate() {
        let name = ts.name.as_str();
        if name == "xb" {
            dynamic.push(DynSlot::Xb(idx));
            inputs.push(Tensor::zeros(&ts.shape));
        } else if name.starts_with("param.") {
            inputs.push(params[pi].clone());
            pi += 1;
        } else if let Some((lstr, field)) = name.split_once('.') {
            let l: usize = lstr[1..].parse().context("layer index")?;
            let known = match field {
                "c_in" => {
                    c_in_idx[l] = Some(idx);
                    true
                }
                "c_out" => {
                    c_out_idx[l] = Some(idx);
                    true
                }
                "mask_in" => {
                    mask_idx[l] = Some(idx);
                    true
                }
                "m_out" => {
                    m_out_idx[l] = Some(idx);
                    true
                }
                "cnt_out" => {
                    dynamic.push(DynSlot::CntOut { l, idx });
                    true
                }
                "cw" => {
                    inputs.push(cache.layers[l].cw.clone());
                    false
                }
                other => bail!("unknown serve ctx field {other}"),
            };
            if known && field != "cw" {
                inputs.push(Tensor::zeros(&ts.shape));
            }
        } else {
            bail!("unknown serve input {name}");
        }
    }
    for l in 0..nl {
        match (c_in_idx[l], c_out_idx[l], mask_idx[l], m_out_idx[l]) {
            (Some(ci), Some(co), None, None) => {
                dynamic.push(DynSlot::Fixed { l, c_in: ci, c_out: co })
            }
            (None, None, Some(mi), Some(mo)) => {
                dynamic.push(DynSlot::Learnable { l, mask_in: mi, m_out: mo })
            }
            other => bail!("serve layer {l}: incomplete sketch slot pair {other:?}"),
        }
    }
    Ok((inputs, dynamic))
}

impl ServingModel {
    /// Freeze a trained `VqTrainer` into an immutable serving model: clone
    /// the parameters, snapshot the VQ state into the embedding cache, and
    /// compile the forward-only serve artifact.
    pub fn freeze(rt: &mut Runtime, man: &Manifest, tr: &VqTrainer) -> Result<ServingModel> {
        let name = serve_artifact_name(&tr.ds.cfg.name, &tr.model_name);
        let art = rt.load(man, &name)?;
        // Refuse shape-incompatible trainers up front (ablation-suffix
        // trainers — "_l2", "_k64", ... — have no serve artifact; without
        // this check the mismatch surfaces as an index panic or a cryptic
        // execute-time shape error).
        let spec = &art.spec;
        let pspecs: Vec<_> =
            spec.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        if tr.params.len() != pspecs.len() {
            bail!(
                "cannot freeze '{}' into '{name}': trainer has {} params, serve spec \
                 wants {} (ablation-suffix trainers have no serving artifact)",
                tr.train_art.spec.name,
                tr.params.len(),
                pspecs.len()
            );
        }
        for (p, s) in tr.params.iter().zip(&pspecs) {
            if p.shape != s.shape {
                bail!(
                    "cannot freeze '{}' into '{name}': param '{}' is {:?}, serve spec \
                     wants {:?} (ablation-suffix trainers have no serving artifact)",
                    tr.train_art.spec.name,
                    s.name,
                    p.shape,
                    s.shape
                );
            }
        }
        if tr.vq.layers.len() != spec.plan.len()
            || tr.vq.layers.iter().any(|l| l.k != spec.k)
        {
            bail!(
                "cannot freeze '{}' into '{name}': VQ state ({} layers, k={}) does not \
                 fit the serve plan ({} layers, k={})",
                tr.train_art.spec.name,
                tr.vq.layers.len(),
                tr.vq.layers.first().map(|l| l.k).unwrap_or(0),
                spec.plan.len(),
                spec.k
            );
        }
        let params = tr.params.clone();
        let cache = EmbeddingCache::from_vq(&tr.vq);
        let (inputs, dynamic) = build_input_template(spec, &params, &cache)?;
        Ok(ServingModel {
            art,
            ds: tr.ds.clone(),
            model_name: tr.model_name.clone(),
            params,
            cache,
            scratch: SketchScratch::new(tr.ds.n()),
            inputs,
            outputs: Vec::new(),
            dynamic,
        })
    }

    /// Export this model as a serving artifact (loadable by [`Self::load`]
    /// in a process that never trained anything).
    pub fn save(&self, path: &Path) -> Result<()> {
        checkpoint::save_serving(
            path,
            &self.art.spec.name,
            &self.params,
            &self.cache.to_serving_layers(),
        )
    }

    /// Load a serving artifact for `(dataset, model)` and validate every
    /// payload shape against the manifest's serve spec.
    pub fn load(
        rt: &mut Runtime,
        man: &Manifest,
        ds: Rc<Dataset>,
        model_name: &str,
        path: &Path,
    ) -> Result<ServingModel> {
        let name = serve_artifact_name(&ds.cfg.name, model_name);
        let art = rt.load(man, &name)?;
        let (params, layers) = checkpoint::load_serving(path, &name)?;
        let spec = &art.spec;
        let pspecs: Vec<_> =
            spec.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        if params.len() != pspecs.len() {
            bail!("serving artifact has {} params, spec wants {}", params.len(), pspecs.len());
        }
        for (p, s) in params.iter().zip(&pspecs) {
            if p.shape != s.shape {
                bail!("serving param '{}' shape {:?}, spec wants {:?}", s.name, p.shape, s.shape);
            }
        }
        if layers.len() != spec.plan.len() {
            bail!("serving artifact has {} layers, spec wants {}", layers.len(), spec.plan.len());
        }
        for (l, p) in layers.iter().zip(&spec.plan) {
            if l.k != spec.k || l.n != ds.n() || l.n_br != p.n_br || l.fp != p.fp {
                bail!(
                    "serving layer shape (k={}, n={}, n_br={}, fp={}) does not fit \
                     spec (k={}, n={}, n_br={}, fp={})",
                    l.k, l.n, l.n_br, l.fp, spec.k, ds.n(), p.n_br, p.fp
                );
            }
        }
        let cache = EmbeddingCache::from_serving_layers(&spec.plan, layers);
        let (inputs, dynamic) = build_input_template(spec, &params, &cache)?;
        let scratch = SketchScratch::new(ds.n());
        Ok(ServingModel {
            art,
            ds,
            model_name: model_name.to_string(),
            params,
            cache,
            scratch,
            inputs,
            outputs: Vec::new(),
            dynamic,
        })
    }

    /// Fixed micro-batch width of the compiled serve artifact.
    pub fn batch_size(&self) -> usize {
        self.art.spec.b
    }

    /// Output row width: class scores for node tasks, embedding dim for
    /// link tasks.
    pub fn out_dim(&self) -> usize {
        self.art.spec.outputs[0].shape[1]
    }

    fn conv_opt(&self) -> Option<Conv> {
        match self.model_name.as_str() {
            "gcn" => Some(Conv::GcnSym),
            "sage" => Some(Conv::SageMean),
            _ => None, // learnable convolutions build count sketches instead
        }
    }

    /// One forward-only micro-batch: `batch` must be exactly `batch_size()`
    /// node ids (the engine pads); returns row-major `(b, out_dim)` scores
    /// borrowed from the session's output buffer (valid until the next
    /// call).  Only the batch-dependent input slots are rewritten — in
    /// place — so a steady-state micro-batch performs no heap allocation:
    /// the frozen weights and codebooks ride the prebuilt template
    /// untouched, and the executor's step arena owns every intermediate.
    pub fn forward_batch(&mut self, rt: &mut Runtime, batch: &[u32]) -> Result<&[f32]> {
        let art = self.art.clone();
        if batch.len() != art.spec.b {
            bail!("forward_batch wants exactly b={} nodes, got {}", art.spec.b, batch.len());
        }
        let ds = self.ds.clone();
        // request-controlled ids must never panic the server
        if let Some(&bad) = batch.iter().find(|&&v| v as usize >= ds.n()) {
            bail!("node id {bad} out of range (dataset '{}' has n={})", ds.cfg.name, ds.n());
        }
        let conv = self.conv_opt();
        for slot in &self.dynamic {
            match *slot {
                DynSlot::Xb(idx) => gather_features_into(
                    &ds.features,
                    ds.cfg.f_in_pad,
                    batch,
                    &mut self.inputs[idx].f,
                ),
                DynSlot::Fixed { l, c_in, c_out } => {
                    let (ti, to) = tensor::mut2(&mut self.inputs, c_in, c_out);
                    self.cache.layers[l].build_fixed_fwd_into(
                        &ds.graph,
                        conv.expect("fixed-conv serve artifact without a fixed conv"),
                        batch,
                        &mut self.scratch,
                        &mut ti.f,
                        &mut to.f,
                    );
                }
                DynSlot::Learnable { l, mask_in, m_out } => {
                    let (tm, to) = tensor::mut2(&mut self.inputs, mask_in, m_out);
                    self.cache.layers[l].build_learnable_fwd_into(
                        &ds.graph,
                        batch,
                        &mut self.scratch,
                        &mut tm.f,
                        &mut to.f,
                    );
                }
                DynSlot::CntOut { l, idx } => self.cache.layers[l].build_cnt_fwd_into(
                    batch,
                    &mut self.scratch,
                    &mut self.inputs[idx].f,
                ),
            }
        }
        rt.execute_into(&art, &self.inputs, &mut self.outputs)?;
        Ok(&self.outputs[0].f)
    }
}
