//! Multi-model router: the `ServeEngine`'s name → (model, queue) table.
//! Linear scan over a handful of registered models — routing cost is
//! nanoseconds next to a micro-batch, and registration order stays the
//! iteration (flush) order, which keeps multi-model drains deterministic.

use crate::serve::engine::MicroBatcher;
use crate::serve::model::ServingModel;

/// One routed model: its serving pool plus its own bounded micro-batch
/// queue (per-model `EngineStats` live on the queue).
pub(crate) struct ModelEntry {
    pub name: String,
    pub model: ServingModel,
    pub queue: MicroBatcher,
    /// Whether the model's drift metric was at/above the engine threshold
    /// after the last flush — edge detector for `EngineStats::drift_alerts`
    /// (one alert per excursion, not per flush).
    pub drift_high: bool,
}

pub(crate) struct Router {
    entries: Vec<ModelEntry>,
}

impl Router {
    pub fn new(entries: Vec<ModelEntry>) -> Router {
        Router { entries }
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut ModelEntry> {
        self.entries.iter_mut().find(|e| e.name == name)
    }

    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    pub fn entries_mut(&mut self) -> &mut [ModelEntry] {
        &mut self.entries
    }

    pub fn push(&mut self, entry: ModelEntry) {
        self.entries.push(entry);
    }

    pub fn into_models(self) -> Vec<(String, ServingModel)> {
        self.entries.into_iter().map(|e| (e.name, e.model)).collect()
    }
}
