//! Micro-batching engine: a request queue that coalesces incoming queries
//! into fixed-size batches (the serve artifact's compiled width `b`),
//! fans them out across the model's session pool, and merges per-request
//! results back in submit order.
//!
//! Two flushing disciplines share one body:
//!
//! - [`MicroBatcher::drain`] — cut everything, padding the tail: mirrors
//!   `VqTrainer::infer_nodes` exactly (FIFO chunks of `b`, tail padded
//!   with the flush's first queued node), so a drained queue answers
//!   bit-identically to one-shot inference over the same query list
//!   (asserted by `tests/serve.rs`);
//! - [`MicroBatcher::flush`] — **deadline-driven**: full `b`-wide batches
//!   are always cut, but a partial tail runs (padded) only once a request
//!   in it has outlived the engine's deadline; otherwise those requests
//!   stay queued for the next flush to coalesce with newer arrivals.
//!   This is what shrinks the padded-row waste under streaming load: the
//!   common case is that the tail keeps filling, and only a deadline
//!   expiry ever pays for padding.  The two tail paths are counted
//!   separately ([`EngineStats::tail_deadline_flushes`] /
//!   [`EngineStats::tail_forced_flushes`]).
//!
//! **Concurrency**: batches of one flush are independent — each is a pure
//! function of the shared [`ServeCore`](crate::serve::model::ServeCore) —
//! so they run across the pool's sessions via `util::par::scope_map`
//! (worker `w` takes batches `w, w+T, w+2T, …`; results land in
//! batch-indexed slots).  Answers are bit-identical to the serial
//! schedule for ANY worker count (`tests/serve_concurrent.rs`); only the
//! latency stamps differ.  Duplicate node ids in one batch are fine: each
//! occurrence owns a row, and rows of the same node are computed from
//! identical inputs.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::Runtime;
use crate::serve::model::ServingModel;
use crate::serve::{Answer, Request};
use crate::util::par;

/// A completed request: the answer plus its queue-to-completion latency.
pub struct Served {
    pub id: usize,
    pub answer: Answer,
    pub latency_s: f64,
}

/// Lifetime + per-flush accounting of the engine (capacity-planning
/// signals; the CLI and `bench_guard` read these).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Micro-batches executed over the engine's lifetime.
    pub batches_run: u64,
    /// Batches that ran completely full (no padding).
    pub full_batches: u64,
    /// Padding rows wasted on partial tails, lifetime total.
    pub padded_rows: u64,
    /// Padding rows of the MOST RECENT flush (per-drain signal).
    pub last_flush_padded_rows: u64,
    /// Partial tails flushed because a request's deadline expired.
    pub tail_deadline_flushes: u64,
    /// Partial tails flushed because the caller forced a full drain.
    pub tail_forced_flushes: u64,
}

pub struct MicroBatcher {
    pending: Vec<(usize, Request, Instant)>,
    next_id: usize,
    /// Tail-flush deadline: a partial tail runs once its oldest request is
    /// older than this.  `None` means tails only run on `drain`.
    deadline: Option<Duration>,
    pub stats: EngineStats,
}

impl Default for MicroBatcher {
    fn default() -> MicroBatcher {
        MicroBatcher::new()
    }
}

fn slots_of(req: &Request) -> usize {
    match req {
        Request::Node(_) => 1,
        Request::Link(..) => 2, // a link query owns two consecutive rows
    }
}

impl MicroBatcher {
    pub fn new() -> MicroBatcher {
        MicroBatcher {
            pending: Vec::new(),
            next_id: 0,
            deadline: None,
            stats: EngineStats::default(),
        }
    }

    /// An engine whose partial tails flush once a request has waited
    /// `deadline` (zero = every flush behaves like a drain).
    pub fn with_deadline(deadline: Duration) -> MicroBatcher {
        let mut eng = MicroBatcher::new();
        eng.deadline = Some(deadline);
        eng
    }

    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }

    /// Enqueue a request; returns its ticket id (stable across flushes).
    pub fn submit(&mut self, req: Request) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push((id, req, Instant::now()));
        id
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Coalesce every pending request into `b`-wide micro-batches —
    /// padding the tail — execute them across the pool, and return
    /// answers in submit order.
    pub fn drain(&mut self, rt: &Runtime, model: &mut ServingModel) -> Result<Vec<Served>> {
        self.flush_inner(rt, model, true)
    }

    /// Deadline-driven flush: cut and execute every FULL micro-batch; run
    /// the partial tail only if one of its requests has outlived the
    /// engine's deadline, otherwise leave it queued.  Answers come back in
    /// submit order (for the served prefix).
    pub fn flush(&mut self, rt: &Runtime, model: &mut ServingModel) -> Result<Vec<Served>> {
        self.flush_inner(rt, model, false)
    }

    /// How many leading requests to serve, and whether the deadline forced
    /// the tail.  Cutting is at request granularity (a link query's two
    /// rows never split across flushes), so when the tail is withheld the
    /// served prefix is trimmed until it fills whole batches exactly.
    fn cut_point(&self, b: usize, force_tail: bool) -> (usize, bool) {
        let total: usize = self.pending.iter().map(|(_, r, _)| slots_of(r)).sum();
        if total % b == 0 || force_tail {
            return (self.pending.len(), false);
        }
        // trim to the longest request prefix that packs whole batches
        // (a link query straddling a batch boundary shrinks the target)
        let mut target = total / b * b;
        let cut = loop {
            let mut cut = 0usize;
            let mut cum = 0usize;
            for (_, r, _) in &self.pending {
                if cum + slots_of(r) > target {
                    break;
                }
                cum += slots_of(r);
                cut += 1;
            }
            if cum % b == 0 {
                break cut;
            }
            target = cum / b * b;
        };
        // the OLDEST WITHHELD request governs the deadline — pending[cut],
        // not the first request past the full-batch boundary: a straddling
        // link query can push the cut earlier, and the requests it drags
        // along must not outwait their own deadlines (FIFO ⇒ pending[cut]
        // has the earliest one)
        if cut < self.pending.len() {
            if let Some(d) = self.deadline {
                if self.pending[cut].2.elapsed() >= d {
                    return (self.pending.len(), true);
                }
            }
        }
        (cut, false)
    }

    fn flush_inner(
        &mut self,
        rt: &Runtime,
        model: &mut ServingModel,
        force_tail: bool,
    ) -> Result<Vec<Served>> {
        if self.pending.is_empty() {
            return Ok(Vec::new());
        }
        let b = model.batch_size();
        let c = model.out_dim();
        let (cut, deadline_tail) = self.cut_point(b, force_tail);
        if cut == 0 {
            self.stats.last_flush_padded_rows = 0;
            return Ok(Vec::new());
        }
        let taken: Vec<(usize, Request, Instant)> = self.pending.drain(..cut).collect();
        // Expand requests into node slots in arrival order.
        let mut slots: Vec<u32> = Vec::with_capacity(taken.len());
        for (_, req, _) in &taken {
            match *req {
                Request::Node(v) => slots.push(v),
                Request::Link(u, v) => {
                    slots.push(u);
                    slots.push(v);
                }
            }
        }
        let n_batches = (slots.len() + b - 1) / b;
        let padded = n_batches * b - slots.len();
        // padding mirrors infer_nodes: the flush's FIRST queued node pads
        // the tail, so drain == one-shot inference bitwise.  Padding the
        // slot vector itself makes every batch a plain `chunks(b)` slice —
        // no per-batch node vectors.
        slots.resize(n_batches * b, slots[0]);

        // ---- fan out across the session pool ----------------------------
        let mut rows = vec![0.0f32; n_batches * b * c];
        let mut stamps: Vec<Option<Instant>> = vec![None; n_batches];
        {
            let (core, sessions) = model.parts();
            let workers = sessions.len().min(n_batches).max(1);
            // worker w owns batches w, w+T, w+2T, … — deterministic, and
            // each batch's row block is a disjoint &mut slice
            let mut buckets: Vec<Vec<(usize, &[u32], &mut [f32])>> =
                (0..workers).map(|_| Vec::new()).collect();
            for (bi, (nodes, chunk)) in
                slots.chunks(b).zip(rows.chunks_mut(b * c)).enumerate()
            {
                buckets[bi % workers].push((bi, nodes, chunk));
            }
            let mut states: Vec<(&mut crate::serve::model::ServeSession, Vec<_>)> =
                sessions.iter_mut().take(workers).zip(buckets).collect();
            // split the kernel thread budget across the pool: without the
            // cap, every worker's matmul/sketch kernels would each spawn
            // max_threads() scoped threads — N-fold oversubscription.  The
            // budget is a pure scheduling hint (kernels are deterministic
            // across thread counts), so answers are unchanged.
            let inner = (par::max_threads() + workers - 1) / workers;
            let results = par::scope_map(&mut states, |_w, state| {
                par::with_thread_budget(inner, || {
                    let mut done: Vec<(usize, Instant)> =
                        Vec::with_capacity(state.1.len());
                    for (bi, nodes, out) in state.1.drain(..) {
                        core.run_batch(&mut *state.0, nodes, out)?;
                        // completion stamp per micro-batch: a request's
                        // latency ends when the batch holding its LAST slot
                        // returns, not when the whole flush does — otherwise
                        // p50/p99 collapse to the burst wall time
                        done.push((bi, Instant::now()));
                    }
                    Ok::<_, anyhow::Error>(done)
                })
            });
            for r in results {
                for (bi, t) in r? {
                    stamps[bi] = Some(t);
                }
            }
        }
        let spec = &model.core.art.spec;
        rt.record_external(
            n_batches as u64,
            n_batches as u64 * spec.input_bytes(),
            n_batches as u64 * spec.output_bytes(),
        );

        // ---- accounting -------------------------------------------------
        self.stats.batches_run += n_batches as u64;
        self.stats.full_batches += (n_batches - usize::from(padded > 0)) as u64;
        self.stats.padded_rows += padded as u64;
        self.stats.last_flush_padded_rows = padded as u64;
        if padded > 0 {
            if deadline_tail {
                self.stats.tail_deadline_flushes += 1;
            } else if force_tail {
                self.stats.tail_forced_flushes += 1;
            }
        }

        // ---- merge in submit order --------------------------------------
        let mut served = Vec::with_capacity(taken.len());
        let mut s = 0usize;
        for (id, req, t0) in taken {
            let (answer, last_slot) = match req {
                Request::Node(_) => {
                    let a = Answer::Scores(rows[s * c..(s + 1) * c].to_vec());
                    s += 1;
                    (a, s - 1)
                }
                Request::Link(..) => {
                    let eu = &rows[s * c..(s + 1) * c];
                    let ev = &rows[(s + 1) * c..(s + 2) * c];
                    s += 2;
                    (Answer::Link(eu.iter().zip(ev).map(|(x, y)| x * y).sum()), s - 1)
                }
            };
            let done = stamps[last_slot / b].expect("batch executed");
            served.push(Served { id, answer, latency_s: (done - t0).as_secs_f64() });
        }
        Ok(served)
    }
}
