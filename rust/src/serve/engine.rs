//! Serving engine: the [`ServeEngine`] facade owns the `Runtime`, a
//! multi-model router (one [`MicroBatcher`] queue + [`EngineStats`] per
//! model, every model's pool behind one `submit → poll/flush → Served`
//! call shape), and the load-shedding policy; the [`MicroBatcher`] below
//! it coalesces queries into fixed-size batches (the serve artifact's
//! compiled width `b`), fans them out across a model's session pool, and
//! merges per-request results back in submit order.
//!
//! The old split call shape — `MicroBatcher::{drain,flush}(&Runtime,
//! &mut ServingModel)` — survives as `#[deprecated]` shims delegating to
//! the same body the facade uses (`tests/serve_engine.rs` pins shim ==
//! facade bitwise); new code goes through [`ServeEngine`].
//!
//! Two flushing disciplines share one body:
//!
//! - [`MicroBatcher::drain`] — cut everything, padding the tail: mirrors
//!   `VqTrainer::infer_nodes` exactly (FIFO chunks of `b`, tail padded
//!   with the flush's first queued node), so a drained queue answers
//!   bit-identically to one-shot inference over the same query list
//!   (asserted by `tests/serve.rs`);
//! - [`MicroBatcher::flush`] — **deadline-driven**: full `b`-wide batches
//!   are always cut, but a partial tail runs (padded) only once a request
//!   in it has outlived the engine's deadline; otherwise those requests
//!   stay queued for the next flush to coalesce with newer arrivals.
//!   This is what shrinks the padded-row waste under streaming load: the
//!   common case is that the tail keeps filling, and only a deadline
//!   expiry ever pays for padding.  The two tail paths are counted
//!   separately ([`EngineStats::tail_deadline_flushes`] /
//!   [`EngineStats::tail_forced_flushes`]).
//!
//! **Concurrency**: batches of one flush are independent — each is a pure
//! function of the shared [`ServeCore`](crate::serve::model::ServeCore) —
//! so they run across the pool's sessions via `util::par::scope_map`
//! (worker `w` takes batches `w, w+T, w+2T, …`; results land in
//! batch-indexed slots).  Answers are bit-identical to the serial
//! schedule for ANY worker count (`tests/serve_concurrent.rs`); only the
//! latency stamps differ.  Duplicate node ids in one batch are fine: each
//! occurrence owns a row, and rows of the same node are computed from
//! identical inputs.

use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::obs::{self, Registry};
use crate::runtime::Runtime;
use crate::serve::model::ServingModel;
use crate::serve::router::{ModelEntry, Router};
use crate::serve::{Answer, Request};
use crate::util::par;

/// Metric handles the engine records into, resolved ONCE at build time
/// (the flush path never touches the registry's name map).  All-disabled
/// by default — recording then costs one `Option` test and reads no
/// clock, and answers are byte-identical either way (`tests/obs.rs`).
#[derive(Clone, Default)]
pub(crate) struct EngineMetrics {
    /// submit → flush-cut wait per request.
    pub queue_wait: obs::HistHandle,
    /// submit → batch-completion latency per request (the same stamps the
    /// `Served::latency_s` accounting already takes — no extra clock
    /// reads on the data path).
    pub request_latency: obs::HistHandle,
    /// Batch-assembly / session-exec split, recorded inside the pool.
    pub stages: obs::ServeStages,
    pub admit: obs::HistHandle,
    pub evict: obs::HistHandle,
    pub drift_check: obs::HistHandle,
    pub refresh: obs::HistHandle,
    pub requests: obs::CounterHandle,
    pub served: obs::CounterHandle,
    pub shed: obs::CounterHandle,
    pub drift_tv: obs::GaugeHandle,
}

impl EngineMetrics {
    fn wire(reg: Option<&Registry>) -> EngineMetrics {
        let Some(r) = reg else { return EngineMetrics::default() };
        EngineMetrics {
            queue_wait: r.hist("serve_queue_wait"),
            request_latency: r.hist("serve_request_latency"),
            stages: obs::ServeStages {
                assembly: r.hist("serve_batch_assembly"),
                exec: r.hist("serve_session_exec"),
            },
            admit: r.hist("serve_admit"),
            evict: r.hist("serve_evict"),
            drift_check: r.hist("serve_drift_check"),
            refresh: r.hist("serve_refresh"),
            requests: r.counter("serve_requests"),
            served: r.counter("serve_served"),
            shed: r.counter("serve_shed"),
            drift_tv: r.gauge("serve_drift_tv"),
        }
    }
}

/// Publish one model's residency + VQ-health gauges (admission, eviction
/// and refresh move them; the scrape reads last-written values).
fn publish_model_gauges(reg: &Registry, e: &ModelEntry) {
    let cache = e.model.cache();
    reg.gauge("serve_resident_admitted").set(cache.admitted.len() as f64);
    reg.gauge("serve_cache_bytes").set(cache.memory_bytes() as f64);
    for (l, lc) in cache.layers.iter().enumerate() {
        // serving populations are integer counts: < 0.5 means empty
        let (pp, dead) = obs::codebook_health(lc.codeword_populations(), 0.5);
        reg.gauge(&format!("vq_codebook_perplexity_l{l}")).set(pp);
        reg.gauge(&format!("vq_dead_codes_l{l}")).set(dead as f64);
    }
}

/// A completed request: the answer plus its queue-to-completion latency.
pub struct Served {
    pub id: usize,
    pub answer: Answer,
    pub latency_s: f64,
}

/// Lifetime + per-flush accounting of one model's queue
/// (capacity-planning signals; the CLI and `bench_guard` read these).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct EngineStats {
    /// Micro-batches executed over the engine's lifetime.
    pub batches_run: u64,
    /// Batches that ran completely full (no padding).
    pub full_batches: u64,
    /// Padding rows wasted on partial tails, lifetime total.
    pub padded_rows: u64,
    /// Padding rows of the MOST RECENT flush (per-drain signal).
    pub last_flush_padded_rows: u64,
    /// Partial tails flushed because a request's deadline expired.
    pub tail_deadline_flushes: u64,
    /// Partial tails flushed because the caller forced a full drain.
    pub tail_forced_flushes: u64,
    /// Admitted nodes evicted by the retention policy (TTL + LRU cap),
    /// lifetime total.
    pub evictions: u64,
    /// Drift-alert excursions: +1 each time the model's codebook-drift
    /// metric crosses the engine threshold from below (edge-triggered, so
    /// a sustained excursion counts once).
    pub drift_alerts: u64,
}

pub struct MicroBatcher {
    pending: Vec<(usize, Request, Instant)>,
    next_id: usize,
    /// Tail-flush deadline: a partial tail runs once its oldest request is
    /// older than this.  `None` means tails only run on `drain`.
    deadline: Option<Duration>,
    pub stats: EngineStats,
}

impl Default for MicroBatcher {
    fn default() -> MicroBatcher {
        MicroBatcher::new()
    }
}

fn slots_of(req: &Request) -> usize {
    match req {
        Request::Node(_) => 1,
        Request::Link(..) => 2, // a link query owns two consecutive rows
    }
}

impl MicroBatcher {
    pub fn new() -> MicroBatcher {
        MicroBatcher {
            pending: Vec::new(),
            next_id: 0,
            deadline: None,
            stats: EngineStats::default(),
        }
    }

    /// An engine whose partial tails flush once a request has waited
    /// `deadline` (zero = every flush behaves like a drain).
    pub fn with_deadline(deadline: Duration) -> MicroBatcher {
        let mut eng = MicroBatcher::new();
        eng.deadline = Some(deadline);
        eng
    }

    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }

    /// Enqueue a request; returns its ticket id (stable across flushes).
    pub fn submit(&mut self, req: Request) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push((id, req, Instant::now()));
        id
    }

    /// Enqueue under a caller-assigned ticket id — the facade's path: the
    /// engine hands out ONE id sequence across every model's queue, so
    /// merged results sort back into global submit order.
    pub(crate) fn submit_with_id(&mut self, id: usize, req: Request) {
        self.next_id = self.next_id.max(id + 1);
        self.pending.push((id, req, Instant::now()));
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Queued node slots (a link query holds two) — the queue-depth input
    /// to the facade's shedding policy.
    pub(crate) fn pending_slots(&self) -> usize {
        self.pending.iter().map(|(_, r, _)| slots_of(r)).sum()
    }

    /// Coalesce every pending request into `b`-wide micro-batches —
    /// padding the tail — execute them across the pool, and return
    /// answers in submit order.
    #[deprecated(note = "go through ServeEngine::drain — this shim delegates to the same body")]
    pub fn drain(&mut self, rt: &Runtime, model: &mut ServingModel) -> Result<Vec<Served>> {
        self.flush_with(rt, model, true, &EngineMetrics::default())
    }

    /// Deadline-driven flush: cut and execute every FULL micro-batch; run
    /// the partial tail only if one of its requests has outlived the
    /// engine's deadline, otherwise leave it queued.  Answers come back in
    /// submit order (for the served prefix).
    #[deprecated(note = "go through ServeEngine::poll — this shim delegates to the same body")]
    pub fn flush(&mut self, rt: &Runtime, model: &mut ServingModel) -> Result<Vec<Served>> {
        self.flush_with(rt, model, false, &EngineMetrics::default())
    }

    /// How many leading requests to serve, and whether the deadline forced
    /// the tail.  Cutting is at request granularity (a link query's two
    /// rows never split across flushes), so when the tail is withheld the
    /// served prefix is trimmed until it fills whole batches exactly.
    fn cut_point(&self, b: usize, force_tail: bool) -> (usize, bool) {
        let total: usize = self.pending.iter().map(|(_, r, _)| slots_of(r)).sum();
        if total % b == 0 || force_tail {
            return (self.pending.len(), false);
        }
        // trim to the longest request prefix that packs whole batches
        // (a link query straddling a batch boundary shrinks the target)
        let mut target = total / b * b;
        let cut = loop {
            let mut cut = 0usize;
            let mut cum = 0usize;
            for (_, r, _) in &self.pending {
                if cum + slots_of(r) > target {
                    break;
                }
                cum += slots_of(r);
                cut += 1;
            }
            if cum % b == 0 {
                break cut;
            }
            target = cum / b * b;
        };
        // the OLDEST WITHHELD request governs the deadline — pending[cut],
        // not the first request past the full-batch boundary: a straddling
        // link query can push the cut earlier, and the requests it drags
        // along must not outwait their own deadlines (FIFO ⇒ pending[cut]
        // has the earliest one)
        if cut < self.pending.len() {
            if let Some(d) = self.deadline {
                if self.pending[cut].2.elapsed() >= d {
                    return (self.pending.len(), true);
                }
            }
        }
        (cut, false)
    }

    pub(crate) fn flush_with(
        &mut self,
        rt: &Runtime,
        model: &mut ServingModel,
        force_tail: bool,
        metrics: &EngineMetrics,
    ) -> Result<Vec<Served>> {
        if self.pending.is_empty() {
            return Ok(Vec::new());
        }
        let b = model.batch_size();
        let c = model.out_dim();
        let (cut, deadline_tail) = self.cut_point(b, force_tail);
        if cut == 0 {
            self.stats.last_flush_padded_rows = 0;
            return Ok(Vec::new());
        }
        let taken: Vec<(usize, Request, Instant)> = self.pending.drain(..cut).collect();
        // queue-wait per request, off the submit stamps latency accounting
        // already takes — one clock read per flush, none when disabled
        if metrics.queue_wait.enabled() {
            let now = Instant::now();
            for (_, _, t0) in &taken {
                metrics.queue_wait.record_duration(now.saturating_duration_since(*t0));
            }
        }
        // Expand requests into node slots in arrival order.
        let mut slots: Vec<u32> = Vec::with_capacity(taken.len());
        for (_, req, _) in &taken {
            match *req {
                Request::Node(v) => slots.push(v),
                Request::Link(u, v) => {
                    slots.push(u);
                    slots.push(v);
                }
            }
        }
        let n_batches = (slots.len() + b - 1) / b;
        let n_real = slots.len(); // before padding — maintenance hooks must not see pad rows
        let padded = n_batches * b - slots.len();
        // padding mirrors infer_nodes: the flush's FIRST queued node pads
        // the tail, so drain == one-shot inference bitwise.  Padding the
        // slot vector itself makes every batch a plain `chunks(b)` slice —
        // no per-batch node vectors.
        slots.resize(n_batches * b, slots[0]);

        // ---- fan out across the session pool ----------------------------
        let mut rows = vec![0.0f32; n_batches * b * c];
        let mut stamps: Vec<Option<Instant>> = vec![None; n_batches];
        {
            let (core, sessions) = model.parts();
            let workers = sessions.len().min(n_batches).max(1);
            // worker w owns batches w, w+T, w+2T, … — deterministic, and
            // each batch's row block is a disjoint &mut slice
            let mut buckets: Vec<Vec<(usize, &[u32], &mut [f32])>> =
                (0..workers).map(|_| Vec::new()).collect();
            for (bi, (nodes, chunk)) in
                slots.chunks(b).zip(rows.chunks_mut(b * c)).enumerate()
            {
                buckets[bi % workers].push((bi, nodes, chunk));
            }
            let mut states: Vec<(&mut crate::serve::model::ServeSession, Vec<_>)> =
                sessions.iter_mut().take(workers).zip(buckets).collect();
            // split the kernel thread budget across the pool: without the
            // cap, every worker's matmul/sketch kernels would each spawn
            // max_threads() scoped threads — N-fold oversubscription.  The
            // budget is a pure scheduling hint (kernels are deterministic
            // across thread counts), so answers are unchanged.
            let inner = (par::max_threads() + workers - 1) / workers;
            let results = par::scope_map(&mut states, |_w, state| {
                par::with_thread_budget(inner, || {
                    // prep/exec overlap inside each worker: batch i+1's
                    // slot rewrite + gather runs while batch i executes
                    // (`join2` spawns one thread beyond the kernel budget,
                    // same accepted pattern as the trainers).  Answers and
                    // per-batch completion stamps are byte-identical to the
                    // serial drain — a request's latency still ends when
                    // the batch holding its LAST slot finishes executing.
                    let batches = std::mem::take(&mut state.1);
                    core.run_batches_pipelined(&mut *state.0, batches, &metrics.stages)
                })
            });
            for r in results {
                for (bi, t) in r? {
                    stamps[bi] = Some(t);
                }
            }
        }
        let spec = &model.core.art.spec;
        rt.record_external(
            n_batches as u64,
            n_batches as u64 * spec.input_bytes(),
            n_batches as u64 * spec.output_bytes(),
        );
        // maintenance hook: touch the served admitted nodes' LRU stamps and
        // feed the drift observer (histograms/stamps only — answers already
        // computed above are never affected)
        model.note_served(&slots[..n_real]);

        // ---- accounting -------------------------------------------------
        self.stats.batches_run += n_batches as u64;
        self.stats.full_batches += (n_batches - usize::from(padded > 0)) as u64;
        self.stats.padded_rows += padded as u64;
        self.stats.last_flush_padded_rows = padded as u64;
        if padded > 0 {
            if deadline_tail {
                self.stats.tail_deadline_flushes += 1;
            } else if force_tail {
                self.stats.tail_forced_flushes += 1;
            }
        }

        // ---- merge in submit order --------------------------------------
        let mut served = Vec::with_capacity(taken.len());
        let mut s = 0usize;
        for (id, req, t0) in taken {
            let (answer, last_slot) = match req {
                Request::Node(_) => {
                    let a = Answer::Scores(rows[s * c..(s + 1) * c].to_vec());
                    s += 1;
                    (a, s - 1)
                }
                Request::Link(..) => {
                    let eu = &rows[s * c..(s + 1) * c];
                    let ev = &rows[(s + 1) * c..(s + 2) * c];
                    s += 2;
                    (Answer::Link(eu.iter().zip(ev).map(|(x, y)| x * y).sum()), s - 1)
                }
            };
            let done = stamps[last_slot / b].expect("batch executed");
            let latency_s = (done - t0).as_secs_f64();
            metrics.request_latency.record_ns((latency_s * 1e9) as u64);
            served.push(Served { id, answer, latency_s });
        }
        metrics.served.add(served.len() as u64);
        Ok(served)
    }
}

// ======================== ServeEngine facade ============================

/// Typed serving-facade errors: builder misconfiguration and per-request
/// admission-control refusals.  The per-request variants (`UnknownModel`,
/// `InvalidNode`, `Shed`) map 1:1 onto wire error frames; builder
/// variants surface at construction time, never as panics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The builder was given no models.
    NoModels,
    /// Two models were registered under one routing name.
    DuplicateModel(String),
    /// `.threads(0)` — the pool needs at least one worker.
    ZeroWorkers,
    /// The per-model queue cap cannot hold even one link query (2 slots).
    QueueCapTooSmall(usize),
    /// `.max_admitted(c)` cannot retain even one admitted node.
    AdmitCapTooSmall(usize),
    /// `.admit_ttl(0)` — every admitted node would expire instantly.
    ZeroAdmitTtl,
    /// `.drift_threshold(t)` outside (0, 1] — TV distance lives in [0, 1],
    /// and a threshold of 0 would alert on any traffic at all.
    BadDriftThreshold,
    /// `.refresh_gamma(g)` outside [0, 1) — 1 would make refresh a no-op.
    BadRefreshGamma,
    /// `submit` named a model the router does not carry.
    UnknownModel(String),
    /// A node id the model cannot serve: outside the frozen range and not
    /// a RESIDENT admitted id (evicted ids land here too).
    InvalidNode { model: String, id: u32, total: usize },
    /// Backpressure: the model's queue is at capacity, so the request is
    /// load-shed instead of letting the tail latency grow unboundedly.
    Shed { model: String, pending_slots: usize, cap: usize },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::NoModels => write!(f, "serve engine: no models configured"),
            ServeError::DuplicateModel(m) => {
                write!(f, "serve engine: duplicate model name '{m}'")
            }
            ServeError::ZeroWorkers => {
                write!(f, "serve engine: worker pool width must be at least 1")
            }
            ServeError::QueueCapTooSmall(c) => write!(
                f,
                "serve engine: queue cap {c} cannot hold a link query (needs at least 2 slots)"
            ),
            ServeError::AdmitCapTooSmall(c) => write!(
                f,
                "serve engine: admitted-node cap {c} cannot retain a single admission"
            ),
            ServeError::ZeroAdmitTtl => write!(
                f,
                "serve engine: a zero admit TTL would expire every admission instantly"
            ),
            ServeError::BadDriftThreshold => write!(
                f,
                "serve engine: drift threshold must be in (0, 1] (TV distance)"
            ),
            ServeError::BadRefreshGamma => write!(
                f,
                "serve engine: refresh gamma must be in [0, 1) (1 keeps codewords frozen)"
            ),
            ServeError::UnknownModel(m) => write!(f, "serve engine: unknown model '{m}'"),
            ServeError::InvalidNode { model, id, total } => write!(
                f,
                "serve engine: node id {id} is not servable by model '{model}' \
                 ({total} resident ids; evicted ids are refused)"
            ),
            ServeError::Shed { model, pending_slots, cap } => write!(
                f,
                "serve engine: model '{model}' shed the request \
                 ({pending_slots}/{cap} queued slots)"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Validated construction shared by single- and multi-model setups:
/// `.model(name, m)` × N, `.threads(n)`, `.deadline(d)`, `.queue_cap(c)`,
/// then [`ServeEngineBuilder::build`].  Misconfiguration is a typed
/// [`ServeError`], not a panic.
pub struct ServeEngineBuilder {
    models: Vec<(String, ServingModel)>,
    threads: usize,
    shards: usize,
    deadline: Option<Duration>,
    queue_cap: Option<usize>,
    max_admitted: Option<usize>,
    ttl: Option<Duration>,
    drift_threshold: f32,
    refresh_gamma: f32,
    metrics: Option<Arc<Registry>>,
}

impl ServeEngineBuilder {
    /// Register a model under a routing name (FIFO registration order is
    /// the router's iteration order).
    pub fn model(mut self, name: impl Into<String>, model: ServingModel) -> Self {
        self.models.push((name.into(), model));
        self
    }

    /// Worker-pool width applied to every model (default 1).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// In-process shard count for every model (default 1 = unsharded).
    /// `s > 1` partitions each model's maintenance paths across `s` shard
    /// workers ([`ServingModel::set_shards`]) and widens the session pool
    /// to at least `s` so each shard worker drives its own session.
    /// Answers and maintenance state stay byte-identical at any `s` —
    /// this knob only changes who computes what (see the `shard` module).
    pub fn shards(mut self, s: usize) -> Self {
        self.shards = s.max(1);
        self
    }

    /// Tail-flush deadline for every model's queue (see
    /// [`MicroBatcher::flush`]).
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Bounded per-model queue: once a model holds this many node slots,
    /// further submits are load-shed with [`ServeError::Shed`].
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = Some(cap);
        self
    }

    /// Retention cap on admitted nodes per model: past it, the
    /// least-recently-served admitted nodes are evicted on the admission
    /// path (or via [`ServeEngine::maintain`]).  Unset = unbounded (the
    /// pre-maintenance behavior).
    pub fn max_admitted(mut self, cap: usize) -> Self {
        self.max_admitted = Some(cap);
        self
    }

    /// Time-to-live for admitted nodes: one untouched for this long is
    /// evicted at the next retention pass.  Touches are admissions and
    /// being served in a flush.  Unset = no expiry.
    pub fn admit_ttl(mut self, ttl: Duration) -> Self {
        self.ttl = Some(ttl);
        self
    }

    /// Codebook-drift alert threshold in TV distance (default 0.5): at or
    /// above it, `EngineStats::drift_alerts` counts an excursion and
    /// [`ServeEngine::refresh`] is willing to re-fit.
    pub fn drift_threshold(mut self, t: f32) -> Self {
        self.drift_threshold = t;
        self
    }

    /// EMA retention factor for [`ServeEngine::refresh`] (default 0.8):
    /// a re-fitted codeword keeps `gamma` of its old position.
    pub fn refresh_gamma(mut self, g: f32) -> Self {
        self.refresh_gamma = g;
        self
    }

    /// Attach a metrics registry: the engine resolves its handles once
    /// here and records queue-wait/assembly/exec/latency histograms,
    /// request counters, and maintenance timings + VQ-health gauges into
    /// it.  Without this call the engine runs metrics-free (no clock
    /// reads, no atomics) — answers are byte-identical either way.
    pub fn metrics(mut self, reg: Arc<Registry>) -> Self {
        self.metrics = Some(reg);
        self
    }

    pub fn build(self, rt: Runtime) -> Result<ServeEngine, ServeError> {
        if self.models.is_empty() {
            return Err(ServeError::NoModels);
        }
        if self.threads == 0 {
            return Err(ServeError::ZeroWorkers);
        }
        if let Some(cap) = self.queue_cap {
            if cap < 2 {
                return Err(ServeError::QueueCapTooSmall(cap));
            }
        }
        if let Some(cap) = self.max_admitted {
            if cap < 1 {
                return Err(ServeError::AdmitCapTooSmall(cap));
            }
        }
        if self.ttl == Some(Duration::ZERO) {
            return Err(ServeError::ZeroAdmitTtl);
        }
        if !(self.drift_threshold > 0.0 && self.drift_threshold <= 1.0) {
            return Err(ServeError::BadDriftThreshold);
        }
        if !(0.0..1.0).contains(&self.refresh_gamma) {
            return Err(ServeError::BadRefreshGamma);
        }
        let mut entries: Vec<ModelEntry> = Vec::with_capacity(self.models.len());
        for (name, mut model) in self.models {
            if entries.iter().any(|e| e.name == name) {
                return Err(ServeError::DuplicateModel(name));
            }
            model.set_threads(self.threads.max(self.shards));
            model.set_shards(self.shards);
            let mut queue = MicroBatcher::new();
            queue.set_deadline(self.deadline);
            entries.push(ModelEntry { name, model, queue, drift_high: false });
        }
        let metrics = EngineMetrics::wire(self.metrics.as_deref());
        if let Some(reg) = self.metrics.as_deref() {
            for e in &entries {
                publish_model_gauges(reg, e);
            }
        }
        Ok(ServeEngine {
            rt,
            router: Router::new(entries),
            next_ticket: 0,
            threads: self.threads.max(self.shards),
            shards: self.shards,
            deadline: self.deadline,
            queue_cap: self.queue_cap,
            max_admitted: self.max_admitted,
            ttl: self.ttl,
            drift_threshold: self.drift_threshold,
            refresh_gamma: self.refresh_gamma,
            registry: self.metrics,
            metrics,
        })
    }
}

/// THE serving entry point (see module docs): owns the `Runtime`, the
/// multi-model [`Router`], and one bounded queue + [`EngineStats`] per
/// model.  Every caller — CLI file path, socket server, tests, benches —
/// uses the same shape: `submit(model, req) → poll()/drain() → Served`,
/// with results merged across models into global submit order (one
/// engine-wide ticket sequence).
pub struct ServeEngine {
    rt: Runtime,
    router: Router,
    next_ticket: usize,
    threads: usize,
    shards: usize,
    deadline: Option<Duration>,
    queue_cap: Option<usize>,
    max_admitted: Option<usize>,
    ttl: Option<Duration>,
    drift_threshold: f32,
    refresh_gamma: f32,
    registry: Option<Arc<Registry>>,
    metrics: EngineMetrics,
}

impl ServeEngine {
    pub fn builder() -> ServeEngineBuilder {
        ServeEngineBuilder {
            models: Vec::new(),
            threads: 1,
            shards: 1,
            deadline: None,
            queue_cap: None,
            max_admitted: None,
            ttl: None,
            drift_threshold: 0.5,
            refresh_gamma: 0.8,
            metrics: None,
        }
    }

    /// The registry attached at build time, if any — the server renders
    /// STATS scrapes from it, the CLI prints `--metrics-every` lines.
    pub fn registry(&self) -> Option<&Arc<Registry>> {
        self.registry.as_ref()
    }

    /// Admission control + enqueue; returns the request's global ticket
    /// id (results sort by it).  Typed refusals — unknown model,
    /// out-of-range node id (request-controlled data must fail alone, not
    /// poison a whole flush), and [`ServeError::Shed`] once the model's
    /// queue is at capacity.
    pub fn submit(&mut self, model: &str, req: Request) -> Result<usize, ServeError> {
        let entry = self
            .router
            .get_mut(model)
            .ok_or_else(|| ServeError::UnknownModel(model.to_string()))?;
        let total = entry.model.total_nodes();
        let servable = |v: u32| entry.model.cache().admitted.is_servable(v);
        let bad = match req {
            Request::Node(v) => (!servable(v)).then_some(v),
            Request::Link(u, v) => [u, v].into_iter().find(|&x| !servable(x)),
        };
        if let Some(id) = bad {
            return Err(ServeError::InvalidNode { model: model.to_string(), id, total });
        }
        if let Some(cap) = self.queue_cap {
            let depth = entry.queue.pending_slots();
            if depth + slots_of(&req) > cap {
                self.metrics.shed.add(1);
                return Err(ServeError::Shed {
                    model: model.to_string(),
                    pending_slots: depth,
                    cap,
                });
            }
        }
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        entry.queue.submit_with_id(ticket, req);
        self.metrics.requests.add(1);
        Ok(ticket)
    }

    /// Deadline-driven flush across every model: full batches always cut,
    /// partial tails only once their oldest request outlives the deadline.
    pub fn poll(&mut self) -> Result<Vec<Served>> {
        self.flush_all(false)
    }

    /// Force-flush everything (padding partial tails) across every model.
    pub fn drain(&mut self) -> Result<Vec<Served>> {
        self.flush_all(true)
    }

    fn flush_all(&mut self, force_tail: bool) -> Result<Vec<Served>> {
        let rt = &self.rt;
        let threshold = self.drift_threshold;
        let metrics = &self.metrics;
        let mut served: Vec<Served> = Vec::new();
        let mut max_tv = 0.0f32;
        for e in self.router.entries_mut() {
            served.extend(e.queue.flush_with(rt, &mut e.model, force_tail, metrics)?);
            // edge-triggered drift alert: the flush just fed the observer,
            // so this is the freshest the metric gets
            let tv = e.model.max_drift();
            max_tv = max_tv.max(tv);
            let high = tv >= threshold;
            if high && !e.drift_high {
                e.queue.stats.drift_alerts += 1;
            }
            e.drift_high = high;
        }
        metrics.drift_tv.set(max_tv as f64);
        // one engine-wide ticket sequence ⇒ sorting recovers submit order
        served.sort_by_key(|s| s.id);
        Ok(served)
    }

    /// Requests queued across every model.
    pub fn pending(&self) -> usize {
        self.router.entries().iter().map(|e| e.queue.pending_len()).sum()
    }

    /// Per-model queue statistics.
    pub fn stats(&self, model: &str) -> Option<&EngineStats> {
        self.router.get(model).map(|e| &e.queue.stats)
    }

    pub fn model(&self, model: &str) -> Option<&ServingModel> {
        self.router.get(model).map(|e| &e.model)
    }

    /// Mutable model access (the admission queue verbs, introspection).
    pub fn model_mut(&mut self, model: &str) -> Option<&mut ServingModel> {
        self.router.get_mut(model).map(|e| &mut e.model)
    }

    /// Routing names in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.router.entries().iter().map(|e| e.name.as_str()).collect()
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.rt
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// In-process shard count applied to every model (1 = unsharded).
    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn deadline(&self) -> Option<Duration> {
        self.deadline
    }

    pub fn queue_cap(&self) -> Option<usize> {
        self.queue_cap
    }

    pub fn max_admitted(&self) -> Option<usize> {
        self.max_admitted
    }

    pub fn admit_ttl(&self) -> Option<Duration> {
        self.ttl
    }

    pub fn drift_threshold(&self) -> f32 {
        self.drift_threshold
    }

    pub fn refresh_gamma(&self) -> f32 {
        self.refresh_gamma
    }

    /// Widen/narrow every model's worker pool.
    pub fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
        for e in self.router.entries_mut() {
            e.model.set_threads(n);
        }
    }

    /// Hot-add a model behind a new routing name (e.g. a reloaded
    /// artifact served next to the original).
    pub fn add_model(
        &mut self,
        name: impl Into<String>,
        mut model: ServingModel,
    ) -> Result<(), ServeError> {
        let name = name.into();
        if self.router.get(&name).is_some() {
            return Err(ServeError::DuplicateModel(name));
        }
        model.set_threads(self.threads);
        model.set_shards(self.shards);
        let mut queue = MicroBatcher::new();
        queue.set_deadline(self.deadline);
        self.router.push(ModelEntry { name, model, queue, drift_high: false });
        if let Some(reg) = self.registry.as_deref() {
            let e = self.router.entries().last().expect("just pushed");
            publish_model_gauges(reg, e);
        }
        Ok(())
    }

    /// Admit one unseen node to `model` NOW (the single-writer path; see
    /// `ServingModel::admit`), then run the retention policy — admission
    /// is what grows the tables, so it pays for its own trimming.
    pub fn admit(&mut self, model: &str, features: &[f32], neighbors: &[u32]) -> Result<u32> {
        let (max_admitted, ttl) = (self.max_admitted, self.ttl);
        let rt = &self.rt;
        let metrics = &self.metrics;
        let e = self
            .router
            .get_mut(model)
            .with_context(|| format!("admit: unknown model '{model}'"))?;
        let stage = metrics.admit.stage();
        let id = e.model.admit(rt, features, neighbors)?;
        stage.stop();
        Self::retain_entry(e, max_admitted, ttl, metrics);
        if let Some(reg) = self.registry.as_deref() {
            publish_model_gauges(reg, self.router.get(model).expect("present"));
        }
        Ok(id)
    }

    /// Apply `model`'s queued admissions FIFO (see
    /// `ServingModel::admit_queued`), then run the retention policy.
    pub fn admit_queued(&mut self, model: &str) -> Result<Vec<u32>> {
        let (max_admitted, ttl) = (self.max_admitted, self.ttl);
        let rt = &self.rt;
        let metrics = &self.metrics;
        let e = self
            .router
            .get_mut(model)
            .with_context(|| format!("admit_queued: unknown model '{model}'"))?;
        let stage = metrics.admit.stage();
        let ids = e.model.admit_queued(rt)?;
        stage.stop();
        Self::retain_entry(e, max_admitted, ttl, metrics);
        if let Some(reg) = self.registry.as_deref() {
            publish_model_gauges(reg, self.router.get(model).expect("present"));
        }
        Ok(ids)
    }

    /// One retention pass on `model` (the admission paths run this
    /// implicitly; long-running hosts can also call it on a timer).
    /// Returns how many admitted nodes were evicted.
    pub fn maintain(&mut self, model: &str) -> Result<usize> {
        let (max_admitted, ttl) = (self.max_admitted, self.ttl);
        let metrics = &self.metrics;
        let e = self
            .router
            .get_mut(model)
            .with_context(|| format!("maintain: unknown model '{model}'"))?;
        let n = Self::retain_entry(e, max_admitted, ttl, metrics);
        if let Some(reg) = self.registry.as_deref() {
            publish_model_gauges(reg, self.router.get(model).expect("present"));
        }
        Ok(n)
    }

    /// Evict `model`'s TTL-expired admitted nodes plus the LRU overflow
    /// past `max_admitted`.  Skipped while admissions are queued: queued
    /// requests hold promised ids citing current residents, and the queue
    /// is drained by `admit_queued` which retains afterwards anyway.
    fn retain_entry(
        e: &mut ModelEntry,
        max_admitted: Option<usize>,
        ttl: Option<Duration>,
        metrics: &EngineMetrics,
    ) -> usize {
        if (max_admitted.is_none() && ttl.is_none()) || e.model.queued_admissions() > 0 {
            return 0;
        }
        let victims = e.model.retention_victims(max_admitted, ttl);
        if victims.is_empty() {
            return 0;
        }
        let stage = metrics.evict.stage();
        let n = e.model.evict(&victims);
        stage.stop();
        e.queue.stats.evictions += n as u64;
        n
    }

    /// Codebook-drift metric of one model (max over layers, TV distance).
    pub fn drift(&self, model: &str) -> Option<f32> {
        let stage = self.metrics.drift_check.stage();
        let tv = self.router.get(model).map(|e| e.model.max_drift());
        stage.stop();
        if let Some(tv) = tv {
            self.metrics.drift_tv.set(tv as f64);
        }
        tv
    }

    /// Drift-gated online EMA refresh (single-writer path): re-fit
    /// `model`'s codewords from its retained recent traffic IF its drift
    /// metric is at/above the engine threshold; below it this is a no-op
    /// (healthy codebooks must not wander).  Returns whether codewords
    /// changed.  See `ServingModel::refresh` for the staleness caveat.
    pub fn refresh(&mut self, model: &str) -> Result<bool> {
        let (threshold, gamma) = (self.drift_threshold, self.refresh_gamma);
        let metrics = &self.metrics;
        let e = self
            .router
            .get_mut(model)
            .with_context(|| format!("refresh: unknown model '{model}'"))?;
        if e.model.max_drift() < threshold {
            return Ok(false);
        }
        let stage = metrics.refresh.stage();
        let changed = e.model.refresh(gamma)?;
        stage.stop();
        if let Some(reg) = self.registry.as_deref() {
            publish_model_gauges(reg, self.router.get(model).expect("present"));
        }
        Ok(changed)
    }

    /// Disassemble the facade — rebuild with a different deadline/cap
    /// without re-freezing the models (bench reconfiguration).
    #[allow(clippy::type_complexity)]
    pub fn into_parts(self) -> (Runtime, Vec<(String, ServingModel)>) {
        (self.rt, self.router.into_models())
    }
}
