//! Micro-batching engine: a request queue that coalesces incoming queries
//! into fixed-size batches (the serve artifact's compiled width `b`),
//! pads the tail, runs the forward-only path, and scatters per-request
//! results back in submit order.
//!
//! Batch composition mirrors `VqTrainer::infer_nodes` exactly — FIFO
//! chunks of `b`, the tail padded with the first queued node — so a
//! drained queue answers bit-identically to one-shot inference over the
//! same query list (asserted by `tests/serve.rs`).  Duplicate node ids in
//! one batch are fine: each occurrence owns a row, and rows of the same
//! node are computed from identical inputs.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::Runtime;
use crate::serve::model::ServingModel;
use crate::serve::{Answer, Request};

/// A completed request: the answer plus its queue-to-completion latency.
pub struct Served {
    pub id: usize,
    pub answer: Answer,
    pub latency_s: f64,
}

#[derive(Default)]
pub struct MicroBatcher {
    pending: Vec<(usize, Request, Instant)>,
    next_id: usize,
    /// Micro-batches executed over the engine's lifetime.
    pub batches_run: u64,
    /// Padding rows wasted on partial tails (capacity-planning signal).
    pub padded_rows: u64,
}

impl MicroBatcher {
    pub fn new() -> MicroBatcher {
        MicroBatcher::default()
    }

    /// Enqueue a request; returns its ticket id (stable across drains).
    pub fn submit(&mut self, req: Request) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push((id, req, Instant::now()));
        id
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Coalesce every pending request into `b`-wide micro-batches, execute
    /// them, and return answers in submit order.
    pub fn drain(&mut self, rt: &mut Runtime, model: &mut ServingModel) -> Result<Vec<Served>> {
        let pending = std::mem::take(&mut self.pending);
        if pending.is_empty() {
            return Ok(Vec::new());
        }
        // Expand requests into node slots in arrival order (a link query
        // owns two consecutive rows).
        let mut slots: Vec<u32> = Vec::with_capacity(pending.len());
        for (_, req, _) in &pending {
            match *req {
                Request::Node(v) => slots.push(v),
                Request::Link(u, v) => {
                    slots.push(u);
                    slots.push(v);
                }
            }
        }
        let b = model.batch_size();
        let c = model.out_dim();
        let pad = slots[0]; // infer_nodes pads with nodes[0]; mirror it
        let mut rows = vec![0.0f32; slots.len() * c];
        // completion stamp per micro-batch: a request's latency ends when
        // the batch holding its LAST slot returns, not when the whole
        // drain does — otherwise p50/p99 collapse to the burst wall time
        let mut batch_done: Vec<Instant> = Vec::with_capacity(slots.len() / b + 1);
        let mut batch: Vec<u32> = Vec::with_capacity(b);
        let mut i = 0;
        while i < slots.len() {
            let end = (i + b).min(slots.len());
            batch.clear();
            batch.extend_from_slice(&slots[i..end]);
            let real = end - i;
            while batch.len() < b {
                batch.push(pad);
            }
            // forward_batch rewrites the serving session in place and hands
            // back a view of its output buffer — no per-batch copies beyond
            // the result scatter below
            let out = model.forward_batch(rt, &batch)?;
            rows[i * c..end * c].copy_from_slice(&out[..real * c]);
            batch_done.push(Instant::now());
            self.batches_run += 1;
            self.padded_rows += (b - real) as u64;
            i = end;
        }
        let mut served = Vec::with_capacity(pending.len());
        let mut s = 0usize;
        for (id, req, t0) in pending {
            let (answer, last_slot) = match req {
                Request::Node(_) => {
                    let a = Answer::Scores(rows[s * c..(s + 1) * c].to_vec());
                    s += 1;
                    (a, s - 1)
                }
                Request::Link(..) => {
                    let eu = &rows[s * c..(s + 1) * c];
                    let ev = &rows[(s + 1) * c..(s + 2) * c];
                    s += 2;
                    (Answer::Link(eu.iter().zip(ev).map(|(x, y)| x * y).sum()), s - 1)
                }
            };
            let done = batch_done[last_slot / b];
            served.push(Served { id, answer, latency_s: (done - t0).as_secs_f64() });
        }
        Ok(served)
    }
}
