//! Synthetic dataset generators — stand-ins for the paper's five benchmarks
//! (DESIGN.md §3 table).  Each generator plants community structure that
//! labels and features both derive from, so message passing genuinely helps
//! (verified by tests::message_passing_signal_exists).

use crate::graph::Graph;
use crate::runtime::manifest::DatasetCfg;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

#[derive(Debug)]
pub struct Dataset {
    pub cfg: DatasetCfg,
    pub graph: Graph,
    /// Row-major (n, f_in_pad) — already zero-padded to the artifact dim.
    pub features: Vec<f32>,
    /// Single-label targets (empty for multilabel / link tasks).
    pub labels: Vec<i32>,
    /// Multilabel targets, row-major (n, n_classes) (empty otherwise).
    pub labels_multi: Vec<f32>,
    pub split: Vec<Split>,
    pub community: Vec<u32>,
    /// Link task: held-out positive edges.
    pub val_pos: Vec<(u32, u32)>,
    pub test_pos: Vec<(u32, u32)>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.graph.n
    }

    pub fn feature_row(&self, v: usize) -> &[f32] {
        let f = self.cfg.f_in_pad;
        &self.features[v * f..(v + 1) * f]
    }

    pub fn nodes_in_split(&self, s: Split) -> Vec<u32> {
        (0..self.n() as u32).filter(|&v| self.split[v as usize] == s).collect()
    }

    /// Generate deterministically from the manifest config.
    pub fn generate(cfg: &DatasetCfg, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0xD5EA5E);
        let n = cfg.n;
        let ncomm = cfg.communities.max(1);

        // --- communities (balanced, shuffled) --------------------------------
        let mut community: Vec<u32> = (0..n).map(|i| (i % ncomm) as u32).collect();
        rng.shuffle(&mut community);
        // Disjoint-union datasets (ppi_sim): assign nodes to graphs by block
        // and keep communities within graphs.
        let per_graph = n / cfg.n_graphs.max(1);

        // --- edges ------------------------------------------------------------
        // Budget: GCN self loops must also fit in m_max.
        let max_undirected = (cfg.m_max - n) / 2;
        let target = ((n as f64 * cfg.avg_degree) / 2.0) as usize;
        let m_target = target.min(max_undirected);
        let edges = if cfg.name.contains("arxiv") || cfg.name.contains("collab") {
            gen_preferential(n, m_target, &community, per_graph, cfg, &mut rng)
        } else {
            gen_sbm(n, m_target, &community, per_graph, cfg, &mut rng)
        };

        // --- link-task split: hold out positives BEFORE building the graph ----
        let (msg_edges, val_pos, test_pos) = if cfg.task == "link" {
            let mut e = edges;
            rng.shuffle(&mut e);
            let n_val = e.len() / 10;
            let n_test = e.len() / 10;
            let test_pos = e.split_off(e.len() - n_test);
            let val_pos = e.split_off(e.len() - n_val);
            (e, val_pos, test_pos)
        } else {
            (edges, vec![], vec![])
        };

        let mut graph = Graph::from_undirected(n, &msg_edges);
        if cfg.n_graphs > 1 {
            for v in 0..n {
                graph.component[v] = (v / per_graph).min(cfg.n_graphs - 1) as u32;
            }
        }

        // --- features -----------------------------------------------------------
        let fpad = cfg.f_in_pad;
        let f = cfg.f_in;
        let mut proto = vec![0.0f32; ncomm * f];
        for x in proto.iter_mut() {
            *x = rng.gauss_f32();
        }
        let mut features = vec![0.0f32; n * fpad];
        for v in 0..n {
            let c = community[v] as usize;
            // degree signal in dim 0 keeps features non-degenerate for
            // isolated nodes
            let deg = graph.in_degree(v) as f32;
            for j in 0..f {
                features[v * fpad + j] = proto[c * f + j]
                    + cfg.feature_noise as f32 * rng.gauss_f32();
            }
            features[v * fpad] += 0.05 * (deg + 1.0).ln();
        }

        // --- labels ---------------------------------------------------------------
        let (labels, labels_multi) = if cfg.task == "link" {
            (vec![], vec![])
        } else if cfg.multilabel {
            let c = cfg.n_classes;
            let mut affinity = vec![0.0f32; ncomm * c];
            for x in affinity.iter_mut() {
                *x = if rng.f64() < 0.35 { 1.0 } else { 0.0 };
            }
            let mut y = vec![0.0f32; n * c];
            for v in 0..n {
                let comm = community[v] as usize;
                for j in 0..c {
                    let mut lab = affinity[comm * c + j];
                    if rng.f64() < 0.05 {
                        lab = 1.0 - lab;
                    }
                    y[v * c + j] = lab;
                }
            }
            (vec![], y)
        } else {
            let y = community
                .iter()
                .map(|&c| (c as usize % cfg.n_classes.max(1)) as i32)
                .collect();
            (y, vec![])
        };

        // --- splits -------------------------------------------------------------
        let split = if cfg.inductive {
            // whole graphs: last two components are val / test
            (0..n)
                .map(|v| {
                    let g = graph.component[v] as usize;
                    if g >= cfg.n_graphs - 1 {
                        Split::Test
                    } else if g == cfg.n_graphs - 2 {
                        Split::Val
                    } else {
                        Split::Train
                    }
                })
                .collect()
        } else {
            (0..n)
                .map(|_| {
                    let r = rng.f64();
                    if r < 0.6 {
                        Split::Train
                    } else if r < 0.8 {
                        Split::Val
                    } else {
                        Split::Test
                    }
                })
                .collect()
        };

        Dataset {
            cfg: cfg.clone(),
            graph,
            features,
            labels,
            labels_multi,
            split,
            community,
            val_pos,
            test_pos,
        }
    }
}

/// SBM-style generator: homophilous edges with ratio `intra_p_scale`
/// (reddit_sim / flickr_sim / ppi_sim / tiny_sim).
fn gen_sbm(n: usize, m: usize, community: &[u32], per_graph: usize,
           cfg: &DatasetCfg, rng: &mut Rng) -> Vec<(u32, u32)> {
    let r = cfg.intra_p_scale.max(1.0);
    let q_intra = r / (r + (cfg.communities.max(2) - 1) as f64);
    // community member lists (within graph blocks for disjoint unions)
    let ncomm = cfg.communities.max(1);
    let ngr = cfg.n_graphs.max(1);
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); ncomm * ngr];
    for v in 0..n {
        let g = if ngr > 1 { (v / per_graph).min(ngr - 1) } else { 0 };
        members[g * ncomm + community[v] as usize].push(v as u32);
    }
    let mut seen = std::collections::HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    let mut attempts = 0usize;
    while edges.len() < m && attempts < m * 30 {
        attempts += 1;
        let u = rng.below(n) as u32;
        let g = if ngr > 1 { (u as usize / per_graph).min(ngr - 1) } else { 0 };
        let v = if rng.f64() < q_intra {
            let list = &members[g * ncomm + community[u as usize] as usize];
            list[rng.below(list.len())]
        } else if ngr > 1 {
            // stay within the same graph block
            let lo = g * per_graph;
            let hi = if g == ngr - 1 { n } else { (g + 1) * per_graph };
            (lo + rng.below(hi - lo)) as u32
        } else {
            rng.below(n) as u32
        };
        if u == v {
            continue;
        }
        let key = if u < v { (u, v) } else { (v, u) };
        if seen.insert(key) {
            edges.push(key);
        }
    }
    edges
}

/// Preferential-attachment generator with community bias (arxiv_sim /
/// collab_sim): scale-free degree distribution like citation graphs.
fn gen_preferential(n: usize, m: usize, community: &[u32], _per_graph: usize,
                    cfg: &DatasetCfg, rng: &mut Rng) -> Vec<(u32, u32)> {
    let per_node = (2 * m / n).max(1);
    let r = cfg.intra_p_scale.max(1.0);
    let q_intra = r / (r + (cfg.communities.max(2) - 1) as f64);
    let ncomm = cfg.communities.max(1);
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); ncomm];
    let mut endpoints: Vec<u32> = Vec::with_capacity(m * 2); // degree-proportional pool
    let mut seen = std::collections::HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    for v in 0..n as u32 {
        let c = community[v as usize] as usize;
        let tries = per_node * 3;
        let mut added = 0;
        for _ in 0..tries {
            if added >= per_node || edges.len() >= m {
                break;
            }
            let u = if rng.f64() < q_intra && !members[c].is_empty() {
                members[c][rng.below(members[c].len())]
            } else if !endpoints.is_empty() && rng.f64() < 0.7 {
                endpoints[rng.below(endpoints.len())] // preferential
            } else if v > 0 {
                rng.below(v as usize) as u32
            } else {
                continue;
            };
            if u == v {
                continue;
            }
            let key = if u < v { (u, v) } else { (v, u) };
            if seen.insert(key) {
                edges.push(key);
                endpoints.push(u);
                endpoints.push(v);
                added += 1;
            }
        }
        members[c].push(v);
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DatasetCfg;

    fn tiny_cfg() -> DatasetCfg {
        DatasetCfg {
            name: "tiny_sim".into(),
            n: 256,
            m_max: 4096,
            f_in: 16,
            f_in_pad: 16,
            n_classes: 4,
            task: "node".into(),
            multilabel: false,
            inductive: false,
            n_graphs: 1,
            avg_degree: 6.0,
            communities: 4,
            feature_noise: 1.0,
            intra_p_scale: 12.0,
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = tiny_cfg();
        let a = Dataset::generate(&cfg, 7);
        let b = Dataset::generate(&cfg, 7);
        assert_eq!(a.graph.num_arcs(), b.graph.num_arcs());
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn respects_edge_budget_and_degree_target() {
        let cfg = tiny_cfg();
        let d = Dataset::generate(&cfg, 1);
        assert!(d.graph.num_arcs() + d.n() <= cfg.m_max);
        let deg = d.graph.avg_degree();
        assert!(deg > 3.0 && deg < 8.0, "avg degree {deg}");
    }

    #[test]
    fn homophily_exists() {
        let cfg = tiny_cfg();
        let d = Dataset::generate(&cfg, 2);
        let mut intra = 0usize;
        let mut total = 0usize;
        for v in 0..d.n() {
            for &u in d.graph.in_neighbors(v) {
                total += 1;
                if d.community[u as usize] == d.community[v] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / total.max(1) as f64;
        assert!(frac > 0.5, "intra-community fraction {frac}");
    }

    #[test]
    fn message_passing_signal_exists() {
        // A neighbor-majority-vote classifier must beat chance by a wide
        // margin — otherwise GNNs would have nothing to learn here.
        let cfg = tiny_cfg();
        let d = Dataset::generate(&cfg, 3);
        let mut correct = 0usize;
        let mut cnt = 0usize;
        for v in 0..d.n() {
            let nbs = d.graph.in_neighbors(v);
            if nbs.is_empty() {
                continue;
            }
            let mut votes = vec![0usize; cfg.n_classes];
            for &u in nbs {
                votes[d.labels[u as usize] as usize] += 1;
            }
            let pred = votes.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0;
            cnt += 1;
            if pred as i32 == d.labels[v] {
                correct += 1;
            }
        }
        let acc = correct as f64 / cnt as f64;
        assert!(acc > 0.6, "neighbor-vote acc {acc}");
    }

    #[test]
    fn link_split_disjoint_from_message_graph() {
        let mut cfg = tiny_cfg();
        cfg.task = "link".into();
        cfg.name = "collab_like".into();
        let d = Dataset::generate(&cfg, 4);
        assert!(!d.val_pos.is_empty() && !d.test_pos.is_empty());
        let mut msg: std::collections::HashSet<(u32, u32)> =
            std::collections::HashSet::new();
        for v in 0..d.n() {
            for &u in d.graph.in_neighbors(v) {
                msg.insert((u.min(v as u32), u.max(v as u32)));
            }
        }
        for &(a, b) in d.test_pos.iter().chain(&d.val_pos) {
            assert!(!msg.contains(&(a.min(b), a.max(b))));
        }
    }

    #[test]
    fn inductive_split_by_component() {
        let mut cfg = tiny_cfg();
        cfg.inductive = true;
        cfg.multilabel = true;
        cfg.n_graphs = 4;
        let d = Dataset::generate(&cfg, 5);
        for v in 0..d.n() {
            let g = d.graph.component[v];
            let want = if g == 3 {
                Split::Test
            } else if g == 2 {
                Split::Val
            } else {
                Split::Train
            };
            assert_eq!(d.split[v], want);
        }
        // no edges cross graph blocks
        for v in 0..d.n() {
            for &u in d.graph.in_neighbors(v) {
                assert_eq!(d.graph.component[u as usize], d.graph.component[v]);
            }
        }
        assert_eq!(d.labels_multi.len(), d.n() * cfg.n_classes);
    }

    #[test]
    fn features_padded_and_finite() {
        let mut cfg = tiny_cfg();
        cfg.f_in = 13;
        cfg.f_in_pad = 16;
        let d = Dataset::generate(&cfg, 6);
        for v in 0..d.n() {
            let row = d.feature_row(v);
            assert_eq!(row.len(), 16);
            assert!(row[13..].iter().all(|&x| x == 0.0));
            assert!(row.iter().all(|x| x.is_finite()));
        }
    }
}
