//! vq-gnn CLI — leader entrypoint.
//!
//!   vq-gnn train --dataset arxiv_sim --model gcn --method vq --epochs 30
//!   vq-gnn serve --dataset tiny_sim --model gcn --requests reqs.txt
//!   vq-gnn exp <table3|table4|table7|table8|fig4|inference|complexity|
//!               ablation-layers|ablation-codebook|ablation-batch|
//!               ablation-sampling|all> [--epochs N] [--seeds a,b,c]
//!
//! (clap is unavailable offline — hand-rolled parsing, DESIGN.md §7.)

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use vq_gnn::harness::experiments as exp;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    // --backend native|pjrt (default native; see README "Backends")
    if let Some(backend) = flags.get("backend") {
        std::env::set_var("VQ_GNN_BACKEND", backend);
    }
    let epochs: usize = flags.get("epochs").map(|s| s.parse()).transpose()?.unwrap_or(20);
    let seeds: Vec<u64> = flags
        .get("seeds")
        .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1, 2, 3]);

    match pos.first().map(String::as_str) {
        Some("train") => {
            let ds = flags.get("dataset").cloned().unwrap_or("tiny_sim".into());
            let model = flags.get("model").cloned().unwrap_or("gcn".into());
            let method = flags.get("method").cloned().unwrap_or("vq".into());
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
            let suffix = flags.get("suffix").cloned().unwrap_or_default();
            let mut ctx = exp::Ctx::new(epochs, seeds)?;
            let t = std::time::Instant::now();
            let (metric, stats) =
                exp::run_one_suffix(&mut ctx, &ds, &model, &method, &suffix, seed)?;
            println!(
                "{ds}/{model}/{method}: test metric {metric:.4} \
                 ({} steps, {:.1}s train, {:.1} MB peak step, {} msgs/step, total {:.1}s)",
                stats.steps,
                stats.train_secs,
                stats.peak_step_bytes as f64 / 1e6,
                stats.messages_per_step,
                t.elapsed().as_secs_f64()
            );
        }
        Some("serve") => serve_cmd(&flags)?,
        Some("exp") => {
            let which = pos.get(1).context("exp needs a name")?.as_str();
            let mut ctx = exp::Ctx::new(epochs, seeds)?;
            match which {
                "table3" => exp::table3(&mut ctx)?,
                "table4" => {
                    let ds: Vec<&str> = flags
                        .get("datasets")
                        .map(|s| s.split(',').collect())
                        .unwrap_or_else(|| {
                            vec!["arxiv_sim", "reddit_sim", "ppi_sim", "collab_sim"]
                        });
                    exp::table_perf(&mut ctx, &ds, "table4")?
                }
                "table7" => exp::table_perf(&mut ctx, &["flickr_sim"], "table7")?,
                "table8" => exp::table8(&mut ctx)?,
                "fig4" => exp::fig4(&mut ctx)?,
                "inference" => exp::inference(&mut ctx)?,
                "complexity" => exp::complexity(&mut ctx)?,
                "ablation-layers" => exp::ablations(&mut ctx, "layers")?,
                "ablation-codebook" => exp::ablations(&mut ctx, "codebook")?,
                "ablation-batch" => exp::ablations(&mut ctx, "batch")?,
                "ablation-sampling" => exp::ablations(&mut ctx, "sampling")?,
                "all" => {
                    exp::complexity(&mut ctx)?;
                    exp::table3(&mut ctx)?;
                    exp::inference(&mut ctx)?;
                    exp::table_perf(
                        &mut ctx,
                        &["arxiv_sim", "reddit_sim", "ppi_sim", "collab_sim"],
                        "table4",
                    )?;
                    exp::table_perf(&mut ctx, &["flickr_sim"], "table7")?;
                    exp::table8(&mut ctx)?;
                    exp::fig4(&mut ctx)?;
                    for a in ["layers", "codebook", "batch", "sampling"] {
                        exp::ablations(&mut ctx, a)?;
                    }
                }
                other => bail!("unknown experiment '{other}'"),
            }
        }
        _ => {
            eprintln!(
                "usage:\n  vq-gnn train --dataset D --model M --method \
                 [vq|full|ns|cluster|saint] [--epochs N] [--seed S] \
                 [--backend native|pjrt]\n  \
                 vq-gnn serve --dataset D --model M --requests FILE \
                 [--ckpt SERVING.bin] [--epochs N] [--seed S] [--out FILE] \
                 [--threads N] [--deadline-ms D]\n  \
                 vq-gnn exp [table3|table4|table7|table8|fig4|inference|\
                 complexity|ablation-*|all] [--epochs N] [--seeds 1,2,3] \
                 [--datasets a,b] [--backend native|pjrt]"
            );
        }
    }
    Ok(())
}

/// `vq-gnn serve`: freeze (or load) a model and answer a batch request
/// file through the micro-batching engine, reporting latency/throughput.
///
/// With `--ckpt PATH`: loads the serving artifact if the file exists,
/// otherwise trains `--epochs` (default 3) epochs, freezes, and exports
/// the artifact to that path for the next run.
///
/// `--threads N` widens the session pool (micro-batches fan out across N
/// `util::par` workers — answers are byte-identical to `--threads 1`);
/// `--deadline-ms D` switches to deadline-driven flushing: partial tails
/// wait up to D ms for newer arrivals before padding.
fn serve_cmd(flags: &HashMap<String, String>) -> Result<()> {
    use vq_gnn::coordinator::vq_trainer::VqTrainer;
    use vq_gnn::datasets::Dataset;
    use vq_gnn::runtime::manifest::Manifest;
    use vq_gnn::runtime::Runtime;
    use vq_gnn::sampler::NodeStrategy;
    use vq_gnn::serve::{self, report, Answer, LatencyReport, MicroBatcher, Request,
                        ServingModel};

    let ds_name = flags.get("dataset").cloned().unwrap_or("tiny_sim".into());
    let model = flags.get("model").cloned().unwrap_or("gcn".into());
    let epochs: usize = flags.get("epochs").map(|s| s.parse()).transpose()?.unwrap_or(3);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let threads: usize = flags.get("threads").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let deadline_ms: Option<u64> = flags.get("deadline-ms").map(|s| s.parse()).transpose()?;
    let req_path = flags.get("requests").context("serve needs --requests FILE")?;

    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let cfg = man
        .datasets
        .get(&ds_name)
        .with_context(|| format!("unknown dataset '{ds_name}'"))?
        .clone();
    let mut rt = Runtime::new()?;
    // Same generator seed as the experiment harness: the request file's
    // node ids and any exported serving artifact refer to this graph.
    let ds = Rc::new(Dataset::generate(&cfg, 42));

    let ckpt = flags.get("ckpt").map(std::path::PathBuf::from);
    let mut sm = match &ckpt {
        Some(path) if path.exists() => {
            eprintln!("loading serving artifact {}", path.display());
            ServingModel::load(&mut rt, &man, ds.clone(), &model, path)?
        }
        _ => {
            eprintln!("training {ds_name}/{model} for {epochs} epochs, then freezing");
            let mut tr = VqTrainer::new(
                &mut rt, &man, ds.clone(), &model, "", NodeStrategy::Nodes, seed,
            )?;
            for _ in 0..epochs {
                tr.epoch(&mut rt)?;
            }
            let sm = ServingModel::freeze(&mut rt, &man, &tr)?;
            if let Some(path) = &ckpt {
                sm.save(path)?;
                eprintln!("exported serving artifact to {}", path.display());
            }
            sm
        }
    };

    sm.set_threads(threads);
    let text = std::fs::read_to_string(req_path)
        .with_context(|| format!("read requests file {req_path}"))?;
    // validate ids against everything the MODEL serves — a loaded VQS2
    // artifact's admitted nodes are queryable too, not just the dataset's
    let reqs = serve::parse_requests(&text, sm.total_nodes())?;
    let mut eng = match deadline_ms {
        Some(ms) => MicroBatcher::with_deadline(std::time::Duration::from_millis(ms)),
        None => MicroBatcher::new(),
    };
    for r in &reqs {
        eng.submit(*r);
    }
    let t0 = std::time::Instant::now();
    let served = if deadline_ms.is_some() {
        // deadline mode: full batches go immediately, then — the input
        // file is exhausted, so the tail can never coalesce with newer
        // arrivals — drain the remainder at once instead of sleeping out
        // its deadline (a live front-end would keep calling flush())
        let mut served = eng.flush(&rt, &mut sm)?;
        served.extend(eng.drain(&rt, &mut sm)?);
        served
    } else {
        eng.drain(&rt, &mut sm)?
    };
    let wall = t0.elapsed().as_secs_f64();

    if let Some(out_path) = flags.get("out") {
        let link_task = ds.cfg.task == "link";
        let mut out = String::with_capacity(served.len() * 24);
        for s in &served {
            match &s.answer {
                // on link-task datasets the row is an embedding, not class
                // scores — argmax of it would be meaningless
                Answer::Scores(row) if link_task => {
                    let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
                    out.push_str(&format!("req {} emb_norm {norm:.6}\n", s.id));
                }
                Answer::Scores(_) => {
                    out.push_str(&format!("req {} class {}\n", s.id, s.answer.argmax().unwrap()));
                }
                Answer::Link(sc) => out.push_str(&format!("req {} link_score {sc:.6}\n", s.id)),
            }
        }
        std::fs::write(out_path, out)?;
        eprintln!("wrote {out_path}");
    }

    let lat: Vec<f64> = served.iter().map(|s| s.latency_s).collect();
    let lr = LatencyReport::from_latencies(&lat, wall);
    let nodes = reqs.iter().filter(|r| matches!(r, Request::Node(_))).count();
    println!(
        "serve {ds_name}/{model} ({} backend, b={}, {} worker{}): {lr}\n\
         {} node + {} link queries in {} micro-batches ({} full); \
         padded rows {} last flush / {} lifetime; tail flushes {} deadline + {} forced; \
         embedding cache resident {:.1} KB",
        rt.backend_name(),
        sm.batch_size(),
        sm.threads(),
        if sm.threads() == 1 { "" } else { "s" },
        nodes,
        reqs.len() - nodes,
        eng.stats.batches_run,
        eng.stats.full_batches,
        eng.stats.last_flush_padded_rows,
        eng.stats.padded_rows,
        eng.stats.tail_deadline_flushes,
        eng.stats.tail_forced_flushes,
        sm.cache().memory_bytes() as f64 / 1024.0,
    );
    print!("{}", report::format_workers(&sm.worker_stats(), wall));
    Ok(())
}
