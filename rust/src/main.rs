//! vq-gnn CLI — leader entrypoint.
//!
//!   vq-gnn train --dataset arxiv_sim --model gcn --method vq --epochs 30
//!   vq-gnn exp <table3|table4|table7|table8|fig4|inference|complexity|
//!               ablation-layers|ablation-codebook|ablation-batch|
//!               ablation-sampling|all> [--epochs N] [--seeds a,b,c]
//!
//! (clap is unavailable offline — hand-rolled parsing, DESIGN.md §7.)

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use vq_gnn::harness::experiments as exp;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    // --backend native|pjrt (default native; see README "Backends")
    if let Some(backend) = flags.get("backend") {
        std::env::set_var("VQ_GNN_BACKEND", backend);
    }
    let epochs: usize = flags.get("epochs").map(|s| s.parse()).transpose()?.unwrap_or(20);
    let seeds: Vec<u64> = flags
        .get("seeds")
        .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1, 2, 3]);

    match pos.first().map(String::as_str) {
        Some("train") => {
            let ds = flags.get("dataset").cloned().unwrap_or("tiny_sim".into());
            let model = flags.get("model").cloned().unwrap_or("gcn".into());
            let method = flags.get("method").cloned().unwrap_or("vq".into());
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
            let suffix = flags.get("suffix").cloned().unwrap_or_default();
            let mut ctx = exp::Ctx::new(epochs, seeds)?;
            let t = std::time::Instant::now();
            let (metric, stats) =
                exp::run_one_suffix(&mut ctx, &ds, &model, &method, &suffix, seed)?;
            println!(
                "{ds}/{model}/{method}: test metric {metric:.4} \
                 ({} steps, {:.1}s train, {:.1} MB peak step, {} msgs/step, total {:.1}s)",
                stats.steps,
                stats.train_secs,
                stats.peak_step_bytes as f64 / 1e6,
                stats.messages_per_step,
                t.elapsed().as_secs_f64()
            );
        }
        Some("exp") => {
            let which = pos.get(1).context("exp needs a name")?.as_str();
            let mut ctx = exp::Ctx::new(epochs, seeds)?;
            match which {
                "table3" => exp::table3(&mut ctx)?,
                "table4" => {
                    let ds: Vec<&str> = flags
                        .get("datasets")
                        .map(|s| s.split(',').collect())
                        .unwrap_or_else(|| {
                            vec!["arxiv_sim", "reddit_sim", "ppi_sim", "collab_sim"]
                        });
                    exp::table_perf(&mut ctx, &ds, "table4")?
                }
                "table7" => exp::table_perf(&mut ctx, &["flickr_sim"], "table7")?,
                "table8" => exp::table8(&mut ctx)?,
                "fig4" => exp::fig4(&mut ctx)?,
                "inference" => exp::inference(&mut ctx)?,
                "complexity" => exp::complexity(&mut ctx)?,
                "ablation-layers" => exp::ablations(&mut ctx, "layers")?,
                "ablation-codebook" => exp::ablations(&mut ctx, "codebook")?,
                "ablation-batch" => exp::ablations(&mut ctx, "batch")?,
                "ablation-sampling" => exp::ablations(&mut ctx, "sampling")?,
                "all" => {
                    exp::complexity(&mut ctx)?;
                    exp::table3(&mut ctx)?;
                    exp::inference(&mut ctx)?;
                    exp::table_perf(
                        &mut ctx,
                        &["arxiv_sim", "reddit_sim", "ppi_sim", "collab_sim"],
                        "table4",
                    )?;
                    exp::table_perf(&mut ctx, &["flickr_sim"], "table7")?;
                    exp::table8(&mut ctx)?;
                    exp::fig4(&mut ctx)?;
                    for a in ["layers", "codebook", "batch", "sampling"] {
                        exp::ablations(&mut ctx, a)?;
                    }
                }
                other => bail!("unknown experiment '{other}'"),
            }
        }
        _ => {
            eprintln!(
                "usage:\n  vq-gnn train --dataset D --model M --method \
                 [vq|full|ns|cluster|saint] [--epochs N] [--seed S] \
                 [--backend native|pjrt]\n  \
                 vq-gnn exp [table3|table4|table7|table8|fig4|inference|\
                 complexity|ablation-*|all] [--epochs N] [--seeds 1,2,3] \
                 [--datasets a,b] [--backend native|pjrt]"
            );
        }
    }
    Ok(())
}
