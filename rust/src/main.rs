//! vq-gnn CLI — leader entrypoint.
//!
//!   vq-gnn train --dataset arxiv_sim --model gcn --method vq --epochs 30
//!   vq-gnn serve --dataset tiny_sim --model gcn --requests reqs.txt
//!   vq-gnn serve --dataset tiny_sim --model gcn,sage --listen 127.0.0.1:7571
//!   vq-gnn client --addr 127.0.0.1:7571 --model gcn --requests reqs.txt --shutdown
//!   vq-gnn exp <table3|table4|table7|table8|fig4|inference|complexity|
//!               ablation-layers|ablation-codebook|ablation-batch|
//!               ablation-sampling|all> [--epochs N] [--seeds a,b,c]
//!
//! (clap is unavailable offline — hand-rolled parsing, DESIGN.md §7.)

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use vq_gnn::harness::experiments as exp;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    // --backend native|pjrt (default native; see README "Backends")
    if let Some(backend) = flags.get("backend") {
        std::env::set_var("VQ_GNN_BACKEND", backend);
    }
    let epochs: usize = flags.get("epochs").map(|s| s.parse()).transpose()?.unwrap_or(20);
    let seeds: Vec<u64> = flags
        .get("seeds")
        .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1, 2, 3]);

    match pos.first().map(String::as_str) {
        Some("train") => {
            let ds = flags.get("dataset").cloned().unwrap_or("tiny_sim".into());
            let model = flags.get("model").cloned().unwrap_or("gcn".into());
            let method = flags.get("method").cloned().unwrap_or("vq".into());
            let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
            let suffix = flags.get("suffix").cloned().unwrap_or_default();
            let metrics_every: Option<usize> =
                flags.get("metrics-every").map(|s| s.parse()).transpose()?;
            let shards: usize = flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
            let mut ctx = exp::Ctx::new(epochs, seeds)?;
            ctx.shards = shards.max(1);
            let registry = metrics_every
                .map(|n| (std::sync::Arc::new(vq_gnn::obs::Registry::new()), n));
            ctx.metrics = registry.clone();
            let t = std::time::Instant::now();
            let (metric, stats) =
                exp::run_one_suffix(&mut ctx, &ds, &model, &method, &suffix, seed)?;
            println!(
                "{ds}/{model}/{method}: test metric {metric:.4} \
                 ({} steps, {:.1}s train, {:.1} MB peak step, {} msgs/step, total {:.1}s)",
                stats.steps,
                stats.train_secs,
                stats.peak_step_bytes as f64 / 1e6,
                stats.messages_per_step,
                t.elapsed().as_secs_f64()
            );
            if let Some((reg, _)) = &registry {
                eprintln!("[metrics final] {}", reg.render_line());
            }
        }
        Some("serve") => serve_cmd(&flags)?,
        Some("client") => client_cmd(&flags)?,
        Some("exp") => {
            let which = pos.get(1).context("exp needs a name")?.as_str();
            let mut ctx = exp::Ctx::new(epochs, seeds)?;
            match which {
                "table3" => exp::table3(&mut ctx)?,
                "table4" => {
                    let ds: Vec<&str> = flags
                        .get("datasets")
                        .map(|s| s.split(',').collect())
                        .unwrap_or_else(|| {
                            vec!["arxiv_sim", "reddit_sim", "ppi_sim", "collab_sim"]
                        });
                    exp::table_perf(&mut ctx, &ds, "table4")?
                }
                "table7" => exp::table_perf(&mut ctx, &["flickr_sim"], "table7")?,
                "table8" => exp::table8(&mut ctx)?,
                "fig4" => exp::fig4(&mut ctx)?,
                "inference" => exp::inference(&mut ctx)?,
                "complexity" => exp::complexity(&mut ctx)?,
                "ablation-layers" => exp::ablations(&mut ctx, "layers")?,
                "ablation-codebook" => exp::ablations(&mut ctx, "codebook")?,
                "ablation-batch" => exp::ablations(&mut ctx, "batch")?,
                "ablation-sampling" => exp::ablations(&mut ctx, "sampling")?,
                "all" => {
                    exp::complexity(&mut ctx)?;
                    exp::table3(&mut ctx)?;
                    exp::inference(&mut ctx)?;
                    exp::table_perf(
                        &mut ctx,
                        &["arxiv_sim", "reddit_sim", "ppi_sim", "collab_sim"],
                        "table4",
                    )?;
                    exp::table_perf(&mut ctx, &["flickr_sim"], "table7")?;
                    exp::table8(&mut ctx)?;
                    exp::fig4(&mut ctx)?;
                    for a in ["layers", "codebook", "batch", "sampling"] {
                        exp::ablations(&mut ctx, a)?;
                    }
                }
                other => bail!("unknown experiment '{other}'"),
            }
        }
        _ => {
            eprintln!(
                "usage:\n  vq-gnn train --dataset D --model M --method \
                 [vq|full|ns|cluster|saint] [--epochs N] [--seed S] [--shards S] \
                 [--metrics-every EPOCHS] [--backend native|pjrt]\n  \
                 vq-gnn serve --dataset D --model M[,M2,..] \
                 (--requests FILE | --listen ADDR) \
                 [--ckpt SERVING.bin] [--epochs N] [--seed S] [--out FILE] \
                 [--threads N] [--shards S] [--deadline-ms D] [--queue-cap C] \
                 [--admit FILE] [--max-admitted N] [--ttl-ms T] \
                 [--drift-threshold T] [--refresh] [--metrics-every N]\n  \
                 vq-gnn client --addr HOST:PORT --model M (--requests FILE | --stats) \
                 [--out FILE] [--rate R] [--wait-ms W] [--drain] [--shutdown]\n  \
                 vq-gnn exp [table3|table4|table7|table8|fig4|inference|\
                 complexity|ablation-*|all] [--epochs N] [--seeds 1,2,3] \
                 [--datasets a,b] [--backend native|pjrt]"
            );
        }
    }
    Ok(())
}

/// Render one served answer in the CLI's stable line format (the socket
/// client emits byte-identical lines, which is what CI's `cmp` pins).
fn answer_line(id: usize, answer: &vq_gnn::serve::Answer, link_task: bool) -> String {
    use vq_gnn::serve::Answer;
    match answer {
        // on link-task datasets the row is an embedding, not class
        // scores — argmax of it would be meaningless
        Answer::Scores(row) if link_task => {
            let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            format!("req {id} emb_norm {norm:.6}\n")
        }
        Answer::Scores(_) => format!("req {id} class {}\n", answer.argmax().unwrap()),
        Answer::Link(sc) => format!("req {id} link_score {sc:.6}\n"),
    }
}

/// Per-model maintenance report, printed when any maintenance flag was
/// given: lifetime admitted/evicted counts, the resident admitted-table
/// size, and the codebook-drift metric — then the opt-in drift-gated EMA
/// refresh (`--refresh`), which only moves codewords while the drift
/// metric is at/above the engine threshold.
fn maintenance_epilogue(
    eng: &mut vq_gnn::serve::ServeEngine,
    ds_n: usize,
    do_refresh: bool,
) -> Result<()> {
    let names: Vec<String> = eng.models().iter().map(|s| s.to_string()).collect();
    for name in &names {
        let resident = eng.model(name).unwrap().total_nodes() - ds_n;
        let st = eng.stats(name).unwrap();
        let (evicted, alerts) = (st.evictions, st.drift_alerts);
        let drift = eng.drift(name).unwrap_or(0.0);
        println!(
            "model {name}: admitted {}, evicted {evicted}, resident {resident}; \
             drift max {drift:.3} ({alerts} alert(s))",
            resident as u64 + evicted,
        );
        if do_refresh {
            if eng.refresh(name)? {
                println!(
                    "model {name}: EMA refresh moved codewords \
                     (drift {drift:.3} -> {:.3})",
                    eng.drift(name).unwrap_or(0.0)
                );
            } else {
                println!(
                    "model {name}: EMA refresh skipped \
                     (drift {drift:.3} below threshold {:.3})",
                    eng.drift_threshold()
                );
            }
        }
    }
    Ok(())
}

/// `vq-gnn serve`: freeze (or load) models and serve them through one
/// [`ServeEngine`](vq_gnn::serve::ServeEngine) — either answering a batch
/// request file, or listening on a TCP address (`--listen`) for framed
/// queries from `vq-gnn client`.
///
/// `--model` takes a comma-separated list; with several models and
/// `--ckpt PATH`, each model's artifact lives at `PATH.<name>`.  A ckpt
/// path is loaded if the file exists, otherwise the model is trained for
/// `--epochs` (default 3), frozen, and exported there for the next run.
///
/// `--threads N` widens every model's session pool (answers are
/// byte-identical to `--threads 1`); `--deadline-ms D` switches to
/// deadline-driven flushing; `--queue-cap C` bounds each model's queue —
/// excess load is shed (file mode drains and retries instead).
///
/// Online maintenance: `--admit FILE` streams admissions into the first
/// model before serving (one line per node: `<src> [nbr..]` — the new
/// node clones frozen node `<src>`'s features and cites `nbr..` as
/// in-arcs); `--max-admitted N` / `--ttl-ms T` bound the admitted tables
/// (LRU / age eviction); `--drift-threshold T` tunes the codebook-drift
/// alert; `--refresh` runs the drift-gated EMA codebook refresh after
/// serving.  Any of these turns on the per-model maintenance report line.
fn serve_cmd(flags: &HashMap<String, String>) -> Result<()> {
    use vq_gnn::coordinator::vq_trainer::VqTrainer;
    use vq_gnn::datasets::Dataset;
    use vq_gnn::runtime::manifest::Manifest;
    use vq_gnn::runtime::Runtime;
    use vq_gnn::sampler::NodeStrategy;
    use vq_gnn::serve::{self, report, server, LatencyReport, Request, ServeEngine,
                        ServeError, ServingModel};

    let ds_name = flags.get("dataset").cloned().unwrap_or("tiny_sim".into());
    let model_list = flags.get("model").cloned().unwrap_or("gcn".into());
    let models: Vec<String> = model_list.split(',').map(str::to_string).collect();
    let epochs: usize = flags.get("epochs").map(|s| s.parse()).transpose()?.unwrap_or(3);
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let threads: usize = flags.get("threads").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let shards: usize = flags.get("shards").map(|s| s.parse()).transpose()?.unwrap_or(1);
    let deadline_ms: Option<u64> = flags.get("deadline-ms").map(|s| s.parse()).transpose()?;
    let queue_cap: Option<usize> = flags.get("queue-cap").map(|s| s.parse()).transpose()?;
    let max_admitted: Option<usize> =
        flags.get("max-admitted").map(|s| s.parse()).transpose()?;
    let ttl_ms: Option<u64> = flags.get("ttl-ms").map(|s| s.parse()).transpose()?;
    let drift_threshold: Option<f32> =
        flags.get("drift-threshold").map(|s| s.parse()).transpose()?;
    let do_refresh = flags.contains_key("refresh");
    let metrics_every: Option<u64> =
        flags.get("metrics-every").map(|s| s.parse()).transpose()?;
    let admit_path = flags.get("admit");
    let maintenance_on = max_admitted.is_some()
        || ttl_ms.is_some()
        || drift_threshold.is_some()
        || do_refresh
        || admit_path.is_some();
    let listen = flags.get("listen");
    let req_path = flags.get("requests");
    if listen.is_none() && req_path.is_none() {
        bail!("serve needs --requests FILE or --listen ADDR");
    }

    let man = Manifest::load_or_builtin(&Manifest::default_dir());
    let cfg = man
        .datasets
        .get(&ds_name)
        .with_context(|| format!("unknown dataset '{ds_name}'"))?
        .clone();
    let mut rt = Runtime::new()?;
    // Same generator seed as the experiment harness: the request file's
    // node ids and any exported serving artifact refer to this graph.
    let ds = Rc::new(Dataset::generate(&cfg, 42));

    let ckpt = flags.get("ckpt").map(std::path::PathBuf::from);
    // Always attach a live registry: the STATS wire frame scrapes it with
    // zero flags, and recording never perturbs answers (pinned by
    // tests/obs.rs).  --metrics-every only gates the periodic report line.
    let registry = std::sync::Arc::new(vq_gnn::obs::Registry::new());
    let mut builder = ServeEngine::builder()
        .threads(threads)
        .shards(shards)
        .metrics(registry.clone());
    if let Some(ms) = deadline_ms {
        builder = builder.deadline(std::time::Duration::from_millis(ms));
    }
    if let Some(cap) = queue_cap {
        builder = builder.queue_cap(cap);
    }
    if let Some(cap) = max_admitted {
        builder = builder.max_admitted(cap);
    }
    if let Some(ms) = ttl_ms {
        builder = builder.admit_ttl(std::time::Duration::from_millis(ms));
    }
    if let Some(t) = drift_threshold {
        builder = builder.drift_threshold(t);
    }
    for name in &models {
        // one model: the ckpt path as given; several: PATH.<name> each
        let path = ckpt.as_ref().map(|p| {
            if models.len() == 1 {
                p.clone()
            } else {
                std::path::PathBuf::from(format!("{}.{name}", p.display()))
            }
        });
        let sm = match &path {
            Some(path) if path.exists() => {
                eprintln!("loading serving artifact {}", path.display());
                ServingModel::load(&mut rt, &man, ds.clone(), name, path)?
            }
            _ => {
                eprintln!("training {ds_name}/{name} for {epochs} epochs, then freezing");
                let mut tr = VqTrainer::new(
                    &mut rt, &man, ds.clone(), name, "", NodeStrategy::Nodes, seed,
                )?;
                for _ in 0..epochs {
                    tr.epoch(&mut rt)?;
                }
                let sm = ServingModel::freeze(&mut rt, &man, &tr)?;
                if let Some(path) = &path {
                    sm.save(path)?;
                    eprintln!("exported serving artifact to {}", path.display());
                }
                sm
            }
        };
        builder = builder.model(name.clone(), sm);
    }
    let mut eng = builder.build(rt).map_err(anyhow::Error::new)?;

    // ---- streamed admissions (first model) ------------------------------
    // Each line admits one unseen node cloning a frozen node's features;
    // the retention policy (LRU cap / TTL) runs inline with every admit,
    // so driving this past --max-admitted exercises eviction.
    if let Some(path) = admit_path {
        let target = models[0].as_str();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read admissions file {path}"))?;
        let mut count = 0usize;
        for (i, line) in text.lines().enumerate() {
            let lno = i + 1;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_whitespace();
            let src: usize = toks
                .next()
                .unwrap()
                .parse()
                .map_err(|_| anyhow::anyhow!("{path}:{lno}: bad source id"))?;
            if src >= ds.n() {
                bail!("{path}:{lno}: source {src} outside the frozen graph (n={})", ds.n());
            }
            let nbrs: Vec<u32> = toks
                .map(|t| {
                    t.parse()
                        .map_err(|_| anyhow::anyhow!("{path}:{lno}: bad neighbor '{t}'"))
                })
                .collect::<Result<_>>()?;
            let feat = ds.feature_row(src).to_vec();
            eng.admit(target, &feat, &nbrs)
                .with_context(|| format!("{path}:{lno}: admit"))?;
            count += 1;
        }
        eprintln!("admitted {count} streamed node(s) into model '{target}'");
    }

    // ---- socket mode ----------------------------------------------------
    if let Some(addr) = listen {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("serve: bind {addr}"))?;
        eprintln!("listening on {}", listener.local_addr()?);
        // --metrics-every N (socket mode: N seconds): periodic report line
        // on stderr while the accept loop runs
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let printer = metrics_every.map(|secs| {
            let reg = registry.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let period = std::time::Duration::from_secs(secs.max(1));
                let mut next = std::time::Instant::now() + period;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::thread::sleep(std::time::Duration::from_millis(50));
                    if std::time::Instant::now() >= next {
                        eprintln!("[metrics] {}", reg.render_line());
                        next += period;
                    }
                }
            })
        });
        let rep = server::run(&mut eng, listener)?;
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        if let Some(p) = printer {
            let _ = p.join();
        }
        println!(
            "serve {ds_name}/{model_list} ({} backend, {} worker{}): \
             {} connection(s), {} request(s), {} served, shed {}, {} error(s)",
            eng.runtime().backend_name(),
            eng.threads(),
            if eng.threads() == 1 { "" } else { "s" },
            rep.connections,
            rep.requests,
            rep.served,
            rep.shed,
            rep.errors,
        );
        for name in eng.models() {
            let st = eng.stats(name).unwrap();
            println!(
                "model {name}: {} micro-batches ({} full), padded rows {} lifetime, \
                 tail flushes {} deadline + {} forced",
                st.batches_run,
                st.full_batches,
                st.padded_rows,
                st.tail_deadline_flushes,
                st.tail_forced_flushes,
            );
        }
        if maintenance_on {
            maintenance_epilogue(&mut eng, ds.n(), do_refresh)?;
        }
        return Ok(());
    }

    // ---- file mode: every request goes to the FIRST model ---------------
    let target = models[0].as_str();
    let req_path = req_path.unwrap();
    let text = std::fs::read_to_string(req_path)
        .with_context(|| format!("read requests file {req_path}"))?;
    // validate ids against every id the MODEL ever issued — admitted
    // nodes (loaded or streamed) are queryable too, and with eviction the
    // live set is sparse, so the parse bound is the id BOUND; `submit`
    // still refuses evicted ids in range with the typed unknown-id error
    let bound = eng.model(target).unwrap().cache().admitted.id_bound() as usize;
    let reqs = serve::parse_requests(&text, bound)?;
    let t0 = std::time::Instant::now();
    let mut served = Vec::new();
    for (i, r) in reqs.iter().enumerate() {
        match eng.submit(target, *r) {
            Ok(_) => {}
            Err(ServeError::Shed { .. }) => {
                // bounded queue in batch mode: make room, then retry —
                // a file has no client to shed to
                served.extend(eng.drain()?);
                eng.submit(target, *r).map_err(anyhow::Error::new)?;
            }
            Err(e) => return Err(anyhow::Error::new(e)),
        }
        // --metrics-every N (file mode: N requests)
        if let Some(n) = metrics_every {
            if n > 0 && (i as u64 + 1) % n == 0 {
                eprintln!(
                    "[metrics {}/{} reqs] {}",
                    i + 1,
                    reqs.len(),
                    registry.render_line()
                );
            }
        }
    }
    if deadline_ms.is_some() {
        // deadline mode: full batches go immediately, then — the input
        // file is exhausted, so the tail can never coalesce with newer
        // arrivals — drain the remainder at once instead of sleeping out
        // its deadline (a live front-end keeps polling instead)
        served.extend(eng.poll()?);
    }
    served.extend(eng.drain()?);
    served.sort_by_key(|s| s.id);
    let wall = t0.elapsed().as_secs_f64();

    if let Some(out_path) = flags.get("out") {
        let link_task = eng.model(target).unwrap().link_task();
        let mut out = String::with_capacity(served.len() * 24);
        for s in &served {
            out.push_str(&answer_line(s.id, &s.answer, link_task));
        }
        std::fs::write(out_path, out)?;
        eprintln!("wrote {out_path}");
    }

    let lat: Vec<f64> = served.iter().map(|s| s.latency_s).collect();
    let lr = LatencyReport::from_latencies(&lat, wall);
    let nodes = reqs.iter().filter(|r| matches!(r, Request::Node(_))).count();
    let sm = eng.model(target).unwrap();
    let st = eng.stats(target).unwrap();
    println!(
        "serve {ds_name}/{target} ({} backend, b={}, {} worker{}): {lr}\n\
         {} node + {} link queries in {} micro-batches ({} full); \
         padded rows {} last flush / {} lifetime; tail flushes {} deadline + {} forced; \
         embedding cache resident {:.1} KB",
        eng.runtime().backend_name(),
        sm.batch_size(),
        sm.threads(),
        if sm.threads() == 1 { "" } else { "s" },
        nodes,
        reqs.len() - nodes,
        st.batches_run,
        st.full_batches,
        st.last_flush_padded_rows,
        st.padded_rows,
        st.tail_deadline_flushes,
        st.tail_forced_flushes,
        sm.cache().memory_bytes() as f64 / 1024.0,
    );
    print!("{}", report::format_workers(&sm.worker_stats()));
    if metrics_every.is_some() {
        eprintln!("[metrics final] {}", registry.render_line());
    }
    if maintenance_on {
        maintenance_epilogue(&mut eng, ds.n(), do_refresh)?;
    }
    Ok(())
}

/// `vq-gnn client`: send a request file to a running `serve --listen`
/// instance over the framed TCP protocol and collect the answers.
///
/// `--rate R` paces submissions open-loop at R queries/s (default: blast
/// everything); `--drain`/`--shutdown` append the corresponding control
/// frames; `--wait-ms W` keeps retrying the initial connect for W ms (the
/// server may still be loading its artifact); `--out FILE` writes answer
/// lines byte-identical to `serve --requests`'s `--out`; `--stats` appends
/// a STATS frame and prints the server's Prometheus text exposition on
/// stdout (a curl-free scrape — `--requests` becomes optional).
fn client_cmd(flags: &HashMap<String, String>) -> Result<()> {
    use std::io::Write;
    use vq_gnn::serve::proto::{self, WireRequest, WireResponse};
    use vq_gnn::serve::{self, Request};
    use vq_gnn::util::bench::Pacer;

    let addr = flags.get("addr").context("client needs --addr HOST:PORT")?.clone();
    let model = flags.get("model").cloned().unwrap_or("gcn".into());
    let do_stats = flags.contains_key("stats");
    let req_path = flags.get("requests");
    if req_path.is_none() && !do_stats {
        bail!("client needs --requests FILE (or --stats for a scrape-only probe)");
    }
    let rate: Option<f64> = flags.get("rate").map(|s| s.parse()).transpose()?;
    let wait_ms: u64 = flags.get("wait-ms").map(|s| s.parse()).transpose()?.unwrap_or(10_000);
    let do_drain = flags.contains_key("drain");
    let do_shutdown = flags.contains_key("shutdown");

    let reqs = match req_path {
        Some(req_path) => {
            let text = std::fs::read_to_string(req_path)
                .with_context(|| format!("read requests file {req_path}"))?;
            // no local range check — the server owns admission control and
            // answers out-of-range ids with a typed BAD_REQUEST frame
            serve::parse_requests(&text, usize::MAX)?
        }
        None => Vec::new(),
    };

    let connect_deadline =
        std::time::Instant::now() + std::time::Duration::from_millis(wait_ms);
    let stream = loop {
        match std::net::TcpStream::connect(&addr) {
            Ok(s) => break s,
            Err(e) => {
                if std::time::Instant::now() >= connect_deadline {
                    return Err(anyhow::Error::new(e)).context(format!("connect {addr}"));
                }
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    };
    stream.set_nodelay(true)?;
    let mut rstream = stream.try_clone()?;
    // every node/link query gets exactly one response frame (scores or a
    // typed error), and a STATS probe exactly one stats frame
    let expected = reqs.len() + usize::from(do_stats);

    // reader thread: counts responses down to `expected`
    let reader = std::thread::spawn(move || -> Result<Vec<WireResponse>> {
        let mut got = Vec::with_capacity(expected);
        while got.len() < expected {
            match proto::read_frame(&mut rstream)? {
                Some(payload) => match proto::decode_response(&payload)? {
                    WireResponse::Pong { .. } => continue,
                    resp => got.push(resp),
                },
                None => break, // server hung up
            }
        }
        Ok(got)
    });

    let t0 = std::time::Instant::now();
    let mut w = stream;
    let mut pacer = rate.map(Pacer::new);
    for (i, r) in reqs.iter().enumerate() {
        if let Some(p) = &mut pacer {
            while p.due() == 0 {
                p.sleep_until_next(std::time::Duration::from_millis(2));
            }
            p.note_issued(1);
        }
        let req_id = i as u64;
        let wire = match *r {
            Request::Node(v) => WireRequest::Node { req_id, model: model.clone(), node: v },
            Request::Link(u, v) => {
                WireRequest::Link { req_id, model: model.clone(), u, v }
            }
        };
        w.write_all(&proto::encode_request(&wire))?;
    }
    if do_stats {
        // after the queries so the scrape reflects them once drained
        w.write_all(&proto::encode_request(&WireRequest::Stats {
            req_id: reqs.len() as u64,
        }))?;
    }
    if do_drain {
        w.write_all(&proto::encode_request(&WireRequest::Drain))?;
    }
    if do_shutdown {
        w.write_all(&proto::encode_request(&WireRequest::Shutdown))?;
    }
    w.flush()?;

    let mut resps = reader.join().expect("client reader thread")?;
    let wall = t0.elapsed().as_secs_f64();
    let tally = render_client_responses(&mut resps);
    for line in &tally.err_lines {
        eprintln!("{line}");
    }
    // scrape text goes straight to stdout (greppable, pipeable); the STATS
    // frame's req_id sorts after every query, so it renders last
    print!("{}", tally.stats);
    if let Some(out_path) = flags.get("out") {
        std::fs::write(out_path, tally.out)?;
        eprintln!("wrote {out_path}");
    }
    if !do_stats || !reqs.is_empty() {
        println!(
            "client {addr}: {} sent, {} served, shed {}, {} error(s), {wall:.1}s",
            reqs.len(),
            tally.served,
            tally.shed,
            tally.errors,
        );
    }
    Ok(())
}

/// What one client run renders from its response frames, split by sink:
/// `out` is the answer file bytes (identical to `serve --requests --out`),
/// `stats` the Prometheus exposition for stdout, `err_lines` the typed
/// error reports for stderr, and the counters feed the summary line.
#[derive(Default)]
struct ClientTally {
    out: String,
    stats: String,
    err_lines: Vec<String>,
    served: u64,
    shed: u64,
    errors: u64,
}

/// Sort responses into req_id order and render/tally them.  Pure so the
/// accounting rules — shed vs error split, the STATS frame sorting after
/// every answer, Pong frames ignored — stay pinned by unit tests.
fn render_client_responses(
    resps: &mut [vq_gnn::serve::proto::WireResponse],
) -> ClientTally {
    use vq_gnn::serve::proto::{ErrCode, WireResponse};
    resps.sort_by_key(|r| match r {
        WireResponse::Scores { req_id, .. }
        | WireResponse::Link { req_id, .. }
        | WireResponse::Error { req_id, .. }
        | WireResponse::Pong { req_id }
        | WireResponse::Stats { req_id, .. } => *req_id,
    });

    let mut tally = ClientTally::default();
    tally.out.reserve(resps.len() * 24);
    for resp in resps.iter() {
        match resp {
            WireResponse::Scores { req_id, embedding, row } => {
                tally.served += 1;
                tally.out.push_str(&answer_line(
                    *req_id as usize,
                    &vq_gnn::serve::Answer::Scores(row.clone()),
                    *embedding,
                ));
            }
            WireResponse::Link { req_id, score } => {
                tally.served += 1;
                tally.out.push_str(&answer_line(
                    *req_id as usize,
                    &vq_gnn::serve::Answer::Link(*score),
                    false,
                ));
            }
            WireResponse::Error { req_id, code, msg } => {
                if *code == ErrCode::Shed {
                    tally.shed += 1;
                } else {
                    tally.errors += 1;
                }
                tally.err_lines.push(format!("req {req_id}: {} — {msg}", code.name()));
            }
            WireResponse::Pong { .. } => {}
            WireResponse::Stats { text, .. } => tally.stats.push_str(text),
        }
    }
    tally
}

#[cfg(test)]
mod tests {
    use super::*;
    use vq_gnn::serve::proto::{ErrCode, WireResponse};

    #[test]
    fn parse_flags_handles_boolean_and_valued_flags() {
        let args: Vec<String> = ["client", "--addr", "h:1", "--stats", "--rate", "5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (pos, flags) = parse_flags(&args);
        assert_eq!(pos, vec!["client".to_string()]);
        assert_eq!(flags.get("addr").map(String::as_str), Some("h:1"));
        // a flag followed by another flag (or nothing) is boolean "true"
        assert_eq!(flags.get("stats").map(String::as_str), Some("true"));
        assert_eq!(flags.get("rate").map(String::as_str), Some("5"));
        // trailing boolean flag
        let (_, f2) = parse_flags(&["--drain".to_string()]);
        assert_eq!(f2.get("drain").map(String::as_str), Some("true"));
    }

    #[test]
    fn stats_frame_renders_after_answers_and_counters_split() {
        // arrival order scrambled: the STATS frame (req_id = n_queries)
        // arrives first, answers out of order, one shed, one hard error
        let mut resps = vec![
            WireResponse::Stats { req_id: 4, text: "vqgnn_up 1\n".into() },
            WireResponse::Link { req_id: 2, score: 0.5 },
            WireResponse::Error { req_id: 3, code: ErrCode::Shed, msg: "full".into() },
            WireResponse::Error { req_id: 1, code: ErrCode::BadRequest, msg: "bad".into() },
            WireResponse::Scores { req_id: 0, embedding: false, row: vec![1.0, 2.0] },
            WireResponse::Pong { req_id: 0 },
        ];
        let tally = render_client_responses(&mut resps);
        assert_eq!(tally.served, 2);
        assert_eq!(tally.shed, 1);
        assert_eq!(tally.errors, 1);
        assert_eq!(tally.stats, "vqgnn_up 1\n");
        // answer lines in req_id order: node answer (id 0) before link (id 2)
        let lines: Vec<&str> = tally.out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("req 0 class"), "node answer first: {:?}", lines[0]);
        assert!(lines[1].starts_with("req 2 link_score"), "link answer second: {:?}", lines[1]);
        // stderr reports in req_id order, typed code names preserved
        assert_eq!(tally.err_lines.len(), 2);
        assert!(tally.err_lines[0].starts_with("req 1: BAD_REQUEST"));
        assert!(tally.err_lines[1].starts_with("req 3: SHED"));
    }
}
