//! Blocked, thread-parallel VQ kernels — the L3 hot path shared by the
//! trainers, the native backend and `benches/hot_paths.rs`.
//!
//! FINDNEAREST uses the classic distance decomposition
//! `‖v − c‖² = ‖v‖² − 2·v·cᵀ + ‖c‖²` over contiguous row blocks: whitening
//! is hoisted out of the O(b·k·fp) inner loop (the seed's scalar loop paid a
//! divide + sqrt per element), codeword norms are computed once, and rows
//! are distributed over threads.  Codewords are always scanned in ascending
//! index order with a strict `<` comparison, so ties break to the lowest
//! index — identical to the scalar reference and to
//! `python/compile/kernels/ref.py`.

use crate::util::{par, simd};
use crate::vq::EPS;

/// Rows per parallel work unit (large enough to amortize thread dispatch,
/// small enough to balance uneven tails).
pub const ROW_BLOCK: usize = 64;

/// Minimum codebook size before the two-stage quantized FINDNEAREST pays
/// for its table build + candidate bookkeeping.  Every test config in the
/// repo uses k ≤ 33, which keeps them on the exact single-stage path.
pub const PRUNE_MIN_K: usize = 64;

/// Candidates kept by the first-pass i8 scan (in addition to every
/// codeword whose error-bounded lower bound beats the best upper bound —
/// the soundness net that guarantees the exact argmin survives).
pub const PRUNE_TOP_M: usize = 16;

/// `1 / sqrt(var + EPS)` per dim — the whitening scale, computed once —
/// into a reused buffer.
pub fn inv_std_into(var: &[f32], out: &mut [f32]) {
    debug_assert_eq!(var.len(), out.len());
    for (o, &v) in out.iter_mut().zip(var) {
        *o = 1.0 / (v + EPS).sqrt();
    }
}

/// Allocating wrapper of [`inv_std_into`].
pub fn inv_std(var: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; var.len()];
    inv_std_into(var, &mut out);
    out
}

/// Whiten `(b, fp)` row-major vectors: `w = (v − mean) · inv`, into a
/// reused buffer (every element overwritten).
pub fn whiten_into(v: &[f32], fp: usize, mean: &[f32], inv: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len() % fp.max(1), 0);
    debug_assert_eq!(mean.len(), fp);
    debug_assert_eq!(inv.len(), fp);
    debug_assert_eq!(v.len(), out.len());
    if fp == 0 {
        return;
    }
    // Row-wise (the old loop recomputed `% fp` per element): each row is a
    // fused (v − mean) · inv over the contiguous fp dims, which the SIMD
    // layer handles sub-then-mul — bit-identical to the scalar loop.
    par::par_chunks_mut(out, ROW_BLOCK * fp, |ci, chunk| {
        let base = ci * ROW_BLOCK * fp;
        for (row_off, orow) in chunk.chunks_mut(fp).enumerate() {
            let src = base + row_off * fp;
            simd::whiten_row(orow, &v[src..src + orow.len()], mean, inv);
        }
    });
}

/// Allocating wrapper of [`whiten_into`].
pub fn whiten(v: &[f32], fp: usize, mean: &[f32], inv: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; v.len()];
    whiten_into(v, fp, mean, inv, &mut out);
    out
}

/// ‖c‖² per codeword over the `width` prefix, into a caller-reusable
/// buffer — hoisted out of [`assign_blocked`] so per-step callers amortize
/// the allocation.
pub fn codeword_norms_into(cww: &[f32], k: usize, c_stride: usize, width: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), k);
    for (c, o) in out.iter_mut().enumerate() {
        *o = simd::sum_sq(&cww[c * c_stride..c * c_stride + width]);
    }
}

/// Nearest-codeword assignment over pre-whitened rows.
///
/// `vw`  — row-major vectors, one row every `v_stride` floats, of which the
///         first `width` dims participate in the distance;
/// `cww` — `k` codewords, one row every `c_stride` floats (same `width`
///         prefix participates — the feature-masked inductive path passes
///         `width < c_stride`);
/// `out` — one `i32` per row (its length defines the row count).
pub fn assign_blocked(
    vw: &[f32],
    width: usize,
    v_stride: usize,
    cww: &[f32],
    k: usize,
    c_stride: usize,
    out: &mut [i32],
) {
    if k == 0 {
        return;
    }
    let mut cnorm = vec![0.0f32; k];
    codeword_norms_into(cww, k, c_stride, width, &mut cnorm);
    assign_blocked_with_norms(vw, width, v_stride, cww, k, c_stride, &cnorm, out);
}

/// [`assign_blocked`] with the codeword norms supplied by the caller.
pub fn assign_blocked_with_norms(
    vw: &[f32],
    width: usize,
    v_stride: usize,
    cww: &[f32],
    k: usize,
    c_stride: usize,
    cnorm: &[f32],
    out: &mut [i32],
) {
    debug_assert!(width <= v_stride && width <= c_stride);
    debug_assert!(vw.len() >= out.len() * v_stride || out.is_empty());
    debug_assert!(cww.len() >= k * c_stride || k == 0);
    debug_assert_eq!(cnorm.len(), k);
    if k == 0 {
        return;
    }
    par::par_chunks_mut(out, ROW_BLOCK, |ci, ochunk| {
        let r0 = ci * ROW_BLOCK;
        for (rr, o) in ochunk.iter_mut().enumerate() {
            let r = r0 + rr;
            let v = &vw[r * v_stride..r * v_stride + width];
            let vn = simd::sum_sq(v);
            let mut best = f32::INFINITY;
            let mut arg = 0usize;
            for c in 0..k {
                let cr = &cww[c * c_stride..c * c_stride + width];
                let d2 = vn - 2.0 * simd::dot(v, cr) + cnorm[c];
                if d2 < best {
                    best = d2;
                    arg = c;
                }
            }
            *o = arg as i32;
        }
    });
}

/// i8-quantized codeword table for the two-stage FINDNEAREST: a first-pass
/// approximate scan over the quantized rows prunes the codebook down to a
/// provably-sufficient candidate set, then the survivors are rescored with
/// the exact f32 decomposition.
pub struct QuantCodebook {
    pub k: usize,
    pub width: usize,
    /// `k × width` row-major i8 codewords, `q = round(c / scale)`.
    pub q: Vec<i8>,
    /// Per-codeword dequant scale (`max|c_d| / 127`).
    pub scale: Vec<f32>,
    /// Exact f32 ‖c‖² per codeword (shared with the rescore pass).
    pub cnorm: Vec<f32>,
    /// Σ|c_d| per codeword — feeds the quantization-error bound.
    pub cabs: Vec<f32>,
}

impl QuantCodebook {
    pub fn build(cww: &[f32], k: usize, c_stride: usize, width: usize) -> Self {
        let mut q = vec![0i8; k * width];
        let mut scale = vec![0.0f32; k];
        let mut cnorm = vec![0.0f32; k];
        let mut cabs = vec![0.0f32; k];
        for c in 0..k {
            let row = &cww[c * c_stride..c * c_stride + width];
            let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let sc = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            scale[c] = sc;
            cnorm[c] = simd::sum_sq(row);
            cabs[c] = row.iter().map(|x| x.abs()).sum();
            let dst = &mut q[c * width..(c + 1) * width];
            for (d, &x) in row.iter().enumerate() {
                dst[d] = (x / sc).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantCodebook { k, width, q, scale, cnorm, cabs }
    }
}

/// Two-stage FINDNEAREST: i8 approximate scan → error-bounded candidate
/// set (∪ top-`m` by approximate distance) → exact f32 rescore.
///
/// Soundness: with per-element quantization error ≤ scale/2 on each side,
/// the approximate dot satisfies `|dot − approx| ≤ errdot` where
/// `errdot = (sv·Σ|c| + sc·Σ|v|)/2 + width·sv·sc/4`; any codeword whose
/// approximate distance minus `2·errdot` exceeds the best upper bound
/// `min(approx + 2·errdot)` cannot be the true argmin, so the exact winner
/// (and every exact tie, including the lowest index) always survives into
/// the rescore, which uses the same `‖v‖² − 2·v·c + ‖c‖²` arithmetic as
/// [`assign_blocked_with_norms`] in ascending index order with strict `<`.
/// The i8 dot itself accumulates in i32 (associative), so the candidate
/// set is identical across SIMD dispatches.
pub fn assign_pruned(
    vw: &[f32],
    width: usize,
    v_stride: usize,
    cww: &[f32],
    c_stride: usize,
    qcb: &QuantCodebook,
    m: usize,
    out: &mut [i32],
) {
    let k = qcb.k;
    debug_assert_eq!(qcb.width, width);
    debug_assert!(width <= v_stride && width <= c_stride);
    if k == 0 {
        return;
    }
    par::par_chunks_mut(out, ROW_BLOCK, |ci, ochunk| {
        let r0 = ci * ROW_BLOCK;
        // Per-chunk scratch, reused across the block's rows.
        let mut qv = vec![0i8; width];
        let mut ad2 = vec![0.0f32; k];
        let mut err = vec![0.0f32; k];
        let mut cand: Vec<usize> = Vec::with_capacity(k);
        let mut thresh_scratch = vec![0.0f32; k];
        for (rr, o) in ochunk.iter_mut().enumerate() {
            let r = r0 + rr;
            let v = &vw[r * v_stride..r * v_stride + width];
            let vn = simd::sum_sq(v);
            let vabs: f32 = v.iter().map(|x| x.abs()).sum();
            let amax = v.iter().fold(0.0f32, |mx, &x| mx.max(x.abs()));
            let sv = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            for (d, &x) in v.iter().enumerate() {
                qv[d] = (x / sv).round().clamp(-127.0, 127.0) as i8;
            }
            // First pass: approximate distances + per-codeword error radii.
            let mut ub_min = f32::INFINITY;
            for c in 0..k {
                let qrow = &qcb.q[c * width..(c + 1) * width];
                let approx_dot = simd::dot_i8(&qv, qrow) as f32 * sv * qcb.scale[c];
                let errdot = 0.5 * qcb.scale[c] * vabs
                    + 0.5 * sv * qcb.cabs[c]
                    + 0.25 * width as f32 * sv * qcb.scale[c];
                let d2 = vn - 2.0 * approx_dot + qcb.cnorm[c];
                // Inflate slightly so float rounding in the bound itself
                // can never exclude the true winner.
                let e = 2.0 * errdot * (1.0 + 1e-3) + 1e-6;
                ad2[c] = d2;
                err[c] = e;
                ub_min = ub_min.min(d2 + e);
            }
            // Candidates: everything whose lower bound beats the best upper
            // bound (soundness) ∪ top-m by approximate distance (recall
            // insurance for sloppy bounds).
            let m_eff = m.min(k);
            let thresh = if m_eff > 0 && m_eff < k {
                thresh_scratch.copy_from_slice(&ad2);
                let (_, t, _) = thresh_scratch
                    .select_nth_unstable_by(m_eff - 1, |a, b| a.total_cmp(b));
                *t
            } else {
                f32::INFINITY
            };
            cand.clear();
            for c in 0..k {
                if ad2[c] - err[c] <= ub_min || ad2[c] <= thresh {
                    cand.push(c);
                }
            }
            // Exact rescore, ascending index, strict < — same tie-breaking
            // as the single-stage kernel.
            let mut best = f32::INFINITY;
            let mut arg = cand[0];
            for &c in &cand {
                let cr = &cww[c * c_stride..c * c_stride + width];
                let d2 = vn - 2.0 * simd::dot(v, cr) + qcb.cnorm[c];
                if d2 < best {
                    best = d2;
                    arg = c;
                }
            }
            *o = arg as i32;
        }
    });
}

/// f64 (Σx, Σx²) per-dim partial over one `ROW_BLOCK·fp` chunk of raw
/// rows — the single source of truth shared by [`batch_mean_var`]'s
/// in-kernel parallel path and the shard coordinator (`crate::shard`),
/// so the two compute bit-identical partials by construction.
pub fn mean_var_chunk_partial(chunk: &[f32], fp: usize) -> (Vec<f64>, Vec<f64>) {
    let mut s = vec![0.0f64; fp];
    let mut s2 = vec![0.0f64; fp];
    for (j, &x) in chunk.iter().enumerate() {
        let d = j % fp;
        let x = x as f64;
        s[d] += x;
        s2[d] += x * x;
    }
    (s, s2)
}

/// Merge mean/var chunk partials **in iteration order** (callers must
/// supply ascending chunk order — f64 addition is not associative) and
/// finalize to per-dim mean and population variance over `b` rows.
pub fn mean_var_from_partials(
    partials: impl IntoIterator<Item = (Vec<f64>, Vec<f64>)>,
    b: usize,
    fp: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut s = vec![0.0f64; fp];
    let mut s2 = vec![0.0f64; fp];
    for (ps, ps2) in partials {
        for d in 0..fp {
            s[d] += ps[d];
            s2[d] += ps2[d];
        }
    }
    let bf = b as f64;
    let mean: Vec<f32> = s.iter().map(|&x| (x / bf) as f32).collect();
    let var: Vec<f32> = (0..fp)
        .map(|d| {
            let m = s[d] / bf;
            ((s2[d] / bf - m * m).max(0.0)) as f32
        })
        .collect();
    (mean, var)
}

/// Per-dim batch mean and (population) variance of `(b, fp)` rows, f64
/// accumulation, parallel over row blocks with a deterministic in-order
/// merge.  Matches `numpy`'s `v.mean(0)` / `v.var(0)` semantics used by
/// `python/compile/vq.py`.
pub fn batch_mean_var(v: &[f32], b: usize, fp: usize) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(v.len(), b * fp);
    let partials =
        par::par_map_chunks(v, ROW_BLOCK * fp, |_ci, chunk| mean_var_chunk_partial(chunk, fp));
    mean_var_from_partials(partials, b, fp)
}

/// Per-cluster (counts, vector sums) partial over one `ROW_BLOCK` chunk
/// of whitened rows — `vw` holds exactly the chunk's rows
/// (`assign.len() · fp` floats).  Shared by [`cluster_accumulate`] and
/// the shard coordinator, same reasoning as
/// [`mean_var_chunk_partial`].
pub fn cluster_chunk_partial(
    vw: &[f32],
    assign: &[i32],
    fp: usize,
    k: usize,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(vw.len(), assign.len() * fp);
    let mut counts = vec![0.0f32; k];
    let mut sums = vec![0.0f32; k * fp];
    for (i, &ai) in assign.iter().enumerate() {
        let a = ai as usize;
        debug_assert!(a < k);
        counts[a] += 1.0;
        let row = &vw[i * fp..(i + 1) * fp];
        // Element-wise adds — the SIMD path is bit-identical to the
        // scalar scatter loop it replaces.
        simd::add_assign(&mut sums[a * fp..(a + 1) * fp], row);
    }
    (counts, sums)
}

/// Merge cluster chunk partials **in iteration order** (ascending chunk
/// order — the `simd::add_assign` merges are f32 and order-sensitive).
pub fn cluster_from_partials(
    partials: impl IntoIterator<Item = (Vec<f32>, Vec<f32>)>,
    fp: usize,
    k: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut counts = vec![0.0f32; k];
    let mut sums = vec![0.0f32; k * fp];
    for (pc, ps) in partials {
        simd::add_assign(&mut counts, &pc);
        simd::add_assign(&mut sums, &ps);
    }
    (counts, sums)
}

/// Scatter whitened rows into per-cluster counts and vector sums
/// (`onehot.sum(0)`, `onehotᵀ @ vw`), parallel over row blocks with
/// deterministic in-order merge of the per-block partials.
pub fn cluster_accumulate(
    vw: &[f32],
    assign: &[i32],
    b: usize,
    fp: usize,
    k: usize,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(vw.len(), b * fp);
    debug_assert_eq!(assign.len(), b);
    let partials = par::par_map_chunks(assign, ROW_BLOCK, |ci, chunk| {
        let row0 = ci * ROW_BLOCK;
        cluster_chunk_partial(&vw[row0 * fp..(row0 + chunk.len()) * fp], chunk, fp, k)
    });
    cluster_from_partials(partials, fp, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The seed's scalar FINDNEAREST (whitening recomputed per element) —
    /// kept as the reference the blocked kernel must agree with.
    fn scalar_assign(
        v: &[f32],
        fp: usize,
        mean: &[f32],
        var: &[f32],
        cww: &[f32],
        k: usize,
    ) -> Vec<i32> {
        let b = v.len() / fp;
        let mut out = vec![0i32; b];
        for i in 0..b {
            let mut best = f32::INFINITY;
            let mut arg = 0usize;
            for c in 0..k {
                let mut d2 = 0.0f32;
                for d in 0..fp {
                    let w = (v[i * fp + d] - mean[d]) / (var[d] + EPS).sqrt();
                    let diff = w - cww[c * fp + d];
                    d2 += diff * diff;
                }
                if d2 < best {
                    best = d2;
                    arg = c;
                }
            }
            out[i] = arg as i32;
        }
        out
    }

    #[test]
    fn blocked_matches_scalar_reference_randomized() {
        // Property (replacing the old fixed-shape parity test): across
        // randomized (b, k, fp) — including b below ROW_BLOCK (serial tail
        // path), b larger than several blocks, and k = 1 — the blocked
        // decomposed-distance assignment agrees with the seed's scalar
        // whiten-in-the-inner-loop loop.  The two float paths may pick
        // different winners only on genuine near-ties (distances equal to
        // within f32 rounding), which the property verifies explicitly.
        crate::util::prop::check("assign_parity", 30, |rng, _case| {
            let b = 1 + rng.below(3 * ROW_BLOCK);
            let k = 1 + rng.below(33);
            let fp = 1 + rng.below(16);
            let v: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
            let cww: Vec<f32> = (0..k * fp).map(|_| 0.5 * rng.gauss_f32()).collect();
            let mean: Vec<f32> = (0..fp).map(|_| 0.2 * rng.gauss_f32()).collect();
            let var: Vec<f32> = (0..fp).map(|_| 0.5 + rng.f32()).collect();
            let want = scalar_assign(&v, fp, &mean, &var, &cww, k);
            let inv = inv_std(&var);
            let vw = whiten(&v, fp, &mean, &inv);
            let mut got = vec![0i32; b];
            assign_blocked(&vw, fp, fp, &cww, k, fp, &mut got);
            let d2 = |i: usize, c: usize| -> f64 {
                let mut acc = 0.0f64;
                for d in 0..fp {
                    let w = ((v[i * fp + d] - mean[d]) / (var[d] + EPS).sqrt()) as f64;
                    let diff = w - cww[c * fp + d] as f64;
                    acc += diff * diff;
                }
                acc
            };
            for i in 0..b {
                if got[i] == want[i] {
                    continue;
                }
                let (dg, dw) = (d2(i, got[i] as usize), d2(i, want[i] as usize));
                if (dg - dw).abs() > 1e-5 * dg.max(dw).max(1e-12) {
                    return Err(format!(
                        "b={b} k={k} fp={fp} row {i}: blocked chose {} (d²={dg:.9}), \
                         scalar chose {} (d²={dw:.9}) — not a near-tie",
                        got[i], want[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ties_break_to_lowest_index() {
        // Duplicate codewords produce bit-identical distances: the winner
        // must be the lowest index, exactly like the scalar loop.
        let fp = 4;
        let proto = [0.5f32, -1.0, 0.25, 2.0];
        let mut cww = Vec::new();
        for _ in 0..6 {
            cww.extend_from_slice(&proto); // all six codewords identical
        }
        let vw: Vec<f32> = vec![0.1, 0.2, 0.3, 0.4, -3.0, 1.0, 0.0, 9.0];
        let mut got = vec![0i32; 2];
        assign_blocked(&vw, fp, fp, &cww, 6, fp, &mut got);
        assert_eq!(got, vec![0, 0]);
        // and with two distinct groups, a row equidistant picks the first
        let cww2: Vec<f32> = vec![1.0, 0.0, -1.0, 0.0, 1.0, 0.0, -1.0, 0.0];
        let mut got2 = vec![0i32; 1];
        assign_blocked(&[0.0, 0.0], 2, 2, &cww2, 4, 2, &mut got2);
        assert_eq!(got2, vec![0]);
    }

    #[test]
    fn prefix_width_ignores_masked_dims() {
        // width < stride: the trailing (gradient) dims must not matter.
        let mut rng = Rng::new(3);
        let (b, k, fp, width) = (40, 7, 8, 5);
        let cww: Vec<f32> = (0..k * fp).map(|_| rng.gauss_f32()).collect();
        let mut vw: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
        let mut a1 = vec![0i32; b];
        assign_blocked(&vw, width, fp, &cww, k, fp, &mut a1);
        for i in 0..b {
            for d in width..fp {
                vw[i * fp + d] = 1e6; // poison masked dims
            }
        }
        let mut a2 = vec![0i32; b];
        assign_blocked(&vw, width, fp, &cww, k, fp, &mut a2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn pruned_matches_blocked_exactly() {
        // The candidate set provably contains every exact-distance tie of
        // the true argmin, and the rescore reuses the single-stage kernel's
        // arithmetic (same dispatch within this process) — so the pruned
        // path must agree with assign_blocked bit-for-bit, for every m.
        crate::util::prop::check("pruned_parity", 12, |rng, _case| {
            let b = 1 + rng.below(2 * ROW_BLOCK);
            let k = PRUNE_MIN_K + rng.below(80);
            let fp = 4 + rng.below(28);
            let vw: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
            let cww: Vec<f32> = (0..k * fp).map(|_| 0.7 * rng.gauss_f32()).collect();
            let mut want = vec![0i32; b];
            assign_blocked(&vw, fp, fp, &cww, k, fp, &mut want);
            let qcb = QuantCodebook::build(&cww, k, fp, fp);
            for m in [1usize, PRUNE_TOP_M, k] {
                let mut got = vec![0i32; b];
                assign_pruned(&vw, fp, fp, &cww, fp, &qcb, m, &mut got);
                if got != want {
                    return Err(format!("b={b} k={k} fp={fp} m={m}: prune diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pruned_handles_duplicate_and_zero_codewords() {
        // Ties (duplicate codewords) must still break to the lowest index
        // through the prune, and all-zero rows/codewords must not divide
        // by a zero scale.
        let fp = 6;
        let k = PRUNE_MIN_K;
        let mut cww = vec![0.0f32; k * fp];
        for c in 2..k {
            for d in 0..fp {
                cww[c * fp + d] = (c * fp + d) as f32 * 0.01 + 1.0;
            }
        }
        // codewords 0 and 1 are both all-zero → exact tie at the origin.
        let vw = vec![0.0f32; fp];
        let qcb = QuantCodebook::build(&cww, k, fp, fp);
        let mut got = vec![7i32];
        assign_pruned(&vw, fp, fp, &cww, fp, &qcb, 4, &mut got);
        assert_eq!(got, vec![0]);
    }

    #[test]
    fn with_norms_matches_allocating_wrapper() {
        let mut rng = Rng::new(11);
        let (b, k, fp) = (90, 17, 10);
        let vw: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
        let cww: Vec<f32> = (0..k * fp).map(|_| rng.gauss_f32()).collect();
        let mut a1 = vec![0i32; b];
        assign_blocked(&vw, fp, fp, &cww, k, fp, &mut a1);
        let mut cnorm = vec![0.0f32; k];
        codeword_norms_into(&cww, k, fp, fp, &mut cnorm);
        let mut a2 = vec![0i32; b];
        assign_blocked_with_norms(&vw, fp, fp, &cww, k, fp, &cnorm, &mut a2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn mean_var_match_two_pass_reference() {
        let mut rng = Rng::new(5);
        let (b, fp) = (301, 9);
        let v: Vec<f32> = (0..b * fp).map(|_| 3.0 * rng.gauss_f32() + 1.5).collect();
        let (m, va) = batch_mean_var(&v, b, fp);
        for d in 0..fp {
            let mut s = 0.0f64;
            for i in 0..b {
                s += v[i * fp + d] as f64;
            }
            let mr = s / b as f64;
            let mut s2 = 0.0f64;
            for i in 0..b {
                let x = v[i * fp + d] as f64 - mr;
                s2 += x * x;
            }
            let vr = s2 / b as f64;
            assert!((m[d] as f64 - mr).abs() < 1e-5, "mean[{d}]");
            assert!((va[d] as f64 - vr).abs() < 1e-4, "var[{d}]");
        }
    }

    #[test]
    fn cluster_accumulate_matches_scatter() {
        let mut rng = Rng::new(7);
        let (b, k, fp) = (200, 13, 6);
        let vw: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
        let assign: Vec<i32> = (0..b).map(|_| rng.below(k) as i32).collect();
        let (counts, sums) = cluster_accumulate(&vw, &assign, b, fp, k);
        let mut wc = vec![0.0f32; k];
        let mut ws = vec![0.0f32; k * fp];
        for i in 0..b {
            let a = assign[i] as usize;
            wc[a] += 1.0;
            for d in 0..fp {
                ws[a * fp + d] += vw[i * fp + d];
            }
        }
        for c in 0..k {
            assert!((counts[c] - wc[c]).abs() < 1e-4);
        }
        for j in 0..k * fp {
            assert!((sums[j] - ws[j]).abs() < 1e-3, "sums[{j}]");
        }
    }
}
