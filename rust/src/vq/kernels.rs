//! Blocked, thread-parallel VQ kernels — the L3 hot path shared by the
//! trainers, the native backend and `benches/hot_paths.rs`.
//!
//! FINDNEAREST uses the classic distance decomposition
//! `‖v − c‖² = ‖v‖² − 2·v·cᵀ + ‖c‖²` over contiguous row blocks: whitening
//! is hoisted out of the O(b·k·fp) inner loop (the seed's scalar loop paid a
//! divide + sqrt per element), codeword norms are computed once, and rows
//! are distributed over threads.  Codewords are always scanned in ascending
//! index order with a strict `<` comparison, so ties break to the lowest
//! index — identical to the scalar reference and to
//! `python/compile/kernels/ref.py`.

use crate::util::par;
use crate::vq::EPS;

/// Rows per parallel work unit (large enough to amortize thread dispatch,
/// small enough to balance uneven tails).
pub const ROW_BLOCK: usize = 64;

/// `1 / sqrt(var + EPS)` per dim — the whitening scale, computed once —
/// into a reused buffer.
pub fn inv_std_into(var: &[f32], out: &mut [f32]) {
    debug_assert_eq!(var.len(), out.len());
    for (o, &v) in out.iter_mut().zip(var) {
        *o = 1.0 / (v + EPS).sqrt();
    }
}

/// Allocating wrapper of [`inv_std_into`].
pub fn inv_std(var: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; var.len()];
    inv_std_into(var, &mut out);
    out
}

/// Whiten `(b, fp)` row-major vectors: `w = (v − mean) · inv`, into a
/// reused buffer (every element overwritten).
pub fn whiten_into(v: &[f32], fp: usize, mean: &[f32], inv: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len() % fp.max(1), 0);
    debug_assert_eq!(mean.len(), fp);
    debug_assert_eq!(inv.len(), fp);
    debug_assert_eq!(v.len(), out.len());
    par::par_chunks_mut(out, ROW_BLOCK * fp, |ci, chunk| {
        let base = ci * ROW_BLOCK * fp;
        for (j, o) in chunk.iter_mut().enumerate() {
            let d = (base + j) % fp;
            *o = (v[base + j] - mean[d]) * inv[d];
        }
    });
}

/// Allocating wrapper of [`whiten_into`].
pub fn whiten(v: &[f32], fp: usize, mean: &[f32], inv: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; v.len()];
    whiten_into(v, fp, mean, inv, &mut out);
    out
}

/// Nearest-codeword assignment over pre-whitened rows.
///
/// `vw`  — row-major vectors, one row every `v_stride` floats, of which the
///         first `width` dims participate in the distance;
/// `cww` — `k` codewords, one row every `c_stride` floats (same `width`
///         prefix participates — the feature-masked inductive path passes
///         `width < c_stride`);
/// `out` — one `i32` per row (its length defines the row count).
pub fn assign_blocked(
    vw: &[f32],
    width: usize,
    v_stride: usize,
    cww: &[f32],
    k: usize,
    c_stride: usize,
    out: &mut [i32],
) {
    debug_assert!(width <= v_stride && width <= c_stride);
    debug_assert!(vw.len() >= out.len() * v_stride || out.is_empty());
    debug_assert!(cww.len() >= k * c_stride || k == 0);
    if k == 0 {
        return;
    }
    // ‖c‖² once per codeword, shared by every row.
    let cnorm: Vec<f32> = (0..k)
        .map(|c| {
            let row = &cww[c * c_stride..c * c_stride + width];
            row.iter().map(|x| x * x).sum()
        })
        .collect();
    let cnorm = &cnorm;
    par::par_chunks_mut(out, ROW_BLOCK, |ci, ochunk| {
        let r0 = ci * ROW_BLOCK;
        for (rr, o) in ochunk.iter_mut().enumerate() {
            let r = r0 + rr;
            let v = &vw[r * v_stride..r * v_stride + width];
            let vn: f32 = v.iter().map(|x| x * x).sum();
            let mut best = f32::INFINITY;
            let mut arg = 0usize;
            for c in 0..k {
                let cr = &cww[c * c_stride..c * c_stride + width];
                let mut dot = 0.0f32;
                for d in 0..width {
                    dot += v[d] * cr[d];
                }
                let d2 = vn - 2.0 * dot + cnorm[c];
                if d2 < best {
                    best = d2;
                    arg = c;
                }
            }
            *o = arg as i32;
        }
    });
}

/// Per-dim batch mean and (population) variance of `(b, fp)` rows, f64
/// accumulation, parallel over row blocks with a deterministic in-order
/// merge.  Matches `numpy`'s `v.mean(0)` / `v.var(0)` semantics used by
/// `python/compile/vq.py`.
pub fn batch_mean_var(v: &[f32], b: usize, fp: usize) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(v.len(), b * fp);
    let partials = par::par_map_chunks(v, ROW_BLOCK * fp, |_ci, chunk| {
        let mut s = vec![0.0f64; fp];
        let mut s2 = vec![0.0f64; fp];
        for (j, &x) in chunk.iter().enumerate() {
            let d = j % fp;
            let x = x as f64;
            s[d] += x;
            s2[d] += x * x;
        }
        (s, s2)
    });
    let mut s = vec![0.0f64; fp];
    let mut s2 = vec![0.0f64; fp];
    for (ps, ps2) in partials {
        for d in 0..fp {
            s[d] += ps[d];
            s2[d] += ps2[d];
        }
    }
    let bf = b as f64;
    let mean: Vec<f32> = s.iter().map(|&x| (x / bf) as f32).collect();
    let var: Vec<f32> = (0..fp)
        .map(|d| {
            let m = s[d] / bf;
            ((s2[d] / bf - m * m).max(0.0)) as f32
        })
        .collect();
    (mean, var)
}

/// Scatter whitened rows into per-cluster counts and vector sums
/// (`onehot.sum(0)`, `onehotᵀ @ vw`), parallel over row blocks with
/// deterministic in-order merge of the per-block partials.
pub fn cluster_accumulate(
    vw: &[f32],
    assign: &[i32],
    b: usize,
    fp: usize,
    k: usize,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(vw.len(), b * fp);
    debug_assert_eq!(assign.len(), b);
    let partials = par::par_map_chunks(assign, ROW_BLOCK, |ci, chunk| {
        let row0 = ci * ROW_BLOCK;
        let mut counts = vec![0.0f32; k];
        let mut sums = vec![0.0f32; k * fp];
        for (off, &ai) in chunk.iter().enumerate() {
            let i = row0 + off;
            let a = ai as usize;
            debug_assert!(a < k);
            counts[a] += 1.0;
            let row = &vw[i * fp..(i + 1) * fp];
            let dst = &mut sums[a * fp..(a + 1) * fp];
            for d in 0..fp {
                dst[d] += row[d];
            }
        }
        (counts, sums)
    });
    let mut counts = vec![0.0f32; k];
    let mut sums = vec![0.0f32; k * fp];
    for (pc, ps) in partials {
        for c in 0..k {
            counts[c] += pc[c];
        }
        for j in 0..k * fp {
            sums[j] += ps[j];
        }
    }
    (counts, sums)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The seed's scalar FINDNEAREST (whitening recomputed per element) —
    /// kept as the reference the blocked kernel must agree with.
    fn scalar_assign(
        v: &[f32],
        fp: usize,
        mean: &[f32],
        var: &[f32],
        cww: &[f32],
        k: usize,
    ) -> Vec<i32> {
        let b = v.len() / fp;
        let mut out = vec![0i32; b];
        for i in 0..b {
            let mut best = f32::INFINITY;
            let mut arg = 0usize;
            for c in 0..k {
                let mut d2 = 0.0f32;
                for d in 0..fp {
                    let w = (v[i * fp + d] - mean[d]) / (var[d] + EPS).sqrt();
                    let diff = w - cww[c * fp + d];
                    d2 += diff * diff;
                }
                if d2 < best {
                    best = d2;
                    arg = c;
                }
            }
            out[i] = arg as i32;
        }
        out
    }

    #[test]
    fn blocked_matches_scalar_reference_randomized() {
        // Property (replacing the old fixed-shape parity test): across
        // randomized (b, k, fp) — including b below ROW_BLOCK (serial tail
        // path), b larger than several blocks, and k = 1 — the blocked
        // decomposed-distance assignment agrees with the seed's scalar
        // whiten-in-the-inner-loop loop.  The two float paths may pick
        // different winners only on genuine near-ties (distances equal to
        // within f32 rounding), which the property verifies explicitly.
        crate::util::prop::check("assign_parity", 30, |rng, _case| {
            let b = 1 + rng.below(3 * ROW_BLOCK);
            let k = 1 + rng.below(33);
            let fp = 1 + rng.below(16);
            let v: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
            let cww: Vec<f32> = (0..k * fp).map(|_| 0.5 * rng.gauss_f32()).collect();
            let mean: Vec<f32> = (0..fp).map(|_| 0.2 * rng.gauss_f32()).collect();
            let var: Vec<f32> = (0..fp).map(|_| 0.5 + rng.f32()).collect();
            let want = scalar_assign(&v, fp, &mean, &var, &cww, k);
            let inv = inv_std(&var);
            let vw = whiten(&v, fp, &mean, &inv);
            let mut got = vec![0i32; b];
            assign_blocked(&vw, fp, fp, &cww, k, fp, &mut got);
            let d2 = |i: usize, c: usize| -> f64 {
                let mut acc = 0.0f64;
                for d in 0..fp {
                    let w = ((v[i * fp + d] - mean[d]) / (var[d] + EPS).sqrt()) as f64;
                    let diff = w - cww[c * fp + d] as f64;
                    acc += diff * diff;
                }
                acc
            };
            for i in 0..b {
                if got[i] == want[i] {
                    continue;
                }
                let (dg, dw) = (d2(i, got[i] as usize), d2(i, want[i] as usize));
                if (dg - dw).abs() > 1e-5 * dg.max(dw).max(1e-12) {
                    return Err(format!(
                        "b={b} k={k} fp={fp} row {i}: blocked chose {} (d²={dg:.9}), \
                         scalar chose {} (d²={dw:.9}) — not a near-tie",
                        got[i], want[i]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ties_break_to_lowest_index() {
        // Duplicate codewords produce bit-identical distances: the winner
        // must be the lowest index, exactly like the scalar loop.
        let fp = 4;
        let proto = [0.5f32, -1.0, 0.25, 2.0];
        let mut cww = Vec::new();
        for _ in 0..6 {
            cww.extend_from_slice(&proto); // all six codewords identical
        }
        let vw: Vec<f32> = vec![0.1, 0.2, 0.3, 0.4, -3.0, 1.0, 0.0, 9.0];
        let mut got = vec![0i32; 2];
        assign_blocked(&vw, fp, fp, &cww, 6, fp, &mut got);
        assert_eq!(got, vec![0, 0]);
        // and with two distinct groups, a row equidistant picks the first
        let cww2: Vec<f32> = vec![1.0, 0.0, -1.0, 0.0, 1.0, 0.0, -1.0, 0.0];
        let mut got2 = vec![0i32; 1];
        assign_blocked(&[0.0, 0.0], 2, 2, &cww2, 4, 2, &mut got2);
        assert_eq!(got2, vec![0]);
    }

    #[test]
    fn prefix_width_ignores_masked_dims() {
        // width < stride: the trailing (gradient) dims must not matter.
        let mut rng = Rng::new(3);
        let (b, k, fp, width) = (40, 7, 8, 5);
        let cww: Vec<f32> = (0..k * fp).map(|_| rng.gauss_f32()).collect();
        let mut vw: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
        let mut a1 = vec![0i32; b];
        assign_blocked(&vw, width, fp, &cww, k, fp, &mut a1);
        for i in 0..b {
            for d in width..fp {
                vw[i * fp + d] = 1e6; // poison masked dims
            }
        }
        let mut a2 = vec![0i32; b];
        assign_blocked(&vw, width, fp, &cww, k, fp, &mut a2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn mean_var_match_two_pass_reference() {
        let mut rng = Rng::new(5);
        let (b, fp) = (301, 9);
        let v: Vec<f32> = (0..b * fp).map(|_| 3.0 * rng.gauss_f32() + 1.5).collect();
        let (m, va) = batch_mean_var(&v, b, fp);
        for d in 0..fp {
            let mut s = 0.0f64;
            for i in 0..b {
                s += v[i * fp + d] as f64;
            }
            let mr = s / b as f64;
            let mut s2 = 0.0f64;
            for i in 0..b {
                let x = v[i * fp + d] as f64 - mr;
                s2 += x * x;
            }
            let vr = s2 / b as f64;
            assert!((m[d] as f64 - mr).abs() < 1e-5, "mean[{d}]");
            assert!((va[d] as f64 - vr).abs() < 1e-4, "var[{d}]");
        }
    }

    #[test]
    fn cluster_accumulate_matches_scatter() {
        let mut rng = Rng::new(7);
        let (b, k, fp) = (200, 13, 6);
        let vw: Vec<f32> = (0..b * fp).map(|_| rng.gauss_f32()).collect();
        let assign: Vec<i32> = (0..b).map(|_| rng.below(k) as i32).collect();
        let (counts, sums) = cluster_accumulate(&vw, &assign, b, fp, k);
        let mut wc = vec![0.0f32; k];
        let mut ws = vec![0.0f32; k * fp];
        for i in 0..b {
            let a = assign[i] as usize;
            wc[a] += 1.0;
            for d in 0..fp {
                ws[a * fp + d] += vw[i * fp + d];
            }
        }
        for c in 0..k {
            assert!((counts[c] - wc[c]).abs() < 1e-4);
        }
        for j in 0..k * fp {
            assert!((sums[j] - ws[j]).abs() < 1e-3, "sums[{j}]");
        }
    }
}
