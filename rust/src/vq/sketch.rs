//! Sketch builder — the L3 hot path.  Per mini-batch and per layer it
//! produces the Eq. 6/7 inputs from the CSR graph + the global assignment
//! table R:
//!
//!   C_in      (b, b)        intra-batch convolution block (exact)
//!   C̃_out     (n_br, b, k)  out-of-batch sketches  C_out R_j
//!   (C̃ᵀ)_out  (n_br, b, k)  transposed-conv sketches (Cᵀ)_out R_j
//!
//! and for learnable convolutions the masked count sketches
//! (mask_in, M_out, M_outᵀ, cnt_out).  Complexity O(b·d̄·n_br) — scanning
//! each batch node's in/out arcs once per branch.

use crate::graph::{Conv, Graph};
use crate::util::tensor::Tensor;
use crate::vq::LayerVq;

/// Reusable per-batch scratch (avoids O(n) clears between batches).
pub struct SketchScratch {
    /// node → position in current batch, or -1.
    pos: Vec<i32>,
}

impl SketchScratch {
    pub fn new(n: usize) -> SketchScratch {
        SketchScratch { pos: vec![-1; n] }
    }

    /// Grow the position table to cover `n` nodes (no-op when it already
    /// does).  The serving path calls this when inductively-admitted node
    /// ids extend past the dataset's `n` — the only allocation admission
    /// adds to an otherwise steady-state session, and only on growth.
    pub fn ensure(&mut self, n: usize) {
        if self.pos.len() < n {
            self.pos.resize(n, -1);
        }
    }

    /// Mark a batch: `pos_of` then answers membership + position.  Public
    /// for the serving cache's forward-only sketch builders.
    pub fn mark(&mut self, batch: &[u32]) {
        for (i, &g) in batch.iter().enumerate() {
            self.pos[g as usize] = i as i32;
        }
    }

    pub fn unmark(&mut self, batch: &[u32]) {
        for &g in batch {
            self.pos[g as usize] = -1;
        }
    }

    /// Position of `node` in the currently-marked batch, or -1.
    pub fn pos_of(&self, node: usize) -> i32 {
        self.pos[node]
    }
}

/// Fixed-convolution sketches for one layer (GCN / SAGE mean aggregator),
/// written into caller-owned buffers — a trainer session's persistent
/// input slots are rebuilt in place every batch, so the per-step assembly
/// allocates nothing here.
#[allow(clippy::too_many_arguments)]
pub fn build_fixed_into(graph: &Graph, conv: Conv, batch: &[u32], layer: &LayerVq,
                        scratch: &mut SketchScratch,
                        c_in: &mut [f32], c_out: &mut [f32], ct_out: &mut [f32]) {
    let b = batch.len();
    let (nb, k) = (layer.plan.n_br, layer.k);
    let n = layer.n;
    debug_assert_eq!(c_in.len(), b * b);
    debug_assert_eq!(c_out.len(), nb * b * k);
    debug_assert_eq!(ct_out.len(), nb * b * k);
    c_in.fill(0.0);
    c_out.fill(0.0);
    ct_out.fill(0.0);
    scratch.mark(batch);
    for (i, &gi) in batch.iter().enumerate() {
        let gi = gi as usize;
        // forward messages: in-neighbors u → gi with coef C[gi, u]
        for &u in graph.in_neighbors(gi) {
            let coef = graph.coef(conv, u as usize, gi);
            let p = scratch.pos[u as usize];
            if p >= 0 {
                c_in[i * b + p as usize] += coef;
            } else {
                for j in 0..nb {
                    let v = layer.assign[j * n + u as usize] as usize;
                    c_out[(j * b + i) * k + v] += coef;
                }
            }
        }
        if conv.with_self_loops() {
            c_in[i * b + i] += graph.coef(conv, gi, gi);
        }
        // backward ("blue") messages: Cᵀ[gi, w] = C[w, gi] over out-arcs
        // gi → w; only out-of-batch targets (in-batch handled by C_inᵀ).
        for &w in graph.out_neighbors(gi) {
            if scratch.pos[w as usize] >= 0 {
                continue;
            }
            let coef = graph.coef(conv, gi, w as usize);
            for j in 0..nb {
                let v = layer.assign[j * n + w as usize] as usize;
                ct_out[(j * b + i) * k + v] += coef;
            }
        }
    }
    scratch.unmark(batch);
}

/// Allocating wrapper of [`build_fixed_into`].
pub fn build_fixed(graph: &Graph, conv: Conv, batch: &[u32], layer: &LayerVq,
                   scratch: &mut SketchScratch)
                   -> (Tensor, Tensor, Tensor) {
    let b = batch.len();
    let (nb, k) = (layer.plan.n_br, layer.k);
    let mut c_in = vec![0.0f32; b * b];
    let mut c_out = vec![0.0f32; nb * b * k];
    let mut ct_out = vec![0.0f32; nb * b * k];
    build_fixed_into(graph, conv, batch, layer, scratch, &mut c_in, &mut c_out, &mut ct_out);
    (
        Tensor::from_f32(&[b, b], c_in),
        Tensor::from_f32(&[nb, b, k], c_out),
        Tensor::from_f32(&[nb, b, k], ct_out),
    )
}

/// Learnable-convolution count sketches for one layer (GAT / Transformer),
/// written into caller-owned buffers: mask_in[i,j] = 𝔠 over the batch
/// block (A+I), M_out[i,v] = #out-of-batch in-neighbors of i in cluster v,
/// M_outᵀ[i,v] = same over out-arcs.
pub fn build_learnable_into(graph: &Graph, batch: &[u32], layer: &LayerVq,
                            scratch: &mut SketchScratch,
                            mask_in: &mut [f32], m_out: &mut [f32], m_out_t: &mut [f32]) {
    let b = batch.len();
    let k = layer.k;
    let n = layer.n;
    debug_assert_eq!(layer.plan.n_br, 1, "learnable convs use a single branch");
    debug_assert_eq!(mask_in.len(), b * b);
    debug_assert_eq!(m_out.len(), b * k);
    debug_assert_eq!(m_out_t.len(), b * k);
    mask_in.fill(0.0);
    m_out.fill(0.0);
    m_out_t.fill(0.0);
    scratch.mark(batch);
    for (i, &gi) in batch.iter().enumerate() {
        let gi = gi as usize;
        mask_in[i * b + i] = 1.0; // self loop of 𝔠 = A + I
        for &u in graph.in_neighbors(gi) {
            let p = scratch.pos[u as usize];
            if p >= 0 {
                mask_in[i * b + p as usize] = 1.0;
            } else {
                let v = layer.assign[u as usize] as usize;
                m_out[i * k + v] += 1.0;
            }
        }
        for &w in graph.out_neighbors(gi) {
            if scratch.pos[w as usize] < 0 {
                let v = layer.assign[w as usize] as usize;
                m_out_t[i * k + v] += 1.0;
            }
        }
    }
    scratch.unmark(batch);
    let _ = n;
}

/// Allocating wrapper of [`build_learnable_into`].
pub fn build_learnable(graph: &Graph, batch: &[u32], layer: &LayerVq,
                       scratch: &mut SketchScratch)
                       -> (Tensor, Tensor, Tensor) {
    let b = batch.len();
    let k = layer.k;
    let mut mask_in = vec![0.0f32; b * b];
    let mut m_out = vec![0.0f32; b * k];
    let mut m_out_t = vec![0.0f32; b * k];
    build_learnable_into(graph, batch, layer, scratch, &mut mask_in, &mut m_out, &mut m_out_t);
    (
        Tensor::from_f32(&[b, b], mask_in),
        Tensor::from_f32(&[b, k], m_out),
        Tensor::from_f32(&[b, k], m_out_t),
    )
}

/// Global out-of-batch cluster histogram (Transformer global attention),
/// written into a caller-owned buffer: cnt_out[v] = |{u ∉ batch : R[u] = v}|.
pub fn build_cnt_out_into(batch: &[u32], layer: &LayerVq,
                          scratch: &mut SketchScratch, cnt: &mut [f32]) {
    let n = layer.n;
    debug_assert_eq!(cnt.len(), layer.k);
    cnt.fill(0.0);
    scratch.mark(batch);
    for u in 0..n {
        if scratch.pos[u] < 0 {
            cnt[layer.assign[u] as usize] += 1.0;
        }
    }
    scratch.unmark(batch);
}

/// Allocating wrapper of [`build_cnt_out_into`].
pub fn build_cnt_out(batch: &[u32], layer: &LayerVq,
                     scratch: &mut SketchScratch) -> Tensor {
    let mut cnt = vec![0.0f32; layer.k];
    build_cnt_out_into(batch, layer, scratch, &mut cnt);
    Tensor::from_f32(&[layer.k], cnt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayerPlan;
    use crate::util::rng::Rng;
    use crate::vq::LayerVq;

    fn dense_conv(g: &Graph, conv: Conv) -> Vec<f32> {
        let n = g.n;
        let mut c = vec![0.0f32; n * n];
        for v in 0..n {
            for &u in g.in_neighbors(v) {
                c[v * n + u as usize] += g.coef(conv, u as usize, v);
            }
            if conv.with_self_loops() {
                c[v * n + v] += g.coef(conv, v, v);
            }
        }
        c
    }

    fn setup(n: usize, seed: u64, nb: usize) -> (Graph, LayerVq) {
        let mut rng = Rng::new(seed);
        let mut edges = Vec::new();
        for _ in 0..n * 3 {
            let u = rng.below(n) as u32;
            let v = rng.below(n) as u32;
            edges.push((u, v));
        }
        let g = Graph::from_undirected(n, &edges);
        let plan = LayerPlan {
            f_in: 8, h_out: 4, g_dim: 4, n_br: nb, fp: 12 / nb, cf: 12, heads: 1,
        };
        let lv = LayerVq::init(&plan, 5, n, &mut rng);
        (g, lv)
    }

    #[test]
    fn fixed_sketch_matches_dense_reference() {
        for &conv in &[Conv::GcnSym, Conv::SageMean] {
            let (g, lv) = setup(40, 9, 2);
            let batch: Vec<u32> = vec![1, 5, 17, 30, 39];
            let b = batch.len();
            let mut scratch = SketchScratch::new(g.n);
            let (c_in, c_out, ct_out) = build_fixed(&g, conv, &batch, &lv, &mut scratch);
            let dense = dense_conv(&g, conv);
            // C_in == C[batch, batch]
            for i in 0..b {
                for j in 0..b {
                    let want = dense[batch[i] as usize * g.n + batch[j] as usize];
                    assert!((c_in.f[i * b + j] - want).abs() < 1e-5,
                            "c_in[{i},{j}]");
                }
            }
            // C̃_out[j][i][v] == Σ_{u∉batch, R_j[u]=v} C[batch_i, u]
            let inb: std::collections::HashSet<u32> = batch.iter().cloned().collect();
            for br in 0..2 {
                for i in 0..b {
                    for v in 0..5 {
                        let mut want = 0.0f32;
                        for u in 0..g.n as u32 {
                            if !inb.contains(&u)
                                && lv.assign[br * g.n + u as usize] as usize == v
                            {
                                want += dense[batch[i] as usize * g.n + u as usize];
                            }
                        }
                        let got = c_out.f[(br * b + i) * 5 + v];
                        assert!((got - want).abs() < 1e-5, "c_out[{br},{i},{v}]");
                        // transposed side against denseᵀ
                        let mut want_t = 0.0f32;
                        for u in 0..g.n as u32 {
                            if !inb.contains(&u)
                                && lv.assign[br * g.n + u as usize] as usize == v
                            {
                                want_t += dense[u as usize * g.n + batch[i] as usize];
                            }
                        }
                        let got_t = ct_out.f[(br * b + i) * 5 + v];
                        assert!((got_t - want_t).abs() < 1e-5,
                                "ct_out[{br},{i},{v}]");
                    }
                }
            }
        }
    }

    #[test]
    fn sketch_preserves_all_messages() {
        // Paper's headline property: row sums of [C_in | C̃_out] equal the
        // full-graph convolution row sums — NO message is dropped.
        let (g, lv) = setup(50, 11, 3);
        let batch: Vec<u32> = vec![0, 2, 8, 21, 33, 49];
        let b = batch.len();
        let mut scratch = SketchScratch::new(g.n);
        let (c_in, c_out, _) = build_fixed(&g, Conv::GcnSym, &batch, &lv, &mut scratch);
        let dense = dense_conv(&g, Conv::GcnSym);
        for i in 0..b {
            let full: f32 = (0..g.n).map(|u| dense[batch[i] as usize * g.n + u]).sum();
            for br in 0..3 {
                let intra: f32 = (0..b).map(|j| c_in.f[i * b + j]).sum();
                let out: f32 = (0..5).map(|v| c_out.f[(br * b + i) * 5 + v]).sum();
                assert!((intra + out - full).abs() < 1e-4,
                        "row {i} branch {br}: {} vs {}", intra + out, full);
            }
        }
    }

    #[test]
    fn learnable_counts_match_brute_force() {
        let (g, mut lv) = setup(30, 13, 1);
        lv.plan.n_br = 1;
        let batch: Vec<u32> = vec![3, 7, 12, 29];
        let b = batch.len();
        let mut scratch = SketchScratch::new(g.n);
        let (mask_in, m_out, m_out_t) = build_learnable(&g, &batch, &lv, &mut scratch);
        let inb: std::collections::HashSet<u32> = batch.iter().cloned().collect();
        for i in 0..b {
            assert_eq!(mask_in.f[i * b + i], 1.0);
            for (j, &gj) in batch.iter().enumerate() {
                let adj = g.in_neighbors(batch[i] as usize).contains(&gj);
                let want = if adj || i == j { 1.0 } else { 0.0 };
                assert_eq!(mask_in.f[i * b + j], want, "mask[{i},{j}]");
            }
            for v in 0..5 {
                let want = g
                    .in_neighbors(batch[i] as usize)
                    .iter()
                    .filter(|&&u| !inb.contains(&u) && lv.assign[u as usize] == v as u32)
                    .count() as f32;
                assert_eq!(m_out.f[i * 5 + v], want);
                let want_t = g
                    .out_neighbors(batch[i] as usize)
                    .iter()
                    .filter(|&&u| !inb.contains(&u) && lv.assign[u as usize] == v as u32)
                    .count() as f32;
                assert_eq!(m_out_t.f[i * 5 + v], want_t);
            }
        }
    }

    #[test]
    fn cnt_out_partitions_out_of_batch_nodes() {
        let (g, lv) = setup(30, 17, 1);
        let batch: Vec<u32> = vec![1, 2, 3];
        let mut scratch = SketchScratch::new(g.n);
        let cnt = build_cnt_out(&batch, &lv, &mut scratch);
        assert!((cnt.f.iter().sum::<f32>() - (g.n - 3) as f32).abs() < 1e-5);
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // property: building with two different batches back-to-back gives
        // the same result as with fresh scratch (no state leaks).
        crate::util::prop::check("scratch_reuse", 10, |rng, _| {
            let (g, lv) = setup(25, rng.next_u64(), 2);
            let b1: Vec<u32> = rng.sample_distinct(25, 6);
            let b2: Vec<u32> = rng.sample_distinct(25, 6);
            let mut s = SketchScratch::new(g.n);
            let _ = build_fixed(&g, Conv::GcnSym, &b1, &lv, &mut s);
            let (a1, a2, a3) = build_fixed(&g, Conv::GcnSym, &b2, &lv, &mut s);
            let mut fresh = SketchScratch::new(g.n);
            let (f1, f2, f3) = build_fixed(&g, Conv::GcnSym, &b2, &lv, &mut fresh);
            if a1.f != f1.f || a2.f != f2.f || a3.f != f3.f {
                return Err("scratch leaked state".into());
            }
            Ok(())
        });
        // and the scratch ends clean
    }
}
