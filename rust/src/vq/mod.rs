//! VQ codebook state (paper Alg. 2): product-VQ branches with implicit
//! whitening + EMA cluster statistics, and the global assignment table R
//! maintained across mini-batches.  Mirrors python/compile/vq.py (the
//! executable spec) — semantics are locked by tests on both sides.

pub mod kernels;
pub mod sketch;

use crate::runtime::manifest::LayerPlan;
use crate::util::rng::Rng;
use crate::util::simd;
use crate::util::tensor::Tensor;

pub const EPS: f32 = 1e-5;

/// Warm-start prior mass per cluster: small enough that the first real
/// mini-batches dominate the EMA (codewords become data-driven within ~3
/// steps instead of lingering near random init for ~1/(1-γ) steps — which
/// left the learnable-convolution backbones training against noise for
/// their first epochs), large enough to keep untouched clusters and the
/// refresh guard well-defined.  Mirrors `compile/vq.py::VqState.PRIOR_MASS`.
pub const PRIOR_MASS: f32 = 0.01;

/// One product-VQ branch: k codewords over an fp-dim slice of the concat
/// (feature ‖ gradient) space.
#[derive(Debug, Clone)]
pub struct VqBranch {
    pub k: usize,
    pub fp: usize,
    /// Whitened codewords Ṽ̄, row-major (k, fp).
    pub cww: Vec<f32>,
    /// EMA cluster sizes η (k).
    pub counts: Vec<f32>,
    /// EMA cluster vector sums Σ, row-major (k, fp).
    pub sums: Vec<f32>,
    /// Smoothed whitening stats Ẽ[V], Ṽar[V] (fp).
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

impl VqBranch {
    pub fn init(k: usize, fp: usize, rng: &mut Rng) -> VqBranch {
        let mut cww = vec![0.0f32; k * fp];
        for x in cww.iter_mut() {
            *x = 0.1 * rng.gauss_f32();
        }
        // sums/counts seeded consistently (cww == sums/counts) at the small
        // warm-start prior mass, so step one already pulls codewords ~80%
        // of the way to the batch cluster means.
        VqBranch {
            k,
            fp,
            sums: cww.iter().map(|x| x * PRIOR_MASS).collect(),
            cww,
            counts: vec![PRIOR_MASS; k],
            mean: vec![0.0; fp],
            var: vec![1.0; fp],
        }
    }

    /// Inverse whitening transform: raw-space codewords (Eq. 6/7 inputs).
    pub fn raw_codewords_into(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.k * self.fp);
        for v in 0..self.k {
            for d in 0..self.fp {
                out[v * self.fp + d] = self.cww[v * self.fp + d]
                    * (self.var[d] + EPS).sqrt()
                    + self.mean[d];
            }
        }
    }

    /// Alg. 2 body: EMA whitening stats → whiten batch → EMA cluster
    /// stats → codeword refresh.  `v` is (b, fp) raw vectors; `assign` the
    /// in-graph FINDNEAREST result (computed against the pre-update state).
    /// Runs on the blocked parallel kernels in [`kernels`].
    pub fn update(&mut self, v: &[f32], assign: &[i32], gamma: f32, beta: f32) {
        self.update_expiring(v, assign, gamma, beta, None);
    }

    /// [`VqBranch::update`] with optional dead-code expiry (the
    /// `threshold_ema_dead_code` idiom): after the codeword refresh,
    /// clusters whose EMA count fell below the threshold are re-seeded
    /// from rows of the current batch, drawn deterministically from the
    /// caller's RNG in ascending cluster order.  `None` (the default
    /// everywhere) keeps the trajectory bit-identical to [`update`].
    pub fn update_expiring(
        &mut self,
        v: &[f32],
        assign: &[i32],
        gamma: f32,
        beta: f32,
        expiry: Option<(f32, &mut Rng)>,
    ) {
        let b = assign.len();
        if b == 0 {
            // An empty batch has no statistics: the seed's per-dim mean
            // divided by b and produced NaN whitening stats here.
            return;
        }
        debug_assert_eq!(v.len(), b * self.fp);
        let (m, va) = kernels::batch_mean_var(v, b, self.fp);
        let inv = self.apply_moments(&m, &va, gamma, beta);
        let vw = kernels::whiten(v, self.fp, &self.mean, &inv);
        let (bc, bs) = kernels::cluster_accumulate(&vw, assign, b, self.fp, self.k);
        self.apply_cluster_partials(&bc, &bs, gamma);
        if let Some((threshold, rng)) = expiry {
            self.expire_dead(v, b, &inv, threshold, rng);
        }
    }

    /// First half of the EMA update: blend the batch moments into the
    /// smoothed whitening stats, decay the cluster EMA mass, and return
    /// the fresh whitening scale.  Split out so the shard coordinator
    /// (`crate::shard`) can run the identical sequence around its own
    /// partial-merge — bit-identity by shared code, not by re-derivation.
    pub fn apply_moments(&mut self, m: &[f32], va: &[f32], gamma: f32, beta: f32) -> Vec<f32> {
        // EMA blend (mul/mul/add — the SIMD path is bit-identical).
        simd::lerp(&mut self.mean, m, beta);
        simd::lerp(&mut self.var, va, beta);
        // EMA cluster sizes + sums over whitened vectors
        simd::scale(&mut self.counts, gamma);
        simd::scale(&mut self.sums, gamma);
        kernels::inv_std(&self.var)
    }

    /// Second half of the EMA update: fold the batch's merged cluster
    /// (counts, sums) into the EMA state and refresh the codewords.
    pub fn apply_cluster_partials(&mut self, bc: &[f32], bs: &[f32], gamma: f32) {
        let g1 = 1.0 - gamma;
        simd::axpy(&mut self.counts, g1, bc);
        simd::axpy(&mut self.sums, g1, bs);
        // Refresh only clusters with mass; empty clusters keep their
        // position — dividing by a vanishing count would mint NaN/Inf
        // codewords that poison every later assignment.
        for c in 0..self.k {
            let cnt = self.counts[c];
            if cnt > 1e-6 && cnt.is_finite() {
                for d in 0..self.fp {
                    self.cww[c * self.fp + d] = self.sums[c * self.fp + d] / cnt;
                }
            }
        }
    }

    /// Dead-code expiry: re-seed every cluster whose EMA count is below
    /// `threshold` with a whitened row sampled from the current batch.
    /// Runs in ascending cluster order and draws from `rng` only for
    /// dead clusters, so the draw sequence — and with it the trajectory —
    /// is deterministic and independent of the shard count (expiry
    /// always runs on the coordinator, after the merged refresh).
    pub fn expire_dead(
        &mut self,
        v: &[f32],
        b: usize,
        inv: &[f32],
        threshold: f32,
        rng: &mut Rng,
    ) {
        let fp = self.fp;
        let mut row = vec![0.0f32; fp];
        for c in 0..self.k {
            if self.counts[c] < threshold {
                let i = rng.below(b);
                // Whitening one raw row with the post-blend stats gives a
                // result bit-identical to the batch's `vw` row, so both
                // the unsharded and sharded paths can re-derive it here
                // without shipping whitened rows back from the shards.
                simd::whiten_row(&mut row, &v[i * fp..(i + 1) * fp], &self.mean, inv);
                self.cww[c * fp..(c + 1) * fp].copy_from_slice(&row);
                self.sums[c * fp..(c + 1) * fp].copy_from_slice(&row);
                self.counts[c] = 1.0;
            }
        }
    }

    /// Host-side FINDNEAREST (tests + inductive bootstrap fallback), via
    /// the blocked parallel kernel.  Large codebooks on batches big enough
    /// to amortize the table build take the two-stage quantized prune —
    /// whose result is provably identical to the single-stage scan (the
    /// error-bounded candidate set keeps every exact tie of the argmin).
    pub fn assign_host(&self, v: &[f32]) -> Vec<i32> {
        debug_assert_eq!(v.len() % self.fp, 0);
        let b = v.len() / self.fp;
        let inv = kernels::inv_std(&self.var);
        let vw = kernels::whiten(v, self.fp, &self.mean, &inv);
        let mut out = vec![0i32; b];
        if self.k >= kernels::PRUNE_MIN_K && b >= 64 {
            let qcb = kernels::QuantCodebook::build(&self.cww, self.k, self.fp, self.fp);
            kernels::assign_pruned(
                &vw, self.fp, self.fp, &self.cww, self.fp, &qcb, kernels::PRUNE_TOP_M, &mut out,
            );
        } else {
            kernels::assign_blocked(&vw, self.fp, self.fp, &self.cww, self.k, self.fp, &mut out);
        }
        out
    }
}

/// Per-layer codebook: branches + the global node→codeword table R.
#[derive(Debug)]
pub struct LayerVq {
    pub plan: LayerPlan,
    pub k: usize,
    pub branches: Vec<VqBranch>,
    /// Assignment table, (n_br, n) row-major: R_j[node] ∈ [0, k).
    pub assign: Vec<u32>,
    pub n: usize,
}

impl LayerVq {
    pub fn init(plan: &LayerPlan, k: usize, n: usize, rng: &mut Rng) -> LayerVq {
        let branches = (0..plan.n_br).map(|_| VqBranch::init(k, plan.fp, rng)).collect();
        let assign = (0..plan.n_br * n).map(|_| rng.below(k) as u32).collect();
        LayerVq { plan: plan.clone(), k, branches, assign, n }
    }

    pub fn assign_of(&self, branch: usize, node: usize) -> usize {
        self.assign[branch * self.n + node] as usize
    }

    /// Artifact input buffers: raw codewords cw, whitened cww, mean, var.
    /// The `_into` forms fill a session's persistent input slot in place
    /// (the per-step assembly path); the `_tensor` wrappers allocate.
    pub fn cw_into(&self, out: &mut [f32]) {
        let (k, fp) = (self.k, self.plan.fp);
        debug_assert_eq!(out.len(), self.plan.n_br * k * fp);
        for (j, br) in self.branches.iter().enumerate() {
            br.raw_codewords_into(&mut out[j * k * fp..(j + 1) * k * fp]);
        }
    }

    pub fn cw_tensor(&self) -> Tensor {
        let (nb, k, fp) = (self.plan.n_br, self.k, self.plan.fp);
        let mut data = vec![0.0f32; nb * k * fp];
        self.cw_into(&mut data);
        Tensor::from_f32(&[nb, k, fp], data)
    }

    pub fn cww_into(&self, out: &mut [f32]) {
        let (k, fp) = (self.k, self.plan.fp);
        debug_assert_eq!(out.len(), self.plan.n_br * k * fp);
        for (j, br) in self.branches.iter().enumerate() {
            out[j * k * fp..(j + 1) * k * fp].copy_from_slice(&br.cww);
        }
    }

    pub fn cww_tensor(&self) -> Tensor {
        let (nb, k, fp) = (self.plan.n_br, self.k, self.plan.fp);
        let mut data = vec![0.0f32; nb * k * fp];
        self.cww_into(&mut data);
        Tensor::from_f32(&[nb, k, fp], data)
    }

    pub fn mean_into(&self, out: &mut [f32]) {
        let fp = self.plan.fp;
        debug_assert_eq!(out.len(), self.plan.n_br * fp);
        for (j, br) in self.branches.iter().enumerate() {
            out[j * fp..(j + 1) * fp].copy_from_slice(&br.mean);
        }
    }

    pub fn mean_tensor(&self) -> Tensor {
        let (nb, fp) = (self.plan.n_br, self.plan.fp);
        let mut data = vec![0.0f32; nb * fp];
        self.mean_into(&mut data);
        Tensor::from_f32(&[nb, fp], data)
    }

    pub fn var_into(&self, out: &mut [f32]) {
        let fp = self.plan.fp;
        debug_assert_eq!(out.len(), self.plan.n_br * fp);
        for (j, br) in self.branches.iter().enumerate() {
            out[j * fp..(j + 1) * fp].copy_from_slice(&br.var);
        }
    }

    pub fn var_tensor(&self) -> Tensor {
        let (nb, fp) = (self.plan.n_br, self.plan.fp);
        let mut data = vec![0.0f32; nb * fp];
        self.var_into(&mut data);
        Tensor::from_f32(&[nb, fp], data)
    }

    /// Lay the concat space out per node: `[feat | grad | zero-pad]` — the
    /// (b, cf) matrix the product-VQ branches slice.  Shared with the
    /// shard coordinator so both paths build bit-identical branch rows.
    pub fn concat_z(&self, xfeat: &Tensor, gvec: &Tensor) -> Vec<f32> {
        let (f, g, cf) = (self.plan.f_in, self.plan.g_dim, self.plan.cf);
        debug_assert_eq!(xfeat.shape[1], f);
        debug_assert_eq!(gvec.shape[1], g);
        let b = xfeat.shape[0];
        let mut z = vec![0.0f32; b * cf];
        for i in 0..b {
            z[i * cf..i * cf + f].copy_from_slice(&xfeat.f[i * f..(i + 1) * f]);
            z[i * cf + f..i * cf + f + g]
                .copy_from_slice(&gvec.f[i * g..(i + 1) * g]);
        }
        z
    }

    /// Copy branch `j`'s (b, fp) slice out of the concat matrix `z`.
    pub fn branch_rows_into(&self, z: &[f32], j: usize, out: &mut [f32]) {
        let (fp, cf) = (self.plan.fp, self.plan.cf);
        let b = z.len() / cf.max(1);
        debug_assert_eq!(out.len(), b * fp);
        for i in 0..b {
            out[i * fp..(i + 1) * fp]
                .copy_from_slice(&z[i * cf + j * fp..i * cf + (j + 1) * fp]);
        }
    }

    /// Write the fresh batch assignments for branch `j` into the global
    /// node→codeword table R.
    pub fn write_assignments(&mut self, j: usize, batch: &[u32], a: &[i32]) {
        for (i, &node) in batch.iter().enumerate() {
            self.assign[j * self.n + node as usize] = a[i] as u32;
        }
    }

    /// Apply a train step's outputs: update branch EMAs with the batch's
    /// concat vectors and write the fresh assignments into R.
    ///
    /// xfeat: (b, f_in) features; gvec: (b, g_dim) gradients;
    /// assign: (n_br, b) int32 from the in-graph L1 kernel.
    pub fn update_from_batch(&mut self, batch: &[u32], xfeat: &Tensor,
                             gvec: &Tensor, assign: &Tensor,
                             gamma: f32, beta: f32) {
        self.update_from_batch_expiring(batch, xfeat, gvec, assign, gamma, beta, &mut None);
    }

    /// [`LayerVq::update_from_batch`] with the dead-code expiry knob
    /// threaded through (see [`VqBranch::update_expiring`]).  Branches
    /// draw from the shared RNG in ascending branch order, so the draw
    /// sequence is deterministic.
    pub fn update_from_batch_expiring(&mut self, batch: &[u32], xfeat: &Tensor,
                                      gvec: &Tensor, assign: &Tensor,
                                      gamma: f32, beta: f32,
                                      expiry: &mut Option<(f32, Rng)>) {
        let b = batch.len();
        let (nb, fp) = (self.plan.n_br, self.plan.fp);
        debug_assert_eq!(xfeat.shape, &[b, self.plan.f_in]);
        debug_assert_eq!(gvec.shape, &[b, self.plan.g_dim]);
        debug_assert_eq!(assign.shape, &[nb, b]);
        let z = self.concat_z(xfeat, gvec);
        let mut vbr = vec![0.0f32; b * fp];
        for j in 0..nb {
            self.branch_rows_into(&z, j, &mut vbr);
            let a = &assign.i[j * b..(j + 1) * b];
            let e = expiry.as_mut().map(|(t, r)| (*t, &mut *r));
            self.branches[j].update_expiring(&vbr, a, gamma, beta, e);
            self.write_assignments(j, batch, a);
        }
    }
}

/// All layers' codebooks for one VQ-GNN model instance.
#[derive(Debug)]
pub struct VqModel {
    pub layers: Vec<LayerVq>,
}

impl VqModel {
    pub fn init(plans: &[LayerPlan], k: usize, n: usize, seed: u64) -> VqModel {
        let mut rng = Rng::new(seed ^ 0x56515Fu64);
        VqModel {
            layers: plans.iter().map(|p| LayerVq::init(p, k, n, &mut rng)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn plan(f: usize, h: usize, nb: usize) -> LayerPlan {
        let cf = ((f + h) + nb - 1) / nb * nb;
        LayerPlan { f_in: f, h_out: h, g_dim: h, n_br: nb, fp: cf / nb, cf, heads: 1 }
    }

    #[test]
    fn whitening_roundtrip() {
        let mut rng = Rng::new(1);
        let mut br = VqBranch::init(4, 3, &mut rng);
        br.mean = vec![1.0, -2.0, 0.5];
        br.var = vec![4.0, 0.25, 1.0];
        let mut raw = vec![0.0; 12];
        br.raw_codewords_into(&mut raw);
        for v in 0..4 {
            for d in 0..3 {
                let back = (raw[v * 3 + d] - br.mean[d]) / (br.var[d] + EPS).sqrt();
                assert!((back - br.cww[v * 3 + d]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn ema_mass_interpolates() {
        let mut rng = Rng::new(2);
        let mut br = VqBranch::init(8, 4, &mut rng);
        let total0: f32 = br.counts.iter().sum();
        let b = 64;
        let v: Vec<f32> = (0..b * 4).map(|_| rng.gauss_f32()).collect();
        let assign = br.assign_host(&v);
        br.update(&v, &assign, 0.9, 0.9);
        let total1: f32 = br.counts.iter().sum();
        let (lo, hi) = if total0 < b as f32 { (total0, b as f32) } else { (b as f32, total0) };
        assert!(total1 >= lo - 1e-3 && total1 <= hi + 1e-3, "{total1}");
        assert!(br.counts.iter().all(|&c| c >= 0.0));
    }

    #[test]
    fn online_kmeans_recovers_centroids() {
        let mut rng = Rng::new(3);
        let centers = [[4.0f32, 4.0], [-4.0, 4.0], [4.0, -4.0], [-4.0, -4.0]];
        let mut br = VqBranch::init(4, 2, &mut rng);
        for (c, row) in centers.iter().enumerate() {
            br.cww[c * 2] = row[0] * 0.1;
            br.cww[c * 2 + 1] = row[1] * 0.1;
        }
        for _ in 0..300 {
            let mut v = vec![0.0f32; 128 * 2];
            for i in 0..128 {
                let c = rng.below(4);
                v[i * 2] = centers[c][0] + 0.3 * rng.gauss_f32();
                v[i * 2 + 1] = centers[c][1] + 0.3 * rng.gauss_f32();
            }
            let a = br.assign_host(&v);
            br.update(&v, &a, 0.95, 0.95);
        }
        let mut raw = vec![0.0f32; 8];
        br.raw_codewords_into(&mut raw);
        for c in centers {
            let best = (0..4)
                .map(|v| {
                    let dx = raw[v * 2] - c[0];
                    let dy = raw[v * 2 + 1] - c[1];
                    (dx * dx + dy * dy).sqrt()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(best < 0.5, "center {c:?} best {best}");
        }
    }

    #[test]
    fn update_from_batch_writes_assignment_table() {
        let p = plan(6, 4, 2);
        let mut rng = Rng::new(4);
        let mut lv = LayerVq::init(&p, 8, 50, &mut rng);
        let batch = vec![3u32, 10, 49];
        let xf = Tensor::from_f32(&[3, 6], (0..18).map(|x| x as f32 * 0.1).collect());
        let gv = Tensor::from_f32(&[3, 4], (0..12).map(|x| x as f32 * 0.01).collect());
        let asg = Tensor::from_i32(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        lv.update_from_batch(&batch, &xf, &gv, &asg, 0.9, 0.9);
        assert_eq!(lv.assign_of(0, 3), 1);
        assert_eq!(lv.assign_of(0, 10), 2);
        assert_eq!(lv.assign_of(1, 49), 6);
        // untouched nodes keep their assignment in [0, k)
        assert!(lv.assign_of(0, 0) < 8);
    }

    #[test]
    fn empty_clusters_never_go_nan() {
        // Drive every cluster's EMA mass toward zero while feeding all
        // vectors to cluster 0: codewords must stay finite throughout.
        let mut rng = Rng::new(6);
        let mut br = VqBranch::init(8, 4, &mut rng);
        let v: Vec<f32> = (0..32 * 4).map(|_| rng.gauss_f32()).collect();
        let assign = vec![0i32; 32];
        for _ in 0..400 {
            br.update(&v, &assign, 0.05, 0.9); // aggressive decay
            assert!(br.cww.iter().all(|x| x.is_finite()), "NaN codeword");
            assert!(br.counts.iter().all(|c| c.is_finite() && *c >= 0.0));
        }
        // clusters 1.. lost all mass but kept their (finite) positions
        for c in 1..8 {
            assert!(br.counts[c] < 1e-3);
        }
    }

    #[test]
    fn dead_code_expiry_reseeds_from_batch() {
        // Starve clusters 1.. (every vector assigned to cluster 0) with
        // aggressive decay: expiry must re-seed them from batch rows
        // instead of leaving them stranded at their init position.
        let mut rng = Rng::new(9);
        let mut br = VqBranch::init(8, 4, &mut rng);
        let v: Vec<f32> = (0..32 * 4).map(|_| rng.gauss_f32()).collect();
        let assign = vec![0i32; 32];
        let mut erng = Rng::new(123);
        for _ in 0..50 {
            br.update_expiring(&v, &assign, 0.05, 0.9, Some((0.5, &mut erng)));
            assert!(br.cww.iter().all(|x| x.is_finite()));
        }
        for c in 1..8 {
            // re-seeded on the final step: unit mass, codeword == sums row
            assert!((br.counts[c] - 1.0).abs() < 1e-6, "cluster {c} not re-seeded");
            for d in 0..4 {
                assert_eq!(br.cww[c * 4 + d].to_bits(), br.sums[c * 4 + d].to_bits());
            }
        }
    }

    #[test]
    fn expiry_with_live_clusters_is_inert() {
        // With every cluster above threshold the expiry path must neither
        // change the trajectory nor consume RNG draws — the off-by-default
        // bit-identity contract.
        let mut rng = Rng::new(10);
        let mut a = VqBranch::init(4, 3, &mut rng);
        let mut b = a.clone();
        let v: Vec<f32> = (0..64 * 3).map(|_| rng.gauss_f32()).collect();
        let assign = a.assign_host(&v);
        let mut e1 = Rng::new(77);
        let mut e2 = Rng::new(77);
        a.update(&v, &assign, 0.9, 0.9);
        b.update_expiring(&v, &assign, 0.9, 0.9, Some((1e-9, &mut e1)));
        assert_eq!(a.cww, b.cww);
        assert_eq!(a.counts, b.counts);
        assert_eq!(a.sums, b.sums);
        assert_eq!(e1.below(1 << 20), e2.below(1 << 20), "expiry consumed RNG draws");
    }

    #[test]
    fn empty_batch_update_is_a_noop() {
        let mut rng = Rng::new(8);
        let mut br = VqBranch::init(4, 3, &mut rng);
        let before = br.clone();
        br.update(&[], &[], 0.9, 0.9);
        assert_eq!(br.cww, before.cww);
        assert_eq!(br.counts, before.counts);
        assert_eq!(br.mean, before.mean);
        assert_eq!(br.var, before.var);
    }

    #[test]
    fn host_assign_matches_brute_force() {
        let mut rng = Rng::new(5);
        let br = VqBranch::init(16, 8, &mut rng);
        let v: Vec<f32> = (0..32 * 8).map(|_| rng.gauss_f32()).collect();
        let got = br.assign_host(&v);
        for i in 0..32 {
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..16 {
                let mut d2 = 0.0;
                for d in 0..8 {
                    let w = (v[i * 8 + d] - br.mean[d]) / (br.var[d] + EPS).sqrt();
                    let diff = w - br.cww[c * 8 + d];
                    d2 += diff * diff;
                }
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            assert_eq!(got[i] as usize, best.1);
        }
    }
}
