//! Deterministic PRNG (SplitMix64 core) with the distributions the
//! coordinator needs — the `rand` crate is not available offline.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes (sampling,
/// shuffles, synthetic data).  Deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second gaussian from Box-Muller.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal (Box-Muller, cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates for
    /// small k/n, reservoir otherwise).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<u32> = (0..n as u32).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let x = self.below(n) as u32;
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100, 5), (100, 80), (10, 10)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&x| (x as usize) < n));
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
