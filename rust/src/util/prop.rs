//! Mini property-test driver (proptest is unavailable offline): run a
//! predicate over many seeded random cases; on failure, report the seed so
//! the case replays deterministically.

use super::rng::Rng;

/// Run `prop(rng, case_index)` for `cases` seeds; panic with the failing
/// seed on the first violation (returning Err(msg) or panicking counts).
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper producing Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("below_in_range", 50, |rng, _| {
            let n = 1 + rng.below(100);
            let x = rng.below(n);
            if x < n {
                Ok(())
            } else {
                Err(format!("{x} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic]
    fn reports_failures() {
        check("always_fails", 3, |_, _| Err("nope".into()));
    }
}
