//! Offline substrates: JSON, PRNG, tensors, stats/benchmarking, and a mini
//! property-test driver (serde/rand/criterion/proptest are unavailable in
//! this image — DESIGN.md §7).

pub mod alloc;
pub mod bench;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod tensor;

use std::time::Instant;

/// Wall-clock stopwatch with named laps (used by the experiment harness).
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Mean / population std of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

#[cfg(test)]
mod tests {
    #[test]
    fn mean_std_basic() {
        let (m, s) = super::mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}
