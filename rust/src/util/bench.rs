//! Mini benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/std/min reporting, runnable under
//! `cargo bench` via `harness = false` targets; plus [`Pacer`], the
//! open-loop load generator shared by the saturation bench and
//! `vq-gnn client --rate`.

use std::time::{Duration, Instant};

/// Open-loop request pacer: issues against a fixed wall-clock schedule
/// (`rate_per_s` arrivals/second from construction time), so lateness is
/// NEVER forgiven — if the consumer stalls, `due()` grows.  This is what
/// distinguishes an open-loop saturation bench from a closed loop, where
/// a slow server quietly throttles its own offered load.
pub struct Pacer {
    t0: Instant,
    /// Seconds between scheduled arrivals.
    per: f64,
    issued: usize,
}

impl Pacer {
    pub fn new(rate_per_s: f64) -> Pacer {
        Pacer { t0: Instant::now(), per: 1.0 / rate_per_s.max(1e-9), issued: 0 }
    }

    /// How many arrivals the schedule owes right now (0 = ahead of
    /// schedule).
    pub fn due(&self) -> usize {
        let scheduled = (self.t0.elapsed().as_secs_f64() / self.per) as usize;
        scheduled.saturating_sub(self.issued)
    }

    pub fn note_issued(&mut self, n: usize) {
        self.issued += n;
    }

    /// Sleep until the next scheduled arrival (at most `cap` — callers
    /// poll other work on a bounded cadence).
    pub fn sleep_until_next(&self, cap: Duration) {
        let next = self.per * (self.issued + 1) as f64;
        let now = self.t0.elapsed().as_secs_f64();
        if next > now {
            std::thread::sleep(Duration::from_secs_f64(next - now).min(cap));
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        let (v, unit) = humanize(self.mean_ns);
        let (vmin, umin) = humanize(self.min_ns);
        println!(
            "{:<44} {:>10.3} {}/iter (min {:.3} {}, ±{:.1}%, n={})",
            self.name,
            v,
            unit,
            vmin,
            umin,
            100.0 * self.std_ns / self.mean_ns.max(1e-9),
            self.iters
        );
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns >= 1e9 {
        (ns / 1e9, "s")
    } else if ns >= 1e6 {
        (ns / 1e6, "ms")
    } else if ns >= 1e3 {
        (ns / 1e3, "us")
    } else {
        (ns, "ns")
    }
}

/// Run `f` for ~`target_secs` (after warmup), return timing stats.
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once).ceil() as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
    };
    r.report();
    r
}
