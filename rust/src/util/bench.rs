//! Mini benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/std/min reporting, runnable under
//! `cargo bench` via `harness = false` targets.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        let (v, unit) = humanize(self.mean_ns);
        let (vmin, umin) = humanize(self.min_ns);
        println!(
            "{:<44} {:>10.3} {}/iter (min {:.3} {}, ±{:.1}%, n={})",
            self.name,
            v,
            unit,
            vmin,
            umin,
            100.0 * self.std_ns / self.mean_ns.max(1e-9),
            self.iters
        );
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns >= 1e9 {
        (ns / 1e9, "s")
    } else if ns >= 1e6 {
        (ns / 1e6, "ms")
    } else if ns >= 1e3 {
        (ns / 1e3, "us")
    } else {
        (ns, "ns")
    }
}

/// Run `f` for ~`target_secs` (after warmup), return timing stats.
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once).ceil() as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
    };
    r.report();
    r
}
