//! Host-side tensors: the marshalling type between the coordinator and the
//! PJRT runtime (and the payload format of the python goldens).

use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn from_str(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "i32" => Some(DType::I32),
            _ => None,
        }
    }

    pub fn bytes(self) -> usize {
        4
    }
}

/// Dense row-major host tensor (f32 or i32 payload).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub f: Vec<f32>,
    pub i: Vec<i32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), dtype: DType::F32, f: vec![0.0; n], i: vec![] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), dtype: DType::F32, f: data, i: vec![] }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), dtype: DType::I32, f: vec![], i: data }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::from_f32(&[], vec![x])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.bytes()
    }

    /// Load a raw little-endian .bin payload (golden format).
    pub fn from_bin(path: &Path, shape: &[usize], dtype: DType) -> std::io::Result<Tensor> {
        let raw = std::fs::read(path)?;
        let n: usize = shape.iter().product();
        assert_eq!(raw.len(), n * 4, "{}: bad payload size", path.display());
        Ok(match dtype {
            DType::F32 => {
                let f = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Tensor::from_f32(shape, f)
            }
            DType::I32 => {
                let i = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Tensor::from_i32(shape, i)
            }
        })
    }

    /// Max |a - b| between two f32 tensors (shape-checked).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        assert_eq!(self.dtype, DType::F32);
        self.f
            .iter()
            .zip(&other.f)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ‖a−b‖/(‖b‖+eps).
    pub fn rel_l2(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (a, b) in self.f.iter().zip(&other.f) {
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        (num.sqrt() / (den.sqrt() + 1e-12)) as f32
    }
}

/// Disjoint mutable borrows of two tensors of a slice (`i < j`).  The
/// in-place assembly paths fill sketch pairs (`c_in`/`c_out`,
/// `mask_in`/`m_out`) with one builder pass, so they need simultaneous
/// `&mut` access to two slots of a session's input vector.
pub fn mut2(ts: &mut [Tensor], i: usize, j: usize) -> (&mut Tensor, &mut Tensor) {
    assert!(i < j && j < ts.len(), "mut2: bad indices {i}, {j} (len {})", ts.len());
    let (left, right) = ts.split_at_mut(j);
    (&mut left[i], &mut right[0])
}

/// Disjoint mutable borrows of three tensors of a slice (`i < j < k`):
/// the fixed-convolution sketch triple (`c_in`/`c_out`/`ct_out`).
pub fn mut3(
    ts: &mut [Tensor],
    i: usize,
    j: usize,
    k: usize,
) -> (&mut Tensor, &mut Tensor, &mut Tensor) {
    assert!(
        i < j && j < k && k < ts.len(),
        "mut3: bad indices {i}, {j}, {k} (len {})",
        ts.len()
    );
    let (left, right) = ts.split_at_mut(j);
    let (mid, tail) = right.split_at_mut(k - j);
    (&mut left[i], &mut mid[0], &mut tail[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_muts_hit_the_right_slots() {
        let mut ts: Vec<Tensor> =
            (0..5).map(|i| Tensor::from_f32(&[1], vec![i as f32])).collect();
        {
            let (a, b) = mut2(&mut ts, 1, 4);
            a.f[0] = 10.0;
            b.f[0] = 40.0;
        }
        {
            let (a, b, c) = mut3(&mut ts, 0, 2, 3);
            a.f[0] = -1.0;
            b.f[0] = -2.0;
            c.f[0] = -3.0;
        }
        let got: Vec<f32> = ts.iter().map(|t| t.f[0]).collect();
        assert_eq!(got, vec![-1.0, 10.0, -2.0, -3.0, 40.0]);
    }

    #[test]
    fn construct_and_measure() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.bytes(), 24);
        let u = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 7.]);
        assert!((t.max_abs_diff(&u) - 1.0).abs() < 1e-6);
        assert!(t.rel_l2(&t) < 1e-6);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_f32(&[2, 2], vec![1.0]);
    }
}
