//! Runtime-dispatched SIMD primitives under the scalar kernel layer.
//!
//! Dispatch is resolved ONCE per process (cached in an atomic), so every
//! call within a build takes the same code path — that is what keeps the
//! repo's bit-identity tests (pipelined == serial, concurrent == serial,
//! metrics-on == off) green: they compare two runs of the *same* binary,
//! and both runs see the same arithmetic.
//!
//! Exactness contract, per primitive:
//!
//! * **Bit-exact vs scalar** (no FMA, no reassociation — per-element ops
//!   only): `scale`, `add_assign`, `whiten_row`, `lerp`, `scale_into`,
//!   `scale2_into`. Safe anywhere, including paths pinned by bitwise
//!   comparisons against a scalar twin.
//! * **Exact by integer associativity**: `dot_i8` (i32 accumulation —
//!   integer adds reassociate freely, so AVX2/NEON/scalar all agree
//!   bit-for-bit). Safe for dispatch-invariant candidate selection.
//! * **Tolerance-class** (lane-split accumulation, FMA on AVX2 — results
//!   differ from scalar by rounding): `dot`, `sum_sq`, `axpy`. Only wired
//!   into paths protected by a numeric tolerance (goldens at 2e-3 rel,
//!   EMA transcription at 1e-5 rel, gradcheck at 1e-3) or by near-tie
//!   tolerant argmin parity.
//!
//! The `VQGNN_SIMD` env knob (`0`/`off`/`false`/`scalar` → scalar path)
//! lets CI exercise both paths on one runner; see `parse` for the pure,
//! testable decision function.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which vector path is active for this process.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Simd {
    Scalar,
    Avx2,
    Neon,
}

// 0 = undecided, 1 = Scalar, 2 = Avx2, 3 = Neon. Detection is idempotent,
// so a racing double-store is harmless.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// Pure dispatch decision: env knob first, then hardware capability.
/// Split out from `active()` so tests can cover the env parsing without
/// mutating process env (cargo test threads share it).
pub fn parse(env: Option<&str>, has_avx2_fma: bool, has_neon: bool) -> Simd {
    if let Some(v) = env {
        let v = v.trim().to_ascii_lowercase();
        if matches!(v.as_str(), "0" | "off" | "false" | "scalar") {
            return Simd::Scalar;
        }
    }
    if has_avx2_fma {
        Simd::Avx2
    } else if has_neon {
        Simd::Neon
    } else {
        Simd::Scalar
    }
}

fn detect() -> Simd {
    #[cfg(target_arch = "x86_64")]
    let caps = (
        std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"),
        false,
    );
    #[cfg(target_arch = "aarch64")]
    let caps = (false, std::arch::is_aarch64_feature_detected!("neon"));
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let caps = (false, false);
    let env = std::env::var("VQGNN_SIMD").ok();
    parse(env.as_deref(), caps.0, caps.1)
}

/// The path this process dispatches to. Resolved once, then cached.
pub fn active() -> Simd {
    match ACTIVE.load(Ordering::Relaxed) {
        1 => Simd::Scalar,
        2 => Simd::Avx2,
        3 => Simd::Neon,
        _ => {
            let d = detect();
            let code = match d {
                Simd::Scalar => 1,
                Simd::Avx2 => 2,
                Simd::Neon => 3,
            };
            ACTIVE.store(code, Ordering::Relaxed);
            d
        }
    }
}

/// Human-readable dispatch name, surfaced in the bench report so a
/// silently-scalar CI runner is visible in the artifact.
pub fn name() -> &'static str {
    match active() {
        Simd::Scalar => "scalar",
        Simd::Avx2 => "avx2",
        Simd::Neon => "neon",
    }
}

// ---------------------------------------------------------------------------
// Scalar reference twins — public so property tests can pit every dispatched
// primitive against its exact scalar counterpart.
// ---------------------------------------------------------------------------

pub mod scalar {
    /// Σ a[i]·b[i], left-to-right.
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let mut s = 0.0f32;
        for i in 0..a.len().min(b.len()) {
            s += a[i] * b[i];
        }
        s
    }

    /// Σ a[i]², left-to-right.
    pub fn sum_sq(a: &[f32]) -> f32 {
        let mut s = 0.0f32;
        for &x in a {
            s += x * x;
        }
        s
    }

    /// Σ a[i]·b[i] with i32 accumulation (exact — no overflow possible for
    /// i8 operands below ~2^16 elements; our widths are ≤ a few thousand).
    pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let mut s = 0i32;
        for i in 0..a.len().min(b.len()) {
            s += a[i] as i32 * b[i] as i32;
        }
        s
    }

    /// y[i] += a·x[i].
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// y[i] *= a.
    pub fn scale(y: &mut [f32], a: f32) {
        for yi in y.iter_mut() {
            *yi *= a;
        }
    }

    /// y[i] += x[i].
    pub fn add_assign(y: &mut [f32], x: &[f32]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += xi;
        }
    }

    /// out[i] = (v[i] − mean[i])·inv[i] — the fused whiten row.
    pub fn whiten_row(out: &mut [f32], v: &[f32], mean: &[f32], inv: &[f32]) {
        for i in 0..out.len() {
            out[i] = (v[i] - mean[i]) * inv[i];
        }
    }

    /// y[i] = y[i]·beta + x[i]·(1−beta) — the EMA blend (mul/mul/add, no
    /// FMA, so the vector path is bit-identical).
    pub fn lerp(y: &mut [f32], x: &[f32], beta: f32) {
        let g = 1.0 - beta;
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi = *yi * beta + xi * g;
        }
    }

    /// out[i] = a·x[i].
    pub fn scale_into(out: &mut [f32], a: f32, x: &[f32]) {
        for (oi, &xi) in out.iter_mut().zip(x) {
            *oi = a * xi;
        }
    }

    /// out[i] = a·x[i] + b·y[i] (mul/mul/add, no FMA).
    pub fn scale2_into(out: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
        for i in 0..out.len() {
            out[i] = a * x[i] + b * y[i];
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 + FMA implementations (x86_64).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Horizontal sum in a fixed lane order so the result is deterministic
    /// for a given input (still differs from scalar by reassociation —
    /// tolerance-class callers only).
    #[inline]
    unsafe fn hsum256(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]))
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_fmadd_ps(va, vb, acc);
            i += 8;
        }
        let mut s = hsum256(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sum_sq(a: &[f32]) -> f32 {
        let n = a.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i + 8 <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            acc = _mm256_fmadd_ps(va, va, acc);
            i += 8;
        }
        let mut s = hsum256(acc);
        while i < n {
            s += a[i] * a[i];
            i += 1;
        }
        s
    }

    /// i8·i8 → i32 dot. Exact: `_mm256_madd_epi16` sums adjacent i16
    /// products into i32 lanes; integer addition is associative, so this
    /// agrees bit-for-bit with the scalar twin.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_si256();
        let mut i = 0;
        while i + 16 <= n {
            let va = _mm_loadu_si128(a.as_ptr().add(i) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(i) as *const __m128i);
            let wa = _mm256_cvtepi8_epi16(va);
            let wb = _mm256_cvtepi8_epi16(vb);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wa, wb));
            i += 16;
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut s: i32 = lanes.iter().sum();
        while i < n {
            s += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_fmadd_ps(va, vx, vy));
            i += 8;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(y: &mut [f32], a: f32) {
        let n = y.len();
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_mul_ps(vy, va));
            i += 8;
        }
        while i < n {
            y[i] *= a;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign(y: &mut [f32], x: &[f32]) {
        let n = y.len().min(x.len());
        let mut i = 0;
        while i + 8 <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(vy, vx));
            i += 8;
        }
        while i < n {
            y[i] += x[i];
            i += 1;
        }
    }

    /// (v − mean)·inv, sub then mul — bit-identical to scalar.
    #[target_feature(enable = "avx2")]
    pub unsafe fn whiten_row(out: &mut [f32], v: &[f32], mean: &[f32], inv: &[f32]) {
        let n = out.len();
        let mut i = 0;
        while i + 8 <= n {
            let vv = _mm256_loadu_ps(v.as_ptr().add(i));
            let vm = _mm256_loadu_ps(mean.as_ptr().add(i));
            let vi = _mm256_loadu_ps(inv.as_ptr().add(i));
            let r = _mm256_mul_ps(_mm256_sub_ps(vv, vm), vi);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            out[i] = (v[i] - mean[i]) * inv[i];
            i += 1;
        }
    }

    /// y·β + x·(1−β), mul/mul/add (deliberately NOT fmadd) so the EMA path
    /// is bit-identical across dispatches.
    #[target_feature(enable = "avx2")]
    pub unsafe fn lerp(y: &mut [f32], x: &[f32], beta: f32) {
        let n = y.len().min(x.len());
        let vb = _mm256_set1_ps(beta);
        let vg = _mm256_set1_ps(1.0 - beta);
        let mut i = 0;
        while i + 8 <= n {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let r = _mm256_add_ps(_mm256_mul_ps(vy, vb), _mm256_mul_ps(vx, vg));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += 8;
        }
        let g = 1.0 - beta;
        while i < n {
            y[i] = y[i] * beta + x[i] * g;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_into(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len().min(x.len());
        let va = _mm256_set1_ps(a);
        let mut i = 0;
        while i + 8 <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(va, vx));
            i += 8;
        }
        while i < n {
            out[i] = a * x[i];
            i += 1;
        }
    }

    /// a·x + b·y, mul/mul/add (no FMA) — bit-identical to scalar, required
    /// by the attention backward whose forward twin is bitwise-pinned.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale2_into(out: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
        let n = out.len();
        let va = _mm256_set1_ps(a);
        let vb = _mm256_set1_ps(b);
        let mut i = 0;
        while i + 8 <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vy = _mm256_loadu_ps(y.as_ptr().add(i));
            let r = _mm256_add_ps(_mm256_mul_ps(va, vx), _mm256_mul_ps(vb, vy));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            out[i] = a * x[i] + b * y[i];
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON implementations (aarch64).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            let va = vld1q_f32(a.as_ptr().add(i));
            let vb = vld1q_f32(b.as_ptr().add(i));
            acc = vfmaq_f32(acc, va, vb);
            i += 4;
        }
        let mut s = vaddvq_f32(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum_sq(a: &[f32]) -> f32 {
        let n = a.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 4 <= n {
            let va = vld1q_f32(a.as_ptr().add(i));
            acc = vfmaq_f32(acc, va, va);
            i += 4;
        }
        let mut s = vaddvq_f32(acc);
        while i < n {
            s += a[i] * a[i];
            i += 1;
        }
        s
    }

    /// Exact i8 dot via widening multiply-accumulate into i32 lanes.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_s32(0);
        let mut i = 0;
        while i + 8 <= n {
            let va = vmovl_s8(vld1_s8(a.as_ptr().add(i)));
            let vb = vmovl_s8(vld1_s8(b.as_ptr().add(i)));
            acc = vmlal_s16(acc, vget_low_s16(va), vget_low_s16(vb));
            acc = vmlal_s16(acc, vget_high_s16(va), vget_high_s16(vb));
            i += 8;
        }
        let mut s = vaddvq_s32(acc);
        while i < n {
            s += a[i] as i32 * b[i] as i32;
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let va = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vy = vld1q_f32(y.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vfmaq_f32(vy, va, vx));
            i += 4;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale(y: &mut [f32], a: f32) {
        let n = y.len();
        let va = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let vy = vld1q_f32(y.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vmulq_f32(vy, va));
            i += 4;
        }
        while i < n {
            y[i] *= a;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign(y: &mut [f32], x: &[f32]) {
        let n = y.len().min(x.len());
        let mut i = 0;
        while i + 4 <= n {
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vy = vld1q_f32(y.as_ptr().add(i));
            vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(vy, vx));
            i += 4;
        }
        while i < n {
            y[i] += x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn whiten_row(out: &mut [f32], v: &[f32], mean: &[f32], inv: &[f32]) {
        let n = out.len();
        let mut i = 0;
        while i + 4 <= n {
            let vv = vld1q_f32(v.as_ptr().add(i));
            let vm = vld1q_f32(mean.as_ptr().add(i));
            let vi = vld1q_f32(inv.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(vsubq_f32(vv, vm), vi));
            i += 4;
        }
        while i < n {
            out[i] = (v[i] - mean[i]) * inv[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn lerp(y: &mut [f32], x: &[f32], beta: f32) {
        let n = y.len().min(x.len());
        let vb = vdupq_n_f32(beta);
        let vg = vdupq_n_f32(1.0 - beta);
        let mut i = 0;
        while i + 4 <= n {
            let vy = vld1q_f32(y.as_ptr().add(i));
            let vx = vld1q_f32(x.as_ptr().add(i));
            let r = vaddq_f32(vmulq_f32(vy, vb), vmulq_f32(vx, vg));
            vst1q_f32(y.as_mut_ptr().add(i), r);
            i += 4;
        }
        let g = 1.0 - beta;
        while i < n {
            y[i] = y[i] * beta + x[i] * g;
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale_into(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len().min(x.len());
        let va = vdupq_n_f32(a);
        let mut i = 0;
        while i + 4 <= n {
            let vx = vld1q_f32(x.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(va, vx));
            i += 4;
        }
        while i < n {
            out[i] = a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn scale2_into(out: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
        let n = out.len();
        let va = vdupq_n_f32(a);
        let vb = vdupq_n_f32(b);
        let mut i = 0;
        while i + 4 <= n {
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vy = vld1q_f32(y.as_ptr().add(i));
            let r = vaddq_f32(vmulq_f32(va, vx), vmulq_f32(vb, vy));
            vst1q_f32(out.as_mut_ptr().add(i), r);
            i += 4;
        }
        while i < n {
            out[i] = a * x[i] + b * y[i];
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points.
// ---------------------------------------------------------------------------

macro_rules! dispatch {
    ($name:ident ( $($arg:expr),* )) => {
        match active() {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `active()` returns Avx2 only after runtime detection
            // of avx2+fma on this CPU.
            Simd::Avx2 => unsafe { avx2::$name($($arg),*) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: `active()` returns Neon only after runtime detection.
            Simd::Neon => unsafe { neon::$name($($arg),*) },
            #[allow(unreachable_patterns)]
            _ => scalar::$name($($arg),*),
        }
    };
}

/// Σ a[i]·b[i]. Tolerance-class (lane accumulation + FMA).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dispatch!(dot(a, b))
}

/// Σ a[i]². Tolerance-class.
#[inline]
pub fn sum_sq(a: &[f32]) -> f32 {
    dispatch!(sum_sq(a))
}

/// Σ a[i]·b[i] over i8 with i32 accumulation. Exact across dispatches.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    dispatch!(dot_i8(a, b))
}

/// y += a·x. Tolerance-class (FMA on AVX2). Callers that special-case
/// `a == 0.0` (zero-skip in the matmuls) keep that check — it is a
/// semantic filter (inf/NaN/−0.0 propagation), not just a perf skip.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    dispatch!(axpy(y, a, x))
}

/// y *= a. Bit-exact vs scalar.
#[inline]
pub fn scale(y: &mut [f32], a: f32) {
    dispatch!(scale(y, a))
}

/// y += x (element-wise). Bit-exact vs scalar.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    dispatch!(add_assign(y, x))
}

/// out = (v − mean)·inv, fused whiten row. Bit-exact vs scalar.
#[inline]
pub fn whiten_row(out: &mut [f32], v: &[f32], mean: &[f32], inv: &[f32]) {
    dispatch!(whiten_row(out, v, mean, inv))
}

/// y = y·β + x·(1−β), the EMA blend. Bit-exact vs scalar (no FMA).
#[inline]
pub fn lerp(y: &mut [f32], x: &[f32], beta: f32) {
    dispatch!(lerp(y, x, beta))
}

/// out = a·x. Bit-exact vs scalar.
#[inline]
pub fn scale_into(out: &mut [f32], a: f32, x: &[f32]) {
    dispatch!(scale_into(out, a, x))
}

/// out = a·x + b·y. Bit-exact vs scalar (no FMA).
#[inline]
pub fn scale2_into(out: &mut [f32], a: f32, x: &[f32], b: f32, y: &[f32]) {
    dispatch!(scale2_into(out, a, x, b, y))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_honors_off_values() {
        for v in ["0", "off", "false", "scalar", " OFF ", "False"] {
            assert_eq!(parse(Some(v), true, false), Simd::Scalar, "{v}");
            assert_eq!(parse(Some(v), false, true), Simd::Scalar, "{v}");
        }
    }

    #[test]
    fn parse_prefers_hardware_when_unset_or_on() {
        assert_eq!(parse(None, true, false), Simd::Avx2);
        assert_eq!(parse(None, false, true), Simd::Neon);
        assert_eq!(parse(None, false, false), Simd::Scalar);
        assert_eq!(parse(Some("1"), true, false), Simd::Avx2);
        assert_eq!(parse(Some("avx2"), false, false), Simd::Scalar);
    }

    #[test]
    fn active_is_stable_across_calls() {
        let a = active();
        for _ in 0..4 {
            assert_eq!(active(), a);
        }
        assert!(!name().is_empty());
    }

    #[test]
    fn exact_primitives_match_scalar_bitwise() {
        // Deterministic pseudo-random fill (no external RNG dep needed).
        let mut state = 0x2545_f491u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 100] {
            let x: Vec<f32> = (0..n).map(|_| next()).collect();
            let y0: Vec<f32> = (0..n).map(|_| next()).collect();
            let m: Vec<f32> = (0..n).map(|_| next()).collect();
            let inv: Vec<f32> = (0..n).map(|_| next().abs() + 0.1).collect();

            let mut a = y0.clone();
            let mut b = y0.clone();
            scale(&mut a, 1.7);
            scalar::scale(&mut b, 1.7);
            assert_eq!(a, b, "scale n={n}");

            let mut a = y0.clone();
            let mut b = y0.clone();
            add_assign(&mut a, &x);
            scalar::add_assign(&mut b, &x);
            assert_eq!(a, b, "add_assign n={n}");

            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            whiten_row(&mut a, &x, &m, &inv);
            scalar::whiten_row(&mut b, &x, &m, &inv);
            assert_eq!(a, b, "whiten_row n={n}");

            let mut a = y0.clone();
            let mut b = y0.clone();
            lerp(&mut a, &x, 0.99);
            scalar::lerp(&mut b, &x, 0.99);
            assert_eq!(a, b, "lerp n={n}");

            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            scale_into(&mut a, -0.3, &x);
            scalar::scale_into(&mut b, -0.3, &x);
            assert_eq!(a, b, "scale_into n={n}");

            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            scale2_into(&mut a, 0.4, &x, -1.1, &m);
            scalar::scale2_into(&mut b, 0.4, &x, -1.1, &m);
            assert_eq!(a, b, "scale2_into n={n}");
        }
    }

    #[test]
    fn dot_i8_is_exact() {
        let mut state = 7u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            (state >> 56) as i8
        };
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 40, 129] {
            let a: Vec<i8> = (0..n).map(|_| next()).collect();
            let b: Vec<i8> = (0..n).map(|_| next()).collect();
            assert_eq!(dot_i8(&a, &b), scalar::dot_i8(&a, &b), "n={n}");
        }
    }

    #[test]
    fn reductions_match_scalar_within_tolerance() {
        let mut state = 0xdead_beefu64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        };
        for n in [1usize, 5, 8, 13, 16, 64, 200, 1000] {
            let a: Vec<f32> = (0..n).map(|_| next()).collect();
            let b: Vec<f32> = (0..n).map(|_| next()).collect();
            let d = dot(&a, &b);
            let ds = scalar::dot(&a, &b);
            assert!((d - ds).abs() <= 1e-4 * (1.0 + ds.abs()), "dot n={n}: {d} vs {ds}");
            let s = sum_sq(&a);
            let ss = scalar::sum_sq(&a);
            assert!((s - ss).abs() <= 1e-4 * (1.0 + ss.abs()), "sum_sq n={n}");

            let mut ya = b.clone();
            let mut yb = b.clone();
            axpy(&mut ya, 0.37, &a);
            scalar::axpy(&mut yb, 0.37, &a);
            for i in 0..n {
                assert!((ya[i] - yb[i]).abs() <= 1e-5 * (1.0 + yb[i].abs()), "axpy n={n} i={i}");
            }
        }
    }
}
