//! Minimal JSON parser/serializer (serde is not available offline — see
//! DESIGN.md §7).  Covers the full JSON grammar we produce/consume:
//! manifest.json, golden indexes, and results output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (stable key order; floats via shortest-roundtrip `{}`).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"version":1,"arr":[1,2.5,-3e2],"s":"a\"b\nc","b":true,"n":null,"o":{"x":[]}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("arr").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\"b\nc"));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 10);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
