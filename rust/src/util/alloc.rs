//! Counting global allocator for the `alloc-count` bench feature.
//!
//! `benches/hot_paths.rs` installs [`CountingAlloc`] as the global
//! allocator when built with `--features alloc-count` and reports the heap
//! bytes requested by one steady-state train / serve step
//! (`train_step_alloc_bytes` / `serve_alloc_bytes` in
//! `BENCH_hot_paths.json`).  Those keys are what arms `bench_guard` against
//! regressions of the plan-compiled executor's zero-allocation contract:
//! the step arena owns every intermediate buffer, so a hot-path `Vec`
//! sneaking back in shows up as a byte-count jump, not a vague slowdown.
//!
//! Only *requests* are counted (alloc / alloc_zeroed / the growth half of
//! realloc); frees are not subtracted, so the counter is monotone and a
//! delta across a closure is exactly "bytes asked from the allocator while
//! it ran".  Counting is a pair of relaxed atomic adds — cheap enough to
//! leave on for a whole bench run.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Total bytes requested since process start (monotone).
pub static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Total allocation calls since process start (monotone).
pub static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

/// Monotone byte counter snapshot.
pub fn bytes_now() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// Monotone call-count snapshot.
pub fn calls_now() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// A [`System`]-backed allocator that counts every allocation request.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > layout.size() {
            ALLOC_BYTES.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}
