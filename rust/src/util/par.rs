//! Tiny data-parallel helpers over `std::thread::scope` (rayon is not
//! available offline — DESIGN.md §7).  Work is split into fixed contiguous
//! chunks assigned round-robin to workers, so the partitioning — and with it
//! every merge order downstream — is deterministic for a given machine.

/// Worker count: physical parallelism, overridable via `VQ_GNN_THREADS`.
pub fn max_threads() -> usize {
    if let Ok(s) = std::env::var("VQ_GNN_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, chunk)` over contiguous chunks of `data`, in
/// parallel.  Chunks are disjoint `&mut` slices, so `f` may write freely;
/// chunk `i` always covers `data[i*chunk .. (i+1)*chunk]`.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    let n_chunks = (data.len() + chunk - 1) / chunk;
    let threads = max_threads().min(n_chunks);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, c) in data.chunks_mut(chunk).enumerate() {
        buckets[i % threads].push((i, c));
    }
    let f = &f;
    std::thread::scope(|s| {
        for bucket in buckets {
            s.spawn(move || {
                for (i, c) in bucket {
                    f(i, c);
                }
            });
        }
    });
}

/// Run two closures concurrently — `fa` on a scoped worker thread, `fb` on
/// the calling thread — and return both results.  This is the
/// double-buffering primitive behind pipelined batch assembly: the trainer
/// runs the compiled step (`fb`) while the worker samples + gathers the
/// next batch (`fa`).  Determinism is the caller's contract: `fa` must not
/// share mutable state with `fb` (the borrow checker enforces it), so the
/// overlapped schedule computes exactly what the serial one would.
pub fn join2<A, B, FA, FB>(fa: FA, fb: FB) -> (A, B)
where
    A: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B,
{
    std::thread::scope(|s| {
        let ha = s.spawn(fa);
        let b = fb();
        (ha.join().expect("par: prep worker panicked"), b)
    })
}

/// Map contiguous chunks of `data` to partial results, in parallel, and
/// return them **in chunk order** — callers merge sequentially, which keeps
/// floating-point reductions deterministic for a fixed thread count.
pub fn par_map_chunks<T, R, F>(data: &[T], chunk: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &[T]) -> R + Sync,
{
    let chunk = chunk.max(1);
    let n_chunks = (data.len() + chunk - 1) / chunk;
    let threads = max_threads().min(n_chunks);
    if threads <= 1 {
        return data.chunks(chunk).enumerate().map(|(i, c)| f(i, c)).collect();
    }
    let f = &f;
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n_chunks);
    slots.resize_with(n_chunks, || None);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let chunks: Vec<(usize, &[T])> = data
                .chunks(chunk)
                .enumerate()
                .filter(|(i, _)| i % threads == w)
                .collect();
            handles.push(s.spawn(move || {
                chunks.into_iter().map(|(i, c)| (i, f(i, c))).collect::<Vec<_>>()
            }));
        }
        for h in handles {
            for (i, r) in h.join().expect("par worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|r| r.expect("chunk not computed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 1037];
        par_chunks_mut(&mut v, 64, |i, c| {
            for (j, x) in c.iter_mut().enumerate() {
                *x = (i * 64 + j) as u32;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn map_chunks_in_order() {
        let data: Vec<u64> = (0..1000).collect();
        let partials = par_map_chunks(&data, 128, |i, c| (i, c.iter().sum::<u64>()));
        assert_eq!(partials.len(), 8);
        let mut total = 0u64;
        for (i, (ci, s)) in partials.iter().enumerate() {
            assert_eq!(i, *ci);
            total += s;
        }
        assert_eq!(total, 999 * 1000 / 2);
    }

    #[test]
    fn join2_runs_both_and_orders_results() {
        let mut left = 0u64;
        let mut right = 0u64;
        let (a, b) = join2(
            || {
                (0..1000u64).sum::<u64>()
            },
            || {
                right = 7;
                "main"
            },
        );
        left += a;
        assert_eq!(left, 999 * 1000 / 2);
        assert_eq!(right, 7);
        assert_eq!(b, "main");
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 16, |_, _| panic!("no chunks expected"));
        let out = par_map_chunks(&[1u8, 2, 3], 16, |_, c| c.len());
        assert_eq!(out, vec![3]);
    }
}
