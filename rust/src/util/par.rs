//! Tiny data-parallel helpers over `std::thread::scope` (rayon is not
//! available offline — DESIGN.md §7).  Work is split into fixed contiguous
//! chunks assigned round-robin to workers, so the partitioning — and with it
//! every merge order downstream — is deterministic for a given machine.

use std::cell::Cell;

thread_local! {
    /// Per-thread cap on nested kernel parallelism (0 = uncapped).  Set by
    /// [`with_thread_budget`] on pool-worker threads so N serving workers
    /// don't each spawn `max_threads()` kernel threads — N × cores
    /// runnable threads oversubscribes the machine N-fold.
    static THREAD_BUDGET: Cell<usize> = Cell::new(0);
}

/// Run `f` with this thread's kernel-parallelism budget capped at `cap`
/// (restored afterwards).  Purely a scheduling hint: every kernel above is
/// deterministic across thread counts (disjoint chunk writes, in-order
/// partial merges), so the budget never changes results — only how many
/// scoped threads the nested kernels spawn.
pub fn with_thread_budget<R>(cap: usize, f: impl FnOnce() -> R) -> R {
    let prev = THREAD_BUDGET.with(|b| b.replace(cap.max(1)));
    let out = f();
    THREAD_BUDGET.with(|b| b.set(prev));
    out
}

/// Worker count: physical parallelism, overridable via `VQ_GNN_THREADS`
/// and capped by the calling thread's [`with_thread_budget`] scope.
pub fn max_threads() -> usize {
    let n = if let Ok(s) = std::env::var("VQ_GNN_THREADS") {
        s.parse::<usize>().map(|n| n.max(1)).unwrap_or_else(|_| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    match THREAD_BUDGET.with(Cell::get) {
        0 => n,
        cap => n.min(cap),
    }
}

/// Run `f(chunk_index, chunk)` over contiguous chunks of `data`, in
/// parallel.  Chunks are disjoint `&mut` slices, so `f` may write freely;
/// chunk `i` always covers `data[i*chunk .. (i+1)*chunk]`.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    let n_chunks = (data.len() + chunk - 1) / chunk;
    let threads = max_threads().min(n_chunks);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            f(i, c);
        }
        return;
    }
    let mut buckets: Vec<Vec<(usize, &mut [T])>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, c) in data.chunks_mut(chunk).enumerate() {
        buckets[i % threads].push((i, c));
    }
    let f = &f;
    std::thread::scope(|s| {
        for bucket in buckets {
            s.spawn(move || {
                for (i, c) in bucket {
                    f(i, c);
                }
            });
        }
    });
}

/// Run two closures concurrently — `fa` on a scoped worker thread, `fb` on
/// the calling thread — and return both results.  This is the
/// double-buffering primitive behind pipelined batch assembly: the trainer
/// runs the compiled step (`fb`) while the worker samples + gathers the
/// next batch (`fa`).  Determinism is the caller's contract: `fa` must not
/// share mutable state with `fb` (the borrow checker enforces it), so the
/// overlapped schedule computes exactly what the serial one would.
pub fn join2<A, B, FA, FB>(fa: FA, fb: FB) -> (A, B)
where
    A: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B,
{
    std::thread::scope(|s| {
        let ha = s.spawn(fa);
        let b = fb();
        (ha.join().expect("par: prep worker panicked"), b)
    })
}

/// One scoped worker per element of `states`, each running
/// `f(worker_index, &mut state)` concurrently; results come back **in
/// worker order**.  This is the session-pool primitive behind concurrent
/// serving: each worker owns one mutable session (disjoint `&mut`, so the
/// borrow checker enforces that workers share only `Sync` state), and the
/// deterministic result order keeps every merge downstream identical to
/// the serial schedule.  A single state runs inline — no thread spawn, so
/// a 1-worker pool is byte-and-timing-comparable to the serial path.
pub fn scope_map<S, R, F>(states: &mut [S], f: F) -> Vec<R>
where
    S: Send,
    R: Send,
    F: Fn(usize, &mut S) -> R + Sync,
{
    if states.len() <= 1 {
        return states.iter_mut().enumerate().map(|(i, st)| f(i, st)).collect();
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = states
            .iter_mut()
            .enumerate()
            .map(|(i, st)| s.spawn(move || f(i, st)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par: pool worker panicked"))
            .collect()
    })
}

/// Map contiguous chunks of `data` to partial results, in parallel, and
/// return them **in chunk order** — callers merge sequentially, which keeps
/// floating-point reductions deterministic for a fixed thread count.
pub fn par_map_chunks<T, R, F>(data: &[T], chunk: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &[T]) -> R + Sync,
{
    let chunk = chunk.max(1);
    let n_chunks = (data.len() + chunk - 1) / chunk;
    let threads = max_threads().min(n_chunks);
    if threads <= 1 {
        return data.chunks(chunk).enumerate().map(|(i, c)| f(i, c)).collect();
    }
    let f = &f;
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n_chunks);
    slots.resize_with(n_chunks, || None);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let chunks: Vec<(usize, &[T])> = data
                .chunks(chunk)
                .enumerate()
                .filter(|(i, _)| i % threads == w)
                .collect();
            handles.push(s.spawn(move || {
                chunks.into_iter().map(|(i, c)| (i, f(i, c))).collect::<Vec<_>>()
            }));
        }
        for h in handles {
            for (i, r) in h.join().expect("par worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|r| r.expect("chunk not computed")).collect()
}

type ShardJob<T> = Box<dyn FnOnce(&mut T) + Send>;

/// Persistent shard-worker pool: S long-lived threads, each owning one
/// shard state `T` for the lifetime of the pool (unlike the scoped
/// helpers above, workers survive across calls — the substrate for
/// sharded execution, where per-step work is dispatched to the thread
/// that owns the shard's tables).
///
/// Jobs are `'static` closures, so everything a step sends to a shard
/// must be owned or `Arc`'d — deliberately the same discipline a future
/// process/socket boundary would impose: the cross-shard message is
/// data (codebooks, whitening stats, batch slices), never a borrow.
///
/// [`ShardPool::map`] collects results **in shard order**, which keeps
/// every downstream partial-merge deterministic, exactly like
/// [`par_map_chunks`]'s chunk-order contract.
pub struct ShardPool<T> {
    txs: Vec<std::sync::mpsc::Sender<ShardJob<T>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl<T: Send + 'static> ShardPool<T> {
    /// Spawn one worker per element of `states`; each worker runs its
    /// jobs under a kernel-parallelism budget of `inner_budget` (see
    /// [`with_thread_budget`]) so S shards don't oversubscribe the
    /// machine S-fold.
    pub fn new(states: Vec<T>, inner_budget: usize) -> ShardPool<T> {
        let mut txs = Vec::with_capacity(states.len());
        let mut handles = Vec::with_capacity(states.len());
        for (i, mut st) in states.into_iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::channel::<ShardJob<T>>();
            let h = std::thread::Builder::new()
                .name(format!("vqgnn-shard-{i}"))
                .spawn(move || {
                    with_thread_budget(inner_budget, || {
                        while let Ok(job) = rx.recv() {
                            job(&mut st);
                        }
                    })
                })
                .expect("par: failed to spawn shard worker");
            txs.push(tx);
            handles.push(h);
        }
        ShardPool { txs, handles }
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Run `f(shard_index, &mut state)` on every shard worker
    /// concurrently; results come back **in shard order** regardless of
    /// which worker finishes first.
    pub fn map<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, &mut T) -> R + Send + Clone + 'static,
    {
        let (rtx, rrx) = std::sync::mpsc::channel::<(usize, R)>();
        for (i, tx) in self.txs.iter().enumerate() {
            let f = f.clone();
            let rtx = rtx.clone();
            tx.send(Box::new(move |st: &mut T| {
                let r = f(i, st);
                let _ = rtx.send((i, r));
            }))
            .expect("par: shard worker disappeared");
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = Vec::with_capacity(self.txs.len());
        slots.resize_with(self.txs.len(), || None);
        for (i, r) in rrx {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|r| r.expect("par: shard worker dropped its result"))
            .collect()
    }
}

impl<T> Drop for ShardPool<T> {
    fn drop(&mut self) {
        // Closing the senders ends each worker's recv loop.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 1037];
        par_chunks_mut(&mut v, 64, |i, c| {
            for (j, x) in c.iter_mut().enumerate() {
                *x = (i * 64 + j) as u32;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn map_chunks_in_order() {
        let data: Vec<u64> = (0..1000).collect();
        let partials = par_map_chunks(&data, 128, |i, c| (i, c.iter().sum::<u64>()));
        assert_eq!(partials.len(), 8);
        let mut total = 0u64;
        for (i, (ci, s)) in partials.iter().enumerate() {
            assert_eq!(i, *ci);
            total += s;
        }
        assert_eq!(total, 999 * 1000 / 2);
    }

    #[test]
    fn join2_runs_both_and_orders_results() {
        let mut left = 0u64;
        let mut right = 0u64;
        let (a, b) = join2(
            || {
                (0..1000u64).sum::<u64>()
            },
            || {
                right = 7;
                "main"
            },
        );
        left += a;
        assert_eq!(left, 999 * 1000 / 2);
        assert_eq!(right, 7);
        assert_eq!(b, "main");
    }

    #[test]
    fn thread_budget_caps_and_restores() {
        let full = max_threads();
        let inside = with_thread_budget(1, || {
            assert_eq!(max_threads(), 1);
            // nested scopes replace the cap for their extent, then restore
            with_thread_budget(5, || assert_eq!(max_threads(), full.min(5)));
            max_threads()
        });
        assert_eq!(inside, 1);
        assert_eq!(max_threads(), full, "budget must not leak past the scope");
        // budgets are per-thread: a worker under budget 1 doesn't cap others
        with_thread_budget(1, || {
            let (worker_sees, _) = join2(|| max_threads(), || ());
            // the spawned worker has its own (uncapped) budget
            assert_eq!(worker_sees, full);
        });
    }

    #[test]
    fn scope_map_orders_results_and_mutates_disjoint_states() {
        let mut states: Vec<u64> = (0..5).collect();
        let out = scope_map(&mut states, |i, st| {
            *st += 100;
            i as u64 * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30, 40]);
        assert_eq!(states, vec![100, 101, 102, 103, 104]);
        // single-state pools run inline
        let mut one = vec![7u64];
        assert_eq!(scope_map(&mut one, |_, st| *st), vec![7]);
        let mut none: Vec<u64> = vec![];
        assert!(scope_map(&mut none, |_, _| 0u64).is_empty());
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 16, |_, _| panic!("no chunks expected"));
        let out = par_map_chunks(&[1u8, 2, 3], 16, |_, c| c.len());
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn shard_pool_orders_results_and_persists_state() {
        let pool = ShardPool::new(vec![0u64; 4], 1);
        assert_eq!(pool.shards(), 4);
        // results come back in shard order even though workers race
        let out = pool.map(|i, st| {
            *st += 1;
            i as u64 * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30]);
        // state persists across calls on the same worker
        for _ in 0..5 {
            pool.map(|_, st| *st += 1);
        }
        let counts = pool.map(|_, st| *st);
        assert_eq!(counts, vec![7, 7, 7, 7]);
    }

    #[test]
    fn shard_pool_workers_run_under_inner_budget() {
        let pool = ShardPool::new(vec![(); 2], 1);
        let seen = pool.map(|_, _| max_threads());
        assert_eq!(seen, vec![1, 1]);
        drop(pool); // Drop joins cleanly
    }

    #[test]
    fn shard_pool_moves_owned_messages() {
        use std::sync::Arc;
        let pool = ShardPool::new(vec![Vec::<u32>::new(); 3], 1);
        let msg = Arc::new(vec![5u32, 6, 7]);
        let m = msg.clone();
        let sums = pool.map(move |i, st| {
            st.push(m[i]);
            st.iter().sum::<u32>()
        });
        assert_eq!(sums, vec![5, 6, 7]);
    }
}
