//! Cluster-GCN (paper §5): partition the graph into densely-connected
//! clusters (METIS in the original; BFS-grown + LDG greedy here — DESIGN.md
//! §7), then train each step on a random group of clusters with the
//! intra-group edges restored.

use crate::graph::Graph;
use crate::util::rng::Rng;

/// Partition `g` into `parts` clusters of roughly n/parts nodes.
///
/// Streaming LDG (linear deterministic greedy): visit nodes in BFS order
/// from random seeds; place each node in the cluster holding most of its
/// already-placed neighbors, penalized by fullness.  This matches
/// Cluster-GCN's requirement (dense clusters) without METIS.
pub fn partition(g: &Graph, parts: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.n;
    let cap = (n + parts - 1) / parts;
    let mut part = vec![u32::MAX; n];
    let mut sizes = vec![0usize; parts];
    // BFS visit order over components (keeps clusters contiguous)
    let mut order = Vec::with_capacity(n);
    let mut seen = vec![false; n];
    let mut starts: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut starts);
    let mut queue = std::collections::VecDeque::new();
    for &s in &starts {
        if seen[s as usize] {
            continue;
        }
        seen[s as usize] = true;
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for &v in g.out_neighbors(u as usize) {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    let mut score = vec![0.0f64; parts];
    for &u in &order {
        for s in score.iter_mut() {
            *s = 0.0;
        }
        for &v in g.in_neighbors(u as usize) {
            let p = part[v as usize];
            if p != u32::MAX {
                score[p as usize] += 1.0;
            }
        }
        let mut best = 0usize;
        let mut best_s = f64::NEG_INFINITY;
        for p in 0..parts {
            if sizes[p] >= cap {
                continue;
            }
            let s = (score[p] + 1e-3) * (1.0 - sizes[p] as f64 / cap as f64);
            if s > best_s {
                best_s = s;
                best = p;
            }
        }
        part[u as usize] = best as u32;
        sizes[best] += 1;
    }
    part
}

/// One Cluster-GCN batch: the union of `group` clusters.
pub fn batch_nodes(part: &[u32], group: &[u32]) -> Vec<u32> {
    let set: std::collections::HashSet<u32> = group.iter().cloned().collect();
    (0..part.len() as u32)
        .filter(|&v| set.contains(&part[v as usize]))
        .collect()
}

/// Edge-cut fraction — partition quality metric (lower = denser clusters).
pub fn edge_cut(g: &Graph, part: &[u32]) -> f64 {
    let mut cut = 0usize;
    for v in 0..g.n {
        for &u in g.in_neighbors(v) {
            if part[u as usize] != part[v] {
                cut += 1;
            }
        }
    }
    cut as f64 / g.num_arcs().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn community_graph(n: usize, comms: usize, rng: &mut Rng) -> Graph {
        let mut edges = Vec::new();
        let per = n / comms;
        for _ in 0..n * 4 {
            let c = rng.below(comms);
            let u = (c * per + rng.below(per)) as u32;
            let v = if rng.f64() < 0.9 {
                (c * per + rng.below(per)) as u32
            } else {
                rng.below(n) as u32
            };
            edges.push((u, v));
        }
        Graph::from_undirected(n, &edges)
    }

    #[test]
    fn partition_covers_all_nodes_balanced() {
        check("partition_cover", 8, |rng, _| {
            let g = community_graph(120, 4, rng);
            let parts = 6;
            let part = partition(&g, parts, rng);
            if part.iter().any(|&p| p == u32::MAX || p as usize >= parts) {
                return Err("unassigned node".into());
            }
            let mut sizes = vec![0usize; parts];
            for &p in &part {
                sizes[p as usize] += 1;
            }
            let cap = (120 + parts - 1) / parts;
            if sizes.iter().any(|&s| s > cap) {
                return Err(format!("oversized cluster {sizes:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn partition_beats_random_on_edge_cut() {
        let mut rng = Rng::new(5);
        let g = community_graph(200, 4, &mut rng);
        let part = partition(&g, 4, &mut rng);
        let random: Vec<u32> = (0..200).map(|_| rng.below(4) as u32).collect();
        assert!(edge_cut(&g, &part) < edge_cut(&g, &random) * 0.8,
                "ldg {} vs random {}", edge_cut(&g, &part), edge_cut(&g, &random));
    }

    #[test]
    fn batch_nodes_selects_exactly_group() {
        let part = vec![0, 1, 2, 0, 1, 2, 0];
        let b = batch_nodes(&part, &[0, 2]);
        assert_eq!(b, vec![0, 2, 3, 5, 6]);
    }
}
