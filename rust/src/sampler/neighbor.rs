//! NS-SAGE neighbor sampling (paper §5): per layer, each node keeps at most
//! `fanout_l` sampled in-neighbors; the union computation graph is trained
//! on with loss restricted to the root nodes.  The union grows as
//! O(b·Πfanouts) — the "neighbor explosion" the paper's Table 2 charges this
//! method with (our memory meter observes it directly).

use crate::graph::Graph;
use crate::util::rng::Rng;

pub struct NeighborSample {
    /// Union node set; roots come first.
    pub nodes: Vec<u32>,
    /// Sampled directed arcs (src, dst) in *local* indices.
    pub edges: Vec<(u32, u32)>,
    pub n_roots: usize,
}

/// Sample the L-layer computation graph of `roots` with the given fanouts
/// (fanouts[0] = deepest layer's fanout, PyG convention is reversed — we
/// expand outward so order doesn't matter for the union).
pub fn sample(graph: &Graph, roots: &[u32], fanouts: &[usize], cap_nodes: usize,
              rng: &mut Rng) -> NeighborSample {
    let mut local: Vec<i32> = Vec::new();
    local.resize(graph.n, -1);
    let mut nodes: Vec<u32> = Vec::with_capacity(roots.len() * 4);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for &r in roots {
        if local[r as usize] < 0 {
            local[r as usize] = nodes.len() as i32;
            nodes.push(r);
        }
    }
    let n_roots = nodes.len();
    let mut frontier: Vec<u32> = nodes.clone();
    for &fan in fanouts {
        let mut next = Vec::new();
        for &v in &frontier {
            let lv = local[v as usize] as u32;
            let nbs = graph.in_neighbors(v as usize);
            if nbs.is_empty() {
                continue;
            }
            let take = fan.min(nbs.len());
            // sample `take` distinct in-neighbors
            let picks = if take == nbs.len() {
                (0..nbs.len()).collect::<Vec<_>>()
            } else {
                rng.sample_distinct(nbs.len(), take)
                    .into_iter()
                    .map(|x| x as usize)
                    .collect()
            };
            for p in picks {
                let u = nbs[p];
                if local[u as usize] < 0 {
                    if nodes.len() >= cap_nodes {
                        continue; // capacity-capped (documented in DESIGN.md)
                    }
                    local[u as usize] = nodes.len() as i32;
                    nodes.push(u);
                    next.push(u);
                }
                edges.push((local[u as usize] as u32, lv));
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    NeighborSample { nodes, edges, n_roots }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> Graph {
        // 5x5 grid
        let mut e = Vec::new();
        for r in 0..5u32 {
            for c in 0..5u32 {
                let v = r * 5 + c;
                if c < 4 {
                    e.push((v, v + 1));
                }
                if r < 4 {
                    e.push((v, v + 5));
                }
            }
        }
        Graph::from_undirected(25, &e)
    }

    #[test]
    fn roots_first_and_edges_local() {
        let g = grid();
        let mut rng = Rng::new(1);
        let s = sample(&g, &[12, 7], &[2, 2], 100, &mut rng);
        assert_eq!(s.n_roots, 2);
        assert_eq!(s.nodes[0], 12);
        assert_eq!(s.nodes[1], 7);
        for &(u, v) in &s.edges {
            assert!((u as usize) < s.nodes.len());
            assert!((v as usize) < s.nodes.len());
            // sampled arc must exist in the graph
            let gu = s.nodes[u as usize] as usize;
            let gv = s.nodes[v as usize];
            assert!(g.out_neighbors(gu).contains(&gv));
        }
    }

    #[test]
    fn fanout_bounds_edges_per_node_per_layer() {
        let g = grid();
        let mut rng = Rng::new(2);
        let s = sample(&g, &[12], &[2], 100, &mut rng);
        // root has at most 2 sampled in-arcs
        let into_root = s.edges.iter().filter(|&&(_, v)| v == 0).count();
        assert!(into_root <= 2);
    }

    #[test]
    fn union_grows_with_depth_neighbor_explosion() {
        let g = grid();
        let mut rng = Rng::new(3);
        let s1 = sample(&g, &[12], &[4], 1000, &mut rng);
        let s3 = sample(&g, &[12], &[4, 4, 4], 1000, &mut rng);
        assert!(s3.nodes.len() > s1.nodes.len());
    }

    #[test]
    fn capacity_cap_is_respected() {
        let g = grid();
        let mut rng = Rng::new(4);
        let s = sample(&g, &[0, 6, 12, 18, 24], &[4, 4, 4], 10, &mut rng);
        assert!(s.nodes.len() <= 10);
    }
}
