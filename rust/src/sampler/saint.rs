//! GraphSAINT-RW (paper §5): random-walk-induced subgraphs with the
//! unbiasedness normalizations — aggregator coefficients divided by edge
//! inclusion probability α_e and per-node loss weights λ_v = 1/p_v, both
//! estimated from pre-sampled subgraphs as in the original.

use crate::graph::Graph;
use crate::util::rng::Rng;

pub struct SaintSampler {
    pub roots: usize,
    pub walk_len: usize,
    /// Estimated node/edge inclusion probabilities (per undirected arc id).
    node_p: Vec<f32>,
    arc_p: Vec<f32>,
}

impl SaintSampler {
    /// `pre_samples` subgraphs estimate inclusion probabilities.
    pub fn new(g: &Graph, roots: usize, walk_len: usize, pre_samples: usize,
               rng: &mut Rng) -> SaintSampler {
        let mut node_c = vec![1.0f32; g.n]; // +1 smoothing
        let mut arc_c = vec![1.0f32; g.num_arcs()];
        let mut scratch = vec![-1i32; g.n];
        for _ in 0..pre_samples {
            let nodes = sample_nodes(g, roots, walk_len, rng);
            for &v in &nodes {
                node_c[v as usize] += 1.0;
            }
            for (u_local, v_local) in induced_arc_ids(g, &nodes, &mut scratch) {
                let _ = u_local;
                arc_c[v_local] += 1.0;
            }
        }
        let s = (pre_samples + 1) as f32;
        SaintSampler {
            roots,
            walk_len,
            node_p: node_c.into_iter().map(|c| c / s).collect(),
            arc_p: arc_c.into_iter().map(|c| c / s).collect(),
        }
    }

    /// Sample one subgraph; returns (nodes, local arcs with normalized
    /// coefficients relative to `base_coef`, loss weights λ).
    pub fn sample(&self, g: &Graph, rng: &mut Rng)
                  -> (Vec<u32>, Vec<(u32, u32, f32)>, Vec<f32>) {
        let nodes = sample_nodes(g, self.roots, self.walk_len, rng);
        let mut scratch = vec![-1i32; g.n];
        for (li, &v) in nodes.iter().enumerate() {
            scratch[v as usize] = li as i32;
        }
        let mut arcs = Vec::new();
        for (li, &v) in nodes.iter().enumerate() {
            let (s0, s1) = (g.in_ptr[v as usize] as usize, g.in_ptr[v as usize + 1] as usize);
            for e in s0..s1 {
                let u = g.in_col[e];
                let lu = scratch[u as usize];
                if lu >= 0 {
                    // α_e ≈ p(edge in subgraph); divide to stay unbiased
                    let alpha = self.arc_p[e].max(1e-3);
                    arcs.push((lu as u32, li as u32, 1.0 / alpha));
                }
            }
        }
        for &v in &nodes {
            scratch[v as usize] = -1;
        }
        let lam: Vec<f32> = nodes
            .iter()
            .map(|&v| 1.0 / self.node_p[v as usize].max(1e-3))
            .collect();
        (nodes, arcs, lam)
    }
}

fn sample_nodes(g: &Graph, roots: usize, walk_len: usize, rng: &mut Rng) -> Vec<u32> {
    let mut seen = std::collections::HashSet::with_capacity(roots * walk_len);
    let mut nodes = Vec::with_capacity(roots * walk_len);
    for _ in 0..roots {
        let r = rng.below(g.n) as u32;
        for v in g.random_walk(r, walk_len, rng) {
            if seen.insert(v) {
                nodes.push(v);
            }
        }
    }
    nodes
}

/// Local arcs of the induced subgraph, tagged with the *global* in-CSR arc
/// index (for inclusion-probability accounting).
fn induced_arc_ids(g: &Graph, nodes: &[u32], scratch: &mut [i32]) -> Vec<(u32, usize)> {
    for (li, &v) in nodes.iter().enumerate() {
        scratch[v as usize] = li as i32;
    }
    let mut out = Vec::new();
    for &v in nodes {
        let (s0, s1) = (g.in_ptr[v as usize] as usize, g.in_ptr[v as usize + 1] as usize);
        for e in s0..s1 {
            if scratch[g.in_col[e] as usize] >= 0 {
                out.push((g.in_col[e], e));
            }
        }
    }
    for &v in nodes {
        scratch[v as usize] = -1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn com_graph(rng: &mut Rng) -> Graph {
        let n = 120;
        let mut e = Vec::new();
        for _ in 0..n * 4 {
            e.push((rng.below(n) as u32, rng.below(n) as u32));
        }
        Graph::from_undirected(n, &e)
    }

    #[test]
    fn subgraph_nodes_unique_and_connected_ish() {
        let mut rng = Rng::new(1);
        let g = com_graph(&mut rng);
        let s = SaintSampler::new(&g, 8, 3, 10, &mut rng);
        let (nodes, arcs, lam) = s.sample(&g, &mut rng);
        let uniq: std::collections::HashSet<_> = nodes.iter().collect();
        assert_eq!(uniq.len(), nodes.len());
        assert_eq!(lam.len(), nodes.len());
        for &(u, v, c) in &arcs {
            assert!((u as usize) < nodes.len() && (v as usize) < nodes.len());
            assert!(c > 0.0);
        }
    }

    #[test]
    fn frequently_sampled_nodes_get_lower_loss_weight() {
        let mut rng = Rng::new(2);
        // star graph: hub 0 is in nearly every walk
        let edges: Vec<(u32, u32)> = (1..60u32).map(|v| (0, v)).collect();
        let g = Graph::from_undirected(60, &edges);
        let s = SaintSampler::new(&g, 6, 4, 50, &mut rng);
        // hub inclusion prob >> leaf inclusion prob → λ_hub << λ_leaf
        let hub_p = s.node_p[0];
        let leaf_p: f32 = (1..60).map(|v| s.node_p[v]).sum::<f32>() / 59.0;
        assert!(hub_p > leaf_p * 3.0, "hub {hub_p} leaf {leaf_p}");
    }

    #[test]
    fn walk_subgraphs_cover_graph_over_epoch() {
        let mut rng = Rng::new(3);
        let g = com_graph(&mut rng);
        let s = SaintSampler::new(&g, 10, 3, 5, &mut rng);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..40 {
            let (nodes, _, _) = s.sample(&g, &mut rng);
            seen.extend(nodes);
        }
        assert!(seen.len() > g.n * 8 / 10, "covered {}/{}", seen.len(), g.n);
    }
}
