//! Mini-batch samplers.
//!
//! VQ-GNN samples *nodes* (by node / edge / random-walk strategies — the
//! App. G ablation); the baselines sample *subgraphs* (neighbor.rs,
//! cluster.rs, saint.rs).

pub mod cluster;
pub mod neighbor;
pub mod saint;

use crate::graph::Graph;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStrategy {
    /// Uniform node sampling (the paper's default).
    Nodes,
    /// Sample edges, take both endpoints.
    Edges,
    /// GraphSAINT-style random-walk roots.
    Walks,
}

impl NodeStrategy {
    pub fn from_str(s: &str) -> Option<NodeStrategy> {
        match s {
            "nodes" => Some(NodeStrategy::Nodes),
            "edges" => Some(NodeStrategy::Edges),
            "walks" => Some(NodeStrategy::Walks),
            _ => None,
        }
    }
}

/// Epoch-wise node batcher for VQ-GNN: traverses a node pool in shuffled
/// order (strategy Nodes), or draws correlated batches (Edges / Walks) while
/// still touching every pool node once per epoch on average.
pub struct NodeBatcher {
    pool: Vec<u32>,
    pub b: usize,
    strategy: NodeStrategy,
    cursor: usize,
    order: Vec<u32>,
}

impl NodeBatcher {
    pub fn new(pool: Vec<u32>, b: usize, strategy: NodeStrategy) -> NodeBatcher {
        assert!(!pool.is_empty());
        let order = pool.clone();
        NodeBatcher { pool, b, strategy, cursor: 0, order }
    }

    pub fn batches_per_epoch(&self) -> usize {
        (self.pool.len() + self.b - 1) / self.b
    }

    /// Next batch of exactly b node ids (the tail wraps with resampled
    /// nodes so artifact shapes stay fixed); `pad` gives the count of
    /// duplicated tail nodes whose loss weight must be zeroed.
    pub fn next_batch(&mut self, graph: &Graph, rng: &mut Rng) -> (Vec<u32>, usize) {
        match self.strategy {
            NodeStrategy::Nodes => {
                if self.cursor == 0 {
                    rng.shuffle(&mut self.order);
                }
                let start = self.cursor;
                let end = (start + self.b).min(self.order.len());
                let mut out: Vec<u32> = self.order[start..end].to_vec();
                self.cursor = if end == self.order.len() { 0 } else { end };
                let pad = self.b - out.len();
                // pad with distinct nodes not already in the batch
                if pad > 0 {
                    let mut seen: std::collections::HashSet<u32> =
                        out.iter().cloned().collect();
                    while out.len() < self.b {
                        let c = self.pool[rng.below(self.pool.len())];
                        if seen.insert(c) {
                            out.push(c);
                        }
                    }
                }
                (out, pad)
            }
            NodeStrategy::Edges => {
                let mut seen = std::collections::HashSet::with_capacity(self.b * 2);
                let mut out = Vec::with_capacity(self.b);
                let mut guard = 0;
                while out.len() < self.b && guard < self.b * 50 {
                    guard += 1;
                    let u = self.pool[rng.below(self.pool.len())];
                    if seen.insert(u) {
                        out.push(u);
                    }
                    if out.len() >= self.b {
                        break;
                    }
                    let nbs = graph.out_neighbors(u as usize);
                    if !nbs.is_empty() {
                        let v = nbs[rng.below(nbs.len())];
                        if seen.insert(v) {
                            out.push(v);
                        }
                    }
                }
                while out.len() < self.b {
                    let c = self.pool[rng.below(self.pool.len())];
                    if seen.insert(c) {
                        out.push(c);
                    }
                }
                (out, 0)
            }
            NodeStrategy::Walks => {
                let mut seen = std::collections::HashSet::with_capacity(self.b * 2);
                let mut out = Vec::with_capacity(self.b);
                let mut guard = 0;
                while out.len() < self.b && guard < self.b * 50 {
                    guard += 1;
                    let root = self.pool[rng.below(self.pool.len())];
                    for v in graph.random_walk(root, 3, rng) {
                        if out.len() >= self.b {
                            break;
                        }
                        if seen.insert(v) {
                            out.push(v);
                        }
                    }
                }
                while out.len() < self.b {
                    let c = self.pool[rng.below(self.pool.len())];
                    if seen.insert(c) {
                        out.push(c);
                    }
                }
                (out, 0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(n: usize) -> Graph {
        let edges: Vec<(u32, u32)> =
            (0..n as u32).map(|i| (i, (i + 1) % n as u32)).collect();
        Graph::from_undirected(n, &edges)
    }

    #[test]
    fn node_strategy_covers_pool_each_epoch() {
        let g = ring(100);
        let pool: Vec<u32> = (0..100).collect();
        let mut nb = NodeBatcher::new(pool, 32, NodeStrategy::Nodes);
        let mut rng = Rng::new(1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..nb.batches_per_epoch() {
            let (batch, _pad) = nb.next_batch(&g, &mut rng);
            assert_eq!(batch.len(), 32);
            let uniq: std::collections::HashSet<_> = batch.iter().collect();
            assert_eq!(uniq.len(), 32, "batch has duplicates");
            seen.extend(batch);
        }
        assert_eq!(seen.len(), 100);
    }

    #[test]
    fn edge_and_walk_strategies_fill_batches() {
        let g = ring(64);
        for strat in [NodeStrategy::Edges, NodeStrategy::Walks] {
            let mut nb = NodeBatcher::new((0..64).collect(), 16, strat);
            let mut rng = Rng::new(2);
            for _ in 0..10 {
                let (batch, pad) = nb.next_batch(&g, &mut rng);
                assert_eq!(batch.len(), 16);
                assert_eq!(pad, 0);
                let uniq: std::collections::HashSet<_> = batch.iter().collect();
                assert_eq!(uniq.len(), 16);
            }
        }
    }

    #[test]
    fn restricted_pool_is_respected() {
        let g = ring(50);
        let pool: Vec<u32> = (0..25).collect();
        let mut nb = NodeBatcher::new(pool, 10, NodeStrategy::Nodes);
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            let (batch, _) = nb.next_batch(&g, &mut rng);
            assert!(batch.iter().all(|&v| v < 25));
        }
    }
}
