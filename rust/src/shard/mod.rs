//! In-process sharded scale-out: codebooks as the compressed cross-shard
//! message.
//!
//! The paper's central trick — all out-of-batch context rides k quantized
//! codewords plus count sketches — means only O(k·fp) codebook state ever
//! needs to cross a shard boundary, never per-node messages.  This module
//! owns that boundary:
//!
//! - [`ShardPlan`] — the node→shard partition map (contiguous ranges).
//!   It governs which shard *owns* a node's rows: feature gathers, serve
//!   cache maintenance, and checkpointed state are split along it.  The
//!   map is deliberately a plain table of `u32` bounds: it is the seam a
//!   later process/socket hop over `serve::proto` would serialize.
//! - [`ShardExec`] — a coordinator over a persistent
//!   [`par::ShardPool`] of S workers that runs the EMA codebook update
//!   as a broadcast→partial→merge cycle: the coordinator broadcasts the
//!   current whitening stats (the compressed message), each shard
//!   computes moment and cluster partials over its resident chunk range,
//!   and the coordinator merges all partials **in global chunk order**.
//!
//! # Determinism contract
//!
//! The sharded trajectory is bit-identical to the unsharded one at any
//! shard count S, because:
//!
//! 1. Per-chunk partials are computed by the *same functions* the
//!    unsharded kernels use (`kernels::mean_var_chunk_partial`,
//!    `kernels::cluster_chunk_partial`) over the same `ROW_BLOCK`-aligned
//!    chunks — the partial boundaries never move with S.
//! 2. Partials are merged in ascending global chunk order with the same
//!    `f64` adds / `simd::add_assign` the unsharded merge uses
//!    (`kernels::{mean_var_from_partials, cluster_from_partials}`) —
//!    float addition is non-associative, so the order is the contract.
//! 3. Everything order-free (whitening, assignment distances) is
//!    elementwise per row and identical wherever it runs.
//!
//! Note the seam: EMA partials are sharded by **batch chunk index**
//! (rows land in `ROW_BLOCK` chunks exactly as `par::par_map_chunks`
//! would cut them), while the [`ShardPlan`] node ranges govern **table
//! residence** (gathers, serve-cache maintenance, checkpoints).  Both
//! produce results independent of S by the argument above.

use std::sync::Arc;

use crate::util::par::{self, ShardPool};
use crate::util::rng::Rng;
use crate::util::simd;
use crate::util::tensor::Tensor;
use crate::vq::kernels::{self, ROW_BLOCK};
use crate::vq::{LayerVq, VqBranch};

/// Contiguous node→shard partition map: shard `s` owns nodes
/// `[bounds[s], bounds[s+1])`.  `bounds` always starts at 0 and ends at
/// the node count, so `bounds.len() == shards + 1`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    bounds: Vec<u32>,
}

impl ShardPlan {
    /// Balanced contiguous partition of `n` nodes into `shards` ranges
    /// (the first `n % shards` ranges get one extra node).
    pub fn contiguous(n: usize, shards: usize) -> ShardPlan {
        let s = shards.max(1);
        let mut bounds = Vec::with_capacity(s + 1);
        for i in 0..s {
            bounds.push(chunk_range(n, s, i).0 as u32);
        }
        bounds.push(n as u32);
        ShardPlan { bounds }
    }

    /// Rebuild a plan from checkpointed bounds, validating the shape.
    pub fn from_bounds(bounds: Vec<u32>) -> Result<ShardPlan, String> {
        if bounds.len() < 2 {
            return Err(format!("shard plan needs >= 2 bounds, got {}", bounds.len()));
        }
        if bounds[0] != 0 {
            return Err(format!("shard plan must start at node 0, got {}", bounds[0]));
        }
        if bounds.windows(2).any(|w| w[0] > w[1]) {
            return Err("shard plan bounds must be non-decreasing".into());
        }
        Ok(ShardPlan { bounds })
    }

    /// The checkpoint wire form — the exact bounds table.
    pub fn bounds(&self) -> &[u32] {
        &self.bounds
    }

    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    pub fn n_nodes(&self) -> usize {
        *self.bounds.last().unwrap() as usize
    }

    /// The node range `[lo, hi)` shard `s` owns.
    pub fn node_range(&self, s: usize) -> (usize, usize) {
        (self.bounds[s] as usize, self.bounds[s + 1] as usize)
    }

    /// Owning shard of a frozen-graph node (the last shard whose lower
    /// bound is ≤ `node`, which skips empty ranges).
    pub fn shard_of(&self, node: usize) -> usize {
        debug_assert!(node < self.n_nodes());
        self.bounds[..self.bounds.len() - 1]
            .partition_point(|&b| b as usize <= node)
            .saturating_sub(1)
    }

    /// Owning shard of any serving id: frozen nodes by range, admitted
    /// ids (which are minted past the frozen range, monotone for life)
    /// round-robin — a total ownership rule over the open-ended id
    /// space.  Maintenance results are merged in slot order afterwards,
    /// so serving answers never depend on this choice.
    pub fn owner_of(&self, id: u32) -> usize {
        let n = self.n_nodes();
        let id = id as usize;
        if id < n {
            self.shard_of(id)
        } else {
            (id - n) % self.shards()
        }
    }
}

/// Balanced contiguous split of `n` items into `shards` ranges: the
/// range `[lo, hi)` owned by shard `s`.  Shared by the node partition
/// and the per-batch chunk partition.
pub fn chunk_range(n: usize, shards: usize, s: usize) -> (usize, usize) {
    let q = n / shards;
    let r = n % shards;
    let lo = s * q + s.min(r);
    (lo, lo + q + usize::from(s < r))
}

/// Per-shard worker state for the trainer's EMA cycle: just reusable
/// whitening scratch — all real inputs arrive as per-step `Arc`
/// broadcasts (the cross-shard message is data, never a borrow).
#[derive(Default)]
pub struct TrainShard {
    vw: Vec<f32>,
}

/// Coordinator over a persistent pool of S shard workers, running the
/// EMA codebook update as the broadcast→partial→merge cycle described
/// in the module docs.
pub struct ShardExec {
    pub plan: ShardPlan,
    pool: ShardPool<TrainShard>,
}

impl ShardExec {
    pub fn new(plan: ShardPlan) -> ShardExec {
        let s = plan.shards();
        let inner = (par::max_threads() / s).max(1);
        let states = (0..s).map(|_| TrainShard::default()).collect();
        ShardExec { plan, pool: ShardPool::new(states, inner) }
    }

    pub fn shards(&self) -> usize {
        self.pool.shards()
    }

    /// Sharded [`VqBranch::update_expiring`]: two broadcast→merge rounds.
    ///
    /// Round A — shards compute f64 moment partials over their chunk
    /// ranges of the raw batch; the coordinator merges them in global
    /// chunk order and blends the whitening EMAs.  Round B — the
    /// coordinator broadcasts the fresh (mean, inv_std) stats, shards
    /// whiten their resident rows and compute cluster partials, and the
    /// coordinator merges those in chunk order and refreshes codewords.
    /// Expiry (when enabled) runs on the coordinator after the merge,
    /// so its RNG draw sequence is shard-count independent.
    pub fn update_branch(
        &self,
        br: &mut VqBranch,
        v: &Arc<Vec<f32>>,
        assign: &Arc<Vec<i32>>,
        gamma: f32,
        beta: f32,
        expiry: Option<(f32, &mut Rng)>,
    ) {
        let b = assign.len();
        if b == 0 {
            return;
        }
        let (fp, k) = (br.fp, br.k);
        debug_assert_eq!(v.len(), b * fp);
        let s_total = self.pool.shards();
        let n_chunks = (b + ROW_BLOCK - 1) / ROW_BLOCK;

        // Round A: moment partials over resident chunk ranges.
        let va = v.clone();
        let mv = self.pool.map(move |s, _st| {
            let (c0, c1) = chunk_range(n_chunks, s_total, s);
            (c0..c1)
                .map(|ci| {
                    let lo = ci * ROW_BLOCK * fp;
                    let hi = (lo + ROW_BLOCK * fp).min(b * fp);
                    kernels::mean_var_chunk_partial(&va[lo..hi], fp)
                })
                .collect::<Vec<_>>()
        });
        // Shard s owns chunks [c_s, c_{s+1}), so flattening in shard
        // order IS ascending global chunk order — the same merge the
        // unsharded kernel performs.
        let (m, varr) = kernels::mean_var_from_partials(mv.into_iter().flatten(), b, fp);
        let inv = br.apply_moments(&m, &varr, gamma, beta);

        // Broadcast the updated whitening stats — O(fp) data, the
        // compressed cross-shard message.
        let mean = Arc::new(br.mean.clone());
        let inv = Arc::new(inv);

        // Round B: whiten resident rows, cluster partials per chunk.
        let (v2, a2, mean2, inv2) = (v.clone(), assign.clone(), mean.clone(), inv.clone());
        let cl = self.pool.map(move |s, st| {
            let (c0, c1) = chunk_range(n_chunks, s_total, s);
            let r0 = c0 * ROW_BLOCK;
            let r1 = (c1 * ROW_BLOCK).min(b);
            let rows = r1.saturating_sub(r0);
            st.vw.resize(rows * fp, 0.0);
            for r in 0..rows {
                simd::whiten_row(
                    &mut st.vw[r * fp..(r + 1) * fp],
                    &v2[(r0 + r) * fp..(r0 + r + 1) * fp],
                    &mean2,
                    &inv2,
                );
            }
            (c0..c1)
                .map(|ci| {
                    let lo = ci * ROW_BLOCK;
                    let hi = (lo + ROW_BLOCK).min(b);
                    kernels::cluster_chunk_partial(
                        &st.vw[(lo - r0) * fp..(hi - r0) * fp],
                        &a2[lo..hi],
                        fp,
                        k,
                    )
                })
                .collect::<Vec<_>>()
        });
        let (bc, bs) = kernels::cluster_from_partials(cl.into_iter().flatten(), fp, k);
        br.apply_cluster_partials(&bc, &bs, gamma);
        if let Some((threshold, rng)) = expiry {
            br.expire_dead(v, b, &inv, threshold, rng);
        }
    }

    /// Sharded [`LayerVq::update_from_batch_expiring`]: identical concat
    /// layout and assignment-table writes, with each branch's EMA update
    /// running the broadcast→merge cycle above.
    pub fn update_layer(
        &self,
        lv: &mut LayerVq,
        batch: &[u32],
        xfeat: &Tensor,
        gvec: &Tensor,
        assign: &Tensor,
        gamma: f32,
        beta: f32,
        expiry: &mut Option<(f32, Rng)>,
    ) {
        let b = batch.len();
        let (nb, fp) = (lv.plan.n_br, lv.plan.fp);
        debug_assert_eq!(assign.shape, &[nb, b]);
        let z = lv.concat_z(xfeat, gvec);
        for j in 0..nb {
            let mut vbr = vec![0.0f32; b * fp];
            lv.branch_rows_into(&z, j, &mut vbr);
            let v = Arc::new(vbr);
            let a = Arc::new(assign.i[j * b..(j + 1) * b].to_vec());
            let e = expiry.as_mut().map(|(t, r)| (*t, &mut *r));
            self.update_branch(&mut lv.branches[j], &v, &a, gamma, beta, e);
            lv.write_assignments(j, batch, a.as_slice());
        }
    }
}

/// Shard-parallel feature gather: split the batch into `shards`
/// contiguous position ranges and copy each range's rows on its own
/// worker.  Pure disjoint row copies — byte-identical to the serial
/// gather at any shard count.
pub fn gather_features_sharded(
    features: &[f32],
    f: usize,
    nodes: &[u32],
    out: &mut [f32],
    shards: usize,
) {
    let s = shards.max(1);
    if s == 1 || f == 0 || nodes.len() < s {
        crate::coordinator::gather_features_into(features, f, nodes, out);
        return;
    }
    let per = (nodes.len() + s - 1) / s;
    let mut parts: Vec<(&[u32], &mut [f32])> =
        nodes.chunks(per).zip(out.chunks_mut(per * f)).collect();
    par::scope_map(&mut parts, |_w, (ns, os)| {
        crate::coordinator::gather_features_into(features, f, ns, os);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_plan_covers_every_node_once() {
        for (n, s) in [(10usize, 3usize), (7, 7), (5, 8), (1000, 4), (0, 2)] {
            let plan = ShardPlan::contiguous(n, s);
            assert_eq!(plan.shards(), s.max(1));
            assert_eq!(plan.n_nodes(), n);
            let mut covered = 0usize;
            for sh in 0..plan.shards() {
                let (lo, hi) = plan.node_range(sh);
                assert!(lo <= hi && hi <= n);
                for node in lo..hi {
                    assert_eq!(plan.shard_of(node), sh, "node {node}");
                }
                covered += hi - lo;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn chunk_range_is_a_balanced_cover() {
        for (n, s) in [(13usize, 4usize), (4, 4), (3, 5), (0, 3), (64, 1)] {
            let mut next = 0usize;
            for sh in 0..s {
                let (lo, hi) = chunk_range(n, s, sh);
                assert_eq!(lo, next);
                assert!(hi - lo <= n / s + 1);
                next = hi;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn owner_of_is_total_over_admitted_ids() {
        let plan = ShardPlan::contiguous(100, 4);
        for id in 0..100u32 {
            assert_eq!(plan.owner_of(id), plan.shard_of(id as usize));
        }
        for id in 100..140u32 {
            assert!(plan.owner_of(id) < 4);
        }
        assert_eq!(plan.owner_of(100), 0);
        assert_eq!(plan.owner_of(101), 1);
    }

    #[test]
    fn plan_bounds_round_trip_and_validate() {
        let plan = ShardPlan::contiguous(37, 3);
        let back = ShardPlan::from_bounds(plan.bounds().to_vec()).unwrap();
        assert_eq!(plan, back);
        assert!(ShardPlan::from_bounds(vec![]).is_err());
        assert!(ShardPlan::from_bounds(vec![1, 5]).is_err());
        assert!(ShardPlan::from_bounds(vec![0, 5, 3]).is_err());
    }

    #[test]
    fn sharded_branch_update_is_bit_identical() {
        let mut rng = Rng::new(42);
        let reference = VqBranch::init(16, 8, &mut rng);
        let b = 3 * ROW_BLOCK + 17; // exercises the short tail chunk
        let v: Vec<f32> = (0..b * 8).map(|_| rng.gauss_f32()).collect();
        let assign: Vec<i32> = (0..b).map(|_| rng.below(16) as i32).collect();
        let mut unsharded = reference.clone();
        for _ in 0..3 {
            unsharded.update(&v, &assign, 0.9, 0.9);
        }
        let va = Arc::new(v.clone());
        let aa = Arc::new(assign.clone());
        for s in [1usize, 2, 4] {
            let exec = ShardExec::new(ShardPlan::contiguous(b, s));
            let mut br = reference.clone();
            for _ in 0..3 {
                exec.update_branch(&mut br, &va, &aa, 0.9, 0.9, None);
            }
            let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&br.cww), bits(&unsharded.cww), "cww diverged at S={s}");
            assert_eq!(bits(&br.counts), bits(&unsharded.counts), "counts diverged at S={s}");
            assert_eq!(bits(&br.sums), bits(&unsharded.sums), "sums diverged at S={s}");
            assert_eq!(bits(&br.mean), bits(&unsharded.mean), "mean diverged at S={s}");
            assert_eq!(bits(&br.var), bits(&unsharded.var), "var diverged at S={s}");
        }
    }

    #[test]
    fn sharded_gather_matches_serial() {
        let mut rng = Rng::new(7);
        let (n, f) = (50usize, 6usize);
        let features: Vec<f32> = (0..n * f).map(|_| rng.gauss_f32()).collect();
        let nodes: Vec<u32> = (0..33).map(|_| rng.below(n) as u32).collect();
        let mut serial = vec![0.0f32; nodes.len() * f];
        crate::coordinator::gather_features_into(&features, f, &nodes, &mut serial);
        for s in [1usize, 2, 4, 64] {
            let mut sharded = vec![0.0f32; nodes.len() * f];
            gather_features_sharded(&features, f, &nodes, &mut sharded, s);
            assert_eq!(
                serial.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                sharded.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "gather diverged at S={s}"
            );
        }
    }
}
