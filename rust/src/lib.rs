//! VQ-GNN: a universal framework to scale up graph neural networks using
//! vector quantization — NeurIPS 2021 reproduction.
//!
//! Three-layer architecture (DESIGN.md):
//! - L3 (this crate): coordinator — datasets, samplers, VQ codebook state,
//!   sketch building, trainers, metrics, experiment harness.
//! - L2/L1: the model math, behind `runtime::Backend`.  Default is the
//!   **native CPU backend** (`runtime::native`) — pure Rust, no Python/JAX,
//!   specs reconstructed by `runtime::builtin`.  With `--features pjrt` the
//!   original path is available: JAX model + Pallas kernels AOT-lowered to
//!   `artifacts/*.hlo.txt` (python/, build-time only), executed via PJRT.

// Index-heavy numeric kernels: these pedantic lints fight the row-major
// arithmetic style used throughout (and in the seed code).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::type_complexity
)]

pub mod coordinator;
pub mod datasets;
pub mod graph;
pub mod harness;
pub mod obs;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod shard;
pub mod util;
pub mod vq;
