//! VQ-GNN: a universal framework to scale up graph neural networks using
//! vector quantization — NeurIPS 2021 reproduction.
//!
//! Three-layer architecture (DESIGN.md):
//! - L3 (this crate): coordinator — datasets, samplers, VQ codebook state,
//!   sketch building, trainers, metrics, experiment harness.
//! - L2/L1 (python/, build-time only): JAX model + Pallas kernels, AOT
//!   lowered to `artifacts/*.hlo.txt`, executed here via PJRT.

pub mod coordinator;
pub mod datasets;
pub mod graph;
pub mod harness;
pub mod runtime;
pub mod sampler;
pub mod util;
pub mod vq;
