//! Dense row-major f32 ops for the native CPU backend: matmul variants
//! (thread-parallel over row blocks above a serial threshold), the VQ
//! unsketch primitive (codebook-weighted out-of-batch message
//! reconstruction), activations and loss-head numerics.
//!
//! Semantics mirror `python/compile/kernels/ref.py` — these are the same
//! mathematical definitions the Pallas kernels are tested against.
//!
//! Every op exists in two forms: an `_into` variant writing into a
//! caller-owned buffer (the plan-compiled executor's step arena reuses
//! those buffers across steps, so the hot path allocates nothing) and an
//! allocating wrapper that delegates to it.  The `_into` bodies keep the
//! exact accumulation order of the original allocating loops — zero the
//! buffer, then accumulate — so a reused buffer computes bit-identical
//! results to a fresh one.

#![allow(clippy::too_many_arguments)]

use crate::util::{par, simd};

/// Below this many multiply-accumulates a matmul runs serially (thread
/// dispatch costs more than the arithmetic).
const PAR_THRESHOLD: usize = 1 << 16;

/// Rows per parallel work unit.
const ROW_BLOCK: usize = 32;

/// `(m, k) @ (k, n) -> (m, n)`, ikj order (streams `b` rows, vectorizes n),
/// into a reused buffer.
pub fn matmul_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(n).enumerate() {
            let r = r0 + rr;
            let arow = &a[r * k..(r + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue; // semantic skip (sparse rows), kept pre-SIMD
                }
                simd::axpy(orow, av, &b[kk * n..(kk + 1) * n]);
            }
        }
    };
    if m * k * n < PAR_THRESHOLD {
        body(0, &mut *out);
    } else {
        par::par_chunks_mut(out, ROW_BLOCK * n, |ci, chunk| body(ci * ROW_BLOCK, chunk));
    }
}

/// Allocating wrapper of [`matmul_into`].
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, m, k, b, n, &mut out);
    out
}

/// `aᵀ @ b` where `a` is `(m, k)` and `b` is `(m, n)` -> `(k, n)`, into a
/// reused buffer.  Serial: used for weight gradients whose output is small.
pub fn matmul_at_b_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    out.fill(0.0);
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let brow = &b[r * n..(r + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            simd::axpy(&mut out[i * n..(i + 1) * n], av, brow);
        }
    }
}

/// Allocating wrapper of [`matmul_at_b_into`].
pub fn matmul_at_b(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k * n];
    matmul_at_b_into(a, m, k, b, n, &mut out);
    out
}

/// `a @ bᵀ` where `a` is `(m, k)` and `b` is `(n, k)` -> `(m, n)` (row-dot),
/// into a reused buffer (every element overwritten).
pub fn matmul_a_bt_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a[(r0 + rr) * k..(r0 + rr + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                *o = simd::dot(arow, &b[j * k..(j + 1) * k]);
            }
        }
    };
    if m * k * n < PAR_THRESHOLD {
        body(0, &mut *out);
    } else {
        par::par_chunks_mut(out, ROW_BLOCK * n, |ci, chunk| body(ci * ROW_BLOCK, chunk));
    }
}

/// Allocating wrapper of [`matmul_a_bt_into`].
pub fn matmul_a_bt(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_a_bt_into(a, m, k, b, n, &mut out);
    out
}

/// Out-of-batch message reconstruction (`unsketch_ref`): per branch `j`,
/// `(b, k) @ (k, fp)` written into columns `[j*fp, (j+1)*fp)` of a
/// `(b, n_br*fp)` buffer.
pub fn unsketch_into(
    c_out: &[f32],
    n_br: usize,
    b: usize,
    k: usize,
    cw: &[f32],
    fp: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(c_out.len(), n_br * b * k);
    debug_assert_eq!(cw.len(), n_br * k * fp);
    let width = n_br * fp;
    debug_assert_eq!(out.len(), b * width);
    out.fill(0.0);
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(width).enumerate() {
            let i = r0 + rr;
            for j in 0..n_br {
                let ocols = &mut orow[j * fp..(j + 1) * fp];
                let sk = &c_out[(j * b + i) * k..(j * b + i + 1) * k];
                for (v, &coef) in sk.iter().enumerate() {
                    if coef == 0.0 {
                        continue; // sketch sparsity — most buckets are empty
                    }
                    simd::axpy(ocols, coef, &cw[(j * k + v) * fp..(j * k + v + 1) * fp]);
                }
            }
        }
    };
    if b * k * width < PAR_THRESHOLD {
        body(0, &mut *out);
    } else {
        par::par_chunks_mut(out, ROW_BLOCK * width, |ci, chunk| body(ci * ROW_BLOCK, chunk));
    }
}

/// Allocating wrapper of [`unsketch_into`].
pub fn unsketch(c_out: &[f32], n_br: usize, b: usize, k: usize, cw: &[f32], fp: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * n_br * fp];
    unsketch_into(c_out, n_br, b, k, cw, fp, &mut out);
    out
}

/// `dst += src`, elementwise (the fused-add used between op outputs; the
/// addend is always materialized first so associativity matches the
/// pre-arena interpreter exactly).
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    // Element-wise — the SIMD path is bit-identical to the scalar loop.
    simd::add_assign(dst, src);
}

/// Per-row dot with a fixed vector: `(rows, w) · (w,) -> (rows,)` — the
/// attention projections `e = (X W) a`.
pub fn dot_rows_into(a: &[f32], w: usize, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(v.len(), w);
    debug_assert_eq!(a.len(), out.len() * w);
    for (o, row) in out.iter_mut().zip(a.chunks(w)) {
        *o = simd::dot(row, v);
    }
}

/// Allocating wrapper of [`dot_rows_into`].
pub fn dot_rows(a: &[f32], w: usize, v: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; a.len() / w.max(1)];
    dot_rows_into(a, w, v, &mut out);
    out
}

/// Add a broadcast row bias in place: `x (rows, n) += bias (n)`.
pub fn add_bias(x: &mut [f32], n: usize, bias: &[f32]) {
    debug_assert_eq!(bias.len(), n);
    for row in x.chunks_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

/// Column sums: `(rows, n) -> (n)` (bias gradient), into a reused buffer.
pub fn col_sum_into(x: &[f32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n);
    out.fill(0.0);
    for row in x.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Allocating wrapper of [`col_sum_into`].
pub fn col_sum(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    col_sum_into(x, n, &mut out);
    out
}

/// Elementwise ReLU into a reused buffer.
pub fn relu_into(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = if v > 0.0 { v } else { 0.0 };
    }
}

/// Allocating wrapper of [`relu_into`].
pub fn relu(x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    relu_into(x, &mut out);
    out
}

/// Mask a gradient by ReLU'(pre): `g ⊙ 1[pre > 0]`, in place.
pub fn relu_bwd(g: &mut [f32], pre: &[f32]) {
    debug_assert_eq!(g.len(), pre.len());
    for (gv, &pv) in g.iter_mut().zip(pre) {
        if pv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Copy columns `[lo, hi)` of a `(rows, width)` buffer into a dense
/// `(rows, hi-lo)` one (reused buffer).
pub fn slice_cols_into(x: &[f32], width: usize, lo: usize, hi: usize, out: &mut [f32]) {
    debug_assert!(lo <= hi && hi <= width);
    let rows = x.len() / width;
    let w = hi - lo;
    debug_assert_eq!(out.len(), rows * w);
    for i in 0..rows {
        out[i * w..(i + 1) * w].copy_from_slice(&x[i * width + lo..i * width + hi]);
    }
}

/// Allocating wrapper of [`slice_cols_into`].
pub fn slice_cols(x: &[f32], width: usize, lo: usize, hi: usize) -> Vec<f32> {
    let rows = x.len() / width;
    let mut out = vec![0.0f32; rows * (hi - lo)];
    slice_cols_into(x, width, lo, hi, &mut out);
    out
}

/// Row-stable log-softmax over `(rows, c)`, into a reused buffer.
pub fn log_softmax_into(x: &[f32], c: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (orow, row) in out.chunks_mut(c).zip(x.chunks(c)) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut lse = 0.0f32;
        for &v in row {
            lse += (v - mx).exp();
        }
        let lse = lse.ln() + mx;
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = v - lse;
        }
    }
}

/// Allocating wrapper of [`log_softmax_into`].
pub fn log_softmax(x: &[f32], c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    log_softmax_into(x, c, &mut out);
    out
}

/// Numerically-stable `log(1 + exp(-|z|))` BCE pieces: returns
/// `max(z,0) - z*y + log1p(exp(-|z|))`.
pub fn bce_with_logits(z: f32, y: f32) -> f32 {
    z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()
}

/// Logistic sigmoid.
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

// ---------------------------------------------------------------------------
// Attention-score numerics (GAT / Graph Transformer, paper Table 1 + App. E).
// Mirrors python/compile/kernels/gat_scores.py: scores are UNNORMALIZED
// (decoupled row normalization — the denominator is the same attention
// applied to ones), LeakyReLU-shaped, and capped before the exp so ±1e4
// logits can never overflow (the Lipschitz control of App. E).
// ---------------------------------------------------------------------------

/// LeakyReLU slope of the GAT score nonlinearity.
pub const SLOPE: f32 = 0.2;

/// Cap on the pre-exp score: bounds `exp()` at e⁸ ≈ 2981 (App. E).
pub const SCORE_CAP: f32 = 8.0;

/// `exp(min(LeakyReLU(t), SCORE_CAP))` — one unnormalized GAT score.
#[inline]
pub fn leaky_exp(t: f32) -> f32 {
    let l = if t >= 0.0 { t } else { SLOPE * t };
    l.min(SCORE_CAP).exp()
}

/// `d/dt exp(min(LeakyReLU(t), CAP)) / leaky_exp(t)`: the multiplicative
/// gradient factor (slope gate × cap gate), matching the analytic VJP of
/// `gat_scores` (`leaky < CAP` is a strict comparison there too).
#[inline]
pub fn leaky_exp_grad(t: f32) -> f32 {
    let l = if t >= 0.0 { t } else { SLOPE * t };
    if l < SCORE_CAP {
        if t >= 0.0 {
            1.0
        } else {
            SLOPE
        }
    } else {
        0.0
    }
}

/// `exp(min(t, SCORE_CAP))` — one global dot-product attention score (txf).
#[inline]
pub fn exp_capped(t: f32) -> f32 {
    t.min(SCORE_CAP).exp()
}

/// Multiplicative gradient factor of [`exp_capped`] (cap gate only).
#[inline]
pub fn exp_capped_grad(t: f32) -> f32 {
    if t < SCORE_CAP {
        1.0
    } else {
        0.0
    }
}

/// Below this many tile elements the exp-heavy score kernels run serially.
/// An `exp` costs ~20 multiply-accumulates, so the dispatch break-even
/// arrives much earlier than the matmuls' [`PAR_THRESHOLD`].
const EXP_PAR_THRESHOLD: usize = 1 << 13;

/// Dense GAT score tile over a fixed mask (`gat_scores` kernel semantics):
/// `out[i,v] = mask[i,v] · leaky_exp(e_dst[i] + e_src[v])` for a `(b, m)`
/// mask.  Serves both the in-batch block (`m = b`, mask = 𝔠 = A+I) and the
/// out-of-batch block (`m = k`, mask = the M_out count sketches: a codeword
/// bucket with zero out-of-batch members contributes exactly nothing).
/// Rows are independent, so the tile blocks over `util::par` exactly like
/// the matmuls — bit-identical to [`gat_score_tile_serial`] at any thread
/// count.
pub fn gat_score_tile_into(e_dst: &[f32], e_src: &[f32], mask: &[f32], out: &mut [f32]) {
    let (b, m) = (e_dst.len(), e_src.len());
    debug_assert_eq!(mask.len(), b * m);
    debug_assert_eq!(out.len(), b * m);
    out.fill(0.0);
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(m).enumerate() {
            let i = r0 + rr;
            let mrow = &mask[i * m..(i + 1) * m];
            for v in 0..m {
                if mrow[v] != 0.0 {
                    orow[v] = mrow[v] * leaky_exp(e_dst[i] + e_src[v]);
                }
            }
        }
    };
    if b * m < EXP_PAR_THRESHOLD {
        body(0, &mut *out);
    } else {
        par::par_chunks_mut(out, ROW_BLOCK * m, |ci, chunk| body(ci * ROW_BLOCK, chunk));
    }
}

/// Allocating wrapper of [`gat_score_tile_into`].
pub fn gat_score_tile(e_dst: &[f32], e_src: &[f32], mask: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; e_dst.len() * e_src.len()];
    gat_score_tile_into(e_dst, e_src, mask, &mut out);
    out
}

/// Serial reference of [`gat_score_tile`] (the pre-parallel loop, kept
/// verbatim as the parity baseline for tests and benches).
pub fn gat_score_tile_serial(e_dst: &[f32], e_src: &[f32], mask: &[f32]) -> Vec<f32> {
    let (b, m) = (e_dst.len(), e_src.len());
    debug_assert_eq!(mask.len(), b * m);
    let mut out = vec![0.0f32; b * m];
    for i in 0..b {
        let orow = &mut out[i * m..(i + 1) * m];
        let mrow = &mask[i * m..(i + 1) * m];
        for v in 0..m {
            if mrow[v] != 0.0 {
                orow[v] = mrow[v] * leaky_exp(e_dst[i] + e_src[v]);
            }
        }
    }
    out
}

/// Elementwise `exp_capped` over a score tile (txf global attention,
/// 𝔠 = all-ones), blocked over `util::par` above the exp threshold.
/// Purely elementwise, so parallel == serial bitwise.
pub fn exp_capped_tile_into(t: &[f32], out: &mut [f32]) {
    debug_assert_eq!(t.len(), out.len());
    let body = |o0: usize, chunk: &mut [f32]| {
        for (j, x) in chunk.iter_mut().enumerate() {
            *x = exp_capped(t[o0 + j]);
        }
    };
    if t.len() < EXP_PAR_THRESHOLD {
        body(0, &mut *out);
    } else {
        let chunk = ROW_BLOCK * 64;
        par::par_chunks_mut(out, chunk, |ci, c| body(ci * chunk, c));
    }
}

/// Allocating wrapper of [`exp_capped_tile_into`].
pub fn exp_capped_tile(t: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; t.len()];
    exp_capped_tile_into(t, &mut out);
    out
}

/// Column-weighted capped-exp tile: `out[i,v] = w[v] · exp_capped(scale ·
/// t[i,v])` for a `(rows, k)` tile — the txf out-of-batch score block
/// (`w = cnt_out`, the bucket populations: an empty bucket contributes
/// exactly nothing).  Blocked over rows like [`gat_score_tile`].
pub fn col_weighted_exp_tile_into(t: &[f32], k: usize, w: &[f32], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(w.len(), k);
    debug_assert_eq!(t.len() % k, 0);
    debug_assert_eq!(t.len(), out.len());
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(k).enumerate() {
            let trow = &t[(r0 + rr) * k..(r0 + rr + 1) * k];
            for v in 0..k {
                orow[v] = if w[v] != 0.0 {
                    w[v] * exp_capped(scale * trow[v])
                } else {
                    0.0
                };
            }
        }
    };
    if t.len() < EXP_PAR_THRESHOLD {
        body(0, &mut *out);
    } else {
        par::par_chunks_mut(out, ROW_BLOCK * k, |ci, chunk| body(ci * ROW_BLOCK, chunk));
    }
}

/// Allocating wrapper of [`col_weighted_exp_tile_into`].
pub fn col_weighted_exp_tile(t: &[f32], k: usize, w: &[f32], scale: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; t.len()];
    col_weighted_exp_tile_into(t, k, w, scale, &mut out);
    out
}

/// Per-edge GAT attention scatter (forward): for every live edge `u → v`,
/// `sc = ecoef[e] · leaky_exp(e_dst[v] + e_src[u])`, accumulating
/// `num[v] += sc · proj[u]` and `den[v] += sc`.  Parallelized like the VQ
/// kernels: edges are bucketed by destination row block (one serial O(E)
/// pass), then blocks of destination rows are processed concurrently —
/// each thread owns disjoint `num`/`den` rows, and contributions within a
/// destination keep their original edge order, so the result is
/// bit-identical to [`edge_attn_scatter_serial`] at any thread count.
pub fn edge_attn_scatter(
    proj: &[f32],
    hh: usize,
    nn: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    e_src: &[f32],
    e_dst: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    if esrc.len() * hh < PAR_THRESHOLD {
        return edge_attn_scatter_serial(proj, hh, nn, esrc, edst, ecoef, e_src, e_dst);
    }
    edge_attn_scatter_blocked(proj, hh, nn, esrc, edst, ecoef, e_src, e_dst)
}

/// [`edge_attn_scatter`] into caller-owned buffers (the edge executor's
/// arena).  The serial path (below the dispatch threshold — every hermetic
/// test config) writes `num`/`den` directly and allocates nothing; the
/// blocked-parallel path still allocates its internal fused accumulator +
/// edge buckets (inherent to the bucketing scheme) and copies out.
pub fn edge_attn_scatter_into(
    proj: &[f32],
    hh: usize,
    nn: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    e_src: &[f32],
    e_dst: &[f32],
    num: &mut [f32],
    den: &mut [f32],
) {
    debug_assert_eq!(num.len(), nn * hh);
    debug_assert_eq!(den.len(), nn);
    if esrc.len() * hh < PAR_THRESHOLD {
        edge_attn_scatter_serial_into(proj, hh, esrc, edst, ecoef, e_src, e_dst, num, den);
        return;
    }
    let (n, d) = edge_attn_scatter_blocked(proj, hh, nn, esrc, edst, ecoef, e_src, e_dst);
    num.copy_from_slice(&n);
    den.copy_from_slice(&d);
}

/// The one serial scatter body (shared by [`edge_attn_scatter_serial`] and
/// the arena path) — zero-then-accumulate in edge order.
fn edge_attn_scatter_serial_into(
    proj: &[f32],
    hh: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    e_src: &[f32],
    e_dst: &[f32],
    num: &mut [f32],
    den: &mut [f32],
) {
    num.fill(0.0);
    den.fill(0.0);
    for e in 0..esrc.len() {
        let cf = ecoef[e];
        if cf == 0.0 {
            continue; // padding edge
        }
        let (u, v) = (esrc[e] as usize, edst[e] as usize);
        let sc = cf * leaky_exp(e_dst[v] + e_src[u]);
        den[v] += sc;
        let src = &proj[u * hh..(u + 1) * hh];
        let dst = &mut num[v * hh..(v + 1) * hh];
        for t in 0..hh {
            dst[t] += sc * src[t];
        }
    }
}

/// Serial reference of the per-edge scatter (the pre-parallel loop,
/// parity baseline for tests and the fallback below the threshold).
pub fn edge_attn_scatter_serial(
    proj: &[f32],
    hh: usize,
    nn: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    e_src: &[f32],
    e_dst: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut num = vec![0.0f32; nn * hh];
    let mut den = vec![0.0f32; nn];
    edge_attn_scatter_serial_into(proj, hh, esrc, edst, ecoef, e_src, e_dst, &mut num, &mut den);
    (num, den)
}

/// The blocked-parallel body of [`edge_attn_scatter`] (public so the
/// parity tests can force it below the size threshold).
pub fn edge_attn_scatter_blocked(
    proj: &[f32],
    hh: usize,
    nn: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    e_src: &[f32],
    e_dst: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let n_blocks = (nn + ROW_BLOCK - 1) / ROW_BLOCK;
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); n_blocks.max(1)];
    for e in 0..esrc.len() {
        if ecoef[e] != 0.0 {
            buckets[edst[e] as usize / ROW_BLOCK].push(e as u32);
        }
    }
    // num and den fused row-wise ([num_0..num_hh, den]) so one
    // par_chunks_mut owns both accumulators of a destination row.
    let w = hh + 1;
    let mut numden = vec![0.0f32; nn * w];
    par::par_chunks_mut(&mut numden, ROW_BLOCK * w, |ci, chunk| {
        let base = ci * ROW_BLOCK;
        for &e in &buckets[ci] {
            let e = e as usize;
            let (u, v) = (esrc[e] as usize, edst[e] as usize);
            let sc = ecoef[e] * leaky_exp(e_dst[v] + e_src[u]);
            let row = &mut chunk[(v - base) * w..(v - base + 1) * w];
            let src = &proj[u * hh..(u + 1) * hh];
            for t in 0..hh {
                row[t] += sc * src[t];
            }
            row[hh] += sc;
        }
    });
    let mut num = vec![0.0f32; nn * hh];
    let mut den = vec![0.0f32; nn];
    for v in 0..nn {
        num[v * hh..(v + 1) * hh].copy_from_slice(&numden[v * w..v * w + hh]);
        den[v] = numden[v * w + hh];
    }
    (num, den)
}

/// Attention-mass floor for the decoupled row normalization:
/// `exp(-SCORE_CAP)`, the cap's reciprocal.  A destination whose every
/// score underflows would otherwise divide by ~0 and blow the probe
/// gradient ∂ℓ/∂num up by ~1/floor — this keeps the normalization
/// Lipschitz on both sides of the cap (App. E; same constant as
/// `python/compile/layers.py::DEN_FLOOR`).  An isolated row with zero
/// attention mass still stays exactly zero.
pub const DEN_FLOOR: f32 = 3.354_626_2e-4;

/// Row-normalize an unnormalized attention numerator in place:
/// `num[i, :] /= max(den[i], DEN_FLOOR)`.
pub fn attn_normalize(num: &mut [f32], h: usize, den: &[f32]) {
    for (row, &d) in num.chunks_mut(h).zip(den) {
        let inv = 1.0 / d.max(DEN_FLOOR);
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Row sums of a `(rows, m)` score tile (the attention denominator), into
/// a reused buffer.
pub fn row_sum_into(x: &[f32], m: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len() * m);
    for (o, row) in out.iter_mut().zip(x.chunks(m)) {
        *o = row.iter().sum();
    }
}

/// Allocating wrapper of [`row_sum_into`].
pub fn row_sum(x: &[f32], m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len() / m.max(1)];
    row_sum_into(x, m, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // (2,3) @ (3,2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, 2, 3, &b, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transposed_variants_agree_with_explicit_transpose() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (m, k, n) = (17, 9, 13);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss_f32()).collect();
        let b: Vec<f32> = (0..m * n).map(|_| rng.gauss_f32()).collect();
        // aᵀ b via explicit transpose
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let want = matmul(&at, k, m, &b, n);
        let got = matmul_at_b(&a, m, k, &b, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
        // a bᵀ via explicit transpose
        let c: Vec<f32> = (0..n * k).map(|_| rng.gauss_f32()).collect();
        let mut ct = vec![0.0f32; k * n];
        for i in 0..n {
            for j in 0..k {
                ct[j * n + i] = c[i * k + j];
            }
        }
        let want2 = matmul(&a, m, k, &ct, n);
        let got2 = matmul_a_bt(&a, m, k, &c, n);
        for (x, y) in got2.iter().zip(&want2) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn unsketch_matches_reference_einsum() {
        // einsum("jbv,jvp->bjp") laid out as (b, n_br*fp)
        let mut rng = crate::util::rng::Rng::new(4);
        let (nb, b, k, fp) = (3, 5, 7, 4);
        let c_out: Vec<f32> = (0..nb * b * k).map(|_| rng.gauss_f32()).collect();
        let cw: Vec<f32> = (0..nb * k * fp).map(|_| rng.gauss_f32()).collect();
        let got = unsketch(&c_out, nb, b, k, &cw, fp);
        for i in 0..b {
            for j in 0..nb {
                for p in 0..fp {
                    let mut want = 0.0f32;
                    for v in 0..k {
                        want += c_out[(j * b + i) * k + v] * cw[(j * k + v) * fp + p];
                    }
                    let x = got[i * nb * fp + j * fp + p];
                    assert!((x - want).abs() < 1e-4, "[{i},{j},{p}]");
                }
            }
        }
    }

    #[test]
    fn log_softmax_rows_normalize() {
        let x = [1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let ls = log_softmax(&x, 3);
        for row in ls.chunks(3) {
            let s: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn bce_matches_naive_formula_on_safe_range() {
        for &(z, y) in &[(0.3f32, 1.0f32), (-0.7, 0.0), (2.0, 1.0), (-3.0, 1.0)] {
            let naive = -(y * sigmoid(z).ln() + (1.0 - y) * (1.0 - sigmoid(z)).ln());
            assert!((bce_with_logits(z, y) - naive).abs() < 1e-5);
        }
    }

    // -----------------------------------------------------------------------
    // Attention numerics: table-driven edge cases of the gat/txf forward.
    // -----------------------------------------------------------------------

    #[test]
    fn attention_score_overflow_is_capped() {
        // Logits at ±1e4 must stay finite on every score path (App. E cap).
        let cap = SCORE_CAP.exp();
        let cases: &[(f32, f32)] = &[
            (1e4, cap),                    // raw overflow → capped at e⁸
            (SCORE_CAP, cap),              // exactly at the cap
            (0.0, 1.0),                    // kink of the LeakyReLU
            (-1.0, (-SLOPE).exp()),        // negative branch: slope 0.2
            (-1e4, (SLOPE * -1e4).exp()),  // extreme negative → underflows to 0
        ];
        for &(t, want) in cases {
            let got = leaky_exp(t);
            assert!(got.is_finite(), "leaky_exp({t}) not finite");
            assert!(
                (got - want).abs() <= 1e-4 * want.max(1e-30),
                "leaky_exp({t}) = {got}, want {want}"
            );
            assert!(exp_capped(t).is_finite(), "exp_capped({t}) not finite");
        }
        assert_eq!(exp_capped(1e4), cap);
        // Gradient gates: zero beyond the cap, slope-blended below zero.
        assert_eq!(leaky_exp_grad(1e4), 0.0);
        assert_eq!(leaky_exp_grad(SCORE_CAP), 0.0); // strict `<` like the VJP
        assert_eq!(leaky_exp_grad(1.0), 1.0);
        assert_eq!(leaky_exp_grad(-1.0), SLOPE);
        assert_eq!(exp_capped_grad(1e4), 0.0);
        assert_eq!(exp_capped_grad(0.0), 1.0);
    }

    #[test]
    fn score_tile_single_neighbor_and_isolated_rows() {
        // Three destination rows over a 3-node batch: row 0 attends to its
        // single neighbor (+ self), row 1 is isolated (self loop only), row
        // 2 has no mask mass at all (pure padding row).
        let e_dst = [0.5f32, -0.25, 2.0];
        let e_src = [0.1f32, 0.3, -0.7];
        #[rustfmt::skip]
        let mask = [
            1.0, 1.0, 0.0,
            0.0, 1.0, 0.0,
            0.0, 0.0, 0.0,
        ];
        let s = gat_score_tile(&e_dst, &e_src, &mask);
        // row 0: self + one neighbor
        assert!((s[0] - leaky_exp(0.6)).abs() < 1e-6);
        assert!((s[1] - leaky_exp(0.8)).abs() < 1e-6);
        assert_eq!(s[2], 0.0);
        // row 1: single (self) entry survives
        assert!((s[4] - leaky_exp(0.05)).abs() < 1e-6);
        assert_eq!((s[3], s[5]), (0.0, 0.0));
        // row 2: fully masked out
        assert_eq!(&s[6..9], &[0.0, 0.0, 0.0]);
        // Normalization: the single-neighbor rows become convex weights,
        // the empty row divides by the floor and stays exactly zero.
        let den = row_sum(&s, 3);
        let mut num = s.clone();
        attn_normalize(&mut num, 3, &den);
        assert!((num[0] + num[1] - 1.0).abs() < 1e-6);
        assert!((num[4] - 1.0).abs() < 1e-6);
        assert_eq!(&num[6..9], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn score_tile_zero_degree_codeword_buckets() {
        // Out-of-batch block: M_out[i,v] counts out-of-batch in-neighbors in
        // codeword bucket v.  Empty buckets (count 0) must contribute nothing
        // even when the codeword projection is extreme.
        let e_dst = [0.2f32, -1.0];
        let ecw_src = [1e4f32, -3.0, 0.5]; // bucket 0's projection overflows
        #[rustfmt::skip]
        let m_out = [
            0.0, 2.0, 1.0,  // row 0: bucket 0 empty
            0.0, 0.0, 0.0,  // row 1: every bucket empty (all nbrs in-batch)
        ];
        let s = gat_score_tile(&e_dst, &ecw_src, &m_out);
        assert_eq!(s[0], 0.0, "empty bucket leaked a message");
        assert!((s[1] - 2.0 * leaky_exp(-2.8)).abs() < 1e-6);
        assert!((s[2] - leaky_exp(0.7)).abs() < 1e-6);
        assert_eq!(&s[3..6], &[0.0, 0.0, 0.0]);
        assert!(s.iter().all(|x| x.is_finite()));
        // txf global attention at the same extremes: cnt_out ⊙ exp_capped
        // stays finite and an empty bucket stays silent.
        let glob = 0.0f32 * exp_capped(1e4);
        assert_eq!(glob, 0.0);
    }

    #[test]
    fn score_tile_parallel_matches_serial_bitwise() {
        // Above and below the dispatch threshold, the blocked tile must be
        // bit-identical to the serial reference (ROADMAP parity promise).
        let mut rng = crate::util::rng::Rng::new(21);
        for &(b, m) in &[(7usize, 5usize), (96, 96), (130, 40)] {
            let e_dst: Vec<f32> = (0..b).map(|_| rng.gauss_f32()).collect();
            let e_src: Vec<f32> = (0..m).map(|_| rng.gauss_f32()).collect();
            let mask: Vec<f32> = (0..b * m)
                .map(|_| if rng.f64() < 0.2 { (1 + rng.below(3)) as f32 } else { 0.0 })
                .collect();
            let got = gat_score_tile(&e_dst, &e_src, &mask);
            let want = gat_score_tile_serial(&e_dst, &e_src, &mask);
            assert_eq!(got, want, "b={b} m={m}");
        }
    }

    #[test]
    fn exp_tiles_match_scalar_reference_bitwise() {
        let mut rng = crate::util::rng::Rng::new(22);
        let k = 24;
        let rows = 400; // rows*k > EXP_PAR_THRESHOLD → parallel path
        let t: Vec<f32> = (0..rows * k).map(|_| 4.0 * rng.gauss_f32()).collect();
        let w: Vec<f32> = (0..k)
            .map(|_| if rng.f64() < 0.3 { 0.0 } else { rng.below(20) as f32 })
            .collect();
        let got = exp_capped_tile(&t);
        for (g, &x) in got.iter().zip(&t) {
            assert_eq!(*g, exp_capped(x));
        }
        let scale = 0.25f32;
        let got = col_weighted_exp_tile(&t, k, &w, scale);
        for i in 0..rows {
            for v in 0..k {
                assert_eq!(got[i * k + v], w[v] * exp_capped(scale * t[i * k + v]));
            }
        }
    }

    #[test]
    fn edge_scatter_parallel_matches_serial_bitwise() {
        // The bucketed scatter preserves per-destination edge order, so it
        // must agree with the serial loop exactly — including padding
        // edges (coef 0) and destinations with no edges at all.
        let mut rng = crate::util::rng::Rng::new(23);
        for &(nn, ne, hh) in &[(50usize, 300usize, 8usize), (333, 4000, 16), (64, 0, 4)] {
            let proj: Vec<f32> = (0..nn * hh).map(|_| rng.gauss_f32()).collect();
            let e_src: Vec<f32> = (0..nn).map(|_| rng.gauss_f32()).collect();
            let e_dst: Vec<f32> = (0..nn).map(|_| rng.gauss_f32()).collect();
            let esrc: Vec<i32> = (0..ne).map(|_| rng.below(nn) as i32).collect();
            let edst: Vec<i32> = (0..ne).map(|_| rng.below(nn) as i32).collect();
            let ecoef: Vec<f32> = (0..ne)
                .map(|_| if rng.f64() < 0.25 { 0.0 } else { rng.f32() })
                .collect();
            let (ns, ds) =
                edge_attn_scatter_serial(&proj, hh, nn, &esrc, &edst, &ecoef, &e_src, &e_dst);
            let (nb, db) =
                edge_attn_scatter_blocked(&proj, hh, nn, &esrc, &edst, &ecoef, &e_src, &e_dst);
            assert_eq!(ns, nb, "num nn={nn} ne={ne}");
            assert_eq!(ds, db, "den nn={nn} ne={ne}");
            // and the dispatching wrapper agrees with both
            let (nw, dw) =
                edge_attn_scatter(&proj, hh, nn, &esrc, &edst, &ecoef, &e_src, &e_dst);
            assert_eq!(nw, ns);
            assert_eq!(dw, ds);
        }
    }

    #[test]
    fn log_softmax_survives_extreme_logits() {
        // The loss head downstream of attention must also absorb ±1e4.
        let x = [1e4f32, -1e4, 0.0, -1e4, 1e4, 0.0];
        let ls = log_softmax(&x, 3);
        assert!(ls.iter().all(|v| v.is_finite()));
        for row in ls.chunks(3) {
            let s: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
        // the dominant logit owns (almost) all the mass
        assert!(ls[0].abs() < 1e-3);
        assert!(ls[4].abs() < 1e-3);
    }
}
