//! Dense row-major f32 ops for the native CPU backend: matmul variants
//! (thread-parallel over row blocks above a serial threshold), the VQ
//! unsketch primitive (codebook-weighted out-of-batch message
//! reconstruction), activations and loss-head numerics.
//!
//! Semantics mirror `python/compile/kernels/ref.py` — these are the same
//! mathematical definitions the Pallas kernels are tested against.

use crate::util::par;

/// Below this many multiply-accumulates a matmul runs serially (thread
/// dispatch costs more than the arithmetic).
const PAR_THRESHOLD: usize = 1 << 16;

/// Rows per parallel work unit.
const ROW_BLOCK: usize = 32;

/// `(m, k) @ (k, n) -> (m, n)`, ikj order (streams `b` rows, vectorizes n).
pub fn matmul(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = vec![0.0f32; m * n];
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(n).enumerate() {
            let r = r0 + rr;
            let arow = &a[r * k..(r + 1) * k];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
    };
    if m * k * n < PAR_THRESHOLD {
        body(0, &mut out);
    } else {
        par::par_chunks_mut(&mut out, ROW_BLOCK * n, |ci, chunk| body(ci * ROW_BLOCK, chunk));
    }
    out
}

/// `aᵀ @ b` where `a` is `(m, k)` and `b` is `(m, n)` -> `(k, n)`.
/// Serial: used for weight gradients whose output is small.
pub fn matmul_at_b(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut out = vec![0.0f32; k * n];
    for r in 0..m {
        let arow = &a[r * k..(r + 1) * k];
        let brow = &b[r * n..(r + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// `a @ bᵀ` where `a` is `(m, k)` and `b` is `(n, k)` -> `(m, n)` (row-dot).
pub fn matmul_a_bt(a: &[f32], m: usize, k: usize, b: &[f32], n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = vec![0.0f32; m * n];
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a[(r0 + rr) * k..(r0 + rr + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut dot = 0.0f32;
                for d in 0..k {
                    dot += arow[d] * brow[d];
                }
                *o = dot;
            }
        }
    };
    if m * k * n < PAR_THRESHOLD {
        body(0, &mut out);
    } else {
        par::par_chunks_mut(&mut out, ROW_BLOCK * n, |ci, chunk| body(ci * ROW_BLOCK, chunk));
    }
    out
}

/// Out-of-batch message reconstruction (`unsketch_ref`): per branch `j`,
/// `(b, k) @ (k, fp)` written into columns `[j*fp, (j+1)*fp)` of a
/// `(b, n_br*fp)` buffer.
pub fn unsketch(c_out: &[f32], n_br: usize, b: usize, k: usize, cw: &[f32], fp: usize) -> Vec<f32> {
    debug_assert_eq!(c_out.len(), n_br * b * k);
    debug_assert_eq!(cw.len(), n_br * k * fp);
    let width = n_br * fp;
    let mut out = vec![0.0f32; b * width];
    let body = |r0: usize, chunk: &mut [f32]| {
        for (rr, orow) in chunk.chunks_mut(width).enumerate() {
            let i = r0 + rr;
            for j in 0..n_br {
                let ocols = &mut orow[j * fp..(j + 1) * fp];
                let sk = &c_out[(j * b + i) * k..(j * b + i + 1) * k];
                for (v, &coef) in sk.iter().enumerate() {
                    if coef == 0.0 {
                        continue;
                    }
                    let cwrow = &cw[(j * k + v) * fp..(j * k + v + 1) * fp];
                    for d in 0..fp {
                        ocols[d] += coef * cwrow[d];
                    }
                }
            }
        }
    };
    if b * k * width < PAR_THRESHOLD {
        body(0, &mut out);
    } else {
        par::par_chunks_mut(&mut out, ROW_BLOCK * width, |ci, chunk| {
            body(ci * ROW_BLOCK, chunk)
        });
    }
    out
}

/// Add a broadcast row bias in place: `x (rows, n) += bias (n)`.
pub fn add_bias(x: &mut [f32], n: usize, bias: &[f32]) {
    debug_assert_eq!(bias.len(), n);
    for row in x.chunks_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

/// Column sums: `(rows, n) -> (n)` (bias gradient).
pub fn col_sum(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for row in x.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// Elementwise ReLU.
pub fn relu(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| if v > 0.0 { v } else { 0.0 }).collect()
}

/// Mask a gradient by ReLU'(pre): `g ⊙ 1[pre > 0]`, in place.
pub fn relu_bwd(g: &mut [f32], pre: &[f32]) {
    debug_assert_eq!(g.len(), pre.len());
    for (gv, &pv) in g.iter_mut().zip(pre) {
        if pv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Copy columns `[lo, hi)` of a `(rows, width)` buffer into a dense
/// `(rows, hi-lo)` one.
pub fn slice_cols(x: &[f32], width: usize, lo: usize, hi: usize) -> Vec<f32> {
    debug_assert!(lo <= hi && hi <= width);
    let rows = x.len() / width;
    let w = hi - lo;
    let mut out = vec![0.0f32; rows * w];
    for i in 0..rows {
        out[i * w..(i + 1) * w].copy_from_slice(&x[i * width + lo..i * width + hi]);
    }
    out
}

/// Row-stable log-softmax over `(rows, c)`.
pub fn log_softmax(x: &[f32], c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (orow, row) in out.chunks_mut(c).zip(x.chunks(c)) {
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut lse = 0.0f32;
        for &v in row {
            lse += (v - mx).exp();
        }
        let lse = lse.ln() + mx;
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = v - lse;
        }
    }
    out
}

/// Numerically-stable `log(1 + exp(-|z|))` BCE pieces: returns
/// `max(z,0) - z*y + log1p(exp(-|z|))`.
pub fn bce_with_logits(z: f32, y: f32) -> f32 {
    z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()
}

/// Logistic sigmoid.
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // (2,3) @ (3,2)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [7.0, 8.0, 9.0, 10.0, 11.0, 12.0];
        let c = matmul(&a, 2, 3, &b, 2);
        assert_eq!(c, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn transposed_variants_agree_with_explicit_transpose() {
        let mut rng = crate::util::rng::Rng::new(2);
        let (m, k, n) = (17, 9, 13);
        let a: Vec<f32> = (0..m * k).map(|_| rng.gauss_f32()).collect();
        let b: Vec<f32> = (0..m * n).map(|_| rng.gauss_f32()).collect();
        // aᵀ b via explicit transpose
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let want = matmul(&at, k, m, &b, n);
        let got = matmul_at_b(&a, m, k, &b, n);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
        // a bᵀ via explicit transpose
        let c: Vec<f32> = (0..n * k).map(|_| rng.gauss_f32()).collect();
        let mut ct = vec![0.0f32; k * n];
        for i in 0..n {
            for j in 0..k {
                ct[j * n + i] = c[i * k + j];
            }
        }
        let want2 = matmul(&a, m, k, &ct, n);
        let got2 = matmul_a_bt(&a, m, k, &c, n);
        for (x, y) in got2.iter().zip(&want2) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn unsketch_matches_reference_einsum() {
        // einsum("jbv,jvp->bjp") laid out as (b, n_br*fp)
        let mut rng = crate::util::rng::Rng::new(4);
        let (nb, b, k, fp) = (3, 5, 7, 4);
        let c_out: Vec<f32> = (0..nb * b * k).map(|_| rng.gauss_f32()).collect();
        let cw: Vec<f32> = (0..nb * k * fp).map(|_| rng.gauss_f32()).collect();
        let got = unsketch(&c_out, nb, b, k, &cw, fp);
        for i in 0..b {
            for j in 0..nb {
                for p in 0..fp {
                    let mut want = 0.0f32;
                    for v in 0..k {
                        want += c_out[(j * b + i) * k + v] * cw[(j * k + v) * fp + p];
                    }
                    let x = got[i * nb * fp + j * fp + p];
                    assert!((x - want).abs() < 1e-4, "[{i},{j},{p}]");
                }
            }
        }
    }

    #[test]
    fn log_softmax_rows_normalize() {
        let x = [1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        let ls = log_softmax(&x, 3);
        for row in ls.chunks(3) {
            let s: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn bce_matches_naive_formula_on_safe_range() {
        for &(z, y) in &[(0.3f32, 1.0f32), (-0.7, 0.0), (2.0, 1.0), (-3.0, 1.0)] {
            let naive = -(y * sigmoid(z).ln() + (1.0 - y) * (1.0 - sigmoid(z)).ln());
            assert!((bce_with_logits(z, y) - naive).abs() < 1e-5);
        }
    }
}
