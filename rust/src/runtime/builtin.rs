//! Built-in manifest: a pure-Rust mirror of `python/compile/config.py` +
//! `python/compile/aot.py`'s artifact registry.
//!
//! The PJRT path consumes `artifacts/manifest.json` emitted by the AOT
//! pipeline; the native backend needs the same shape contract but no
//! Python, so this module reconstructs the registry deterministically.  Any
//! drift between the two is caught by `tests/` (the builtin manifest is
//! validated against a checked-in manifest.json whenever one exists).
//!
//! The signatures declared here are resolved exactly once per artifact by
//! `runtime::native::plan::Plan::compile` (slot indices + per-layer dims);
//! a registry output the compiled executor would not produce fails at load
//! time, not at step time — keep the two in sync when adding artifacts.

use std::path::Path;

use crate::runtime::manifest::{
    ArtifactSpec, DatasetCfg, LayerPlan, Manifest, ModelCfg, TensorSpec, TrainCfg,
};
use crate::util::tensor::DType;

fn ceil8(x: usize) -> usize {
    (x + 7) / 8 * 8
}

#[allow(clippy::too_many_arguments)]
fn dataset(
    name: &str,
    n: usize,
    m_max: usize,
    f_in: usize,
    n_classes: usize,
    task: &str,
    multilabel: bool,
    inductive: bool,
    n_graphs: usize,
    avg_degree: f64,
    communities: usize,
) -> DatasetCfg {
    DatasetCfg {
        name: name.to_string(),
        n,
        m_max,
        f_in,
        f_in_pad: ceil8(f_in),
        n_classes,
        task: task.to_string(),
        multilabel,
        inductive,
        n_graphs,
        avg_degree,
        communities,
        feature_noise: 1.0,
        intra_p_scale: 12.0,
    }
}

fn model(name: &str, fp: usize) -> ModelCfg {
    ModelCfg { name: name.to_string(), hidden: 64, layers: 3, heads: 2, fp }
}

fn learnable(model_name: &str) -> bool {
    matches!(model_name, "gat" | "txf")
}

fn out_dim(ds: &DatasetCfg, mo: &ModelCfg) -> usize {
    if ds.task == "link" {
        mo.hidden
    } else {
        ds.n_classes
    }
}

/// `(num_branches, padded_concat_dim)`; `fp == 0` ⇒ one full-width branch.
fn branch_layout(f_l: usize, g_l: usize, fp: usize) -> (usize, usize) {
    let concat = f_l + g_l;
    if fp == 0 {
        (1, concat)
    } else {
        let n_br = (concat + fp - 1) / fp;
        (n_br, n_br * fp)
    }
}

/// Mirror of `compile.model.make_plan`.
pub fn make_plan(ds: &DatasetCfg, mo: &ModelCfg) -> Vec<LayerPlan> {
    let mut plans = Vec::with_capacity(mo.layers);
    let mut f = ds.f_in_pad;
    for l in 0..mo.layers {
        let last = l == mo.layers - 1;
        let h = if last { out_dim(ds, mo) } else { mo.hidden };
        let heads = if last || !learnable(&mo.name) { 1 } else { mo.heads };
        let g_dim = if mo.name == "txf" { 2 * h } else { h };
        let (n_br, cf) = branch_layout(f, g_dim, mo.fp);
        plans.push(LayerPlan { f_in: f, h_out: h, g_dim, n_br, fp: cf / n_br, cf, heads });
        f = h;
    }
    plans
}

/// Ordered `(name, shape)` parameter list (`compile.model.param_specs`);
/// names are WITHOUT the `param.` prefix.
fn param_specs(mo: &ModelCfg, plans: &[LayerPlan]) -> Vec<(String, Vec<usize>)> {
    let mut specs = Vec::new();
    for (l, p) in plans.iter().enumerate() {
        let pre = format!("l{l}.");
        match mo.name.as_str() {
            "gcn" => {
                specs.push((format!("{pre}w"), vec![p.f_in, p.h_out]));
                specs.push((format!("{pre}bias"), vec![p.h_out]));
            }
            "sage" => {
                specs.push((format!("{pre}w_self"), vec![p.f_in, p.h_out]));
                specs.push((format!("{pre}w_nbr"), vec![p.f_in, p.h_out]));
                specs.push((format!("{pre}bias"), vec![p.h_out]));
            }
            "gat" => {
                let hh = p.h_out / p.heads;
                specs.push((format!("{pre}w"), vec![p.heads, p.f_in, hh]));
                specs.push((format!("{pre}a_src"), vec![p.heads, hh]));
                specs.push((format!("{pre}a_dst"), vec![p.heads, hh]));
                specs.push((format!("{pre}bias"), vec![p.h_out]));
            }
            "txf" => {
                let hh = p.h_out / p.heads;
                let dk = 32;
                specs.push((format!("{pre}w"), vec![p.heads, p.f_in, hh]));
                specs.push((format!("{pre}a_src"), vec![p.heads, hh]));
                specs.push((format!("{pre}a_dst"), vec![p.heads, hh]));
                specs.push((format!("{pre}bias"), vec![p.h_out]));
                specs.push((format!("{pre}wq"), vec![p.f_in, dk]));
                specs.push((format!("{pre}wk"), vec![p.f_in, dk]));
                specs.push((format!("{pre}wv"), vec![p.f_in, p.h_out]));
                specs.push((format!("{pre}w_lin"), vec![p.f_in, p.h_out]));
            }
            other => panic!("unknown model {other}"),
        }
    }
    specs
}

fn f32_spec(name: String, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name, shape, dtype: DType::F32 }
}

fn i32_spec(name: String, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name, shape, dtype: DType::I32 }
}

/// Per-layer VQ context inputs (`compile.model.ctx_specs`).
fn ctx_specs(mo: &ModelCfg, plans: &[LayerPlan], b: usize, k: usize, train: bool) -> Vec<TensorSpec> {
    let mut specs = Vec::new();
    for (l, p) in plans.iter().enumerate() {
        let pre = format!("l{l}.");
        if learnable(&mo.name) {
            specs.push(f32_spec(format!("{pre}mask_in"), vec![b, b]));
            specs.push(f32_spec(format!("{pre}m_out"), vec![b, k]));
            specs.push(f32_spec(format!("{pre}m_out_t"), vec![b, k]));
            if mo.name == "txf" {
                specs.push(f32_spec(format!("{pre}cnt_out"), vec![k]));
            }
        } else {
            specs.push(f32_spec(format!("{pre}c_in"), vec![b, b]));
            specs.push(f32_spec(format!("{pre}c_out"), vec![p.n_br, b, k]));
            specs.push(f32_spec(format!("{pre}ct_out"), vec![p.n_br, b, k]));
        }
        specs.push(f32_spec(format!("{pre}cw"), vec![p.n_br, k, p.fp]));
        if train {
            specs.push(f32_spec(format!("{pre}mean"), vec![p.n_br, p.fp]));
            specs.push(f32_spec(format!("{pre}var"), vec![p.n_br, p.fp]));
            specs.push(f32_spec(format!("{pre}cww"), vec![p.n_br, k, p.fp]));
        }
    }
    specs
}

/// Per-layer context of the forward-only serving path: the read path never
/// runs Eq. 7, so the transposed sketches (`ct_out` / `m_out_t`) and the
/// whitening stats drop out of the signature — the serving cache only has
/// to materialize forward sketches + raw codewords per micro-batch.
fn serve_ctx_specs(mo: &ModelCfg, plans: &[LayerPlan], b: usize, k: usize) -> Vec<TensorSpec> {
    let mut specs = Vec::new();
    for (l, p) in plans.iter().enumerate() {
        let pre = format!("l{l}.");
        if learnable(&mo.name) {
            specs.push(f32_spec(format!("{pre}mask_in"), vec![b, b]));
            specs.push(f32_spec(format!("{pre}m_out"), vec![b, k]));
            if mo.name == "txf" {
                specs.push(f32_spec(format!("{pre}cnt_out"), vec![k]));
            }
        } else {
            specs.push(f32_spec(format!("{pre}c_in"), vec![b, b]));
            specs.push(f32_spec(format!("{pre}c_out"), vec![p.n_br, b, k]));
        }
        specs.push(f32_spec(format!("{pre}cw"), vec![p.n_br, k, p.fp]));
    }
    specs
}

/// The `vq_serve` artifact: same plan as the vq pair, inputs reduced to
/// `xb` + forward sketches + codewords + params, outputs reduced to
/// `logits` — the micro-batched inference-serving contract.
fn vq_serve_spec(
    ds: &DatasetCfg,
    mo: &ModelCfg,
    b: usize,
    k: usize,
    suffix: &str,
) -> ArtifactSpec {
    let plans = make_plan(ds, mo);
    let pspecs = param_specs(mo, &plans);
    let c = out_dim(ds, mo);
    let name = format!("vq_serve_{}_{}{suffix}", ds.name, mo.name);
    let mut inputs = vec![f32_spec("xb".into(), vec![b, ds.f_in_pad])];
    inputs.extend(serve_ctx_specs(mo, &plans, b, k));
    inputs.extend(pspecs.iter().map(|(n, s)| f32_spec(format!("param.{n}"), s.clone())));
    ArtifactSpec {
        file: format!("{name}.hlo.txt"),
        name,
        kind: "vq_serve".to_string(),
        dataset: ds.name.clone(),
        model: mo.name.clone(),
        b,
        k,
        nn: 0,
        ne: 0,
        layers_override: 0,
        inputs,
        outputs: vec![f32_spec("logits".into(), vec![b, c])],
        plan: plans,
    }
}

fn task_specs(ds: &DatasetCfg, tc: &TrainCfg, rows: usize, c: usize) -> Vec<TensorSpec> {
    if ds.task == "link" {
        vec![
            i32_spec("psrc".into(), vec![tc.p_pairs]),
            i32_spec("pdst".into(), vec![tc.p_pairs]),
            f32_spec("py".into(), vec![tc.p_pairs]),
            f32_spec("pw".into(), vec![tc.p_pairs]),
        ]
    } else if ds.multilabel {
        vec![f32_spec("y".into(), vec![rows, c]), f32_spec("wloss".into(), vec![rows])]
    } else {
        vec![i32_spec("y".into(), vec![rows]), f32_spec("wloss".into(), vec![rows])]
    }
}

#[allow(clippy::too_many_arguments)]
fn vq_spec(
    train: bool,
    ds: &DatasetCfg,
    mo: &ModelCfg,
    tc: &TrainCfg,
    b: usize,
    k: usize,
    suffix: &str,
    layers_override: usize,
) -> ArtifactSpec {
    let plans = make_plan(ds, mo);
    let pspecs = param_specs(mo, &plans);
    let c = out_dim(ds, mo);
    let kind = if train { "vq_train" } else { "vq_infer" };
    let name = format!("{kind}_{}_{}{suffix}", ds.name, mo.name);

    let mut inputs = vec![f32_spec("xb".into(), vec![b, ds.f_in_pad])];
    if train {
        inputs.extend(task_specs(ds, tc, b, c));
    }
    inputs.extend(ctx_specs(mo, &plans, b, k, train));
    inputs.extend(pspecs.iter().map(|(n, s)| f32_spec(format!("param.{n}"), s.clone())));

    let mut outputs = Vec::new();
    if train {
        outputs.push(f32_spec("loss".into(), vec![]));
    }
    outputs.push(f32_spec("logits".into(), vec![b, c]));
    if train {
        for (l, p) in plans.iter().enumerate() {
            outputs.push(f32_spec(format!("l{l}.xfeat"), vec![b, p.f_in]));
            outputs.push(f32_spec(format!("l{l}.gvec"), vec![b, p.g_dim]));
            outputs.push(i32_spec(format!("l{l}.assign"), vec![p.n_br, b]));
        }
        outputs.extend(pspecs.iter().map(|(n, s)| f32_spec(format!("grad.{n}"), s.clone())));
    } else {
        for (l, p) in plans.iter().enumerate() {
            outputs.push(f32_spec(format!("l{l}.xfeat"), vec![b, p.f_in]));
        }
    }

    ArtifactSpec {
        file: format!("{name}.hlo.txt"),
        name,
        kind: kind.to_string(),
        dataset: ds.name.clone(),
        model: mo.name.clone(),
        b,
        k,
        nn: 0,
        ne: 0,
        layers_override,
        inputs,
        outputs,
        plan: plans,
    }
}

fn edge_spec(
    train: bool,
    ds: &DatasetCfg,
    mo: &ModelCfg,
    tc: &TrainCfg,
    nn: usize,
    ne: usize,
    suffix: &str,
) -> ArtifactSpec {
    let plans = make_plan(ds, mo);
    let pspecs = param_specs(mo, &plans);
    let c = out_dim(ds, mo);
    let kind = if train { "edge_train" } else { "edge_infer" };
    let name = format!("{kind}_{}_{}{suffix}", ds.name, mo.name);

    let mut inputs = vec![
        f32_spec("x".into(), vec![nn, ds.f_in_pad]),
        i32_spec("esrc".into(), vec![ne]),
        i32_spec("edst".into(), vec![ne]),
        f32_spec("ecoef".into(), vec![ne]),
    ];
    if train {
        inputs.extend(task_specs(ds, tc, nn, c));
    }
    inputs.extend(pspecs.iter().map(|(n, s)| f32_spec(format!("param.{n}"), s.clone())));

    let mut outputs = Vec::new();
    if train {
        outputs.push(f32_spec("loss".into(), vec![]));
    }
    outputs.push(f32_spec("logits".into(), vec![nn, c]));
    if train {
        outputs.extend(pspecs.iter().map(|(n, s)| f32_spec(format!("grad.{n}"), s.clone())));
    }

    ArtifactSpec {
        file: format!("{name}.hlo.txt"),
        name,
        kind: kind.to_string(),
        dataset: ds.name.clone(),
        model: mo.name.clone(),
        b: 0,
        k: 0,
        nn,
        ne,
        layers_override: 0,
        inputs,
        outputs,
        plan: vec![],
    }
}

/// Padded edge capacity for subgraph artifacts (`aot._sub_edges`).
fn sub_edges(ds: &DatasetCfg, nodes: usize) -> usize {
    let want = (nodes as f64 * (ds.avg_degree + 2.0) * 1.6) as usize;
    let bits = usize::BITS - want.saturating_sub(1).leading_zeros();
    let cap = 1usize << bits.max(10);
    cap.min(ds.m_max)
}

fn vq_assign_spec(ds: &DatasetCfg, gcn: &ModelCfg, b: usize, k: usize) -> ArtifactSpec {
    let p0 = &make_plan(ds, gcn)[0];
    let name = format!("vq_assign_{}", ds.name);
    ArtifactSpec {
        file: format!("{name}.hlo.txt"),
        name,
        kind: "vq_assign".to_string(),
        dataset: ds.name.clone(),
        model: "gcn".to_string(),
        b,
        k,
        nn: 0,
        ne: 0,
        layers_override: 0,
        inputs: vec![
            f32_spec("z".into(), vec![p0.n_br, b, p0.fp]),
            f32_spec("cww".into(), vec![p0.n_br, k, p0.fp]),
            f32_spec("mask".into(), vec![p0.n_br, p0.fp]),
        ],
        outputs: vec![i32_spec("assign".into(), vec![p0.n_br, b])],
        plan: vec![],
    }
}

/// Reconstruct the full manifest (datasets, models, train config and every
/// registry artifact) without touching the filesystem.
pub fn manifest(dir: &Path) -> Manifest {
    let tc = TrainCfg {
        b: 512,
        k: 128,
        lr: 3e-3,
        rms_alpha: 0.99,
        gamma: 0.99,
        beta: 0.99,
        p_pairs: 1024,
        weight_clip: 4.0,
    };

    let ds_list = vec![
        dataset("tiny_sim", 256, 4096, 16, 4, "node", false, false, 1, 6.0, 4),
        dataset("arxiv_sim", 8192, 163840, 64, 16, "node", false, false, 1, 7.0, 16),
        dataset("reddit_sim", 4096, 262144, 128, 16, "node", false, false, 1, 50.0, 16),
        dataset("ppi_sim", 4608, 131072, 56, 16, "node", true, true, 12, 14.0, 16),
        dataset("collab_sim", 8192, 163840, 64, 0, "link", false, false, 1, 8.0, 32),
        dataset("flickr_sim", 4096, 98304, 104, 7, "node", false, false, 1, 10.0, 7),
    ];
    let mo_list = vec![model("gcn", 16), model("sage", 16), model("gat", 0), model("txf", 0)];

    let mut datasets = std::collections::BTreeMap::new();
    for d in &ds_list {
        datasets.insert(d.name.clone(), d.clone());
    }
    let mut models = std::collections::BTreeMap::new();
    for m in &mo_list {
        models.insert(m.name.clone(), m.clone());
    }

    let mut artifacts = std::collections::BTreeMap::new();
    let mut add = |spec: ArtifactSpec| {
        artifacts.insert(spec.name.clone(), spec);
    };

    for ds in &ds_list {
        let tiny = ds.name == "tiny_sim";
        let b = if tiny { 64 } else { tc.b };
        let k = if tiny { 16 } else { tc.k };
        let mut model_names = vec!["gcn", "sage", "gat"];
        if ds.name == "arxiv_sim" || tiny {
            // txf: the paper's Table-8 backbone (arxiv) + the tiny config
            // the test/gradcheck suites train hermetically.
            model_names.push("txf");
        }
        for mn in model_names {
            let mo = &models[mn];
            add(vq_spec(true, ds, mo, &tc, b, k, "", 0));
            add(vq_spec(false, ds, mo, &tc, b, k, "", 0));
            add(vq_serve_spec(ds, mo, b, k, ""));
            if mn == "txf" {
                // Global attention has no edge-list form; the registry makes
                // this a typed lookup error (ManifestError::UnsupportedEdgeForm)
                // instead of a silent gap.
                continue;
            }
            add(edge_spec(true, ds, mo, &tc, ds.n, ds.m_max, "_full"));
            add(edge_spec(false, ds, mo, &tc, ds.n, ds.m_max, "_full"));
            if !tiny {
                add(edge_spec(true, ds, mo, &tc, 1024, sub_edges(ds, 1024), "_sub"));
            }
        }
        if !tiny {
            for mn in ["sage", "gat"] {
                let mo = &models[mn];
                add(edge_spec(true, ds, mo, &tc, ds.n.min(4096), ds.m_max.min(131072), "_ns"));
            }
        }
    }

    // App. G ablations on arxiv_sim + GCN (layers / codebook / batch), plus
    // the perf-pass fp variants — all mirror aot.py's suffix scheme.
    let arxiv = datasets["arxiv_sim"].clone();
    let gcn = models["gcn"].clone();
    for nl in [1usize, 2, 4, 5] {
        let mo = ModelCfg { layers: nl, ..gcn.clone() };
        add(vq_spec(true, &arxiv, &mo, &tc, tc.b, tc.k, &format!("_l{nl}"), nl));
        add(vq_spec(false, &arxiv, &mo, &tc, tc.b, tc.k, &format!("_l{nl}"), nl));
    }
    for kk in [32usize, 64, 256] {
        add(vq_spec(true, &arxiv, &gcn, &tc, tc.b, kk, &format!("_k{kk}"), 0));
        add(vq_spec(false, &arxiv, &gcn, &tc, tc.b, kk, &format!("_k{kk}"), 0));
    }
    for bb in [128usize, 256, 1024] {
        add(vq_spec(true, &arxiv, &gcn, &tc, bb, tc.k, &format!("_b{bb}"), 0));
        add(vq_spec(false, &arxiv, &gcn, &tc, bb, tc.k, &format!("_b{bb}"), 0));
    }
    let gcn_fp32 = ModelCfg { fp: 32, ..gcn.clone() };
    add(vq_spec(true, &arxiv, &gcn_fp32, &tc, tc.b, tc.k, "_fp32", 0));
    add(vq_spec(false, &arxiv, &gcn_fp32, &tc, tc.b, tc.k, "_fp32", 0));
    add(vq_spec(true, &arxiv, &gcn_fp32, &tc, tc.b, 64, "_fp32k64", 0));
    add(vq_spec(false, &arxiv, &gcn_fp32, &tc, tc.b, 64, "_fp32k64", 0));

    // Standalone assignment kernel artifacts (inductive inference).
    add(vq_assign_spec(&datasets["ppi_sim"], &gcn, tc.b, tc.k));
    add(vq_assign_spec(&datasets["tiny_sim"], &gcn, 64, 16));

    Manifest { dir: dir.to_path_buf(), train: tc, datasets, models, artifacts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_the_trainer_name_scheme() {
        let m = manifest(Path::new("artifacts"));
        for name in [
            "vq_train_tiny_sim_gcn",
            "vq_infer_tiny_sim_gcn",
            "vq_train_tiny_sim_sage",
            "vq_train_tiny_sim_gat",
            "vq_train_tiny_sim_txf",
            "vq_infer_tiny_sim_txf",
            "vq_train_arxiv_sim_txf",
            "edge_train_tiny_sim_gcn_full",
            "edge_infer_tiny_sim_gcn_full",
            "edge_train_arxiv_sim_gcn_sub",
            "edge_train_arxiv_sim_sage_ns",
            "vq_train_arxiv_sim_gcn_l5",
            "vq_train_arxiv_sim_gcn_k64",
            "vq_train_arxiv_sim_gcn_b256",
            "vq_train_arxiv_sim_gcn_fp32",
            "vq_train_arxiv_sim_gcn_fp32k64",
            "vq_assign_tiny_sim",
        ] {
            assert!(m.artifacts.contains_key(name), "missing {name}");
        }
    }

    #[test]
    fn tiny_gcn_train_spec_shapes() {
        let m = manifest(Path::new("artifacts"));
        let a = m.artifact("vq_train_tiny_sim_gcn").unwrap();
        assert_eq!((a.b, a.k), (64, 16));
        assert_eq!(a.inputs[0].name, "xb");
        assert_eq!(a.inputs[0].shape, vec![64, 16]);
        assert_eq!(a.plan.len(), 3);
        // layer 0: f=16, h=64 ⇒ concat 80 ⇒ 5 branches of fp=16
        let p0 = &a.plan[0];
        assert_eq!((p0.f_in, p0.h_out, p0.n_br, p0.fp, p0.cf), (16, 64, 5, 16, 80));
        // last layer: h = n_classes = 4
        assert_eq!(a.plan[2].h_out, 4);
        // params and grads pair up in order
        let params: Vec<&TensorSpec> =
            a.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        let grads: Vec<&TensorSpec> =
            a.outputs.iter().filter(|t| t.name.starts_with("grad.")).collect();
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), 6); // 3 layers × (w, bias)
        for (p, g) in params.iter().zip(&grads) {
            assert_eq!(p.shape, g.shape);
            assert_eq!(g.name, format!("grad.{}", &p.name["param.".len()..]));
        }
        // outputs start with loss, logits, then per-layer triples
        assert_eq!(a.outputs[0].name, "loss");
        assert_eq!(a.outputs[1].name, "logits");
        assert_eq!(a.outputs[1].shape, vec![64, 4]);
        assert_eq!(a.outputs[2].name, "l0.xfeat");
        assert_eq!(a.outputs[4].name, "l0.assign");
        assert_eq!(a.outputs[4].dtype, DType::I32);
    }

    #[test]
    fn tiny_txf_train_spec_shapes() {
        let m = manifest(Path::new("artifacts"));
        let a = m.artifact("vq_train_tiny_sim_txf").unwrap();
        assert_eq!((a.b, a.k), (64, 16));
        // l0: f=16, h=64, 2 heads, global split ⇒ g_dim = 2h = 128, one
        // branch over the whole 144-wide concat space
        let p0 = &a.plan[0];
        assert_eq!(
            (p0.f_in, p0.h_out, p0.g_dim, p0.n_br, p0.fp, p0.heads),
            (16, 64, 128, 1, 144, 2)
        );
        // last layer: single head, g_dim = 2·n_classes
        let p2 = &a.plan[2];
        assert_eq!((p2.h_out, p2.g_dim, p2.heads), (4, 8, 1));
        // learnable ctx inputs incl. the global out-of-batch histogram
        for name in ["l0.mask_in", "l0.m_out", "l0.m_out_t", "l0.cnt_out"] {
            assert!(a.inputs.iter().any(|t| t.name == name), "missing {name}");
        }
        // per-layer params: w/a_src/a_dst/bias + wq/wk/wv/w_lin
        let n_params = a.inputs.iter().filter(|t| t.name.starts_with("param.")).count();
        assert_eq!(n_params, 3 * 8);
        let wq = a.inputs.iter().find(|t| t.name == "param.l0.wq").unwrap();
        assert_eq!(wq.shape, vec![16, 32]);
        let w0 = a.inputs.iter().find(|t| t.name == "param.l0.w").unwrap();
        assert_eq!(w0.shape, vec![2, 16, 32]);
        // grads pair up with params in order
        let params: Vec<&TensorSpec> =
            a.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
        let grads: Vec<&TensorSpec> =
            a.outputs.iter().filter(|t| t.name.starts_with("grad.")).collect();
        assert_eq!(params.len(), grads.len());
        for (p, g) in params.iter().zip(&grads) {
            assert_eq!(p.shape, g.shape);
            assert_eq!(g.name, format!("grad.{}", &p.name["param.".len()..]));
        }
    }

    #[test]
    fn serve_specs_are_forward_only() {
        let m = manifest(Path::new("artifacts"));
        for mn in ["gcn", "sage", "gat", "txf"] {
            let a = m.artifact(&format!("vq_serve_tiny_sim_{mn}")).unwrap();
            assert_eq!(a.kind, "vq_serve");
            assert_eq!((a.b, a.k), (64, 16));
            // logits is the ONLY output — no residuals, no grads
            assert_eq!(a.outputs.len(), 1);
            assert_eq!(a.outputs[0].name, "logits");
            // no backward-only inputs: transposed sketches, whitening
            // stats, labels and loss weights all drop out of the read path
            for t in &a.inputs {
                for banned in [".ct_out", ".m_out_t", ".mean", ".var", ".cww"] {
                    assert!(!t.name.ends_with(banned), "{}: {}", a.name, t.name);
                }
                assert!(t.name != "y" && t.name != "wloss", "{}", t.name);
            }
            // plan matches the train/infer pair (same frozen weights fit)
            let infer = m.artifact(&format!("vq_infer_tiny_sim_{mn}")).unwrap();
            assert_eq!(a.plan.len(), infer.plan.len());
            let pa: Vec<_> =
                a.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
            let pi: Vec<_> =
                infer.inputs.iter().filter(|t| t.name.starts_with("param.")).collect();
            assert_eq!(pa.len(), pi.len());
            for (x, y) in pa.iter().zip(&pi) {
                assert_eq!((&x.name, &x.shape), (&y.name, &y.shape));
            }
        }
        // serve artifacts exist for every dataset with a vq pair
        assert!(m.artifacts.contains_key("vq_serve_arxiv_sim_txf"));
        assert!(m.artifacts.contains_key("vq_serve_collab_sim_sage"));
    }

    #[test]
    fn edge_full_spec_matches_dataset_capacity() {
        let m = manifest(Path::new("artifacts"));
        let a = m.artifact("edge_train_tiny_sim_sage_full").unwrap();
        assert_eq!((a.nn, a.ne), (256, 4096));
        assert_eq!(a.inputs[0].shape, vec![256, 16]);
        assert_eq!(a.inputs[1].name, "esrc");
        // sage: 3 layers × (w_self, w_nbr, bias)
        let n_params = a.inputs.iter().filter(|t| t.name.starts_with("param.")).count();
        assert_eq!(n_params, 9);
    }

    #[test]
    fn link_dataset_uses_pair_inputs_and_embedding_logits() {
        let m = manifest(Path::new("artifacts"));
        let a = m.artifact("vq_train_collab_sim_sage").unwrap();
        assert!(a.inputs.iter().any(|t| t.name == "psrc"));
        assert!(!a.inputs.iter().any(|t| t.name == "y"));
        let lo = a.outputs.iter().find(|t| t.name == "logits").unwrap();
        assert_eq!(lo.shape, vec![512, 64]); // embeddings, not classes
    }

    #[test]
    fn matches_checked_in_manifest_when_present() {
        // Drift guard: if an AOT manifest.json exists in the tree, the
        // builtin registry must agree on shapes for every shared artifact.
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            return;
        }
        let real = Manifest::load(dir).unwrap();
        let ours = manifest(dir);
        for (name, a) in &real.artifacts {
            let b = ours.artifact(name).unwrap_or_else(|_| panic!("builtin missing {name}"));
            assert_eq!(a.inputs.len(), b.inputs.len(), "{name}: input count");
            for (x, y) in a.inputs.iter().zip(&b.inputs) {
                assert_eq!((&x.name, &x.shape), (&y.name, &y.shape), "{name}");
            }
            for (x, y) in a.outputs.iter().zip(&b.outputs) {
                assert_eq!((&x.name, &x.shape), (&y.name, &y.shape), "{name}");
            }
        }
    }
}
