//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them from
//! the rust hot path (the only place python output is consumed).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile` →
//! `execute`, with outputs arriving as a single tuple literal
//! (`return_tuple=True` at lowering time).

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::tensor::{DType, Tensor};
use manifest::{ArtifactSpec, Manifest};

/// Process-wide PJRT CPU client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<Artifact>>,
    /// Cumulative bytes shipped to/from the device (memory-meter input).
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub executions: u64,
}

pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new(), bytes_in: 0, bytes_out: 0, executions: 0 })
    }

    /// Load + compile an artifact (cached per name).
    pub fn load(&mut self, man: &Manifest, name: &str) -> Result<std::rc::Rc<Artifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let spec = man.artifact(name).map_err(anyhow::Error::msg)?.clone();
        let path = man.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", spec.name))?;
        let a = std::rc::Rc::new(Artifact { spec, exe });
        self.cache.insert(name.to_string(), a.clone());
        Ok(a)
    }

    /// Execute with positional inputs matching the manifest signature.
    pub fn execute(&mut self, art: &Artifact, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = &art.spec;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{}: got {} inputs, artifact expects {}",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            if t.shape != s.shape || t.dtype != s.dtype {
                bail!(
                    "{}: input '{}' shape/dtype mismatch: got {:?}/{:?}, want {:?}/{:?}",
                    spec.name, s.name, t.shape, t.dtype, s.shape, s.dtype
                );
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = match t.dtype {
                DType::F32 => xla::Literal::vec1(&t.f).reshape(&dims)?,
                DType::I32 => xla::Literal::vec1(&t.i).reshape(&dims)?,
            };
            self.bytes_in += t.bytes() as u64;
            lits.push(lit);
        }
        let result = art.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest declares {}",
                spec.name,
                outs.len(),
                spec.outputs.len()
            );
        }
        let mut tensors = Vec::with_capacity(outs.len());
        for (lit, s) in outs.iter().zip(&spec.outputs) {
            let t = match s.dtype {
                DType::F32 => Tensor::from_f32(&s.shape, lit.to_vec::<f32>()?),
                DType::I32 => Tensor::from_i32(&s.shape, lit.to_vec::<i32>()?),
            };
            self.bytes_out += t.bytes() as u64;
            tensors.push(t);
        }
        self.executions += 1;
        Ok(tensors)
    }
}

impl ArtifactSpec {
    /// Static byte sizes (the memory-meter primitive for Table 3).
    pub fn input_bytes(&self) -> u64 {
        self.inputs.iter().map(|t| 4 * t.numel() as u64).sum()
    }

    pub fn output_bytes(&self) -> u64 {
        self.outputs.iter().map(|t| 4 * t.numel() as u64).sum()
    }

    pub fn param_bytes(&self) -> u64 {
        self.inputs
            .iter()
            .filter(|t| t.name.starts_with("param."))
            .map(|t| 4 * t.numel() as u64)
            .sum()
    }
}

/// Load a golden bundle produced by python/compile/goldens.py.
pub struct Golden {
    pub inputs: Vec<(String, Tensor)>,
    pub outputs: Vec<(String, Tensor)>,
}

impl Golden {
    pub fn load(dir: &Path) -> Result<Golden> {
        use crate::util::json::Json;
        let idx = Json::parse(
            &std::fs::read_to_string(dir.join("index.json")).context("golden index")?,
        )
        .map_err(anyhow::Error::msg)?;
        let load = |section: &str| -> Result<Vec<(String, Tensor)>> {
            let mut out = Vec::new();
            for e in idx.get(section).and_then(Json::as_arr).unwrap_or(&[]) {
                let name = e.get("name").and_then(Json::as_str).unwrap().to_string();
                let file = e.get("file").and_then(Json::as_str).unwrap();
                let shape: Vec<usize> = e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap()
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dt = DType::from_str(
                    e.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
                )
                .unwrap();
                out.push((name, Tensor::from_bin(&dir.join(file), &shape, dt)?));
            }
            Ok(out)
        };
        Ok(Golden { inputs: load("inputs")?, outputs: load("outputs")? })
    }
}
