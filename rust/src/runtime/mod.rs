//! Execution runtime behind a [`Backend`] trait with two implementations:
//!
//! - **native** (default): pure-Rust CPU executor of the manifest's
//!   artifact contract — zero Python/JAX dependency, runs anywhere
//!   (`runtime::native`: artifacts are plan-compiled at load time and run
//!   against a reusable step arena; specs reconstructed by
//!   `runtime::builtin`);
//! - **pjrt** (`--features pjrt`): the original PJRT executor for
//!   AOT-compiled HLO text artifacts (`runtime::pjrt`).
//!
//! Backend selection: `Runtime::new()` honors `VQ_GNN_BACKEND=native|pjrt`
//! (the CLI's `--backend` flag sets it), defaulting to native.  The
//! `Runtime` owns the artifact cache and the bytes/executions accounting
//! (the memory-meter input for Table 3), so trainers are backend-agnostic.

pub mod builtin;
pub mod manifest;
pub mod native;
pub mod ops;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::util::tensor::{DType, Tensor};
use manifest::{ArtifactSpec, Manifest};

pub use native::arena::ExecSession;

/// Positional input view for execution entry points: either a plain dense
/// slice (the trainer paths), or a shared constant base with a small
/// per-session dynamic overlay — the serving pool's Arc-backed template,
/// where the frozen weights and codebooks live ONCE in the shared core and
/// each worker carries only its batch-dependent slots (xb + sketches).
///
/// The executor reads inputs purely positionally (`inputs[i]`), so the
/// overlay resolves in `Index` and the kernels cannot tell the views
/// apart; answers are bit-identical by construction.
#[derive(Clone, Copy)]
pub enum InputSlots<'a> {
    Dense(&'a [Tensor]),
    /// `idx` holds the ASCENDING spec positions of the dynamic slots;
    /// position `idx[p]` resolves to `dynamic[p]`, everything else to
    /// `base` (whose tensors at dynamic positions are never read).
    Overlay { base: &'a [Tensor], idx: &'a [usize], dynamic: &'a [Tensor] },
}

impl InputSlots<'_> {
    pub fn len(&self) -> usize {
        match self {
            InputSlots::Dense(s) => s.len(),
            InputSlots::Overlay { base, .. } => base.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::ops::Index<usize> for InputSlots<'_> {
    type Output = Tensor;

    fn index(&self, i: usize) -> &Tensor {
        match self {
            InputSlots::Dense(s) => &s[i],
            InputSlots::Overlay { base, idx, dynamic } => match idx.binary_search(&i) {
                Ok(p) => &dynamic[p],
                Err(_) => &base[i],
            },
        }
    }
}

/// A compiled artifact, ready to execute.
///
/// `Send + Sync` is part of the contract: the compiled program is read-only
/// after `compile()`, and all per-step mutable state lives either in the
/// executable's own internal session (behind a lock, for the single-caller
/// `run`/`run_into` paths) or in a caller-owned [`ExecSession`] — so
/// `&dyn Executable` can be driven from multiple `util::par` workers at
/// once through [`Executable::run_session`].  (The in-tree `xla` stub
/// satisfies the bound trivially; a real xla-rs build must wrap its client
/// handles accordingly.)
pub trait Executable: Send + Sync {
    fn run(&self, spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute into caller-held output tensors.  Stateful executors (the
    /// plan-compiled native backend) overwrite the tensors in place so a
    /// session reusing one `outputs` vector allocates nothing per step; the
    /// default falls back to [`Executable::run`] and replaces the vector.
    fn run_into(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Tensor],
        outputs: &mut Vec<Tensor>,
    ) -> Result<()> {
        *outputs = self.run(spec, inputs)?;
        Ok(())
    }

    /// Detach a fresh execution session (the per-caller mutable half of the
    /// compiled program).  Backends with no host-side step state return a
    /// stateless session.
    fn new_session(&self) -> ExecSession {
        ExecSession::stateless()
    }

    /// Execute against a detached session — the `Sync` entry point: takes
    /// `&self`, touches only the caller's session, so N workers holding N
    /// sessions can run the same executable concurrently.  The default
    /// (stateless backends) ignores the session and falls back to
    /// [`Executable::run_into`].
    fn run_session(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Tensor],
        outputs: &mut Vec<Tensor>,
        _sess: &mut ExecSession,
    ) -> Result<()> {
        self.run_into(spec, inputs, outputs)
    }

    /// [`Executable::run_session`] over an [`InputSlots`] view — the
    /// Arc-shared-template serving path.  The default handles dense views
    /// by delegating and refuses overlays: only backends that read inputs
    /// through the view (native) can execute one without materializing it.
    fn run_slots(
        &self,
        spec: &ArtifactSpec,
        inputs: InputSlots<'_>,
        outputs: &mut Vec<Tensor>,
        sess: &mut ExecSession,
    ) -> Result<()> {
        match inputs {
            InputSlots::Dense(s) => self.run_session(spec, s, outputs, sess),
            InputSlots::Overlay { .. } => {
                bail!("{}: this backend cannot execute overlay input views", spec.name)
            }
        }
    }
}

/// An execution engine that can compile manifest artifacts.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Whether artifacts of this model family can execute on this backend.
    fn supports_model(&self, _model: &str) -> bool {
        true
    }

    fn compile(&mut self, man: &Manifest, spec: &ArtifactSpec) -> Result<Box<dyn Executable>>;
}

pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: Box<dyn Executable>,
}

impl Artifact {
    /// Detach a fresh execution session for this artifact.
    pub fn new_session(&self) -> ExecSession {
        self.exe.new_session()
    }

    /// Validated session execution WITHOUT runtime accounting — the
    /// fan-out workers' entry point (`&Artifact` is `Sync`; a worker holds
    /// its own session).  Callers that care about the bytes/executions
    /// meters aggregate after the join via [`Runtime::record_external`],
    /// or go through [`Runtime::run_session`] instead.
    pub fn run_session(
        &self,
        inputs: &[Tensor],
        outputs: &mut Vec<Tensor>,
        sess: &mut ExecSession,
    ) -> Result<()> {
        check_inputs(&self.spec, inputs)?;
        self.exe.run_session(&self.spec, inputs, outputs, sess)?;
        check_output_count(&self.spec, outputs)
    }

    /// [`Artifact::run_session`] over an [`InputSlots`] view — validated
    /// and unaccounted, like `run_session`; pool workers aggregate via
    /// [`Runtime::record_external`] after the join.
    pub fn run_slots(
        &self,
        inputs: InputSlots<'_>,
        outputs: &mut Vec<Tensor>,
        sess: &mut ExecSession,
    ) -> Result<()> {
        check_input_view(&self.spec, inputs)?;
        self.exe.run_slots(&self.spec, inputs, outputs, sess)?;
        check_output_count(&self.spec, outputs)
    }
}

/// Positional input validation shared by every execution entry point.
fn check_inputs(spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<()> {
    check_input_view(spec, InputSlots::Dense(inputs))
}

fn check_input_view(spec: &ArtifactSpec, inputs: InputSlots<'_>) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{}: got {} inputs, artifact expects {}",
            spec.name,
            inputs.len(),
            spec.inputs.len()
        );
    }
    for (i, s) in spec.inputs.iter().enumerate() {
        let t = &inputs[i];
        if t.shape != s.shape || t.dtype != s.dtype {
            bail!(
                "{}: input '{}' shape/dtype mismatch: got {:?}/{:?}, want {:?}/{:?}",
                spec.name,
                s.name,
                t.shape,
                t.dtype,
                s.shape,
                s.dtype
            );
        }
    }
    Ok(())
}

fn check_output_count(spec: &ArtifactSpec, outputs: &[Tensor]) -> Result<()> {
    if outputs.len() != spec.outputs.len() {
        bail!(
            "{}: got {} outputs, manifest declares {}",
            spec.name,
            outputs.len(),
            spec.outputs.len()
        );
    }
    Ok(())
}

/// Backend + executable cache + transfer accounting.
///
/// The bytes/executions meters are atomics so the `&self` execution entry
/// point ([`Runtime::run_session`]) can account from any thread; the
/// single-threaded trainer paths observe exactly the same totals as the
/// old plain-`u64` fields did.
pub struct Runtime {
    backend: Box<dyn Backend>,
    cache: HashMap<String, Rc<Artifact>>,
    /// Cumulative bytes shipped to/from the backend (memory-meter input).
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
    executions: AtomicU64,
}

impl Runtime {
    /// Backend chosen by `VQ_GNN_BACKEND` (default: native).
    pub fn new() -> Result<Runtime> {
        match std::env::var("VQ_GNN_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("native") => Ok(Runtime::native()),
            Ok("pjrt") => Runtime::pjrt(),
            Ok(other) => bail!("unknown VQ_GNN_BACKEND '{other}' (native|pjrt)"),
        }
    }

    pub fn native() -> Runtime {
        Runtime::with_backend(Box::new(native::NativeBackend))
    }

    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(pjrt::PjrtBackend::new()?)))
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn pjrt() -> Result<Runtime> {
        bail!("this build has no PJRT support — rebuild with `--features pjrt`")
    }

    pub fn with_backend(backend: Box<dyn Backend>) -> Runtime {
        Runtime {
            backend,
            cache: HashMap::new(),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            executions: AtomicU64::new(0),
        }
    }

    pub fn bytes_in(&self) -> u64 {
        self.bytes_in.load(Ordering::Relaxed)
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out.load(Ordering::Relaxed)
    }

    pub fn executions(&self) -> u64 {
        self.executions.load(Ordering::Relaxed)
    }

    /// Fold in executions performed off-runtime (workers driving
    /// [`Artifact::run_session`] directly aggregate their accounting here
    /// after the join).
    pub fn record_external(&self, execs: u64, bytes_in: u64, bytes_out: u64) {
        self.executions.fetch_add(execs, Ordering::Relaxed);
        self.bytes_in.fetch_add(bytes_in, Ordering::Relaxed);
        self.bytes_out.fetch_add(bytes_out, Ordering::Relaxed);
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn supports_model(&self, model: &str) -> bool {
        self.backend.supports_model(model)
    }

    /// Load + compile an artifact (cached per name).
    pub fn load(&mut self, man: &Manifest, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let spec = man.artifact(name).map_err(anyhow::Error::msg)?.clone();
        let exe = self
            .backend
            .compile(man, &spec)
            .with_context(|| format!("compile {} on {} backend", spec.name, self.backend.name()))?;
        let a = Rc::new(Artifact { spec, exe });
        self.cache.insert(name.to_string(), a.clone());
        Ok(a)
    }

    /// Execute with positional inputs matching the manifest signature.
    pub fn execute(&mut self, art: &Artifact, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut outputs = Vec::new();
        self.execute_into(art, inputs, &mut outputs)?;
        Ok(outputs)
    }

    /// Execute into a caller-held output vector (a trainer/serving
    /// session's persistent buffers): on the native backend the tensors are
    /// rewritten in place, so the steady-state step allocates nothing here.
    pub fn execute_into(
        &mut self,
        art: &Artifact,
        inputs: &[Tensor],
        outputs: &mut Vec<Tensor>,
    ) -> Result<()> {
        let spec = &art.spec;
        check_inputs(spec, inputs)?;
        art.exe.run_into(spec, inputs, outputs)?;
        check_output_count(spec, outputs)?;
        self.account(inputs, outputs);
        Ok(())
    }

    /// Execute through a detached [`ExecSession`] — the `Sync` entry point:
    /// `&self`, per-caller session, atomic accounting.  Single-threaded
    /// callers (a serving model's own micro-batch) use this directly;
    /// parallel fan-outs drive [`Artifact::run_session`] per worker and
    /// aggregate accounting via [`Runtime::record_external`].
    pub fn run_session(
        &self,
        art: &Artifact,
        inputs: &[Tensor],
        outputs: &mut Vec<Tensor>,
        sess: &mut ExecSession,
    ) -> Result<()> {
        let spec = &art.spec;
        check_inputs(spec, inputs)?;
        art.exe.run_session(spec, inputs, outputs, sess)?;
        check_output_count(spec, outputs)?;
        self.account(inputs, outputs);
        Ok(())
    }

    fn account(&self, inputs: &[Tensor], outputs: &[Tensor]) {
        let bin: u64 = inputs.iter().map(|t| t.bytes() as u64).sum();
        let bout: u64 = outputs.iter().map(|t| t.bytes() as u64).sum();
        self.record_external(1, bin, bout);
    }
}

impl ArtifactSpec {
    /// Static byte sizes (the memory-meter primitive for Table 3).
    pub fn input_bytes(&self) -> u64 {
        self.inputs.iter().map(|t| 4 * t.numel() as u64).sum()
    }

    pub fn output_bytes(&self) -> u64 {
        self.outputs.iter().map(|t| 4 * t.numel() as u64).sum()
    }

    pub fn param_bytes(&self) -> u64 {
        self.inputs
            .iter()
            .filter(|t| t.name.starts_with("param."))
            .map(|t| 4 * t.numel() as u64)
            .sum()
    }
}

/// Load a golden bundle produced by python/compile/goldens.py.
pub struct Golden {
    pub inputs: Vec<(String, Tensor)>,
    pub outputs: Vec<(String, Tensor)>,
}

impl Golden {
    pub fn load(dir: &Path) -> Result<Golden> {
        use crate::util::json::Json;
        let idx = Json::parse(
            &std::fs::read_to_string(dir.join("index.json")).context("golden index")?,
        )
        .map_err(anyhow::Error::msg)?;
        let load = |section: &str| -> Result<Vec<(String, Tensor)>> {
            let mut out = Vec::new();
            for e in idx.get(section).and_then(Json::as_arr).unwrap_or(&[]) {
                let name = e.get("name").and_then(Json::as_str).unwrap().to_string();
                let file = e.get("file").and_then(Json::as_str).unwrap();
                let shape: Vec<usize> = e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap()
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dt = DType::from_str(
                    e.get("dtype").and_then(Json::as_str).unwrap_or("f32"),
                )
                .unwrap();
                out.push((name, Tensor::from_bin(&dir.join(file), &shape, dt)?));
            }
            Ok(out)
        };
        Ok(Golden { inputs: load("inputs")?, outputs: load("outputs")? })
    }
}
