//! artifacts/manifest.json — the single source of truth for shapes, dataset
//! generator parameters and artifact input/output signatures (emitted by
//! python/compile/aot.py; parsed here so the two sides can never drift).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::tensor::DType;

#[derive(Debug, Clone)]
pub struct DatasetCfg {
    pub name: String,
    pub n: usize,
    pub m_max: usize,
    pub f_in: usize,
    pub f_in_pad: usize,
    pub n_classes: usize,
    pub task: String,
    pub multilabel: bool,
    pub inductive: bool,
    pub n_graphs: usize,
    pub avg_degree: f64,
    pub communities: usize,
    pub feature_noise: f64,
    pub intra_p_scale: f64,
}

#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub fp: usize,
}

impl ModelCfg {
    /// Global-attention backbones attend over every node pair (𝔠 =
    /// all-ones, paper App. Table 5), so no edge-list artifact form can
    /// exist for them — only the VQ method scales them.
    pub fn global_attention(&self) -> bool {
        self.name == "txf"
    }
}

#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub b: usize,
    pub k: usize,
    pub lr: f64,
    pub rms_alpha: f64,
    pub gamma: f64,
    pub beta: f64,
    pub p_pairs: usize,
    pub weight_clip: f64,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Per-layer VQ shape plan (mirrors python compile.model.LayerPlan).
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub f_in: usize,
    pub h_out: usize,
    pub g_dim: usize,
    pub n_br: usize,
    pub fp: usize,
    pub cf: usize, // padded concat dim F
    pub heads: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub dataset: String,
    pub model: String,
    pub b: usize,
    pub k: usize,
    pub nn: usize,
    pub ne: usize,
    pub layers_override: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub plan: Vec<LayerPlan>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub train: TrainCfg,
    pub datasets: BTreeMap<String, DatasetCfg>,
    pub models: BTreeMap<String, ModelCfg>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

/// Typed registry lookup error.  `UnsupportedEdgeForm` makes the Graph
/// Transformer's edge-list gap explicit: global attention attends over
/// every node pair, so no edge-list artifact can exist — `EdgeTrainer`
/// fails loudly with the reason instead of a generic missing-name message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ManifestError {
    /// No artifact registered under this name.
    NotFound(String),
    /// The model family fundamentally has no edge-list artifact form.
    UnsupportedEdgeForm { model: String, artifact: String },
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::NotFound(name) => write!(f, "artifact '{name}' not in manifest"),
            ManifestError::UnsupportedEdgeForm { model, artifact } => write!(
                f,
                "UnsupportedEdgeForm: artifact '{artifact}' cannot exist — the '{model}' \
                 backbone's global attention has no edge-list form (every node pair \
                 attends); use its vq_train/vq_infer artifacts instead"
            ),
        }
    }
}

impl std::error::Error for ManifestError {}

fn us(j: &Json, k: &str) -> usize {
    j.get(k).and_then(Json::as_usize).unwrap_or(0)
}

fn fl(j: &Json, k: &str) -> f64 {
    j.get(k).and_then(Json::as_f64).unwrap_or(0.0)
}

fn st(j: &Json, k: &str) -> String {
    j.get(k).and_then(Json::as_str).unwrap_or("").to_string()
}

fn bo(j: &Json, k: &str) -> bool {
    j.get(k).and_then(Json::as_bool).unwrap_or(false)
}

fn tensor_specs(j: &Json) -> Vec<TensorSpec> {
    j.as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|t| TensorSpec {
            name: st(t, "name"),
            shape: t
                .get("shape")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            dtype: DType::from_str(&st(t, "dtype")).unwrap_or(DType::F32),
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text)?;

        let tj = j.get("train").ok_or("missing train")?;
        let train = TrainCfg {
            b: us(tj, "b"),
            k: us(tj, "k"),
            lr: fl(tj, "lr"),
            rms_alpha: fl(tj, "rms_alpha"),
            gamma: fl(tj, "gamma"),
            beta: fl(tj, "beta"),
            p_pairs: us(tj, "p_pairs"),
            weight_clip: fl(tj, "weight_clip"),
        };

        let mut datasets = BTreeMap::new();
        for (name, d) in j.get("datasets").and_then(Json::as_obj).ok_or("datasets")? {
            datasets.insert(
                name.clone(),
                DatasetCfg {
                    name: name.clone(),
                    n: us(d, "n"),
                    m_max: us(d, "m_max"),
                    f_in: us(d, "f_in"),
                    f_in_pad: (us(d, "f_in") + 7) / 8 * 8,
                    n_classes: us(d, "n_classes"),
                    task: st(d, "task"),
                    multilabel: bo(d, "multilabel"),
                    inductive: bo(d, "inductive"),
                    n_graphs: us(d, "n_graphs").max(1),
                    avg_degree: fl(d, "avg_degree"),
                    communities: us(d, "communities"),
                    feature_noise: fl(d, "feature_noise"),
                    intra_p_scale: fl(d, "intra_p_scale"),
                },
            );
        }

        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).ok_or("models")? {
            models.insert(
                name.clone(),
                ModelCfg {
                    name: name.clone(),
                    hidden: us(m, "hidden"),
                    layers: us(m, "layers"),
                    heads: us(m, "heads"),
                    fp: us(m, "fp"),
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts").and_then(Json::as_arr).ok_or("artifacts")? {
            let plan = a
                .get("plan")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|p| LayerPlan {
                    f_in: us(p, "f_in"),
                    h_out: us(p, "h_out"),
                    g_dim: us(p, "g_dim"),
                    n_br: us(p, "n_br"),
                    fp: us(p, "fp"),
                    cf: us(p, "F"),
                    heads: us(p, "heads"),
                })
                .collect();
            let spec = ArtifactSpec {
                name: st(a, "name"),
                file: st(a, "file"),
                kind: st(a, "kind"),
                dataset: st(a, "dataset"),
                model: st(a, "model"),
                b: us(a, "b"),
                k: us(a, "k"),
                nn: us(a, "nn"),
                ne: us(a, "ne"),
                layers_override: us(a, "layers"),
                inputs: tensor_specs(a.get("inputs").unwrap_or(&Json::Null)),
                outputs: tensor_specs(a.get("outputs").unwrap_or(&Json::Null)),
                plan,
            };
            artifacts.insert(spec.name.clone(), spec);
        }

        Ok(Manifest { dir: dir.to_path_buf(), train, datasets, models, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec, ManifestError> {
        if let Some(a) = self.artifacts.get(name) {
            return Ok(a);
        }
        // Edge-artifact lookups for global-attention models are a structural
        // gap, not a typo (aot.py's registry skips them for the same reason).
        if name.starts_with("edge_") {
            for m in self.models.values().filter(|m| m.global_attention()) {
                if name.contains(&format!("_{}", m.name)) {
                    return Err(ManifestError::UnsupportedEdgeForm {
                        model: m.name.clone(),
                        artifact: name.to_string(),
                    });
                }
            }
        }
        Err(ManifestError::NotFound(name.to_string()))
    }

    pub fn default_dir() -> PathBuf {
        std::env::var("VQ_GNN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load `manifest.json` when present (AOT/PJRT checkouts); otherwise
    /// fall back to the built-in registry (`runtime::builtin`) — the native
    /// backend needs no files at all.  A manifest that exists but fails to
    /// parse is a hard error: silently substituting builtin shapes for a
    /// user's artifacts would misconfigure every downstream run.
    pub fn load_or_builtin(dir: &Path) -> Manifest {
        if !dir.join("manifest.json").exists() {
            return crate::runtime::builtin::manifest(dir);
        }
        match Manifest::load(dir) {
            Ok(m) => m,
            Err(e) => panic!("{}/manifest.json is present but unusable: {e}", dir.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn txf_edge_lookup_is_a_typed_unsupported_error() {
        let m = crate::runtime::builtin::manifest(Path::new("artifacts"));
        let err = m.artifact("edge_train_arxiv_sim_txf_full").unwrap_err();
        assert!(matches!(err, ManifestError::UnsupportedEdgeForm { .. }));
        let msg = err.to_string();
        assert!(msg.contains("UnsupportedEdgeForm"), "{msg}");
        assert!(msg.contains("edge-list form"), "{msg}");
        // a plain typo still reports not-found, not unsupported
        assert!(matches!(
            m.artifact("vq_train_tiny_sim_nope").unwrap_err(),
            ManifestError::NotFound(_)
        ));
    }

    #[test]
    fn loads_real_manifest() {
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.train.b > 0 && m.train.k > 0);
        assert!(m.datasets.contains_key("tiny_sim"));
        let a = m.artifact("vq_train_tiny_sim_gcn").unwrap();
        assert_eq!(a.kind, "vq_train");
        assert!(!a.inputs.is_empty() && !a.outputs.is_empty());
        assert_eq!(a.plan.len(), m.models["gcn"].layers);
        // xb comes first and matches (b, f_in_pad)
        assert_eq!(a.inputs[0].name, "xb");
        assert_eq!(a.inputs[0].shape[0], a.b);
        // every vq_train has matching grad outputs for each param input
        let params: Vec<_> = a
            .inputs
            .iter()
            .filter(|t| t.name.starts_with("param."))
            .collect();
        for p in params {
            let g = format!("grad.{}", &p.name["param.".len()..]);
            let go = a.outputs.iter().find(|t| t.name == g).expect("grad output");
            assert_eq!(go.shape, p.shape);
        }
    }
}
