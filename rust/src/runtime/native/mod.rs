//! Native CPU backend: executes the manifest's artifacts as pure-Rust
//! computations — no Python, no JAX, no HLO artifacts, no PJRT.
//!
//! It honors the same positional input/output contract the AOT artifacts
//! expose (`runtime::builtin` reconstructs the specs), so the trainers
//! cannot tell the backends apart.  Supported today:
//!
//! - `vq_train` / `vq_infer` for the fixed-convolution backbones (GCN,
//!   SAGE-mean): Eq. 6 forward, loss head (CE / multilabel BCE / link BCE),
//!   Eq. 7 custom-VJP backward (the out-of-batch gradient messages ride the
//!   gradient half of the codewords via the transposed sketches), per-layer
//!   probe gradients, whitened FINDNEAREST via the blocked VQ kernels, and
//!   exact parameter gradients ([`vq`]);
//! - `vq_train` / `vq_infer` for the learnable convolutions (GAT
//!   edge-softmax attention, Graph-Transformer local+global attention): the
//!   decoupled row-normalization form of App. E with a hand-derived VJP
//!   mirroring `python/compile/layers.py`, pinned by `tests/gradcheck.rs`
//!   finite differences ([`attn`]);
//! - `vq_serve`: the forward-only serving path of either family — logits
//!   only, no gradient buffers, no residual outputs;
//! - `edge_train` / `edge_infer`: exact edge-list message passing with full
//!   backprop (the four sampling baselines), including per-edge GAT
//!   attention ([`edge`]);
//! - `vq_assign`: the standalone masked assignment kernel.
//!
//! Unlike the original per-call interpreter, the backend is **plan
//! compiled**: [`plan::Plan::compile`] resolves every string-keyed slot and
//! per-layer dimension once at `Runtime::load` time, and
//! [`arena::StepArena`] owns every intermediate buffer (forward caches,
//! attention caches, gradient accumulators) for the executor to rewrite in
//! place on every step.  Steady-state steps through a cached executor
//! allocate nothing in the compute path, and a session driving
//! `Runtime::execute_into` with persistent output tensors allocates nothing
//! at the boundary either.  The arena carries no semantic state across
//! steps — outputs are bit-identical to the old interpreter's and to a
//! fresh executor's (`tests/plan_executor.rs`).
//!
//! The compiled [`plan::Plan`] is immutable and `Arc`-shared; all per-step
//! mutable state lives in [`arena::ExecSession`]s detachable via
//! `Executable::new_session`, so any number of sessions can drive one
//! `&Executable` from concurrent `util::par` workers
//! (`Executable::run_session` — the serving pool's fan-out path), while
//! the single-caller `run`/`run_into` entry points keep using the
//! executable's built-in session.
//!
//! The only artifact family without a native path is the Graph Transformer's
//! edge-list form — global attention has none (see
//! `manifest::ManifestError::UnsupportedEdgeForm`).

pub mod arena;
mod attn;
mod edge;
pub mod plan;
mod vq;

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::ops;
use crate::runtime::{Backend, Executable, InputSlots};
use crate::util::tensor::{DType, Tensor};

use arena::{ExecSession, StepArena};
use plan::{Plan, PlanKind};

pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_model(&self, model: &str) -> bool {
        matches!(model, "gcn" | "sage" | "gat" | "txf")
    }

    fn compile(&mut self, man: &Manifest, spec: &ArtifactSpec) -> Result<Box<dyn Executable>> {
        let ds = man
            .datasets
            .get(&spec.dataset)
            .with_context(|| format!("native: unknown dataset '{}'", spec.dataset))?;
        let model = man
            .models
            .get(&spec.model)
            .with_context(|| format!("native: unknown model '{}'", spec.model))?;
        match spec.kind.as_str() {
            "vq_train" | "vq_infer" | "vq_serve" => {
                if !self.supports_model(&spec.model) {
                    bail!("native: unknown model '{}' (artifact {})", spec.model, spec.name);
                }
            }
            "edge_train" | "edge_infer" => {
                if !matches!(spec.model.as_str(), "gcn" | "sage" | "gat") {
                    bail!(
                        "native: the '{}' backbone has no edge-list form (artifact {}): \
                         global attention touches every node pair, not an edge list",
                        spec.model,
                        spec.name
                    );
                }
            }
            "vq_assign" => {}
            other => bail!("native: unknown artifact kind '{other}' ({})", spec.name),
        }
        let plan = Arc::new(Plan::compile(ds, model, spec)?);
        let builtin = StepArena::for_plan(&plan);
        Ok(Box::new(NativeExec { plan, builtin: Mutex::new(builtin) }))
    }
}

/// One compiled artifact, split into the read-only shared half and the
/// per-caller mutable half:
///
/// - the [`Plan`] is `Arc`-shared — every session of this executable (and
///   the executable itself) reads the same resolved slots and dims;
/// - each [`ExecSession`] owns a private [`StepArena`], so any number of
///   sessions can drive the same `&NativeExec` concurrently through
///   [`Executable::run_session`] (the serving pool's fan-out path);
/// - `builtin` is the executable's own session for the legacy
///   single-caller `run`/`run_into` entry points (trainers, one-shot
///   inference).  It rides a `Mutex` only to keep the type `Sync`; those
///   paths are single-threaded, so the lock is uncontended and the outputs
///   are bit-identical to the pre-split `RefCell` executor's.
pub struct NativeExec {
    plan: Arc<Plan>,
    builtin: Mutex<StepArena>,
}

/// One step against a caller-chosen arena — the shared body of every entry
/// point.  Outputs are a pure function of `(plan, inputs)`; the arena
/// carries no semantic state across steps (`tests/plan_executor.rs`).
fn run_with(
    plan: &Plan,
    ar: &mut StepArena,
    spec: &ArtifactSpec,
    inputs: InputSlots<'_>,
    outputs: &mut Vec<Tensor>,
) -> Result<()> {
    debug_assert_eq!(spec.name, plan.name, "executor driven with a foreign spec");
    ensure_outputs(spec, outputs);
    match plan.kind {
        PlanKind::Vq(mode) => vq::run_vq(plan, ar, inputs, outputs, mode),
        PlanKind::VqAttn(mode) => attn::run_vq_attn(plan, ar, inputs, outputs, mode),
        PlanKind::Edge { train } => edge::run_edge(plan, ar, inputs, outputs, train),
        PlanKind::Assign => vq::run_vq_assign(plan, ar, inputs, outputs),
    }
}

impl Executable for NativeExec {
    fn run(&self, spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut outputs = Vec::new();
        self.run_into(spec, inputs, &mut outputs)?;
        Ok(outputs)
    }

    fn run_into(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Tensor],
        outputs: &mut Vec<Tensor>,
    ) -> Result<()> {
        let mut ar = self.builtin.lock().expect("native: built-in session poisoned");
        run_with(&self.plan, &mut ar, spec, InputSlots::Dense(inputs), outputs)
    }

    fn new_session(&self) -> ExecSession {
        ExecSession::for_native(self.plan.clone())
    }

    fn run_session(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Tensor],
        outputs: &mut Vec<Tensor>,
        sess: &mut ExecSession,
    ) -> Result<()> {
        self.run_slots(spec, InputSlots::Dense(inputs), outputs, sess)
    }

    /// The native executor reads inputs positionally through the view, so
    /// overlay views (serving's Arc-shared constant template + per-session
    /// dynamic slots) execute directly — no materialized dense copy.
    fn run_slots(
        &self,
        spec: &ArtifactSpec,
        inputs: InputSlots<'_>,
        outputs: &mut Vec<Tensor>,
        sess: &mut ExecSession,
    ) -> Result<()> {
        let st = sess.native_mut().with_context(|| {
            format!(
                "native {}: driven with a stateless session (detach one with \
                 Executable::new_session)",
                self.plan.name
            )
        })?;
        if st.plan.name != self.plan.name {
            bail!(
                "native {}: driven with a session detached from '{}'",
                self.plan.name,
                st.plan.name
            );
        }
        run_with(&self.plan, &mut st.arena, spec, inputs, outputs)
    }
}

impl NativeExec {
    /// The compiled plan (read-only introspection for tests).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }
}

/// Make `outputs` hold exactly the spec's declared tensors, reusing the
/// existing buffers when they already match (the steady-state path: a
/// session passes the same vector every step).  Shape correctness of every
/// output is by construction — the executor writes into buffers sized from
/// the spec, with slice-length panics guarding any drift.
fn ensure_outputs(spec: &ArtifactSpec, outputs: &mut Vec<Tensor>) {
    let ok = outputs.len() == spec.outputs.len()
        && outputs
            .iter()
            .zip(&spec.outputs)
            .all(|(t, s)| t.shape == s.shape && t.dtype == s.dtype);
    if ok {
        return;
    }
    outputs.clear();
    for ts in &spec.outputs {
        outputs.push(match ts.dtype {
            DType::F32 => Tensor::zeros(&ts.shape),
            DType::I32 => Tensor::from_i32(&ts.shape, vec![0; ts.numel()]),
        });
    }
}

/// Loss head shared by all train paths.  Writes `∂ℓ/∂logits` into
/// `dlogits` (zeroed first) and returns the loss; for the link task
/// `logits` are node embeddings and the gradient is the pair-loss cotangent
/// scattered back onto them.  `s_logp` is the CE path's log-softmax scratch.
fn loss_head_into(
    plan: &Plan,
    inputs: InputSlots<'_>,
    logits: &[f32],
    rows: usize,
    c: usize,
    dlogits: &mut [f32],
    s_logp: &mut [f32],
) -> Result<f32> {
    debug_assert_eq!(dlogits.len(), rows * c);
    dlogits.fill(0.0);
    if plan.link {
        let psrc = &inputs[plan.in_psrc.expect("plan: psrc")].i;
        let pdst = &inputs[plan.in_pdst.expect("plan: pdst")].i;
        let py = &inputs[plan.in_py.expect("plan: py")].f;
        let pw = &inputs[plan.in_pw.expect("plan: pw")].f;
        let wsum: f32 = pw.iter().sum::<f32>().max(1.0);
        let mut loss = 0.0f64;
        for e in 0..psrc.len() {
            let (u, v) = (psrc[e] as usize, pdst[e] as usize);
            let eu = &logits[u * c..(u + 1) * c];
            let ev = &logits[v * c..(v + 1) * c];
            let mut z = 0.0f32;
            for d in 0..c {
                z += eu[d] * ev[d];
            }
            loss += (pw[e] * ops::bce_with_logits(z, py[e])) as f64;
            let dz = pw[e] * (ops::sigmoid(z) - py[e]) / wsum;
            if dz != 0.0 {
                for d in 0..c {
                    dlogits[u * c + d] += dz * ev[d];
                    dlogits[v * c + d] += dz * eu[d];
                }
            }
        }
        return Ok((loss / wsum as f64) as f32);
    }
    let w = &inputs[plan.in_wloss.expect("plan: wloss")].f;
    let wsum: f32 = w.iter().sum::<f32>().max(1.0);
    if plan.multilabel {
        let y = &inputs[plan.in_y.expect("plan: y")].f;
        let mut loss = 0.0f64;
        for i in 0..rows {
            if w[i] == 0.0 {
                // gradient rows stay zero; skip the loss term too
                continue;
            }
            let mut per = 0.0f32;
            for j in 0..c {
                let z = logits[i * c + j];
                per += ops::bce_with_logits(z, y[i * c + j]);
                dlogits[i * c + j] = w[i] * (ops::sigmoid(z) - y[i * c + j]) / (c as f32 * wsum);
            }
            loss += (w[i] * per / c as f32) as f64;
        }
        Ok((loss / wsum as f64) as f32)
    } else {
        let y = &inputs[plan.in_y.expect("plan: y")].i;
        debug_assert_eq!(s_logp.len(), rows * c);
        ops::log_softmax_into(logits, c, s_logp);
        let mut loss = 0.0f64;
        for i in 0..rows {
            if w[i] == 0.0 {
                continue;
            }
            let yi = y[i] as usize;
            loss += (w[i] * -s_logp[i * c + yi]) as f64;
            for j in 0..c {
                let soft = s_logp[i * c + j].exp();
                let delta = if j == yi { 1.0 } else { 0.0 };
                dlogits[i * c + j] = w[i] * (soft - delta) / wsum;
            }
        }
        Ok((loss / wsum as f64) as f32)
    }
}

/// VJP of `attn_normalize`: given `go = ∂ℓ/∂(num/den_c)`, the cached mass
/// and the normalized output, write `(∂ℓ/∂num, ∂ℓ/∂den)` into
/// `gnum`/`gden` (every element assigned).  The `max(den, floor)` guard
/// gates the denominator gradient exactly like `jnp.maximum` does.
fn normalize_bwd_into(
    go: &[f32],
    h: usize,
    den: &[f32],
    o: &[f32],
    gnum: &mut [f32],
    gden: &mut [f32],
) {
    let b = den.len();
    debug_assert_eq!(go.len(), b * h);
    debug_assert_eq!(gnum.len(), b * h);
    debug_assert_eq!(gden.len(), b);
    for i in 0..b {
        let d = den[i];
        if d > ops::DEN_FLOOR {
            let inv = 1.0 / d;
            let mut acc = 0.0f32;
            for t in 0..h {
                gnum[i * h + t] = go[i * h + t] * inv;
                acc += go[i * h + t] * o[i * h + t];
            }
            gden[i] = -acc * inv;
        } else {
            let inv = 1.0 / ops::DEN_FLOOR;
            for t in 0..h {
                gnum[i * h + t] = go[i * h + t] * inv;
            }
            gden[i] = 0.0;
        }
    }
}
