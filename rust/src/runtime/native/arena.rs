//! The step arena: every intermediate buffer one compiled step touches,
//! allocated once at `compile()` time and rewritten in place on every
//! subsequent step.  Steady-state train/infer/serve steps through a cached
//! executor perform no heap allocation in the compute path — the
//! `alloc-count` bench feature measures exactly this.  (Two bounded
//! exceptions, both internal to the blocked-parallel kernels above their
//! size thresholds: the per-edge scatter's destination buckets and
//! `util::par`'s worker bookkeeping.)
//!
//! Reuse discipline (what makes a reused buffer bit-identical to a fresh
//! one): every buffer is either fully overwritten by the op that produces
//! it (`*_into` ops zero-then-accumulate or assign every element) or
//! explicitly `fill(0.0)`-ed before an accumulation loop.  Buffers are
//! allocated at their maximum per-layer size and sliced to the live layer's
//! logical width at each use site, so one arena serves every layer of a
//! plan.  Nothing in the arena carries semantic state across steps — a
//! cached executor is still a pure function of its inputs (pinned by
//! `tests/plan_executor.rs`).

use std::sync::Arc;

use super::plan::{Plan, PlanKind};

/// A detachable execution session: the per-caller mutable half of a
/// compiled artifact.  The read-only [`Plan`] is `Arc`-shared by every
/// session of one executable; the [`StepArena`] here is private to the
/// session, so any number of sessions can drive the SAME `&Executable`
/// from different `util::par` workers at once
/// (`Executable::run_session`).  Stateless backends (PJRT keeps no
/// host-side step state) use [`ExecSession::stateless`], and their
/// `run_session` ignores it.
pub struct ExecSession {
    native: Option<NativeSession>,
}

/// The native backend's session state.
pub(crate) struct NativeSession {
    pub plan: Arc<Plan>,
    pub arena: StepArena,
}

impl ExecSession {
    /// A session for backends with no per-caller step state.
    pub fn stateless() -> ExecSession {
        ExecSession { native: None }
    }

    /// Detach a fresh native session (its own arena) from a shared plan.
    pub(crate) fn for_native(plan: Arc<Plan>) -> ExecSession {
        let arena = StepArena::for_plan(&plan);
        ExecSession { native: Some(NativeSession { plan, arena }) }
    }

    pub(crate) fn native_mut(&mut self) -> Option<&mut NativeSession> {
        self.native.as_mut()
    }

    /// Layer `l`'s input-feature rows `(rows, f_in)` as left by the last
    /// step through this session — the inductive-admission bootstrap reads
    /// the cold node's per-layer features out of its serve forward instead
    /// of re-deriving them on the host.  `None` on stateless sessions or
    /// plans without per-layer features (edge/assign).
    pub fn layer_xfeat(&self, l: usize) -> Option<&[f32]> {
        self.native
            .as_ref()
            .and_then(|st| st.arena.xfeat.get(l))
            .filter(|v| !v.is_empty())
            .map(|v| v.as_slice())
    }
}

/// Forward residuals of one GAT attention head (VQ path), preallocated.
#[derive(Debug, Default)]
pub struct HeadBufs {
    pub proj: Vec<f32>,    // (b, hh)  X W_s
    pub e_src: Vec<f32>,   // (b,)     proj · a_src
    pub e_dst: Vec<f32>,   // (b,)     proj · a_dst
    pub cproj: Vec<f32>,   // (k, hh)  X̃ W_s
    pub ecw_src: Vec<f32>, // (k,)     cproj · a_src
    pub ecw_dst: Vec<f32>, // (k,)     cproj · a_dst
    pub c_in: Vec<f32>,    // (b, b)   masked in-batch scores
    pub c_out: Vec<f32>,   // (b, k)   count-weighted out-of-batch scores
    pub m: Vec<f32>,       // (b, f)   approximated messages C_in X + C_out X̃
    pub den: Vec<f32>,     // (b,)     attention mass
    pub o: Vec<f32>,       // (b, hh)  normalized head output
}

impl HeadBufs {
    fn new(b: usize, k: usize, f: usize, hh: usize) -> HeadBufs {
        HeadBufs {
            proj: vec![0.0; b * hh],
            e_src: vec![0.0; b],
            e_dst: vec![0.0; b],
            cproj: vec![0.0; k * hh],
            ecw_src: vec![0.0; k],
            ecw_dst: vec![0.0; k],
            c_in: vec![0.0; b * b],
            c_out: vec![0.0; b * k],
            m: vec![0.0; b * f],
            den: vec![0.0; b],
            o: vec![0.0; b * hh],
        }
    }
}

/// Forward residuals of the txf global-attention branch, preallocated.
#[derive(Debug, Default)]
pub struct GlobBufs {
    pub q: Vec<f32>,     // (b, dk)
    pub kk: Vec<f32>,    // (b, dk)
    pub kcw: Vec<f32>,   // (k, dk)  X̃ W_k
    pub qcw: Vec<f32>,   // (k, dk)  X̃ W_q (transposed-sketch side)
    pub t_in: Vec<f32>,  // (b, b)   scaled raw dots (cap-gate input)
    pub t_out: Vec<f32>, // (b, k)
    pub c_in: Vec<f32>,  // (b, b)   exp scores
    pub c_out: Vec<f32>, // (b, k)   cnt_out-weighted exp scores
    pub m: Vec<f32>,     // (b, f)
    pub den: Vec<f32>,   // (b,)
    pub o: Vec<f32>,     // (b, h)
}

impl GlobBufs {
    fn new(b: usize, k: usize, f: usize, h: usize, dk: usize) -> GlobBufs {
        GlobBufs {
            q: vec![0.0; b * dk],
            kk: vec![0.0; b * dk],
            kcw: vec![0.0; k * dk],
            qcw: vec![0.0; k * dk],
            t_in: vec![0.0; b * b],
            t_out: vec![0.0; b * k],
            c_in: vec![0.0; b * b],
            c_out: vec![0.0; b * k],
            m: vec![0.0; b * f],
            den: vec![0.0; b],
            o: vec![0.0; b * h],
        }
    }
}

/// Forward residuals of one per-edge GAT head (edge-list path).
#[derive(Debug, Default)]
pub struct EdgeHeadBufs {
    pub proj: Vec<f32>,  // (nn, hh)
    pub e_src: Vec<f32>, // (nn,)
    pub e_dst: Vec<f32>, // (nn,)
    pub den: Vec<f32>,   // (nn,)
    pub o: Vec<f32>,     // (nn, hh) normalized head output
}

impl EdgeHeadBufs {
    fn new(nn: usize, hh: usize) -> EdgeHeadBufs {
        EdgeHeadBufs {
            proj: vec![0.0; nn * hh],
            e_src: vec![0.0; nn],
            e_dst: vec![0.0; nn],
            den: vec![0.0; nn],
            o: vec![0.0; nn * hh],
        }
    }
}

/// All of a compiled step's reusable buffers.  Per-layer vectors hold
/// forward residuals that the backward pass re-reads; `s_*` fields are
/// within-layer scratch sized to the maximum use across layers.
#[derive(Debug, Default)]
pub struct StepArena {
    // per-layer persistent forward residuals
    pub xfeat: Vec<Vec<f32>>,   // layer inputs (rows, f_in)
    pub pre: Vec<Vec<f32>>,     // pre-activations (rows, h_out)
    pub mbuf: Vec<Vec<f32>>,    // fixed-conv messages / edge aggregates
    pub gvec: Vec<Vec<f32>>,    // per-layer probe gradients (b, g_dim)
    pub cw_feat: Vec<Vec<f32>>, // attn: feature half of the codebook (k, f)
    pub heads: Vec<Vec<HeadBufs>>,
    pub glob: Vec<Option<GlobBufs>>,
    pub eheads: Vec<Vec<EdgeHeadBufs>>,
    // rotating gradient buffers (rows × max dim)
    pub g: Vec<f32>,
    pub dh: Vec<f32>,
    // generic scratch
    pub s_un: Vec<f32>,   // unsketch output (b, cf)
    pub s_mat: Vec<f32>,  // matmul temp (rows, max dim)
    pub s_gsl: Vec<f32>,  // Eq. 7 gradient-column messages
    pub s_logp: Vec<f32>, // log-softmax (rows, c)
    pub s_rs: Vec<f32>,   // row-sum temp (rows,)
    // attention backward scratch
    pub s_go: Vec<f32>,     // per-head slice of the incoming gradient
    pub s_gnum: Vec<f32>,   // numerator cotangent
    pub s_gden: Vec<f32>,   // denominator cotangent
    pub s_dm: Vec<f32>,     // message cotangent (b, f)
    pub s_dcin: Vec<f32>,   // ∂ℓ/∂C_in (b, b)
    pub s_dcout: Vec<f32>,  // ∂ℓ/∂C̃_out (b, k)
    pub s_ct: Vec<f32>,     // transposed-score tile (b, k)
    pub s_cwg: Vec<f32>,    // gradient-column codeword slice (k, h)
    pub s_desrc: Vec<f32>,  // (b,)
    pub s_dedst: Vec<f32>,  // (b,)
    pub s_decw: Vec<f32>,   // (k,)
    pub s_dproj: Vec<f32>,  // (rows, hh)
    pub s_dcproj: Vec<f32>, // (k, hh)
    pub s_das: Vec<f32>,    // per-head a_src gradient (hh,)
    pub s_dad: Vec<f32>,    // per-head a_dst gradient (hh,)
    pub s_wtmp: Vec<f32>,   // weight-gradient temp (f, max(hh, dk))
    // txf global-branch backward scratch
    pub s_dtin: Vec<f32>,  // (b, b)
    pub s_dtout: Vec<f32>, // (b, k)
    pub s_dq: Vec<f32>,    // (b, dk)
    pub s_dkk: Vec<f32>,   // (b, dk)
    pub s_dkcw: Vec<f32>,  // (k, dk)
    // edge backward scratch
    pub s_dagg: Vec<f32>, // scattered aggregate cotangent (nn, f)
    // Alg. 2 FINDNEAREST scratch
    pub s_zb: Vec<f32>,  // branch concat slice (b, fp)
    pub s_zw: Vec<f32>,  // whitened slice (b, fp) / masked codebook (k, fp)
    pub s_inv: Vec<f32>, // inverse std (fp,)
}

fn zeros(n: usize) -> Vec<f32> {
    vec![0.0; n]
}

impl StepArena {
    pub fn for_plan(plan: &Plan) -> StepArena {
        let mut ar = StepArena::default();
        match plan.kind {
            PlanKind::Vq(mode) => size_vq(&mut ar, plan, mode == super::plan::Mode::Train),
            PlanKind::VqAttn(mode) => size_attn(&mut ar, plan, mode == super::plan::Mode::Train),
            PlanKind::Edge { train } => size_edge(&mut ar, plan, train),
            PlanKind::Assign => {
                ar.s_zb = zeros(plan.b * plan.fp0);
                ar.s_zw = zeros(plan.k * plan.fp0);
            }
        }
        ar
    }
}

fn size_vq(ar: &mut StepArena, plan: &Plan, train: bool) {
    let b = plan.b;
    let mut maxdim = 0usize;
    let mut max_cf = 0usize;
    let mut max_fp = 0usize;
    for sl in &plan.layers {
        maxdim = maxdim.max(sl.f_in).max(sl.h_out);
        max_cf = max_cf.max(sl.cf);
        max_fp = max_fp.max(sl.fp);
    }
    ar.xfeat = plan.layers.iter().map(|sl| zeros(b * sl.f_in)).collect();
    ar.pre = plan.layers.iter().map(|sl| zeros(b * sl.h_out)).collect();
    ar.mbuf = plan.layers.iter().map(|sl| zeros(b * sl.f_in)).collect();
    ar.s_un = zeros(b * max_cf);
    ar.s_mat = zeros(b * maxdim);
    if train {
        ar.gvec = plan.layers.iter().map(|sl| zeros(b * sl.g_dim)).collect();
        ar.g = zeros(b * maxdim);
        ar.dh = zeros(b * maxdim);
        ar.s_gsl = zeros(b * maxdim);
        ar.s_logp = zeros(b * plan.c);
        ar.s_zb = zeros(b * max_fp);
        ar.s_zw = zeros(b * max_fp);
        ar.s_inv = zeros(max_fp);
    }
}

fn size_attn(ar: &mut StepArena, plan: &Plan, train: bool) {
    let (b, k) = (plan.b, plan.k);
    let mut f_max = 0usize;
    let mut h_max = 0usize;
    let mut hh_max = 0usize;
    let mut dk_max = 0usize;
    let mut max_fp = 0usize;
    for sl in &plan.layers {
        f_max = f_max.max(sl.f_in);
        h_max = h_max.max(sl.h_out);
        hh_max = hh_max.max(sl.hh);
        dk_max = dk_max.max(sl.dk);
        max_fp = max_fp.max(sl.fp);
    }
    let maxdim = f_max.max(h_max).max(dk_max);
    ar.xfeat = plan.layers.iter().map(|sl| zeros(b * sl.f_in)).collect();
    ar.pre = plan.layers.iter().map(|sl| zeros(b * sl.h_out)).collect();
    ar.cw_feat = plan.layers.iter().map(|sl| zeros(k * sl.f_in)).collect();
    ar.heads = plan
        .layers
        .iter()
        .map(|sl| (0..sl.heads).map(|_| HeadBufs::new(b, k, sl.f_in, sl.hh)).collect())
        .collect();
    ar.glob = plan
        .layers
        .iter()
        .map(|sl| {
            if plan.txf {
                Some(GlobBufs::new(b, k, sl.f_in, sl.h_out, sl.dk))
            } else {
                None
            }
        })
        .collect();
    ar.s_mat = zeros(b * maxdim);
    ar.s_rs = zeros(b);
    if train {
        ar.gvec = plan.layers.iter().map(|sl| zeros(b * sl.g_dim)).collect();
        ar.g = zeros(b * maxdim);
        ar.dh = zeros(b * maxdim);
        ar.s_logp = zeros(b * plan.c);
        ar.s_go = zeros(b * h_max);
        ar.s_gnum = zeros(b * h_max);
        ar.s_gden = zeros(b);
        ar.s_dm = zeros(b * f_max);
        ar.s_dcin = zeros(b * b);
        ar.s_dcout = zeros(b * k);
        ar.s_ct = zeros(b * k);
        ar.s_cwg = zeros(k * h_max);
        ar.s_desrc = zeros(b);
        ar.s_dedst = zeros(b);
        ar.s_decw = zeros(k);
        ar.s_dproj = zeros(b * hh_max);
        ar.s_dcproj = zeros(k * hh_max);
        ar.s_das = zeros(hh_max);
        ar.s_dad = zeros(hh_max);
        ar.s_gsl = zeros(b * h_max);
        ar.s_wtmp = zeros(f_max * hh_max.max(dk_max).max(1));
        if plan.txf {
            ar.s_dtin = zeros(b * b);
            ar.s_dtout = zeros(b * k);
            ar.s_dq = zeros(b * dk_max);
            ar.s_dkk = zeros(b * dk_max);
            ar.s_dkcw = zeros(k * dk_max);
        }
        ar.s_zb = zeros(b * max_fp);
        ar.s_zw = zeros(b * max_fp);
        ar.s_inv = zeros(max_fp);
    }
}

fn size_edge(ar: &mut StepArena, plan: &Plan, train: bool) {
    let nn = plan.nn;
    let mut f_max = 0usize;
    let mut h_max = 0usize;
    let mut hh_max = 0usize;
    for sl in &plan.layers {
        f_max = f_max.max(sl.f_in);
        h_max = h_max.max(sl.h_out);
        hh_max = hh_max.max(sl.hh);
    }
    let maxdim = f_max.max(h_max);
    ar.xfeat = plan.layers.iter().map(|sl| zeros(nn * sl.f_in)).collect();
    ar.pre = plan.layers.iter().map(|sl| zeros(nn * sl.h_out)).collect();
    if plan.gat {
        ar.eheads = plan
            .layers
            .iter()
            .map(|sl| (0..sl.heads).map(|_| EdgeHeadBufs::new(nn, sl.hh)).collect())
            .collect();
    } else {
        ar.mbuf = plan.layers.iter().map(|sl| zeros(nn * sl.f_in)).collect();
    }
    ar.s_mat = zeros(nn * maxdim);
    if train {
        ar.g = zeros(nn * maxdim);
        ar.dh = zeros(nn * maxdim);
        ar.s_logp = zeros(nn * plan.c);
        if plan.gat {
            ar.s_go = zeros(nn * hh_max);
            ar.s_gnum = zeros(nn * hh_max);
            ar.s_gden = zeros(nn);
            ar.s_dproj = zeros(nn * hh_max);
            ar.s_desrc = zeros(nn);
            ar.s_dedst = zeros(nn);
            ar.s_das = zeros(hh_max);
            ar.s_dad = zeros(hh_max);
            ar.s_wtmp = zeros(f_max * hh_max.max(1));
        } else {
            ar.s_dagg = zeros(nn * f_max);
        }
    }
}
