//! Fixed-convolution VQ-GNN step (Eq. 6/7 + Alg. 2 FINDNEAREST) on the
//! plan-compiled executor, plus the standalone masked-assignment kernel.
//! The op sequence — and therefore every floating-point accumulation
//! order — mirrors the pre-arena interpreter exactly; only the buffer
//! ownership moved into [`StepArena`].

#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use anyhow::Result;

use crate::runtime::ops;
use crate::runtime::InputSlots;
use crate::util::tensor::Tensor;
use crate::vq::kernels;

use super::arena::StepArena;
use super::plan::{Mode, Plan};
use super::loss_head_into;

pub(super) fn run_vq(
    plan: &Plan,
    ar: &mut StepArena,
    inputs: InputSlots<'_>,
    outputs: &mut [Tensor],
    mode: Mode,
) -> Result<()> {
    let train = mode == Mode::Train;
    let (b, k) = (plan.b, plan.k);
    let ll = plan.layers.len();
    let sage = plan.sage;
    let StepArena {
        xfeat,
        pre,
        mbuf,
        gvec,
        g,
        dh,
        s_un,
        s_mat,
        s_gsl,
        s_logp,
        s_zb,
        s_zw,
        s_inv,
        ..
    } = ar;

    // ---- forward (Eq. 6): m = C_in X_B + unsketch(C̃_out, X̃)[:, :f] ----
    xfeat[0].copy_from_slice(&inputs[plan.in_x].f);
    for l in 0..ll {
        let sl = &plan.layers[l];
        let (f, h, cf) = (sl.f_in, sl.h_out, sl.cf);
        let c_in = &inputs[sl.c_in.expect("plan: c_in")].f;
        let c_out = &inputs[sl.c_out.expect("plan: c_out")].f;
        let cw = &inputs[sl.cw.expect("plan: cw")].f;
        ops::unsketch_into(c_out, sl.n_br, b, k, cw, sl.fp, &mut s_un[..b * cf]);
        {
            let m = &mut mbuf[l];
            ops::matmul_into(c_in, b, b, &xfeat[l], f, m);
            for i in 0..b {
                for d in 0..f {
                    m[i * f + d] += s_un[i * cf + d];
                }
            }
        }
        let bias = &inputs[sl.bias.expect("plan: bias")].f;
        {
            let y = &mut pre[l];
            if sage {
                let w_self = &inputs[sl.w_self.expect("plan: w_self")].f;
                let w_nbr = &inputs[sl.w_nbr.expect("plan: w_nbr")].f;
                ops::matmul_into(&xfeat[l], b, f, w_self, h, y);
                ops::matmul_into(&mbuf[l], b, f, w_nbr, h, &mut s_mat[..b * h]);
                ops::add_into(y, &s_mat[..b * h]);
            } else {
                let w = &inputs[sl.w.expect("plan: w")].f;
                ops::matmul_into(&mbuf[l], b, f, w, h, y);
            }
            ops::add_bias(y, h, bias);
        }
        if l + 1 < ll {
            ops::relu_into(&pre[l], &mut xfeat[l + 1]);
        }
    }
    let c = plan.c;
    outputs[plan.o_logits.expect("plan: logits")].f.copy_from_slice(&pre[ll - 1]);
    if !train {
        if mode == Mode::Infer {
            for l in 0..ll {
                outputs[plan.layers[l].o_xfeat.expect("plan: xfeat out")]
                    .f
                    .copy_from_slice(&xfeat[l]);
            }
        }
        return Ok(());
    }

    let loss = loss_head_into(
        plan,
        inputs,
        &pre[ll - 1],
        b,
        c,
        &mut g[..b * c],
        &mut s_logp[..b * c],
    )?;
    outputs[plan.o_loss.expect("plan: loss")].f[0] = loss;

    // ---- backward (Eq. 7): same fused form with C_inᵀ and the
    // transposed out-of-batch sketches; the probe gradient at each layer
    // is exactly G_B^{l+1} ----
    for l in (0..ll).rev() {
        let sl = &plan.layers[l];
        let (f, h, gdim, cf) = (sl.f_in, sl.h_out, sl.g_dim, sl.cf);
        debug_assert_eq!(gdim, h, "fixed conv: gradient dim equals layer width");
        if l + 1 < ll {
            ops::relu_bwd(&mut g[..b * h], &pre[l]);
        }
        gvec[l].copy_from_slice(&g[..b * h]);
        ops::col_sum_into(&g[..b * h], h, &mut outputs[sl.g_bias.expect("plan: g_bias")].f);
        let c_in = &inputs[sl.c_in.expect("plan: c_in")].f;
        let ct_out = &inputs[sl.ct_out.expect("plan: ct_out")].f;
        let cw = &inputs[sl.cw.expect("plan: cw")].f;
        // (C_inᵀ G_B + unsketch((C̃ᵀ)_out, G̃)) — gradient columns of the
        // concat space are [f_in, f_in + g_dim).
        ops::unsketch_into(ct_out, sl.n_br, b, k, cw, sl.fp, &mut s_un[..b * cf]);
        ops::slice_cols_into(&s_un[..b * cf], cf, f, f + gdim, &mut s_gsl[..b * gdim]);
        ops::matmul_at_b_into(c_in, b, b, &g[..b * h], h, &mut s_mat[..b * h]);
        ops::add_into(&mut s_gsl[..b * gdim], &s_mat[..b * h]);
        if sage {
            let w_self = &inputs[sl.w_self.expect("plan: w_self")].f;
            let w_nbr = &inputs[sl.w_nbr.expect("plan: w_nbr")].f;
            ops::matmul_at_b_into(
                &xfeat[l],
                b,
                f,
                &g[..b * h],
                h,
                &mut outputs[sl.g_w_self.expect("plan: g_w_self")].f,
            );
            ops::matmul_at_b_into(
                &mbuf[l],
                b,
                f,
                &g[..b * h],
                h,
                &mut outputs[sl.g_w_nbr.expect("plan: g_w_nbr")].f,
            );
            ops::matmul_a_bt_into(&g[..b * h], b, h, w_self, f, &mut dh[..b * f]);
            ops::matmul_a_bt_into(&s_gsl[..b * h], b, h, w_nbr, f, &mut s_mat[..b * f]);
            ops::add_into(&mut dh[..b * f], &s_mat[..b * f]);
        } else {
            let w = &inputs[sl.w.expect("plan: w")].f;
            ops::matmul_at_b_into(
                &mbuf[l],
                b,
                f,
                &g[..b * h],
                h,
                &mut outputs[sl.g_w.expect("plan: g_w")].f,
            );
            ops::matmul_a_bt_into(&s_gsl[..b * h], b, h, w, f, &mut dh[..b * f]);
        }
        std::mem::swap(g, dh);
    }

    // ---- Alg. 2 FINDNEAREST on (X_B^l ‖ G_B^{l+1}) ----
    push_assign_outputs(plan, inputs, outputs, xfeat, gvec, s_zb, s_zw, s_inv)
}

/// Alg. 2 FINDNEAREST on the concat vectors (X_B^l ‖ G_B^{l+1}), whitened
/// against the pre-update codebook stats supplied as inputs; emits the
/// per-layer `xfeat` / `gvec` / `assign` outputs shared by every vq_train
/// backbone.
pub(super) fn push_assign_outputs(
    plan: &Plan,
    inputs: InputSlots<'_>,
    outputs: &mut [Tensor],
    xfeat: &[Vec<f32>],
    gvec: &[Vec<f32>],
    s_zb: &mut [f32],
    s_zw: &mut [f32],
    s_inv: &mut [f32],
) -> Result<()> {
    let (b, k) = (plan.b, plan.k);
    for (l, sl) in plan.layers.iter().enumerate() {
        let mean = &inputs[sl.mean.expect("plan: mean")].f;
        let var = &inputs[sl.var.expect("plan: var")].f;
        let cww = &inputs[sl.cww.expect("plan: cww")].f;
        let (f, gdim, fp) = (sl.f_in, sl.g_dim, sl.fp);
        {
            let assign = &mut outputs[sl.o_assign.expect("plan: assign out")].i;
            for j in 0..sl.n_br {
                // branch j covers concat columns [j*fp, (j+1)*fp)
                for i in 0..b {
                    for d in 0..fp {
                        let col = j * fp + d;
                        let raw = if col < f {
                            xfeat[l][i * f + col]
                        } else if col < f + gdim {
                            gvec[l][i * gdim + (col - f)]
                        } else {
                            0.0
                        };
                        s_zb[i * fp + d] = raw;
                    }
                }
                kernels::inv_std_into(&var[j * fp..(j + 1) * fp], &mut s_inv[..fp]);
                kernels::whiten_into(
                    &s_zb[..b * fp],
                    fp,
                    &mean[j * fp..(j + 1) * fp],
                    &s_inv[..fp],
                    &mut s_zw[..b * fp],
                );
                kernels::assign_blocked(
                    &s_zw[..b * fp],
                    fp,
                    fp,
                    &cww[j * k * fp..(j + 1) * k * fp],
                    k,
                    fp,
                    &mut assign[j * b..(j + 1) * b],
                );
            }
        }
        outputs[sl.o_xfeat.expect("plan: xfeat out")].f.copy_from_slice(&xfeat[l]);
        outputs[sl.o_gvec.expect("plan: gvec out")].f.copy_from_slice(&gvec[l]);
    }
    Ok(())
}

/// Standalone masked assignment (inductive inference path).
pub(super) fn run_vq_assign(
    plan: &Plan,
    ar: &mut StepArena,
    inputs: InputSlots<'_>,
    outputs: &mut [Tensor],
) -> Result<()> {
    let z = &inputs[plan.in_x];
    let cww = &inputs[plan.in_cww.expect("plan: cww")].f;
    let mask = &inputs[plan.in_mask.expect("plan: mask")].f;
    let (nb, b, fp) = (z.shape[0], z.shape[1], z.shape[2]);
    let k = plan.k;
    let StepArena { s_zb, s_zw, .. } = ar;
    let assign = &mut outputs[plan.o_assign_only.expect("plan: assign out")].i;
    for j in 0..nb {
        let mj = &mask[j * fp..(j + 1) * fp];
        let zm = &mut s_zb[..b * fp];
        zm.copy_from_slice(&z.f[j * b * fp..(j + 1) * b * fp]);
        for (idx, v) in zm.iter_mut().enumerate() {
            *v *= mj[idx % fp];
        }
        let cm = &mut s_zw[..k * fp];
        cm.copy_from_slice(&cww[j * k * fp..(j + 1) * k * fp]);
        for (idx, v) in cm.iter_mut().enumerate() {
            *v *= mj[idx % fp];
        }
        kernels::assign_blocked(zm, fp, fp, cm, k, fp, &mut assign[j * b..(j + 1) * b]);
    }
    Ok(())
}
