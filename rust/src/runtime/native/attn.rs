//! Learnable-convolution VQ-GNN step (GAT / Graph Transformer) on the
//! plan-compiled executor — the decoupled row-normalization form of App. E.
//!
//! Per head `s` with projection W_s and attention vectors a_src/a_dst,
//! the unnormalized score is `h(i,j) = exp(min(LeakyReLU(e_dst(i) +
//! e_src(j)), CAP))`.  The in-batch block lives on the fixed mask
//! 𝔠 = A + I; out-of-batch messages are merged per codeword (paper
//! Fig. 1) with weight `M_out[i,v] · h(i, X̃_v)` — the low-rank Eq. 6
//! form: scores against k codeword projections instead of n nodes.  The
//! numerator is the approximated message passing `(C_in X_B + C_out X̃)
//! W_s`; the denominator is the same attention applied to ones (plain
//! row sums), so an isolated row stays exactly zero.
//!
//! The backward pass mirrors `python/compile/layers.py` `mp_linear`'s
//! custom VJP: ∇X_B rides `C_inᵀ G + (C̃ᵀ)_out G̃` (Eq. 7 — the
//! transposed count sketches weight the *gradient* half of the
//! codewords), the convolution cotangents `∂ℓ/∂C_in = (G W ᵀ) X_Bᵀ` and
//! `∂ℓ/∂C̃_out = (G Wᵀ) X̃ᵀ` flow into the attention parameters through
//! the analytic score gradient (slope gate × cap gate), and the
//! transposed sketches themselves carry no cotangent.  The probe
//! gradient captured per layer is ∂ℓ/∂numerator — exactly the G̃
//! quantity the codebook update needs under decoupled normalization.
//!
//! txf adds a global scaled-dot-product branch (𝔠 = all-ones, so the
//! out-of-batch weight is just the bucket population `cnt_out[v]`) and a
//! linear branch; its gradient concat space is 2h wide (local ‖ global).
//!
//! The op sequence — and therefore every floating-point accumulation
//! order — mirrors the pre-arena interpreter exactly (pinned by the golden
//! tests and `tests/gradcheck.rs`); only buffer ownership moved into
//! [`StepArena`].

#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use anyhow::Result;

use crate::runtime::ops;
use crate::runtime::InputSlots;
use crate::util::simd;
use crate::util::tensor::Tensor;

use super::arena::StepArena;
use super::plan::{Mode, Plan};
use super::{loss_head_into, normalize_bwd_into};

/// Fold the attention-denominator cotangent into the score cotangents:
/// `den[i] = Σ_j c_in[i,j] + Σ_v c_out[i,v]`, so ∂ℓ/∂den broadcasts into
/// every score of row i.
fn add_den_cotangent(dc_in: &mut [f32], dc_out: &mut [f32], gden: &[f32], b: usize, k: usize) {
    debug_assert_eq!(dc_in.len(), b * b);
    debug_assert_eq!(dc_out.len(), b * k);
    for i in 0..b {
        let gd = gden[i];
        for x in dc_in[i * b..(i + 1) * b].iter_mut() {
            *x += gd;
        }
        for x in dc_out[i * k..(i + 1) * k].iter_mut() {
            *x += gd;
        }
    }
}

#[allow(clippy::needless_range_loop)]
pub(super) fn run_vq_attn(
    plan: &Plan,
    ar: &mut StepArena,
    inputs: InputSlots<'_>,
    outputs: &mut [Tensor],
    mode: Mode,
) -> Result<()> {
    let train = mode == Mode::Train;
    let (b, k) = (plan.b, plan.k);
    let ll = plan.layers.len();
    let txf = plan.txf;
    let StepArena {
        xfeat,
        pre,
        gvec,
        cw_feat,
        heads,
        glob,
        g,
        dh,
        s_mat,
        s_gsl,
        s_logp,
        s_rs,
        s_go,
        s_gnum,
        s_gden,
        s_dm,
        s_dcin,
        s_dcout,
        s_ct,
        s_cwg,
        s_desrc,
        s_dedst,
        s_decw,
        s_dproj,
        s_dcproj,
        s_das,
        s_dad,
        s_wtmp,
        s_dtin,
        s_dtout,
        s_dq,
        s_dkk,
        s_dkcw,
        s_zb,
        s_zw,
        s_inv,
        ..
    } = ar;

    // ---- forward ----
    xfeat[0].copy_from_slice(&inputs[plan.in_x].f);
    for l in 0..ll {
        let sl = &plan.layers[l];
        let (f, h, hh, nheads) = (sl.f_in, sl.h_out, sl.hh, sl.heads);
        debug_assert_eq!(hh * nheads, h, "heads must tile the layer width");
        let mask_in = &inputs[sl.mask_in.expect("plan: mask_in")].f;
        let m_out = &inputs[sl.m_out.expect("plan: m_out")].f;
        let cw = &inputs[sl.cw.expect("plan: cw")].f;
        ops::slice_cols_into(cw, sl.fp, 0, f, &mut cw_feat[l]); // feature half X̃ (k, f)
        let w = &inputs[sl.w.expect("plan: w")].f;
        let a_src = &inputs[sl.a_src.expect("plan: a_src")].f;
        let a_dst = &inputs[sl.a_dst.expect("plan: a_dst")].f;
        let bias = &inputs[sl.bias.expect("plan: bias")].f;

        for s in 0..nheads {
            let hb = &mut heads[l][s];
            let ws = &w[s * f * hh..(s + 1) * f * hh];
            let asr = &a_src[s * hh..(s + 1) * hh];
            let ads = &a_dst[s * hh..(s + 1) * hh];
            ops::matmul_into(&xfeat[l], b, f, ws, hh, &mut hb.proj);
            ops::dot_rows_into(&hb.proj, hh, asr, &mut hb.e_src);
            ops::dot_rows_into(&hb.proj, hh, ads, &mut hb.e_dst);
            ops::matmul_into(&cw_feat[l], k, f, ws, hh, &mut hb.cproj);
            ops::dot_rows_into(&hb.cproj, hh, asr, &mut hb.ecw_src);
            ops::dot_rows_into(&hb.cproj, hh, ads, &mut hb.ecw_dst);
            ops::gat_score_tile_into(&hb.e_dst, &hb.e_src, mask_in, &mut hb.c_in);
            ops::gat_score_tile_into(&hb.e_dst, &hb.ecw_src, m_out, &mut hb.c_out);
            // m = C_in X_B + C̃_out X̃ (the fused Eq. 6 kernel)
            ops::matmul_into(&hb.c_in, b, b, &xfeat[l], f, &mut hb.m);
            ops::matmul_into(&hb.c_out, b, k, &cw_feat[l], f, &mut s_mat[..b * f]);
            ops::add_into(&mut hb.m, &s_mat[..b * f]);
            ops::matmul_into(&hb.m, b, f, ws, hh, &mut hb.o);
            ops::row_sum_into(&hb.c_in, b, &mut hb.den);
            ops::row_sum_into(&hb.c_out, k, &mut s_rs[..b]);
            ops::add_into(&mut hb.den, &s_rs[..b]);
            ops::attn_normalize(&mut hb.o, hh, &hb.den);
            for i in 0..b {
                pre[l][i * h + s * hh..i * h + (s + 1) * hh]
                    .copy_from_slice(&hb.o[i * hh..(i + 1) * hh]);
            }
        }
        ops::add_bias(&mut pre[l], h, bias);

        if txf {
            let gb = glob[l].as_mut().expect("plan: txf glob bufs");
            let dk = sl.dk;
            let cnt_out = &inputs[sl.cnt_out.expect("plan: cnt_out")].f;
            let wq = &inputs[sl.wq.expect("plan: wq")].f;
            let wk = &inputs[sl.wk.expect("plan: wk")].f;
            let wv = &inputs[sl.wv.expect("plan: wv")].f;
            let w_lin = &inputs[sl.w_lin.expect("plan: w_lin")].f;
            let scale = 1.0 / (dk as f32).sqrt();
            ops::matmul_into(&xfeat[l], b, f, wq, dk, &mut gb.q);
            ops::matmul_into(&xfeat[l], b, f, wk, dk, &mut gb.kk);
            ops::matmul_into(&cw_feat[l], k, f, wk, dk, &mut gb.kcw);
            ops::matmul_into(&cw_feat[l], k, f, wq, dk, &mut gb.qcw);
            // global scores: 𝔠 = all-ones (App. Table 5)
            ops::matmul_a_bt_into(&gb.q, b, dk, &gb.kk, b, &mut gb.t_in);
            for x in gb.t_in.iter_mut() {
                *x *= scale;
            }
            ops::exp_capped_tile_into(&gb.t_in, &mut gb.c_in);
            ops::matmul_a_bt_into(&gb.q, b, dk, &gb.kcw, k, &mut gb.t_out);
            for x in gb.t_out.iter_mut() {
                *x *= scale;
            }
            ops::col_weighted_exp_tile_into(&gb.t_out, k, cnt_out, 1.0, &mut gb.c_out);
            ops::matmul_into(&gb.c_in, b, b, &xfeat[l], f, &mut gb.m);
            ops::matmul_into(&gb.c_out, b, k, &cw_feat[l], f, &mut s_mat[..b * f]);
            ops::add_into(&mut gb.m, &s_mat[..b * f]);
            ops::matmul_into(&gb.m, b, f, wv, h, &mut gb.o);
            ops::row_sum_into(&gb.c_in, b, &mut gb.den);
            ops::row_sum_into(&gb.c_out, k, &mut s_rs[..b]);
            ops::add_into(&mut gb.den, &s_rs[..b]);
            ops::attn_normalize(&mut gb.o, h, &gb.den);
            ops::add_into(&mut pre[l], &gb.o);
            ops::matmul_into(&xfeat[l], b, f, w_lin, h, &mut s_mat[..b * h]);
            ops::add_into(&mut pre[l], &s_mat[..b * h]);
        }

        if l + 1 < ll {
            ops::relu_into(&pre[l], &mut xfeat[l + 1]);
        }
    }
    let c = plan.c;
    outputs[plan.o_logits.expect("plan: logits")].f.copy_from_slice(&pre[ll - 1]);
    if !train {
        if mode == Mode::Infer {
            for l in 0..ll {
                outputs[plan.layers[l].o_xfeat.expect("plan: xfeat out")]
                    .f
                    .copy_from_slice(&xfeat[l]);
            }
        }
        return Ok(());
    }

    let loss = loss_head_into(
        plan,
        inputs,
        &pre[ll - 1],
        b,
        c,
        &mut g[..b * c],
        &mut s_logp[..b * c],
    )?;
    outputs[plan.o_loss.expect("plan: loss")].f[0] = loss;

    // ---- backward ----
    for l in (0..ll).rev() {
        let sl = &plan.layers[l];
        let (f, h, hh, nheads, gdim) = (sl.f_in, sl.h_out, sl.hh, sl.heads, sl.g_dim);
        if l + 1 < ll {
            ops::relu_bwd(&mut g[..b * h], &pre[l]);
        }
        ops::col_sum_into(&g[..b * h], h, &mut outputs[sl.g_bias.expect("plan: g_bias")].f);
        let m_out_t = &inputs[sl.m_out_t.expect("plan: m_out_t")].f;
        let cw = &inputs[sl.cw.expect("plan: cw")].f;
        let w = &inputs[sl.w.expect("plan: w")].f;
        let a_src = &inputs[sl.a_src.expect("plan: a_src")].f;
        let a_dst = &inputs[sl.a_dst.expect("plan: a_dst")].f;

        dh[..b * f].fill(0.0);
        gvec[l].fill(0.0);
        outputs[sl.g_w.expect("plan: g_w")].f.fill(0.0);
        outputs[sl.g_a_src.expect("plan: g_a_src")].f.fill(0.0);
        outputs[sl.g_a_dst.expect("plan: g_a_dst")].f.fill(0.0);

        for s in 0..nheads {
            let hb = &heads[l][s];
            let ws = &w[s * f * hh..(s + 1) * f * hh];
            let asr = &a_src[s * hh..(s + 1) * hh];
            let ads = &a_dst[s * hh..(s + 1) * hh];
            for i in 0..b {
                s_go[i * hh..(i + 1) * hh]
                    .copy_from_slice(&g[i * h + s * hh..i * h + (s + 1) * hh]);
            }
            normalize_bwd_into(
                &s_go[..b * hh],
                hh,
                &hb.den,
                &hb.o,
                &mut s_gnum[..b * hh],
                &mut s_gden[..b],
            );
            // probe gradient: this head's slice of the local columns
            for i in 0..b {
                gvec[l][i * gdim + s * hh..i * gdim + (s + 1) * hh]
                    .copy_from_slice(&s_gnum[i * hh..(i + 1) * hh]);
            }
            // ∇W through the numerator (exact given approximated m)
            ops::matmul_at_b_into(&hb.m, b, f, &s_gnum[..b * hh], hh, &mut s_wtmp[..f * hh]);
            ops::add_into(
                &mut outputs[sl.g_w.expect("plan: g_w")].f[s * f * hh..(s + 1) * f * hh],
                &s_wtmp[..f * hh],
            );
            // Eq. 7: C_inᵀ G + (C̃ᵀ)_out G̃ on this head's gradient cols
            ops::gat_score_tile_into(&hb.e_src, &hb.ecw_dst, m_out_t, &mut s_ct[..b * k]);
            ops::slice_cols_into(cw, sl.fp, f + s * hh, f + (s + 1) * hh, &mut s_cwg[..k * hh]);
            ops::matmul_at_b_into(&hb.c_in, b, b, &s_gnum[..b * hh], hh, &mut s_gsl[..b * hh]);
            ops::matmul_into(&s_ct[..b * k], b, k, &s_cwg[..k * hh], hh, &mut s_mat[..b * hh]);
            ops::add_into(&mut s_gsl[..b * hh], &s_mat[..b * hh]);
            ops::matmul_a_bt_into(&s_gsl[..b * hh], b, hh, ws, f, &mut s_mat[..b * f]);
            ops::add_into(&mut dh[..b * f], &s_mat[..b * f]);
            // convolution cotangents (numerator + denominator paths)
            ops::matmul_a_bt_into(&s_gnum[..b * hh], b, hh, ws, f, &mut s_dm[..b * f]);
            ops::matmul_a_bt_into(&s_dm[..b * f], b, f, &xfeat[l], b, &mut s_dcin[..b * b]);
            ops::matmul_a_bt_into(&s_dm[..b * f], b, f, &cw_feat[l], k, &mut s_dcout[..b * k]);
            add_den_cotangent(&mut s_dcin[..b * b], &mut s_dcout[..b * k], &s_gden[..b], b, k);
            // analytic score backward (gat_scores VJP): gs = dc ⊙ score
            // ⊙ slope/cap gate; scatter onto the e projections
            s_desrc[..b].fill(0.0);
            s_dedst[..b].fill(0.0);
            s_decw[..k].fill(0.0);
            for i in 0..b {
                for j in 0..b {
                    let sc = hb.c_in[i * b + j];
                    if sc == 0.0 {
                        continue;
                    }
                    let gt = s_dcin[i * b + j]
                        * sc
                        * ops::leaky_exp_grad(hb.e_dst[i] + hb.e_src[j]);
                    s_dedst[i] += gt;
                    s_desrc[j] += gt;
                }
                for v in 0..k {
                    let sc = hb.c_out[i * k + v];
                    if sc == 0.0 {
                        continue;
                    }
                    let gt = s_dcout[i * k + v]
                        * sc
                        * ops::leaky_exp_grad(hb.e_dst[i] + hb.ecw_src[v]);
                    s_dedst[i] += gt;
                    s_decw[v] += gt;
                }
            }
            // project e-gradients back: batch side and codeword side
            // (row-wise a·x + b·y and a·x — the SIMD forms are mul/mul/add,
            // bit-identical to the scalar loops they replaced)
            for i in 0..b {
                simd::scale2_into(
                    &mut s_dproj[i * hh..(i + 1) * hh],
                    s_desrc[i],
                    asr,
                    s_dedst[i],
                    ads,
                );
            }
            for v in 0..k {
                simd::scale_into(&mut s_dcproj[v * hh..(v + 1) * hh], s_decw[v], asr);
            }
            // ∇a_src / ∇a_dst: the old per-column accumulation, restructured
            // row-major so each row is one axpy — per element t the adds
            // still land in the original order (rows i ascending, then
            // codewords v ascending).
            s_das[..hh].fill(0.0);
            s_dad[..hh].fill(0.0);
            for i in 0..b {
                let prow = &hb.proj[i * hh..(i + 1) * hh];
                simd::axpy(&mut s_das[..hh], s_desrc[i], prow);
                simd::axpy(&mut s_dad[..hh], s_dedst[i], prow);
            }
            for v in 0..k {
                simd::axpy(&mut s_das[..hh], s_decw[v], &hb.cproj[v * hh..(v + 1) * hh]);
            }
            ops::add_into(
                &mut outputs[sl.g_a_src.expect("plan: g_a_src")].f[s * hh..(s + 1) * hh],
                &s_das[..hh],
            );
            ops::add_into(
                &mut outputs[sl.g_a_dst.expect("plan: g_a_dst")].f[s * hh..(s + 1) * hh],
                &s_dad[..hh],
            );
            ops::matmul_a_bt_into(&s_dproj[..b * hh], b, hh, ws, f, &mut s_mat[..b * f]);
            ops::add_into(&mut dh[..b * f], &s_mat[..b * f]);
            ops::matmul_at_b_into(&xfeat[l], b, f, &s_dproj[..b * hh], hh, &mut s_wtmp[..f * hh]);
            ops::add_into(
                &mut outputs[sl.g_w.expect("plan: g_w")].f[s * f * hh..(s + 1) * f * hh],
                &s_wtmp[..f * hh],
            );
            ops::matmul_at_b_into(
                &cw_feat[l],
                k,
                f,
                &s_dcproj[..k * hh],
                hh,
                &mut s_wtmp[..f * hh],
            );
            ops::add_into(
                &mut outputs[sl.g_w.expect("plan: g_w")].f[s * f * hh..(s + 1) * f * hh],
                &s_wtmp[..f * hh],
            );
        }

        if txf {
            let gb = glob[l].as_ref().expect("plan: txf glob bufs");
            let ho = h;
            let dk = sl.dk;
            let wq = &inputs[sl.wq.expect("plan: wq")].f;
            let wk = &inputs[sl.wk.expect("plan: wk")].f;
            let wv = &inputs[sl.wv.expect("plan: wv")].f;
            let w_lin = &inputs[sl.w_lin.expect("plan: w_lin")].f;
            let cnt_out = &inputs[sl.cnt_out.expect("plan: cnt_out")].f;
            let scale = 1.0 / (dk as f32).sqrt();
            normalize_bwd_into(
                &g[..b * ho],
                ho,
                &gb.den,
                &gb.o,
                &mut s_gnum[..b * ho],
                &mut s_gden[..b],
            );
            // probe gradient: global columns [h, 2h)
            for i in 0..b {
                gvec[l][i * gdim + ho..i * gdim + 2 * ho]
                    .copy_from_slice(&s_gnum[i * ho..(i + 1) * ho]);
            }
            ops::matmul_at_b_into(
                &gb.m,
                b,
                f,
                &s_gnum[..b * ho],
                ho,
                &mut outputs[sl.g_wv.expect("plan: g_wv")].f,
            );
            // Eq. 7 on the global gradient columns [f+h, f+2h): the
            // transposed sketch is cnt_out ⊙ h(X̃, X_B)ᵀ
            ops::matmul_a_bt_into(&gb.kk, b, dk, &gb.qcw, k, &mut s_dtout[..b * k]);
            ops::col_weighted_exp_tile_into(
                &s_dtout[..b * k],
                k,
                cnt_out,
                scale,
                &mut s_ct[..b * k],
            );
            ops::slice_cols_into(cw, sl.fp, f + ho, f + 2 * ho, &mut s_cwg[..k * ho]);
            ops::matmul_at_b_into(&gb.c_in, b, b, &s_gnum[..b * ho], ho, &mut s_gsl[..b * ho]);
            ops::matmul_into(&s_ct[..b * k], b, k, &s_cwg[..k * ho], ho, &mut s_mat[..b * ho]);
            ops::add_into(&mut s_gsl[..b * ho], &s_mat[..b * ho]);
            ops::matmul_a_bt_into(&s_gsl[..b * ho], b, ho, wv, f, &mut s_mat[..b * f]);
            ops::add_into(&mut dh[..b * f], &s_mat[..b * f]);
            // convolution cotangents + analytic dot-product score bwd
            ops::matmul_a_bt_into(&s_gnum[..b * ho], b, ho, wv, f, &mut s_dm[..b * f]);
            ops::matmul_a_bt_into(&s_dm[..b * f], b, f, &xfeat[l], b, &mut s_dcin[..b * b]);
            ops::matmul_a_bt_into(&s_dm[..b * f], b, f, &cw_feat[l], k, &mut s_dcout[..b * k]);
            add_den_cotangent(&mut s_dcin[..b * b], &mut s_dcout[..b * k], &s_gden[..b], b, k);
            // d(raw dot): fold the cap gate and the 1/√dk scale in
            for (idx, x) in s_dtin[..b * b].iter_mut().enumerate() {
                *x = s_dcin[idx] * gb.c_in[idx] * ops::exp_capped_grad(gb.t_in[idx]) * scale;
            }
            for (idx, x) in s_dtout[..b * k].iter_mut().enumerate() {
                *x = s_dcout[idx] * gb.c_out[idx] * ops::exp_capped_grad(gb.t_out[idx]) * scale;
            }
            ops::matmul_into(&s_dtin[..b * b], b, b, &gb.kk, dk, &mut s_dq[..b * dk]);
            ops::matmul_into(&s_dtout[..b * k], b, k, &gb.kcw, dk, &mut s_mat[..b * dk]);
            ops::add_into(&mut s_dq[..b * dk], &s_mat[..b * dk]);
            ops::matmul_at_b_into(&s_dtin[..b * b], b, b, &gb.q, dk, &mut s_dkk[..b * dk]);
            ops::matmul_at_b_into(&s_dtout[..b * k], b, k, &gb.q, dk, &mut s_dkcw[..k * dk]);
            ops::matmul_at_b_into(
                &xfeat[l],
                b,
                f,
                &s_dq[..b * dk],
                dk,
                &mut outputs[sl.g_wq.expect("plan: g_wq")].f,
            );
            ops::matmul_at_b_into(
                &xfeat[l],
                b,
                f,
                &s_dkk[..b * dk],
                dk,
                &mut outputs[sl.g_wk.expect("plan: g_wk")].f,
            );
            ops::matmul_at_b_into(&cw_feat[l], k, f, &s_dkcw[..k * dk], dk, &mut s_wtmp[..f * dk]);
            ops::add_into(&mut outputs[sl.g_wk.expect("plan: g_wk")].f, &s_wtmp[..f * dk]);
            ops::matmul_a_bt_into(&s_dq[..b * dk], b, dk, wq, f, &mut s_mat[..b * f]);
            ops::add_into(&mut dh[..b * f], &s_mat[..b * f]);
            ops::matmul_a_bt_into(&s_dkk[..b * dk], b, dk, wk, f, &mut s_mat[..b * f]);
            ops::add_into(&mut dh[..b * f], &s_mat[..b * f]);
            // linear branch
            ops::matmul_at_b_into(
                &xfeat[l],
                b,
                f,
                &g[..b * ho],
                ho,
                &mut outputs[sl.g_w_lin.expect("plan: g_w_lin")].f,
            );
            ops::matmul_a_bt_into(&g[..b * ho], b, ho, w_lin, f, &mut s_mat[..b * f]);
            ops::add_into(&mut dh[..b * f], &s_mat[..b * f]);
        }

        std::mem::swap(g, dh);
    }

    super::vq::push_assign_outputs(plan, inputs, outputs, xfeat, gvec, s_zb, s_zw, s_inv)
}
