//! Ahead-of-time compilation of an [`ArtifactSpec`] into a [`Plan`]: every
//! string-keyed input/output lookup the old per-call interpreter performed
//! (`spec.input_index(&format!("l{l}.c_in"))`, the `HashMap<String, Tensor>`
//! emit path) is resolved ONCE here into positional slot indices, and every
//! per-layer dimension the step needs is precomputed.  The hot path then
//! indexes flat arrays only.
//!
//! Compilation also front-loads the interpreter/spec drift guard the old
//! `emit()` enforced per call: a plan only compiles if every declared output
//! is claimed by exactly the computation this executor will run, so a spec
//! that drifts from the interpreter fails at `Runtime::load` time with the
//! output's name, not at step time.

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, DatasetCfg, ModelCfg};

/// Execution mode of the VQ paths.  `Train` runs the full Eq. 7 backward;
/// `Infer` is forward-only but still emits the per-layer `xfeat` residuals
/// (the inductive bootstrap consumes them); `Serve` is the read path — no
/// gradient buffers, no residual outputs, logits only (and the artifact
/// signature drops the transposed sketches, which only the backward reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Train,
    Infer,
    Serve,
}

/// Which compiled step body a plan drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    /// Fixed-convolution VQ step (GCN / SAGE-mean), Eq. 6/7.
    Vq(Mode),
    /// Learnable-convolution VQ step (GAT / Graph Transformer), App. E.
    VqAttn(Mode),
    /// Exact edge-list message passing (the sampling baselines).
    Edge { train: bool },
    /// Standalone masked assignment kernel.
    Assign,
}

/// One layer's resolved slots + dimensions.  Fields are `Option` because
/// the struct is shared by every plan family; [`Plan::compile`] resolves
/// exactly the slots its family/mode reads, so an `.expect()` at a use site
/// can only fire on an executor bug, never on caller input.
#[derive(Debug, Clone, Default)]
pub struct LayerSlots {
    // dimensions
    pub f_in: usize,
    pub h_out: usize,
    pub g_dim: usize,
    pub n_br: usize,
    pub fp: usize,
    pub cf: usize,
    pub heads: usize,
    pub hh: usize,
    pub dk: usize,
    // fixed-convolution context inputs
    pub c_in: Option<usize>,
    pub c_out: Option<usize>,
    pub ct_out: Option<usize>,
    // learnable-convolution context inputs
    pub mask_in: Option<usize>,
    pub m_out: Option<usize>,
    pub m_out_t: Option<usize>,
    pub cnt_out: Option<usize>,
    // shared VQ context inputs
    pub cw: Option<usize>,
    pub mean: Option<usize>,
    pub var: Option<usize>,
    pub cww: Option<usize>,
    // parameters
    pub w: Option<usize>,
    pub w_self: Option<usize>,
    pub w_nbr: Option<usize>,
    pub bias: Option<usize>,
    pub a_src: Option<usize>,
    pub a_dst: Option<usize>,
    pub wq: Option<usize>,
    pub wk: Option<usize>,
    pub wv: Option<usize>,
    pub w_lin: Option<usize>,
    // outputs
    pub o_xfeat: Option<usize>,
    pub o_gvec: Option<usize>,
    pub o_assign: Option<usize>,
    pub g_w: Option<usize>,
    pub g_w_self: Option<usize>,
    pub g_w_nbr: Option<usize>,
    pub g_bias: Option<usize>,
    pub g_a_src: Option<usize>,
    pub g_a_dst: Option<usize>,
    pub g_wq: Option<usize>,
    pub g_wk: Option<usize>,
    pub g_wv: Option<usize>,
    pub g_w_lin: Option<usize>,
}

/// A compiled artifact: resolved slots, per-layer dims, loss-head flags.
#[derive(Debug, Clone)]
pub struct Plan {
    pub name: String,
    pub kind: PlanKind,
    pub b: usize,
    pub k: usize,
    pub nn: usize,
    /// Logits width (classes, or the embedding dim on link tasks).
    pub c: usize,
    /// Loss-head rows: `b` on the VQ paths, `nn` on the edge paths.
    pub rows: usize,
    pub sage: bool,
    pub txf: bool,
    pub gat: bool,
    pub multilabel: bool,
    pub link: bool,
    pub layers: Vec<LayerSlots>,
    // common inputs
    pub in_x: usize,
    pub in_y: Option<usize>,
    pub in_wloss: Option<usize>,
    pub in_psrc: Option<usize>,
    pub in_pdst: Option<usize>,
    pub in_py: Option<usize>,
    pub in_pw: Option<usize>,
    pub in_esrc: Option<usize>,
    pub in_edst: Option<usize>,
    pub in_ecoef: Option<usize>,
    pub in_cww: Option<usize>,
    pub in_mask: Option<usize>,
    // common outputs
    pub o_loss: Option<usize>,
    pub o_logits: Option<usize>,
    pub o_assign_only: Option<usize>,
    /// `vq_assign` branch width (z's trailing dim).
    pub fp0: usize,
}

impl Plan {
    pub fn compile(ds: &DatasetCfg, model: &ModelCfg, spec: &ArtifactSpec) -> Result<Plan> {
        let learnable = matches!(model.name.as_str(), "gat" | "txf");
        let kind = match spec.kind.as_str() {
            "vq_train" if learnable => PlanKind::VqAttn(Mode::Train),
            "vq_infer" if learnable => PlanKind::VqAttn(Mode::Infer),
            "vq_serve" if learnable => PlanKind::VqAttn(Mode::Serve),
            "vq_train" => PlanKind::Vq(Mode::Train),
            "vq_infer" => PlanKind::Vq(Mode::Infer),
            "vq_serve" => PlanKind::Vq(Mode::Serve),
            "edge_train" => PlanKind::Edge { train: true },
            "edge_infer" => PlanKind::Edge { train: false },
            "vq_assign" => PlanKind::Assign,
            other => bail!("native: unknown artifact kind '{other}' ({})", spec.name),
        };
        let req_in = |name: &str| -> Result<usize> {
            spec.input_index(name)
                .with_context(|| format!("native {}: missing input '{name}'", spec.name))
        };
        let req_out = |name: &str| -> Result<usize> {
            spec.output_index(name)
                .with_context(|| format!("native {}: missing output '{name}'", spec.name))
        };
        let logits_c = spec
            .outputs
            .iter()
            .find(|t| t.name == "logits")
            .map(|t| t.shape[1]);

        let mut plan = Plan {
            name: spec.name.clone(),
            kind,
            b: spec.b,
            k: spec.k,
            nn: spec.nn,
            c: logits_c.unwrap_or(0),
            rows: if matches!(kind, PlanKind::Edge { .. }) { spec.nn } else { spec.b },
            sage: model.name == "sage",
            txf: model.name == "txf",
            gat: model.name == "gat",
            multilabel: ds.multilabel,
            link: ds.task == "link",
            layers: Vec::new(),
            in_x: 0,
            in_y: None,
            in_wloss: None,
            in_psrc: None,
            in_pdst: None,
            in_py: None,
            in_pw: None,
            in_esrc: None,
            in_edst: None,
            in_ecoef: None,
            in_cww: None,
            in_mask: None,
            o_loss: None,
            o_logits: None,
            o_assign_only: None,
            fp0: 0,
        };

        match kind {
            PlanKind::Assign => {
                plan.in_x = req_in("z")?;
                plan.in_cww = Some(req_in("cww")?);
                plan.in_mask = Some(req_in("mask")?);
                plan.o_assign_only = Some(req_out("assign")?);
                plan.fp0 = spec.inputs[plan.in_x].shape[2];
            }
            PlanKind::Vq(mode) | PlanKind::VqAttn(mode) => {
                plan.in_x = req_in("xb")?;
                plan.o_logits = Some(req_out("logits")?);
                let train = mode == Mode::Train;
                if train {
                    plan.o_loss = Some(req_out("loss")?);
                    if plan.link {
                        plan.in_psrc = Some(req_in("psrc")?);
                        plan.in_pdst = Some(req_in("pdst")?);
                        plan.in_py = Some(req_in("py")?);
                        plan.in_pw = Some(req_in("pw")?);
                    } else {
                        plan.in_y = Some(req_in("y")?);
                        plan.in_wloss = Some(req_in("wloss")?);
                    }
                }
                let attn = matches!(kind, PlanKind::VqAttn(_));
                for (l, p) in spec.plan.iter().enumerate() {
                    let heads = p.heads.max(1);
                    let mut sl = LayerSlots {
                        f_in: p.f_in,
                        h_out: p.h_out,
                        g_dim: p.g_dim,
                        n_br: p.n_br,
                        fp: p.fp,
                        cf: p.cf,
                        heads,
                        hh: p.h_out / heads,
                        ..LayerSlots::default()
                    };
                    if attn {
                        sl.mask_in = Some(req_in(&format!("l{l}.mask_in"))?);
                        sl.m_out = Some(req_in(&format!("l{l}.m_out"))?);
                        if train {
                            sl.m_out_t = Some(req_in(&format!("l{l}.m_out_t"))?);
                        }
                        if plan.txf {
                            sl.cnt_out = Some(req_in(&format!("l{l}.cnt_out"))?);
                            let wq = req_in(&format!("param.l{l}.wq"))?;
                            sl.dk = spec.inputs[wq].shape[1];
                            sl.wq = Some(wq);
                            sl.wk = Some(req_in(&format!("param.l{l}.wk"))?);
                            sl.wv = Some(req_in(&format!("param.l{l}.wv"))?);
                            sl.w_lin = Some(req_in(&format!("param.l{l}.w_lin"))?);
                        }
                        sl.w = Some(req_in(&format!("param.l{l}.w"))?);
                        sl.a_src = Some(req_in(&format!("param.l{l}.a_src"))?);
                        sl.a_dst = Some(req_in(&format!("param.l{l}.a_dst"))?);
                    } else {
                        sl.c_in = Some(req_in(&format!("l{l}.c_in"))?);
                        sl.c_out = Some(req_in(&format!("l{l}.c_out"))?);
                        if train {
                            sl.ct_out = Some(req_in(&format!("l{l}.ct_out"))?);
                        }
                        if plan.sage {
                            sl.w_self = Some(req_in(&format!("param.l{l}.w_self"))?);
                            sl.w_nbr = Some(req_in(&format!("param.l{l}.w_nbr"))?);
                        } else {
                            sl.w = Some(req_in(&format!("param.l{l}.w"))?);
                        }
                    }
                    sl.cw = Some(req_in(&format!("l{l}.cw"))?);
                    sl.bias = Some(req_in(&format!("param.l{l}.bias"))?);
                    if train {
                        sl.mean = Some(req_in(&format!("l{l}.mean"))?);
                        sl.var = Some(req_in(&format!("l{l}.var"))?);
                        sl.cww = Some(req_in(&format!("l{l}.cww"))?);
                        sl.o_xfeat = Some(req_out(&format!("l{l}.xfeat"))?);
                        sl.o_gvec = Some(req_out(&format!("l{l}.gvec"))?);
                        sl.o_assign = Some(req_out(&format!("l{l}.assign"))?);
                        sl.g_bias = Some(req_out(&format!("grad.l{l}.bias"))?);
                        if attn {
                            sl.g_w = Some(req_out(&format!("grad.l{l}.w"))?);
                            sl.g_a_src = Some(req_out(&format!("grad.l{l}.a_src"))?);
                            sl.g_a_dst = Some(req_out(&format!("grad.l{l}.a_dst"))?);
                            if plan.txf {
                                sl.g_wq = Some(req_out(&format!("grad.l{l}.wq"))?);
                                sl.g_wk = Some(req_out(&format!("grad.l{l}.wk"))?);
                                sl.g_wv = Some(req_out(&format!("grad.l{l}.wv"))?);
                                sl.g_w_lin = Some(req_out(&format!("grad.l{l}.w_lin"))?);
                            }
                        } else if plan.sage {
                            sl.g_w_self = Some(req_out(&format!("grad.l{l}.w_self"))?);
                            sl.g_w_nbr = Some(req_out(&format!("grad.l{l}.w_nbr"))?);
                        } else {
                            sl.g_w = Some(req_out(&format!("grad.l{l}.w"))?);
                        }
                    } else if mode == Mode::Infer {
                        sl.o_xfeat = Some(req_out(&format!("l{l}.xfeat"))?);
                    }
                    plan.layers.push(sl);
                }
            }
            PlanKind::Edge { train } => {
                plan.in_x = req_in("x")?;
                plan.in_esrc = Some(req_in("esrc")?);
                plan.in_edst = Some(req_in("edst")?);
                plan.in_ecoef = Some(req_in("ecoef")?);
                plan.o_logits = Some(req_out("logits")?);
                if train {
                    plan.o_loss = Some(req_out("loss")?);
                    if plan.link {
                        plan.in_psrc = Some(req_in("psrc")?);
                        plan.in_pdst = Some(req_in("pdst")?);
                        plan.in_py = Some(req_in("py")?);
                        plan.in_pw = Some(req_in("pw")?);
                    } else {
                        plan.in_y = Some(req_in("y")?);
                        plan.in_wloss = Some(req_in("wloss")?);
                    }
                }
                let c = logits_c.context("edge spec has no logits output")?;
                let ll = model.layers;
                for l in 0..ll {
                    let f = if l == 0 { ds.f_in_pad } else { model.hidden };
                    let last = l + 1 == ll;
                    let h = if last { c } else { model.hidden };
                    let heads = if plan.gat && !last { model.heads.max(1) } else { 1 };
                    let mut sl = LayerSlots {
                        f_in: f,
                        h_out: h,
                        heads,
                        hh: h / heads,
                        ..LayerSlots::default()
                    };
                    if plan.gat {
                        sl.w = Some(req_in(&format!("param.l{l}.w"))?);
                        sl.a_src = Some(req_in(&format!("param.l{l}.a_src"))?);
                        sl.a_dst = Some(req_in(&format!("param.l{l}.a_dst"))?);
                    } else if plan.sage {
                        sl.w_self = Some(req_in(&format!("param.l{l}.w_self"))?);
                        sl.w_nbr = Some(req_in(&format!("param.l{l}.w_nbr"))?);
                    } else {
                        sl.w = Some(req_in(&format!("param.l{l}.w"))?);
                    }
                    sl.bias = Some(req_in(&format!("param.l{l}.bias"))?);
                    if train {
                        sl.g_bias = Some(req_out(&format!("grad.l{l}.bias"))?);
                        if plan.gat {
                            sl.g_w = Some(req_out(&format!("grad.l{l}.w"))?);
                            sl.g_a_src = Some(req_out(&format!("grad.l{l}.a_src"))?);
                            sl.g_a_dst = Some(req_out(&format!("grad.l{l}.a_dst"))?);
                        } else if plan.sage {
                            sl.g_w_self = Some(req_out(&format!("grad.l{l}.w_self"))?);
                            sl.g_w_nbr = Some(req_out(&format!("grad.l{l}.w_nbr"))?);
                        } else {
                            sl.g_w = Some(req_out(&format!("grad.l{l}.w"))?);
                        }
                    }
                    plan.layers.push(sl);
                }
            }
        }

        plan.check_output_coverage(spec)?;
        Ok(plan)
    }

    /// The compile-time half of the old `emit()` drift guard: every output
    /// the spec declares must be claimed by a slot this plan writes.
    fn check_output_coverage(&self, spec: &ArtifactSpec) -> Result<()> {
        let mut claimed = vec![false; spec.outputs.len()];
        let mut claim = |i: Option<usize>| {
            if let Some(i) = i {
                claimed[i] = true;
            }
        };
        claim(self.o_loss);
        claim(self.o_logits);
        claim(self.o_assign_only);
        for sl in &self.layers {
            claim(sl.o_xfeat);
            claim(sl.o_gvec);
            claim(sl.o_assign);
            claim(sl.g_w);
            claim(sl.g_w_self);
            claim(sl.g_w_nbr);
            claim(sl.g_bias);
            claim(sl.g_a_src);
            claim(sl.g_a_dst);
            claim(sl.g_wq);
            claim(sl.g_wk);
            claim(sl.g_wv);
            claim(sl.g_w_lin);
        }
        for (i, done) in claimed.iter().enumerate() {
            if !done {
                bail!(
                    "native {}: output '{}' is not produced by the compiled plan \
                     (interpreter/spec drift)",
                    spec.name,
                    spec.outputs[i].name
                );
            }
        }
        Ok(())
    }
}
