//! Exact edge-list message passing (baseline compute path) on the
//! plan-compiled executor, with full backprop for the train variant.
//! GCN/SAGE aggregate with fixed per-edge coefficients; GAT computes
//! per-edge attention in-graph (ecoef is edge validity), mirroring
//! `python/compile/edgemp.py`.  The op sequence matches the pre-arena
//! interpreter exactly; only buffer ownership moved into [`StepArena`].

#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use anyhow::Result;

use crate::runtime::ops;
use crate::runtime::InputSlots;
use crate::util::tensor::Tensor;

use super::arena::StepArena;
use super::plan::Plan;
use super::{loss_head_into, normalize_bwd_into};

/// Edge-list scatter: `out[dst] += coef · h[src]` per edge (`transpose`
/// flips the arc, which is exactly the backward pass of the aggregation).
#[allow(clippy::too_many_arguments)]
fn scatter_edges_into(
    h: &[f32],
    f: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    transpose: bool,
    out: &mut [f32],
) {
    out.fill(0.0);
    for e in 0..esrc.len() {
        let coef = ecoef[e];
        if coef == 0.0 {
            continue; // padding edge
        }
        let (s, d) = if transpose {
            (edst[e] as usize, esrc[e] as usize)
        } else {
            (esrc[e] as usize, edst[e] as usize)
        };
        let src = &h[s * f..(s + 1) * f];
        let dst = &mut out[d * f..(d + 1) * f];
        for j in 0..f {
            dst[j] += coef * src[j];
        }
    }
}

#[allow(clippy::needless_range_loop)]
pub(super) fn run_edge(
    plan: &Plan,
    ar: &mut StepArena,
    inputs: InputSlots<'_>,
    outputs: &mut [Tensor],
    train: bool,
) -> Result<()> {
    let nn = plan.nn;
    let (sage, gat) = (plan.sage, plan.gat);
    let ll = plan.layers.len();
    let esrc = &inputs[plan.in_esrc.expect("plan: esrc")].i;
    let edst = &inputs[plan.in_edst.expect("plan: edst")].i;
    let ecoef = &inputs[plan.in_ecoef.expect("plan: ecoef")].f;
    let StepArena {
        xfeat,
        pre,
        mbuf,
        eheads,
        g,
        dh,
        s_mat,
        s_logp,
        s_go,
        s_gnum,
        s_gden,
        s_dproj,
        s_desrc,
        s_dedst,
        s_das,
        s_dad,
        s_wtmp,
        s_dagg,
        ..
    } = ar;

    // ---- forward ----
    xfeat[0].copy_from_slice(&inputs[plan.in_x].f);
    for l in 0..ll {
        let sl = &plan.layers[l];
        let (f, ho, nheads, hh) = (sl.f_in, sl.h_out, sl.heads, sl.hh);
        debug_assert_eq!(hh * nheads, ho, "heads must tile the layer width");
        let bias = &inputs[sl.bias.expect("plan: bias")].f;
        if gat {
            let w = &inputs[sl.w.expect("plan: w")].f;
            let a_src = &inputs[sl.a_src.expect("plan: a_src")].f;
            let a_dst = &inputs[sl.a_dst.expect("plan: a_dst")].f;
            for s in 0..nheads {
                let hb = &mut eheads[l][s];
                let ws = &w[s * f * hh..(s + 1) * f * hh];
                ops::matmul_into(&xfeat[l], nn, f, ws, hh, &mut hb.proj);
                ops::dot_rows_into(&hb.proj, hh, &a_src[s * hh..(s + 1) * hh], &mut hb.e_src);
                ops::dot_rows_into(&hb.proj, hh, &a_dst[s * hh..(s + 1) * hh], &mut hb.e_dst);
                // per-edge scatter, blocked over destination rows
                // (bit-identical to the serial loop — see ops tests),
                // accumulating straight into the arena's num/den buffers
                ops::edge_attn_scatter_into(
                    &hb.proj, hh, nn, esrc, edst, ecoef, &hb.e_src, &hb.e_dst, &mut hb.o,
                    &mut hb.den,
                );
                ops::attn_normalize(&mut hb.o, hh, &hb.den);
                for i in 0..nn {
                    pre[l][i * ho + s * hh..i * ho + (s + 1) * hh]
                        .copy_from_slice(&hb.o[i * hh..(i + 1) * hh]);
                }
            }
        } else {
            scatter_edges_into(&xfeat[l], f, esrc, edst, ecoef, false, &mut mbuf[l]);
            if sage {
                let w_self = &inputs[sl.w_self.expect("plan: w_self")].f;
                let w_nbr = &inputs[sl.w_nbr.expect("plan: w_nbr")].f;
                ops::matmul_into(&xfeat[l], nn, f, w_self, ho, &mut pre[l]);
                ops::matmul_into(&mbuf[l], nn, f, w_nbr, ho, &mut s_mat[..nn * ho]);
                ops::add_into(&mut pre[l], &s_mat[..nn * ho]);
            } else {
                let w = &inputs[sl.w.expect("plan: w")].f;
                ops::matmul_into(&mbuf[l], nn, f, w, ho, &mut pre[l]);
            }
        }
        ops::add_bias(&mut pre[l], ho, bias);
        if l + 1 < ll {
            ops::relu_into(&pre[l], &mut xfeat[l + 1]);
        }
    }
    let c = plan.c;
    outputs[plan.o_logits.expect("plan: logits")].f.copy_from_slice(&pre[ll - 1]);
    if !train {
        return Ok(());
    }

    let loss = loss_head_into(
        plan,
        inputs,
        &pre[ll - 1],
        nn,
        c,
        &mut g[..nn * c],
        &mut s_logp[..nn * c],
    )?;
    outputs[plan.o_loss.expect("plan: loss")].f[0] = loss;

    // ---- backward ----
    for l in (0..ll).rev() {
        let sl = &plan.layers[l];
        let (f, ho, nheads, hh) = (sl.f_in, sl.h_out, sl.heads, sl.hh);
        if l + 1 < ll {
            ops::relu_bwd(&mut g[..nn * ho], &pre[l]);
        }
        ops::col_sum_into(&g[..nn * ho], ho, &mut outputs[sl.g_bias.expect("plan: g_bias")].f);
        if gat {
            let w = &inputs[sl.w.expect("plan: w")].f;
            let a_src = &inputs[sl.a_src.expect("plan: a_src")].f;
            let a_dst = &inputs[sl.a_dst.expect("plan: a_dst")].f;
            dh[..nn * f].fill(0.0);
            outputs[sl.g_w.expect("plan: g_w")].f.fill(0.0);
            outputs[sl.g_a_src.expect("plan: g_a_src")].f.fill(0.0);
            outputs[sl.g_a_dst.expect("plan: g_a_dst")].f.fill(0.0);
            for s in 0..nheads {
                let hb = &eheads[l][s];
                let ws = &w[s * f * hh..(s + 1) * f * hh];
                let asr = &a_src[s * hh..(s + 1) * hh];
                let ads = &a_dst[s * hh..(s + 1) * hh];
                for i in 0..nn {
                    s_go[i * hh..(i + 1) * hh]
                        .copy_from_slice(&g[i * ho + s * hh..i * ho + (s + 1) * hh]);
                }
                normalize_bwd_into(
                    &s_go[..nn * hh],
                    hh,
                    &hb.den,
                    &hb.o,
                    &mut s_gnum[..nn * hh],
                    &mut s_gden[..nn],
                );
                s_dproj[..nn * hh].fill(0.0);
                s_desrc[..nn].fill(0.0);
                s_dedst[..nn].fill(0.0);
                for e in 0..esrc.len() {
                    let cf = ecoef[e];
                    if cf == 0.0 {
                        continue;
                    }
                    let (u, v) = (esrc[e] as usize, edst[e] as usize);
                    let raw = hb.e_dst[v] + hb.e_src[u];
                    let sc = cf * ops::leaky_exp(raw);
                    // num[v] += sc·proj[u]; den[v] += sc
                    let gn = &s_gnum[v * hh..(v + 1) * hh];
                    let pu = &hb.proj[u * hh..(u + 1) * hh];
                    let mut dsc = s_gden[v];
                    for t in 0..hh {
                        dsc += gn[t] * pu[t];
                    }
                    let dp = &mut s_dproj[u * hh..(u + 1) * hh];
                    for t in 0..hh {
                        dp[t] += sc * gn[t];
                    }
                    let draw = dsc * sc * ops::leaky_exp_grad(raw);
                    s_dedst[v] += draw;
                    s_desrc[u] += draw;
                }
                for i in 0..nn {
                    for t in 0..hh {
                        s_dproj[i * hh + t] += s_desrc[i] * asr[t] + s_dedst[i] * ads[t];
                    }
                }
                for t in 0..hh {
                    let mut acc_src = 0.0f32;
                    let mut acc_dst = 0.0f32;
                    for i in 0..nn {
                        acc_src += s_desrc[i] * hb.proj[i * hh + t];
                        acc_dst += s_dedst[i] * hb.proj[i * hh + t];
                    }
                    s_das[t] = acc_src;
                    s_dad[t] = acc_dst;
                }
                ops::add_into(
                    &mut outputs[sl.g_a_src.expect("plan: g_a_src")].f[s * hh..(s + 1) * hh],
                    &s_das[..hh],
                );
                ops::add_into(
                    &mut outputs[sl.g_a_dst.expect("plan: g_a_dst")].f[s * hh..(s + 1) * hh],
                    &s_dad[..hh],
                );
                ops::matmul_a_bt_into(&s_dproj[..nn * hh], nn, hh, ws, f, &mut s_mat[..nn * f]);
                ops::add_into(&mut dh[..nn * f], &s_mat[..nn * f]);
                ops::matmul_at_b_into(
                    &xfeat[l],
                    nn,
                    f,
                    &s_dproj[..nn * hh],
                    hh,
                    &mut s_wtmp[..f * hh],
                );
                ops::add_into(
                    &mut outputs[sl.g_w.expect("plan: g_w")].f[s * f * hh..(s + 1) * f * hh],
                    &s_wtmp[..f * hh],
                );
            }
        } else if sage {
            let w_self = &inputs[sl.w_self.expect("plan: w_self")].f;
            let w_nbr = &inputs[sl.w_nbr.expect("plan: w_nbr")].f;
            ops::matmul_at_b_into(
                &xfeat[l],
                nn,
                f,
                &g[..nn * ho],
                ho,
                &mut outputs[sl.g_w_self.expect("plan: g_w_self")].f,
            );
            ops::matmul_at_b_into(
                &mbuf[l],
                nn,
                f,
                &g[..nn * ho],
                ho,
                &mut outputs[sl.g_w_nbr.expect("plan: g_w_nbr")].f,
            );
            ops::matmul_a_bt_into(&g[..nn * ho], nn, ho, w_self, f, &mut dh[..nn * f]);
            ops::matmul_a_bt_into(&g[..nn * ho], nn, ho, w_nbr, f, &mut s_mat[..nn * f]);
            scatter_edges_into(&s_mat[..nn * f], f, esrc, edst, ecoef, true, &mut s_dagg[..nn * f]);
            ops::add_into(&mut dh[..nn * f], &s_dagg[..nn * f]);
        } else {
            let w = &inputs[sl.w.expect("plan: w")].f;
            ops::matmul_at_b_into(
                &mbuf[l],
                nn,
                f,
                &g[..nn * ho],
                ho,
                &mut outputs[sl.g_w.expect("plan: g_w")].f,
            );
            ops::matmul_a_bt_into(&g[..nn * ho], nn, ho, w, f, &mut s_mat[..nn * f]);
            scatter_edges_into(&s_mat[..nn * f], f, esrc, edst, ecoef, true, &mut dh[..nn * f]);
        }
        std::mem::swap(g, dh);
    }
    Ok(())
}
