//! Native CPU backend: executes the manifest's artifacts as pure-Rust
//! computations — no Python, no JAX, no HLO artifacts, no PJRT.
//!
//! It interprets the same positional input/output contract the AOT
//! artifacts expose (`runtime::builtin` reconstructs the specs), so the
//! trainers cannot tell the backends apart.  Supported today:
//!
//! - `vq_train` / `vq_infer` for the fixed-convolution backbones (GCN,
//!   SAGE-mean): Eq. 6 forward, loss head (CE / multilabel BCE / link BCE),
//!   Eq. 7 custom-VJP backward (the out-of-batch gradient messages ride the
//!   gradient half of the codewords via the transposed sketches), per-layer
//!   probe gradients, whitened FINDNEAREST via the blocked VQ kernels, and
//!   exact parameter gradients;
//! - `edge_train` / `edge_infer`: exact edge-list message passing with full
//!   autodiff (the four sampling baselines);
//! - `vq_assign`: the standalone masked assignment kernel.
//!
//! Learnable convolutions (GAT / Graph Transformer) still require the PJRT
//! backend — `compile` rejects them with a clear error.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, DatasetCfg, LayerPlan, Manifest, ModelCfg};
use crate::runtime::ops;
use crate::runtime::{Backend, Executable};
use crate::util::tensor::Tensor;
use crate::vq::kernels;

pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_model(&self, model: &str) -> bool {
        matches!(model, "gcn" | "sage")
    }

    fn compile(&mut self, man: &Manifest, spec: &ArtifactSpec) -> Result<Box<dyn Executable>> {
        let ds = man
            .datasets
            .get(&spec.dataset)
            .with_context(|| format!("native: unknown dataset '{}'", spec.dataset))?
            .clone();
        let model = man
            .models
            .get(&spec.model)
            .with_context(|| format!("native: unknown model '{}'", spec.model))?
            .clone();
        match spec.kind.as_str() {
            "vq_train" | "vq_infer" | "edge_train" | "edge_infer" => {
                if !self.supports_model(&spec.model) {
                    bail!(
                        "native backend does not implement the learnable convolution \
                         '{}' (artifact {}); build with --features pjrt and AOT \
                         artifacts to run it",
                        spec.model,
                        spec.name
                    );
                }
            }
            "vq_assign" => {}
            other => bail!("native: unknown artifact kind '{other}' ({})", spec.name),
        }
        Ok(Box::new(NativeExec { ds, model }))
    }
}

pub struct NativeExec {
    ds: DatasetCfg,
    model: ModelCfg,
}

impl Executable for NativeExec {
    fn run(&self, spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match spec.kind.as_str() {
            "vq_train" => self.run_vq(spec, inputs, true),
            "vq_infer" => self.run_vq(spec, inputs, false),
            "edge_train" => self.run_edge(spec, inputs, true),
            "edge_infer" => self.run_edge(spec, inputs, false),
            "vq_assign" => self.run_vq_assign(spec, inputs),
            other => bail!("native: unknown artifact kind '{other}'"),
        }
    }
}

fn tin<'a>(spec: &ArtifactSpec, inputs: &'a [Tensor], name: &str) -> Result<&'a Tensor> {
    let i = spec
        .input_index(name)
        .with_context(|| format!("native {}: missing input '{name}'", spec.name))?;
    Ok(&inputs[i])
}

fn fin<'a>(spec: &ArtifactSpec, inputs: &'a [Tensor], name: &str) -> Result<&'a [f32]> {
    Ok(&tin(spec, inputs, name)?.f)
}

fn iin<'a>(spec: &ArtifactSpec, inputs: &'a [Tensor], name: &str) -> Result<&'a [i32]> {
    Ok(&tin(spec, inputs, name)?.i)
}

/// Emit the computed tensors in the spec's declared output order.  Shapes
/// are enforced unconditionally: trainers index these buffers flat by the
/// declared spec shape, so any interpreter/spec drift must fail loudly
/// (the PJRT path got the same guarantee by reconstructing tensors from
/// the spec).
fn emit(spec: &ArtifactSpec, mut out: HashMap<String, Tensor>) -> Result<Vec<Tensor>> {
    let mut tensors = Vec::with_capacity(spec.outputs.len());
    for ts in &spec.outputs {
        let t = out
            .remove(&ts.name)
            .with_context(|| format!("native {}: output '{}' not computed", spec.name, ts.name))?;
        if t.shape != ts.shape {
            bail!(
                "native {}: output '{}' computed as {:?}, spec declares {:?}",
                spec.name,
                ts.name,
                t.shape,
                ts.shape
            );
        }
        tensors.push(t);
    }
    Ok(tensors)
}

/// Loss head shared by both train paths.  Returns `(loss, dloss/dlogits)`;
/// for the link task `logits` are node embeddings and the gradient is the
/// pair-loss cotangent scattered back onto them.
fn loss_head(
    ds: &DatasetCfg,
    spec: &ArtifactSpec,
    inputs: &[Tensor],
    logits: &[f32],
    rows: usize,
    c: usize,
) -> Result<(f32, Vec<f32>)> {
    let mut dlogits = vec![0.0f32; rows * c];
    if ds.task == "link" {
        let psrc = iin(spec, inputs, "psrc")?;
        let pdst = iin(spec, inputs, "pdst")?;
        let py = fin(spec, inputs, "py")?;
        let pw = fin(spec, inputs, "pw")?;
        let wsum: f32 = pw.iter().sum::<f32>().max(1.0);
        let mut loss = 0.0f64;
        for e in 0..psrc.len() {
            let (u, v) = (psrc[e] as usize, pdst[e] as usize);
            let eu = &logits[u * c..(u + 1) * c];
            let ev = &logits[v * c..(v + 1) * c];
            let mut z = 0.0f32;
            for d in 0..c {
                z += eu[d] * ev[d];
            }
            loss += (pw[e] * ops::bce_with_logits(z, py[e])) as f64;
            let dz = pw[e] * (ops::sigmoid(z) - py[e]) / wsum;
            if dz != 0.0 {
                for d in 0..c {
                    dlogits[u * c + d] += dz * ev[d];
                    dlogits[v * c + d] += dz * eu[d];
                }
            }
        }
        return Ok(((loss / wsum as f64) as f32, dlogits));
    }
    let w = fin(spec, inputs, "wloss")?;
    let wsum: f32 = w.iter().sum::<f32>().max(1.0);
    if ds.multilabel {
        let y = fin(spec, inputs, "y")?;
        let mut loss = 0.0f64;
        for i in 0..rows {
            if w[i] == 0.0 {
                // gradient rows stay zero; skip the loss term too
                continue;
            }
            let mut per = 0.0f32;
            for j in 0..c {
                let z = logits[i * c + j];
                per += ops::bce_with_logits(z, y[i * c + j]);
                dlogits[i * c + j] =
                    w[i] * (ops::sigmoid(z) - y[i * c + j]) / (c as f32 * wsum);
            }
            loss += (w[i] * per / c as f32) as f64;
        }
        Ok(((loss / wsum as f64) as f32, dlogits))
    } else {
        let y = iin(spec, inputs, "y")?;
        let logp = ops::log_softmax(logits, c);
        let mut loss = 0.0f64;
        for i in 0..rows {
            if w[i] == 0.0 {
                continue;
            }
            let yi = y[i] as usize;
            loss += (w[i] * -logp[i * c + yi]) as f64;
            for j in 0..c {
                let soft = logp[i * c + j].exp();
                let delta = if j == yi { 1.0 } else { 0.0 };
                dlogits[i * c + j] = w[i] * (soft - delta) / wsum;
            }
        }
        Ok(((loss / wsum as f64) as f32, dlogits))
    }
}

impl NativeExec {
    /// VQ-GNN train / inference step (Eq. 6/7 + Alg. 2 FINDNEAREST).
    fn run_vq(&self, spec: &ArtifactSpec, inputs: &[Tensor], train: bool) -> Result<Vec<Tensor>> {
        let plans: &[LayerPlan] = &spec.plan;
        let ll = plans.len();
        let (b, k) = (spec.b, spec.k);
        let sage = self.model.name == "sage";
        let xb = fin(spec, inputs, "xb")?;

        // ---- forward (Eq. 6): m = C_in X_B + unsketch(C̃_out, X̃)[:, :f] ----
        let mut h: Vec<f32> = xb.to_vec();
        let mut xfeat: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut mbuf: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(ll);
        for (l, p) in plans.iter().enumerate() {
            let c_in = fin(spec, inputs, &format!("l{l}.c_in"))?;
            let c_out = fin(spec, inputs, &format!("l{l}.c_out"))?;
            let cw = fin(spec, inputs, &format!("l{l}.cw"))?;
            let un = ops::unsketch(c_out, p.n_br, b, k, cw, p.fp);
            let mut m = ops::matmul(c_in, b, b, &h, p.f_in);
            for i in 0..b {
                for d in 0..p.f_in {
                    m[i * p.f_in + d] += un[i * p.cf + d];
                }
            }
            let bias = fin(spec, inputs, &format!("param.l{l}.bias"))?;
            let mut y = if sage {
                let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                let mut y = ops::matmul(&h, b, p.f_in, w_self, p.h_out);
                let ynbr = ops::matmul(&m, b, p.f_in, w_nbr, p.h_out);
                for (a, x) in y.iter_mut().zip(&ynbr) {
                    *a += x;
                }
                y
            } else {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                ops::matmul(&m, b, p.f_in, w, p.h_out)
            };
            ops::add_bias(&mut y, p.h_out, bias);
            xfeat.push(std::mem::take(&mut h));
            h = if l + 1 < ll { ops::relu(&y) } else { y.clone() };
            mbuf.push(m);
            pre.push(y);
        }
        let c = plans[ll - 1].h_out;
        let logits = h;

        let mut out: HashMap<String, Tensor> = HashMap::new();
        out.insert("logits".into(), Tensor::from_f32(&[b, c], logits.clone()));
        if !train {
            for (l, p) in plans.iter().enumerate() {
                out.insert(
                    format!("l{l}.xfeat"),
                    Tensor::from_f32(&[b, p.f_in], xfeat[l].clone()),
                );
            }
            return emit(spec, out);
        }

        let (loss, dlogits) = loss_head(&self.ds, spec, inputs, &logits, b, c)?;
        out.insert("loss".into(), Tensor::from_f32(&[], vec![loss]));

        // ---- backward (Eq. 7): same fused form with C_inᵀ and the
        // transposed out-of-batch sketches; the probe gradient at each layer
        // is exactly G_B^{l+1} ----
        let mut g = dlogits;
        let mut gvec: Vec<Vec<f32>> = vec![Vec::new(); ll];
        for l in (0..ll).rev() {
            let p = &plans[l];
            if l + 1 < ll {
                ops::relu_bwd(&mut g, &pre[l]);
            }
            gvec[l] = g.clone();
            out.insert(
                format!("grad.l{l}.bias"),
                Tensor::from_f32(&[p.h_out], ops::col_sum(&g, p.h_out)),
            );
            let c_in = fin(spec, inputs, &format!("l{l}.c_in"))?;
            let ct_out = fin(spec, inputs, &format!("l{l}.ct_out"))?;
            let cw = fin(spec, inputs, &format!("l{l}.cw"))?;
            // (C_inᵀ G_B + unsketch((C̃ᵀ)_out, G̃)) — gradient columns of the
            // concat space are [f_in, f_in + g_dim).
            let mut gsl = ops::slice_cols(
                &ops::unsketch(ct_out, p.n_br, b, k, cw, p.fp),
                p.cf,
                p.f_in,
                p.f_in + p.g_dim,
            );
            let bsk = ops::matmul_at_b(c_in, b, b, &g, p.h_out);
            for (a, x) in gsl.iter_mut().zip(&bsk) {
                *a += x;
            }
            let dx = if sage {
                let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                out.insert(
                    format!("grad.l{l}.w_self"),
                    Tensor::from_f32(
                        &[p.f_in, p.h_out],
                        ops::matmul_at_b(&xfeat[l], b, p.f_in, &g, p.h_out),
                    ),
                );
                out.insert(
                    format!("grad.l{l}.w_nbr"),
                    Tensor::from_f32(
                        &[p.f_in, p.h_out],
                        ops::matmul_at_b(&mbuf[l], b, p.f_in, &g, p.h_out),
                    ),
                );
                let mut dx = ops::matmul_a_bt(&g, b, p.h_out, w_self, p.f_in);
                let dx2 = ops::matmul_a_bt(&gsl, b, p.h_out, w_nbr, p.f_in);
                for (a, x) in dx.iter_mut().zip(&dx2) {
                    *a += x;
                }
                dx
            } else {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                out.insert(
                    format!("grad.l{l}.w"),
                    Tensor::from_f32(
                        &[p.f_in, p.h_out],
                        ops::matmul_at_b(&mbuf[l], b, p.f_in, &g, p.h_out),
                    ),
                );
                ops::matmul_a_bt(&gsl, b, p.h_out, w, p.f_in)
            };
            g = dx;
        }

        // ---- Alg. 2 FINDNEAREST on (X_B^l ‖ G_B^{l+1}), whitened against
        // the pre-update codebook stats supplied as inputs ----
        for (l, p) in plans.iter().enumerate() {
            let mean = fin(spec, inputs, &format!("l{l}.mean"))?;
            let var = fin(spec, inputs, &format!("l{l}.var"))?;
            let cww = fin(spec, inputs, &format!("l{l}.cww"))?;
            let mut assign = vec![0i32; p.n_br * b];
            let mut zb = vec![0.0f32; b * p.fp];
            for j in 0..p.n_br {
                // branch j covers concat columns [j*fp, (j+1)*fp)
                for i in 0..b {
                    for d in 0..p.fp {
                        let col = j * p.fp + d;
                        let raw = if col < p.f_in {
                            xfeat[l][i * p.f_in + col]
                        } else if col < p.f_in + p.g_dim {
                            gvec[l][i * p.g_dim + (col - p.f_in)]
                        } else {
                            0.0
                        };
                        zb[i * p.fp + d] = raw;
                    }
                }
                let inv = kernels::inv_std(&var[j * p.fp..(j + 1) * p.fp]);
                let zw = kernels::whiten(&zb, p.fp, &mean[j * p.fp..(j + 1) * p.fp], &inv);
                kernels::assign_blocked(
                    &zw,
                    p.fp,
                    p.fp,
                    &cww[j * k * p.fp..(j + 1) * k * p.fp],
                    k,
                    p.fp,
                    &mut assign[j * b..(j + 1) * b],
                );
            }
            out.insert(
                format!("l{l}.xfeat"),
                Tensor::from_f32(&[b, p.f_in], xfeat[l].clone()),
            );
            out.insert(
                format!("l{l}.gvec"),
                Tensor::from_f32(&[b, p.g_dim], gvec[l].clone()),
            );
            out.insert(format!("l{l}.assign"), Tensor::from_i32(&[p.n_br, b], assign));
        }
        emit(spec, out)
    }

    /// Exact edge-list message passing (baseline compute path), with full
    /// backprop for the train variant.
    fn run_edge(&self, spec: &ArtifactSpec, inputs: &[Tensor], train: bool) -> Result<Vec<Tensor>> {
        let (nn, _ne) = (spec.nn, spec.ne);
        let sage = self.model.name == "sage";
        let x = fin(spec, inputs, "x")?;
        let esrc = iin(spec, inputs, "esrc")?;
        let edst = iin(spec, inputs, "edst")?;
        let ecoef = fin(spec, inputs, "ecoef")?;
        let c = spec
            .outputs
            .iter()
            .find(|t| t.name == "logits")
            .context("edge spec has no logits output")?
            .shape[1];
        let ll = self.model.layers;
        // per-layer (f_in, h_out)
        let dims: Vec<(usize, usize)> = (0..ll)
            .map(|l| {
                let f = if l == 0 { self.ds.f_in_pad } else { self.model.hidden };
                let h = if l + 1 == ll { c } else { self.model.hidden };
                (f, h)
            })
            .collect();

        let mut h: Vec<f32> = x.to_vec();
        let mut xin: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut aggbuf: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(ll);
        for l in 0..ll {
            let (f, ho) = dims[l];
            let agg = scatter_edges(&h, f, nn, esrc, edst, ecoef, false);
            let bias = fin(spec, inputs, &format!("param.l{l}.bias"))?;
            let mut y = if sage {
                let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                let mut y = ops::matmul(&h, nn, f, w_self, ho);
                let ynbr = ops::matmul(&agg, nn, f, w_nbr, ho);
                for (a, v) in y.iter_mut().zip(&ynbr) {
                    *a += v;
                }
                y
            } else {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                ops::matmul(&agg, nn, f, w, ho)
            };
            ops::add_bias(&mut y, ho, bias);
            xin.push(std::mem::take(&mut h));
            h = if l + 1 < ll { ops::relu(&y) } else { y.clone() };
            aggbuf.push(agg);
            pre.push(y);
        }
        let logits = h;
        let mut out: HashMap<String, Tensor> = HashMap::new();
        out.insert("logits".into(), Tensor::from_f32(&[nn, c], logits.clone()));
        if !train {
            return emit(spec, out);
        }

        let (loss, dlogits) = loss_head(&self.ds, spec, inputs, &logits, nn, c)?;
        out.insert("loss".into(), Tensor::from_f32(&[], vec![loss]));

        let mut g = dlogits;
        for l in (0..ll).rev() {
            let (f, ho) = dims[l];
            if l + 1 < ll {
                ops::relu_bwd(&mut g, &pre[l]);
            }
            out.insert(
                format!("grad.l{l}.bias"),
                Tensor::from_f32(&[ho], ops::col_sum(&g, ho)),
            );
            let dx = if sage {
                let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                out.insert(
                    format!("grad.l{l}.w_self"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(&xin[l], nn, f, &g, ho)),
                );
                out.insert(
                    format!("grad.l{l}.w_nbr"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(&aggbuf[l], nn, f, &g, ho)),
                );
                let mut dx = ops::matmul_a_bt(&g, nn, ho, w_self, f);
                let dagg = ops::matmul_a_bt(&g, nn, ho, w_nbr, f);
                let dxa = scatter_edges(&dagg, f, nn, esrc, edst, ecoef, true);
                for (a, v) in dx.iter_mut().zip(&dxa) {
                    *a += v;
                }
                dx
            } else {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                out.insert(
                    format!("grad.l{l}.w"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(&aggbuf[l], nn, f, &g, ho)),
                );
                let dagg = ops::matmul_a_bt(&g, nn, ho, w, f);
                scatter_edges(&dagg, f, nn, esrc, edst, ecoef, true)
            };
            g = dx;
        }
        emit(spec, out)
    }

    /// Standalone masked assignment (inductive inference path).
    fn run_vq_assign(&self, spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let z = tin(spec, inputs, "z")?;
        let cww = fin(spec, inputs, "cww")?;
        let mask = fin(spec, inputs, "mask")?;
        let (nb, b, fp) = (z.shape[0], z.shape[1], z.shape[2]);
        let k = spec.k;
        let mut assign = vec![0i32; nb * b];
        for j in 0..nb {
            let mj = &mask[j * fp..(j + 1) * fp];
            let mut zm = z.f[j * b * fp..(j + 1) * b * fp].to_vec();
            for (idx, v) in zm.iter_mut().enumerate() {
                *v *= mj[idx % fp];
            }
            let mut cm = cww[j * k * fp..(j + 1) * k * fp].to_vec();
            for (idx, v) in cm.iter_mut().enumerate() {
                *v *= mj[idx % fp];
            }
            kernels::assign_blocked(&zm, fp, fp, &cm, k, fp, &mut assign[j * b..(j + 1) * b]);
        }
        let mut out = HashMap::new();
        out.insert("assign".to_string(), Tensor::from_i32(&[nb, b], assign));
        emit(spec, out)
    }
}

/// Edge-list scatter: `out[dst] += coef · h[src]` per edge (`transpose`
/// flips the arc, which is exactly the backward pass of the aggregation).
fn scatter_edges(
    h: &[f32],
    f: usize,
    nn: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    transpose: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; nn * f];
    for e in 0..esrc.len() {
        let coef = ecoef[e];
        if coef == 0.0 {
            continue; // padding edge
        }
        let (s, d) = if transpose {
            (edst[e] as usize, esrc[e] as usize)
        } else {
            (esrc[e] as usize, edst[e] as usize)
        };
        let src = &h[s * f..(s + 1) * f];
        let dst = &mut out[d * f..(d + 1) * f];
        for j in 0..f {
            dst[j] += coef * src[j];
        }
    }
    out
}
