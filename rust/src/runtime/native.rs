//! Native CPU backend: executes the manifest's artifacts as pure-Rust
//! computations — no Python, no JAX, no HLO artifacts, no PJRT.
//!
//! It interprets the same positional input/output contract the AOT
//! artifacts expose (`runtime::builtin` reconstructs the specs), so the
//! trainers cannot tell the backends apart.  Supported today:
//!
//! - `vq_train` / `vq_infer` for the fixed-convolution backbones (GCN,
//!   SAGE-mean): Eq. 6 forward, loss head (CE / multilabel BCE / link BCE),
//!   Eq. 7 custom-VJP backward (the out-of-batch gradient messages ride the
//!   gradient half of the codewords via the transposed sketches), per-layer
//!   probe gradients, whitened FINDNEAREST via the blocked VQ kernels, and
//!   exact parameter gradients;
//! - `vq_train` / `vq_infer` for the learnable convolutions (GAT edge-softmax
//!   attention, Graph-Transformer local+global attention): the decoupled
//!   row-normalization form of App. E, with the out-of-batch score blocks
//!   built from codeword projections weighted by the masked count sketches
//!   (low-rank Eq. 6), and a hand-derived VJP mirroring
//!   `python/compile/layers.py` exactly (the convolution-matrix cotangents
//!   flow through both the exact and approximated message paths; the
//!   transposed sketches carry no cotangent, matching `mp_linear`'s VJP) —
//!   pinned by `tests/gradcheck.rs` finite differences;
//! - `vq_serve`: the forward-only serving path of either family — logits
//!   only, no gradient buffers, no residual outputs, and no transposed
//!   sketches in the signature (the serving cache never builds them);
//! - `edge_train` / `edge_infer`: exact edge-list message passing with full
//!   backprop (the four sampling baselines), including per-edge GAT
//!   attention;
//! - `vq_assign`: the standalone masked assignment kernel.
//!
//! The only artifact family without a native path is the Graph Transformer's
//! edge-list form — global attention has none (see
//! `manifest::ManifestError::UnsupportedEdgeForm`).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, DatasetCfg, LayerPlan, Manifest, ModelCfg};
use crate::runtime::ops;
use crate::runtime::{Backend, Executable};
use crate::util::tensor::Tensor;
use crate::vq::kernels;

pub struct NativeBackend;

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_model(&self, model: &str) -> bool {
        matches!(model, "gcn" | "sage" | "gat" | "txf")
    }

    fn compile(&mut self, man: &Manifest, spec: &ArtifactSpec) -> Result<Box<dyn Executable>> {
        let ds = man
            .datasets
            .get(&spec.dataset)
            .with_context(|| format!("native: unknown dataset '{}'", spec.dataset))?
            .clone();
        let model = man
            .models
            .get(&spec.model)
            .with_context(|| format!("native: unknown model '{}'", spec.model))?
            .clone();
        match spec.kind.as_str() {
            "vq_train" | "vq_infer" | "vq_serve" => {
                if !self.supports_model(&spec.model) {
                    bail!("native: unknown model '{}' (artifact {})", spec.model, spec.name);
                }
            }
            "edge_train" | "edge_infer" => {
                if !matches!(spec.model.as_str(), "gcn" | "sage" | "gat") {
                    bail!(
                        "native: the '{}' backbone has no edge-list form (artifact {}): \
                         global attention touches every node pair, not an edge list",
                        spec.model,
                        spec.name
                    );
                }
            }
            "vq_assign" => {}
            other => bail!("native: unknown artifact kind '{other}' ({})", spec.name),
        }
        Ok(Box::new(NativeExec { ds, model }))
    }
}

pub struct NativeExec {
    ds: DatasetCfg,
    model: ModelCfg,
}

/// Execution mode of the VQ paths.  `Train` runs the full Eq. 7 backward;
/// `Infer` is forward-only but still emits the per-layer `xfeat` residuals
/// (the inductive bootstrap consumes them); `Serve` is the read path — no
/// gradient buffers, no residual outputs, logits only (and the artifact
/// signature drops the transposed sketches, which only the backward reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Train,
    Infer,
    Serve,
}

impl Executable for NativeExec {
    fn run(&self, spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let learnable = matches!(self.model.name.as_str(), "gat" | "txf");
        match spec.kind.as_str() {
            "vq_train" if learnable => self.run_vq_attn(spec, inputs, Mode::Train),
            "vq_infer" if learnable => self.run_vq_attn(spec, inputs, Mode::Infer),
            "vq_serve" if learnable => self.run_vq_attn(spec, inputs, Mode::Serve),
            "vq_train" => self.run_vq(spec, inputs, Mode::Train),
            "vq_infer" => self.run_vq(spec, inputs, Mode::Infer),
            "vq_serve" => self.run_vq(spec, inputs, Mode::Serve),
            "edge_train" => self.run_edge(spec, inputs, true),
            "edge_infer" => self.run_edge(spec, inputs, false),
            "vq_assign" => self.run_vq_assign(spec, inputs),
            other => bail!("native: unknown artifact kind '{other}'"),
        }
    }
}

fn tin<'a>(spec: &ArtifactSpec, inputs: &'a [Tensor], name: &str) -> Result<&'a Tensor> {
    let i = spec
        .input_index(name)
        .with_context(|| format!("native {}: missing input '{name}'", spec.name))?;
    Ok(&inputs[i])
}

fn fin<'a>(spec: &ArtifactSpec, inputs: &'a [Tensor], name: &str) -> Result<&'a [f32]> {
    Ok(&tin(spec, inputs, name)?.f)
}

fn iin<'a>(spec: &ArtifactSpec, inputs: &'a [Tensor], name: &str) -> Result<&'a [i32]> {
    Ok(&tin(spec, inputs, name)?.i)
}

/// Emit the computed tensors in the spec's declared output order.  Shapes
/// are enforced unconditionally: trainers index these buffers flat by the
/// declared spec shape, so any interpreter/spec drift must fail loudly
/// (the PJRT path got the same guarantee by reconstructing tensors from
/// the spec).
fn emit(spec: &ArtifactSpec, mut out: HashMap<String, Tensor>) -> Result<Vec<Tensor>> {
    let mut tensors = Vec::with_capacity(spec.outputs.len());
    for ts in &spec.outputs {
        let t = out
            .remove(&ts.name)
            .with_context(|| format!("native {}: output '{}' not computed", spec.name, ts.name))?;
        if t.shape != ts.shape {
            bail!(
                "native {}: output '{}' computed as {:?}, spec declares {:?}",
                spec.name,
                ts.name,
                t.shape,
                ts.shape
            );
        }
        tensors.push(t);
    }
    Ok(tensors)
}

/// Loss head shared by both train paths.  Returns `(loss, dloss/dlogits)`;
/// for the link task `logits` are node embeddings and the gradient is the
/// pair-loss cotangent scattered back onto them.
fn loss_head(
    ds: &DatasetCfg,
    spec: &ArtifactSpec,
    inputs: &[Tensor],
    logits: &[f32],
    rows: usize,
    c: usize,
) -> Result<(f32, Vec<f32>)> {
    let mut dlogits = vec![0.0f32; rows * c];
    if ds.task == "link" {
        let psrc = iin(spec, inputs, "psrc")?;
        let pdst = iin(spec, inputs, "pdst")?;
        let py = fin(spec, inputs, "py")?;
        let pw = fin(spec, inputs, "pw")?;
        let wsum: f32 = pw.iter().sum::<f32>().max(1.0);
        let mut loss = 0.0f64;
        for e in 0..psrc.len() {
            let (u, v) = (psrc[e] as usize, pdst[e] as usize);
            let eu = &logits[u * c..(u + 1) * c];
            let ev = &logits[v * c..(v + 1) * c];
            let mut z = 0.0f32;
            for d in 0..c {
                z += eu[d] * ev[d];
            }
            loss += (pw[e] * ops::bce_with_logits(z, py[e])) as f64;
            let dz = pw[e] * (ops::sigmoid(z) - py[e]) / wsum;
            if dz != 0.0 {
                for d in 0..c {
                    dlogits[u * c + d] += dz * ev[d];
                    dlogits[v * c + d] += dz * eu[d];
                }
            }
        }
        return Ok(((loss / wsum as f64) as f32, dlogits));
    }
    let w = fin(spec, inputs, "wloss")?;
    let wsum: f32 = w.iter().sum::<f32>().max(1.0);
    if ds.multilabel {
        let y = fin(spec, inputs, "y")?;
        let mut loss = 0.0f64;
        for i in 0..rows {
            if w[i] == 0.0 {
                // gradient rows stay zero; skip the loss term too
                continue;
            }
            let mut per = 0.0f32;
            for j in 0..c {
                let z = logits[i * c + j];
                per += ops::bce_with_logits(z, y[i * c + j]);
                dlogits[i * c + j] =
                    w[i] * (ops::sigmoid(z) - y[i * c + j]) / (c as f32 * wsum);
            }
            loss += (w[i] * per / c as f32) as f64;
        }
        Ok(((loss / wsum as f64) as f32, dlogits))
    } else {
        let y = iin(spec, inputs, "y")?;
        let logp = ops::log_softmax(logits, c);
        let mut loss = 0.0f64;
        for i in 0..rows {
            if w[i] == 0.0 {
                continue;
            }
            let yi = y[i] as usize;
            loss += (w[i] * -logp[i * c + yi]) as f64;
            for j in 0..c {
                let soft = logp[i * c + j].exp();
                let delta = if j == yi { 1.0 } else { 0.0 };
                dlogits[i * c + j] = w[i] * (soft - delta) / wsum;
            }
        }
        Ok(((loss / wsum as f64) as f32, dlogits))
    }
}

/// `dst += src`, elementwise.
fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (a, x) in dst.iter_mut().zip(src) {
        *a += x;
    }
}

/// Per-row dot with a fixed vector: `(rows, w) · (w,) -> (rows,)` — the
/// attention projections `e = (X W) a`.
fn dot_rows(a: &[f32], w: usize, v: &[f32]) -> Vec<f32> {
    debug_assert_eq!(v.len(), w);
    a.chunks(w).map(|row| row.iter().zip(v).map(|(x, y)| x * y).sum()).collect()
}

/// Forward residuals of one GAT attention head (VQ path).
struct HeadFwd {
    proj: Vec<f32>,    // (b, hh)  X W_s
    e_src: Vec<f32>,   // (b,)     proj · a_src
    e_dst: Vec<f32>,   // (b,)     proj · a_dst
    cproj: Vec<f32>,   // (k, hh)  X̃ W_s
    ecw_src: Vec<f32>, // (k,)     cproj · a_src
    ecw_dst: Vec<f32>, // (k,)     cproj · a_dst
    c_in: Vec<f32>,    // (b, b)   masked in-batch scores
    c_out: Vec<f32>,   // (b, k)   count-weighted out-of-batch scores
    m: Vec<f32>,       // (b, f)   approximated messages C_in X + C_out X̃
    den: Vec<f32>,     // (b,)     attention mass
    o: Vec<f32>,       // (b, hh)  normalized head output
}

/// Forward residuals of the txf global-attention branch.
struct GlobFwd {
    dk: usize,
    q: Vec<f32>,     // (b, dk)
    kk: Vec<f32>,    // (b, dk)
    kcw: Vec<f32>,   // (k, dk)  X̃ W_k
    qcw: Vec<f32>,   // (k, dk)  X̃ W_q (transposed-sketch side)
    t_in: Vec<f32>,  // (b, b)   scaled raw dots (cap-gate input)
    t_out: Vec<f32>, // (b, k)
    c_in: Vec<f32>,  // (b, b)   exp scores
    c_out: Vec<f32>, // (b, k)   cnt_out-weighted exp scores
    m: Vec<f32>,     // (b, f)
    den: Vec<f32>,   // (b,)
    o: Vec<f32>,     // (b, h)
}

struct AttnLayerFwd {
    heads: Vec<HeadFwd>,
    glob: Option<GlobFwd>,
}

/// Forward residuals of one per-edge GAT head (edge-list path).
struct EdgeHeadFwd {
    proj: Vec<f32>,  // (nn, hh)
    e_src: Vec<f32>, // (nn,)
    e_dst: Vec<f32>, // (nn,)
    den: Vec<f32>,   // (nn,)
    o: Vec<f32>,     // (nn, hh) normalized head output
}

/// Fold the attention-denominator cotangent into the score cotangents:
/// `den[i] = Σ_j c_in[i,j] + Σ_v c_out[i,v]`, so ∂ℓ/∂den broadcasts into
/// every score of row i.
fn add_den_cotangent(dc_in: &mut [f32], dc_out: &mut [f32], gden: &[f32], b: usize, k: usize) {
    debug_assert_eq!(dc_in.len(), b * b);
    debug_assert_eq!(dc_out.len(), b * k);
    for i in 0..b {
        let gd = gden[i];
        for x in dc_in[i * b..(i + 1) * b].iter_mut() {
            *x += gd;
        }
        for x in dc_out[i * k..(i + 1) * k].iter_mut() {
            *x += gd;
        }
    }
}

/// VJP of `attn_normalize`: given `go = ∂ℓ/∂(num/den_c)`, the cached mass
/// and the normalized output, return `(∂ℓ/∂num, ∂ℓ/∂den)`.  The `max(den,
/// floor)` guard gates the denominator gradient exactly like
/// `jnp.maximum` does.
fn normalize_bwd(go: &[f32], h: usize, den: &[f32], o: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let b = den.len();
    debug_assert_eq!(go.len(), b * h);
    let mut gnum = vec![0.0f32; b * h];
    let mut gden = vec![0.0f32; b];
    for i in 0..b {
        let d = den[i];
        if d > ops::DEN_FLOOR {
            let inv = 1.0 / d;
            let mut acc = 0.0f32;
            for t in 0..h {
                gnum[i * h + t] = go[i * h + t] * inv;
                acc += go[i * h + t] * o[i * h + t];
            }
            gden[i] = -acc * inv;
        } else {
            let inv = 1.0 / ops::DEN_FLOOR;
            for t in 0..h {
                gnum[i * h + t] = go[i * h + t] * inv;
            }
        }
    }
    (gnum, gden)
}

/// Alg. 2 FINDNEAREST on the concat vectors (X_B^l ‖ G_B^{l+1}), whitened
/// against the pre-update codebook stats supplied as inputs; emits the
/// per-layer `xfeat` / `gvec` / `assign` outputs shared by every vq_train
/// backbone.
fn push_assign_outputs(
    spec: &ArtifactSpec,
    inputs: &[Tensor],
    xfeat: &[Vec<f32>],
    gvec: &[Vec<f32>],
    out: &mut HashMap<String, Tensor>,
) -> Result<()> {
    let (b, k) = (spec.b, spec.k);
    for (l, p) in spec.plan.iter().enumerate() {
        let mean = fin(spec, inputs, &format!("l{l}.mean"))?;
        let var = fin(spec, inputs, &format!("l{l}.var"))?;
        let cww = fin(spec, inputs, &format!("l{l}.cww"))?;
        let mut assign = vec![0i32; p.n_br * b];
        let mut zb = vec![0.0f32; b * p.fp];
        for j in 0..p.n_br {
            // branch j covers concat columns [j*fp, (j+1)*fp)
            for i in 0..b {
                for d in 0..p.fp {
                    let col = j * p.fp + d;
                    let raw = if col < p.f_in {
                        xfeat[l][i * p.f_in + col]
                    } else if col < p.f_in + p.g_dim {
                        gvec[l][i * p.g_dim + (col - p.f_in)]
                    } else {
                        0.0
                    };
                    zb[i * p.fp + d] = raw;
                }
            }
            let inv = kernels::inv_std(&var[j * p.fp..(j + 1) * p.fp]);
            let zw = kernels::whiten(&zb, p.fp, &mean[j * p.fp..(j + 1) * p.fp], &inv);
            kernels::assign_blocked(
                &zw,
                p.fp,
                p.fp,
                &cww[j * k * p.fp..(j + 1) * k * p.fp],
                k,
                p.fp,
                &mut assign[j * b..(j + 1) * b],
            );
        }
        out.insert(format!("l{l}.xfeat"), Tensor::from_f32(&[b, p.f_in], xfeat[l].clone()));
        out.insert(format!("l{l}.gvec"), Tensor::from_f32(&[b, p.g_dim], gvec[l].clone()));
        out.insert(format!("l{l}.assign"), Tensor::from_i32(&[p.n_br, b], assign));
    }
    Ok(())
}

impl NativeExec {
    /// Fixed-convolution VQ-GNN step (Eq. 6/7 + Alg. 2 FINDNEAREST).
    fn run_vq(&self, spec: &ArtifactSpec, inputs: &[Tensor], mode: Mode) -> Result<Vec<Tensor>> {
        let train = mode == Mode::Train;
        let plans: &[LayerPlan] = &spec.plan;
        let ll = plans.len();
        let (b, k) = (spec.b, spec.k);
        let sage = self.model.name == "sage";
        let xb = fin(spec, inputs, "xb")?;

        // ---- forward (Eq. 6): m = C_in X_B + unsketch(C̃_out, X̃)[:, :f] ----
        let mut h: Vec<f32> = xb.to_vec();
        let mut xfeat: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut mbuf: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(ll);
        for (l, p) in plans.iter().enumerate() {
            let c_in = fin(spec, inputs, &format!("l{l}.c_in"))?;
            let c_out = fin(spec, inputs, &format!("l{l}.c_out"))?;
            let cw = fin(spec, inputs, &format!("l{l}.cw"))?;
            let un = ops::unsketch(c_out, p.n_br, b, k, cw, p.fp);
            let mut m = ops::matmul(c_in, b, b, &h, p.f_in);
            for i in 0..b {
                for d in 0..p.f_in {
                    m[i * p.f_in + d] += un[i * p.cf + d];
                }
            }
            let bias = fin(spec, inputs, &format!("param.l{l}.bias"))?;
            let mut y = if sage {
                let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                let mut y = ops::matmul(&h, b, p.f_in, w_self, p.h_out);
                let ynbr = ops::matmul(&m, b, p.f_in, w_nbr, p.h_out);
                for (a, x) in y.iter_mut().zip(&ynbr) {
                    *a += x;
                }
                y
            } else {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                ops::matmul(&m, b, p.f_in, w, p.h_out)
            };
            ops::add_bias(&mut y, p.h_out, bias);
            xfeat.push(std::mem::take(&mut h));
            h = if l + 1 < ll { ops::relu(&y) } else { y.clone() };
            mbuf.push(m);
            pre.push(y);
        }
        let c = plans[ll - 1].h_out;
        let logits = h;

        let mut out: HashMap<String, Tensor> = HashMap::new();
        out.insert("logits".into(), Tensor::from_f32(&[b, c], logits.clone()));
        if !train {
            if mode == Mode::Infer {
                for (l, p) in plans.iter().enumerate() {
                    out.insert(
                        format!("l{l}.xfeat"),
                        Tensor::from_f32(&[b, p.f_in], xfeat[l].clone()),
                    );
                }
            }
            return emit(spec, out);
        }

        let (loss, dlogits) = loss_head(&self.ds, spec, inputs, &logits, b, c)?;
        out.insert("loss".into(), Tensor::from_f32(&[], vec![loss]));

        // ---- backward (Eq. 7): same fused form with C_inᵀ and the
        // transposed out-of-batch sketches; the probe gradient at each layer
        // is exactly G_B^{l+1} ----
        let mut g = dlogits;
        let mut gvec: Vec<Vec<f32>> = vec![Vec::new(); ll];
        for l in (0..ll).rev() {
            let p = &plans[l];
            if l + 1 < ll {
                ops::relu_bwd(&mut g, &pre[l]);
            }
            gvec[l] = g.clone();
            out.insert(
                format!("grad.l{l}.bias"),
                Tensor::from_f32(&[p.h_out], ops::col_sum(&g, p.h_out)),
            );
            let c_in = fin(spec, inputs, &format!("l{l}.c_in"))?;
            let ct_out = fin(spec, inputs, &format!("l{l}.ct_out"))?;
            let cw = fin(spec, inputs, &format!("l{l}.cw"))?;
            // (C_inᵀ G_B + unsketch((C̃ᵀ)_out, G̃)) — gradient columns of the
            // concat space are [f_in, f_in + g_dim).
            let mut gsl = ops::slice_cols(
                &ops::unsketch(ct_out, p.n_br, b, k, cw, p.fp),
                p.cf,
                p.f_in,
                p.f_in + p.g_dim,
            );
            let bsk = ops::matmul_at_b(c_in, b, b, &g, p.h_out);
            for (a, x) in gsl.iter_mut().zip(&bsk) {
                *a += x;
            }
            let dx = if sage {
                let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                out.insert(
                    format!("grad.l{l}.w_self"),
                    Tensor::from_f32(
                        &[p.f_in, p.h_out],
                        ops::matmul_at_b(&xfeat[l], b, p.f_in, &g, p.h_out),
                    ),
                );
                out.insert(
                    format!("grad.l{l}.w_nbr"),
                    Tensor::from_f32(
                        &[p.f_in, p.h_out],
                        ops::matmul_at_b(&mbuf[l], b, p.f_in, &g, p.h_out),
                    ),
                );
                let mut dx = ops::matmul_a_bt(&g, b, p.h_out, w_self, p.f_in);
                let dx2 = ops::matmul_a_bt(&gsl, b, p.h_out, w_nbr, p.f_in);
                for (a, x) in dx.iter_mut().zip(&dx2) {
                    *a += x;
                }
                dx
            } else {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                out.insert(
                    format!("grad.l{l}.w"),
                    Tensor::from_f32(
                        &[p.f_in, p.h_out],
                        ops::matmul_at_b(&mbuf[l], b, p.f_in, &g, p.h_out),
                    ),
                );
                ops::matmul_a_bt(&gsl, b, p.h_out, w, p.f_in)
            };
            g = dx;
        }

        // ---- Alg. 2 FINDNEAREST on (X_B^l ‖ G_B^{l+1}) ----
        push_assign_outputs(spec, inputs, &xfeat, &gvec, &mut out)?;
        emit(spec, out)
    }

    /// Learnable-convolution VQ-GNN step (GAT / Graph Transformer), the
    /// decoupled row-normalization form of App. E:
    ///
    /// Per head `s` with projection W_s and attention vectors a_src/a_dst,
    /// the unnormalized score is `h(i,j) = exp(min(LeakyReLU(e_dst(i) +
    /// e_src(j)), CAP))`.  The in-batch block lives on the fixed mask
    /// 𝔠 = A + I; out-of-batch messages are merged per codeword (paper
    /// Fig. 1) with weight `M_out[i,v] · h(i, X̃_v)` — the low-rank Eq. 6
    /// form: scores against k codeword projections instead of n nodes.  The
    /// numerator is the approximated message passing `(C_in X_B + C_out X̃)
    /// W_s`; the denominator is the same attention applied to ones (plain
    /// row sums), so an isolated row stays exactly zero.
    ///
    /// The backward pass mirrors `python/compile/layers.py` `mp_linear`'s
    /// custom VJP: ∇X_B rides `C_inᵀ G + (C̃ᵀ)_out G̃` (Eq. 7 — the
    /// transposed count sketches weight the *gradient* half of the
    /// codewords), the convolution cotangents `∂ℓ/∂C_in = (G W ᵀ) X_Bᵀ` and
    /// `∂ℓ/∂C̃_out = (G Wᵀ) X̃ᵀ` flow into the attention parameters through
    /// the analytic score gradient (slope gate × cap gate), and the
    /// transposed sketches themselves carry no cotangent.  The probe
    /// gradient captured per layer is ∂ℓ/∂numerator — exactly the G̃
    /// quantity the codebook update needs under decoupled normalization.
    ///
    /// txf adds a global scaled-dot-product branch (𝔠 = all-ones, so the
    /// out-of-batch weight is just the bucket population `cnt_out[v]`) and a
    /// linear branch; its gradient concat space is 2h wide (local ‖ global).
    fn run_vq_attn(
        &self,
        spec: &ArtifactSpec,
        inputs: &[Tensor],
        mode: Mode,
    ) -> Result<Vec<Tensor>> {
        let train = mode == Mode::Train;
        let plans: &[LayerPlan] = &spec.plan;
        let ll = plans.len();
        let (b, k) = (spec.b, spec.k);
        let txf = self.model.name == "txf";
        let xb = fin(spec, inputs, "xb")?;

        // ---- forward ----
        let mut h: Vec<f32> = xb.to_vec();
        let mut xfeat: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut caches: Vec<AttnLayerFwd> = Vec::with_capacity(ll);
        for (l, p) in plans.iter().enumerate() {
            let f = p.f_in;
            let heads = p.heads.max(1);
            let hh = p.h_out / heads;
            let mask_in = fin(spec, inputs, &format!("l{l}.mask_in"))?;
            let m_out = fin(spec, inputs, &format!("l{l}.m_out"))?;
            let cw = fin(spec, inputs, &format!("l{l}.cw"))?;
            let cw_feat = ops::slice_cols(cw, p.fp, 0, f); // feature half X̃ (k, f)
            let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
            let a_src = fin(spec, inputs, &format!("param.l{l}.a_src"))?;
            let a_dst = fin(spec, inputs, &format!("param.l{l}.a_dst"))?;
            let bias = fin(spec, inputs, &format!("param.l{l}.bias"))?;

            let mut y = vec![0.0f32; b * p.h_out];
            let mut hcs = Vec::with_capacity(heads);
            for s in 0..heads {
                let ws = &w[s * f * hh..(s + 1) * f * hh];
                let asr = &a_src[s * hh..(s + 1) * hh];
                let ads = &a_dst[s * hh..(s + 1) * hh];
                let proj = ops::matmul(&h, b, f, ws, hh);
                let e_src = dot_rows(&proj, hh, asr);
                let e_dst = dot_rows(&proj, hh, ads);
                let cproj = ops::matmul(&cw_feat, k, f, ws, hh);
                let ecw_src = dot_rows(&cproj, hh, asr);
                let ecw_dst = dot_rows(&cproj, hh, ads);
                let c_in = ops::gat_score_tile(&e_dst, &e_src, mask_in);
                let c_out = ops::gat_score_tile(&e_dst, &ecw_src, m_out);
                // m = C_in X_B + C̃_out X̃ (the fused Eq. 6 kernel)
                let mut m = ops::matmul(&c_in, b, b, &h, f);
                add_into(&mut m, &ops::matmul(&c_out, b, k, &cw_feat, f));
                let mut o = ops::matmul(&m, b, f, ws, hh);
                let mut den = ops::row_sum(&c_in, b);
                add_into(&mut den, &ops::row_sum(&c_out, k));
                ops::attn_normalize(&mut o, hh, &den);
                for i in 0..b {
                    y[i * p.h_out + s * hh..i * p.h_out + (s + 1) * hh]
                        .copy_from_slice(&o[i * hh..(i + 1) * hh]);
                }
                hcs.push(HeadFwd {
                    proj,
                    e_src,
                    e_dst,
                    cproj,
                    ecw_src,
                    ecw_dst,
                    c_in,
                    c_out,
                    m,
                    den,
                    o,
                });
            }
            ops::add_bias(&mut y, p.h_out, bias);

            let glob = if txf {
                let cnt_out = fin(spec, inputs, &format!("l{l}.cnt_out"))?;
                let wq_t = tin(spec, inputs, &format!("param.l{l}.wq"))?;
                let dk = wq_t.shape[1];
                let wq = &wq_t.f;
                let wk = fin(spec, inputs, &format!("param.l{l}.wk"))?;
                let wv = fin(spec, inputs, &format!("param.l{l}.wv"))?;
                let w_lin = fin(spec, inputs, &format!("param.l{l}.w_lin"))?;
                let scale = 1.0 / (dk as f32).sqrt();
                let q = ops::matmul(&h, b, f, wq, dk);
                let kk = ops::matmul(&h, b, f, wk, dk);
                let kcw = ops::matmul(&cw_feat, k, f, wk, dk);
                let qcw = ops::matmul(&cw_feat, k, f, wq, dk);
                // global scores: 𝔠 = all-ones (App. Table 5)
                let mut t_in = ops::matmul_a_bt(&q, b, dk, &kk, b);
                for x in t_in.iter_mut() {
                    *x *= scale;
                }
                let c_in = ops::exp_capped_tile(&t_in);
                let mut t_out = ops::matmul_a_bt(&q, b, dk, &kcw, k);
                for x in t_out.iter_mut() {
                    *x *= scale;
                }
                let c_out = ops::col_weighted_exp_tile(&t_out, k, cnt_out, 1.0);
                let mut m = ops::matmul(&c_in, b, b, &h, f);
                add_into(&mut m, &ops::matmul(&c_out, b, k, &cw_feat, f));
                let mut o = ops::matmul(&m, b, f, wv, p.h_out);
                let mut den = ops::row_sum(&c_in, b);
                add_into(&mut den, &ops::row_sum(&c_out, k));
                ops::attn_normalize(&mut o, p.h_out, &den);
                add_into(&mut y, &o);
                add_into(&mut y, &ops::matmul(&h, b, f, w_lin, p.h_out));
                Some(GlobFwd { dk, q, kk, kcw, qcw, t_in, t_out, c_in, c_out, m, den, o })
            } else {
                None
            };

            xfeat.push(std::mem::take(&mut h));
            h = if l + 1 < ll { ops::relu(&y) } else { y.clone() };
            caches.push(AttnLayerFwd { heads: hcs, glob });
            pre.push(y);
        }
        let c = plans[ll - 1].h_out;
        let logits = h;

        let mut out: HashMap<String, Tensor> = HashMap::new();
        out.insert("logits".into(), Tensor::from_f32(&[b, c], logits.clone()));
        if !train {
            if mode == Mode::Infer {
                for (l, p) in plans.iter().enumerate() {
                    out.insert(
                        format!("l{l}.xfeat"),
                        Tensor::from_f32(&[b, p.f_in], xfeat[l].clone()),
                    );
                }
            }
            return emit(spec, out);
        }

        let (loss, dlogits) = loss_head(&self.ds, spec, inputs, &logits, b, c)?;
        out.insert("loss".into(), Tensor::from_f32(&[], vec![loss]));

        // ---- backward ----
        let mut g = dlogits;
        let mut gvec: Vec<Vec<f32>> = vec![Vec::new(); ll];
        for l in (0..ll).rev() {
            let p = &plans[l];
            let f = p.f_in;
            let heads = p.heads.max(1);
            let hh = p.h_out / heads;
            if l + 1 < ll {
                ops::relu_bwd(&mut g, &pre[l]);
            }
            out.insert(
                format!("grad.l{l}.bias"),
                Tensor::from_f32(&[p.h_out], ops::col_sum(&g, p.h_out)),
            );
            let xin = &xfeat[l];
            let m_out_t = fin(spec, inputs, &format!("l{l}.m_out_t"))?;
            let cw = fin(spec, inputs, &format!("l{l}.cw"))?;
            let cw_feat = ops::slice_cols(cw, p.fp, 0, f);
            let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
            let a_src = fin(spec, inputs, &format!("param.l{l}.a_src"))?;
            let a_dst = fin(spec, inputs, &format!("param.l{l}.a_dst"))?;

            let mut dh = vec![0.0f32; b * f];
            let mut gv = vec![0.0f32; b * p.g_dim];
            let mut dw = vec![0.0f32; heads * f * hh];
            let mut da_src = vec![0.0f32; heads * hh];
            let mut da_dst = vec![0.0f32; heads * hh];

            for s in 0..heads {
                let hc = &caches[l].heads[s];
                let ws = &w[s * f * hh..(s + 1) * f * hh];
                let asr = &a_src[s * hh..(s + 1) * hh];
                let ads = &a_dst[s * hh..(s + 1) * hh];
                let mut go = vec![0.0f32; b * hh];
                for i in 0..b {
                    go[i * hh..(i + 1) * hh].copy_from_slice(
                        &g[i * p.h_out + s * hh..i * p.h_out + (s + 1) * hh],
                    );
                }
                let (gnum, gden) = normalize_bwd(&go, hh, &hc.den, &hc.o);
                // probe gradient: this head's slice of the local columns
                for i in 0..b {
                    gv[i * p.g_dim + s * hh..i * p.g_dim + (s + 1) * hh]
                        .copy_from_slice(&gnum[i * hh..(i + 1) * hh]);
                }
                // ∇W through the numerator (exact given approximated m)
                add_into(
                    &mut dw[s * f * hh..(s + 1) * f * hh],
                    &ops::matmul_at_b(&hc.m, b, f, &gnum, hh),
                );
                // Eq. 7: C_inᵀ G + (C̃ᵀ)_out G̃ on this head's gradient cols
                let ct_out = ops::gat_score_tile(&hc.e_src, &hc.ecw_dst, m_out_t);
                let cw_g = ops::slice_cols(cw, p.fp, f + s * hh, f + (s + 1) * hh);
                let mut gsl = ops::matmul_at_b(&hc.c_in, b, b, &gnum, hh);
                add_into(&mut gsl, &ops::matmul(&ct_out, b, k, &cw_g, hh));
                add_into(&mut dh, &ops::matmul_a_bt(&gsl, b, hh, ws, f));
                // convolution cotangents (numerator + denominator paths)
                let dm = ops::matmul_a_bt(&gnum, b, hh, ws, f);
                let mut dc_in = ops::matmul_a_bt(&dm, b, f, xin, b);
                let mut dc_out = ops::matmul_a_bt(&dm, b, f, &cw_feat, k);
                add_den_cotangent(&mut dc_in, &mut dc_out, &gden, b, k);
                // analytic score backward (gat_scores VJP): gs = dc ⊙ score
                // ⊙ slope/cap gate; scatter onto the e projections
                let mut de_src = vec![0.0f32; b];
                let mut de_dst = vec![0.0f32; b];
                let mut decw_src = vec![0.0f32; k];
                for i in 0..b {
                    for j in 0..b {
                        let sc = hc.c_in[i * b + j];
                        if sc == 0.0 {
                            continue;
                        }
                        let gt = dc_in[i * b + j]
                            * sc
                            * ops::leaky_exp_grad(hc.e_dst[i] + hc.e_src[j]);
                        de_dst[i] += gt;
                        de_src[j] += gt;
                    }
                    for v in 0..k {
                        let sc = hc.c_out[i * k + v];
                        if sc == 0.0 {
                            continue;
                        }
                        let gt = dc_out[i * k + v]
                            * sc
                            * ops::leaky_exp_grad(hc.e_dst[i] + hc.ecw_src[v]);
                        de_dst[i] += gt;
                        decw_src[v] += gt;
                    }
                }
                // project e-gradients back: batch side and codeword side
                let mut dproj = vec![0.0f32; b * hh];
                for i in 0..b {
                    for t in 0..hh {
                        dproj[i * hh + t] = de_src[i] * asr[t] + de_dst[i] * ads[t];
                    }
                }
                let mut dcproj = vec![0.0f32; k * hh];
                for v in 0..k {
                    for t in 0..hh {
                        dcproj[v * hh + t] = decw_src[v] * asr[t];
                    }
                }
                for t in 0..hh {
                    let mut s_src = 0.0f32;
                    let mut s_dst = 0.0f32;
                    for i in 0..b {
                        s_src += de_src[i] * hc.proj[i * hh + t];
                        s_dst += de_dst[i] * hc.proj[i * hh + t];
                    }
                    for v in 0..k {
                        s_src += decw_src[v] * hc.cproj[v * hh + t];
                    }
                    da_src[s * hh + t] += s_src;
                    da_dst[s * hh + t] += s_dst;
                }
                add_into(&mut dh, &ops::matmul_a_bt(&dproj, b, hh, ws, f));
                add_into(
                    &mut dw[s * f * hh..(s + 1) * f * hh],
                    &ops::matmul_at_b(xin, b, f, &dproj, hh),
                );
                add_into(
                    &mut dw[s * f * hh..(s + 1) * f * hh],
                    &ops::matmul_at_b(&cw_feat, k, f, &dcproj, hh),
                );
            }

            if txf {
                let gc = caches[l].glob.as_ref().unwrap();
                let ho = p.h_out;
                let dk = gc.dk;
                let wq = fin(spec, inputs, &format!("param.l{l}.wq"))?;
                let wk = fin(spec, inputs, &format!("param.l{l}.wk"))?;
                let wv = fin(spec, inputs, &format!("param.l{l}.wv"))?;
                let w_lin = fin(spec, inputs, &format!("param.l{l}.w_lin"))?;
                let cnt_out = fin(spec, inputs, &format!("l{l}.cnt_out"))?;
                let scale = 1.0 / (dk as f32).sqrt();
                let (gnum, gden) = normalize_bwd(&g, ho, &gc.den, &gc.o);
                // probe gradient: global columns [h, 2h)
                for i in 0..b {
                    gv[i * p.g_dim + ho..i * p.g_dim + 2 * ho]
                        .copy_from_slice(&gnum[i * ho..(i + 1) * ho]);
                }
                out.insert(
                    format!("grad.l{l}.wv"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(&gc.m, b, f, &gnum, ho)),
                );
                // Eq. 7 on the global gradient columns [f+h, f+2h): the
                // transposed sketch is cnt_out ⊙ h(X̃, X_B)ᵀ
                let ct_out = ops::col_weighted_exp_tile(
                    &ops::matmul_a_bt(&gc.kk, b, dk, &gc.qcw, k),
                    k,
                    cnt_out,
                    scale,
                );
                let cw_g = ops::slice_cols(cw, p.fp, f + ho, f + 2 * ho);
                let mut gsl = ops::matmul_at_b(&gc.c_in, b, b, &gnum, ho);
                add_into(&mut gsl, &ops::matmul(&ct_out, b, k, &cw_g, ho));
                add_into(&mut dh, &ops::matmul_a_bt(&gsl, b, ho, wv, f));
                // convolution cotangents + analytic dot-product score bwd
                let dm = ops::matmul_a_bt(&gnum, b, ho, wv, f);
                let mut dc_in = ops::matmul_a_bt(&dm, b, f, xin, b);
                let mut dc_out = ops::matmul_a_bt(&dm, b, f, &cw_feat, k);
                add_den_cotangent(&mut dc_in, &mut dc_out, &gden, b, k);
                // d(raw dot): fold the cap gate and the 1/√dk scale in
                let mut dt_in = vec![0.0f32; b * b];
                for (idx, x) in dt_in.iter_mut().enumerate() {
                    *x = dc_in[idx]
                        * gc.c_in[idx]
                        * ops::exp_capped_grad(gc.t_in[idx])
                        * scale;
                }
                let mut dt_out = vec![0.0f32; b * k];
                for (idx, x) in dt_out.iter_mut().enumerate() {
                    *x = dc_out[idx]
                        * gc.c_out[idx]
                        * ops::exp_capped_grad(gc.t_out[idx])
                        * scale;
                }
                let mut dq = ops::matmul(&dt_in, b, b, &gc.kk, dk);
                add_into(&mut dq, &ops::matmul(&dt_out, b, k, &gc.kcw, dk));
                let dkk = ops::matmul_at_b(&dt_in, b, b, &gc.q, dk);
                let dkcw = ops::matmul_at_b(&dt_out, b, k, &gc.q, dk);
                out.insert(
                    format!("grad.l{l}.wq"),
                    Tensor::from_f32(&[f, dk], ops::matmul_at_b(xin, b, f, &dq, dk)),
                );
                let mut dwk = ops::matmul_at_b(xin, b, f, &dkk, dk);
                add_into(&mut dwk, &ops::matmul_at_b(&cw_feat, k, f, &dkcw, dk));
                out.insert(format!("grad.l{l}.wk"), Tensor::from_f32(&[f, dk], dwk));
                add_into(&mut dh, &ops::matmul_a_bt(&dq, b, dk, wq, f));
                add_into(&mut dh, &ops::matmul_a_bt(&dkk, b, dk, wk, f));
                // linear branch
                out.insert(
                    format!("grad.l{l}.w_lin"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(xin, b, f, &g, ho)),
                );
                add_into(&mut dh, &ops::matmul_a_bt(&g, b, ho, w_lin, f));
            }

            out.insert(
                format!("grad.l{l}.w"),
                Tensor::from_f32(&[heads, f, hh], dw),
            );
            out.insert(format!("grad.l{l}.a_src"), Tensor::from_f32(&[heads, hh], da_src));
            out.insert(format!("grad.l{l}.a_dst"), Tensor::from_f32(&[heads, hh], da_dst));
            gvec[l] = gv;
            g = dh;
        }

        push_assign_outputs(spec, inputs, &xfeat, &gvec, &mut out)?;
        emit(spec, out)
    }

    /// Exact edge-list message passing (baseline compute path), with full
    /// backprop for the train variant.  GCN/SAGE aggregate with fixed
    /// per-edge coefficients; GAT computes per-edge attention in-graph
    /// (ecoef is edge validity), mirroring `python/compile/edgemp.py`.
    fn run_edge(&self, spec: &ArtifactSpec, inputs: &[Tensor], train: bool) -> Result<Vec<Tensor>> {
        let (nn, _ne) = (spec.nn, spec.ne);
        let sage = self.model.name == "sage";
        let gat = self.model.name == "gat";
        let x = fin(spec, inputs, "x")?;
        let esrc = iin(spec, inputs, "esrc")?;
        let edst = iin(spec, inputs, "edst")?;
        let ecoef = fin(spec, inputs, "ecoef")?;
        let c = spec
            .outputs
            .iter()
            .find(|t| t.name == "logits")
            .context("edge spec has no logits output")?
            .shape[1];
        let ll = self.model.layers;
        // per-layer (f_in, h_out, heads)
        let dims: Vec<(usize, usize, usize)> = (0..ll)
            .map(|l| {
                let f = if l == 0 { self.ds.f_in_pad } else { self.model.hidden };
                let last = l + 1 == ll;
                let h = if last { c } else { self.model.hidden };
                let heads = if gat && !last { self.model.heads.max(1) } else { 1 };
                (f, h, heads)
            })
            .collect();

        let mut h: Vec<f32> = x.to_vec();
        let mut xin: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut aggbuf: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut pre: Vec<Vec<f32>> = Vec::with_capacity(ll);
        let mut attn: Vec<Vec<EdgeHeadFwd>> = Vec::with_capacity(ll);
        for l in 0..ll {
            let (f, ho, heads) = dims[l];
            let bias = fin(spec, inputs, &format!("param.l{l}.bias"))?;
            let mut y;
            let mut agg = Vec::new();
            let mut hcs = Vec::new();
            if gat {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                let a_src = fin(spec, inputs, &format!("param.l{l}.a_src"))?;
                let a_dst = fin(spec, inputs, &format!("param.l{l}.a_dst"))?;
                let hh = ho / heads;
                y = vec![0.0f32; nn * ho];
                for s in 0..heads {
                    let ws = &w[s * f * hh..(s + 1) * f * hh];
                    let proj = ops::matmul(&h, nn, f, ws, hh);
                    let e_src = dot_rows(&proj, hh, &a_src[s * hh..(s + 1) * hh]);
                    let e_dst = dot_rows(&proj, hh, &a_dst[s * hh..(s + 1) * hh]);
                    // per-edge scatter, blocked over destination rows
                    // (bit-identical to the serial loop — see ops tests)
                    let (num, den) = ops::edge_attn_scatter(
                        &proj, hh, nn, esrc, edst, ecoef, &e_src, &e_dst,
                    );
                    let mut o = num;
                    ops::attn_normalize(&mut o, hh, &den);
                    for i in 0..nn {
                        y[i * ho + s * hh..i * ho + (s + 1) * hh]
                            .copy_from_slice(&o[i * hh..(i + 1) * hh]);
                    }
                    hcs.push(EdgeHeadFwd { proj, e_src, e_dst, den, o });
                }
            } else {
                agg = scatter_edges(&h, f, nn, esrc, edst, ecoef, false);
                y = if sage {
                    let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                    let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                    let mut y = ops::matmul(&h, nn, f, w_self, ho);
                    let ynbr = ops::matmul(&agg, nn, f, w_nbr, ho);
                    for (a, v) in y.iter_mut().zip(&ynbr) {
                        *a += v;
                    }
                    y
                } else {
                    let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                    ops::matmul(&agg, nn, f, w, ho)
                };
            }
            ops::add_bias(&mut y, ho, bias);
            xin.push(std::mem::take(&mut h));
            h = if l + 1 < ll { ops::relu(&y) } else { y.clone() };
            aggbuf.push(agg);
            attn.push(hcs);
            pre.push(y);
        }
        let logits = h;
        let mut out: HashMap<String, Tensor> = HashMap::new();
        out.insert("logits".into(), Tensor::from_f32(&[nn, c], logits.clone()));
        if !train {
            return emit(spec, out);
        }

        let (loss, dlogits) = loss_head(&self.ds, spec, inputs, &logits, nn, c)?;
        out.insert("loss".into(), Tensor::from_f32(&[], vec![loss]));

        let mut g = dlogits;
        for l in (0..ll).rev() {
            let (f, ho, heads) = dims[l];
            if l + 1 < ll {
                ops::relu_bwd(&mut g, &pre[l]);
            }
            out.insert(
                format!("grad.l{l}.bias"),
                Tensor::from_f32(&[ho], ops::col_sum(&g, ho)),
            );
            let dx = if gat {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                let a_src = fin(spec, inputs, &format!("param.l{l}.a_src"))?;
                let a_dst = fin(spec, inputs, &format!("param.l{l}.a_dst"))?;
                let hh = ho / heads;
                let mut dh = vec![0.0f32; nn * f];
                let mut dw = vec![0.0f32; heads * f * hh];
                let mut da_src = vec![0.0f32; heads * hh];
                let mut da_dst = vec![0.0f32; heads * hh];
                for s in 0..heads {
                    let hc = &attn[l][s];
                    let ws = &w[s * f * hh..(s + 1) * f * hh];
                    let asr = &a_src[s * hh..(s + 1) * hh];
                    let ads = &a_dst[s * hh..(s + 1) * hh];
                    let mut go = vec![0.0f32; nn * hh];
                    for i in 0..nn {
                        go[i * hh..(i + 1) * hh]
                            .copy_from_slice(&g[i * ho + s * hh..i * ho + (s + 1) * hh]);
                    }
                    let (gnum, gden) = normalize_bwd(&go, hh, &hc.den, &hc.o);
                    let mut dproj = vec![0.0f32; nn * hh];
                    let mut de_src = vec![0.0f32; nn];
                    let mut de_dst = vec![0.0f32; nn];
                    for e in 0..esrc.len() {
                        let cf = ecoef[e];
                        if cf == 0.0 {
                            continue;
                        }
                        let (u, v) = (esrc[e] as usize, edst[e] as usize);
                        let raw = hc.e_dst[v] + hc.e_src[u];
                        let sc = cf * ops::leaky_exp(raw);
                        // num[v] += sc·proj[u]; den[v] += sc
                        let gn = &gnum[v * hh..(v + 1) * hh];
                        let pu = &hc.proj[u * hh..(u + 1) * hh];
                        let mut dsc = gden[v];
                        for t in 0..hh {
                            dsc += gn[t] * pu[t];
                        }
                        let dp = &mut dproj[u * hh..(u + 1) * hh];
                        for t in 0..hh {
                            dp[t] += sc * gn[t];
                        }
                        let draw = dsc * sc * ops::leaky_exp_grad(raw);
                        de_dst[v] += draw;
                        de_src[u] += draw;
                    }
                    for i in 0..nn {
                        for t in 0..hh {
                            dproj[i * hh + t] += de_src[i] * asr[t] + de_dst[i] * ads[t];
                        }
                    }
                    for t in 0..hh {
                        let mut s_src = 0.0f32;
                        let mut s_dst = 0.0f32;
                        for i in 0..nn {
                            s_src += de_src[i] * hc.proj[i * hh + t];
                            s_dst += de_dst[i] * hc.proj[i * hh + t];
                        }
                        da_src[s * hh + t] += s_src;
                        da_dst[s * hh + t] += s_dst;
                    }
                    add_into(&mut dh, &ops::matmul_a_bt(&dproj, nn, hh, ws, f));
                    add_into(
                        &mut dw[s * f * hh..(s + 1) * f * hh],
                        &ops::matmul_at_b(&xin[l], nn, f, &dproj, hh),
                    );
                }
                out.insert(format!("grad.l{l}.w"), Tensor::from_f32(&[heads, f, hh], dw));
                out.insert(
                    format!("grad.l{l}.a_src"),
                    Tensor::from_f32(&[heads, hh], da_src),
                );
                out.insert(
                    format!("grad.l{l}.a_dst"),
                    Tensor::from_f32(&[heads, hh], da_dst),
                );
                dh
            } else if sage {
                let w_self = fin(spec, inputs, &format!("param.l{l}.w_self"))?;
                let w_nbr = fin(spec, inputs, &format!("param.l{l}.w_nbr"))?;
                out.insert(
                    format!("grad.l{l}.w_self"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(&xin[l], nn, f, &g, ho)),
                );
                out.insert(
                    format!("grad.l{l}.w_nbr"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(&aggbuf[l], nn, f, &g, ho)),
                );
                let mut dx = ops::matmul_a_bt(&g, nn, ho, w_self, f);
                let dagg = ops::matmul_a_bt(&g, nn, ho, w_nbr, f);
                let dxa = scatter_edges(&dagg, f, nn, esrc, edst, ecoef, true);
                for (a, v) in dx.iter_mut().zip(&dxa) {
                    *a += v;
                }
                dx
            } else {
                let w = fin(spec, inputs, &format!("param.l{l}.w"))?;
                out.insert(
                    format!("grad.l{l}.w"),
                    Tensor::from_f32(&[f, ho], ops::matmul_at_b(&aggbuf[l], nn, f, &g, ho)),
                );
                let dagg = ops::matmul_a_bt(&g, nn, ho, w, f);
                scatter_edges(&dagg, f, nn, esrc, edst, ecoef, true)
            };
            g = dx;
        }
        emit(spec, out)
    }

    /// Standalone masked assignment (inductive inference path).
    fn run_vq_assign(&self, spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let z = tin(spec, inputs, "z")?;
        let cww = fin(spec, inputs, "cww")?;
        let mask = fin(spec, inputs, "mask")?;
        let (nb, b, fp) = (z.shape[0], z.shape[1], z.shape[2]);
        let k = spec.k;
        let mut assign = vec![0i32; nb * b];
        for j in 0..nb {
            let mj = &mask[j * fp..(j + 1) * fp];
            let mut zm = z.f[j * b * fp..(j + 1) * b * fp].to_vec();
            for (idx, v) in zm.iter_mut().enumerate() {
                *v *= mj[idx % fp];
            }
            let mut cm = cww[j * k * fp..(j + 1) * k * fp].to_vec();
            for (idx, v) in cm.iter_mut().enumerate() {
                *v *= mj[idx % fp];
            }
            kernels::assign_blocked(&zm, fp, fp, &cm, k, fp, &mut assign[j * b..(j + 1) * b]);
        }
        let mut out = HashMap::new();
        out.insert("assign".to_string(), Tensor::from_i32(&[nb, b], assign));
        emit(spec, out)
    }
}

/// Edge-list scatter: `out[dst] += coef · h[src]` per edge (`transpose`
/// flips the arc, which is exactly the backward pass of the aggregation).
fn scatter_edges(
    h: &[f32],
    f: usize,
    nn: usize,
    esrc: &[i32],
    edst: &[i32],
    ecoef: &[f32],
    transpose: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; nn * f];
    for e in 0..esrc.len() {
        let coef = ecoef[e];
        if coef == 0.0 {
            continue; // padding edge
        }
        let (s, d) = if transpose {
            (edst[e] as usize, esrc[e] as usize)
        } else {
            (esrc[e] as usize, edst[e] as usize)
        };
        let src = &h[s * f..(s + 1) * f];
        let dst = &mut out[d * f..(d + 1) * f];
        for j in 0..f {
            dst[j] += coef * src[j];
        }
    }
    out
}
