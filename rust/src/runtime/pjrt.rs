//! PJRT backend: load AOT-compiled HLO text artifacts and execute them via
//! the `xla` bindings (the original seed execution path, now behind the
//! [`crate::runtime::Backend`] trait and the `pjrt` cargo feature).
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile` →
//! `execute`, with outputs arriving as a single tuple literal
//! (`return_tuple=True` at lowering time).
//!
//! Note: the workspace ships an in-tree `xla` stub so this module always
//! compiles; executing for real requires patching in an actual xla-rs build
//! (see rust/vendor/README.md).

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::{Backend, Executable};
use crate::util::tensor::{DType, Tensor};

pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&mut self, man: &Manifest, spec: &ArtifactSpec) -> Result<Box<dyn Executable>> {
        let path = man.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", spec.name))?;
        Ok(Box::new(PjrtExec { exe }))
    }
}

pub struct PjrtExec {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable for PjrtExec {
    fn run(&self, spec: &ArtifactSpec, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut lits = Vec::with_capacity(inputs.len());
        for t in inputs {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = match t.dtype {
                DType::F32 => xla::Literal::vec1(&t.f).reshape(&dims)?,
                DType::I32 => xla::Literal::vec1(&t.i).reshape(&dims)?,
            };
            lits.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest declares {}",
                spec.name,
                outs.len(),
                spec.outputs.len()
            );
        }
        let mut tensors = Vec::with_capacity(outs.len());
        for (lit, s) in outs.iter().zip(&spec.outputs) {
            let t = match s.dtype {
                DType::F32 => Tensor::from_f32(&s.shape, lit.to_vec::<f32>()?),
                DType::I32 => Tensor::from_i32(&s.shape, lit.to_vec::<i32>()?),
            };
            tensors.push(t);
        }
        Ok(tensors)
    }
}
