//! Task metrics: accuracy (single-label), micro-F1 (multilabel, threshold
//! 0 on logits), and Hits@50 (link prediction) — matching the paper's
//! evaluation protocols per benchmark (Table 4 footnotes).

/// Single-label accuracy over the selected rows.
pub fn accuracy(logits: &[f32], n_classes: usize, labels: &[i32], rows: &[usize]) -> f64 {
    if rows.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for &r in rows {
        let row = &logits[r * n_classes..(r + 1) * n_classes];
        let mut arg = 0usize;
        for c in 1..n_classes {
            if row[c] > row[arg] {
                arg = c;
            }
        }
        if arg as i32 == labels[r] {
            correct += 1;
        }
    }
    correct as f64 / rows.len() as f64
}

/// Micro-averaged F1 for multilabel targets (PPI protocol): predictions are
/// sigmoid(logit) > 0.5, i.e. logit > 0.
pub fn micro_f1(logits: &[f32], n_classes: usize, targets: &[f32], rows: &[usize]) -> f64 {
    let (mut tp, mut fp, mut fne) = (0usize, 0usize, 0usize);
    for &r in rows {
        for c in 0..n_classes {
            let pred = logits[r * n_classes + c] > 0.0;
            let truth = targets[r * n_classes + c] > 0.5;
            match (pred, truth) {
                (true, true) => tp += 1,
                (true, false) => fp += 1,
                (false, true) => fne += 1,
                _ => {}
            }
        }
    }
    if tp == 0 {
        return 0.0;
    }
    let p = tp as f64 / (tp + fp) as f64;
    let r = tp as f64 / (tp + fne) as f64;
    2.0 * p * r / (p + r)
}

/// Hits@K (ogbl-collab protocol): the fraction of positive pairs scoring
/// strictly above the K-th highest negative score.
pub fn hits_at_k(pos_scores: &[f32], neg_scores: &[f32], k: usize) -> f64 {
    if pos_scores.is_empty() || neg_scores.len() < k {
        return 0.0;
    }
    let mut neg = neg_scores.to_vec();
    neg.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let threshold = neg[k - 1];
    let hits = pos_scores.iter().filter(|&&s| s > threshold).count();
    hits as f64 / pos_scores.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let logits = vec![1.0, 2.0, /* row0 -> 1 */ 5.0, 0.0 /* row1 -> 0 */];
        let acc = accuracy(&logits, 2, &[1, 1], &[0, 1]);
        assert!((acc - 0.5).abs() < 1e-9);
    }

    #[test]
    fn f1_perfect_and_empty() {
        let logits = vec![1.0, -1.0, -1.0, 1.0];
        let tgt = vec![1.0, 0.0, 0.0, 1.0];
        assert!((micro_f1(&logits, 2, &tgt, &[0, 1]) - 1.0).abs() < 1e-9);
        let tgt0 = vec![0.0, 1.0, 1.0, 0.0];
        assert_eq!(micro_f1(&logits, 2, &tgt0, &[0, 1]), 0.0);
    }

    #[test]
    fn hits_at_k_threshold_semantics() {
        let neg: Vec<f32> = (0..100).map(|i| i as f32).collect(); // max 99
        // K=50 → threshold is the 50th highest = 50.0
        let pos = vec![51.0, 49.0, 99.5];
        let h = hits_at_k(&pos, &neg, 50);
        assert!((h - 2.0 / 3.0).abs() < 1e-9);
    }
}
