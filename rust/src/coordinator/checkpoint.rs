//! Checkpointing: serialize/restore model parameters and the full VQ state
//! (codebooks, EMA statistics, assignment tables) so long runs survive
//! restarts and trained models can be shipped to inference-only processes.
//!
//! Format: little-endian binary, versioned header, length-prefixed named
//! f32/u32 sections (no serde offline — DESIGN.md §7).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::tensor::Tensor;
use crate::vq::VqModel;

const MAGIC: u32 = 0x56_51_47_31; // "VQG1"

/// Serving-artifact magic: a *frozen* model for the read path — parameters
/// + raw codewords + assignment tables, without the training-only EMA
/// state (cluster counts/sums, whitening stats, optimizer moments).
const SERVE_MAGIC: u32 = 0x56_51_53_31; // "VQS1"

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u32(&mut self, x: u32) -> Result<()> {
        self.w.write_all(&x.to_le_bytes())?;
        Ok(())
    }

    fn f32s(&mut self, xs: &[f32]) -> Result<()> {
        self.u32(xs.len() as u32)?;
        for &x in xs {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    fn u32s(&mut self, xs: &[u32]) -> Result<()> {
        self.u32(xs.len() as u32)?;
        for &x in xs {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut out = vec![0.0f32; n];
        let mut b = [0u8; 4];
        for x in out.iter_mut() {
            self.r.read_exact(&mut b)?;
            *x = f32::from_le_bytes(b);
        }
        Ok(out)
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let mut out = vec![0u32; n];
        let mut b = [0u8; 4];
        for x in out.iter_mut() {
            self.r.read_exact(&mut b)?;
            *x = u32::from_le_bytes(b);
        }
        Ok(out)
    }
}

/// Persist parameters + VQ state.  The artifact name is stored so a loader
/// can refuse a shape-incompatible restore early.
pub fn save(path: &Path, artifact: &str, params: &[Tensor], vq: &VqModel) -> Result<()> {
    let f = std::fs::File::create(path).context("create checkpoint")?;
    let mut w = Writer { w: std::io::BufWriter::new(f) };
    w.u32(MAGIC)?;
    w.u32(artifact.len() as u32)?;
    w.w.write_all(artifact.as_bytes())?;
    w.u32(params.len() as u32)?;
    for p in params {
        w.u32(p.shape.len() as u32)?;
        for &d in &p.shape {
            w.u32(d as u32)?;
        }
        w.f32s(&p.f)?;
    }
    w.u32(vq.layers.len() as u32)?;
    for layer in &vq.layers {
        w.u32(layer.k as u32)?;
        w.u32(layer.n as u32)?;
        w.u32(layer.branches.len() as u32)?;
        for br in &layer.branches {
            w.f32s(&br.cww)?;
            w.f32s(&br.counts)?;
            w.f32s(&br.sums)?;
            w.f32s(&br.mean)?;
            w.f32s(&br.var)?;
        }
        w.u32s(&layer.assign)?;
    }
    Ok(())
}

/// Restore into existing (shape-matched) params + VQ state.
pub fn load(path: &Path, artifact: &str, params: &mut [Tensor], vq: &mut VqModel) -> Result<()> {
    let f = std::fs::File::open(path).context("open checkpoint")?;
    let mut r = Reader { r: std::io::BufReader::new(f) };
    if r.u32()? != MAGIC {
        bail!("not a vq-gnn checkpoint");
    }
    let alen = r.u32()? as usize;
    let mut aname = vec![0u8; alen];
    r.r.read_exact(&mut aname)?;
    let aname = String::from_utf8(aname)?;
    if aname != artifact {
        bail!("checkpoint is for artifact '{aname}', expected '{artifact}'");
    }
    let np = r.u32()? as usize;
    if np != params.len() {
        bail!("checkpoint has {np} params, model has {}", params.len());
    }
    for p in params.iter_mut() {
        let rank = r.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        if shape != p.shape {
            bail!("param shape mismatch: {:?} vs {:?}", shape, p.shape);
        }
        p.f = r.f32s()?;
        if p.f.len() != p.numel() {
            bail!("param payload mismatch");
        }
    }
    let nl = r.u32()? as usize;
    if nl != vq.layers.len() {
        bail!("layer count mismatch");
    }
    for layer in vq.layers.iter_mut() {
        let k = r.u32()? as usize;
        let n = r.u32()? as usize;
        let nb = r.u32()? as usize;
        if k != layer.k || n != layer.n || nb != layer.branches.len() {
            bail!("vq layer shape mismatch");
        }
        for br in layer.branches.iter_mut() {
            br.cww = r.f32s()?;
            br.counts = r.f32s()?;
            br.sums = r.f32s()?;
            br.mean = r.f32s()?;
            br.var = r.f32s()?;
            if br.cww.len() != br.k * br.fp || br.mean.len() != br.fp {
                bail!("vq branch payload mismatch");
            }
        }
        layer.assign = r.u32s()?;
        if layer.assign.len() != nb * n {
            bail!("assignment table mismatch");
        }
    }
    Ok(())
}

/// One frozen layer of a serving artifact: the paper's compact global
/// context — raw codewords `(n_br, k, fp)` plus the node→codeword table
/// `(n_br, n)`.  Exactly what the forward-only `vq_serve` path consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingLayer {
    pub k: usize,
    pub n: usize,
    pub n_br: usize,
    pub fp: usize,
    /// Raw-space codewords, row-major (n_br, k, fp).
    pub cw: Vec<f32>,
    /// Assignment table R, row-major (n_br, n).
    pub assign: Vec<u32>,
}

/// Export a frozen model into a serving artifact.  `artifact` is the
/// `vq_serve_*` artifact name the file is valid for (refused on mismatch
/// at load, like the training checkpoint).
pub fn save_serving(
    path: &Path,
    artifact: &str,
    params: &[Tensor],
    layers: &[ServingLayer],
) -> Result<()> {
    let f = std::fs::File::create(path).context("create serving artifact")?;
    let mut w = Writer { w: std::io::BufWriter::new(f) };
    w.u32(SERVE_MAGIC)?;
    w.u32(artifact.len() as u32)?;
    w.w.write_all(artifact.as_bytes())?;
    w.u32(params.len() as u32)?;
    for p in params {
        w.u32(p.shape.len() as u32)?;
        for &d in &p.shape {
            w.u32(d as u32)?;
        }
        w.f32s(&p.f)?;
    }
    w.u32(layers.len() as u32)?;
    for l in layers {
        w.u32(l.k as u32)?;
        w.u32(l.n as u32)?;
        w.u32(l.n_br as u32)?;
        w.u32(l.fp as u32)?;
        w.f32s(&l.cw)?;
        w.u32s(&l.assign)?;
    }
    Ok(())
}

/// Load a serving artifact; shape validation against the serve spec is the
/// caller's job (`serve::ServingModel::load` checks against the manifest).
pub fn load_serving(path: &Path, artifact: &str) -> Result<(Vec<Tensor>, Vec<ServingLayer>)> {
    let f = std::fs::File::open(path).context("open serving artifact")?;
    let mut r = Reader { r: std::io::BufReader::new(f) };
    if r.u32()? != SERVE_MAGIC {
        bail!("not a vq-gnn serving artifact");
    }
    let alen = r.u32()? as usize;
    let mut aname = vec![0u8; alen];
    r.r.read_exact(&mut aname)?;
    let aname = String::from_utf8(aname)?;
    if aname != artifact {
        bail!("serving artifact is for '{aname}', expected '{artifact}'");
    }
    let np = r.u32()? as usize;
    let mut params = Vec::with_capacity(np);
    for _ in 0..np {
        let rank = r.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        let data = r.f32s()?;
        if data.len() != shape.iter().product::<usize>() {
            bail!("serving param payload mismatch");
        }
        params.push(Tensor::from_f32(&shape, data));
    }
    let nl = r.u32()? as usize;
    let mut layers = Vec::with_capacity(nl);
    for _ in 0..nl {
        let k = r.u32()? as usize;
        let n = r.u32()? as usize;
        let n_br = r.u32()? as usize;
        let fp = r.u32()? as usize;
        let cw = r.f32s()?;
        let assign = r.u32s()?;
        if cw.len() != n_br * k * fp || assign.len() != n_br * n {
            bail!("serving layer payload mismatch");
        }
        if assign.iter().any(|&a| a as usize >= k) {
            bail!("serving assignment out of codebook range");
        }
        layers.push(ServingLayer { k, n, n_br, fp, cw, assign });
    }
    Ok((params, layers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayerPlan;
    use crate::util::rng::Rng;

    fn mk_vq(seed: u64) -> VqModel {
        let plan = LayerPlan { f_in: 8, h_out: 4, g_dim: 4, n_br: 2, fp: 6, cf: 12, heads: 1 };
        VqModel::init(&[plan.clone(), plan], 5, 30, seed)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let mut rng = Rng::new(1);
        let params = vec![
            Tensor::from_f32(&[3, 4], (0..12).map(|_| rng.gauss_f32()).collect()),
            Tensor::from_f32(&[4], vec![1.0, 2.0, 3.0, 4.0]),
        ];
        let vq = mk_vq(7);
        save(&path, "vq_train_x", &params, &vq).unwrap();

        let mut params2 = vec![Tensor::zeros(&[3, 4]), Tensor::zeros(&[4])];
        let mut vq2 = mk_vq(99); // different init, will be overwritten
        load(&path, "vq_train_x", &mut params2, &mut vq2).unwrap();
        assert_eq!(params[0].f, params2[0].f);
        assert_eq!(params[1].f, params2[1].f);
        for (a, b) in vq.layers.iter().zip(&vq2.layers) {
            assert_eq!(a.assign, b.assign);
            for (x, y) in a.branches.iter().zip(&b.branches) {
                assert_eq!(x.cww, y.cww);
                assert_eq!(x.counts, y.counts);
                assert_eq!(x.mean, y.mean);
            }
        }
    }

    #[test]
    fn refuses_wrong_artifact_and_shapes() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        let params = vec![Tensor::zeros(&[2, 2])];
        let vq = mk_vq(1);
        save(&path, "art_a", &params, &vq).unwrap();

        let mut p2 = vec![Tensor::zeros(&[2, 2])];
        let mut vq2 = mk_vq(1);
        assert!(load(&path, "art_b", &mut p2, &mut vq2).is_err());
        let mut p3 = vec![Tensor::zeros(&[2, 3])];
        assert!(load(&path, "art_a", &mut p3, &mut vq2).is_err());
        assert!(load(Path::new("/nonexistent/x.ckpt"), "art_a", &mut p2, &mut vq2).is_err());
    }

    #[test]
    fn serving_roundtrip_and_validation() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.bin");
        let mut rng = Rng::new(3);
        let params = vec![Tensor::from_f32(&[2, 3], (0..6).map(|_| rng.gauss_f32()).collect())];
        let layers = vec![ServingLayer {
            k: 4,
            n: 10,
            n_br: 2,
            fp: 3,
            cw: (0..2 * 4 * 3).map(|_| rng.gauss_f32()).collect(),
            assign: (0..2 * 10).map(|_| rng.below(4) as u32).collect(),
        }];
        save_serving(&path, "vq_serve_tiny_sim_gcn", &params, &layers).unwrap();
        let (p2, l2) = load_serving(&path, "vq_serve_tiny_sim_gcn").unwrap();
        assert_eq!(p2.len(), 1);
        assert_eq!(p2[0].shape, vec![2, 3]);
        assert_eq!(p2[0].f, params[0].f);
        assert_eq!(l2, layers);
        // wrong artifact name refused
        assert!(load_serving(&path, "vq_serve_tiny_sim_gat").is_err());
        // a training checkpoint is not a serving artifact (magic mismatch)
        let tpath = dir.join("t.ckpt");
        save(&tpath, "art", &params, &mk_vq(1)).unwrap();
        assert!(load_serving(&tpath, "art").is_err());
        // out-of-range assignments are rejected
        let mut bad = layers.clone();
        bad[0].assign[0] = 99;
        let bpath = dir.join("bad.bin");
        save_serving(&bpath, "a", &params, &bad).unwrap();
        assert!(load_serving(&bpath, "a").is_err());
    }

    #[test]
    fn corrupt_file_fails_cleanly() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"garbage").unwrap();
        let mut p = vec![];
        let mut vq = mk_vq(1);
        assert!(load(&path, "x", &mut p, &mut vq).is_err());
    }
}
