//! Checkpointing: serialize/restore model parameters and the full VQ state
//! (codebooks, EMA statistics, assignment tables) so long runs survive
//! restarts and trained models can be shipped to inference-only processes.
//!
//! Format: little-endian binary, versioned header, length-prefixed named
//! f32/u32 sections (no serde offline — DESIGN.md §7).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::shard::ShardPlan;
use crate::util::tensor::Tensor;
use crate::vq::VqModel;

const MAGIC: u32 = 0x56_51_47_31; // "VQG1"

/// Optional trailing section of a training checkpoint: the node→shard
/// partition map of a sharded run ([`ShardPlan`] bounds).  Written only
/// when a plan is passed to [`save_with_shards`]; a plain "VQG1" file
/// (every pre-sharding checkpoint) simply ends before it, so old files
/// load unchanged and old loaders never see it (they stop at the VQ
/// payload).
const SHARD_MAGIC: u32 = 0x53_48_50_31; // "SHP1"

/// Legacy serving-artifact magic: parameters + raw codewords + assignment
/// tables only.  Still loadable ([`load_serving`] dispatches on the magic);
/// new exports are "VQS2".
const SERVE_MAGIC_V1: u32 = 0x56_51_53_31; // "VQS1"

/// Serving-artifact magic, version 2: a *frozen* model for the read path —
/// parameters, raw codewords, assignment tables, PLUS the per-branch
/// whitening stats (mean/var — the inductive-admission FINDNEAREST runs in
/// the same whitened space as training) and the admitted-node tables
/// (features, neighbor lists, per-layer codeword assignments), so a cold
/// node admitted in one process stays servable after save → load in
/// another.  Still no training-only EMA state (cluster counts/sums,
/// optimizer moments).  Admitted ids are DENSE (`n + slot`) — this layout
/// predates eviction.  Still loadable; new exports are "VQS3".
const SERVE_MAGIC_V2: u32 = 0x56_51_53_32; // "VQS2"

/// Serving-artifact magic, version 3: VQS2 plus the online-maintenance
/// state — per-layer codebook-drift REFERENCE histograms (the training
/// distribution's distance-to-nearest-codeword footprint, what serving
/// traffic is compared against) and the admitted block's stable-id map +
/// `next_id` watermark, so eviction's sparse monotone id space survives
/// save → load (a survivor keeps its id, an evicted id is never reissued).
const SERVE_MAGIC: u32 = 0x56_51_53_33; // "VQS3"

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u32(&mut self, x: u32) -> Result<()> {
        self.w.write_all(&x.to_le_bytes())?;
        Ok(())
    }

    fn f32s(&mut self, xs: &[f32]) -> Result<()> {
        self.u32(xs.len() as u32)?;
        for &x in xs {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    fn u32s(&mut self, xs: &[u32]) -> Result<()> {
        self.u32(xs.len() as u32)?;
        for &x in xs {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let mut out = vec![0.0f32; n];
        let mut b = [0u8; 4];
        for x in out.iter_mut() {
            self.r.read_exact(&mut b)?;
            *x = f32::from_le_bytes(b);
        }
        Ok(out)
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let mut out = vec![0u32; n];
        let mut b = [0u8; 4];
        for x in out.iter_mut() {
            self.r.read_exact(&mut b)?;
            *x = u32::from_le_bytes(b);
        }
        Ok(out)
    }
}

/// Persist parameters + VQ state.  The artifact name is stored so a loader
/// can refuse a shape-incompatible restore early.
pub fn save(path: &Path, artifact: &str, params: &[Tensor], vq: &VqModel) -> Result<()> {
    save_with_shards(path, artifact, params, vq, None)
}

/// [`save`] plus an optional node→shard partition map, appended as a
/// "SHP1" trailing section (see [`SHARD_MAGIC`]).  `None` writes a plain
/// "VQG1" file byte-identical to [`save`]'s.
pub fn save_with_shards(
    path: &Path,
    artifact: &str,
    params: &[Tensor],
    vq: &VqModel,
    plan: Option<&ShardPlan>,
) -> Result<()> {
    let f = std::fs::File::create(path).context("create checkpoint")?;
    let mut w = Writer { w: std::io::BufWriter::new(f) };
    w.u32(MAGIC)?;
    w.u32(artifact.len() as u32)?;
    w.w.write_all(artifact.as_bytes())?;
    w.u32(params.len() as u32)?;
    for p in params {
        w.u32(p.shape.len() as u32)?;
        for &d in &p.shape {
            w.u32(d as u32)?;
        }
        w.f32s(&p.f)?;
    }
    w.u32(vq.layers.len() as u32)?;
    for layer in &vq.layers {
        w.u32(layer.k as u32)?;
        w.u32(layer.n as u32)?;
        w.u32(layer.branches.len() as u32)?;
        for br in &layer.branches {
            w.f32s(&br.cww)?;
            w.f32s(&br.counts)?;
            w.f32s(&br.sums)?;
            w.f32s(&br.mean)?;
            w.f32s(&br.var)?;
        }
        w.u32s(&layer.assign)?;
    }
    if let Some(p) = plan {
        w.u32(SHARD_MAGIC)?;
        w.u32s(p.bounds())?;
    }
    Ok(())
}

/// Restore into existing (shape-matched) params + VQ state.
pub fn load(path: &Path, artifact: &str, params: &mut [Tensor], vq: &mut VqModel) -> Result<()> {
    load_with_shards(path, artifact, params, vq).map(|_| ())
}

/// [`load`] plus the optional "SHP1" partition map: `Ok(Some(plan))` when
/// the checkpoint came from a sharded run, `Ok(None)` for a plain "VQG1"
/// file (the section is strictly trailing, so its absence is EOF).
pub fn load_with_shards(
    path: &Path,
    artifact: &str,
    params: &mut [Tensor],
    vq: &mut VqModel,
) -> Result<Option<ShardPlan>> {
    let f = std::fs::File::open(path).context("open checkpoint")?;
    let mut r = Reader { r: std::io::BufReader::new(f) };
    if r.u32()? != MAGIC {
        bail!("not a vq-gnn checkpoint");
    }
    let alen = r.u32()? as usize;
    let mut aname = vec![0u8; alen];
    r.r.read_exact(&mut aname)?;
    let aname = String::from_utf8(aname)?;
    if aname != artifact {
        bail!("checkpoint is for artifact '{aname}', expected '{artifact}'");
    }
    let np = r.u32()? as usize;
    if np != params.len() {
        bail!("checkpoint has {np} params, model has {}", params.len());
    }
    for p in params.iter_mut() {
        let rank = r.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        if shape != p.shape {
            bail!("param shape mismatch: {:?} vs {:?}", shape, p.shape);
        }
        p.f = r.f32s()?;
        if p.f.len() != p.numel() {
            bail!("param payload mismatch");
        }
    }
    let nl = r.u32()? as usize;
    if nl != vq.layers.len() {
        bail!("layer count mismatch");
    }
    for layer in vq.layers.iter_mut() {
        let k = r.u32()? as usize;
        let n = r.u32()? as usize;
        let nb = r.u32()? as usize;
        if k != layer.k || n != layer.n || nb != layer.branches.len() {
            bail!("vq layer shape mismatch");
        }
        for br in layer.branches.iter_mut() {
            br.cww = r.f32s()?;
            br.counts = r.f32s()?;
            br.sums = r.f32s()?;
            br.mean = r.f32s()?;
            br.var = r.f32s()?;
            if br.cww.len() != br.k * br.fp || br.mean.len() != br.fp {
                bail!("vq branch payload mismatch");
            }
        }
        layer.assign = r.u32s()?;
        if layer.assign.len() != nb * n {
            bail!("assignment table mismatch");
        }
    }
    // optional trailing shard section: EOF here means "unsharded file"
    let mut b = [0u8; 4];
    match r.r.read_exact(&mut b) {
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        other => other.context("read checkpoint shard section")?,
    }
    if u32::from_le_bytes(b) != SHARD_MAGIC {
        bail!("unexpected trailing section in checkpoint");
    }
    let bounds = r.u32s()?;
    let plan = ShardPlan::from_bounds(bounds)
        .map_err(|e| anyhow::anyhow!("checkpoint shard map: {e}"))?;
    Ok(Some(plan))
}

/// One frozen layer of a serving artifact: the paper's compact global
/// context — raw codewords `(n_br, k, fp)`, the node→codeword table
/// `(n_br, n)`, the per-branch whitening stats the admission FINDNEAREST
/// whitens against, and the admitted-node assignment tail.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingLayer {
    pub k: usize,
    pub n: usize,
    pub n_br: usize,
    pub fp: usize,
    /// Raw-space codewords, row-major (n_br, k, fp).
    pub cw: Vec<f32>,
    /// Assignment table R, row-major (n_br, n).
    pub assign: Vec<u32>,
    /// Whitening mean, row-major (n_br, fp).  VQS1 files load as zeros
    /// (identity whitening — admission degrades to raw-space distances).
    pub mean: Vec<f32>,
    /// Whitening variance, row-major (n_br, fp).  VQS1 files load as ones.
    pub var: Vec<f32>,
    /// Admitted-node assignments, node-major (count, n_br).  Empty on VQS1.
    pub admitted_assign: Vec<u32>,
    /// Codebook-drift reference histogram bins (`serve::drift`).  Empty =
    /// no reference (VQS1/VQS2 files — the detector stays disarmed, never
    /// false-alarming on a legacy load).
    pub drift_ref: Vec<f32>,
}

/// The model-level admitted-node block of a serving artifact: padded
/// feature rows + CSR neighbor lists of every inductively-admitted node
/// (ids `n ..`).  Empty on models that never admitted anything and on
/// VQS1 files.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ServingAdmitted {
    /// Padded feature width (0 when no nodes are admitted).
    pub f_pad: usize,
    /// Row-major (count, f_pad) padded feature rows.
    pub features: Vec<f32>,
    /// CSR offsets into `nbr`, length count + 1 (first entry 0).
    pub nbr_ptr: Vec<u32>,
    /// Neighbor node ids (each a frozen id or an earlier admitted node's
    /// id: a node may only cite already-known nodes).
    pub nbr: Vec<u32>,
    /// Slot → stable id, strictly increasing (VQS3).  Empty on VQS1/VQS2
    /// files, whose ids were dense — `AdmittedNodes::from_serving`
    /// synthesizes `n + slot` then.
    pub ids: Vec<u32>,
    /// Exclusive upper bound on every id ever issued (VQS3) — keeps
    /// eviction's monotone no-reissue guarantee across processes.  0 on
    /// legacy files (the loader derives `n + count`).
    pub next_id: u32,
}

impl ServingAdmitted {
    pub fn count(&self) -> usize {
        self.nbr_ptr.len().saturating_sub(1)
    }
}

fn write_params<W: Write>(w: &mut Writer<W>, params: &[Tensor]) -> Result<()> {
    w.u32(params.len() as u32)?;
    for p in params {
        w.u32(p.shape.len() as u32)?;
        for &d in &p.shape {
            w.u32(d as u32)?;
        }
        w.f32s(&p.f)?;
    }
    Ok(())
}

fn read_params<R: Read>(r: &mut Reader<R>) -> Result<Vec<Tensor>> {
    let np = r.u32()? as usize;
    let mut params = Vec::with_capacity(np);
    for _ in 0..np {
        let rank = r.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        let data = r.f32s()?;
        if data.len() != shape.iter().product::<usize>() {
            bail!("serving param payload mismatch");
        }
        params.push(Tensor::from_f32(&shape, data));
    }
    Ok(params)
}

fn write_header<W: Write>(w: &mut Writer<W>, magic: u32, artifact: &str) -> Result<()> {
    w.u32(magic)?;
    w.u32(artifact.len() as u32)?;
    w.w.write_all(artifact.as_bytes())?;
    Ok(())
}

fn read_artifact_name<R: Read>(r: &mut Reader<R>, artifact: &str) -> Result<()> {
    let alen = r.u32()? as usize;
    let mut aname = vec![0u8; alen];
    r.r.read_exact(&mut aname)?;
    let aname = String::from_utf8(aname)?;
    if aname != artifact {
        bail!("serving artifact is for '{aname}', expected '{artifact}'");
    }
    Ok(())
}

/// Export a frozen model into a "VQS3" serving artifact.  `artifact` is
/// the `vq_serve_*` artifact name the file is valid for (refused on
/// mismatch at load, like the training checkpoint).
pub fn save_serving(
    path: &Path,
    artifact: &str,
    params: &[Tensor],
    layers: &[ServingLayer],
    admitted: &ServingAdmitted,
) -> Result<()> {
    let f = std::fs::File::create(path).context("create serving artifact")?;
    let mut w = Writer { w: std::io::BufWriter::new(f) };
    write_header(&mut w, SERVE_MAGIC, artifact)?;
    write_params(&mut w, params)?;
    w.u32(layers.len() as u32)?;
    for l in layers {
        w.u32(l.k as u32)?;
        w.u32(l.n as u32)?;
        w.u32(l.n_br as u32)?;
        w.u32(l.fp as u32)?;
        w.f32s(&l.cw)?;
        w.u32s(&l.assign)?;
        w.f32s(&l.mean)?;
        w.f32s(&l.var)?;
        w.u32s(&l.admitted_assign)?;
        w.f32s(&l.drift_ref)?;
    }
    w.u32(admitted.f_pad as u32)?;
    w.f32s(&admitted.features)?;
    w.u32s(&admitted.nbr_ptr)?;
    w.u32s(&admitted.nbr)?;
    w.u32s(&admitted.ids)?;
    w.u32(admitted.next_id)?;
    Ok(())
}

/// Export in the "VQS2" layout (no drift references, no stable-id map —
/// admitted ids degrade to dense `n + slot`).  Kept as the pinned writer
/// for the compatibility load path — `load_serving` must keep accepting
/// files older processes produced.
pub fn save_serving_v2(
    path: &Path,
    artifact: &str,
    params: &[Tensor],
    layers: &[ServingLayer],
    admitted: &ServingAdmitted,
) -> Result<()> {
    let f = std::fs::File::create(path).context("create serving artifact")?;
    let mut w = Writer { w: std::io::BufWriter::new(f) };
    write_header(&mut w, SERVE_MAGIC_V2, artifact)?;
    write_params(&mut w, params)?;
    w.u32(layers.len() as u32)?;
    for l in layers {
        w.u32(l.k as u32)?;
        w.u32(l.n as u32)?;
        w.u32(l.n_br as u32)?;
        w.u32(l.fp as u32)?;
        w.f32s(&l.cw)?;
        w.u32s(&l.assign)?;
        w.f32s(&l.mean)?;
        w.f32s(&l.var)?;
        w.u32s(&l.admitted_assign)?;
    }
    w.u32(admitted.f_pad as u32)?;
    w.f32s(&admitted.features)?;
    w.u32s(&admitted.nbr_ptr)?;
    w.u32s(&admitted.nbr)?;
    Ok(())
}

/// Export in the legacy "VQS1" layout (no whitening stats, no admitted
/// nodes).  Kept as the pinned writer for the compatibility load path —
/// `load_serving` must keep accepting files older processes produced.
pub fn save_serving_v1(
    path: &Path,
    artifact: &str,
    params: &[Tensor],
    layers: &[ServingLayer],
) -> Result<()> {
    let f = std::fs::File::create(path).context("create serving artifact")?;
    let mut w = Writer { w: std::io::BufWriter::new(f) };
    write_header(&mut w, SERVE_MAGIC_V1, artifact)?;
    write_params(&mut w, params)?;
    w.u32(layers.len() as u32)?;
    for l in layers {
        w.u32(l.k as u32)?;
        w.u32(l.n as u32)?;
        w.u32(l.n_br as u32)?;
        w.u32(l.fp as u32)?;
        w.f32s(&l.cw)?;
        w.u32s(&l.assign)?;
    }
    Ok(())
}

/// Load a serving artifact ("VQS3", or legacy "VQS2"/"VQS1").  Missing
/// VQS2 stats load as identity whitening and an empty admitted block;
/// missing VQS3 maintenance state loads as "no drift reference" (detector
/// disarmed) and a dense id map (synthesized downstream).  Shape
/// validation against the serve spec is the caller's job
/// (`serve::ServingModel::load` checks against the manifest).
pub fn load_serving(
    path: &Path,
    artifact: &str,
) -> Result<(Vec<Tensor>, Vec<ServingLayer>, ServingAdmitted)> {
    let f = std::fs::File::open(path).context("open serving artifact")?;
    let mut r = Reader { r: std::io::BufReader::new(f) };
    let magic = r.u32()?;
    let version = match magic {
        SERVE_MAGIC => 3,
        SERVE_MAGIC_V2 => 2,
        SERVE_MAGIC_V1 => 1,
        _ => bail!("not a vq-gnn serving artifact"),
    };
    read_artifact_name(&mut r, artifact)?;
    let params = read_params(&mut r)?;
    let nl = r.u32()? as usize;
    let mut layers = Vec::with_capacity(nl);
    for _ in 0..nl {
        let k = r.u32()? as usize;
        let n = r.u32()? as usize;
        let n_br = r.u32()? as usize;
        let fp = r.u32()? as usize;
        let cw = r.f32s()?;
        let assign = r.u32s()?;
        if cw.len() != n_br * k * fp || assign.len() != n_br * n {
            bail!("serving layer payload mismatch");
        }
        if assign.iter().any(|&a| a as usize >= k) {
            bail!("serving assignment out of codebook range");
        }
        let (mean, var, admitted_assign) = if version >= 2 {
            let mean = r.f32s()?;
            let var = r.f32s()?;
            let aa = r.u32s()?;
            if mean.len() != n_br * fp || var.len() != n_br * fp {
                bail!("serving whitening-stats payload mismatch");
            }
            if aa.len() % n_br.max(1) != 0 || aa.iter().any(|&a| a as usize >= k) {
                bail!("serving admitted-assignment payload mismatch");
            }
            (mean, var, aa)
        } else {
            (vec![0.0; n_br * fp], vec![1.0; n_br * fp], Vec::new())
        };
        let drift_ref = if version >= 3 { r.f32s()? } else { Vec::new() };
        if drift_ref.iter().any(|x| !x.is_finite() || *x < 0.0) {
            bail!("serving drift-reference bins must be finite non-negative counts");
        }
        layers.push(ServingLayer {
            k,
            n,
            n_br,
            fp,
            cw,
            assign,
            mean,
            var,
            admitted_assign,
            drift_ref,
        });
    }
    let admitted = if version >= 2 {
        let f_pad = r.u32()? as usize;
        let features = r.f32s()?;
        let nbr_ptr = r.u32s()?;
        let nbr = r.u32s()?;
        let (ids, next_id) = if version >= 3 { (r.u32s()?, r.u32()?) } else { (Vec::new(), 0) };
        let adm = ServingAdmitted { f_pad, features, nbr_ptr, nbr, ids, next_id };
        validate_admitted(&adm, &layers)?;
        adm
    } else {
        ServingAdmitted {
            f_pad: 0,
            features: Vec::new(),
            nbr_ptr: vec![0],
            nbr: Vec::new(),
            ids: Vec::new(),
            next_id: 0,
        }
    };
    Ok((params, layers, admitted))
}

/// Cross-check the admitted block against the layer tables: counts agree
/// everywhere, CSR offsets are well-formed, the stable-id map (when
/// present) is strictly increasing past the frozen range with a
/// consistent `next_id` watermark, and every neighbor id refers to an
/// already-known node — frozen, or an earlier admitted node's id (dense
/// `n + slot` on legacy blocks without an id map).
fn validate_admitted(adm: &ServingAdmitted, layers: &[ServingLayer]) -> Result<()> {
    if adm.nbr_ptr.first() != Some(&0) {
        bail!("serving admitted CSR must start at 0");
    }
    let count = adm.count();
    if adm.features.len() != count * adm.f_pad {
        bail!("serving admitted feature payload mismatch");
    }
    if adm.nbr_ptr.windows(2).any(|w| w[0] > w[1])
        || adm.nbr_ptr.last().copied().unwrap_or(0) as usize != adm.nbr.len()
    {
        bail!("serving admitted CSR offsets malformed");
    }
    let n = layers.first().map(|l| l.n).unwrap_or(0);
    if adm.ids.is_empty() {
        // legacy dense ids: node i is id n + i
        for (i, w) in adm.nbr_ptr.windows(2).enumerate() {
            let lim = (n + i) as u32; // node i may only cite earlier nodes
            if adm.nbr[w[0] as usize..w[1] as usize].iter().any(|&u| u >= lim) {
                bail!("serving admitted node {i} cites an unknown neighbor");
            }
        }
    } else {
        if adm.ids.len() != count {
            bail!("serving admitted id map holds {} ids for {count} nodes", adm.ids.len());
        }
        if adm.ids.first().map_or(false, |&i| (i as usize) < n)
            || adm.ids.windows(2).any(|w| w[0] >= w[1])
        {
            bail!("serving admitted id map must increase strictly from the frozen range");
        }
        if let Some(&last) = adm.ids.last() {
            if adm.next_id <= last {
                bail!("serving admitted next_id watermark is behind the id map");
            }
        }
        for (i, w) in adm.nbr_ptr.windows(2).enumerate() {
            // node i may cite frozen ids or EARLIER admitted nodes' ids
            // (arcs into evicted ids were dropped at eviction time)
            if adm.nbr[w[0] as usize..w[1] as usize]
                .iter()
                .any(|&u| (u as usize) >= n && adm.ids[..i].binary_search(&u).is_err())
            {
                bail!("serving admitted node {i} cites an unknown neighbor");
            }
        }
    }
    for l in layers {
        if l.admitted_assign.len() != count * l.n_br {
            bail!(
                "serving admitted tables disagree: {} nodes vs {} per-layer assignments",
                count,
                l.admitted_assign.len() / l.n_br.max(1)
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayerPlan;
    use crate::util::rng::Rng;

    fn mk_vq(seed: u64) -> VqModel {
        let plan = LayerPlan { f_in: 8, h_out: 4, g_dim: 4, n_br: 2, fp: 6, cf: 12, heads: 1 };
        VqModel::init(&[plan.clone(), plan], 5, 30, seed)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let mut rng = Rng::new(1);
        let params = vec![
            Tensor::from_f32(&[3, 4], (0..12).map(|_| rng.gauss_f32()).collect()),
            Tensor::from_f32(&[4], vec![1.0, 2.0, 3.0, 4.0]),
        ];
        let vq = mk_vq(7);
        save(&path, "vq_train_x", &params, &vq).unwrap();

        let mut params2 = vec![Tensor::zeros(&[3, 4]), Tensor::zeros(&[4])];
        let mut vq2 = mk_vq(99); // different init, will be overwritten
        load(&path, "vq_train_x", &mut params2, &mut vq2).unwrap();
        assert_eq!(params[0].f, params2[0].f);
        assert_eq!(params[1].f, params2[1].f);
        for (a, b) in vq.layers.iter().zip(&vq2.layers) {
            assert_eq!(a.assign, b.assign);
            for (x, y) in a.branches.iter().zip(&b.branches) {
                assert_eq!(x.cww, y.cww);
                assert_eq!(x.counts, y.counts);
                assert_eq!(x.mean, y.mean);
            }
        }
    }

    #[test]
    fn refuses_wrong_artifact_and_shapes() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        let params = vec![Tensor::zeros(&[2, 2])];
        let vq = mk_vq(1);
        save(&path, "art_a", &params, &vq).unwrap();

        let mut p2 = vec![Tensor::zeros(&[2, 2])];
        let mut vq2 = mk_vq(1);
        assert!(load(&path, "art_b", &mut p2, &mut vq2).is_err());
        let mut p3 = vec![Tensor::zeros(&[2, 3])];
        assert!(load(&path, "art_a", &mut p3, &mut vq2).is_err());
        assert!(load(Path::new("/nonexistent/x.ckpt"), "art_a", &mut p2, &mut vq2).is_err());
    }

    fn mk_serving_layer(rng: &mut Rng, admitted: usize) -> ServingLayer {
        ServingLayer {
            k: 4,
            n: 10,
            n_br: 2,
            fp: 3,
            cw: (0..2 * 4 * 3).map(|_| rng.gauss_f32()).collect(),
            assign: (0..2 * 10).map(|_| rng.below(4) as u32).collect(),
            mean: (0..2 * 3).map(|_| 0.1 * rng.gauss_f32()).collect(),
            var: (0..2 * 3).map(|_| 0.5 + rng.f32()).collect(),
            admitted_assign: (0..admitted * 2).map(|_| rng.below(4) as u32).collect(),
            drift_ref: (0..16).map(|_| rng.below(9) as f32).collect(),
        }
    }

    #[test]
    fn serving_roundtrip_and_validation() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.bin");
        let mut rng = Rng::new(3);
        let params = vec![Tensor::from_f32(&[2, 3], (0..6).map(|_| rng.gauss_f32()).collect())];
        let layers = vec![mk_serving_layer(&mut rng, 2)];
        let admitted = ServingAdmitted {
            f_pad: 4,
            features: (0..2 * 4).map(|_| rng.gauss_f32()).collect(),
            nbr_ptr: vec![0, 2, 3],
            nbr: vec![1, 7, 10], // node 1 (id 12) may cite node 0 (id 10)
            ids: vec![10, 12],   // sparse: id 11 was evicted
            next_id: 13,
        };
        save_serving(&path, "vq_serve_tiny_sim_gcn", &params, &layers, &admitted).unwrap();
        let (p2, l2, a2) = load_serving(&path, "vq_serve_tiny_sim_gcn").unwrap();
        assert_eq!(p2.len(), 1);
        assert_eq!(p2[0].shape, vec![2, 3]);
        assert_eq!(p2[0].f, params[0].f);
        assert_eq!(l2, layers);
        assert_eq!(a2, admitted);
        // wrong artifact name refused
        assert!(load_serving(&path, "vq_serve_tiny_sim_gat").is_err());
        // a training checkpoint is not a serving artifact (magic mismatch)
        let tpath = dir.join("t.ckpt");
        save(&tpath, "art", &params, &mk_vq(1)).unwrap();
        assert!(load_serving(&tpath, "art").is_err());
        // out-of-range assignments are rejected
        let mut bad = layers.clone();
        bad[0].assign[0] = 99;
        let bpath = dir.join("bad.bin");
        save_serving(&bpath, "a", &params, &bad, &admitted).unwrap();
        assert!(load_serving(&bpath, "a").is_err());
        // an admitted node citing a not-yet-known (here: evicted) id is
        // rejected — 11 is inside [n, next_id) but absent from the id map
        let mut bad_adm = admitted.clone();
        bad_adm.nbr[2] = 11; // node 1 citing the evicted id 11
        save_serving(&bpath, "a", &params, &layers, &bad_adm).unwrap();
        assert!(load_serving(&bpath, "a").is_err());
        // a non-increasing id map is rejected
        let mut bad_adm = admitted.clone();
        bad_adm.ids = vec![12, 10];
        save_serving(&bpath, "a", &params, &layers, &bad_adm).unwrap();
        assert!(load_serving(&bpath, "a").is_err());
        // a next_id watermark behind the id map is rejected
        let mut bad_adm = admitted.clone();
        bad_adm.next_id = 12;
        save_serving(&bpath, "a", &params, &layers, &bad_adm).unwrap();
        assert!(load_serving(&bpath, "a").is_err());
        // admitted counts must agree between block and layer tables
        let mut bad_layers = layers.clone();
        bad_layers[0].admitted_assign.truncate(2); // 1 node's worth, block says 2
        save_serving(&bpath, "a", &params, &bad_layers, &admitted).unwrap();
        assert!(load_serving(&bpath, "a").is_err());
    }

    #[test]
    fn vqs2_files_still_load_with_dense_ids_and_disarmed_drift() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_serve_v2_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v2.bin");
        let mut rng = Rng::new(11);
        let params = vec![Tensor::from_f32(&[3], vec![4.0, 5.0, 6.0])];
        let layers = vec![mk_serving_layer(&mut rng, 2)];
        let admitted = ServingAdmitted {
            f_pad: 4,
            features: (0..2 * 4).map(|_| rng.gauss_f32()).collect(),
            nbr_ptr: vec![0, 1, 3],
            nbr: vec![2, 9, 10], // dense ids: node 1 (id 11) cites node 0 (id 10)
            ids: Vec::new(),
            next_id: 0,
        };
        save_serving_v2(&path, "vq_serve_tiny_sim_gcn", &params, &layers, &admitted).unwrap();
        let (p2, l2, a2) = load_serving(&path, "vq_serve_tiny_sim_gcn").unwrap();
        assert_eq!(p2[0].f, params[0].f);
        assert_eq!(l2[0].cw, layers[0].cw);
        assert_eq!(l2[0].mean, layers[0].mean);
        assert_eq!(l2[0].var, layers[0].var);
        assert_eq!(l2[0].admitted_assign, layers[0].admitted_assign);
        // VQS2 carries no maintenance state: detector disarmed, dense ids
        assert!(l2[0].drift_ref.is_empty());
        assert!(a2.ids.is_empty());
        assert_eq!(a2.next_id, 0);
        assert_eq!(a2.count(), 2);
        assert_eq!(a2.nbr, admitted.nbr);
        // and re-exporting what a VQS2 load produced round-trips as VQS3
        let v3 = dir.join("v2_as_v3.bin");
        save_serving(&v3, "vq_serve_tiny_sim_gcn", &p2, &l2, &a2).unwrap();
        let (_, l3, a3) = load_serving(&v3, "vq_serve_tiny_sim_gcn").unwrap();
        assert_eq!(l3, l2);
        assert_eq!(a3, a2);
    }

    #[test]
    fn vqs1_files_still_load_with_identity_whitening() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_serve_v1_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.bin");
        let mut rng = Rng::new(9);
        let params = vec![Tensor::from_f32(&[3], vec![1.0, 2.0, 3.0])];
        let layers = vec![mk_serving_layer(&mut rng, 0)];
        save_serving_v1(&path, "vq_serve_tiny_sim_gcn", &params, &layers).unwrap();
        let (p2, l2, a2) = load_serving(&path, "vq_serve_tiny_sim_gcn").unwrap();
        assert_eq!(p2[0].f, params[0].f);
        assert_eq!(l2[0].cw, layers[0].cw);
        assert_eq!(l2[0].assign, layers[0].assign);
        // stats degrade to identity whitening, admitted block is empty,
        // and the drift detector stays disarmed (no reference)
        assert_eq!(l2[0].mean, vec![0.0; 6]);
        assert_eq!(l2[0].var, vec![1.0; 6]);
        assert!(l2[0].admitted_assign.is_empty());
        assert!(l2[0].drift_ref.is_empty());
        assert_eq!(a2.count(), 0);
        assert_eq!(a2.f_pad, 0);
        assert!(a2.ids.is_empty());
    }

    #[test]
    fn shard_plan_round_trips_and_stays_optional() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_shard_test");
        std::fs::create_dir_all(&dir).unwrap();
        let params = vec![Tensor::from_f32(&[2], vec![1.0, 2.0])];
        let vq = mk_vq(5);

        // with a plan: the map comes back exactly
        let plan = ShardPlan::contiguous(30, 4);
        let p1 = dir.join("sharded.ckpt");
        save_with_shards(&p1, "art", &params, &vq, Some(&plan)).unwrap();
        let mut params2 = vec![Tensor::zeros(&[2])];
        let mut vq2 = mk_vq(8);
        let got = load_with_shards(&p1, "art", &mut params2, &mut vq2).unwrap();
        assert_eq!(got.as_ref(), Some(&plan));
        assert_eq!(params2[0].f, params[0].f);
        assert_eq!(vq2.layers[0].assign, vq.layers[0].assign);

        // without: a plain VQG1 file, byte-identical to `save`, loads None
        let p2 = dir.join("plain_a.ckpt");
        let p3 = dir.join("plain_b.ckpt");
        save(&p2, "art", &params, &vq).unwrap();
        save_with_shards(&p3, "art", &params, &vq, None).unwrap();
        assert_eq!(std::fs::read(&p2).unwrap(), std::fs::read(&p3).unwrap());
        let got = load_with_shards(&p2, "art", &mut params2, &mut vq2).unwrap();
        assert!(got.is_none());
        // and the plain `load` accepts a sharded file (section ignored)
        load(&p1, "art", &mut params2, &mut vq2).unwrap();

        // trailing garbage that is not a shard section is refused
        let mut bytes = std::fs::read(&p2).unwrap();
        bytes.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        let p4 = dir.join("garbage.ckpt");
        std::fs::write(&p4, bytes).unwrap();
        assert!(load_with_shards(&p4, "art", &mut params2, &mut vq2).is_err());
    }

    #[test]
    fn corrupt_file_fails_cleanly() {
        let dir = std::env::temp_dir().join("vqgnn_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"garbage").unwrap();
        let mut p = vec![];
        let mut vq = mk_vq(1);
        assert!(load(&path, "x", &mut p, &mut vq).is_err());
    }
}
