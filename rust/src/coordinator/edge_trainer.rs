//! Baseline trainers (paper §5): full-graph "oracle", NS-SAGE neighbor
//! sampling, Cluster-GCN, GraphSAINT-RW.  All share the exact edge-list
//! artifacts (python/compile/edgemp.py); they differ only in the subgraph
//! each step feeds and in the normalization coefficients.
//!
//! Like `VqTrainer`, the trainer holds a persistent [`Session`] per
//! artifact (inputs rewritten in place each step, outputs rewritten by
//! `Runtime::execute_into`) and overlaps subgraph sampling for step `t+1`
//! with the execution of step `t` via `util::par::join2` — subgraph
//! sampling depends only on the sampler state and the trainer RNG stream,
//! never on the parameters, so the overlapped schedule computes exactly
//! the serial trajectory.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::coordinator::opt::{self, Optimizer};
use crate::coordinator::vq_trainer::{pipeline_env_enabled, TrainMetrics};
use crate::coordinator::{
    fill_link_pairs, init_params, lipschitz_clip, InSlot, PairBuf, RunStats, Session,
};
use crate::datasets::{Dataset, Split};
use crate::graph::{Conv, Graph};
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::runtime::{Artifact, Runtime};
use crate::sampler::{cluster, neighbor, saint};
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    FullGraph,
    NsSage,
    ClusterGcn,
    SaintRw,
}

impl Baseline {
    pub fn from_str(s: &str) -> Option<Baseline> {
        match s {
            "full" => Some(Baseline::FullGraph),
            "ns" => Some(Baseline::NsSage),
            "cluster" => Some(Baseline::ClusterGcn),
            "saint" => Some(Baseline::SaintRw),
            _ => None,
        }
    }

    fn artifact_suffix(self) -> &'static str {
        match self {
            Baseline::FullGraph => "_full",
            Baseline::NsSage => "_ns",
            Baseline::ClusterGcn | Baseline::SaintRw => "_sub",
        }
    }
}

/// A sampled subgraph, ready for assembly: node ids, local arcs with
/// coefficients, per-node loss weights.
struct EdgePrep {
    nodes: Vec<u32>,
    arcs: Vec<(u32, u32, f32)>,
    lam: Vec<f32>,
}

/// Induced subgraph arcs with the convolution re-normalized on the
/// subgraph (Cluster-GCN / SAINT convention), plus self loops for GCN.
fn induced_with_subgraph_norm(
    g: &Graph,
    conv: Conv,
    gat: bool,
    nodes: &[u32],
) -> Vec<(u32, u32, f32)> {
    let mut local = vec![-1i32; g.n];
    let pairs = g.induced_edges(nodes, &mut local);
    let nl = nodes.len();
    let mut indeg = vec![0u32; nl];
    for &(_, v) in &pairs {
        indeg[v as usize] += 1;
    }
    let mut arcs: Vec<(u32, u32, f32)> = pairs
        .into_iter()
        .map(|(u, v)| {
            let c = if gat {
                1.0
            } else {
                match conv {
                    Conv::GcnSym => 1.0
                        / (((indeg[u as usize] + 1) as f32
                            * (indeg[v as usize] + 1) as f32)
                            .sqrt()),
                    Conv::SageMean => 1.0 / indeg[v as usize].max(1) as f32,
                }
            };
            (u, v, c)
        })
        .collect();
    if conv.with_self_loops() && !gat {
        for v in 0..nl as u32 {
            arcs.push((v, v, 1.0 / (indeg[v as usize] + 1) as f32));
        }
    } else if gat {
        for v in 0..nl as u32 {
            arcs.push((v, v, 1.0));
        }
    }
    arcs
}

/// Subgraph for one step.  A free function over explicit sampler state so
/// the pipelined prep worker can run it while the executor owns the rest
/// of the trainer.
#[allow(clippy::too_many_arguments)]
fn sample_subgraph_parts(
    kind: Baseline,
    ds: &Dataset,
    cap_nodes: usize,
    rng: &mut Rng,
    partition: &[u32],
    n_parts: usize,
    saint_s: Option<&saint::SaintSampler>,
    gat: bool,
    conv: Conv,
) -> EdgePrep {
    let g = &ds.graph;
    match kind {
        Baseline::FullGraph => {
            let nodes: Vec<u32> = (0..g.n as u32).collect();
            let mut arcs = Vec::with_capacity(g.num_arcs() + g.n);
            for v in 0..g.n {
                for &u in g.in_neighbors(v) {
                    let coef = if gat { 1.0 } else { g.coef(conv, u as usize, v) };
                    arcs.push((u, v as u32, coef));
                }
            }
            // self loops: GCN's Ã and GAT's 𝔠 = A + I
            if conv.with_self_loops() || gat {
                for v in 0..g.n {
                    let coef = if gat { 1.0 } else { g.coef(Conv::GcnSym, v, v) };
                    arcs.push((v as u32, v as u32, coef));
                }
            }
            let lam = vec![1.0; g.n];
            EdgePrep { nodes, arcs, lam }
        }
        Baseline::ClusterGcn => {
            // group random clusters until the capacity class is filled
            let mut group = Vec::new();
            let mut order: Vec<u32> = (0..n_parts as u32).collect();
            rng.shuffle(&mut order);
            let mut size = 0usize;
            let mut sizes = vec![0usize; n_parts];
            for &p in partition {
                sizes[p as usize] += 1;
            }
            for &p in &order {
                if size + sizes[p as usize] > cap_nodes {
                    continue;
                }
                size += sizes[p as usize];
                group.push(p);
                if size > cap_nodes * 3 / 4 {
                    break;
                }
            }
            let nodes = cluster::batch_nodes(partition, &group);
            let arcs = induced_with_subgraph_norm(g, conv, gat, &nodes);
            let lam = vec![1.0; nodes.len()];
            EdgePrep { nodes, arcs, lam }
        }
        Baseline::SaintRw => {
            let s = saint_s.expect("saint sampler state");
            let (nodes, raw_arcs, lam) = s.sample(g, rng);
            let mut nodes = nodes;
            nodes.truncate(cap_nodes);
            let keep = nodes.len() as u32;
            // subgraph normalization × SAINT α correction
            let base = induced_with_subgraph_norm(g, conv, gat, &nodes);
            // fold in the α edge corrections where available
            let alpha: std::collections::HashMap<(u32, u32), f32> = raw_arcs
                .iter()
                .filter(|&&(u, v, _)| u < keep && v < keep)
                .map(|&(u, v, a)| ((u, v), a))
                .collect();
            let arcs = base
                .into_iter()
                .map(|(u, v, c)| {
                    let a = alpha.get(&(u, v)).copied().unwrap_or(1.0);
                    // cap the variance of the unbiasedness correction
                    (u, v, c * a.clamp(0.5, 4.0))
                })
                .collect();
            let mut lam = lam;
            lam.truncate(cap_nodes);
            // normalize λ to mean 1 (stability at small sample counts)
            let m: f32 = lam.iter().sum::<f32>() / lam.len().max(1) as f32;
            for x in lam.iter_mut() {
                *x /= m.max(1e-6);
            }
            EdgePrep { nodes, arcs, lam }
        }
        Baseline::NsSage => {
            let b_roots = (cap_nodes / 8).max(16);
            let pool = ds.nodes_in_split(Split::Train);
            let roots: Vec<u32> = (0..b_roots)
                .map(|_| pool[rng.below(pool.len())])
                .collect();
            let fanouts = [10, 5, 5];
            let s = neighbor::sample(g, &roots, &fanouts, cap_nodes, rng);
            // mean aggregator over the SAMPLED neighbors
            let mut indeg = vec![0u32; s.nodes.len()];
            for &(_, v) in &s.edges {
                indeg[v as usize] += 1;
            }
            let arcs = s
                .edges
                .iter()
                .map(|&(u, v)| {
                    let c = if gat { 1.0 } else { 1.0 / indeg[v as usize].max(1) as f32 };
                    (u, v, c)
                })
                .collect();
            // loss only on roots
            let mut lam = vec![0.0f32; s.nodes.len()];
            for x in lam.iter_mut().take(s.n_roots) {
                *x = 1.0;
            }
            EdgePrep { nodes: s.nodes, arcs, lam }
        }
    }
}

/// Rewrite an edge session's input slots in place for one subgraph.  Rng
/// draws (link pairs) happen FIRST — the same order as the pre-session
/// assemble, so trajectories are unchanged.
#[allow(clippy::too_many_arguments)]
fn fill_edge_session(
    sess: &mut Session,
    spec: &ArtifactSpec,
    ds: &Dataset,
    params: &[Tensor],
    rng: &mut Rng,
    pairs: &mut PairBuf,
    nodes: &[u32],
    arcs: &[(u32, u32, f32)],
    lam: &[f32],
    train: bool,
    shards: usize,
) -> Result<()> {
    let (nn, ne) = (spec.nn, spec.ne);
    anyhow::ensure!(nodes.len() <= nn, "subgraph {} > artifact nn {}", nodes.len(), nn);
    anyhow::ensure!(arcs.len() <= ne, "edges {} > artifact ne {}", arcs.len(), ne);
    let f = ds.cfg.f_in_pad;
    if sess.slots.contains(&InSlot::Psrc) {
        let p = spec.inputs[spec.input_index("psrc").unwrap()].numel();
        fill_link_pairs(&ds.graph, rng, nodes, p, train, pairs);
    }
    let Session { inputs, slots, .. } = sess;
    for (idx, slot) in slots.iter().enumerate() {
        match *slot {
            InSlot::X => {
                // features padded to nn rows; the sharded gather is a
                // disjoint row-range split — byte-identical at any S
                let x = &mut inputs[idx].f;
                x.fill(0.0);
                crate::shard::gather_features_sharded(
                    &ds.features, f, nodes, &mut x[..nodes.len() * f], shards,
                );
            }
            InSlot::Esrc => {
                let e = &mut inputs[idx].i;
                e.fill(0);
                for (i, &(u, _, _)) in arcs.iter().enumerate() {
                    e[i] = u as i32;
                }
            }
            InSlot::Edst => {
                let e = &mut inputs[idx].i;
                e.fill(0);
                for (i, &(_, v, _)) in arcs.iter().enumerate() {
                    e[i] = v as i32;
                }
            }
            InSlot::Ecoef => {
                let e = &mut inputs[idx].f;
                e.fill(0.0);
                for (i, &(_, _, c)) in arcs.iter().enumerate() {
                    e[i] = c;
                }
            }
            InSlot::Y => {
                if ds.cfg.multilabel {
                    let c = ds.cfg.n_classes;
                    let data = &mut inputs[idx].f;
                    data.fill(0.0);
                    for (i, &v) in nodes.iter().enumerate() {
                        data[i * c..(i + 1) * c].copy_from_slice(
                            &ds.labels_multi[v as usize * c..(v as usize + 1) * c],
                        );
                    }
                } else {
                    let data = &mut inputs[idx].i;
                    data.fill(0);
                    for (i, &v) in nodes.iter().enumerate() {
                        data[i] = ds.labels[v as usize];
                    }
                }
            }
            InSlot::WLoss => {
                let w = &mut inputs[idx].f;
                w.fill(0.0);
                for (i, &v) in nodes.iter().enumerate() {
                    let in_split = !train || ds.split[v as usize] == Split::Train;
                    w[i] = if in_split { lam[i] } else { 0.0 };
                }
            }
            InSlot::Psrc => inputs[idx].i.copy_from_slice(&pairs.psrc),
            InSlot::Pdst => inputs[idx].i.copy_from_slice(&pairs.pdst),
            InSlot::Py => inputs[idx].f.copy_from_slice(&pairs.py),
            InSlot::Pw => inputs[idx].f.copy_from_slice(&pairs.pw),
            InSlot::Param(pi) => inputs[idx].f.copy_from_slice(&params[pi].f),
            InSlot::Ctx => anyhow::bail!("VQ context input in an edge artifact ({})", spec.name),
        }
    }
    Ok(())
}

pub struct EdgeTrainer {
    pub kind: Baseline,
    pub train_art: Rc<Artifact>,
    pub infer_art: Rc<Artifact>,
    pub ds: Rc<Dataset>,
    pub model_name: String,
    pub params: Vec<Tensor>,
    opt: opt::Adam,
    rng: Rng,
    weight_clip: f32,
    // method-specific state
    partition: Vec<u32>,
    n_parts: usize,
    saint: Option<saint::SaintSampler>,
    train_io: Session,
    infer_io: Session,
    pairs: PairBuf,
    pipeline: bool,
    prefetched: Option<EdgePrep>,
    pub stats: RunStats,
    metrics: TrainMetrics,
    /// Shard-parallel feature gather width (1 = serial).  The baselines
    /// carry no VQ state, so their shard integration is the partitioned
    /// gather — byte-identical at any width.
    shards: usize,
}

impl EdgeTrainer {
    pub fn new(rt: &mut Runtime, man: &Manifest, ds: Rc<Dataset>,
               model_name: &str, kind: Baseline, seed: u64) -> Result<EdgeTrainer> {
        if kind == Baseline::NsSage && model_name == "gcn" {
            anyhow::bail!("NS-SAGE is not compatible with the GCN backbone (Table 4 fn.1)");
        }
        let train_name = format!(
            "edge_train_{}_{}{}", ds.cfg.name, model_name, kind.artifact_suffix()
        );
        let infer_name = format!("edge_infer_{}_{}_full", ds.cfg.name, model_name);
        let train_art = rt.load(man, &train_name).context("load train artifact")?;
        let infer_art = rt.load(man, &infer_name).context("load infer artifact")?;
        let params = init_params(&train_art.spec, seed);
        let opt = opt::Adam::new(1e-3, &params); // OGB reference setup (App. F)
        let mut rng = Rng::new(seed ^ 0xBA5E);
        let sub_nodes = train_art.spec.nn;
        let (partition, n_parts) = if kind == Baseline::ClusterGcn {
            // clusters of ~sub_nodes/2 so a batch groups ≥2 clusters
            let parts = (ds.n() / (sub_nodes / 2).max(1)).max(2);
            (cluster::partition(&ds.graph, parts, &mut rng), parts)
        } else {
            (vec![], 0)
        };
        let saint_s = if kind == Baseline::SaintRw {
            // roots×(walk+1) ≈ sub_nodes/2 target
            let roots = (sub_nodes / 8).max(8);
            Some(saint::SaintSampler::new(&ds.graph, roots, 3, 30, &mut rng))
        } else {
            None
        };
        let train_io = Session::for_artifact(&train_art.spec)?;
        let infer_io = Session::for_artifact(&infer_art.spec)?;
        // link tasks draw negative-pair samples from the trainer rng on
        // BOTH the train and evaluate paths; the overlapped prefetch
        // captures `&mut self.rng`, so interleaving evaluate() with a
        // pipelined prefetch would reorder rng draws and fork the
        // trajectory.  Mirror VqTrainer: pipelining is node-task only.
        let pipeline = ds.cfg.task != "link" && pipeline_env_enabled();
        Ok(EdgeTrainer {
            kind,
            train_art,
            infer_art,
            model_name: model_name.to_string(),
            params,
            opt,
            rng,
            weight_clip: man.train.weight_clip as f32,
            partition,
            n_parts,
            saint: saint_s,
            train_io,
            infer_io,
            pairs: PairBuf::default(),
            pipeline,
            prefetched: None,
            stats: RunStats::default(),
            metrics: TrainMetrics::default(),
            shards: 1,
            ds,
        })
    }

    /// Split the per-step feature gather across `s` shard workers
    /// (1 = serial).  Purely an execution-layout knob: the gathered
    /// bytes are identical at any `s`.
    pub fn set_shards(&mut self, s: usize) {
        self.shards = s.max(1);
    }

    /// Wire `train_sample`/`train_exec` stage timers into `reg` (the
    /// baselines have no gather-vs-sample split and no VQ state).
    pub fn set_metrics(&mut self, reg: &crate::obs::Registry) {
        self.metrics = TrainMetrics::wire(reg);
    }

    /// Toggle the overlapped subgraph-sampling stage (parity tests /
    /// allocation benches; the overlapped and serial schedules compute
    /// identical trajectories).  Always off for link tasks — see `new`.
    pub fn set_pipelined(&mut self, on: bool) {
        self.pipeline = on && self.ds.cfg.task != "link";
    }

    /// Whether the overlapped prep stage is active.
    pub fn pipelined(&self) -> bool {
        self.pipeline
    }

    fn conv(&self) -> Conv {
        match self.model_name.as_str() {
            "gcn" => Conv::GcnSym,
            "sage" => Conv::SageMean,
            _ => Conv::SageMean, // GAT: ecoef is just validity
        }
    }

    fn is_gat(&self) -> bool {
        self.model_name == "gat"
    }

    pub fn train_step(&mut self, rt: &mut Runtime) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let ds = self.ds.clone();
        let art = self.train_art.clone();
        let gat = self.is_gat();
        let conv = self.conv();
        let cap = art.spec.nn;
        let prep = match self.prefetched.take() {
            Some(p) => p,
            None => {
                let span = self.metrics.sample.stage();
                let p = sample_subgraph_parts(
                    self.kind,
                    &ds,
                    cap,
                    &mut self.rng,
                    &self.partition,
                    self.n_parts,
                    self.saint.as_ref(),
                    gat,
                    conv,
                );
                span.stop();
                p
            }
        };
        fill_edge_session(
            &mut self.train_io,
            &art.spec,
            &ds,
            &self.params,
            &mut self.rng,
            &mut self.pairs,
            &prep.nodes,
            &prep.arcs,
            &prep.lam,
            true,
            self.shards,
        )?;
        // step t computes while the prep worker samples subgraph t+1
        let exec_res = if self.pipeline {
            let kind = self.kind;
            let n_parts = self.n_parts;
            let rng = &mut self.rng;
            let partition = &self.partition;
            let saint_s = self.saint.as_ref();
            let dsr: &Dataset = &ds;
            let io = &mut self.train_io;
            let (inputs, outputs) = (&io.inputs, &mut io.outputs);
            let m = &self.metrics;
            let (next, res) = par::join2(
                move || {
                    let span = m.sample.stage();
                    let p = sample_subgraph_parts(
                        kind, dsr, cap, rng, partition, n_parts, saint_s, gat, conv,
                    );
                    span.stop();
                    p
                },
                move || {
                    let span = m.exec.stage();
                    let res = rt.execute_into(&art, inputs, outputs);
                    span.stop();
                    res
                },
            );
            self.prefetched = Some(next);
            res
        } else {
            let span = self.metrics.exec.stage();
            let res =
                rt.execute_into(&art, &self.train_io.inputs, &mut self.train_io.outputs);
            span.stop();
            res
        };
        exec_res?;
        let spec = &self.train_art.spec;
        let loss;
        {
            let sess = &self.train_io;
            loss = sess.outputs[0].f[0];
            let n_params = self.params.len();
            let grads: Vec<&Tensor> =
                sess.outputs[sess.outputs.len() - n_params..].iter().collect();
            self.opt.step(&mut self.params, &grads);
        }
        if gat {
            lipschitz_clip(spec, &mut self.params, self.weight_clip);
        }
        let step_bytes = spec.input_bytes() + spec.output_bytes()
            + opt::opt_state_bytes(&self.params, 2);
        self.stats.peak_step_bytes = self.stats.peak_step_bytes.max(step_bytes);
        self.stats.steps += 1;
        self.stats.loss_last = loss;
        self.stats.nodes_per_step = prep.nodes.len() as u64;
        self.stats.messages_per_step = prep.arcs.len() as u64;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        Ok(loss)
    }

    /// Steps per "epoch" (coverage-equivalent to one pass over the graph).
    pub fn steps_per_epoch(&self) -> usize {
        match self.kind {
            Baseline::FullGraph => 8, // converge the oracle at equal epoch counts
            _ => {
                let per = self.train_art.spec.nn.max(1);
                (self.ds.n() + per - 1) / per
            }
        }
    }

    pub fn epoch(&mut self, rt: &mut Runtime) -> Result<f32> {
        let mut last = 0.0;
        for _ in 0..self.steps_per_epoch() {
            last = self.train_step(rt)?;
        }
        Ok(last)
    }

    /// Exact full-graph inference (shared by all baselines — OGB protocol).
    pub fn infer_full(&mut self, rt: &mut Runtime) -> Result<Vec<f32>> {
        let ds = self.ds.clone();
        let g = &ds.graph;
        let art = self.infer_art.clone();
        let gat = self.is_gat();
        let conv = self.conv();
        let nodes: Vec<u32> = (0..g.n as u32).collect();
        let mut arcs = Vec::with_capacity(g.num_arcs());
        for v in 0..g.n {
            for &u in g.in_neighbors(v) {
                let coef = if gat { 1.0 } else { g.coef(conv, u as usize, v) };
                arcs.push((u, v as u32, coef));
            }
        }
        if conv.with_self_loops() && !gat {
            for v in 0..g.n {
                arcs.push((v as u32, v as u32, g.coef(Conv::GcnSym, v, v)));
            }
        } else if gat {
            for v in 0..g.n {
                arcs.push((v as u32, v as u32, 1.0));
            }
        }
        let lam = vec![1.0; g.n];
        fill_edge_session(
            &mut self.infer_io,
            &art.spec,
            &ds,
            &self.params,
            &mut self.rng,
            &mut self.pairs,
            &nodes,
            &arcs,
            &lam,
            false,
            self.shards,
        )?;
        rt.execute_into(&art, &self.infer_io.inputs, &mut self.infer_io.outputs)?;
        Ok(self.infer_io.outputs[0].f.clone())
    }

    pub fn evaluate(&mut self, rt: &mut Runtime, split: Split) -> Result<f64> {
        use crate::coordinator::metrics;
        let ds = self.ds.clone();
        let logits = self.infer_full(rt)?;
        if ds.cfg.task == "link" {
            let h = self.infer_art.spec.outputs[0].shape[1];
            let score = |u: u32, v: u32| -> f32 {
                logits[u as usize * h..(u as usize + 1) * h]
                    .iter()
                    .zip(&logits[v as usize * h..(v as usize + 1) * h])
                    .map(|(x, y)| x * y)
                    .sum()
            };
            let pos = if split == Split::Val { &ds.val_pos } else { &ds.test_pos };
            let pos_scores: Vec<f32> = pos.iter().map(|&(u, v)| score(u, v)).collect();
            let mut rng = Rng::new(0xBEEF);
            let neg: Vec<f32> = (0..4096)
                .map(|_| score(rng.below(ds.n()) as u32, rng.below(ds.n()) as u32))
                .collect();
            return Ok(metrics::hits_at_k(&pos_scores, &neg, 50));
        }
        let rows: Vec<usize> = ds.nodes_in_split(split).iter().map(|&v| v as usize).collect();
        let c = ds.cfg.n_classes;
        if ds.cfg.multilabel {
            Ok(metrics::micro_f1(&logits, c, &ds.labels_multi, &rows))
        } else {
            Ok(metrics::accuracy(&logits, c, &ds.labels, &rows))
        }
    }
}
