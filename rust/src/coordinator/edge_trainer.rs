//! Baseline trainers (paper §5): full-graph "oracle", NS-SAGE neighbor
//! sampling, Cluster-GCN, GraphSAINT-RW.  All share the exact edge-list
//! artifacts (python/compile/edgemp.py); they differ only in the subgraph
//! each step feeds and in the normalization coefficients.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::coordinator::opt::{self, Optimizer};
use crate::coordinator::{gather_features, init_params, lipschitz_clip, RunStats};
use crate::datasets::{Dataset, Split};
use crate::graph::Conv;
use crate::runtime::manifest::Manifest;
use crate::runtime::{Artifact, Runtime};
use crate::sampler::{cluster, neighbor, saint};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    FullGraph,
    NsSage,
    ClusterGcn,
    SaintRw,
}

impl Baseline {
    pub fn from_str(s: &str) -> Option<Baseline> {
        match s {
            "full" => Some(Baseline::FullGraph),
            "ns" => Some(Baseline::NsSage),
            "cluster" => Some(Baseline::ClusterGcn),
            "saint" => Some(Baseline::SaintRw),
            _ => None,
        }
    }

    fn artifact_suffix(self) -> &'static str {
        match self {
            Baseline::FullGraph => "_full",
            Baseline::NsSage => "_ns",
            Baseline::ClusterGcn | Baseline::SaintRw => "_sub",
        }
    }
}

pub struct EdgeTrainer {
    pub kind: Baseline,
    pub train_art: Rc<Artifact>,
    pub infer_art: Rc<Artifact>,
    pub ds: Rc<Dataset>,
    pub model_name: String,
    pub params: Vec<Tensor>,
    opt: opt::Adam,
    rng: Rng,
    weight_clip: f32,
    // method-specific state
    partition: Vec<u32>,
    n_parts: usize,
    saint: Option<saint::SaintSampler>,
    pub stats: RunStats,
}

impl EdgeTrainer {
    pub fn new(rt: &mut Runtime, man: &Manifest, ds: Rc<Dataset>,
               model_name: &str, kind: Baseline, seed: u64) -> Result<EdgeTrainer> {
        if kind == Baseline::NsSage && model_name == "gcn" {
            anyhow::bail!("NS-SAGE is not compatible with the GCN backbone (Table 4 fn.1)");
        }
        let train_name = format!(
            "edge_train_{}_{}{}", ds.cfg.name, model_name, kind.artifact_suffix()
        );
        let infer_name = format!("edge_infer_{}_{}_full", ds.cfg.name, model_name);
        let train_art = rt.load(man, &train_name).context("load train artifact")?;
        let infer_art = rt.load(man, &infer_name).context("load infer artifact")?;
        let params = init_params(&train_art.spec, seed);
        let opt = opt::Adam::new(1e-3, &params); // OGB reference setup (App. F)
        let mut rng = Rng::new(seed ^ 0xBA5E);
        let sub_nodes = train_art.spec.nn;
        let (partition, n_parts) = if kind == Baseline::ClusterGcn {
            // clusters of ~sub_nodes/2 so a batch groups ≥2 clusters
            let parts = (ds.n() / (sub_nodes / 2).max(1)).max(2);
            (cluster::partition(&ds.graph, parts, &mut rng), parts)
        } else {
            (vec![], 0)
        };
        let saint_s = if kind == Baseline::SaintRw {
            // roots×(walk+1) ≈ sub_nodes/2 target
            let roots = (sub_nodes / 8).max(8);
            Some(saint::SaintSampler::new(&ds.graph, roots, 3, 30, &mut rng))
        } else {
            None
        };
        Ok(EdgeTrainer {
            kind,
            train_art,
            infer_art,
            model_name: model_name.to_string(),
            params,
            opt,
            rng,
            weight_clip: man.train.weight_clip as f32,
            partition,
            n_parts,
            saint: saint_s,
            stats: RunStats::default(),
            ds,
        })
    }

    fn conv(&self) -> Conv {
        match self.model_name.as_str() {
            "gcn" => Conv::GcnSym,
            "sage" => Conv::SageMean,
            _ => Conv::SageMean, // GAT: ecoef is just validity
        }
    }

    fn is_gat(&self) -> bool {
        self.model_name == "gat"
    }

    /// Subgraph for one step: (nodes, local arcs with coef, loss weights).
    fn sample_subgraph(&mut self) -> (Vec<u32>, Vec<(u32, u32, f32)>, Vec<f32>) {
        let ds = self.ds.clone();
        let g = &ds.graph;
        let cap_nodes = self.train_art.spec.nn;
        match self.kind {
            Baseline::FullGraph => {
                let nodes: Vec<u32> = (0..g.n as u32).collect();
                let mut arcs = Vec::with_capacity(g.num_arcs() + g.n);
                for v in 0..g.n {
                    for &u in g.in_neighbors(v) {
                        let coef = if self.is_gat() {
                            1.0
                        } else {
                            g.coef(self.conv(), u as usize, v)
                        };
                        arcs.push((u, v as u32, coef));
                    }
                }
                // self loops: GCN's Ã and GAT's 𝔠 = A + I
                if self.conv().with_self_loops() || self.is_gat() {
                    for v in 0..g.n {
                        let coef = if self.is_gat() {
                            1.0
                        } else {
                            g.coef(Conv::GcnSym, v, v)
                        };
                        arcs.push((v as u32, v as u32, coef));
                    }
                }
                let lam = vec![1.0; g.n];
                (nodes, arcs, lam)
            }
            Baseline::ClusterGcn => {
                // group random clusters until the capacity class is filled
                let mut group = Vec::new();
                let mut order: Vec<u32> = (0..self.n_parts as u32).collect();
                self.rng.shuffle(&mut order);
                let mut size = 0usize;
                let mut sizes = vec![0usize; self.n_parts];
                for &p in &self.partition {
                    sizes[p as usize] += 1;
                }
                for &p in &order {
                    if size + sizes[p as usize] > cap_nodes {
                        continue;
                    }
                    size += sizes[p as usize];
                    group.push(p);
                    if size > cap_nodes * 3 / 4 {
                        break;
                    }
                }
                let nodes = cluster::batch_nodes(&self.partition, &group);
                let arcs = self.induced_with_subgraph_norm(&nodes);
                let lam = vec![1.0; nodes.len()];
                (nodes, arcs, lam)
            }
            Baseline::SaintRw => {
                let s = self.saint.as_ref().unwrap();
                let (nodes, raw_arcs, lam) = s.sample(g, &mut self.rng);
                let mut nodes = nodes;
                nodes.truncate(cap_nodes);
                let keep = nodes.len() as u32;
                // subgraph normalization × SAINT α correction
                let base = self.induced_with_subgraph_norm(&nodes);
                // fold in the α edge corrections where available
                let alpha: std::collections::HashMap<(u32, u32), f32> = raw_arcs
                    .iter()
                    .filter(|&&(u, v, _)| u < keep && v < keep)
                    .map(|&(u, v, a)| ((u, v), a))
                    .collect();
                let arcs = base
                    .into_iter()
                    .map(|(u, v, c)| {
                        let a = alpha.get(&(u, v)).copied().unwrap_or(1.0);
                        // cap the variance of the unbiasedness correction
                        (u, v, c * a.clamp(0.5, 4.0))
                    })
                    .collect();
                let mut lam = lam;
                lam.truncate(cap_nodes);
                // normalize λ to mean 1 (stability at small sample counts)
                let m: f32 = lam.iter().sum::<f32>() / lam.len().max(1) as f32;
                for x in lam.iter_mut() {
                    *x /= m.max(1e-6);
                }
                (nodes, arcs, lam)
            }
            Baseline::NsSage => {
                let b_roots = (cap_nodes / 8).max(16);
                let pool = ds.nodes_in_split(Split::Train);
                let roots: Vec<u32> = (0..b_roots)
                    .map(|_| pool[self.rng.below(pool.len())])
                    .collect();
                let fanouts = [10, 5, 5];
                let s = neighbor::sample(&ds.graph, &roots, &fanouts, cap_nodes,
                                         &mut self.rng);
                // mean aggregator over the SAMPLED neighbors
                let mut indeg = vec![0u32; s.nodes.len()];
                for &(_, v) in &s.edges {
                    indeg[v as usize] += 1;
                }
                let arcs = s
                    .edges
                    .iter()
                    .map(|&(u, v)| {
                        let c = if self.is_gat() {
                            1.0
                        } else {
                            1.0 / indeg[v as usize].max(1) as f32
                        };
                        (u, v, c)
                    })
                    .collect();
                // loss only on roots
                let mut lam = vec![0.0f32; s.nodes.len()];
                for x in lam.iter_mut().take(s.n_roots) {
                    *x = 1.0;
                }
                (s.nodes, arcs, lam)
            }
        }
    }

    /// Induced subgraph arcs with the convolution re-normalized on the
    /// subgraph (Cluster-GCN / SAINT convention), plus self loops for GCN.
    fn induced_with_subgraph_norm(&mut self, nodes: &[u32]) -> Vec<(u32, u32, f32)> {
        let g = &self.ds.graph;
        let mut local = vec![-1i32; g.n];
        let pairs = g.induced_edges(nodes, &mut local);
        let nl = nodes.len();
        let mut indeg = vec![0u32; nl];
        for &(_, v) in &pairs {
            indeg[v as usize] += 1;
        }
        let conv = self.conv();
        let mut arcs: Vec<(u32, u32, f32)> = pairs
            .into_iter()
            .map(|(u, v)| {
                let c = if self.is_gat() {
                    1.0
                } else {
                    match conv {
                        Conv::GcnSym => 1.0
                            / (((indeg[u as usize] + 1) as f32
                                * (indeg[v as usize] + 1) as f32)
                                .sqrt()),
                        Conv::SageMean => 1.0 / indeg[v as usize].max(1) as f32,
                    }
                };
                (u, v, c)
            })
            .collect();
        if conv.with_self_loops() && !self.is_gat() {
            for v in 0..nl as u32 {
                arcs.push((v, v, 1.0 / (indeg[v as usize] + 1) as f32));
            }
        } else if self.is_gat() {
            for v in 0..nl as u32 {
                arcs.push((v, v, 1.0));
            }
        }
        arcs
    }

    pub fn train_step(&mut self, rt: &mut Runtime) -> Result<f32> {
        let t0 = std::time::Instant::now();
        let (nodes, arcs, lam) = self.sample_subgraph();
        let art = self.train_art.clone();
        let inputs = self.assemble(&art, &nodes, &arcs, &lam, true)?;
        let outputs = rt.execute(&art, &inputs)?;
        let loss = outputs[0].f[0];
        let n_params = self.params.len();
        let grads: Vec<&Tensor> = outputs[outputs.len() - n_params..].iter().collect();
        self.opt.step(&mut self.params, &grads);
        if self.is_gat() {
            lipschitz_clip(&art.spec, &mut self.params, self.weight_clip);
        }
        let step_bytes = art.spec.input_bytes() + art.spec.output_bytes()
            + opt::opt_state_bytes(&self.params, 2);
        self.stats.peak_step_bytes = self.stats.peak_step_bytes.max(step_bytes);
        self.stats.steps += 1;
        self.stats.loss_last = loss;
        self.stats.nodes_per_step = nodes.len() as u64;
        self.stats.messages_per_step = arcs.len() as u64;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        Ok(loss)
    }

    /// Steps per "epoch" (coverage-equivalent to one pass over the graph).
    pub fn steps_per_epoch(&self) -> usize {
        match self.kind {
            Baseline::FullGraph => 8, // converge the oracle at equal epoch counts
            _ => {
                let per = self.train_art.spec.nn.max(1);
                (self.ds.n() + per - 1) / per
            }
        }
    }

    pub fn epoch(&mut self, rt: &mut Runtime) -> Result<f32> {
        let mut last = 0.0;
        for _ in 0..self.steps_per_epoch() {
            last = self.train_step(rt)?;
        }
        Ok(last)
    }

    /// Exact full-graph inference (shared by all baselines — OGB protocol).
    pub fn infer_full(&mut self, rt: &mut Runtime) -> Result<Vec<f32>> {
        let ds = self.ds.clone();
        let g = &ds.graph;
        let art = self.infer_art.clone();
        let nodes: Vec<u32> = (0..g.n as u32).collect();
        let mut arcs = Vec::with_capacity(g.num_arcs());
        for v in 0..g.n {
            for &u in g.in_neighbors(v) {
                let coef = if self.is_gat() {
                    1.0
                } else {
                    g.coef(self.conv(), u as usize, v)
                };
                arcs.push((u, v as u32, coef));
            }
        }
        if self.conv().with_self_loops() && !self.is_gat() {
            for v in 0..g.n {
                arcs.push((v as u32, v as u32, g.coef(Conv::GcnSym, v, v)));
            }
        } else if self.is_gat() {
            for v in 0..g.n {
                arcs.push((v as u32, v as u32, 1.0));
            }
        }
        let lam = vec![1.0; g.n];
        let inputs = self.assemble(&art, &nodes, &arcs, &lam, false)?;
        let out = rt.execute(&art, &inputs)?;
        Ok(out[0].f.clone())
    }

    pub fn evaluate(&mut self, rt: &mut Runtime, split: Split) -> Result<f64> {
        use crate::coordinator::metrics;
        let ds = self.ds.clone();
        let logits = self.infer_full(rt)?;
        if ds.cfg.task == "link" {
            let h = self.infer_art.spec.outputs[0].shape[1];
            let score = |u: u32, v: u32| -> f32 {
                logits[u as usize * h..(u as usize + 1) * h]
                    .iter()
                    .zip(&logits[v as usize * h..(v as usize + 1) * h])
                    .map(|(x, y)| x * y)
                    .sum()
            };
            let pos = if split == Split::Val { &ds.val_pos } else { &ds.test_pos };
            let pos_scores: Vec<f32> = pos.iter().map(|&(u, v)| score(u, v)).collect();
            let mut rng = Rng::new(0xBEEF);
            let neg: Vec<f32> = (0..4096)
                .map(|_| score(rng.below(ds.n()) as u32, rng.below(ds.n()) as u32))
                .collect();
            return Ok(metrics::hits_at_k(&pos_scores, &neg, 50));
        }
        let rows: Vec<usize> = ds.nodes_in_split(split).iter().map(|&v| v as usize).collect();
        let c = ds.cfg.n_classes;
        if ds.cfg.multilabel {
            Ok(metrics::micro_f1(&logits, c, &ds.labels_multi, &rows))
        } else {
            Ok(metrics::accuracy(&logits, c, &ds.labels, &rows))
        }
    }

    /// Assemble the edge-artifact input list.
    fn assemble(&mut self, art: &Rc<Artifact>, nodes: &[u32],
                arcs: &[(u32, u32, f32)], lam: &[f32], train: bool)
                -> Result<Vec<Tensor>> {
        let spec = &art.spec;
        let ds = self.ds.clone();
        let (nn, ne) = (spec.nn, spec.ne);
        anyhow::ensure!(nodes.len() <= nn, "subgraph {} > artifact nn {}", nodes.len(), nn);
        anyhow::ensure!(arcs.len() <= ne, "edges {} > artifact ne {}", arcs.len(), ne);
        let f = ds.cfg.f_in_pad;
        // features padded to nn rows
        let mut x = gather_features(&ds.features, f, nodes);
        x.f.resize(nn * f, 0.0);
        x.shape = vec![nn, f];
        let mut esrc = vec![0i32; ne];
        let mut edst = vec![0i32; ne];
        let mut ecoef = vec![0.0f32; ne];
        for (i, &(u, v, c)) in arcs.iter().enumerate() {
            esrc[i] = u as i32;
            edst[i] = v as i32;
            ecoef[i] = c;
        }
        let link_pairs = if ds.cfg.task == "link" && spec.input_index("psrc").is_some() {
            Some(self.link_pairs(spec.inputs[spec.input_index("psrc").unwrap()].numel(),
                                 nodes, train))
        } else {
            None
        };
        let mut inputs = Vec::with_capacity(spec.inputs.len());
        let mut pi = 0usize;
        for ts in &spec.inputs {
            let t: Tensor = match ts.name.as_str() {
                "x" => x.clone(),
                "esrc" => Tensor::from_i32(&[ne], esrc.clone()),
                "edst" => Tensor::from_i32(&[ne], edst.clone()),
                "ecoef" => Tensor::from_f32(&[ne], ecoef.clone()),
                "y" => {
                    if ds.cfg.multilabel {
                        let c = ds.cfg.n_classes;
                        let mut data = vec![0.0f32; nn * c];
                        for (i, &v) in nodes.iter().enumerate() {
                            data[i * c..(i + 1) * c].copy_from_slice(
                                &ds.labels_multi[v as usize * c..(v as usize + 1) * c],
                            );
                        }
                        Tensor::from_f32(&[nn, c], data)
                    } else {
                        let mut data = vec![0i32; nn];
                        for (i, &v) in nodes.iter().enumerate() {
                            data[i] = ds.labels[v as usize];
                        }
                        Tensor::from_i32(&[nn], data)
                    }
                }
                "wloss" => {
                    let mut w = vec![0.0f32; nn];
                    for (i, &v) in nodes.iter().enumerate() {
                        let in_split = !train || ds.split[v as usize] == Split::Train;
                        w[i] = if in_split { lam[i] } else { 0.0 };
                    }
                    Tensor::from_f32(&[nn], w)
                }
                "psrc" => Tensor::from_i32(&ts.shape, link_pairs.as_ref().unwrap().0.clone()),
                "pdst" => Tensor::from_i32(&ts.shape, link_pairs.as_ref().unwrap().1.clone()),
                "py" => Tensor::from_f32(&ts.shape, link_pairs.as_ref().unwrap().2.clone()),
                "pw" => Tensor::from_f32(&ts.shape, link_pairs.as_ref().unwrap().3.clone()),
                name if name.starts_with("param.") => {
                    let t = self.params[pi].clone();
                    pi += 1;
                    t
                }
                other => anyhow::bail!("unknown edge input {other}"),
            };
            inputs.push(t);
        }
        Ok(inputs)
    }

    fn link_pairs(&mut self, p: usize, nodes: &[u32], train: bool)
                  -> (Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>) {
        let g = &self.ds.graph;
        let nl = nodes.len();
        let mut local = std::collections::HashMap::new();
        for (i, &v) in nodes.iter().enumerate() {
            local.insert(v, i as i32);
        }
        let mut pos = Vec::new();
        'outer: for (i, &v) in nodes.iter().enumerate() {
            for &u in g.in_neighbors(v as usize) {
                if let Some(&lu) = local.get(&u) {
                    pos.push((lu, i as i32));
                    if pos.len() >= p / 2 {
                        break 'outer;
                    }
                }
            }
        }
        let mut psrc = vec![0i32; p];
        let mut pdst = vec![0i32; p];
        let mut py = vec![0.0f32; p];
        let mut pw = vec![0.0f32; p];
        for (i, &(u, v)) in pos.iter().enumerate() {
            psrc[i] = u;
            pdst[i] = v;
            py[i] = 1.0;
            pw[i] = 1.0;
        }
        for i in pos.len()..p {
            psrc[i] = self.rng.below(nl) as i32;
            pdst[i] = self.rng.below(nl) as i32;
            pw[i] = if train { 1.0 } else { 0.0 };
        }
        (psrc, pdst, py, pw)
    }
}
